//! Model-based property tests: every table implementation is checked
//! against `std::collections::HashMap` over random operation sequences
//! (including interleaved rebuilds), with failing-seed reporting and
//! sequence shrinking. This is the "property-based tests on invariants"
//! pillar of the suite: single-threaded sequences make outcomes exactly
//! predictable, so any divergence is a real bug, and rebuilds exercise
//! the migration machinery deterministically.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use dhash::baselines::{ConcurrentMap, HtRht, HtSplit, HtXu};
use dhash::dhash::{DHashMap, HashFn, ResizeError, ShardedDHash};
use dhash::lflist::{CowSortedArray, MichaelList, SpinlockList, SplitOrderedList};
use dhash::rcu::{rcu_barrier, RcuThread};
use dhash::util::prop::{check, shrink_ops, Gen};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Op {
    Insert(u64, u64),
    Delete(u64),
    Lookup(u64),
    Upsert(u64, u64),
    Rebuild(usize, u64),
    /// Split shard `pick % shards` online (elastic sharded runs only).
    Split(u64),
    /// Merge shard `pick % shards` with its buddy (elastic runs only).
    Merge(u64),
}

fn gen_ops(g: &mut Gen, max_len: usize, key_space: u64) -> Vec<Op> {
    g.vec(max_len, |g| {
        let k = g.range(0, key_space);
        match g.usize_in(0, 12) {
            0..=3 => Op::Insert(k, g.u64() >> 1),
            4..=6 => Op::Delete(k),
            7..=8 => Op::Lookup(k),
            9..=10 => Op::Upsert(k, g.u64() >> 1),
            _ => Op::Rebuild(g.usize_in(1, 6) * 16, g.u64()),
        }
    })
}

/// `gen_ops` plus interleaved online splits and merges — the elastic
/// sharded sequences.
fn gen_elastic_ops(g: &mut Gen, max_len: usize, key_space: u64) -> Vec<Op> {
    g.vec(max_len, |g| {
        let k = g.range(0, key_space);
        match g.usize_in(0, 14) {
            0..=3 => Op::Insert(k, g.u64() >> 1),
            4..=6 => Op::Delete(k),
            7..=8 => Op::Lookup(k),
            9..=10 => Op::Upsert(k, g.u64() >> 1),
            11 => Op::Rebuild(g.usize_in(1, 6) * 16, g.u64()),
            12 => Op::Split(g.u64()),
            _ => Op::Merge(g.u64()),
        }
    })
}

/// Run `ops` against both the real table and the model; return the first
/// divergence as Err. `elastic` supplies the concrete sharded handle
/// (plus the key space, for full-sweep audits) that `Op::Split` /
/// `Op::Merge` need; without it those ops are skipped.
fn run_against_model(
    map: &dyn ConcurrentMap,
    ops: &[Op],
    elastic: Option<(&ShardedDHash, u64)>,
) -> Result<(), String> {
    let g = RcuThread::register();
    let mut model: HashMap<u64, u64> = HashMap::new();
    // Audit the whole key space against the model: every present key
    // resolves to its model value (no lost keys), every absent key reads
    // Missing (no resurrected deletes).
    let audit = |model: &HashMap<u64, u64>, i: usize, op: &Op| -> Result<(), String> {
        let Some((m, key_space)) = elastic else {
            return Ok(());
        };
        for k in 0..key_space {
            let want = model.get(&k).copied();
            let got = m.lookup(&g, k);
            if got != want {
                return Err(format!(
                    "op {i} {op:?}: post-resize key {k} -> {got:?}, model {want:?}"
                ));
            }
        }
        Ok(())
    };
    for (i, op) in ops.iter().enumerate() {
        match *op {
            Op::Insert(k, v) => {
                let want = !model.contains_key(&k);
                let got = map.insert(&g, k, v);
                if got != want {
                    return Err(format!("op {i} {op:?}: insert returned {got}, model {want}"));
                }
                if want {
                    model.insert(k, v);
                }
            }
            Op::Delete(k) => {
                let want = model.remove(&k).is_some();
                let got = map.delete(&g, k);
                if got != want {
                    return Err(format!("op {i} {op:?}: delete returned {got}, model {want}"));
                }
            }
            Op::Lookup(k) => {
                let want = model.get(&k).copied();
                let got = map.lookup(&g, k);
                if got != want {
                    return Err(format!("op {i} {op:?}: lookup {got:?}, model {want:?}"));
                }
            }
            Op::Upsert(k, v) => {
                // Last-wins overwrite-or-insert: returns whether the key
                // was newly inserted; the model afterwards holds v.
                let want = !model.contains_key(&k);
                let got = map.upsert(&g, k, v);
                if got != want {
                    return Err(format!("op {i} {op:?}: upsert returned {got}, model {want}"));
                }
                model.insert(k, v);
            }
            Op::Rebuild(nb, seed) => {
                // Single-threaded: a rebuild must always succeed and
                // preserve contents exactly.
                if !map.rebuild(&g, nb, HashFn::Seeded(seed)) {
                    return Err(format!("op {i} {op:?}: rebuild refused"));
                }
                let got_len = map.len(&g);
                if got_len != model.len() {
                    return Err(format!(
                        "op {i} {op:?}: len {got_len} != model {}",
                        model.len()
                    ));
                }
            }
            Op::Split(pick) => {
                let Some((m, _)) = elastic else { continue };
                if m.shards() >= 32 {
                    continue; // keep generated sequences shy of the cap
                }
                let s = (pick % m.shards() as u64) as usize;
                match m.split_shard(&g, s, 16, HashFn::Seeded(pick)) {
                    Ok(_) | Err(ResizeError::AtMaxDepth) => {}
                    Err(e) => {
                        return Err(format!("op {i} {op:?}: split of shard {s} failed: {e:?}"))
                    }
                }
                if m.len(&g) != model.len() {
                    return Err(format!(
                        "op {i} {op:?}: len {} != model {} after split",
                        m.len(&g),
                        model.len()
                    ));
                }
                audit(&model, i, op)?;
            }
            Op::Merge(pick) => {
                let Some((m, _)) = elastic else { continue };
                let s = (pick % m.shards() as u64) as usize;
                match m.merge_shard(&g, s, 16, HashFn::Seeded(pick ^ 1)) {
                    Ok(_) | Err(ResizeError::Unmergeable) => {}
                    Err(e) => {
                        return Err(format!("op {i} {op:?}: merge of shard {s} failed: {e:?}"))
                    }
                }
                if m.len(&g) != model.len() {
                    return Err(format!(
                        "op {i} {op:?}: len {} != model {} after merge",
                        m.len(&g),
                        model.len()
                    ));
                }
                audit(&model, i, op)?;
            }
        }
    }
    // Final audit: every model key present with the right value; len agrees.
    for (k, v) in &model {
        let got = map.lookup(&g, *k);
        if got != Some(*v) {
            return Err(format!("final audit: key {k} -> {got:?}, model {v}"));
        }
    }
    if map.len(&g) != model.len() {
        return Err(format!(
            "final audit: len {} != model {}",
            map.len(&g),
            model.len()
        ));
    }
    g.quiescent_state();
    Ok(())
}

fn fresh(table: &str) -> Arc<dyn ConcurrentMap> {
    match table {
        "dhash-michael" => Arc::new(DHashMap::<MichaelList>::with_hash(16, HashFn::Seeded(1))),
        "dhash-spinlock" => Arc::new(DHashMap::<SpinlockList>::with_hash(16, HashFn::Seeded(1))),
        "dhash-cow" => Arc::new(DHashMap::<CowSortedArray>::with_hash(16, HashFn::Seeded(1))),
        // Few outer buckets on purpose: the 64-key op stream then piles
        // enough load into each split-ordered list to double its local
        // sentinel directory mid-sequence.
        "dhash-splitord" => Arc::new(DHashMap::<SplitOrderedList>::with_hash(4, HashFn::Seeded(1))),
        "sharded" => Arc::new(ShardedDHash::with_buckets(4, 4, 1)),
        "xu" => Arc::new(HtXu::new(16, HashFn::Seeded(1))),
        "rht" => Arc::new(HtRht::new(16, HashFn::Seeded(1))),
        "split" => Arc::new(HtSplit::new(16, 1 << 20)),
        _ => unreachable!(),
    }
}

fn model_check(table: &'static str, cases: usize) {
    check(table, cases, |g| {
        let ops = gen_ops(g, 400, 64);
        let map = fresh(table);
        match run_against_model(&*map, &ops, None) {
            Ok(()) => Ok(()),
            Err(first_err) => {
                // Shrink to a minimal failing sequence for the report.
                let minimal =
                    shrink_ops(&ops, |xs| run_against_model(&*fresh(table), xs, None).is_err());
                let final_err = run_against_model(&*fresh(table), &minimal, None).unwrap_err();
                Err(format!(
                    "{first_err}\nshrunk to {} ops: {minimal:?}\n-> {final_err}",
                    minimal.len()
                ))
            }
        }
    });
    rcu_barrier();
}

/// The elastic variant: the sharded map checked with online splits and
/// merges interleaved into the op stream, the ops running through the
/// same `ConcurrentMap` surface and the resizes through the concrete
/// handle.
fn run_elastic_case(key_space: u64, ops: &[Op]) -> Result<(), String> {
    let map = ShardedDHash::with_buckets(2, 8, 1);
    run_against_model(&map, ops, Some((&map, key_space)))
}

#[test]
fn model_dhash_michael() {
    model_check("dhash-michael", 30);
}

#[test]
fn model_dhash_spinlock() {
    model_check("dhash-spinlock", 20);
}

#[test]
fn model_dhash_cow() {
    model_check("dhash-cow", 20);
}

#[test]
fn model_dhash_split_ordered() {
    model_check("dhash-splitord", 20);
}

#[test]
fn model_sharded() {
    model_check("sharded", 20);
}

#[test]
fn model_xu() {
    model_check("xu", 20);
}

#[test]
fn model_rht() {
    model_check("rht", 20);
}

#[test]
fn model_split() {
    model_check("split", 20);
}

#[test]
fn model_sharded_elastic() {
    // Splits and merges interleaved with get/insert/delete/upsert and
    // staggered rebuilds: linearizable against the sequential model at
    // every step — no lost keys, no resurrected deletes, and the
    // full-sweep audit after every resize pins "Missing is never
    // observed for a present key" in the single-threaded setting (the
    // concurrent counterpart lives in the conformance + torture suites).
    check("sharded-elastic", 15, |g| {
        let key_space = 64;
        let ops = gen_elastic_ops(g, 300, key_space);
        match run_elastic_case(key_space, &ops) {
            Ok(()) => Ok(()),
            Err(first_err) => {
                let minimal = shrink_ops(&ops, |xs| run_elastic_case(key_space, xs).is_err());
                let final_err = run_elastic_case(key_space, &minimal).unwrap_err();
                Err(format!(
                    "{first_err}\nshrunk to {} ops: {minimal:?}\n-> {final_err}",
                    minimal.len()
                ))
            }
        }
    });
    rcu_barrier();
}

#[test]
fn model_elastic_resize_heavy() {
    // Resize-dominated sequences: every few ops the directory splits or
    // merges, with inserts keeping the population non-trivial.
    check("resize heavy", 8, |g| {
        let key_space = 48;
        let ops: Vec<Op> = (0..160)
            .map(|i| match i % 6 {
                4 => {
                    if g.bool(0.5) {
                        Op::Split(g.u64())
                    } else {
                        Op::Merge(g.u64())
                    }
                }
                5 => Op::Delete(g.range(0, key_space)),
                _ => Op::Insert(g.range(0, key_space), i as u64),
            })
            .collect();
        run_elastic_case(key_space, &ops)
    });
    rcu_barrier();
}

#[test]
fn model_dense_key_collisions() {
    // Tiny key space (8 keys) forces constant insert/delete collisions
    // and same-bucket churn.
    check("dense keys", 20, |g| {
        let ops = gen_ops(g, 600, 8);
        run_against_model(&*fresh("dhash-michael"), &ops, None)
    });
    rcu_barrier();
}

// ---------------------------------------------------------------------
// Relaxed-ordering audit cases (DESIGN.md §Memory orderings): one
// concurrent pin per relaxed cluster. These are the tests the ordering
// table cites — if a future edit weakens an Acquire/Release pair below
// what its documented invariant needs, the lost happens-before edge
// shows up here as a lost key or an incoherent epoch, not as silent UB
// in production.
// ---------------------------------------------------------------------

#[test]
fn ordering_audit_lookup_during_rebuild() {
    // Cluster R1+R2 (dhash table pointers + lflist link words at
    // Acquire/Release, Lemma 4.1 without SeqCst): a key inserted once
    // and never deleted must resolve in EVERY interleaving with a
    // continuous rebuild storm — the three-step lookup order relies on
    // the Release `rebuild_cur` store being visible to any reader that
    // missed the key via the unlink CAS chain.
    let map = Arc::new(DHashMap::<MichaelList>::with_hash(8, HashFn::Seeded(1)));
    let keys: Vec<u64> = (0..64u64).map(|i| i * 7 + 1).collect();
    {
        let g = RcuThread::register();
        for &k in &keys {
            assert!(map.insert(&g, k, k + 1).is_ok());
        }
        g.quiescent_state();
    }
    let stop = AtomicBool::new(false);
    std::thread::scope(|s| {
        for _ in 0..2 {
            let map = map.clone();
            let stop = &stop;
            let keys = &keys;
            s.spawn(move || {
                let g = RcuThread::register();
                while !stop.load(Ordering::Relaxed) {
                    for &k in keys {
                        assert_eq!(map.lookup(&g, k), Some(k + 1), "key {k} lost mid-rebuild");
                    }
                    g.quiescent_state();
                }
            });
        }
        let map = map.clone();
        let stop = &stop;
        s.spawn(move || {
            let g = RcuThread::register();
            let rounds = dhash::util::miri_clamp(40, 4) as u64;
            for i in 0..rounds {
                let nb = if i % 2 == 0 { 16 } else { 8 };
                map.rebuild(&g, nb, HashFn::Seeded(i)).unwrap();
            }
            stop.store(true, Ordering::Relaxed);
            g.quiescent_state();
        });
    });
    rcu_barrier();
}

#[test]
fn ordering_audit_lookup_during_split_merge() {
    // Cluster R3 (sharded directory pointer + `moving` hazard pointer at
    // Acquire/Release): resident keys must resolve through the
    // source → hazard node → destination order while the directory
    // splits and merges underneath — the Acquire `moving` load must see
    // the key/flags of a node published by the drain's Release store.
    let map = Arc::new(ShardedDHash::with_buckets(4, 8, 1));
    let keys: Vec<u64> = (0..128u64).map(|i| i * 13 + 1).collect();
    {
        let g = RcuThread::register();
        for &k in &keys {
            map.insert(&g, k, k ^ 0xabc).unwrap();
        }
        g.quiescent_state();
    }
    let stop = AtomicBool::new(false);
    std::thread::scope(|s| {
        for _ in 0..2 {
            let map = map.clone();
            let stop = &stop;
            let keys = &keys;
            s.spawn(move || {
                let g = RcuThread::register();
                while !stop.load(Ordering::Relaxed) {
                    for &k in keys {
                        assert_eq!(map.lookup(&g, k), Some(k ^ 0xabc), "key {k} lost mid-resize");
                    }
                    g.quiescent_state();
                }
            });
        }
        let map = map.clone();
        let stop = &stop;
        s.spawn(move || {
            let g = RcuThread::register();
            let rounds = dhash::util::miri_clamp(12, 3) as u64;
            for i in 0..rounds {
                let s = (i as usize) % map.shards().max(1);
                let _ = map.split_shard(&g, s, 8, HashFn::Seeded(i));
                let _ = map.merge_shard(&g, s, 8, HashFn::Seeded(i ^ 1));
                g.quiescent_state();
            }
            stop.store(true, Ordering::Relaxed);
        });
    });
    rcu_barrier();
}

#[test]
fn ordering_audit_snapshot_vs_epoch() {
    // Cluster R3's mirrors-first invariant (install_dir at Release): a
    // route snapshot's epoch must stay coherent with the guard-free
    // `epoch()` mirror under concurrent publications. The mirror is
    // written BEFORE the directory pointer, so it may lead the snapshot
    // by at most the one in-flight publication (single migration token)
    // and can never trail it — and the snapshot itself must always be
    // internally coherent.
    let map = Arc::new(ShardedDHash::with_buckets(2, 8, 1));
    {
        let g = RcuThread::register();
        for k in 0..64u64 {
            map.insert(&g, k * 3 + 1, k).unwrap();
        }
        g.quiescent_state();
    }
    let stop = AtomicBool::new(false);
    std::thread::scope(|s| {
        {
            let map = map.clone();
            let stop = &stop;
            s.spawn(move || {
                let g = RcuThread::register();
                let mut last_seen = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    let e_before = map.epoch();
                    let snap = map.route_snapshot(&g);
                    let e_after = map.epoch();
                    assert!(e_after >= e_before, "mirror epoch went backwards");
                    assert!(
                        snap.epoch <= e_after,
                        "snapshot epoch {} ahead of mirror {e_after}: the mirror \
                         store must be sequenced before the directory publish",
                        snap.epoch
                    );
                    assert!(
                        snap.epoch + 1 >= e_before,
                        "snapshot epoch {} trails mirror {e_before} by more than \
                         the one in-flight publication",
                        snap.epoch
                    );
                    assert!(
                        snap.epoch >= last_seen,
                        "snapshot epochs must be monotone per observer"
                    );
                    last_seen = snap.epoch;
                    // Internal coherence: one geometry + uid per shard,
                    // every selector routes to a live ordinal.
                    assert_eq!(snap.shards.len(), snap.uids.len());
                    assert!(snap.nshards() >= 1);
                    for k in [0u64, 1, 97, 1 << 40, u64::MAX - 1] {
                        assert!((snap.shard_of(k) as usize) < snap.nshards());
                    }
                    g.quiescent_state();
                }
            });
        }
        let map = map.clone();
        let stop = &stop;
        s.spawn(move || {
            let g = RcuThread::register();
            let rounds = dhash::util::miri_clamp(10, 3) as u64;
            for i in 0..rounds {
                let _ = map.split_shard(&g, 0, 8, HashFn::Seeded(i));
                let _ = map.merge_shard(&g, 0, 8, HashFn::Seeded(i ^ 1));
                g.quiescent_state();
            }
            stop.store(true, Ordering::Relaxed);
        });
    });
    rcu_barrier();
}

#[test]
fn model_rebuild_heavy() {
    // Rebuild-dominated sequences: every few ops the table migrates.
    check("rebuild heavy", 10, |g| {
        let map = fresh("dhash-michael");
        let ops: Vec<Op> = (0..200)
            .map(|i| {
                if i % 5 == 4 {
                    Op::Rebuild(g.usize_in(1, 8) * 8, g.u64())
                } else {
                    Op::Insert(g.range(0, 32), i as u64)
                }
            })
            .collect();
        run_against_model(&*map, &ops, None)
    });
    rcu_barrier();
}
