//! Replay the golden kernel vectors emitted by the Python reference
//! implementation (`python/tests/gen_golden.py`, backed by
//! `python/compile/kernels/ref.py`) against the native detector engine.
//! This is the cross-language contract the multi-backend refactor rests
//! on: the pure-Rust kernels must be indistinguishable from the oracle
//! the Pallas kernels are themselves tested against.

use dhash::runtime::{Engine, HashKind, NativeEngine};

const GOLDEN: &str = include_str!("golden/kernel_vectors.txt");

fn kv(token: &str, key: &str) -> u64 {
    let (k, v) = token.split_once('=').expect("key=value token");
    assert_eq!(k, key, "expected {key}= in golden header");
    v.parse().unwrap_or_else(|_| panic!("bad {key} value {v:?}"))
}

fn numbers<T: std::str::FromStr>(line: &str, tag: &str) -> Vec<T>
where
    T::Err: std::fmt::Debug,
{
    let rest = line
        .strip_prefix(tag)
        .unwrap_or_else(|| panic!("expected line tagged {tag:?}, got {line:?}"));
    rest.split_whitespace()
        .map(|t| t.parse().expect("numeric token"))
        .collect()
}

fn kind_of(tag: u64) -> HashKind {
    match tag {
        0 => HashKind::Modulo,
        1 => HashKind::Seeded,
        other => panic!("unknown kind tag {other}"),
    }
}

#[test]
fn native_engine_matches_python_reference() {
    let mut lines = GOLDEN.lines().filter(|l| !l.starts_with('#'));
    let mut batch_cases = 0;
    let mut multi_cases = 0;
    let mut detector_cases = 0;

    while let Some(header) = lines.next() {
        let mut toks = header.split_whitespace();
        match toks.next() {
            Some("batch_hash_multi") => {
                let nshards = kv(toks.next().unwrap(), "nshards") as usize;
                let seeds: Vec<u64> = numbers(lines.next().unwrap(), "seeds ");
                let nbuckets: Vec<u64> = numbers(lines.next().unwrap(), "nbuckets ");
                let kinds: Vec<u64> = numbers(lines.next().unwrap(), "kinds ");
                let keys: Vec<u64> = numbers(lines.next().unwrap(), "keys ");
                let shard_ids: Vec<u32> = numbers(lines.next().unwrap(), "shard_ids ");
                let want: Vec<i64> = numbers(lines.next().unwrap(), "ids ");
                assert_eq!(seeds.len(), nshards, "bad multi header: {header}");
                let params: Vec<_> = (0..nshards)
                    .map(|s| (seeds[s], nbuckets[s], kind_of(kinds[s])))
                    .collect();
                let engine = NativeEngine::new();
                let got = engine.batch_hash_multi(&keys, &shard_ids, &params).unwrap();
                assert_eq!(got, want, "batch_hash_multi mismatch: {header}");
                multi_cases += 1;
            }
            Some("batch_hash") => {
                let kind = kind_of(kv(toks.next().unwrap(), "kind"));
                let seed = kv(toks.next().unwrap(), "seed");
                let nbuckets = kv(toks.next().unwrap(), "nbuckets");
                let keys: Vec<u64> = numbers(lines.next().unwrap(), "keys ");
                let want: Vec<i32> = numbers(lines.next().unwrap(), "ids ");
                let engine = NativeEngine::new();
                let got = engine.batch_hash(&keys, seed, nbuckets, kind).unwrap();
                assert_eq!(got, want, "batch_hash mismatch: {header}");
                batch_cases += 1;
            }
            Some("detector") => {
                let kind = kind_of(kv(toks.next().unwrap(), "kind"));
                let seed = kv(toks.next().unwrap(), "seed");
                let nbuckets = kv(toks.next().unwrap(), "nbuckets");
                let nbins = kv(toks.next().unwrap(), "nbins") as usize;
                let n = kv(toks.next().unwrap(), "n") as usize;
                let keys: Vec<u64> = numbers(lines.next().unwrap(), "keys ");
                assert_eq!(keys.len(), n, "key count mismatch: {header}");
                let chi2: Vec<f64> = numbers(lines.next().unwrap(), "chi2 ");
                let max_load: Vec<i32> = numbers(lines.next().unwrap(), "max_load ");
                let hist: Vec<i32> = numbers(lines.next().unwrap(), "hist ");

                let engine = NativeEngine::with_shape(keys.len().max(1), nbins);
                let got = engine.detect(&keys, seed, nbuckets, kind).unwrap();
                assert_eq!(got.hist, hist, "hist mismatch: {header}");
                assert_eq!(got.max_load, max_load[0], "max_load mismatch: {header}");
                let want = chi2[0];
                let rel = (got.chi2 as f64 - want).abs() / want.max(1e-9);
                assert!(
                    rel < 1e-4,
                    "chi2 mismatch: {header}: got {} want {want}",
                    got.chi2
                );
                detector_cases += 1;
            }
            other => panic!("unknown golden record {other:?}"),
        }
    }
    assert!(batch_cases >= 10, "only {batch_cases} batch_hash cases");
    assert!(multi_cases >= 2, "only {multi_cases} batch_hash_multi cases");
    assert!(detector_cases >= 3, "only {detector_cases} detector cases");
}
