//! Heavy cross-table integration stress: all four tables under the
//! torture framework with continuous rebuilds, verifying throughput is
//! produced, rebuilds complete, and populations survive.

use std::sync::Arc;
use std::time::Duration;

use dhash::baselines::{ConcurrentMap, HtRht, HtSplit, HtXu};
use dhash::dhash::{DHashMap, HashFn};
use dhash::rcu::{rcu_barrier, RcuThread};
use dhash::torture::{self, OpMix, RebuildMode, TortureConfig};

fn cfg(threads: usize, lookup: u8, alpha: usize) -> TortureConfig {
    TortureConfig {
        threads,
        mix: OpMix::lookup_pct(lookup),
        alpha,
        nbuckets: 256,
        key_range: 0, // auto: stationary 2·α·β
        duration: Duration::from_millis(250),
        rebuild: RebuildMode::Continuous { alt_nbuckets: 512 },
        pin: false,
        seed: 3,
        hash_seed: 9,
    }
}

fn tables(nbuckets: usize, seed: u64) -> Vec<Arc<dyn ConcurrentMap>> {
    vec![
        Arc::new(DHashMap::with_buckets(nbuckets, seed)),
        Arc::new(HtXu::new(nbuckets, HashFn::Seeded(seed))),
        Arc::new(HtRht::new(nbuckets, HashFn::Seeded(seed))),
        Arc::new(HtSplit::new(nbuckets, 1 << 20)),
    ]
}

#[test]
fn all_tables_survive_torture_with_rebuilds() {
    let c = cfg(3, 90, 8);
    for map in tables(c.nbuckets, c.hash_seed) {
        let target = torture::prefill(&*map, &c);
        let rep = torture::run(map.clone(), &c);
        assert!(rep.total_ops > 1_000, "{}: {} ops", rep.table, rep.total_ops);
        // Population stays in the same ballpark (insert% == delete%).
        let g = RcuThread::register();
        let after = map.len(&g) as f64;
        g.quiescent_state();
        assert!(
            (after - target as f64).abs() / target as f64 <= 0.6,
            "{}: population drifted {target} -> {after}",
            rep.table
        );
    }
    rcu_barrier();
}

#[test]
fn update_heavy_mix_with_rebuilds() {
    // 0% lookups: pure insert/delete churn under continuous rebuilding —
    // the paper's "heavy workload" stressor taken to the extreme.
    let c = cfg(2, 0, 16);
    for map in tables(c.nbuckets, c.hash_seed) {
        torture::prefill(&*map, &c);
        let rep = torture::run(map.clone(), &c);
        assert!(rep.total_ops > 500, "{}: {} ops", rep.table, rep.total_ops);
    }
    rcu_barrier();
}

#[test]
fn dhash_high_load_factor_torture() {
    // α = 200: the heavy regime where the paper's headline 2.3-6.2x lives.
    let c = cfg(2, 90, 200);
    let map: Arc<dyn ConcurrentMap> = Arc::new(DHashMap::with_buckets(c.nbuckets, c.hash_seed));
    torture::prefill(&*map, &c);
    let rep = torture::run(map.clone(), &c);
    assert!(rep.total_ops > 1_000);
    assert!(rep.rebuilds > 0, "no rebuild completed at alpha=200");
    rcu_barrier();
}

#[test]
fn no_node_leaks_after_full_cycle() {
    use dhash::lflist::mem_stats;
    rcu_barrier();
    let before = mem_stats::live();
    {
        let c = cfg(2, 80, 8);
        let map: Arc<dyn ConcurrentMap> = Arc::new(DHashMap::with_buckets(c.nbuckets, c.hash_seed));
        torture::prefill(&*map, &c);
        torture::run(map.clone(), &c);
        drop(map);
    }
    rcu_barrier();
    let after = mem_stats::live();
    assert!(
        after <= before + 64,
        "suspected node leak: live {before} -> {after}"
    );
}
