//! Heavy cross-table integration stress: all four tables under the
//! torture framework with continuous rebuilds, verifying throughput is
//! produced, rebuilds complete, and populations survive.

use std::sync::Arc;
use std::time::Duration;

use dhash::baselines::{ConcurrentMap, HtRht, HtSplit, HtXu};
use dhash::dhash::{DHashMap, HashFn, RebuildBusy, ShardedDHash};
use dhash::lflist::SplitOrderedList;
use dhash::rcu::{rcu_barrier, RcuThread};
use dhash::torture::{self, OpMix, RebuildMode, TortureConfig};

fn cfg(threads: usize, lookup: u8, alpha: usize) -> TortureConfig {
    TortureConfig {
        threads,
        mix: OpMix::lookup_pct(lookup),
        alpha,
        nbuckets: 256,
        key_range: 0, // auto: stationary 2·α·β
        duration: Duration::from_millis(250),
        rebuild: RebuildMode::Continuous { alt_nbuckets: 512 },
        pin: false,
        seed: 3,
        hash_seed: 9,
    }
}

fn tables(nbuckets: usize, seed: u64) -> Vec<Arc<dyn ConcurrentMap>> {
    vec![
        Arc::new(DHashMap::with_buckets(nbuckets, seed)),
        // Same total bucket budget, split over 4 shards: the torture
        // rebuilder drives the staggered rebuild_all through the trait.
        Arc::new(ShardedDHash::with_buckets(4, nbuckets / 4, seed)),
        // DHash over the split-ordered backend: full-table rebuilds
        // racing the backend's own local sentinel-directory growth.
        Arc::new(DHashMap::<SplitOrderedList>::with_hash(
            nbuckets,
            HashFn::Seeded(seed),
        )),
        Arc::new(HtXu::new(nbuckets, HashFn::Seeded(seed))),
        Arc::new(HtRht::new(nbuckets, HashFn::Seeded(seed))),
        Arc::new(HtSplit::new(nbuckets, 1 << 20)),
    ]
}

#[test]
fn all_tables_survive_torture_with_rebuilds() {
    let c = cfg(3, 90, 8);
    for map in tables(c.nbuckets, c.hash_seed) {
        let target = torture::prefill(&*map, &c);
        let rep = torture::run(map.clone(), &c);
        assert!(rep.total_ops > 1_000, "{}: {} ops", rep.table, rep.total_ops);
        // Population stays in the same ballpark (insert% == delete%).
        let g = RcuThread::register();
        let after = map.len(&g) as f64;
        g.quiescent_state();
        assert!(
            (after - target as f64).abs() / target as f64 <= 0.6,
            "{}: population drifted {target} -> {after}",
            rep.table
        );
    }
    rcu_barrier();
}

#[test]
fn update_heavy_mix_with_rebuilds() {
    // 0% lookups: pure insert/delete churn under continuous rebuilding —
    // the paper's "heavy workload" stressor taken to the extreme.
    let c = cfg(2, 0, 16);
    for map in tables(c.nbuckets, c.hash_seed) {
        torture::prefill(&*map, &c);
        let rep = torture::run(map.clone(), &c);
        assert!(rep.total_ops > 500, "{}: {} ops", rep.table, rep.total_ops);
    }
    rcu_barrier();
}

#[test]
fn dhash_high_load_factor_torture() {
    // α = 200: the heavy regime where the paper's headline 2.3-6.2x lives.
    let c = cfg(2, 90, 200);
    let map: Arc<dyn ConcurrentMap> = Arc::new(DHashMap::with_buckets(c.nbuckets, c.hash_seed));
    torture::prefill(&*map, &c);
    let rep = torture::run(map.clone(), &c);
    assert!(rep.total_ops > 1_000);
    assert!(rep.rebuilds > 0, "no rebuild completed at alpha=200");
    rcu_barrier();
}

#[test]
fn staggered_rebuild_migrates_one_shard_at_a_time() {
    // The staggered-rebuild invariant, observed from outside while a
    // whole-map sweep races targeted rebuilds: the `migrating` gauge
    // never exceeds 1 (the assert on every migration-gauge acquisition
    // inside ShardedDHash is the hard proof — tripping it aborts this
    // test), and targeted
    // rebuilds attempted mid-migration report RebuildBusy instead of
    // overlapping.
    use std::sync::atomic::{AtomicBool, Ordering};

    let map = Arc::new(ShardedDHash::with_buckets(8, 64, 5));
    {
        let g = RcuThread::register();
        for k in 0..4_000u64 {
            map.insert(&g, k, k).unwrap();
        }
        g.quiescent_state();
    }
    let done = Arc::new(AtomicBool::new(false));
    let m2 = map.clone();
    let d2 = done.clone();
    let sweeper = std::thread::spawn(move || {
        let g = RcuThread::register();
        for i in 0..4u64 {
            m2.rebuild_all(&g, 64, HashFn::Seeded(100 + i)).unwrap();
            g.quiescent_state();
        }
        d2.store(true, Ordering::Relaxed);
        g.offline();
    });
    let g = RcuThread::register();
    let (mut targeted_ok, mut busy) = (0u64, 0u64);
    while !done.load(Ordering::Relaxed) {
        assert!(map.migrating_shards() <= 1, "two shards migrating at once");
        match map.rebuild_shard(&g, 3, 64, HashFn::Seeded(7)) {
            Ok(_) => targeted_ok += 1,
            Err(RebuildBusy) => busy += 1,
        }
        // Back off OFFLINE between attempts: a tight try_lock loop could
        // barge the token away from the blocked sweeper indefinitely, and
        // sleeping online would stall its grace periods.
        g.offline_while(|| std::thread::sleep(Duration::from_millis(1)));
        g.quiescent_state();
    }
    // Join OFFLINE so a straggling grace period can never wait on this
    // thread's online-but-blocked record.
    g.offline_while(|| sweeper.join()).unwrap();
    assert!(targeted_ok + busy > 0, "main thread never contended");
    // Everything survived 4 sweeps + the targeted churn.
    assert_eq!(map.len(&g), 4_000);
    g.quiescent_state();
    rcu_barrier();
}

#[test]
fn elastic_torture_splits_and_merges_under_churn() {
    // The elastic mode end to end: zipf toggle workers + a colliding
    // attack stream churn while the resizer splits to 8 shards and
    // merges back, repeatedly. Every invariant (pinned keys always
    // resolve, snapshot/bucket_loads coherent across epochs, exact
    // final population, at most one migration in flight) is asserted
    // inside run_elastic; here we additionally require that real resize
    // traffic happened.
    use dhash::torture::ElasticTortureConfig;
    let map = Arc::new(ShardedDHash::with_buckets(2, 32, 21));
    let cfg = ElasticTortureConfig {
        threads: 3,
        duration: Duration::from_millis(350),
        resize_every: Duration::from_millis(2),
        ..Default::default()
    }
    .clamped_for_smoke();
    let report = torture::run_elastic(map.clone(), &cfg);
    assert!(report.total_ops > 1_000, "ops {}", report.total_ops);
    assert!(report.splits >= 1, "no split completed");
    assert!(report.merges >= 1, "no merge completed");
    assert_eq!(report.final_epoch, report.splits + report.merges);
    rcu_barrier();
}

#[test]
fn elastic_torture_over_split_ordered_buckets() {
    // The same elastic storm with every shard's buckets backed by the
    // split-ordered list: directory-level splits/merges race the
    // backend's own local sentinel-directory growth, and every
    // run_elastic invariant must still hold.
    use dhash::torture::ElasticTortureConfig;
    let map = Arc::new(ShardedDHash::<SplitOrderedList>::with_hash(
        2,
        32,
        HashFn::Seeded(21),
    ));
    let cfg = ElasticTortureConfig {
        threads: 3,
        duration: Duration::from_millis(350),
        resize_every: Duration::from_millis(2),
        ..Default::default()
    }
    .clamped_for_smoke();
    let report = torture::run_elastic(map.clone(), &cfg);
    assert!(report.total_ops > 1_000, "ops {}", report.total_ops);
    assert!(report.splits >= 1, "no split completed");
    assert!(report.merges >= 1, "no merge completed");
    assert_eq!(report.final_epoch, report.splits + report.merges);
    rcu_barrier();
}

#[test]
fn no_node_leaks_after_full_cycle() {
    use dhash::lflist::mem_stats;
    rcu_barrier();
    let before = mem_stats::live();
    {
        let c = cfg(2, 80, 8);
        let map: Arc<dyn ConcurrentMap> = Arc::new(DHashMap::with_buckets(c.nbuckets, c.hash_seed));
        torture::prefill(&*map, &c);
        torture::run(map.clone(), &c);
        drop(map);
    }
    rcu_barrier();
    let after = mem_stats::live();
    assert!(
        after <= before + 64,
        "suspected node leak: live {before} -> {after}"
    );
}
