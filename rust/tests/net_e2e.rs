//! End-to-end wire-protocol tests over loopback: pipelined clients
//! against a real `NetServer` + `Coordinator`, spanning a mid-stream
//! mitigation rebuild, overload shedding, graceful drain, and protocol
//! failure. Linux-only (the listener backend is epoll).

#![cfg(target_os = "linux")]

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

use dhash::coordinator::{Coordinator, CoordinatorConfig};
use dhash::dhash::HashFn;
use dhash::error::KvError;
use dhash::net::bench::verify_run;
use dhash::net::codec::Decoder;
use dhash::net::proto::{Request, RequestFrame, Response};
use dhash::net::{NetConfig, NetServer};

fn start(shards: usize, window: usize) -> (Coordinator, NetServer, SocketAddr) {
    let cfg = CoordinatorConfig {
        shards,
        lanes: 2,
        enable_analytics: false, // rebuilds are forced, not detected
        ..Default::default()
    };
    let c = Coordinator::start(cfg).expect("coordinator starts");
    let net_cfg = NetConfig {
        inflight_window: window,
        ..Default::default()
    };
    let net = NetServer::start(&net_cfg, c.client()).expect("listener binds");
    let addr = net.local_addr().expect("bound address");
    (c, net, addr)
}

/// The tentpole acceptance run: 8 connections × depth-8 pipelining,
/// self-validating phased workload, with hash-replacement rebuilds
/// forced mid-stream. Zero lost, reordered, or wrong responses.
#[test]
fn pipelined_connections_span_a_rebuild_without_loss() {
    let (c, net, addr) = start(4, 64);
    let hs: Vec<_> = (0..8u64)
        .map(|i| std::thread::spawn(move || verify_run(addr, i << 32, 96, 8)))
        .collect();
    // Force mitigation-style rebuilds while the clients are mid-flight.
    let mut rebuilds = 0;
    for r in 0..6u64 {
        std::thread::sleep(Duration::from_millis(5));
        if c.force_rebuild(4096, HashFn::Seeded(0xFEED ^ r)).is_ok() {
            rebuilds += 1;
        }
    }
    assert!(rebuilds > 0, "no rebuild overlapped the run");
    for h in hs {
        let rep = h.join().expect("client panicked").expect("client io");
        assert_eq!(rep.sent, 96 * 4);
        assert_eq!(rep.received, rep.sent, "lost responses: {rep:?}");
        assert_eq!(rep.reorders, 0, "reordered responses: {rep:?}");
        assert_eq!(rep.mismatches, 0, "wrong responses: {rep:?}");
        assert_eq!(rep.sheds + rep.errors, 0, "unexpected failures: {rep:?}");
    }
    let ns = net.shutdown();
    assert_eq!(ns.frames_in, 8 * 96 * 4);
    assert_eq!(ns.frames_out, ns.frames_in, "every request answered exactly once");
    c.shutdown();
}

/// A burst deeper than the inflight window is shed with the overload
/// wire code — responses stay in order and the connection stays open.
#[test]
fn overload_sheds_with_wire_code_and_keeps_the_connection() {
    let (c, net, addr) = start(1, 4);
    let mut s = TcpStream::connect(addr).expect("connect");
    s.set_nodelay(true).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();

    // One write → one server drain: far more requests than the window.
    let mut wire = Vec::new();
    for i in 0..64u64 {
        RequestFrame::new(i + 1, Request::put(i, i)).encode(&mut wire);
    }
    s.write_all(&wire).expect("burst write");

    let mut dec = Decoder::new();
    let mut got = Vec::new();
    let mut buf = [0u8; 4096];
    while got.len() < 64 {
        let n = s.read(&mut buf).expect("read responses");
        assert!(n > 0, "server closed mid-burst");
        dec.push(&buf[..n]);
        while let Some(f) = dec.next_response().expect("valid response frame") {
            got.push(f);
        }
    }
    let shed = KvError::Overloaded.code();
    let mut sheds = 0;
    for (i, f) in got.iter().enumerate() {
        assert_eq!(f.id, i as u64 + 1, "responses out of request order");
        match f.body {
            Ok(Response::Ok) => {}
            Err(code) if code == shed => sheds += 1,
            other => panic!("unexpected response body {other:?}"),
        }
    }
    assert!(sheds >= 1, "a 64-deep burst into a window of 4 must shed");
    assert!(sheds < 64, "some requests must still be accepted");

    // Shed-on-full is backpressure, not disconnection: the same
    // connection still serves requests.
    let mut wire = Vec::new();
    RequestFrame::new(999, Request::get(0)).encode(&mut wire);
    s.write_all(&wire).expect("follow-up write");
    let f = loop {
        if let Some(f) = dec.next_response().expect("valid follow-up frame") {
            break f;
        }
        let n = s.read(&mut buf).expect("read follow-up");
        assert!(n > 0, "server closed after shedding");
        dec.push(&buf[..n]);
    };
    assert_eq!(f.id, 999);
    assert!(f.body.is_ok(), "connection unusable after sheds: {f:?}");

    net.shutdown();
    c.shutdown();
}

/// Shutdown drains: every ingested request is answered (executed or
/// shutdown-coded), responses flush, then the server FINs.
#[test]
fn graceful_drain_answers_pending_then_fins() {
    let (c, net, addr) = start(1, 256);
    let mut s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut wire = Vec::new();
    for i in 0..32u64 {
        RequestFrame::new(i + 1, Request::put(i, i)).encode(&mut wire);
    }
    s.write_all(&wire).expect("write burst");
    std::thread::sleep(Duration::from_millis(50)); // let the server ingest
    let ns = net.shutdown();

    let mut buf = Vec::new();
    s.read_to_end(&mut buf).expect("responses then FIN");
    let mut dec = Decoder::new();
    dec.push(&buf);
    let mut got = Vec::new();
    while let Some(f) = dec.next_response().expect("valid response frame") {
        got.push(f);
    }
    assert_eq!(got.len(), 32, "drain lost responses");
    let down = KvError::Shutdown.code();
    for (i, f) in got.iter().enumerate() {
        assert_eq!(f.id, i as u64 + 1, "drain reordered responses");
        assert!(
            f.body == Ok(Response::Ok) || f.body == Err(down),
            "unexpected drain response {f:?}"
        );
    }
    assert_eq!(ns.frames_out, 32);
    c.shutdown();
}

/// Garbage on the wire: one error frame (id 0, the protocol error's
/// wire code), then the server closes the connection.
#[test]
fn protocol_error_answers_with_code_then_closes() {
    let (c, net, addr) = start(1, 256);
    let mut s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    s.write_all(&[0xFF, 0x00, 0x00, 0x00]).expect("write garbage");

    let mut buf = Vec::new();
    s.read_to_end(&mut buf).expect("error frame then FIN");
    let mut dec = Decoder::new();
    dec.push(&buf);
    let f = dec
        .next_response()
        .expect("valid error frame")
        .expect("exactly one frame before close");
    assert_eq!(f.id, 0, "no trustworthy request id exists");
    assert_eq!(
        f.body,
        Err(KvError::Protocol(dhash::error::ProtoError::BadMagic(0xFF)).code())
    );
    assert_eq!(dec.pending(), 0);
    assert!(dec.next_response().unwrap().is_none());

    let ns = net.shutdown();
    assert_eq!(ns.protocol_errors, 1);
    c.shutdown();
}
