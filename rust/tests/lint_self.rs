//! Self-tests for `dhash-lint` (`rust/src/lint/`).
//!
//! Two layers:
//!
//! 1. **Fixtures** (`tests/lint_fixtures/`): one deliberately-bad file
//!    per rule, fed through [`LintContext::from_sources`] under a
//!    synthetic path that puts it in the rule's scope. Each test
//!    asserts the *exact* rendered diagnostics — these strings are the
//!    tool's UI contract.
//! 2. **The real tree**: the shipped source must lint clean, and a
//!    deliberate one-line drift in any contract table
//!    (DESIGN.md §Memory orderings, §Error codes, §Lock order,
//!    §Reclamation contract) or in the SeqCst allowlist must fail —
//!    in both directions.

use std::path::Path;

use dhash::lint::{self, LintContext};

/// Render a rule's findings, sorted, as display strings.
fn render(mut diags: Vec<lint::Diagnostic>) -> Vec<String> {
    diags.sort();
    diags.iter().map(|d| d.to_string()).collect()
}

fn load_real_tree() -> LintContext {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("rust/ has a parent")
        .to_path_buf();
    LintContext::load(&root).expect("real tree loads")
}

// ---------------------------------------------------------------- fixtures

#[test]
fn fixture_missing_safety() {
    let ctx = LintContext::from_sources(
        &[(
            "rust/tests/lint_fixtures/missing_safety.rs",
            include_str!("lint_fixtures/missing_safety.rs"),
        )],
        "",
        "",
    );
    assert_eq!(
        render(lint::safety::check(&ctx)),
        vec![
            "rust/tests/lint_fixtures/missing_safety.rs:5: [safety] \
             unsafe site without an adjacent // SAFETY: comment"
                .to_string()
        ]
    );
}

#[test]
fn fixture_unannotated_ordering() {
    // The synthetic path puts the fixture inside the `ord` scope
    // (`rust/src/dhash/`); the synthetic DESIGN table indexes the one
    // key the compliant fn uses.
    let design = "## Memory orderings\n\n\
                  | site | ordering | why |\n|---|---|---|\n\
                  | fixture row — `ord:fixture-key` | Relaxed | test |\n";
    let ctx = LintContext::from_sources(
        &[(
            "rust/src/dhash/unannotated_ordering.rs",
            include_str!("lint_fixtures/unannotated_ordering.rs"),
        )],
        design,
        "",
    );
    assert_eq!(
        render(lint::ord::check(&ctx)),
        vec![
            "rust/src/dhash/unannotated_ordering.rs:7: [ord] \
             Ordering site without an // ord: annotation (see DESIGN.md §Memory orderings)"
                .to_string()
        ]
    );
}

#[test]
fn fixture_over_budget_seqcst() {
    let ctx = LintContext::from_sources(
        &[(
            "rust/src/rcu/over_budget_seqcst.rs",
            include_str!("lint_fixtures/over_budget_seqcst.rs"),
        )],
        "",
        "rust/src/rcu/over_budget_seqcst.rs 1\n",
    );
    assert_eq!(
        render(lint::seqcst::check(&ctx)),
        vec![
            "rust/src/rcu/over_budget_seqcst.rs:7: [seqcst-budget] \
             2 SeqCst site(s); allowlist budgets 1"
                .to_string()
        ]
    );
}

#[test]
fn fixture_hot_alloc() {
    let ctx = LintContext::from_sources(
        &[(
            "rust/tests/lint_fixtures/hot_alloc.rs",
            include_str!("lint_fixtures/hot_alloc.rs"),
        )],
        "",
        "",
    );
    assert_eq!(
        render(lint::hot::check(&ctx)),
        vec![
            "rust/tests/lint_fixtures/hot_alloc.rs:6: [hot] \
             fn 'lookup_fast' is tagged // lint: hot but uses denied operation 'Box::new'"
                .to_string()
        ]
    );
}

#[test]
fn fixture_drifted_wire() {
    // code() = {0x01, 0x02}. Three drifts: the DESIGN table is missing
    // 0x02, lists a phantom 0x03, and the proto const for 0x02 is
    // misnamed.
    let design = "### Error codes\n\n\
                  | code | name | meaning |\n|---|---|---|\n\
                  | `0x01` | `shutdown` | fixture |\n\
                  | `0x03` | `phantom` | fixture |\n";
    let ctx = LintContext::from_sources(
        &[
            ("rust/src/error.rs", include_str!("lint_fixtures/drifted_error.rs")),
            ("rust/src/net/proto.rs", include_str!("lint_fixtures/drifted_proto.rs")),
        ],
        design,
        "",
    );
    assert_eq!(
        render(lint::wire::check(&ctx)),
        vec![
            "rust/DESIGN.md:1: [wire] DESIGN.md §Error codes is missing wire code 0x02 \
             (defined at rust/src/error.rs:14)"
                .to_string(),
            "rust/DESIGN.md:6: [wire] DESIGN.md §Error codes lists wire code 0x03 \
             that KvError::code() never returns"
                .to_string(),
            "rust/src/net/proto.rs:6: [wire] wire_code const for 0x02 is 'OVERLOAD' \
             but code_name() implies 'OVERLOADED'"
                .to_string(),
        ]
    );
}

#[test]
fn fixture_hot_closure() {
    // The denylist applies to the tagged fn's *full extent* — closures
    // and nested fns inside it — and a closure binding is taggable.
    let ctx = LintContext::from_sources(
        &[(
            "rust/tests/lint_fixtures/hot_closure.rs",
            include_str!("lint_fixtures/hot_closure.rs"),
        )],
        "",
        "",
    );
    assert_eq!(
        render(lint::hot::check(&ctx)),
        vec![
            "rust/tests/lint_fixtures/hot_closure.rs:8: [hot] \
             fn 'lookup_hot' is tagged // lint: hot but uses denied operation 'Box::new'"
                .to_string(),
            "rust/tests/lint_fixtures/hot_closure.rs:12: [hot] \
             fn 'lookup_hot' is tagged // lint: hot but uses denied operation 'to_string()'"
                .to_string(),
            "rust/tests/lint_fixtures/hot_closure.rs:20: [hot] \
             fn 'fast' is tagged // lint: hot but uses denied operation 'format!'"
                .to_string(),
        ]
    );
}

#[test]
fn fixture_annot_placement() {
    // Scanner regression: `// ord:` text inside a raw string is data
    // (the site below it must still be flagged), and an annotation
    // trailing a closing-brace-only line covers the next statement
    // (no finding for the covered site).
    let design = "## Memory orderings\n\n\
                  | site | ordering | why |\n|---|---|---|\n\
                  | fixture row — `ord:fix-flag` | Relaxed | test |\n";
    let ctx = LintContext::from_sources(
        &[(
            "rust/src/dhash/annot_placement.rs",
            include_str!("lint_fixtures/annot_placement.rs"),
        )],
        design,
        "",
    );
    assert_eq!(
        render(lint::ord::check(&ctx)),
        vec![
            "rust/src/dhash/annot_placement.rs:12: [ord] \
             Ordering site without an // ord: annotation (see DESIGN.md §Memory orderings)"
                .to_string()
        ]
    );
}

#[test]
fn fixture_lock_inversion() {
    // A two-row hierarchy; the fixture inverts it directly and through
    // a call edge.
    let design = "## Lock order\n\n| rank | key |\n|---|---|\n\
                  | 1 | `lock:fix-outer` |\n| 2 | `lock:fix-inner` |\n";
    let ctx = LintContext::from_sources(
        &[(
            "rust/src/coordinator/lock_inversion.rs",
            include_str!("lint_fixtures/lock_inversion.rs"),
        )],
        design,
        "",
    );
    assert_eq!(
        render(lint::lock_order::check(&ctx)),
        vec![
            "rust/src/coordinator/lock_inversion.rs:27: [lock-order] \
             acquires lock 'fix-outer' while 'fix-inner' (line 26) is held — \
             DESIGN.md ## Lock order ranks 'fix-outer' above 'fix-inner'"
                .to_string(),
            "rust/src/coordinator/lock_inversion.rs:34: [lock-order] \
             call to 'grab_outer' can acquire lock 'fix-outer' while 'fix-inner' \
             (line 33) is held — DESIGN.md ## Lock order ranks 'fix-outer' above 'fix-inner'"
                .to_string(),
        ]
    );
}

#[test]
fn fixture_leaked_free() {
    // A shared-&self op reaching a contract-class free with no
    // call-site discharge, and a key with no paired Box::into_raw.
    let design = "## Reclamation contract\n\n| `reclaim:fix-slot` | fixture row |\n";
    let ctx = LintContext::from_sources(
        &[(
            "rust/src/lflist/leaked_free.rs",
            include_str!("lint_fixtures/leaked_free.rs"),
        )],
        design,
        "",
    );
    assert_eq!(
        render(lint::reclaim::check(&ctx)),
        vec![
            "rust/src/lflist/leaked_free.rs:14: [reclaim] \
             reclaim key 'fix-slot' has free sites but no Box::into_raw site"
                .to_string(),
            "rust/src/lflist/leaked_free.rs:19: [reclaim] \
             shared-&self fn 'evict' reaches free site via 'release' — annotate the call \
             (// reclaim: <key> via unpublished|grace) or restructure"
                .to_string(),
        ]
    );
}

#[test]
fn fixture_publish_reorder() {
    // The drain protocol with the hazard clear hoisted above the
    // hazard publish.
    let ctx = LintContext::from_sources(
        &[(
            "rust/src/dhash/publish_reorder.rs",
            include_str!("lint_fixtures/publish_reorder.rs"),
        )],
        "",
        "",
    );
    assert_eq!(
        render(lint::publish::check(&ctx)),
        vec![
            "rust/src/dhash/publish_reorder.rs:8: [publish] \
             fn 'drain_backwards' (protocol 'drain') performs step \
             'hazard clear after re-insert' before step \
             'hazard publish before logical delete' — protocol order is violated"
                .to_string()
        ]
    );
}

// ---------------------------------------------------------------- real tree

#[test]
fn real_tree_is_clean() {
    let ctx = load_real_tree();
    let diags = lint::run(&ctx, &[]);
    assert!(
        diags.is_empty(),
        "dhash-lint should be clean on the shipped tree, got:\n{}",
        render(diags).join("\n")
    );
}

#[test]
fn design_ord_drift_fails_both_directions() {
    // Direction 1: drop one `ord:<key>` token from §Memory orderings —
    // the key is still used in source, so the rule must fail.
    let mut ctx = load_real_tree();
    assert!(ctx.design_md.contains("`ord:michael-link`"), "token exists");
    ctx.design_md = ctx.design_md.replace(" — `ord:michael-link`", "");
    let diags = render(lint::ord::check(&ctx));
    assert!(
        diags.iter().any(|d| d.contains(
            "[ord] ord key 'michael-link' is not indexed in DESIGN.md ## Memory orderings"
        )),
        "expected key-not-indexed finding, got:\n{}",
        diags.join("\n")
    );

    // Direction 2: add a phantom row no source site uses.
    let mut ctx = load_real_tree();
    ctx.design_md = ctx.design_md.replace(
        "## Memory orderings (read-path audit)\n",
        "## Memory orderings (read-path audit)\n\n\
         | ghost row — `ord:ghost-key` | Relaxed | phantom | none |\n",
    );
    let diags = render(lint::ord::check(&ctx));
    assert!(
        diags.iter().any(|d| d.contains(
            "indexes ord key 'ghost-key' but no source site uses it"
        )),
        "expected stale-row finding, got:\n{}",
        diags.join("\n")
    );
}

#[test]
fn design_wire_drift_fails_both_directions() {
    // Renumbering one documented code both orphans the real code and
    // documents a phantom one — the rule must report each side.
    let mut ctx = load_real_tree();
    assert!(ctx.design_md.contains("| `0x12` |"), "row exists");
    ctx.design_md = ctx.design_md.replace("| `0x12` |", "| `0x17` |");
    let diags = render(lint::wire::check(&ctx));
    assert!(
        diags.iter().any(|d| d.contains("is missing wire code 0x12")),
        "expected missing-code finding, got:\n{}",
        diags.join("\n")
    );
    assert!(
        diags
            .iter()
            .any(|d| d.contains("lists wire code 0x17 that KvError::code() never returns")),
        "expected phantom-code finding, got:\n{}",
        diags.join("\n")
    );
}

#[test]
fn design_lock_order_drift_fails_both_directions() {
    // Replacing one ranked row both orphans the real key (used in
    // source, no longer ranked) and documents a ghost key (ranked,
    // never used) — the rule must report each side.
    let mut ctx = load_real_tree();
    assert!(
        ctx.design_md.contains("| 9 | `lock:map-rebuild` |"),
        "row exists"
    );
    ctx.design_md = ctx
        .design_md
        .replace("| 9 | `lock:map-rebuild` |", "| 9 | `lock:zz-ghost` |");
    let diags = render(lint::lock_order::check(&ctx));
    assert!(
        diags.iter().any(|d| d.contains(
            "lock key 'map-rebuild' is not ranked in DESIGN.md ## Lock order"
        )),
        "expected key-not-ranked finding, got:\n{}",
        diags.join("\n")
    );
    assert!(
        diags.iter().any(|d| d.contains(
            "ranks lock key 'zz-ghost' but no source site uses it"
        )),
        "expected ghost-row finding, got:\n{}",
        diags.join("\n")
    );
}

#[test]
fn design_reclaim_drift_fails_both_directions() {
    let mut ctx = load_real_tree();
    assert!(ctx.design_md.contains("| `reclaim:table` |"), "row exists");
    ctx.design_md = ctx
        .design_md
        .replace("| `reclaim:table` |", "| `reclaim:ghost-key` |");
    let diags = render(lint::reclaim::check(&ctx));
    assert!(
        diags.iter().any(|d| d.contains(
            "reclaim key 'table' is not indexed in DESIGN.md ## Reclamation contract"
        )),
        "expected key-not-indexed finding, got:\n{}",
        diags.join("\n")
    );
    assert!(
        diags.iter().any(|d| d.contains(
            "indexes reclaim key 'ghost-key' but no source site uses it"
        )),
        "expected ghost-row finding, got:\n{}",
        diags.join("\n")
    );
}

#[test]
fn publish_reorder_on_real_tree_fails() {
    // Hoist the rebuild hazard clear above the hazard publish — the
    // one-line reorder Lemma 4.1 forbids — and the rule must fire.
    let root = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("rust/ has a parent")
        .to_path_buf();
    let src = std::fs::read_to_string(root.join("rust/src/dhash/mod.rs"))
        .expect("dhash/mod.rs reads");
    let publish_line = "self.rebuild_cur.store(cand, Ordering::Release);";
    assert!(src.contains(publish_line), "publish site exists");
    let mutated = src.replacen(
        publish_line,
        "self.rebuild_cur.store(std::ptr::null_mut(), Ordering::Release); \
         self.rebuild_cur.store(cand, Ordering::Release);",
        1,
    );
    let ctx = LintContext::from_sources(&[("rust/src/dhash/mod.rs", mutated.as_str())], "", "");
    let diags = render(lint::publish::check(&ctx));
    assert!(
        diags.iter().any(|d| d.contains(
            "performs step 'hazard clear after re-insert' before step \
             'hazard publish before logical delete'"
        )),
        "expected protocol-order finding, got:\n{}",
        diags.join("\n")
    );
}

#[test]
fn allowlist_drift_fails_both_directions() {
    // Direction 1: shrink a real budget.
    let mut ctx = load_real_tree();
    assert!(ctx.allowlist.contains("rust/src/rcu/mod.rs 19"), "entry exists");
    ctx.allowlist = ctx.allowlist.replace("rust/src/rcu/mod.rs 19", "rust/src/rcu/mod.rs 18");
    let diags = render(lint::seqcst::check(&ctx));
    assert!(
        diags
            .iter()
            .any(|d| d.contains("[seqcst-budget] 19 SeqCst site(s); allowlist budgets 18")),
        "expected over-budget finding, got:\n{}",
        diags.join("\n")
    );

    // Direction 2: budget a file with no SeqCst sites.
    let mut ctx = load_real_tree();
    ctx.allowlist.push_str("rust/src/lflist/michael.rs 2\n");
    let diags = render(lint::seqcst::check(&ctx));
    assert!(
        diags.iter().any(|d| d.contains(
            "rust/src/lflist/michael.rs is budgeted (2) but has no SeqCst sites"
        )),
        "expected stale-entry finding, got:\n{}",
        diags.join("\n")
    );
}
