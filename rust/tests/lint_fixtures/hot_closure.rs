//! Fixture: denied operations hidden inside a closure and a nested
//! `fn` within a `// lint: hot` function — the rule scans the full
//! extent — plus a tagged closure binding.

// lint: hot
pub fn lookup_hot(keys: &[u64]) -> u64 {
    let probe = |k: u64| {
        let boxed = Box::new(k); // denied, inside a closure
        *boxed
    };
    fn spill(v: u64) -> u64 {
        v.to_string().len() as u64 // denied, inside a nested fn
    }
    spill(probe(keys[0]))
}

pub fn wrapper() -> u64 {
    // lint: hot
    let fast = |k: u64| -> u64 {
        format!("{k}").len() as u64 // denied, inside a tagged closure
    };
    fast(7)
}
