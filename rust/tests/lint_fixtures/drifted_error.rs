//! Fixture for rule `wire`: `code()` defines {0x01, 0x02};
//! `code_name()` knows both, but the proto constants and the DESIGN
//! table each drift by one entry (see tests/lint_self.rs).

pub enum KvError {
    Shutdown,
    Overloaded,
}

impl KvError {
    pub fn code(&self) -> u8 {
        match self {
            KvError::Shutdown => 0x01,
            KvError::Overloaded => 0x02,
        }
    }

    pub fn code_name(code: u8) -> &'static str {
        match code {
            0x01 => "shutdown",
            0x02 => "overloaded",
            _ => "unknown",
        }
    }
}
