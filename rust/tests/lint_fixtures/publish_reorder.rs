//! Fixture: the `drain` publication protocol with the hazard clear
//! hoisted above the hazard publish — the reorder Lemma 4.1 forbids.
//! Loaded by `lint_self.rs` under a synthetic `rust/src/dhash/` path.

// lint: publish drain
pub fn drain_backwards(bucket: &B, moving: &AtomicPtr<Node>) {
    let cand = bucket.take_first_for_distribution();
    moving.store(std::ptr::null_mut(), Ordering::Release);
    moving.store(cand, Ordering::Release);
    Node::defer_free(cand);
}
