//! Fixture: inverted lock acquisition — directly, and through a call
//! edge — against a two-row hierarchy. Loaded by `lint_self.rs` under
//! a synthetic `rust/src/coordinator/` path.

use std::sync::Mutex;

pub struct Pair {
    outer: Mutex<u32>,
    inner: Mutex<u32>,
}

impl Pair {
    pub fn grab_outer(&self) -> u32 {
        *self.outer.lock().unwrap() // lock: fix-outer
    }

    /// Correct order: outer before inner.
    pub fn ordered(&self) -> u32 {
        let a = self.outer.lock().unwrap(); // lock: fix-outer
        let b = self.inner.lock().unwrap(); // lock: fix-inner
        *a + *b
    }

    /// Direct inversion: inner held, then outer acquired.
    pub fn inverted_direct(&self) -> u32 {
        let b = self.inner.lock().unwrap(); // lock: fix-inner
        let a = self.outer.lock().unwrap(); // lock: fix-outer
        *a + *b
    }

    /// Inversion through a call edge: inner held, the helper takes outer.
    pub fn inverted_via_call(&self) -> u32 {
        let b = self.inner.lock().unwrap(); // lock: fix-inner
        *b + self.grab_outer()
    }
}
