//! Fixture: a shared-`&self` operation that reaches a contract-class
//! free site with no call-site discharge — the leak `reclaim` exists
//! to catch — plus a key with a free site but no paired allocation.
//! Loaded by `lint_self.rs` under a synthetic `rust/src/lflist/` path.

pub struct Slot {
    raw: *mut u64,
}

impl Slot {
    /// # Safety
    /// `ptr` must be unreachable for every reader.
    pub unsafe fn release(ptr: *mut u64) {
        drop(Box::from_raw(ptr)); // reclaim: fix-slot via contract — caller proves unreachability
    }

    /// Shared-`&self` path straight into the free — the finding.
    pub fn evict(&self) {
        unsafe { Slot::release(self.raw) };
    }
}
