//! Fixture proto for rule `wire`: `OVERLOAD` disagrees with the
//! SCREAMING_SNAKE_CASE of `code_name(0x02)`.

pub mod wire_code {
    pub const SHUTDOWN: u8 = 0x01;
    pub const OVERLOAD: u8 = 0x02;
}
