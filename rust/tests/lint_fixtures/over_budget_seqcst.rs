//! Fixture for rule `seqcst-budget`: two `SeqCst` sites against a
//! budget of one.

use std::sync::atomic::{AtomicUsize, Ordering};

pub fn bump(x: &AtomicUsize) -> usize {
    x.fetch_add(1, Ordering::SeqCst)
}

pub fn read(x: &AtomicUsize) -> usize {
    x.load(Ordering::SeqCst)
}
