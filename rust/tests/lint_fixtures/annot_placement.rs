//! Fixture: scanner placement edge cases. `// ord:`-looking text
//! inside a raw string is data, not a comment — it must not arm the
//! rule for the site below. A `// ord:` annotation trailing a
//! closing-brace-only line ends the *previous* statement and must
//! cover the next one. Loaded by `lint_self.rs` under a synthetic
//! `rust/src/dhash/` path.

pub fn raw_string_cannot_arm(flag: &AtomicBool) {
    let _doc = r#"
        // ord: fake-key — string data, not a comment
    "#;
    flag.store(true, Ordering::Relaxed);
}

pub fn closer_line_annotation(flag: &AtomicBool) -> bool {
    {
        let _scope = ();
    } // ord: fix-flag — trailing a closer still covers the next statement
    flag.load(Ordering::Relaxed)
}
