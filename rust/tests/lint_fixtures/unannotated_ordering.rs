//! Fixture for rule `ord`: `peek_bad` has an unannotated
//! `Ordering::*` site; `peek_ok` carries the indexed fixture key.

use std::sync::atomic::{AtomicUsize, Ordering};

pub fn peek_bad(x: &AtomicUsize) -> usize {
    x.load(Ordering::Acquire)
}

pub fn peek_ok(x: &AtomicUsize) -> usize {
    // ord: fixture-key — fixture justification (indexed in the test's
    // synthetic DESIGN table)
    x.load(Ordering::Acquire)
}
