//! Fixture for rule `safety` (see tests/lint_self.rs): `deref_bad`
//! must be flagged, `deref_ok` must not.

pub fn deref_bad(p: *const u64) -> u64 {
    unsafe { *p }
}

pub fn deref_ok(p: *const u64) -> u64 {
    // SAFETY: fixture — the caller passes a valid, aligned pointer.
    unsafe { *p }
}
