//! Fixture for rule `hot`: `lookup_fast` allocates inside a tagged
//! fn; `lookup_clean` is fine.

// lint: hot
pub fn lookup_fast() -> Box<u64> {
    Box::new(7)
}

// lint: hot
pub fn lookup_clean(x: u64) -> u64 {
    x.wrapping_mul(0x9e37_79b9_b97f_4a7d)
}
