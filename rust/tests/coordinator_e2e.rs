//! End-to-end coordinator test: the full detect→rebuild loop against a
//! synthetic collision attack, running on the default native detector
//! engine — no AOT artifacts, no Python toolchain required.

use std::sync::Arc;
use std::time::Duration;

use dhash::coordinator::{
    BatcherConfig, ControllerConfig, Coordinator, CoordinatorConfig, DetectorConfig, PreRoute,
    Request, Response, SubmitError,
};
use dhash::dhash::HashFn;
use dhash::torture::{AttackGen, ShardedAttackGen};

fn attack_config(nbuckets: usize) -> CoordinatorConfig {
    CoordinatorConfig {
        nbuckets,
        hash: HashFn::Modulo, // vulnerable on purpose
        shards: 1,
        lanes: 1,
        workers: 2,
        batcher: BatcherConfig {
            max_batch: 64,
            max_wait: Duration::from_micros(100),
            pre_route: PreRoute::Off,
        },
        detector: DetectorConfig {
            sample_capacity: 4096,
            period: Duration::from_millis(20),
            sigma: 8.0,
            min_samples: 512,
        },
        controller: ControllerConfig {
            cooldown: Duration::from_millis(100),
            rebuild_buckets: None,
        },
        enable_analytics: true,
    }
}

#[test]
fn detects_and_mitigates_collision_attack() {
    let nbuckets = 1024;
    let c = Arc::new(Coordinator::start(attack_config(nbuckets)).unwrap());

    // Benign phase: evenly-spread puts, detector should stay quiet.
    let reqs: Vec<Request> = (0..2048u64).map(|i| Request::put(i * 7919, i)).collect();
    c.execute_many(reqs);
    std::thread::sleep(Duration::from_millis(120));
    assert_eq!(c.stats().rebuilds, 0, "false positive on benign traffic");

    // Attack phase: flood colliding keys (all ≡ 3 mod nbuckets).
    let attack: Vec<Request> = AttackGen::new(nbuckets, 3)
        .take(6000)
        .map(|k| Request::put(k, 0))
        .collect();
    for chunk in attack.chunks(512) {
        c.execute_many(chunk.to_vec());
    }
    // Give the analytics loop time to sample + evaluate + rebuild.
    let mut waited = 0;
    while c.stats().rebuilds == 0 && waited < 3_000 {
        std::thread::sleep(Duration::from_millis(50));
        waited += 50;
    }
    let st = c.stats();
    assert!(
        st.rebuilds >= 1,
        "attack was never mitigated (chi2={})",
        st.last_chi2
    );
    assert!(st.detector_runs > 0);
    let events = c.rebuild_events();
    assert!(!events.is_empty());
    assert!(
        matches!(events[0].new_hash, HashFn::Seeded(_)),
        "mitigation must install a seeded hash"
    );

    // The service still works and holds the data.
    assert_eq!(c.execute(Request::get(3)), Response::Value(0)); // attack key
    assert_eq!(c.execute(Request::get(7919)), Response::Value(1)); // benign key
    c.shutdown();
}

#[test]
fn targeted_mitigation_rebuilds_only_attacked_shard() {
    // Sharded service under a collision flood aimed at ONE shard: the
    // per-shard chi2 verdict must trip only there, and the mitigation
    // must re-seed only that shard — the others keep their (weak) hash
    // and never migrate.
    let shards = 4usize;
    let nbuckets = 1024usize; // per shard; >= detector nbins (256)
    let mut cfg = attack_config(nbuckets);
    cfg.shards = shards;
    let c = Arc::new(Coordinator::start(cfg).unwrap());
    let victim = 2usize;

    // Scoped registration: the guard must be dropped before the waiting
    // phase below, or this thread's stale quiescent state would stall the
    // mitigation rebuild's grace periods forever.
    let before: Vec<HashFn> = {
        let g = dhash::rcu::RcuThread::register();
        let v = (0..shards).map(|s| c.map().shard_hash_fn(&g, s)).collect();
        g.quiescent_state();
        v
    };
    assert!(before.iter().all(|h| *h == HashFn::Modulo));

    // Flood: colliding keys that all route to the victim shard.
    let attack: Vec<Request> = ShardedAttackGen::new(nbuckets, 3, shards, victim)
        .take(6000)
        .map(|k| Request::put(k, k))
        .collect();
    let first_key = attack[0].key();
    for chunk in attack.chunks(512) {
        c.execute_many(chunk.to_vec());
    }
    let mut waited = 0;
    while c.stats().rebuilds == 0 && waited < 3_000 {
        std::thread::sleep(Duration::from_millis(50));
        waited += 50;
    }
    let st = c.stats();
    assert!(
        st.rebuilds >= 1,
        "attack on shard {victim} was never mitigated (chi2={})",
        st.last_chi2
    );
    let events = c.rebuild_events();
    assert!(!events.is_empty());
    assert!(
        events.iter().all(|e| e.shard == victim),
        "mitigation touched a non-attacked shard: {events:?}"
    );

    // Only the victim shard's hash function changed.
    {
        let g = dhash::rcu::RcuThread::register();
        for s in 0..shards {
            let now = c.map().shard_hash_fn(&g, s);
            if s == victim {
                assert!(
                    matches!(now, HashFn::Seeded(_)),
                    "victim shard still on {now:?}"
                );
            } else {
                assert_eq!(now, before[s], "shard {s} was rebuilt needlessly");
            }
        }
        g.quiescent_state();
    }

    // The service still works and holds the flooded data.
    assert_eq!(c.execute(Request::get(first_key)), Response::Value(first_key));
    c.shutdown();
}

#[test]
fn pipelined_tickets_end_to_end() {
    // The completion-based ingest path under the full service (analytics
    // on): submit a pipeline of tickets without waiting, then resolve
    // them all — responses must come back in submission order — through
    // both the single-lane and multi-lane (sharded) configurations.
    for (lanes, shards) in [(1usize, 1usize), (4, 4)] {
        let mut cfg = attack_config(1024);
        cfg.hash = HashFn::Seeded(0xfeed); // benign service
        cfg.lanes = lanes;
        cfg.shards = shards;
        let c = Arc::new(Coordinator::start(cfg).unwrap());
        let n = 3000u64;

        // Phase 1: a wave of puts, all in flight at once.
        let client = c.client();
        let puts: Vec<Request> = (0..n).map(|k| Request::put(k, k ^ 0xabcd)).collect();
        let mut batches = Vec::new();
        for chunk in puts.chunks(256) {
            batches.push(client.submit_batch(chunk).unwrap());
        }
        for bt in &batches {
            assert!(bt.wait().unwrap().iter().all(|r| *r == Response::Ok));
        }

        // Phase 2: concurrent clients pipeline gets; each thread's
        // responses must line up with its own submission order.
        let mut threads = Vec::new();
        for t in 0..3u64 {
            let c2 = c.clone();
            threads.push(std::thread::spawn(move || {
                let client = c2.client();
                let keys: Vec<u64> = (0..n).filter(|k| k % 3 == t).collect();
                let gets: Vec<Request> = keys.iter().map(|&k| Request::get(k)).collect();
                let bt = client.submit_batch(&gets).unwrap();
                let resps = bt.wait().unwrap();
                assert_eq!(resps.len(), keys.len());
                for (k, r) in keys.iter().zip(resps) {
                    assert_eq!(r, Response::Value(k ^ 0xabcd), "lanes={lanes} key {k}");
                }
            }));
        }
        for h in threads {
            h.join().unwrap();
        }
        c.shutdown();
        // Post-shutdown submissions fail cleanly.
        assert_eq!(
            c.client().submit(Request::get(0)).err(),
            Some(SubmitError::Shutdown)
        );
    }
}

#[test]
fn sharded_bucket_pre_route_serves_with_zero_fallbacks() {
    // The tentpole path end to end: a sharded service with composite
    // (shard, bucket) pre-routing on the native engine. Every batch must
    // pre-route via one batch_hash_multi call (no fallbacks of either
    // cause), the service must answer correctly, and routing must
    // survive a targeted rebuild diverging one shard's geometry.
    let mut cfg = attack_config(1024);
    cfg.hash = HashFn::Seeded(0xfeed);
    cfg.shards = 4;
    cfg.lanes = 2;
    cfg.batcher.pre_route = PreRoute::Bucket;
    let c = Arc::new(Coordinator::start(cfg).unwrap());
    let n = 3000u64;
    let client = c.client();
    let puts: Vec<Request> = (0..n).map(|k| Request::put(k, k * 3)).collect();
    for chunk in puts.chunks(256) {
        assert!(client
            .submit_batch(chunk)
            .unwrap()
            .wait()
            .unwrap()
            .iter()
            .all(|r| *r == Response::Ok));
    }

    // Diverge ONE shard mid-service (what a targeted mitigation does),
    // then keep routing traffic through the now-mixed geometry. Scoped
    // guard: it must drop before the remaining service traffic, or this
    // thread's stale quiescent state would stall worker grace periods.
    {
        let g = dhash::rcu::RcuThread::register();
        c.map()
            .rebuild_shard(&g, 1, 2048, HashFn::Seeded(0xd00d))
            .unwrap();
        g.quiescent_state();
    }
    let gets: Vec<Request> = (0..n).map(Request::get).collect();
    for chunk in gets.chunks(256) {
        let resps = client.submit_batch(chunk).unwrap().wait().unwrap();
        for (r, req) in resps.iter().zip(chunk) {
            assert_eq!(*r, Response::Value(req.key() * 3), "key {}", req.key());
        }
    }
    c.shutdown();
    let st = c.stats();
    assert!(st.total_batches >= 1);
    assert_eq!(
        st.pre_route_fallbacks_engine, 0,
        "the native engine must never fall back"
    );
    assert_eq!(st.pre_route_fallbacks_length, 0);
    assert_eq!(
        st.pre_routed_batches, st.total_batches,
        "every batch must pre-route in (shard, bucket) order"
    );
}

#[test]
fn detector_runs_are_counted() {
    let c = Arc::new(Coordinator::start(attack_config(256)).unwrap());
    let reqs: Vec<Request> = (0..1024u64).map(|i| Request::put(i, i)).collect();
    c.execute_many(reqs);
    let mut waited = 0;
    while c.stats().detector_runs == 0 && waited < 2_000 {
        std::thread::sleep(Duration::from_millis(25));
        waited += 25;
    }
    assert!(c.stats().detector_runs > 0, "analytics loop never evaluated");
    c.shutdown();
}

#[test]
fn benign_seeded_service_never_rebuilds() {
    // A service already on a seeded hash sees the same attack keys as
    // uniform load: the detector must not fire.
    let mut cfg = attack_config(1024);
    cfg.hash = HashFn::Seeded(0xfeed);
    let c = Arc::new(Coordinator::start(cfg).unwrap());
    let reqs: Vec<Request> = AttackGen::new(1024, 3)
        .take(4096)
        .map(|k| Request::put(k, k))
        .collect();
    c.execute_many(reqs);
    // Poll until the detector has evaluated the full sample a few times
    // (a fixed sleep flakes on loaded runners), then check no rebuild.
    let mut waited = 0;
    while c.stats().detector_runs < 3 && waited < 3_000 {
        std::thread::sleep(Duration::from_millis(25));
        waited += 25;
    }
    let st = c.stats();
    assert!(st.detector_runs > 0, "detector never ran");
    assert_eq!(st.rebuilds, 0, "seeded hash misdetected as attacked");
    c.shutdown();
}
