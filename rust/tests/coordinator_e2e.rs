//! End-to-end coordinator test: the full detect→rebuild loop against a
//! synthetic collision attack, running on the default native detector
//! engine — no AOT artifacts, no Python toolchain required.

use std::sync::Arc;
use std::time::Duration;

use dhash::coordinator::{
    BatcherConfig, ControllerConfig, Coordinator, CoordinatorConfig, DetectorConfig, ElasticConfig,
    PreRoute, Request, Response, SubmitError,
};
use dhash::dhash::HashFn;
use dhash::torture::{AttackGen, ShardedAttackGen};

fn attack_config(nbuckets: usize) -> CoordinatorConfig {
    CoordinatorConfig {
        nbuckets,
        hash: HashFn::Modulo, // vulnerable on purpose
        shards: 1,
        lanes: 1,
        workers: 2,
        batcher: BatcherConfig {
            max_batch: 64,
            max_wait: Duration::from_micros(100),
            pre_route: PreRoute::Off,
        },
        detector: DetectorConfig {
            sample_capacity: 4096,
            period: Duration::from_millis(20),
            sigma: 8.0,
            min_samples: 512,
        },
        controller: ControllerConfig {
            cooldown: Duration::from_millis(100),
            rebuild_buckets: None,
        },
        elastic: None,
        enable_analytics: true,
    }
}

#[test]
fn detects_and_mitigates_collision_attack() {
    let nbuckets = 1024;
    let c = Arc::new(Coordinator::start(attack_config(nbuckets)).unwrap());

    // Benign phase: evenly-spread puts, detector should stay quiet.
    let reqs: Vec<Request> = (0..2048u64).map(|i| Request::put(i * 7919, i)).collect();
    c.execute_many(reqs);
    std::thread::sleep(Duration::from_millis(120));
    assert_eq!(c.stats().rebuilds, 0, "false positive on benign traffic");

    // Attack phase: flood colliding keys (all ≡ 3 mod nbuckets).
    let attack: Vec<Request> = AttackGen::new(nbuckets, 3)
        .take(6000)
        .map(|k| Request::put(k, 0))
        .collect();
    for chunk in attack.chunks(512) {
        c.execute_many(chunk.to_vec());
    }
    // Give the analytics loop time to sample + evaluate + rebuild.
    let mut waited = 0;
    while c.stats().rebuilds == 0 && waited < 3_000 {
        std::thread::sleep(Duration::from_millis(50));
        waited += 50;
    }
    let st = c.stats();
    assert!(
        st.rebuilds >= 1,
        "attack was never mitigated (chi2={})",
        st.last_chi2
    );
    assert!(st.detector_runs > 0);
    let events = c.rebuild_events();
    assert!(!events.is_empty());
    assert!(
        matches!(events[0].new_hash, HashFn::Seeded(_)),
        "mitigation must install a seeded hash"
    );

    // The service still works and holds the data.
    assert_eq!(c.execute(Request::get(3)), Response::Value(0)); // attack key
    assert_eq!(c.execute(Request::get(7919)), Response::Value(1)); // benign key
    c.shutdown();
}

#[test]
fn targeted_mitigation_rebuilds_only_attacked_shard() {
    // Sharded service under a collision flood aimed at ONE shard: the
    // per-shard chi2 verdict must trip only there, and the mitigation
    // must re-seed only that shard — the others keep their (weak) hash
    // and never migrate.
    let shards = 4usize;
    let nbuckets = 1024usize; // per shard; >= detector nbins (256)
    let mut cfg = attack_config(nbuckets);
    cfg.shards = shards;
    let c = Arc::new(Coordinator::start(cfg).unwrap());
    let victim = 2usize;

    // Scoped registration: the guard must be dropped before the waiting
    // phase below, or this thread's stale quiescent state would stall the
    // mitigation rebuild's grace periods forever.
    let before: Vec<HashFn> = {
        let g = dhash::rcu::RcuThread::register();
        let v = (0..shards).map(|s| c.map().shard_hash_fn(&g, s)).collect();
        g.quiescent_state();
        v
    };
    assert!(before.iter().all(|h| *h == HashFn::Modulo));

    // Flood: colliding keys that all route to the victim shard.
    let attack: Vec<Request> = ShardedAttackGen::new(nbuckets, 3, shards, victim)
        .take(6000)
        .map(|k| Request::put(k, k))
        .collect();
    let first_key = attack[0].key();
    for chunk in attack.chunks(512) {
        c.execute_many(chunk.to_vec());
    }
    let mut waited = 0;
    while c.stats().rebuilds == 0 && waited < 3_000 {
        std::thread::sleep(Duration::from_millis(50));
        waited += 50;
    }
    let st = c.stats();
    assert!(
        st.rebuilds >= 1,
        "attack on shard {victim} was never mitigated (chi2={})",
        st.last_chi2
    );
    let events = c.rebuild_events();
    assert!(!events.is_empty());
    assert!(
        events.iter().all(|e| e.shard == victim),
        "mitigation touched a non-attacked shard: {events:?}"
    );

    // Only the victim shard's hash function changed.
    {
        let g = dhash::rcu::RcuThread::register();
        for s in 0..shards {
            let now = c.map().shard_hash_fn(&g, s);
            if s == victim {
                assert!(
                    matches!(now, HashFn::Seeded(_)),
                    "victim shard still on {now:?}"
                );
            } else {
                assert_eq!(now, before[s], "shard {s} was rebuilt needlessly");
            }
        }
        g.quiescent_state();
    }

    // The service still works and holds the flooded data.
    assert_eq!(c.execute(Request::get(first_key)), Response::Value(first_key));
    c.shutdown();
}

#[test]
fn pipelined_tickets_end_to_end() {
    // The completion-based ingest path under the full service (analytics
    // on): submit a pipeline of tickets without waiting, then resolve
    // them all — responses must come back in submission order — through
    // both the single-lane and multi-lane (sharded) configurations.
    for (lanes, shards) in [(1usize, 1usize), (4, 4)] {
        let mut cfg = attack_config(1024);
        cfg.hash = HashFn::Seeded(0xfeed); // benign service
        cfg.lanes = lanes;
        cfg.shards = shards;
        let c = Arc::new(Coordinator::start(cfg).unwrap());
        let n = 3000u64;

        // Phase 1: a wave of puts, all in flight at once.
        let client = c.client();
        let puts: Vec<Request> = (0..n).map(|k| Request::put(k, k ^ 0xabcd)).collect();
        let mut batches = Vec::new();
        for chunk in puts.chunks(256) {
            batches.push(client.submit_batch(chunk).unwrap());
        }
        for bt in &batches {
            assert!(bt.wait().unwrap().iter().all(|r| *r == Response::Ok));
        }

        // Phase 2: concurrent clients pipeline gets; each thread's
        // responses must line up with its own submission order.
        let mut threads = Vec::new();
        for t in 0..3u64 {
            let c2 = c.clone();
            threads.push(std::thread::spawn(move || {
                let client = c2.client();
                let keys: Vec<u64> = (0..n).filter(|k| k % 3 == t).collect();
                let gets: Vec<Request> = keys.iter().map(|&k| Request::get(k)).collect();
                let bt = client.submit_batch(&gets).unwrap();
                let resps = bt.wait().unwrap();
                assert_eq!(resps.len(), keys.len());
                for (k, r) in keys.iter().zip(resps) {
                    assert_eq!(r, Response::Value(k ^ 0xabcd), "lanes={lanes} key {k}");
                }
            }));
        }
        for h in threads {
            h.join().unwrap();
        }
        c.shutdown();
        // Post-shutdown submissions fail cleanly.
        assert_eq!(
            c.client().submit(Request::get(0)).err(),
            Some(SubmitError::Shutdown)
        );
    }
}

#[test]
fn sharded_bucket_pre_route_serves_with_zero_fallbacks() {
    // The tentpole path end to end: a sharded service with composite
    // (shard, bucket) pre-routing on the native engine. Every batch must
    // pre-route via one batch_hash_multi call (no fallbacks of either
    // cause), the service must answer correctly, and routing must
    // survive a targeted rebuild diverging one shard's geometry.
    let mut cfg = attack_config(1024);
    cfg.hash = HashFn::Seeded(0xfeed);
    cfg.shards = 4;
    cfg.lanes = 2;
    cfg.batcher.pre_route = PreRoute::Bucket;
    let c = Arc::new(Coordinator::start(cfg).unwrap());
    let n = 3000u64;
    let client = c.client();
    let puts: Vec<Request> = (0..n).map(|k| Request::put(k, k * 3)).collect();
    for chunk in puts.chunks(256) {
        assert!(client
            .submit_batch(chunk)
            .unwrap()
            .wait()
            .unwrap()
            .iter()
            .all(|r| *r == Response::Ok));
    }

    // Diverge ONE shard mid-service (what a targeted mitigation does),
    // then keep routing traffic through the now-mixed geometry. Scoped
    // guard: it must drop before the remaining service traffic, or this
    // thread's stale quiescent state would stall worker grace periods.
    {
        let g = dhash::rcu::RcuThread::register();
        c.map()
            .rebuild_shard(&g, 1, 2048, HashFn::Seeded(0xd00d))
            .unwrap();
        g.quiescent_state();
    }
    let gets: Vec<Request> = (0..n).map(Request::get).collect();
    for chunk in gets.chunks(256) {
        let resps = client.submit_batch(chunk).unwrap().wait().unwrap();
        for (r, req) in resps.iter().zip(chunk) {
            assert_eq!(*r, Response::Value(req.key() * 3), "key {}", req.key());
        }
    }
    c.shutdown();
    let st = c.stats();
    assert!(st.total_batches >= 1);
    assert_eq!(
        st.pre_route_fallbacks_engine, 0,
        "the native engine must never fall back"
    );
    assert_eq!(st.pre_route_fallbacks_length, 0);
    assert_eq!(
        st.pre_routed_batches, st.total_batches,
        "every batch must pre-route in (shard, bucket) order"
    );
}

#[test]
fn bucket_pre_routed_stream_crosses_split_and_merge_without_losing_responses() {
    // The elastic tentpole end to end: a sharded service with composite
    // (shard, bucket) pre-routing, with a shard split (and then a merge)
    // landing in the MIDDLE of a pre-routed batch stream. Zero lost or
    // wrong responses; every batch's pre-route attempt is accounted for
    // (routed, or an epoch fallback from ids computed against the
    // retired layout) — never silent; and the native engine never
    // contributes engine/length fallbacks.
    let mut cfg = attack_config(1024);
    cfg.hash = HashFn::Seeded(0xfeed);
    cfg.shards = 4;
    cfg.lanes = 2;
    cfg.batcher.pre_route = PreRoute::Bucket;
    let c = Arc::new(Coordinator::start(cfg).unwrap());
    let n = 4000u64;
    let client = c.client();
    let puts: Vec<Request> = (0..n).map(|k| Request::put(k, k * 3)).collect();
    for chunk in puts.chunks(256) {
        assert!(client
            .submit_batch(chunk)
            .unwrap()
            .wait()
            .unwrap()
            .iter()
            .all(|r| *r == Response::Ok));
    }

    // Stream get batches from a second thread while the main thread
    // splits a shard and merges it back mid-stream.
    let c2 = c.clone();
    let streamer = std::thread::spawn(move || {
        let client = c2.client();
        for round in 0..6u64 {
            let gets: Vec<Request> = (0..n).map(Request::get).collect();
            for chunk in gets.chunks(128) {
                let resps = client.submit_batch(chunk).unwrap().wait().unwrap();
                for (r, req) in resps.iter().zip(chunk) {
                    assert_eq!(
                        *r,
                        Response::Value(req.key() * 3),
                        "round {round} key {} lost or wrong across the resize",
                        req.key()
                    );
                }
            }
        }
    });
    {
        let g = dhash::rcu::RcuThread::register();
        // Let the stream get going, then resize under it. The resizes
        // themselves assert the migration-token gauge (at most one
        // migration in flight) internally.
        std::thread::sleep(Duration::from_millis(20));
        c.map().split_shard(&g, 2, 1024, HashFn::Seeded(0xd00d)).unwrap();
        assert_eq!(c.map().shards(), 5);
        std::thread::sleep(Duration::from_millis(20));
        c.map().merge_shard(&g, 2, 2048, HashFn::Seeded(0xd00e)).unwrap();
        assert_eq!(c.map().shards(), 4);
        g.quiescent_state();
    }
    streamer.join().unwrap();
    c.shutdown();
    let st = c.stats();
    assert!(st.total_batches >= 1);
    assert_eq!(st.splits, 1);
    assert_eq!(st.merges, 1);
    assert_eq!(st.shards, 4);
    assert_eq!(
        st.pre_route_fallbacks_engine, 0,
        "the native engine must never fall back"
    );
    assert_eq!(st.pre_route_fallbacks_length, 0);
    // Full accounting: every batch either pre-routed or counted an
    // epoch fallback — resize-window degradation is visible, not silent.
    assert_eq!(
        st.pre_routed_batches + st.pre_route_fallbacks_epoch,
        st.total_batches,
        "unaccounted pre-route outcome: {st:?}"
    );
}

#[test]
fn elastic_policy_splits_under_load_and_merges_when_it_drains() {
    // The controller's load-based policy end to end on the native
    // engine: sustained occupancy on a 1-shard service must trigger an
    // online split (recorded + visible in the stats), and draining the
    // keyspace must merge back down.
    let mut cfg = attack_config(512);
    cfg.hash = HashFn::Seeded(0xfeed);
    cfg.shards = 1;
    cfg.detector.period = Duration::from_millis(10);
    cfg.elastic = Some(ElasticConfig {
        max_shards: 4,
        split_load_factor: 4.0,
        merge_load_factor: 1.0,
        chi2_weight: 0.0,
        cooldown: Duration::from_millis(20),
    });
    let c = Arc::new(Coordinator::start(cfg).unwrap());

    // Load: 512 buckets * lf 4 = 2048 nodes trips the split threshold.
    let puts: Vec<Request> = (0..6000u64).map(|k| Request::put(k, k)).collect();
    for chunk in puts.chunks(512) {
        c.execute_many(chunk.to_vec());
    }
    let mut waited = 0;
    while c.stats().splits == 0 && waited < 5_000 {
        std::thread::sleep(Duration::from_millis(25));
        waited += 25;
    }
    let st = c.stats();
    assert!(st.splits >= 1, "sustained load never split: {st:?}");
    assert!(st.shards > 1);
    assert!(st.shards <= 4, "split past max_shards: {st:?}");
    let events = c.resize_events();
    assert!(
        events
            .iter()
            .any(|e| matches!(e.action, dhash::coordinator::ResizeAction::Split(_))),
        "no split event recorded: {events:?}"
    );

    // Every key still resolves on the grown directory.
    for k in (0..6000u64).step_by(17) {
        assert_eq!(c.execute(Request::get(k)), Response::Value(k));
    }

    // Drain: occupancy collapses below the merge threshold -> merge.
    let dels: Vec<Request> = (0..6000u64).map(Request::del).collect();
    for chunk in dels.chunks(512) {
        c.execute_many(chunk.to_vec());
    }
    let mut waited = 0;
    while c.stats().merges == 0 && waited < 5_000 {
        std::thread::sleep(Duration::from_millis(25));
        waited += 25;
    }
    let st = c.stats();
    assert!(st.merges >= 1, "drained service never merged: {st:?}");
    c.shutdown();
}

#[test]
fn detector_runs_are_counted() {
    let c = Arc::new(Coordinator::start(attack_config(256)).unwrap());
    let reqs: Vec<Request> = (0..1024u64).map(|i| Request::put(i, i)).collect();
    c.execute_many(reqs);
    let mut waited = 0;
    while c.stats().detector_runs == 0 && waited < 2_000 {
        std::thread::sleep(Duration::from_millis(25));
        waited += 25;
    }
    assert!(c.stats().detector_runs > 0, "analytics loop never evaluated");
    c.shutdown();
}

#[test]
fn benign_seeded_service_never_rebuilds() {
    // A service already on a seeded hash sees the same attack keys as
    // uniform load: the detector must not fire.
    let mut cfg = attack_config(1024);
    cfg.hash = HashFn::Seeded(0xfeed);
    let c = Arc::new(Coordinator::start(cfg).unwrap());
    let reqs: Vec<Request> = AttackGen::new(1024, 3)
        .take(4096)
        .map(|k| Request::put(k, k))
        .collect();
    c.execute_many(reqs);
    // Poll until the detector has evaluated the full sample a few times
    // (a fixed sleep flakes on loaded runners), then check no rebuild.
    let mut waited = 0;
    while c.stats().detector_runs < 3 && waited < 3_000 {
        std::thread::sleep(Duration::from_millis(25));
        waited += 25;
    }
    let st = c.stats();
    assert!(st.detector_runs > 0, "detector never ran");
    assert_eq!(st.rebuilds, 0, "seeded hash misdetected as attacked");
    c.shutdown();
}
