//! Property tests for the wire-protocol frame codec: randomized
//! round-trips, resumption across arbitrary split points, corrupt
//! headers rejected as [`ProtoError`]s (never a panic), and hostile
//! value lengths capped straight from the header. Platform-independent
//! (the codec itself has no OS surface).

use dhash::error::ProtoError;
use dhash::net::codec::Decoder;
use dhash::net::proto::{
    Request, RequestFrame, Response, ResponseFrame, MAGIC_REQ, MAX_VALUE_LEN, REQ_HEADER_LEN,
    VERSION,
};
use dhash::util::prop::{check, Gen};

fn arb_request(g: &mut Gen) -> RequestFrame {
    let id = g.u64();
    let key = g.u64();
    let req = match g.range(0, 3) {
        0 => Request::get(key),
        1 => Request::put(key, g.u64()),
        _ => Request::del(key),
    };
    RequestFrame::new(id, req)
}

fn arb_response(g: &mut Gen) -> ResponseFrame {
    let id = g.u64();
    let body = match g.range(0, 4) {
        0 => Ok(Response::Ok),
        1 => Ok(Response::Value(g.u64())),
        2 => Ok(Response::Missing),
        _ => Err(g.range(0, 256) as u8),
    };
    ResponseFrame { id, body }
}

#[test]
fn requests_round_trip_across_arbitrary_splits() {
    check("request round-trip", 200, |g| {
        let mut frames = g.vec(32, arb_request);
        frames.push(arb_request(g)); // at least one frame per case
        let mut wire = Vec::new();
        for f in &frames {
            f.encode(&mut wire);
        }
        // Feed the stream in random-size chunks; every split point must
        // resume cleanly.
        let mut dec = Decoder::new();
        let mut got = Vec::new();
        let mut pos = 0;
        while pos < wire.len() {
            let n = g.usize_in(1, 9).min(wire.len() - pos);
            dec.push(&wire[pos..pos + n]);
            pos += n;
            while let Some(f) = dec.next_request().map_err(|e| e.to_string())? {
                got.push(f);
            }
        }
        if got != frames {
            return Err(format!("decoded {} frames, sent {}", got.len(), frames.len()));
        }
        if dec.pending() != 0 {
            return Err(format!("{} stray trailing bytes", dec.pending()));
        }
        Ok(())
    });
}

#[test]
fn responses_round_trip_across_arbitrary_splits() {
    check("response round-trip", 200, |g| {
        let mut frames = g.vec(32, arb_response);
        frames.push(arb_response(g));
        let mut wire = Vec::new();
        for f in &frames {
            f.encode(&mut wire);
        }
        let mut dec = Decoder::new();
        let mut got = Vec::new();
        let mut pos = 0;
        while pos < wire.len() {
            let n = g.usize_in(1, 9).min(wire.len() - pos);
            dec.push(&wire[pos..pos + n]);
            pos += n;
            while let Some(f) = dec.next_response().map_err(|e| e.to_string())? {
                got.push(f);
            }
        }
        if got != frames {
            return Err(format!("decoded {} frames, sent {}", got.len(), frames.len()));
        }
        Ok(())
    });
}

#[test]
fn truncated_frames_wait_for_more_instead_of_failing() {
    check("truncation", 200, |g| {
        let f = arb_request(g);
        let mut wire = Vec::new();
        f.encode(&mut wire);
        let cut = g.usize_in(0, wire.len()); // strict prefix
        let mut dec = Decoder::new();
        dec.push(&wire[..cut]);
        match dec.next_request() {
            Ok(None) => Ok(()),
            Ok(Some(f2)) => Err(format!("decoded {f2:?} from a strict prefix")),
            Err(e) => Err(format!("strict prefix rejected: {e}")),
        }
    });
}

#[test]
fn corrupt_headers_are_protocol_errors_not_panics() {
    check("header corruption", 300, |g| {
        let f = arb_request(g);
        let mut wire = Vec::new();
        f.encode(&mut wire);
        let b = g.range(0, 256) as u8;
        let mut dec = Decoder::new();
        match g.range(0, 4) {
            0 => {
                if b == MAGIC_REQ {
                    return Ok(());
                }
                wire[0] = b;
                dec.push(&wire);
                match dec.next_request() {
                    Err(ProtoError::BadMagic(x)) if x == b => Ok(()),
                    other => Err(format!("magic {b:#04x}: got {other:?}")),
                }
            }
            1 => {
                if b == VERSION {
                    return Ok(());
                }
                wire[1] = b;
                dec.push(&wire);
                match dec.next_request() {
                    Err(ProtoError::BadVersion(x)) if x == b => Ok(()),
                    other => Err(format!("version {b:#04x}: got {other:?}")),
                }
            }
            2 => {
                if (1..=3).contains(&b) {
                    return Ok(()); // still a valid op byte
                }
                wire[2] = b;
                dec.push(&wire);
                match dec.next_request() {
                    Err(ProtoError::BadOpCode(x)) if x == b => Ok(()),
                    other => Err(format!("op {b:#04x}: got {other:?}")),
                }
            }
            _ => {
                if b == 0 {
                    return Ok(()); // reserved byte must be 0; 0 is valid
                }
                wire[3] = b;
                dec.push(&wire);
                match dec.next_request() {
                    Err(ProtoError::BadReserved(x)) if x == b => Ok(()),
                    other => Err(format!("reserved {b:#04x}: got {other:?}")),
                }
            }
        }
    });
}

#[test]
fn oversized_value_length_rejected_straight_from_the_header() {
    check("oversized vlen", 200, |g| {
        let mut wire = Vec::new();
        RequestFrame::new(g.u64(), Request::put(g.u64(), g.u64())).encode(&mut wire);
        let vlen = g.range(MAX_VALUE_LEN as u64 + 1, u32::MAX as u64 + 1) as u32;
        wire[20..24].copy_from_slice(&vlen.to_le_bytes());
        // Push the header ONLY: the hostile length must be rejected
        // without waiting for (let alone allocating) the claimed body.
        let mut dec = Decoder::new();
        dec.push(&wire[..REQ_HEADER_LEN]);
        match dec.next_request() {
            Err(ProtoError::ValueTooLong(x)) if x == vlen => Ok(()),
            other => Err(format!("vlen {vlen}: got {other:?}")),
        }
    });
}
