//! Cross-layer agreement: the Rust data path (`util::rng::mix64` /
//! `HashFn`) and the detector engine's batched hash kernel must place
//! every key in the same bucket. Runs against the default engine (the
//! native backend; `DHASH_ENGINE=pjrt` exercises the artifact backend on
//! hosts with an XLA binding).

use dhash::dhash::HashFn;
use dhash::runtime::{load_engine, Engine, HashKind};

fn engine() -> Box<dyn Engine> {
    load_engine().expect("default engine always loads")
}

#[test]
fn seeded_hash_agrees_with_rust() {
    let engine = engine();
    let mut rng = dhash::util::SplitMix64::new(123);
    let keys: Vec<u64> = (0..engine.batch()).map(|_| rng.next_u64()).collect();
    for (seed, nbuckets) in [(0u64, 1024u64), (0xdead_beef, 97), (u64::MAX, 4096)] {
        let ids = engine
            .batch_hash(&keys, seed, nbuckets, HashKind::Seeded)
            .unwrap();
        assert_eq!(ids.len(), keys.len());
        let hash = HashFn::Seeded(seed);
        for (k, id) in keys.iter().zip(&ids) {
            assert_eq!(
                *id as usize,
                hash.bucket(*k, nbuckets as usize),
                "seeded disagreement for key {k:#x} seed {seed:#x} nb {nbuckets}"
            );
        }
    }
}

#[test]
fn modulo_hash_agrees_with_rust() {
    let engine = engine();
    let keys: Vec<u64> = (0..256u64).map(|i| i * 7919).collect();
    let ids = engine.batch_hash(&keys, 0, 64, HashKind::Modulo).unwrap();
    assert_eq!(ids.len(), keys.len());
    for (k, id) in keys.iter().zip(&ids) {
        assert_eq!(*id as usize, HashFn::Modulo.bucket(*k, 64));
    }
}

#[test]
fn detector_flags_attack_but_not_uniform() {
    let engine = engine();
    // Uniform random keys under a seeded hash: chi2 near nbins-1.
    let mut rng = dhash::util::SplitMix64::new(7);
    let uniform: Vec<u64> = (0..engine.batch()).map(|_| rng.next_u64()).collect();
    let d = engine.detect(&uniform, 5, 4096, HashKind::Seeded).unwrap();
    let dof = (engine.nbins() - 1) as f32;
    assert!(d.chi2 < 2.0 * dof, "uniform chi2 too high: {}", d.chi2);
    assert_eq!(
        d.hist.iter().map(|&x| x as usize).sum::<usize>(),
        engine.batch()
    );

    // Collision attack under the weak modulo hash: chi2 explodes.
    let attack: Vec<u64> = (0..engine.batch() as u64).map(|i| 7 + i * 4096).collect();
    let d = engine.detect(&attack, 0, 4096, HashKind::Modulo).unwrap();
    assert!(d.chi2 > 50.0 * dof, "attack chi2 too low: {}", d.chi2);
    assert_eq!(d.max_load as usize, engine.batch());

    // The very same attack keys under a seeded rebuild: healthy again —
    // this is the mitigation the coordinator performs.
    let d = engine.detect(&attack, 0x1234, 4096, HashKind::Seeded).unwrap();
    assert!(d.chi2 < 2.0 * dof, "post-rebuild chi2 still high: {}", d.chi2);
}

#[test]
fn short_samples_keep_proportions() {
    // The native engine evaluates the exact sample (no artifact-style
    // padding): a single key is a single histogram count, and its bucket
    // id matches the data path.
    let engine = engine();
    let ids = engine.batch_hash(&[42], 1, 16, HashKind::Seeded).unwrap();
    assert_eq!(ids.len(), 1);
    assert_eq!(ids[0] as usize, HashFn::Seeded(1).bucket(42, 16));
    let d = engine.detect(&[42, 43], 1, 4096, HashKind::Seeded).unwrap();
    assert_eq!(d.hist.iter().map(|&x| x as i64).sum::<i64>(), 2);
}

#[test]
fn chi2_threshold_monotone_in_sigma() {
    let engine = engine();
    assert!(engine.chi2_threshold(4.0) < engine.chi2_threshold(8.0));
}
