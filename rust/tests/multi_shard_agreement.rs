//! Multi-shard cross-layer agreement, mirroring `hash_agreement.rs`:
//! the vectorized `batch_hash_multi` kernel must place every key of a
//! mixed-shard batch exactly where (a) a per-shard `batch_hash` loop
//! and (b) the data path's `HashFn` put it — including after targeted
//! `rebuild_shard`s diverge individual shards' geometry (the state the
//! routing oracle faces after a mitigation) and after `split_shard` /
//! `merge_shard` reshape the directory itself (the state it faces under
//! the elastic policy).

use dhash::dhash::{HashFn, ShardedDHash};
use dhash::rcu::{rcu_barrier, RcuThread};
use dhash::runtime::{
    composite_route_id, load_engine, Engine, HashKind, NativeEngine, ShardParams,
};
use dhash::util::SplitMix64;

/// Engine-side params for a map's routing snapshot.
fn params_of(snapshot: &[(HashFn, usize)]) -> Vec<ShardParams> {
    snapshot
        .iter()
        .map(|&(hash, nb)| {
            let (kind, seed) = HashKind::of(hash);
            (seed, nb as u64, kind)
        })
        .collect()
}

/// Pin `batch_hash_multi` against both references for `keys` under the
/// map's current epoch-stamped routing snapshot.
fn check_agreement(engine: &dyn Engine, map: &ShardedDHash, g: &RcuThread, keys: &[u64]) {
    let snap = map.route_snapshot(g);
    let params = params_of(&snap.shards);
    let shard_ids: Vec<u32> = keys.iter().map(|&k| snap.shard_of(k)).collect();
    let multi = engine.batch_hash_multi(keys, &shard_ids, &params).unwrap();
    assert_eq!(multi.len(), keys.len(), "exact-length contract");
    // The snapshot's mapping is the live directory's mapping (no resize
    // ran between the two reads in this single-threaded test).
    for &k in keys {
        assert_eq!(snap.shard_of(k) as usize, map.shard_of(g, k));
    }

    // (a) One batch_hash call per shard over that shard's keys must give
    // the same buckets the single multi call gave.
    for s in 0..snap.nshards() {
        let (seed, nb, kind) = params[s];
        let shard_keys: Vec<u64> = keys
            .iter()
            .copied()
            .filter(|&k| snap.shard_of(k) as usize == s)
            .collect();
        if shard_keys.is_empty() {
            continue;
        }
        let per_shard = engine.batch_hash(&shard_keys, seed, nb, kind).unwrap();
        let mut ids = per_shard.iter();
        for (i, &k) in keys.iter().enumerate() {
            if snap.shard_of(k) as usize == s {
                let bucket = *ids.next().unwrap();
                assert_eq!(
                    multi[i],
                    composite_route_id(s as u32, bucket as u32),
                    "key {k:#x}: multi call disagrees with per-shard batch_hash"
                );
            }
        }
    }

    // (b) The data path's HashFn must place every key in the bucket the
    // composite id encodes — the invariant that makes pre-routed batch
    // order equal the worker's actual memory-access order.
    for (i, &k) in keys.iter().enumerate() {
        let s = snap.shard_of(k) as usize;
        let (hash, nb) = snap.shards[s];
        assert_eq!(
            multi[i],
            composite_route_id(s as u32, hash.bucket(k, nb) as u32),
            "key {k:#x} shard {s}: kernel and data path disagree"
        );
    }
}

#[test]
fn multi_shard_routing_agrees_across_layers_and_rebuilds() {
    let engine = load_engine().expect("default engine always loads");
    let g = RcuThread::register();
    let map = ShardedDHash::with_buckets(8, 1024, 0xd1e5);
    let mut rng = SplitMix64::new(2026);
    let keys: Vec<u64> = (0..4096).map(|_| rng.next_u64()).collect();
    check_agreement(engine.as_ref(), &map, &g, &keys);

    // Targeted rebuild: one shard's seed AND bucket count diverge, as
    // after a mitigation. Agreement must hold on the mixed geometry.
    map.rebuild_shard(&g, 3, 2048, HashFn::Seeded(0xfeed_f00d)).unwrap();
    check_agreement(engine.as_ref(), &map, &g, &keys);

    // A second divergence, to the other hash family.
    map.rebuild_shard(&g, 5, 512, HashFn::Modulo).unwrap();
    check_agreement(engine.as_ref(), &map, &g, &keys);

    g.quiescent_state();
    rcu_barrier();
}

#[test]
fn multi_shard_routing_agrees_across_splits_and_merges() {
    // The elastic state: an uneven directory (shards at mixed selector
    // depths) after online splits, then again after a merge folds it
    // back. The composite-id contract must hold at every epoch.
    let engine = load_engine().expect("default engine always loads");
    let g = RcuThread::register();
    let map = ShardedDHash::with_buckets(4, 512, 0xe1a5);
    let mut rng = SplitMix64::new(77);
    let keys: Vec<u64> = (0..4096).map(|_| rng.next_u64()).collect();

    map.split_shard(&g, 1, 256, HashFn::Seeded(0xab)).unwrap();
    assert_eq!(map.shards(), 5);
    check_agreement(engine.as_ref(), &map, &g, &keys);

    // Diverge one child's geometry on top of the uneven layout.
    map.rebuild_shard(&g, 2, 1024, HashFn::Seeded(0xcd)).unwrap();
    check_agreement(engine.as_ref(), &map, &g, &keys);

    // Merge the pair back and re-check on the folded directory.
    map.merge_shard(&g, 1, 512, HashFn::Seeded(0xef)).unwrap();
    assert_eq!(map.shards(), 4);
    check_agreement(engine.as_ref(), &map, &g, &keys);

    g.quiescent_state();
    rcu_barrier();
}

#[test]
fn multi_kernel_chunks_past_its_batch_cap() {
    // An input far beyond the kernel batch must still come back
    // exact-length and key-for-key identical to the references — the
    // truncation regression, at the multi-kernel level.
    let engine = NativeEngine::with_shape(16, 4);
    let g = RcuThread::register();
    let map = ShardedDHash::with_buckets(4, 64, 7);
    let keys: Vec<u64> = (0..1000).map(|i| i * 2_654_435_761).collect();
    check_agreement(&engine, &map, &g, &keys);
    g.quiescent_state();
    rcu_barrier();
}
