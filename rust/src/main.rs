//! `dhash` — the leader binary: torture benchmarks, the KV service (in
//! process or over the wire), rebuild diagnostics, and the network
//! bench client from one CLI.
//!
//! ```text
//! dhash torture   [--table dhash|xu|rht|split] [--threads N] ...
//! dhash serve     [--buckets B] [--shards N] [--max-shards M] ...
//!                 [--listen ADDR] [--net-workers W] [--window K]
//! dhash rebuild   [--table dhash|xu|rht|split] [--nodes N] [--buckets B]
//! dhash netbench  [--addr ADDR] [--conns N] [--depth K] [--secs S]
//! ```
//!
//! Each subcommand owns a flag registry: an unknown flag is a hard
//! error listing the valid set, and `dhash <cmd> --help` prints every
//! flag with its default. `serve --max-shards M` (M > 0) turns on the
//! elastic policy (online split/merge up to M shards); `serve --listen`
//! adds the wire-protocol front end (see `DESIGN.md` §Network front
//! end); `netbench` with no `--addr` benches an internal loopback
//! server.

use std::sync::Arc;
use std::time::Duration;

use dhash::baselines::{ConcurrentMap, HtRht, HtSplit, HtXu};
use dhash::coordinator::{Coordinator, CoordinatorConfig, ElasticConfig, PreRoute, Request};
use dhash::dhash::{DHashMap, HashFn};
use dhash::error::{KvError, ResizeError};
use dhash::rcu::RcuThread;
use dhash::torture::{self, OpMix, RebuildMode, TortureConfig};
use dhash::util::cli::{Args, CmdSpec, FlagSpec};
use dhash::util::Summary;

const TORTURE: CmdSpec = CmdSpec {
    name: "torture",
    about: "multi-threaded throughput benchmark over one table",
    flags: &[
        FlagSpec::new("table", "dhash", "table: dhash|xu|rht|split"),
        FlagSpec::new("threads", "4", "client threads"),
        FlagSpec::new("lookup-pct", "90", "lookup share of the op mix (%)"),
        FlagSpec::new("alpha", "20", "target nodes per bucket"),
        FlagSpec::new("buckets", "1024", "bucket count"),
        FlagSpec::new("alt-buckets", "0", "rebuild target size (0 = 2x)"),
        FlagSpec::new("keys", "1000000", "key range"),
        FlagSpec::new("secs", "1", "seconds per sample"),
        FlagSpec::new("no-rebuild", "false", "disable continuous rebuilds"),
        FlagSpec::new("no-pin", "false", "do not pin threads to cores"),
        FlagSpec::new("repeats", "3", "samples per configuration"),
        FlagSpec::new("seed", "3521470189", "workload RNG seed (0xd1e55eed)"),
        FlagSpec::new("hash-seed", "24301", "hash seed (0x5eed)"),
    ],
};

const SERVE: CmdSpec = CmdSpec {
    name: "serve",
    about: "run the coordinator KV service under synthetic load",
    flags: &[
        FlagSpec::new("buckets", "4096", "buckets per shard"),
        FlagSpec::new("shards", "1", "initial shard count"),
        FlagSpec::new("max-shards", "0", "elastic growth limit (0 = fixed)"),
        FlagSpec::new("lanes", "1", "ingest lanes"),
        FlagSpec::new("workers", "2", "KV worker threads"),
        FlagSpec::new("pre-route", "off", "pre-routing: off|shard|bucket"),
        FlagSpec::new("secs", "10", "run duration in seconds"),
        FlagSpec::new("attack-at", "secs/2", "attack burst start (seconds)"),
        FlagSpec::new("weak-hash", "false", "start from the modulo hash"),
        FlagSpec::new("no-analytics", "false", "disable detector/mitigation"),
        FlagSpec::new("listen", "off", "wire-protocol bind address"),
        FlagSpec::new("net-workers", "2", "epoll worker threads"),
        FlagSpec::new("window", "256", "inflight window before shedding"),
    ],
};

const REBUILD: CmdSpec = CmdSpec {
    name: "rebuild",
    about: "time one full rebuild of a populated table",
    flags: &[
        FlagSpec::new("table", "dhash", "table: dhash|xu|rht|split"),
        FlagSpec::new("nodes", "100000", "nodes inserted pre-rebuild"),
        FlagSpec::new("buckets", "1024", "start size (rebuild doubles)"),
    ],
};

const NETBENCH: CmdSpec = CmdSpec {
    name: "netbench",
    about: "pipelined wire-protocol client: verify pass + load pass",
    flags: &[
        FlagSpec::new("addr", "(internal)", "server address (omit = loopback)"),
        FlagSpec::new("conns", "8", "client connections"),
        FlagSpec::new("depth", "8", "pipelined requests per conn"),
        FlagSpec::new("secs", "2", "load-pass duration (seconds)"),
        FlagSpec::new("keys", "65536", "load-pass key space"),
        FlagSpec::new("verify-keys", "512", "verify-pass keys per conn"),
    ],
};

const COMMANDS: &[&CmdSpec] = &[&TORTURE, &SERVE, &REBUILD, &NETBENCH];

fn make_table(name: &str, nbuckets: usize, seed: u64) -> Arc<dyn ConcurrentMap> {
    match name {
        "dhash" => Arc::new(DHashMap::with_buckets(nbuckets, seed)),
        "xu" => Arc::new(HtXu::new(nbuckets, HashFn::Seeded(seed))),
        "rht" => Arc::new(HtRht::new(nbuckets, HashFn::Seeded(seed))),
        "split" => Arc::new(HtSplit::new(nbuckets, 1 << 20)),
        other => {
            eprintln!("unknown table {other:?} (want dhash|xu|rht|split)");
            std::process::exit(2);
        }
    }
}

fn cmd_torture(args: &Args) -> anyhow::Result<()> {
    let table = args.get("table").unwrap_or("dhash").to_string();
    let buckets = args.get_or("buckets", 1024usize)?;
    let cfg = TortureConfig {
        threads: args.get_or("threads", 4usize)?,
        mix: OpMix::lookup_pct(args.get_or("lookup-pct", 90u8)?),
        alpha: args.get_or("alpha", 20usize)?,
        nbuckets: buckets,
        key_range: args.get_or("keys", 1_000_000u64)?,
        duration: Duration::from_secs_f64(args.get_or("secs", 1.0f64)?),
        rebuild: if args.get_bool("no-rebuild") {
            RebuildMode::None
        } else {
            RebuildMode::Continuous {
                alt_nbuckets: match args.get_or("alt-buckets", 0usize)? {
                    0 => buckets * 2,
                    x => x,
                },
            }
        },
        pin: !args.get_bool("no-pin"),
        seed: args.get_or("seed", 0xd1e5_5eedu64)?,
        hash_seed: args.get_or("hash-seed", 0x5eedu64)?,
    };
    let repeats = args.get_or("repeats", 3usize)?;
    let map = make_table(&table, cfg.nbuckets, cfg.hash_seed);
    eprintln!(
        "torture: table={} threads={} mix={}%L alpha={} buckets={} U={} {:?}",
        map.name(),
        cfg.threads,
        cfg.mix.lookup,
        cfg.alpha,
        cfg.nbuckets,
        cfg.key_range,
        cfg.rebuild
    );
    let samples = torture::measure_mops(map, &cfg, repeats);
    let s = Summary::of(&samples);
    println!(
        "{} threads={} mops_mean={:.3} mops_stddev={:.3} samples={:?}",
        table, cfg.threads, s.mean, s.stddev, samples
    );
    Ok(())
}

/// The network front end, present only where the epoll listener builds.
#[cfg(unix)]
type NetFront = Option<dhash::net::NetServer>;
#[cfg(not(unix))]
type NetFront = Option<std::convert::Infallible>;

#[cfg(unix)]
fn start_net(listen: &str, args: &Args, c: &Coordinator) -> anyhow::Result<NetFront> {
    let cfg = dhash::net::NetConfig {
        addr: listen.to_string(),
        workers: args.get_or("net-workers", 2usize)?,
        inflight_window: args.get_or("window", 256usize)?,
        ..Default::default()
    };
    let net = dhash::net::NetServer::start(&cfg, c.client())?;
    eprintln!("serving the wire protocol on {}", net.local_addr()?);
    Ok(Some(net))
}

#[cfg(not(unix))]
fn start_net(_listen: &str, _args: &Args, _c: &Coordinator) -> anyhow::Result<NetFront> {
    anyhow::bail!("--listen needs the unix network front end (not built on this platform)")
}

#[allow(unused_mut, unused_variables)]
fn folded_stats(c: &Coordinator, net: &NetFront) -> dhash::coordinator::CoordinatorStats {
    let mut st = c.stats();
    #[cfg(unix)]
    if let Some(n) = net {
        n.fold_stats(&mut st);
    }
    st
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let secs = args.get_or("secs", 10u64)?;
    let attack_at = args.get_or("attack-at", secs / 2)?;
    let nbuckets = args.get_or("buckets", 4096usize)?;
    let pre_route = match args.get("pre-route").unwrap_or("off") {
        "off" => PreRoute::Off,
        "shard" => PreRoute::Shard,
        "bucket" => PreRoute::Bucket,
        other => anyhow::bail!("unknown --pre-route {other:?} (want off|shard|bucket)"),
    };
    let max_shards = args.get_or("max-shards", 0usize)?;
    let mut cfg = CoordinatorConfig {
        nbuckets,
        hash: if args.get_bool("weak-hash") {
            HashFn::Modulo
        } else {
            HashFn::Seeded(0xd1e5)
        },
        shards: args.get_or("shards", 1usize)?,
        lanes: args.get_or("lanes", 1usize)?,
        workers: args.get_or("workers", 2usize)?,
        elastic: (max_shards > 0).then(|| ElasticConfig {
            max_shards,
            ..Default::default()
        }),
        enable_analytics: !args.get_bool("no-analytics"),
        ..Default::default()
    };
    cfg.batcher.pre_route = pre_route;
    eprintln!("serve: {cfg:?} for {secs}s, attack at {attack_at}s");
    let c = Arc::new(Coordinator::start(cfg)?);
    let net: NetFront = match args.get("listen").unwrap_or("off") {
        "off" => None,
        addr => start_net(addr, args, &c)?,
    };

    // Client load: normal traffic, then an attack burst.
    let c2 = c.clone();
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let s2 = stop.clone();
    let client = std::thread::spawn(move || {
        let kv = c2.client();
        let mut rng = dhash::util::SplitMix64::new(1);
        let mut attack = dhash::torture::AttackGen::new(nbuckets, 7);
        let t0 = std::time::Instant::now();
        while !s2.load(std::sync::atomic::Ordering::Relaxed) {
            let attacking = t0.elapsed().as_secs() >= attack_at;
            let reqs: Vec<Request> = (0..64)
                .map(|_| {
                    if attacking && rng.next_f64() < 0.8 {
                        Request::put(attack.next().unwrap(), 0)
                    } else {
                        let k = rng.next_bounded(1_000_000);
                        if rng.next_f64() < 0.9 {
                            Request::get(k)
                        } else {
                            Request::put(k, k)
                        }
                    }
                })
                .collect();
            // Completion-based ingest: submit, then resolve the ticket.
            match kv.submit_batch(&reqs) {
                Ok(ticket) => {
                    let _ = ticket.wait();
                }
                Err(_) => break, // shut down
            }
        }
    });

    for sec in 0..secs {
        std::thread::sleep(Duration::from_secs(1));
        let st = folded_stats(&c, &net);
        println!(
            "t={:>3}s requests={:>9} batches={:>7} routed={:>7} fb_len={} fb_eng={} fb_ep={} \
             shards={} epoch={} splits={} merges={} chi2={:>10.1} rebuilds={}",
            sec + 1,
            st.total_requests,
            st.total_batches,
            st.pre_routed_batches,
            st.pre_route_fallbacks_length,
            st.pre_route_fallbacks_engine,
            st.pre_route_fallbacks_epoch,
            st.shards,
            st.epoch,
            st.splits,
            st.merges,
            st.last_chi2,
            st.rebuilds
        );
        if let Some(ns) = &st.net {
            println!(
                "      net conns={}/{} frames_in={} frames_out={} batches={} sheds={} \
                 proto_errs={}",
                ns.active, ns.accepted, ns.frames_in, ns.frames_out, ns.batches, ns.sheds,
                ns.protocol_errors
            );
        }
    }
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    client.join().unwrap();
    // Drain the front end first so pending tickets resolve and flush
    // before the coordinator goes away.
    #[cfg(unix)]
    if let Some(n) = net {
        let ns = n.shutdown();
        println!("net drained: {ns:?}");
    }
    for ev in c.rebuild_events() {
        println!(
            "mitigation at {:?}: shard {} (epoch {}) chi2={:.1} -> {:?} ({} nodes in {:?})",
            ev.at, ev.shard, ev.epoch, ev.chi2, ev.new_hash, ev.moved, ev.elapsed
        );
    }
    for ev in c.resize_events() {
        println!(
            "resize at {:?}: {:?} (epoch {} -> {} shards, {} nodes in {:?})",
            ev.at, ev.action, ev.epoch, ev.shards_after, ev.moved, ev.elapsed
        );
    }
    c.shutdown();
    Ok(())
}

fn cmd_rebuild(args: &Args) -> anyhow::Result<()> {
    let table = args.get("table").unwrap_or("dhash").to_string();
    let nodes = args.get_or("nodes", 100_000u64)?;
    let nbuckets = args.get_or("buckets", 1024usize)?;
    if nbuckets == 0 {
        // Same refusal the wire boundary gives: typed, never a panic in
        // the table allocator.
        anyhow::bail!(
            "invalid --buckets 0: {} (wire code {:#04x})",
            KvError::Resize(ResizeError::BadGeometry),
            KvError::Resize(ResizeError::BadGeometry).code()
        );
    }
    let map = make_table(&table, nbuckets, 1);
    let g = RcuThread::register();
    for k in 0..nodes {
        map.insert(&g, k, k);
    }
    let t0 = std::time::Instant::now();
    let ok = map.rebuild(&g, nbuckets * 2, HashFn::Seeded(2));
    let dt = t0.elapsed();
    g.quiescent_state();
    println!(
        "{}: rebuild of {} nodes -> {} buckets: ok={} in {:?} ({:.0} nodes/ms)",
        map.name(),
        nodes,
        nbuckets * 2,
        ok,
        dt,
        nodes as f64 / dt.as_secs_f64() / 1e3
    );
    Ok(())
}

#[cfg(unix)]
fn cmd_netbench(args: &Args) -> anyhow::Result<()> {
    use dhash::net::bench::{throughput_run, verify_run};
    use dhash::net::{BenchReport, NetConfig, NetServer};

    let conns = args.get_or("conns", 8usize)?.max(1);
    let depth = args.get_or("depth", 8usize)?.max(1);
    let secs = args.get_or("secs", 2.0f64)?;
    let key_space = args.get_or("keys", 65_536u64)?;
    let verify_keys = args.get_or("verify-keys", 512u64)?;

    // Target: an explicit --addr, or an internal loopback server.
    let (addr, internal) = match args.get("addr") {
        Some(a) => (a.parse::<std::net::SocketAddr>()?, None),
        None => {
            let cfg = CoordinatorConfig {
                shards: 4,
                lanes: 2,
                enable_analytics: false,
                ..Default::default()
            };
            let c = Coordinator::start(cfg)?;
            let net = NetServer::start(&NetConfig::default(), c.client())?;
            let addr = net.local_addr()?;
            eprintln!("netbench: internal server on {addr}");
            (addr, Some((c, net)))
        }
    };

    // Verify pass: phased self-validating workload per connection.
    let mut vr = BenchReport::default();
    let hs: Vec<_> = (0..conns)
        .map(|i| {
            std::thread::spawn(move || verify_run(addr, (i as u64) << 32, verify_keys, depth))
        })
        .collect();
    for h in hs {
        vr.merge(&h.join().expect("verify client panicked")?);
    }
    println!(
        "netbench verify conns={conns} depth={depth} keys/conn={verify_keys} sent={} ok={} \
         sheds={} errors={} mismatches={} reorders={}",
        vr.sent, vr.ok, vr.sheds, vr.errors, vr.mismatches, vr.reorders
    );
    if vr.mismatches + vr.reorders > 0 {
        anyhow::bail!("verify pass failed: responses lost, reordered, or wrong");
    }

    // Load pass: random mixed ops, validation off.
    let dur = Duration::from_secs_f64(secs);
    let t0 = std::time::Instant::now();
    let mut tr = BenchReport::default();
    let hs: Vec<_> = (0..conns)
        .map(|i| {
            std::thread::spawn(move || throughput_run(addr, dur, depth, key_space, 1 + i as u64))
        })
        .collect();
    for h in hs {
        tr.merge(&h.join().expect("load client panicked")?);
    }
    let dt = t0.elapsed();
    println!(
        "netbench load conns={conns} depth={depth} secs={:.1} received={} sheds={} errors={} \
         req_per_s={:.0}",
        dt.as_secs_f64(),
        tr.received,
        tr.sheds,
        tr.errors,
        tr.received as f64 / dt.as_secs_f64()
    );

    if let Some((c, net)) = internal {
        net.shutdown();
        c.shutdown();
    }
    Ok(())
}

#[cfg(not(unix))]
fn cmd_netbench(_args: &Args) -> anyhow::Result<()> {
    anyhow::bail!("netbench needs the unix network front end (not built on this platform)")
}

fn usage() -> ! {
    eprintln!("usage: dhash <command> [flags]\n\ncommands:");
    for c in COMMANDS {
        eprintln!("  {:<9} {}", c.name, c.about);
    }
    eprintln!("\n`dhash <command> --help` lists that command's flags.");
    std::process::exit(2);
}

fn main() -> anyhow::Result<()> {
    let mut tokens: Vec<String> = std::env::args().skip(1).collect();
    if tokens.is_empty() {
        usage();
    }
    let cmd = tokens.remove(0);
    let Some(spec) = COMMANDS.iter().find(|c| c.name == cmd) else {
        eprintln!("unknown command {cmd:?}\n");
        usage();
    };
    let args = match spec.parse(tokens) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    if args.get_bool("help") {
        print!("{}", spec.help());
        return Ok(());
    }
    match spec.name {
        "torture" => cmd_torture(&args),
        "serve" => cmd_serve(&args),
        "rebuild" => cmd_rebuild(&args),
        "netbench" => cmd_netbench(&args),
        _ => unreachable!("command table and dispatch drifted"),
    }
}
