//! `dhash` — the leader binary: torture benchmarks, the KV service, and
//! rebuild diagnostics from one CLI.
//!
//! ```text
//! dhash torture  [--table dhash|xu|rht|split] [--threads N] [--lookup-pct P]
//!                [--alpha A] [--buckets B] [--keys U] [--secs S]
//!                [--no-rebuild] [--repeats R]
//! dhash serve    [--buckets B] [--shards N] [--max-shards M] [--lanes L]
//!                [--workers W] [--pre-route off|shard|bucket] [--secs S]
//!                [--attack-at T] [--weak-hash] [--no-analytics]
//!
//! `--max-shards M` (M > 0) turns on the elastic policy: the analytics
//! thread splits hot shards and merges cold buddy pairs online, up to M
//! shards; 0 (the default) keeps the shard count fixed at `--shards`.
//! dhash rebuild  [--table dhash|xu|rht|split] [--nodes N] [--buckets B]
//! ```

use std::sync::Arc;
use std::time::Duration;

use dhash::baselines::{ConcurrentMap, HtRht, HtSplit, HtXu};
use dhash::coordinator::{Coordinator, CoordinatorConfig, ElasticConfig, PreRoute, Request};
use dhash::dhash::{DHashMap, HashFn};
use dhash::rcu::RcuThread;
use dhash::torture::{self, OpMix, RebuildMode, TortureConfig};
use dhash::util::cli::Args;
use dhash::util::Summary;

fn make_table(name: &str, nbuckets: usize, seed: u64) -> Arc<dyn ConcurrentMap> {
    match name {
        "dhash" => Arc::new(DHashMap::with_buckets(nbuckets, seed)),
        "xu" => Arc::new(HtXu::new(nbuckets, HashFn::Seeded(seed))),
        "rht" => Arc::new(HtRht::new(nbuckets, HashFn::Seeded(seed))),
        "split" => Arc::new(HtSplit::new(nbuckets, 1 << 20)),
        other => {
            eprintln!("unknown table {other:?} (want dhash|xu|rht|split)");
            std::process::exit(2);
        }
    }
}

fn cmd_torture(args: &Args) -> anyhow::Result<()> {
    let table = args.get("table").unwrap_or("dhash").to_string();
    let buckets = args.get_or("buckets", 1024usize)?;
    let cfg = TortureConfig {
        threads: args.get_or("threads", 4usize)?,
        mix: OpMix::lookup_pct(args.get_or("lookup-pct", 90u8)?),
        alpha: args.get_or("alpha", 20usize)?,
        nbuckets: buckets,
        key_range: args.get_or("keys", 1_000_000u64)?,
        duration: Duration::from_secs_f64(args.get_or("secs", 1.0f64)?),
        rebuild: if args.get_bool("no-rebuild") {
            RebuildMode::None
        } else {
            RebuildMode::Continuous {
                alt_nbuckets: match args.get_or("alt-buckets", 0usize)? {
                    0 => buckets * 2,
                    x => x,
                },
            }
        },
        pin: !args.get_bool("no-pin"),
        seed: args.get_or("seed", 0xd1e5_5eedu64)?,
        hash_seed: args.get_or("hash-seed", 0x5eedu64)?,
    };
    let repeats = args.get_or("repeats", 3usize)?;
    let map = make_table(&table, cfg.nbuckets, cfg.hash_seed);
    eprintln!(
        "torture: table={} threads={} mix={}%L alpha={} buckets={} U={} {:?}",
        map.name(),
        cfg.threads,
        cfg.mix.lookup,
        cfg.alpha,
        cfg.nbuckets,
        cfg.key_range,
        cfg.rebuild
    );
    let samples = torture::measure_mops(map, &cfg, repeats);
    let s = Summary::of(&samples);
    println!(
        "{} threads={} mops_mean={:.3} mops_stddev={:.3} samples={:?}",
        table, cfg.threads, s.mean, s.stddev, samples
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let secs = args.get_or("secs", 10u64)?;
    let attack_at = args.get_or("attack-at", secs / 2)?;
    let nbuckets = args.get_or("buckets", 4096usize)?;
    let pre_route = match args.get("pre-route").unwrap_or("off") {
        "off" => PreRoute::Off,
        "shard" => PreRoute::Shard,
        "bucket" => PreRoute::Bucket,
        other => anyhow::bail!("unknown --pre-route {other:?} (want off|shard|bucket)"),
    };
    let max_shards = args.get_or("max-shards", 0usize)?;
    let mut cfg = CoordinatorConfig {
        nbuckets,
        hash: if args.get_bool("weak-hash") {
            HashFn::Modulo
        } else {
            HashFn::Seeded(0xd1e5)
        },
        shards: args.get_or("shards", 1usize)?,
        lanes: args.get_or("lanes", 1usize)?,
        workers: args.get_or("workers", 2usize)?,
        elastic: (max_shards > 0).then(|| ElasticConfig {
            max_shards,
            ..Default::default()
        }),
        enable_analytics: !args.get_bool("no-analytics"),
        ..Default::default()
    };
    cfg.batcher.pre_route = pre_route;
    eprintln!("serve: {cfg:?} for {secs}s, attack at {attack_at}s");
    let c = Arc::new(Coordinator::start(cfg)?);

    // Client load: normal traffic, then an attack burst.
    let c2 = c.clone();
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let s2 = stop.clone();
    let client = std::thread::spawn(move || {
        let kv = c2.client();
        let mut rng = dhash::util::SplitMix64::new(1);
        let mut attack = dhash::torture::AttackGen::new(nbuckets, 7);
        let t0 = std::time::Instant::now();
        while !s2.load(std::sync::atomic::Ordering::Relaxed) {
            let attacking = t0.elapsed().as_secs() >= attack_at;
            let reqs: Vec<Request> = (0..64)
                .map(|_| {
                    if attacking && rng.next_f64() < 0.8 {
                        Request::put(attack.next().unwrap(), 0)
                    } else {
                        let k = rng.next_bounded(1_000_000);
                        if rng.next_f64() < 0.9 {
                            Request::get(k)
                        } else {
                            Request::put(k, k)
                        }
                    }
                })
                .collect();
            // Completion-based ingest: submit, then resolve the ticket.
            match kv.submit_batch(&reqs) {
                Ok(ticket) => {
                    let _ = ticket.wait();
                }
                Err(_) => break, // shut down
            }
        }
    });

    for sec in 0..secs {
        std::thread::sleep(Duration::from_secs(1));
        let st = c.stats();
        println!(
            "t={:>3}s requests={:>9} batches={:>7} routed={:>7} fb_len={} fb_eng={} fb_ep={} \
             shards={} epoch={} splits={} merges={} chi2={:>10.1} rebuilds={}",
            sec + 1,
            st.total_requests,
            st.total_batches,
            st.pre_routed_batches,
            st.pre_route_fallbacks_length,
            st.pre_route_fallbacks_engine,
            st.pre_route_fallbacks_epoch,
            st.shards,
            st.epoch,
            st.splits,
            st.merges,
            st.last_chi2,
            st.rebuilds
        );
    }
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    client.join().unwrap();
    for ev in c.rebuild_events() {
        println!(
            "mitigation at {:?}: shard {} (epoch {}) chi2={:.1} -> {:?} ({} nodes in {:?})",
            ev.at, ev.shard, ev.epoch, ev.chi2, ev.new_hash, ev.moved, ev.elapsed
        );
    }
    for ev in c.resize_events() {
        println!(
            "resize at {:?}: {:?} (epoch {} -> {} shards, {} nodes in {:?})",
            ev.at, ev.action, ev.epoch, ev.shards_after, ev.moved, ev.elapsed
        );
    }
    c.shutdown();
    Ok(())
}

fn cmd_rebuild(args: &Args) -> anyhow::Result<()> {
    let table = args.get("table").unwrap_or("dhash").to_string();
    let nodes = args.get_or("nodes", 100_000u64)?;
    let nbuckets = args.get_or("buckets", 1024usize)?;
    let map = make_table(&table, nbuckets, 1);
    let g = RcuThread::register();
    for k in 0..nodes {
        map.insert(&g, k, k);
    }
    let t0 = std::time::Instant::now();
    let ok = map.rebuild(&g, nbuckets * 2, HashFn::Seeded(2));
    let dt = t0.elapsed();
    g.quiescent_state();
    println!(
        "{}: rebuild of {} nodes -> {} buckets: ok={} in {:?} ({:.0} nodes/ms)",
        map.name(),
        nodes,
        nbuckets * 2,
        ok,
        dt,
        nodes as f64 / dt.as_secs_f64() / 1e3
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    const KNOWN: &[&str] = &[
        "table", "threads", "lookup-pct", "alpha", "buckets", "alt-buckets", "keys", "secs",
        "no-rebuild", "no-pin", "repeats", "seed", "hash-seed", "workers", "shards", "max-shards",
        "lanes", "pre-route", "attack-at", "weak-hash", "no-analytics", "nodes",
    ];
    let args = Args::from_env(KNOWN)?;
    match args.positional().first().map(|s| s.as_str()) {
        Some("torture") => cmd_torture(&args),
        Some("serve") => cmd_serve(&args),
        Some("rebuild") => cmd_rebuild(&args),
        _ => {
            eprintln!("usage: dhash <torture|serve|rebuild> [flags] (see source docs)");
            std::process::exit(2);
        }
    }
}
