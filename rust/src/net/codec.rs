//! The incremental frame decoder: an accumulation buffer a connection
//! pushes raw read bytes into, and `next_*` methods that peel complete
//! frames off the front, resuming cleanly at any split point.
//!
//! Zero-copy in the sense that matters here: frames are decoded
//! *in place* from the accumulation buffer — no per-frame allocation,
//! no re-buffering of partial frames. Consumed bytes are reclaimed by
//! shifting the tail only when the dead prefix outgrows the live
//! remainder (amortized O(1) per byte).

use crate::error::ProtoError;
use crate::net::proto::{RequestFrame, ResponseFrame};

/// Accumulates stream bytes and yields complete frames. One per
/// connection direction; both the server (requests in) and the
/// `netbench` client (responses in) run the same decoder, so there is
/// exactly one framing implementation to get right.
#[derive(Default)]
pub struct Decoder {
    buf: Vec<u8>,
    /// Start of undecoded bytes in `buf` (everything before is dead).
    pos: usize,
}

impl Decoder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Append freshly-read stream bytes.
    pub fn push(&mut self, bytes: &[u8]) {
        self.compact();
        self.buf.extend_from_slice(bytes);
    }

    /// Bytes currently buffered but not yet decoded.
    pub fn pending(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Next complete request frame, if the buffer holds one.
    /// `Ok(None)` = need more bytes; `Err` = framing lost (the
    /// connection cannot be resynchronized).
    pub fn next_request(&mut self) -> Result<Option<RequestFrame>, ProtoError> {
        match RequestFrame::decode(&self.buf[self.pos..])? {
            None => Ok(None),
            Some((frame, used)) => {
                self.pos += used;
                Ok(Some(frame))
            }
        }
    }

    /// Next complete response frame; same contract as
    /// [`next_request`](Decoder::next_request).
    pub fn next_response(&mut self) -> Result<Option<ResponseFrame>, ProtoError> {
        match ResponseFrame::decode(&self.buf[self.pos..])? {
            None => Ok(None),
            Some((frame, used)) => {
                self.pos += used;
                Ok(Some(frame))
            }
        }
    }

    /// Reclaim consumed bytes once the dead prefix dominates: shifting
    /// the live tail to the front is O(live), and doing it only when
    /// `pos > live` keeps the total shifted bytes linear in the stream.
    fn compact(&mut self) {
        if self.pos > self.buf.len() - self.pos {
            self.buf.drain(..self.pos);
            self.pos = 0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::proto::{Request, Response};

    #[test]
    fn decodes_frames_across_any_split() {
        let frames = [
            RequestFrame::new(1, Request::put(10, 100)),
            RequestFrame::new(2, Request::get(10)),
            RequestFrame::new(3, Request::del(10)),
        ];
        let mut wire = Vec::new();
        for f in &frames {
            f.encode(&mut wire);
        }
        // Feed one byte at a time — the worst split pattern.
        let mut dec = Decoder::new();
        let mut got = Vec::new();
        for &b in &wire {
            dec.push(&[b]);
            while let Some(f) = dec.next_request().unwrap() {
                got.push(f);
            }
        }
        assert_eq!(got, frames);
        assert_eq!(dec.pending(), 0);
    }

    #[test]
    fn responses_share_the_same_decoder() {
        let frames = [
            ResponseFrame::reply(7, Response::Value(9)),
            ResponseFrame::reply(8, Response::Missing),
        ];
        let mut wire = Vec::new();
        for f in &frames {
            f.encode(&mut wire);
        }
        let mut dec = Decoder::new();
        dec.push(&wire[..5]);
        assert_eq!(dec.next_response().unwrap(), None);
        dec.push(&wire[5..]);
        assert_eq!(dec.next_response().unwrap(), Some(frames[0]));
        assert_eq!(dec.next_response().unwrap(), Some(frames[1]));
        assert_eq!(dec.next_response().unwrap(), None);
    }

    #[test]
    fn framing_errors_surface_not_panic() {
        let mut dec = Decoder::new();
        dec.push(&[0xFF, 0, 0, 0]);
        assert_eq!(dec.next_request(), Err(ProtoError::BadMagic(0xFF)));
    }

    #[test]
    fn compaction_keeps_pending_bytes() {
        let mut dec = Decoder::new();
        let mut wire = Vec::new();
        for i in 0..64u64 {
            RequestFrame::new(i, Request::get(i)).encode(&mut wire);
        }
        // Interleave pushes and drains so pos repeatedly crosses the
        // compaction threshold with a partial frame pending.
        let mut got = 0u64;
        for chunk in wire.chunks(17) {
            dec.push(chunk);
            while let Some(f) = dec.next_request().unwrap() {
                assert_eq!(f.id, got, "frame order broken by compaction");
                got += 1;
            }
        }
        assert_eq!(got, 64);
    }
}
