//! One connection's state machine: decode readable bytes into a
//! request batch, submit it with **one**
//! [`KvClient::submit_batch`] call per drain, and write responses back
//! completion-driven as the ticket resolves — a worker never blocks on
//! a pending ticket.
//!
//! ## Ordering
//!
//! Responses go back **in request order per connection**, even when a
//! drain mixes accepted requests with sheds or shutdown rejections.
//! Each readable drain produces one [`Drain`] queue entry holding the
//! batch ticket plus the drain's items *in decode order*: an accepted
//! request is a `Slot` item (consumes the ticket's next response), a
//! shed/rejection is an inline `Err` item (carries its wire code). The
//! queue is FIFO and a drain is encoded only when its ticket has fully
//! resolved, so interleavings can never reorder a connection's
//! responses.
//!
//! ## Overload
//!
//! The inflight window bounds `sum(accepted, not yet responded)` per
//! connection. A request that would exceed it is **shed**: answered
//! immediately with [`KvError::Overloaded`]'s wire code, connection
//! kept open — explicit backpressure, not a dropped connection.
//!
//! [`KvClient::submit_batch`]: crate::coordinator::KvClient::submit_batch

use std::collections::VecDeque;
use std::io::{self, Read, Write};
use std::net::TcpStream;
use std::os::fd::{AsRawFd, RawFd};
use std::sync::Arc;

use crate::coordinator::{BatchTicket, KvClient, SubmitError};
use crate::error::KvError;
use crate::net::codec::Decoder;
use crate::net::proto::ResponseFrame;
use crate::net::stats::{ConnStats, NetCounters};

/// One response owed to the peer, in decode order within its drain.
enum DrainItem {
    /// An accepted request: consumes the drain ticket's next slot.
    Slot { id: u64 },
    /// An inline failure (shed, shutdown, protocol error): the wire
    /// code is already known, no slot involved.
    Err { id: u64, code: u8 },
}

/// Everything one readable drain owes the peer: at most one submitted
/// batch plus the decode-order item list that interleaves its slots
/// with inline errors.
struct Drain {
    ticket: Option<BatchTicket>,
    items: Vec<DrainItem>,
}

/// One live connection. Owned behind a `Mutex` in the server's
/// connection table; every method runs under that lock, on whichever
/// worker the one-shot readiness event (or the completion sweep)
/// landed.
pub struct Conn {
    stream: TcpStream,
    dec: Decoder,
    /// Encoded-but-unwritten response bytes (`out_pos` = write cursor).
    out: Vec<u8>,
    out_pos: usize,
    /// FIFO of drains not yet encoded; the head blocks on its ticket.
    queue: VecDeque<Drain>,
    /// Accepted requests not yet encoded as responses (window subject).
    inflight: usize,
    pub stats: ConnStats,
    counters: Arc<NetCounters>,
    /// Peer sent FIN (or the socket failed): no more reads.
    read_closed: bool,
    /// Fatal (protocol/io) state: close once `out` is flushed.
    dead: bool,
    /// Removed from the server's table; sweeps must skip it.
    pub gone: bool,
}

impl Conn {
    pub fn new(stream: TcpStream, counters: Arc<NetCounters>) -> io::Result<Self> {
        stream.set_nonblocking(true)?;
        stream.set_nodelay(true)?;
        Ok(Self {
            stream,
            dec: Decoder::new(),
            out: Vec::new(),
            out_pos: 0,
            queue: VecDeque::new(),
            inflight: 0,
            stats: ConnStats::default(),
            counters,
            read_closed: false,
            dead: false,
            gone: false,
        })
    }

    pub fn fd(&self) -> RawFd {
        self.stream.as_raw_fd()
    }

    /// Drain readable bytes, decode frames, and submit the accepted
    /// requests as ONE batch. `stopping` = the server is draining: new
    /// requests are answered with the shutdown code instead of
    /// submitted.
    pub fn on_readable(&mut self, client: &KvClient, window: usize, stopping: bool) {
        let mut buf = [0u8; 16 * 1024];
        while !self.read_closed {
            match self.stream.read(&mut buf) {
                Ok(0) => self.read_closed = true,
                Ok(n) => {
                    self.stats.bytes_in += n as u64;
                    NetCounters::add(&self.counters.bytes_in, n as u64);
                    self.dec.push(&buf[..n]);
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.read_closed = true;
                    self.dead = true;
                }
            }
        }
        self.decode_and_submit(client, window, stopping);
    }

    fn decode_and_submit(&mut self, client: &KvClient, window: usize, stopping: bool) {
        let mut reqs = Vec::new();
        let mut items = Vec::new();
        while !self.dead {
            match self.dec.next_request() {
                Ok(None) => break,
                Ok(Some(frame)) => {
                    self.stats.frames_in += 1;
                    NetCounters::add(&self.counters.frames_in, 1);
                    if stopping {
                        items.push(DrainItem::Err {
                            id: frame.id,
                            code: KvError::Shutdown.code(),
                        });
                    } else if self.inflight + reqs.len() >= window {
                        // Shed-on-full: an explicit wire error, not a
                        // dropped connection.
                        self.stats.sheds += 1;
                        NetCounters::add(&self.counters.sheds, 1);
                        items.push(DrainItem::Err {
                            id: frame.id,
                            code: KvError::Overloaded.code(),
                        });
                    } else {
                        reqs.push(frame.req);
                        items.push(DrainItem::Slot { id: frame.id });
                    }
                }
                Err(e) => {
                    // Framing lost: answer with the error's wire code
                    // (id 0 — no trustworthy request id exists), then
                    // close after flushing.
                    self.stats.protocol_errors += 1;
                    NetCounters::add(&self.counters.protocol_errors, 1);
                    items.push(DrainItem::Err {
                        id: 0,
                        code: KvError::from(e).code(),
                    });
                    self.read_closed = true;
                    self.dead = true;
                }
            }
        }
        if items.is_empty() {
            return;
        }
        let ticket = if reqs.is_empty() {
            None
        } else {
            match client.submit_batch(&reqs) {
                Ok(t) => {
                    self.stats.batches += 1;
                    NetCounters::add(&self.counters.batches, 1);
                    self.inflight += reqs.len();
                    Some(t)
                }
                Err(SubmitError::Shutdown) => {
                    // The whole drain becomes inline shutdown errors;
                    // response order is unchanged.
                    for it in &mut items {
                        if let DrainItem::Slot { id } = *it {
                            *it = DrainItem::Err {
                                id,
                                code: KvError::Shutdown.code(),
                            };
                        }
                    }
                    None
                }
            }
        };
        self.queue.push_back(Drain { ticket, items });
    }

    /// Encode every queued drain whose ticket has resolved (FIFO; the
    /// first unresolved ticket stops the scan — order is the contract).
    /// Never blocks: an unresolved ticket is left for the next sweep.
    pub fn pump(&mut self) {
        while let Some(front) = self.queue.front() {
            let slots = match &front.ticket {
                None => Vec::new(),
                Some(t) => match t.poll_each() {
                    None => break, // still executing; completion-driven
                    Some(slots) => slots,
                },
            };
            let drain = self.queue.pop_front().expect("front exists");
            let mut next_slot = 0;
            for item in &drain.items {
                let frame = match *item {
                    DrainItem::Err { id, code } => ResponseFrame {
                        id,
                        body: Err(code),
                    },
                    DrainItem::Slot { id } => {
                        let r = slots[next_slot];
                        next_slot += 1;
                        self.inflight -= 1;
                        match r {
                            Ok(resp) => ResponseFrame::reply(id, resp),
                            Err(e) => ResponseFrame::error(id, e.into()),
                        }
                    }
                };
                frame.encode(&mut self.out);
                self.stats.frames_out += 1;
                NetCounters::add(&self.counters.frames_out, 1);
            }
        }
    }

    /// Write buffered response bytes until the socket would block.
    pub fn flush(&mut self) {
        while self.out_pos < self.out.len() {
            match self.stream.write(&self.out[self.out_pos..]) {
                Ok(0) => break,
                Ok(n) => {
                    self.out_pos += n;
                    self.stats.bytes_out += n as u64;
                    NetCounters::add(&self.counters.bytes_out, n as u64);
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.dead = true;
                    self.read_closed = true;
                    self.out_pos = self.out.len(); // nothing more to say
                    break;
                }
            }
        }
        if self.out_pos == self.out.len() {
            self.out.clear();
            self.out_pos = 0;
        }
    }

    /// Unwritten response bytes are waiting on the socket.
    pub fn wants_write(&self) -> bool {
        self.out_pos < self.out.len()
    }

    /// Still interested in reading from the peer.
    pub fn wants_read(&self) -> bool {
        !self.read_closed
    }

    /// Work is pending that only a completion sweep (not a readiness
    /// event) will advance: a queued drain, or unflushed output.
    pub fn has_pending(&self) -> bool {
        !self.queue.is_empty() || self.wants_write()
    }

    /// Nothing left to do: every response owed has been written, and no
    /// more requests can arrive (`stopping` ends the connection once
    /// drained — graceful FIN — as does peer close or a fatal error).
    pub fn finished(&self, stopping: bool) -> bool {
        self.queue.is_empty() && !self.wants_write() && (self.read_closed || self.dead || stopping)
    }

    /// Drain-deadline expiry: abandon pending work so the connection
    /// closes now (tickets drop; their slots are already failed or will
    /// be, and nothing further is written).
    pub fn force_close(&mut self) {
        self.queue.clear();
        self.out.clear();
        self.out_pos = 0;
        self.read_closed = true;
        self.dead = true;
    }
}
