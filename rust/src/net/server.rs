//! [`NetServer`]: the single-epoll-multiple-workers serving loop.
//!
//! One [`Listener`] (epoll fd + accept socket) is shared by N worker
//! threads. Every registration is one-shot, so each readiness event is
//! handled by exactly one worker; the same workers also run completion
//! sweeps that advance connections whose batch tickets resolved (the
//! completion-driven write path — nothing ever blocks on a pending
//! ticket). Shutdown is a graceful drain: stop accepting, answer new
//! frames with the shutdown code, let pending tickets resolve and
//! flush, then FIN — with a deadline after which stragglers are
//! force-closed.

use std::collections::HashMap;
use std::io;
use std::net::SocketAddr;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::{CoordinatorStats, KvClient};
use crate::net::conn::Conn;
use crate::net::listener::{EpollListener, Listener, LISTENER_ID};
use crate::net::stats::{ConnStats, NetCounters, NetStats};

#[derive(Clone, Debug)]
pub struct NetConfig {
    /// Bind address, e.g. `127.0.0.1:7171` (port 0 = ephemeral).
    pub addr: String,
    /// Worker threads sharing the one epoll fd.
    pub workers: usize,
    /// Per-connection inflight window: accepted-but-unanswered requests
    /// beyond this are shed with the overload wire code.
    pub inflight_window: usize,
    /// Graceful-drain deadline on shutdown; stragglers past it are
    /// force-closed.
    pub drain_timeout: Duration,
}

impl Default for NetConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:0".into(),
            workers: 2,
            inflight_window: 256,
            drain_timeout: Duration::from_secs(5),
        }
    }
}

type ConnArc = Arc<Mutex<Conn>>;

/// State shared by the worker threads.
struct Service {
    listener: Box<dyn Listener>,
    client: KvClient,
    window: usize,
    counters: Arc<NetCounters>,
    conns: Mutex<HashMap<u64, ConnArc>>,
    next_id: AtomicU64,
    stop: AtomicBool,
    /// Sweep hint: some connection has a pending drain or unflushed
    /// output, so workers poll with the short timeout. Heuristic only —
    /// a stale value costs latency, never correctness.
    has_pending: AtomicBool,
    drain_timeout: Duration,
}

impl Service {
    fn accept_all(&self) {
        loop {
            match self.listener.accept() {
                Ok(Some(stream)) => self.add_conn(stream),
                Ok(None) | Err(_) => break,
            }
        }
    }

    fn add_conn(&self, stream: std::net::TcpStream) {
        if self.stop.load(Ordering::Relaxed) {
            return; // draining: refuse new connections (stream drops → FIN)
        }
        let Ok(conn) = Conn::new(stream, self.counters.clone()) else {
            return;
        };
        // Connection ids start at 1 (LISTENER_ID = 0 is the accept socket).
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let fd = conn.fd();
        self.conns.lock().unwrap().insert(id, Arc::new(Mutex::new(conn)));
        NetCounters::add(&self.counters.accepted, 1);
        NetCounters::add(&self.counters.active, 1);
        if self.listener.register(fd, id, true, false).is_err() {
            self.remove(id);
        }
    }

    /// Close and forget connection `id`. Lock order rule: the conns map
    /// lock and a conn's own lock are never held together.
    fn remove(&self, id: u64) {
        let arc = { self.conns.lock().unwrap().remove(&id) };
        if let Some(arc) = arc {
            let mut c = arc.lock().unwrap();
            c.gone = true;
            let _ = self.listener.deregister(c.fd());
            NetCounters::add(&self.counters.closed, 1);
            self.counters.active.fetch_sub(1, Ordering::Relaxed);
            // The TcpStream closes (FIN) when the last Arc drops.
        }
    }

    /// Advance one locked connection; returns true when it is finished.
    fn advance(&self, c: &mut Conn, readable: bool, stopping: bool) -> bool {
        if readable {
            c.on_readable(&self.client, self.window, stopping);
        }
        c.pump();
        c.flush();
        c.finished(stopping)
    }

    /// Handle a readiness event for connection `id`.
    fn on_event(&self, id: u64, readable: bool, stopping: bool) {
        let arc = {
            let conns = self.conns.lock().unwrap();
            conns.get(&id).cloned()
        };
        let Some(arc) = arc else { return };
        let (finished, fd, r, w, pending) = {
            let mut c = arc.lock().unwrap();
            if c.gone {
                return;
            }
            let finished = self.advance(&mut c, readable, stopping);
            (finished, c.fd(), c.wants_read(), c.wants_write(), c.has_pending())
        };
        if finished {
            self.remove(id);
        } else {
            let _ = self.listener.rearm(fd, id, r, w);
            if pending {
                self.has_pending.store(true, Ordering::Relaxed);
            }
        }
    }

    /// Completion sweep: visit every connection, encode responses whose
    /// tickets resolved, flush, close the finished. `try_lock` — a conn
    /// being serviced by another worker is simply skipped (that worker
    /// pumps it itself).
    fn sweep(&self, stopping: bool) {
        let snapshot: Vec<(u64, ConnArc)> = {
            let conns = self.conns.lock().unwrap();
            conns.iter().map(|(id, a)| (*id, a.clone())).collect()
        };
        let mut pending = false;
        for (id, arc) in snapshot {
            let verdict = match arc.try_lock() {
                Err(_) => {
                    pending = true; // busy elsewhere: check again soon
                    continue;
                }
                Ok(mut c) => {
                    if c.gone {
                        continue;
                    }
                    let finished = self.advance(&mut c, false, stopping);
                    (finished, c.fd(), c.wants_read(), c.wants_write(), c.has_pending())
                }
            };
            let (finished, fd, r, w, pend) = verdict;
            if finished {
                self.remove(id);
            } else {
                pending |= pend;
                if w {
                    // Flush hit WouldBlock: arm for writability so the
                    // event path resumes the write.
                    let _ = self.listener.rearm(fd, id, r, true);
                }
            }
        }
        self.has_pending.store(pending, Ordering::Relaxed);
    }

    /// Drain-deadline expiry: abandon whatever is still pending.
    fn force_close_all(&self) {
        let ids: Vec<u64> = { self.conns.lock().unwrap().keys().copied().collect() };
        for id in ids {
            let arc = { self.conns.lock().unwrap().get(&id).cloned() };
            if let Some(arc) = arc {
                arc.lock().unwrap().force_close();
            }
            self.remove(id);
        }
    }

    fn worker_loop(&self) {
        let mut events = Vec::new();
        let mut deadline: Option<Instant> = None;
        loop {
            let stopping = self.stop.load(Ordering::Relaxed);
            if stopping && deadline.is_none() {
                deadline = Some(Instant::now() + self.drain_timeout);
            }
            let timeout = if self.has_pending.load(Ordering::Relaxed) || stopping {
                Duration::from_millis(1)
            } else {
                Duration::from_millis(25)
            };
            events.clear();
            if self.listener.wait(&mut events, timeout).is_err() {
                return; // readiness backend failed: nothing we can drive
            }
            for ev in &events {
                if ev.id == LISTENER_ID {
                    self.accept_all();
                } else {
                    self.on_event(ev.id, ev.readable, stopping);
                }
            }
            self.sweep(stopping);
            if stopping {
                if deadline.is_some_and(|d| Instant::now() >= d) {
                    self.force_close_all();
                }
                if self.conns.lock().unwrap().is_empty() {
                    return;
                }
            }
        }
    }
}

/// The running network front end. Start it over a [`KvClient`] (the
/// coordinator stays owned by the caller), read stats any time, and
/// [`shutdown`](NetServer::shutdown) for a graceful drain.
pub struct NetServer {
    svc: Arc<Service>,
    workers: Vec<JoinHandle<()>>,
}

impl NetServer {
    /// Bind `cfg.addr` with the epoll backend and start serving.
    pub fn start(cfg: &NetConfig, client: KvClient) -> io::Result<Self> {
        let listener = EpollListener::bind(&cfg.addr)?;
        Self::start_with(Box::new(listener), cfg, client)
    }

    /// Start over an explicit [`Listener`] backend (the io_uring seam,
    /// also used by tests).
    pub fn start_with(
        listener: Box<dyn Listener>,
        cfg: &NetConfig,
        client: KvClient,
    ) -> io::Result<Self> {
        let svc = Arc::new(Service {
            listener,
            client,
            window: cfg.inflight_window.max(1),
            counters: Arc::new(NetCounters::default()),
            conns: Mutex::new(HashMap::new()),
            next_id: AtomicU64::new(LISTENER_ID + 1),
            stop: AtomicBool::new(false),
            has_pending: AtomicBool::new(false),
            drain_timeout: cfg.drain_timeout,
        });
        let mut workers = Vec::new();
        for w in 0..cfg.workers.max(1) {
            let svc2 = svc.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("dhash-net-{w}"))
                    .spawn(move || svc2.worker_loop())?,
            );
        }
        Ok(Self { svc, workers })
    }

    /// The bound address (useful with port 0).
    pub fn local_addr(&self) -> io::Result<SocketAddr> {
        self.svc.listener.local_addr()
    }

    /// Aggregate network counters.
    pub fn net_stats(&self) -> NetStats {
        self.svc.counters.snapshot()
    }

    /// Per-connection stats of the currently open connections.
    pub fn conn_stats(&self) -> Vec<ConnStats> {
        let snapshot: Vec<ConnArc> = {
            let conns = self.svc.conns.lock().unwrap();
            conns.values().cloned().collect()
        };
        snapshot.iter().map(|a| a.lock().unwrap().stats).collect()
    }

    /// Fold the aggregate network counters into a coordinator stats
    /// snapshot (`stats.net`), keeping serving-path and routing-path
    /// degradation in one report.
    pub fn fold_stats(&self, stats: &mut CoordinatorStats) {
        stats.net = Some(self.net_stats());
    }

    /// Graceful drain: stop accepting, answer new frames with the
    /// shutdown code, let pending tickets resolve and responses flush
    /// (bounded by the drain deadline), then close every connection.
    pub fn shutdown(mut self) -> NetStats {
        self.svc.stop.store(true, Ordering::SeqCst);
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        self.svc.counters.snapshot()
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.svc.stop.store(true, Ordering::SeqCst);
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}
