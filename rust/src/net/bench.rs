//! The `netbench` client: depth-K pipelining over one TCP connection,
//! with a self-validating mode that checks every response against a
//! client-side model of its own keys.
//!
//! Validation relies on two contracts:
//!
//! * **Per-connection response order** — the server answers a
//!   connection's requests in request order, sheds included, so the
//!   next response always belongs to the oldest outstanding id.
//! * **Phased pipelining** — within a phase each key is touched once
//!   (distinct keys pipeline freely); the pipeline drains between
//!   phases, so cross-phase per-key ordering holds even though the
//!   coordinator's workers may interleave consecutive *batches*.
//!
//! A shed ([`KvError::Overloaded`]'s code) is never a mismatch: the
//! model simply does not apply the operation, and later phases expect
//! the un-applied state.

use std::collections::{HashMap, VecDeque};
use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::{Duration, Instant};

use crate::error::KvError;
use crate::net::codec::Decoder;
use crate::net::proto::{Request, RequestFrame, Response, ResponseFrame};
use crate::util::SplitMix64;

/// One operation as the client sent it (what to validate the response
/// against).
#[derive(Clone, Copy, Debug)]
pub enum Sent {
    Put { key: u64, val: u64 },
    Get { key: u64 },
    Del { key: u64 },
}

impl Sent {
    fn request(&self) -> Request {
        match *self {
            Sent::Put { key, val } => Request::put(key, val),
            Sent::Get { key } => Request::get(key),
            Sent::Del { key } => Request::del(key),
        }
    }
}

/// What one client connection observed.
#[derive(Clone, Copy, Debug, Default)]
pub struct BenchReport {
    pub sent: u64,
    pub received: u64,
    /// Successful (non-error) responses.
    pub ok: u64,
    /// Requests shed by the server's inflight window (the overload wire
    /// code) — explicit backpressure, counted apart from errors.
    pub sheds: u64,
    /// Error responses other than sheds (e.g. shutdown during drain).
    pub errors: u64,
    /// Responses that contradicted the client-side model (validating
    /// mode only). Must be zero in a correct run.
    pub mismatches: u64,
    /// Responses out of request order. Must be zero: per-connection
    /// order is the server's contract.
    pub reorders: u64,
}

impl BenchReport {
    pub fn merge(&mut self, o: &BenchReport) {
        self.sent += o.sent;
        self.received += o.received;
        self.ok += o.ok;
        self.sheds += o.sheds;
        self.errors += o.errors;
        self.mismatches += o.mismatches;
        self.reorders += o.reorders;
    }
}

/// A pipelined client over one connection.
pub struct NetClient {
    stream: TcpStream,
    dec: Decoder,
    next_id: u64,
    outstanding: VecDeque<(u64, Sent)>,
    /// Client-side model of this connection's keys (validating mode).
    model: HashMap<u64, u64>,
    /// Validate responses against the model. Off for throughput runs,
    /// whose random keys repeat *within* the pipeline window (batch
    /// interleaving then makes per-key order unknowable by design).
    validate: bool,
    pub report: BenchReport,
}

impl NetClient {
    pub fn connect(addr: SocketAddr) -> io::Result<Self> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(Self {
            stream,
            dec: Decoder::new(),
            next_id: 1,
            outstanding: VecDeque::new(),
            model: HashMap::new(),
            validate: true,
            report: BenchReport::default(),
        })
    }

    pub fn set_validate(&mut self, on: bool) {
        self.validate = on;
    }

    pub fn outstanding(&self) -> usize {
        self.outstanding.len()
    }

    /// Send one request (pipelined; does not wait for the response).
    pub fn send(&mut self, op: Sent) -> io::Result<()> {
        let id = self.next_id;
        self.next_id += 1;
        let mut wire = Vec::with_capacity(32);
        RequestFrame::new(id, op.request()).encode(&mut wire);
        self.stream.write_all(&wire)?;
        self.outstanding.push_back((id, op));
        self.report.sent += 1;
        Ok(())
    }

    /// Block until one response arrives and account for it.
    pub fn recv_one(&mut self) -> io::Result<()> {
        loop {
            match self.dec.next_response() {
                Err(e) => return Err(io::Error::new(io::ErrorKind::InvalidData, e)),
                Ok(Some(frame)) => {
                    self.account(frame);
                    return Ok(());
                }
                Ok(None) => {
                    let mut buf = [0u8; 4096];
                    let n = self.stream.read(&mut buf)?;
                    if n == 0 {
                        return Err(io::ErrorKind::UnexpectedEof.into());
                    }
                    self.dec.push(&buf[..n]);
                }
            }
        }
    }

    /// Wait out every outstanding response (a phase barrier).
    pub fn drain(&mut self) -> io::Result<()> {
        while !self.outstanding.is_empty() {
            self.recv_one()?;
        }
        Ok(())
    }

    /// Run one phase: pipeline `ops` at the given depth, then drain.
    pub fn run_phase(
        &mut self,
        ops: impl IntoIterator<Item = Sent>,
        depth: usize,
    ) -> io::Result<()> {
        for op in ops {
            if self.outstanding.len() >= depth.max(1) {
                self.recv_one()?;
            }
            self.send(op)?;
        }
        self.drain()
    }

    fn account(&mut self, frame: ResponseFrame) {
        self.report.received += 1;
        let Some((id, op)) = self.outstanding.pop_front() else {
            self.report.reorders += 1; // response nobody asked for
            return;
        };
        if frame.id != id {
            // Order is the server's per-connection contract; a wrong id
            // means it broke. Count it and stop validating this frame.
            self.report.reorders += 1;
            return;
        }
        match frame.body {
            Err(code) if code == KvError::Overloaded.code() => {
                // Shed: the operation was not applied; the model stays.
                self.report.sheds += 1;
            }
            Err(_) => self.report.errors += 1,
            Ok(resp) => {
                self.report.ok += 1;
                if self.validate && !self.model_check(op, resp) {
                    self.report.mismatches += 1;
                }
            }
        }
    }

    /// Validate `resp` against the model and apply the op's effect.
    fn model_check(&mut self, op: Sent, resp: Response) -> bool {
        match op {
            Sent::Put { key, val } => {
                self.model.insert(key, val);
                matches!(resp, Response::Ok)
            }
            Sent::Get { key } => match self.model.get(&key) {
                Some(&v) => resp == Response::Value(v),
                None => resp == Response::Missing,
            },
            Sent::Del { key } => {
                let was = self.model.remove(&key).is_some();
                if was {
                    resp == Response::Ok
                } else {
                    resp == Response::Missing
                }
            }
        }
    }
}

/// The self-validating workload: four phases over `n` keys unique to
/// this client (`put` → `get` → `del` → `get`-missing), pipelined at
/// `depth` with a drain barrier between phases. Any lost, reordered, or
/// wrong response shows up in the report.
pub fn verify_run(
    addr: SocketAddr,
    key_base: u64,
    n: u64,
    depth: usize,
) -> io::Result<BenchReport> {
    let mut c = NetClient::connect(addr)?;
    let val = |k: u64| k.wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1;
    c.run_phase(
        (0..n).map(|i| Sent::Put {
            key: key_base + i,
            val: val(i),
        }),
        depth,
    )?;
    c.run_phase((0..n).map(|i| Sent::Get { key: key_base + i }), depth)?;
    c.run_phase((0..n).map(|i| Sent::Del { key: key_base + i }), depth)?;
    c.run_phase((0..n).map(|i| Sent::Get { key: key_base + i }), depth)?;
    Ok(c.report)
}

/// The throughput workload: mixed random ops over `key_space` keys at
/// pipeline `depth` until `dur` elapses. Validation is off (random keys
/// repeat within the window); sheds/errors still count.
pub fn throughput_run(
    addr: SocketAddr,
    dur: Duration,
    depth: usize,
    key_space: u64,
    seed: u64,
) -> io::Result<BenchReport> {
    let mut c = NetClient::connect(addr)?;
    c.set_validate(false);
    let mut rng = SplitMix64::new(seed);
    let deadline = Instant::now() + dur;
    while Instant::now() < deadline {
        while c.outstanding() < depth.max(1) {
            let key = rng.next_bounded(key_space.max(1));
            let op = match rng.next_bounded(10) {
                0..=4 => Sent::Get { key },
                5..=8 => Sent::Put {
                    key,
                    val: rng.next_u64(),
                },
                _ => Sent::Del { key },
            };
            c.send(op)?;
        }
        c.recv_one()?;
    }
    c.drain()?;
    Ok(c.report)
}
