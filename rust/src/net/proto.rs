//! The wire protocol: the KV request/response model types and their
//! exact binary frame encoding.
//!
//! This module is the **single source of truth** for the wire format:
//! the server codec ([`super::codec`]), the `netbench` client
//! ([`super::bench`]), and the in-process API all share the same
//! [`Request`]/[`Response`] types and the same `encode`/`decode`
//! methods, so the two sides can never drift apart. (The types used to
//! live in `coordinator::batcher`; `coordinator` re-exports them, so
//! in-process users are unaffected by the move.)
//!
//! ## Frame layout
//!
//! All integers are little-endian. A request frame is a fixed 24-byte
//! header followed by an optional value:
//!
//! ```text
//!  offset  size  field
//!       0     1  magic      0xD4 (requests) / 0xD5 (responses)
//!       1     1  version    0x01
//!       2     1  op code    Get=1 Put=2 Del=3        (requests)
//!       3     1  reserved   must be 0 on the wire
//!       4     8  request id echoed verbatim in the response
//!      12     8  key
//!      20     4  value len  8 for Put, 0 otherwise
//!      24     n  value      little-endian u64 (Put only)
//! ```
//!
//! A response frame is a fixed 16-byte header followed by an optional
//! value:
//!
//! ```text
//!  offset  size  field
//!       0     1  magic      0xD5
//!       1     1  version    0x01
//!       2     1  status     Ok=1 Value=2 Missing=3 Error=4
//!       3     1  error code [`crate::error::KvError::code`]; 0 unless
//!                           status == Error
//!       4     8  request id echoed from the request
//!      12     4  value len  8 for Value, 0 otherwise
//!      16     n  value      little-endian u64 (Value only)
//! ```
//!
//! Decoding is strict: a wrong magic, version, op, status, reserved
//! byte, or a value length inconsistent with the op/status is a
//! [`ProtoError`], never a guess — once framing is in doubt the
//! connection cannot be resynchronized, so the server answers with an
//! error frame and closes. Value lengths are validated against
//! [`MAX_VALUE_LEN`] straight from the header, **before** any buffering
//! decision, so a hostile 4 GiB length field is rejected instead of
//! capping memory.

use crate::error::ProtoError;

/// A KV operation.
#[non_exhaustive]
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Request {
    Get { key: u64 },
    Put { key: u64, val: u64 },
    Del { key: u64 },
}

impl Request {
    pub fn get(key: u64) -> Self {
        Request::Get { key }
    }

    pub fn put(key: u64, val: u64) -> Self {
        Request::Put { key, val }
    }

    pub fn del(key: u64) -> Self {
        Request::Del { key }
    }

    pub fn key(&self) -> u64 {
        match *self {
            Request::Get { key } | Request::Put { key, .. } | Request::Del { key } => key,
        }
    }

    /// The stable wire op code of this request.
    pub fn op(&self) -> OpCode {
        match self {
            Request::Get { .. } => OpCode::Get,
            Request::Put { .. } => OpCode::Put,
            Request::Del { .. } => OpCode::Del,
        }
    }
}

/// Reply to a [`Request`].
#[non_exhaustive]
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Response {
    /// Put/Del succeeded.
    Ok,
    /// Get hit.
    Value(u64),
    /// Get/Del miss.
    Missing,
}

/// Stable wire op codes. The discriminants are the protocol — they can
/// be extended but never renumbered.
#[non_exhaustive]
#[repr(u8)]
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OpCode {
    Get = 1,
    Put = 2,
    Del = 3,
}

impl OpCode {
    /// Decode a wire op byte.
    pub fn from_wire(b: u8) -> Result<OpCode, ProtoError> {
        match b {
            1 => Ok(OpCode::Get),
            2 => Ok(OpCode::Put),
            3 => Ok(OpCode::Del),
            other => Err(ProtoError::BadOpCode(other)),
        }
    }
}

/// Request-frame magic byte.
pub const MAGIC_REQ: u8 = 0xD4;
/// Response-frame magic byte.
pub const MAGIC_RESP: u8 = 0xD5;
/// Protocol version; bumped on any incompatible layout change.
pub const VERSION: u8 = 0x01;
/// Fixed request-header length (bytes before the value).
pub const REQ_HEADER_LEN: usize = 24;
/// Fixed response-header length (bytes before the value).
pub const RESP_HEADER_LEN: usize = 16;
/// Upper bound on the value-length field. Values are u64 today, so any
/// larger length is hostile or corrupt and is rejected straight from
/// the header, before any allocation or buffering decision.
pub const MAX_VALUE_LEN: u32 = 8;

/// Response status bytes.
pub const STATUS_OK: u8 = 1;
pub const STATUS_VALUE: u8 = 2;
pub const STATUS_MISSING: u8 = 3;
pub const STATUS_ERROR: u8 = 4;

/// The wire error-code bytes, named. Each constant is
/// [`crate::error::KvError::code`] for the matching variant — the
/// defining map — with the name derived from
/// [`crate::error::KvError::code_name`] (SCREAMING_SNAKE_CASE of the
/// wire name). `dhash-lint`'s `wire` rule holds this module, the two
/// `error.rs` maps, and the DESIGN.md §Error codes table equal, so a
/// client matching on these constants can never drift from the server.
pub mod wire_code {
    /// Coordinator shut down (or shut down mid-request).
    pub const SHUTDOWN: u8 = 0x01;
    /// Per-connection inflight window full; request shed.
    pub const OVERLOADED: u8 = 0x02;
    /// A shard resize/rebuild token was already taken.
    pub const RESIZE_BUSY: u8 = 0x10;
    /// Resize named a shard the directory does not route.
    pub const RESIZE_NO_SUCH_SHARD: u8 = 0x11;
    /// Split refused: shard already at maximum depth.
    pub const RESIZE_AT_MAX_DEPTH: u8 = 0x12;
    /// Merge refused: shards are not buddy pairs.
    pub const RESIZE_UNMERGEABLE: u8 = 0x13;
    /// Rebuild/resize refused: requested geometry is invalid (0 buckets).
    pub const RESIZE_BAD_GEOMETRY: u8 = 0x14;
    /// Routing-oracle engine failed.
    pub const ORACLE_ENGINE: u8 = 0x20;
    /// Routing-oracle answer was for a superseded epoch.
    pub const ORACLE_EPOCH: u8 = 0x21;
    /// Frame magic byte mismatch.
    pub const PROTO_BAD_MAGIC: u8 = 0x30;
    /// Unsupported protocol version byte.
    pub const PROTO_BAD_VERSION: u8 = 0x31;
    /// Unknown request op-code byte.
    pub const PROTO_BAD_OP: u8 = 0x32;
    /// Unknown response status byte.
    pub const PROTO_BAD_STATUS: u8 = 0x33;
    /// Value-length field exceeds [`super::MAX_VALUE_LEN`].
    pub const PROTO_VALUE_TOO_LONG: u8 = 0x34;
    /// Value length inconsistent with the op/status byte.
    pub const PROTO_BAD_VALUE_LEN: u8 = 0x35;
    /// A reserved byte was not zero.
    pub const PROTO_BAD_RESERVED: u8 = 0x36;
}

fn read_u32(b: &[u8]) -> u32 {
    u32::from_le_bytes(b[..4].try_into().unwrap())
}

fn read_u64(b: &[u8]) -> u64 {
    u64::from_le_bytes(b[..8].try_into().unwrap())
}

/// One request on the wire: the client-chosen id (echoed verbatim in
/// the response) plus the operation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RequestFrame {
    pub id: u64,
    pub req: Request,
}

impl RequestFrame {
    pub fn new(id: u64, req: Request) -> Self {
        Self { id, req }
    }

    /// Append this frame's exact wire bytes to `out`.
    pub fn encode(&self, out: &mut Vec<u8>) {
        let (key, val) = match self.req {
            Request::Get { key } | Request::Del { key } => (key, None),
            Request::Put { key, val } => (key, Some(val)),
        };
        out.reserve(REQ_HEADER_LEN + 8);
        out.push(MAGIC_REQ);
        out.push(VERSION);
        out.push(self.req.op() as u8);
        out.push(0); // reserved
        out.extend_from_slice(&self.id.to_le_bytes());
        out.extend_from_slice(&key.to_le_bytes());
        match val {
            None => out.extend_from_slice(&0u32.to_le_bytes()),
            Some(v) => {
                out.extend_from_slice(&8u32.to_le_bytes());
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
    }

    /// Decode one frame from the front of `buf`.
    ///
    /// `Ok(None)` means the bytes so far are a valid *prefix* — feed
    /// more and retry (incremental decoding resumes at any split
    /// point). `Ok(Some((frame, consumed)))` hands back the frame and
    /// how many bytes it used. `Err` means the stream is not a valid
    /// frame boundary; framing is lost and the connection should be
    /// failed. Validation is strict-first: magic, then version, then
    /// the full header — so corruption is reported as early as the
    /// bytes allow, without waiting for (or allocating) the payload.
    pub fn decode(buf: &[u8]) -> Result<Option<(RequestFrame, usize)>, ProtoError> {
        if buf.is_empty() {
            return Ok(None);
        }
        if buf[0] != MAGIC_REQ {
            return Err(ProtoError::BadMagic(buf[0]));
        }
        if buf.len() < 2 {
            return Ok(None);
        }
        if buf[1] != VERSION {
            return Err(ProtoError::BadVersion(buf[1]));
        }
        if buf.len() < REQ_HEADER_LEN {
            return Ok(None);
        }
        let op = OpCode::from_wire(buf[2])?;
        if buf[3] != 0 {
            return Err(ProtoError::BadReserved(buf[3]));
        }
        let id = read_u64(&buf[4..]);
        let key = read_u64(&buf[12..]);
        let vlen = read_u32(&buf[20..]);
        if vlen > MAX_VALUE_LEN {
            // Capped straight from the header: never wait for (let
            // alone allocate) a hostile multi-GiB "value".
            return Err(ProtoError::ValueTooLong(vlen));
        }
        let want = match op {
            OpCode::Put => 8,
            OpCode::Get | OpCode::Del => 0,
        };
        if vlen != want {
            return Err(ProtoError::BadValueLen {
                op: buf[2],
                len: vlen,
            });
        }
        let total = REQ_HEADER_LEN + vlen as usize;
        if buf.len() < total {
            return Ok(None);
        }
        let req = match op {
            OpCode::Get => Request::get(key),
            OpCode::Del => Request::del(key),
            OpCode::Put => Request::put(key, read_u64(&buf[REQ_HEADER_LEN..])),
        };
        Ok(Some((RequestFrame { id, req }, total)))
    }
}

/// One response on the wire: the echoed request id plus either the KV
/// reply or a [`crate::error::KvError`] code byte (the same numeric
/// code the in-process error carries, so on-wire and in-process errors
/// cannot drift apart).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ResponseFrame {
    pub id: u64,
    pub body: Result<Response, u8>,
}

impl ResponseFrame {
    pub fn reply(id: u64, resp: Response) -> Self {
        Self { id, body: Ok(resp) }
    }

    /// An error response carrying `err`'s stable wire code.
    pub fn error(id: u64, err: crate::error::KvError) -> Self {
        Self {
            id,
            body: Err(err.code()),
        }
    }

    /// Append this frame's exact wire bytes to `out`.
    pub fn encode(&self, out: &mut Vec<u8>) {
        out.reserve(RESP_HEADER_LEN + 8);
        out.push(MAGIC_RESP);
        out.push(VERSION);
        let (status, err, val) = match self.body {
            Ok(Response::Ok) => (STATUS_OK, 0, None),
            Ok(Response::Value(v)) => (STATUS_VALUE, 0, Some(v)),
            Ok(Response::Missing) => (STATUS_MISSING, 0, None),
            Err(code) => (STATUS_ERROR, code, None),
        };
        out.push(status);
        out.push(err);
        out.extend_from_slice(&self.id.to_le_bytes());
        match val {
            None => out.extend_from_slice(&0u32.to_le_bytes()),
            Some(v) => {
                out.extend_from_slice(&8u32.to_le_bytes());
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
    }

    /// Decode one frame from the front of `buf`; same contract as
    /// [`RequestFrame::decode`].
    pub fn decode(buf: &[u8]) -> Result<Option<(ResponseFrame, usize)>, ProtoError> {
        if buf.is_empty() {
            return Ok(None);
        }
        if buf[0] != MAGIC_RESP {
            return Err(ProtoError::BadMagic(buf[0]));
        }
        if buf.len() < 2 {
            return Ok(None);
        }
        if buf[1] != VERSION {
            return Err(ProtoError::BadVersion(buf[1]));
        }
        if buf.len() < RESP_HEADER_LEN {
            return Ok(None);
        }
        let status = buf[2];
        let err = buf[3];
        let id = read_u64(&buf[4..]);
        let vlen = read_u32(&buf[12..]);
        if vlen > MAX_VALUE_LEN {
            return Err(ProtoError::ValueTooLong(vlen));
        }
        let want = if status == STATUS_VALUE { 8 } else { 0 };
        if vlen != want {
            return Err(ProtoError::BadValueLen {
                op: status,
                len: vlen,
            });
        }
        let total = RESP_HEADER_LEN + vlen as usize;
        if buf.len() < total {
            return Ok(None);
        }
        let body = match status {
            STATUS_OK => Ok(Response::Ok),
            STATUS_VALUE => Ok(Response::Value(read_u64(&buf[RESP_HEADER_LEN..]))),
            STATUS_MISSING => Ok(Response::Missing),
            STATUS_ERROR => Err(err),
            other => return Err(ProtoError::BadStatus(other)),
        };
        if status != STATUS_ERROR && err != 0 {
            return Err(ProtoError::BadReserved(err));
        }
        Ok(Some((ResponseFrame { id, body }, total)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::error::KvError;

    #[test]
    fn request_accessors_and_opcodes() {
        assert_eq!(Request::put(3, 4).key(), 3);
        assert_eq!(Request::del(5).key(), 5);
        assert_eq!(Request::get(6).key(), 6);
        assert_eq!(Request::get(0).op() as u8, 1);
        assert_eq!(Request::put(0, 0).op() as u8, 2);
        assert_eq!(Request::del(0).op() as u8, 3);
        assert!(OpCode::from_wire(0).is_err());
        assert!(OpCode::from_wire(4).is_err());
    }

    /// The byte layout is the protocol: pin it against golden bytes so
    /// an accidental field reorder is a test failure, not a silent
    /// version break.
    #[test]
    fn request_frame_golden_bytes() {
        let mut out = Vec::new();
        RequestFrame::new(0x0102_0304_0506_0708, Request::put(0x11, 0x22)).encode(&mut out);
        #[rustfmt::skip]
        let want = [
            0xD4, 0x01, 0x02, 0x00,
            0x08, 0x07, 0x06, 0x05, 0x04, 0x03, 0x02, 0x01,
            0x11, 0, 0, 0, 0, 0, 0, 0,
            0x08, 0, 0, 0,
            0x22, 0, 0, 0, 0, 0, 0, 0,
        ];
        assert_eq!(out, want);
        let mut out = Vec::new();
        RequestFrame::new(7, Request::get(9)).encode(&mut out);
        assert_eq!(out.len(), REQ_HEADER_LEN);
        assert_eq!(out[0], MAGIC_REQ);
        assert_eq!(&out[20..24], &[0, 0, 0, 0]);
    }

    #[test]
    fn response_frame_golden_bytes() {
        let mut out = Vec::new();
        ResponseFrame::reply(1, Response::Value(0x33)).encode(&mut out);
        #[rustfmt::skip]
        let want = [
            0xD5, 0x01, 0x02, 0x00,
            0x01, 0, 0, 0, 0, 0, 0, 0,
            0x08, 0, 0, 0,
            0x33, 0, 0, 0, 0, 0, 0, 0,
        ];
        assert_eq!(out, want);
        let mut out = Vec::new();
        ResponseFrame::error(2, KvError::Overloaded).encode(&mut out);
        assert_eq!(out.len(), RESP_HEADER_LEN);
        assert_eq!(out[2], STATUS_ERROR);
        assert_eq!(out[3], KvError::Overloaded.code());
    }

    #[test]
    fn round_trip_all_ops_and_statuses() {
        let reqs = [
            Request::get(u64::MAX),
            Request::put(0, u64::MAX),
            Request::del(42),
        ];
        for (i, r) in reqs.iter().enumerate() {
            let f = RequestFrame::new(i as u64 * 1_000_003, *r);
            let mut out = Vec::new();
            f.encode(&mut out);
            let (back, used) = RequestFrame::decode(&out).unwrap().unwrap();
            assert_eq!(back, f);
            assert_eq!(used, out.len());
        }
        let resps = [
            ResponseFrame::reply(1, Response::Ok),
            ResponseFrame::reply(2, Response::Value(77)),
            ResponseFrame::reply(3, Response::Missing),
            ResponseFrame::error(4, KvError::Shutdown),
        ];
        for f in resps {
            let mut out = Vec::new();
            f.encode(&mut out);
            let (back, used) = ResponseFrame::decode(&out).unwrap().unwrap();
            assert_eq!(back, f);
            assert_eq!(used, out.len());
        }
    }

    #[test]
    fn every_strict_prefix_asks_for_more() {
        let f = RequestFrame::new(9, Request::put(1, 2));
        let mut out = Vec::new();
        f.encode(&mut out);
        for cut in 0..out.len() {
            assert_eq!(
                RequestFrame::decode(&out[..cut]).unwrap(),
                None,
                "prefix of {cut} bytes must be incomplete, not an error"
            );
        }
    }

    #[test]
    fn corrupt_headers_rejected() {
        let mut out = Vec::new();
        RequestFrame::new(5, Request::get(6)).encode(&mut out);
        for (byte, want) in [
            (0usize, ProtoError::BadMagic(0xFF)),
            (1, ProtoError::BadVersion(0xFF)),
            (2, ProtoError::BadOpCode(0xFF)),
            (3, ProtoError::BadReserved(0xFF)),
        ] {
            let mut bad = out.clone();
            bad[byte] = 0xFF;
            assert_eq!(RequestFrame::decode(&bad).unwrap_err(), want);
        }
    }

    #[test]
    fn oversized_value_len_rejected_from_header_alone() {
        let mut out = Vec::new();
        RequestFrame::new(5, Request::put(6, 7)).encode(&mut out);
        out[20..24].copy_from_slice(&u32::MAX.to_le_bytes());
        // Only the 24-byte header is present — the decoder must reject
        // from the length field without waiting for 4 GiB of payload.
        assert_eq!(
            RequestFrame::decode(&out[..REQ_HEADER_LEN]).unwrap_err(),
            ProtoError::ValueTooLong(u32::MAX)
        );
    }

    #[test]
    fn value_len_must_match_op_and_status() {
        let mut out = Vec::new();
        RequestFrame::new(5, Request::get(6)).encode(&mut out);
        out[20..24].copy_from_slice(&8u32.to_le_bytes());
        assert_eq!(
            RequestFrame::decode(&out).unwrap_err(),
            ProtoError::BadValueLen { op: 1, len: 8 }
        );
        let mut out = Vec::new();
        ResponseFrame::reply(1, Response::Value(2)).encode(&mut out);
        out[12..16].copy_from_slice(&0u32.to_le_bytes());
        assert_eq!(
            ResponseFrame::decode(&out).unwrap_err(),
            ProtoError::BadValueLen {
                op: STATUS_VALUE,
                len: 0
            }
        );
    }

    #[test]
    fn trailing_bytes_left_for_next_frame() {
        let mut out = Vec::new();
        RequestFrame::new(1, Request::get(2)).encode(&mut out);
        let first_len = out.len();
        RequestFrame::new(3, Request::put(4, 5)).encode(&mut out);
        let (f, used) = RequestFrame::decode(&out).unwrap().unwrap();
        assert_eq!(f.id, 1);
        assert_eq!(used, first_len);
        let (g, _) = RequestFrame::decode(&out[used..]).unwrap().unwrap();
        assert_eq!(g.id, 3);
    }
}
