//! The network front end: a compact binary wire protocol and a
//! single-epoll-multiple-workers TCP listener serving the coordinator's
//! completion-slot ingest API over real sockets.
//!
//! Layering (see DESIGN.md §Network front end):
//!
//! * [`proto`] — the wire format itself: [`Request`]/[`Response`] model
//!   types (single source of truth, re-exported by `coordinator`),
//!   stable op codes, and exact frame encode/decode.
//! * [`codec`] — the zero-copy incremental [`codec::Decoder`] that
//!   turns a connection's byte stream back into frames across arbitrary
//!   read boundaries.
//! * [`stats`] — per-connection and aggregate counters, folded into
//!   [`crate::coordinator::CoordinatorStats`].
//! * [`listener`] *(unix)* — the readiness loop behind the
//!   [`listener::Listener`] trait (epoll today; the trait is the seam
//!   where an io_uring backend lands later).
//! * [`conn`] *(unix)* — one connection's state machine: decode →
//!   **one** [`KvClient::submit_batch`] per readable drain →
//!   completion-driven response writes as tickets resolve; bounded
//!   inflight window with shed-on-full as a wire error code.
//! * [`server`] *(unix)* — [`server::NetServer`]: owns the listener,
//!   the worker threads, and graceful drain on shutdown.
//! * [`bench`] *(unix)* — the `netbench` pipelined loopback client and
//!   its verification/throughput drivers.
//!
//! [`KvClient::submit_batch`]: crate::coordinator::KvClient::submit_batch
//! [`Request`]: proto::Request
//! [`Response`]: proto::Response

pub mod codec;
pub mod proto;
pub mod stats;

#[cfg(unix)]
pub mod bench;
#[cfg(unix)]
pub mod conn;
#[cfg(unix)]
pub mod listener;
#[cfg(unix)]
pub mod server;

pub use codec::Decoder;
pub use stats::{ConnStats, NetStats};

#[cfg(unix)]
pub use bench::{BenchReport, NetClient};
#[cfg(unix)]
pub use listener::Listener;
#[cfg(unix)]
pub use server::{NetConfig, NetServer};
