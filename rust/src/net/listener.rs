//! The readiness backend behind the serving loop: the [`Listener`]
//! trait (accept + fd registration + readiness wait) and its epoll
//! implementation.
//!
//! The trait is deliberately the *narrowest* seam that the connection
//! workers need — five methods, no epoll types in the signatures — so
//! an io_uring backend (completions mapped onto [`Event`]s) can land
//! behind it without touching `conn.rs`/`server.rs` (ROADMAP: io_uring
//! follow-on).
//!
//! The epoll backend is hand-rolled over `std::os::fd`: the `libc`
//! crate is outside this workspace's dependency set, so the three
//! syscalls are declared directly against the C library, the same idiom
//! as [`crate::util::affinity`]. Everything is registered
//! `EPOLLONESHOT`: N workers share ONE epoll fd
//! (single-epoll-multiple-workers), and one-shot delivery is what
//! guarantees a given connection is handled by exactly one worker at a
//! time without a herd wakeup.

use std::io;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::os::fd::{AsRawFd, RawFd};
use std::time::Duration;

/// Identity of the accept socket in [`Event::id`]; connections use
/// ids ≥ 1.
pub const LISTENER_ID: u64 = 0;

/// One readiness notification, backend-neutral.
#[derive(Clone, Copy, Debug)]
pub struct Event {
    /// The id the fd was registered under ([`LISTENER_ID`] = accept).
    pub id: u64,
    pub readable: bool,
    pub writable: bool,
}

/// The backend seam: accept plus one-shot readiness registration.
///
/// Contract: every registration is **one-shot** — after an [`Event`]
/// for `id` is delivered, no further events for that fd arrive until
/// [`rearm`](Listener::rearm). [`accept`](Listener::accept) drains and
/// internally re-arms its own socket, so callers loop it until `None`.
pub trait Listener: Send + Sync {
    fn local_addr(&self) -> io::Result<SocketAddr>;

    /// Accept one pending connection (non-blocking). `None` means the
    /// backlog is drained and the accept socket is re-armed.
    fn accept(&self) -> io::Result<Option<TcpStream>>;

    /// Register `fd` under `id` for the given interests (one-shot).
    fn register(&self, fd: RawFd, id: u64, read: bool, write: bool) -> io::Result<()>;

    /// Re-arm an already-registered fd with fresh interests.
    fn rearm(&self, fd: RawFd, id: u64, read: bool, write: bool) -> io::Result<()>;

    /// Drop `fd` from the readiness set.
    fn deregister(&self, fd: RawFd) -> io::Result<()>;

    /// Block up to `timeout` for events, appending them to `out`.
    /// Safe to call from many workers concurrently.
    fn wait(&self, out: &mut Vec<Event>, timeout: Duration) -> io::Result<()>;
}

#[cfg(target_os = "linux")]
mod sys {
    //! Minimal epoll bindings, declared directly against the C library
    //! (no `libc` crate in the workspace).

    use std::io;
    use std::os::fd::{AsRawFd, FromRawFd, OwnedFd, RawFd};

    pub const EPOLLIN: u32 = 0x001;
    pub const EPOLLOUT: u32 = 0x004;
    pub const EPOLLERR: u32 = 0x008;
    pub const EPOLLHUP: u32 = 0x010;
    pub const EPOLLRDHUP: u32 = 0x2000;
    pub const EPOLLONESHOT: u32 = 1 << 30;
    const EPOLL_CTL_ADD: i32 = 1;
    const EPOLL_CTL_DEL: i32 = 2;
    const EPOLL_CTL_MOD: i32 = 3;
    const EPOLL_CLOEXEC: i32 = 0o2000000;

    // The kernel ABI packs the struct on x86-64 only.
    #[cfg_attr(target_arch = "x86_64", repr(C, packed))]
    #[cfg_attr(not(target_arch = "x86_64"), repr(C))]
    #[derive(Clone, Copy)]
    pub struct EpollEvent {
        pub events: u32,
        pub data: u64,
    }

    extern "C" {
        fn epoll_create1(flags: i32) -> i32;
        fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
        fn epoll_wait(epfd: i32, events: *mut EpollEvent, maxevents: i32, timeout: i32) -> i32;
    }

    pub struct Epoll {
        fd: OwnedFd,
    }

    impl Epoll {
        pub fn new() -> io::Result<Self> {
            // SAFETY: no pointer arguments; returns a fresh fd or -1.
            let fd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
            if fd < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(Self {
                // SAFETY: fd is a fresh epoll descriptor we own.
                fd: unsafe { OwnedFd::from_raw_fd(fd) },
            })
        }

        fn ctl(&self, op: i32, fd: RawFd, events: u32, data: u64) -> io::Result<()> {
            let mut ev = EpollEvent { events, data };
            let evp = if op == EPOLL_CTL_DEL {
                std::ptr::null_mut()
            } else {
                &mut ev as *mut EpollEvent
            };
            // SAFETY: evp is null (DEL) or points at a live EpollEvent.
            if unsafe { epoll_ctl(self.fd.as_raw_fd(), op, fd, evp) } < 0 {
                Err(io::Error::last_os_error())
            } else {
                Ok(())
            }
        }

        pub fn add(&self, fd: RawFd, events: u32, data: u64) -> io::Result<()> {
            self.ctl(EPOLL_CTL_ADD, fd, events, data)
        }

        pub fn modify(&self, fd: RawFd, events: u32, data: u64) -> io::Result<()> {
            self.ctl(EPOLL_CTL_MOD, fd, events, data)
        }

        pub fn del(&self, fd: RawFd) -> io::Result<()> {
            self.ctl(EPOLL_CTL_DEL, fd, 0, 0)
        }

        /// Wait up to `timeout_ms`, pushing `(data, events)` pairs.
        pub fn wait(&self, out: &mut Vec<(u64, u32)>, timeout_ms: i32) -> io::Result<()> {
            const CAP: usize = 64;
            let mut buf = [EpollEvent { events: 0, data: 0 }; CAP];
            let n = unsafe {
                // SAFETY: buf is a live array of CAP events.
                epoll_wait(self.fd.as_raw_fd(), buf.as_mut_ptr(), CAP as i32, timeout_ms)
            };
            if n < 0 {
                let e = io::Error::last_os_error();
                // A signal interrupting the wait is a normal early
                // return, not a failure.
                if e.kind() == io::ErrorKind::Interrupted {
                    return Ok(());
                }
                return Err(e);
            }
            for ev in buf.iter().take(n as usize) {
                // Copy out of the (possibly packed) struct before use.
                let (data, events) = (ev.data, ev.events);
                out.push((data, events));
            }
            Ok(())
        }
    }
}

#[cfg(all(unix, not(target_os = "linux")))]
mod sys {
    //! Non-Linux unix stub: compiles everywhere, reports Unsupported at
    //! bind time (the trait seam is where a kqueue backend would go).

    use std::io;
    use std::os::fd::RawFd;

    pub const EPOLLIN: u32 = 0x001;
    pub const EPOLLOUT: u32 = 0x004;
    pub const EPOLLERR: u32 = 0x008;
    pub const EPOLLHUP: u32 = 0x010;
    pub const EPOLLRDHUP: u32 = 0x2000;
    pub const EPOLLONESHOT: u32 = 1 << 30;

    pub struct Epoll;

    impl Epoll {
        pub fn new() -> io::Result<Self> {
            Err(io::Error::new(
                io::ErrorKind::Unsupported,
                "epoll is Linux-only; no readiness backend on this platform",
            ))
        }

        pub fn add(&self, _fd: RawFd, _events: u32, _data: u64) -> io::Result<()> {
            unreachable!("Epoll::new never succeeds on this platform")
        }

        pub fn modify(&self, _fd: RawFd, _events: u32, _data: u64) -> io::Result<()> {
            unreachable!("Epoll::new never succeeds on this platform")
        }

        pub fn del(&self, _fd: RawFd) -> io::Result<()> {
            unreachable!("Epoll::new never succeeds on this platform")
        }

        pub fn wait(&self, _out: &mut Vec<(u64, u32)>, _timeout_ms: i32) -> io::Result<()> {
            unreachable!("Epoll::new never succeeds on this platform")
        }
    }
}

/// Event bits that make a connection readable: data, or an error/hangup
/// the next `read` will report (EOF or the socket error), winding the
/// connection down through the normal path.
const READ_MASK: u32 = sys::EPOLLIN | sys::EPOLLERR | sys::EPOLLHUP | sys::EPOLLRDHUP;
const WRITE_MASK: u32 = sys::EPOLLOUT | sys::EPOLLERR | sys::EPOLLHUP;

fn interests(read: bool, write: bool) -> u32 {
    let mut ev = sys::EPOLLONESHOT | sys::EPOLLRDHUP;
    if read {
        ev |= sys::EPOLLIN;
    }
    if write {
        ev |= sys::EPOLLOUT;
    }
    ev
}

/// The epoll-backed [`Listener`]: one epoll fd shared by every worker,
/// the accept socket registered one-shot under [`LISTENER_ID`].
pub struct EpollListener {
    sock: TcpListener,
    ep: sys::Epoll,
}

impl EpollListener {
    pub fn bind(addr: &str) -> io::Result<Self> {
        let sock = TcpListener::bind(addr)?;
        sock.set_nonblocking(true)?;
        let ep = sys::Epoll::new()?;
        ep.add(sock.as_raw_fd(), interests(true, false), LISTENER_ID)?;
        Ok(Self { sock, ep })
    }
}

impl Listener for EpollListener {
    fn local_addr(&self) -> io::Result<SocketAddr> {
        self.sock.local_addr()
    }

    fn accept(&self) -> io::Result<Option<TcpStream>> {
        match self.sock.accept() {
            Ok((stream, _)) => Ok(Some(stream)),
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                // Backlog drained: re-arm the one-shot registration so
                // the next connect wakes a worker.
                let fd = self.sock.as_raw_fd();
                self.ep.modify(fd, interests(true, false), LISTENER_ID)?;
                Ok(None)
            }
            Err(e) => Err(e),
        }
    }

    fn register(&self, fd: RawFd, id: u64, read: bool, write: bool) -> io::Result<()> {
        self.ep.add(fd, interests(read, write), id)
    }

    fn rearm(&self, fd: RawFd, id: u64, read: bool, write: bool) -> io::Result<()> {
        self.ep.modify(fd, interests(read, write), id)
    }

    fn deregister(&self, fd: RawFd) -> io::Result<()> {
        self.ep.del(fd)
    }

    fn wait(&self, out: &mut Vec<Event>, timeout: Duration) -> io::Result<()> {
        let ms = timeout.as_millis().min(i32::MAX as u128) as i32;
        let mut raw = Vec::new();
        self.ep.wait(&mut raw, ms)?;
        for (id, events) in raw {
            out.push(Event {
                id,
                readable: events & READ_MASK != 0,
                writable: events & WRITE_MASK != 0,
            });
        }
        Ok(())
    }
}

#[cfg(all(test, target_os = "linux"))]
mod tests {
    use super::*;
    use std::io::Write;

    fn wait_for(l: &EpollListener, id: u64, read: bool) -> Event {
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        let mut evs = Vec::new();
        loop {
            l.wait(&mut evs, Duration::from_millis(50)).unwrap();
            if let Some(ev) = evs.iter().find(|e| e.id == id && (!read || e.readable)) {
                return *ev;
            }
            evs.clear();
            assert!(std::time::Instant::now() < deadline, "no event for id {id}");
        }
    }

    #[test]
    fn accept_and_readiness_round_trip() {
        let l = EpollListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();

        // The accept socket signals, then drains (and re-arms) cleanly.
        let ev = wait_for(&l, LISTENER_ID, true);
        assert!(ev.readable);
        let conn = l.accept().unwrap().expect("one pending connection");
        assert!(l.accept().unwrap().is_none(), "backlog is drained");

        // A registered connection signals readable only once data lands.
        conn.set_nonblocking(true).unwrap();
        l.register(conn.as_raw_fd(), 7, true, false).unwrap();
        client.write_all(b"x").unwrap();
        let ev = wait_for(&l, 7, true);
        assert!(ev.readable);

        // Re-arm for write: an idle socket is writable immediately.
        l.rearm(conn.as_raw_fd(), 7, false, true).unwrap();
        let ev = wait_for(&l, 7, false);
        assert!(ev.writable);

        l.deregister(conn.as_raw_fd()).unwrap();

        // A second connect re-fires the re-armed accept socket.
        let _client2 = TcpStream::connect(addr).unwrap();
        let ev = wait_for(&l, LISTENER_ID, true);
        assert!(ev.readable);
    }
}
