//! Network-layer counters: per-connection [`ConnStats`] and the
//! aggregate [`NetStats`] snapshot folded into
//! [`crate::coordinator::CoordinatorStats`] — serving-path degradation
//! (sheds, protocol errors) is surfaced next to routing degradation,
//! never siloed in the network layer.

use std::sync::atomic::{AtomicU64, Ordering};

/// Aggregate network counters, as a plain snapshot.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct NetStats {
    /// Connections accepted since start.
    pub accepted: u64,
    /// Connections currently open.
    pub active: u64,
    /// Connections fully closed.
    pub closed: u64,
    /// Raw bytes read from / written to sockets.
    pub bytes_in: u64,
    pub bytes_out: u64,
    /// Complete frames decoded / responses encoded.
    pub frames_in: u64,
    pub frames_out: u64,
    /// `submit_batch` calls issued (one per connection drain).
    pub batches: u64,
    /// Requests shed by the per-connection inflight window
    /// ([`crate::error::KvError::Overloaded`] on the wire).
    pub sheds: u64,
    /// Connections failed for unparseable bytes
    /// ([`crate::error::KvError::Protocol`] on the wire).
    pub protocol_errors: u64,
}

/// The live atomic counters behind [`NetStats`]. Shared by every worker
/// thread; relaxed ordering is fine — these are monotonic tallies, not
/// synchronization.
#[derive(Default)]
pub struct NetCounters {
    pub accepted: AtomicU64,
    pub active: AtomicU64,
    pub closed: AtomicU64,
    pub bytes_in: AtomicU64,
    pub bytes_out: AtomicU64,
    pub frames_in: AtomicU64,
    pub frames_out: AtomicU64,
    pub batches: AtomicU64,
    pub sheds: AtomicU64,
    pub protocol_errors: AtomicU64,
}

impl NetCounters {
    pub fn add(c: &AtomicU64, n: u64) {
        c.fetch_add(n, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> NetStats {
        let get = |c: &AtomicU64| c.load(Ordering::Relaxed);
        NetStats {
            accepted: get(&self.accepted),
            active: get(&self.active),
            closed: get(&self.closed),
            bytes_in: get(&self.bytes_in),
            bytes_out: get(&self.bytes_out),
            frames_in: get(&self.frames_in),
            frames_out: get(&self.frames_out),
            batches: get(&self.batches),
            sheds: get(&self.sheds),
            protocol_errors: get(&self.protocol_errors),
        }
    }
}

/// One connection's counters (owned by the connection under its lock —
/// plain integers, no atomics needed).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ConnStats {
    pub bytes_in: u64,
    pub bytes_out: u64,
    pub frames_in: u64,
    pub frames_out: u64,
    pub batches: u64,
    pub sheds: u64,
    pub protocol_errors: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_reflects_counter_updates() {
        let c = NetCounters::default();
        assert_eq!(c.snapshot(), NetStats::default());
        NetCounters::add(&c.accepted, 3);
        NetCounters::add(&c.active, 2);
        NetCounters::add(&c.bytes_in, 100);
        NetCounters::add(&c.sheds, 1);
        let s = c.snapshot();
        assert_eq!(s.accepted, 3);
        assert_eq!(s.active, 2);
        assert_eq!(s.bytes_in, 100);
        assert_eq!(s.sheds, 1);
        assert_eq!(s.frames_out, 0);
    }
}
