//! QSBR grace-period machinery: thread records, the global grace-period
//! counter, and `synchronize_rcu`.

use std::cell::Cell;
use std::marker::PhantomData;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// One registered reader thread. `ctr == 0` means offline; otherwise the
/// value of the global grace-period counter at the thread's most recent
/// quiescent state.
struct ThreadRecord {
    ctr: AtomicU64,
}

/// The RCU domain: the global grace-period counter plus the registry of
/// reader threads. A single process-wide domain (as in liburcu) is exposed
/// through the free functions; the struct is public so tests can create
/// isolated domains.
pub struct RcuDomain {
    gp: AtomicU64,
    /// Serializes grace-period detection (concurrent `synchronize_rcu`
    /// calls batch behind each other, exactly like liburcu's `gp_lock`).
    gp_lock: Mutex<()>,
    registry: Mutex<Vec<Arc<ThreadRecord>>>,
}

impl Default for RcuDomain {
    fn default() -> Self {
        Self::new()
    }
}

impl RcuDomain {
    pub const fn new() -> Self {
        Self {
            gp: AtomicU64::new(1),
            gp_lock: Mutex::new(()),
            registry: Mutex::new(Vec::new()),
        }
    }

    fn register(&'static self) -> RcuThread {
        let rec = Arc::new(ThreadRecord {
            // Born online, as if it had just announced a quiescent state.
            ctr: AtomicU64::new(self.gp.load(Ordering::SeqCst)),
        });
        self.registry.lock().unwrap().push(rec.clone());
        RcuThread {
            domain: self,
            rec,
            depth: Cell::new(0),
            _not_send: PhantomData,
        }
    }

    /// Wait for a full grace period: on return, every read-side critical
    /// section that was in progress when this call began has completed.
    pub fn synchronize(&self, caller: Option<&RcuThread>) {
        // A registered caller must not wait on its own record: announce
        // offline for the duration (its read-side references are its own
        // responsibility — calling synchronize_rcu inside a read-side
        // critical section is a bug, same as in liburcu).
        let restore = caller.map(|t| {
            let prev = t.rec.ctr.swap(0, Ordering::SeqCst);
            (t, prev)
        });

        {
            let _g = self.gp_lock.lock().unwrap();
            let target = self.gp.fetch_add(1, Ordering::SeqCst) + 1;
            // Snapshot the registry; threads registered *after* the bump
            // cannot hold pre-bump references, so the snapshot is enough.
            let records: Vec<Arc<ThreadRecord>> =
                self.registry.lock().unwrap().iter().cloned().collect();
            for rec in records {
                let mut spins = 0u32;
                loop {
                    let c = rec.ctr.load(Ordering::SeqCst);
                    if c == 0 || c >= target {
                        break;
                    }
                    spins += 1;
                    if spins < 64 {
                        std::hint::spin_loop();
                    } else {
                        // Single-core friendliness: give the reader a turn.
                        std::thread::yield_now();
                        if spins > 4096 {
                            std::thread::sleep(std::time::Duration::from_micros(50));
                        }
                    }
                }
            }
        }

        if let Some((t, prev)) = restore {
            if prev != 0 {
                // Re-online at the *current* GP value.
                t.rec.ctr.store(self.gp.load(Ordering::SeqCst), Ordering::SeqCst);
            }
        }
    }

    fn deregister(&self, rec: &Arc<ThreadRecord>) {
        // Go offline FIRST: an in-flight `synchronize` may hold a snapshot
        // containing this record; a frozen non-zero ctr would stall that
        // grace period forever once the thread is gone.
        rec.ctr.store(0, Ordering::SeqCst);
        let mut reg = self.registry.lock().unwrap();
        if let Some(pos) = reg.iter().position(|r| Arc::ptr_eq(r, rec)) {
            reg.swap_remove(pos);
        }
    }
}

/// The process-wide RCU domain used by the hash tables.
static GLOBAL: RcuDomain = RcuDomain::new();

pub(crate) fn global() -> &'static RcuDomain {
    &GLOBAL
}

thread_local! {
    /// Set while this thread owns a registration, so `synchronize_rcu`
    /// (the free function) can exempt the caller's own record.
    static CURRENT: Cell<*const ThreadRecord> = const { Cell::new(std::ptr::null()) };
}

/// Run `f` with the calling thread's registration (if any) in an extended
/// quiescent state. Every potentially-blocking wait inside the crate
/// (`synchronize_rcu`, `rcu_barrier`, lock acquisition in rebuild) funnels
/// through this so a registered caller can never stall someone else's
/// grace period while it blocks.
pub(crate) fn with_current_offline<R>(f: impl FnOnce() -> R) -> R {
    let cur = CURRENT.with(|c| c.get());
    if cur.is_null() {
        return f();
    }
    // SAFETY: the record outlives the RcuThread guard that set CURRENT and
    // the guard clears CURRENT on drop, so `cur` is valid here.
    let rec = unsafe { &*cur };
    let prev = rec.ctr.swap(0, Ordering::SeqCst);
    let r = f();
    if prev != 0 {
        rec.ctr
            .store(GLOBAL.gp.load(Ordering::SeqCst), Ordering::SeqCst);
    }
    r
}

/// Wait for a grace period on the global domain.
///
/// Must **not** be called from inside a read-side critical section (it
/// would deadlock against itself); a registered caller is treated as
/// passing through an extended quiescent state for the duration.
pub fn synchronize_rcu() {
    with_current_offline(|| GLOBAL.synchronize(None));
}

/// A per-thread RCU registration (QSBR). Obtain one with
/// [`RcuThread::register`]; all hash-table operations take `&RcuThread` as
/// compile-time proof the calling thread participates in grace periods.
///
/// Not `Send`: the registration is bound to the OS thread that created it.
pub struct RcuThread {
    domain: &'static RcuDomain,
    rec: Arc<ThreadRecord>,
    /// Read-side nesting depth (guards are re-entrant, like liburcu).
    depth: Cell<u32>,
    _not_send: PhantomData<*const ()>,
}

impl RcuThread {
    /// Register the calling thread with the global domain.
    pub fn register() -> Self {
        let t = global().register();
        CURRENT.with(|c| c.set(Arc::as_ptr(&t.rec)));
        t
    }

    /// Enter a read-side critical section. Zero instructions under QSBR —
    /// the guard only tracks nesting so [`quiescent_state`] can assert it
    /// is not called with a section open (a debug build check).
    ///
    /// [`quiescent_state`]: RcuThread::quiescent_state
    #[inline(always)]
    pub fn read_lock(&self) -> RcuReadGuard<'_> {
        self.depth.set(self.depth.get() + 1);
        RcuReadGuard { owner: self }
    }

    /// Announce a quiescent state: the thread holds no RCU-protected
    /// references. Cost: one load + one store.
    #[inline(always)]
    pub fn quiescent_state(&self) {
        debug_assert_eq!(
            self.depth.get(),
            0,
            "quiescent_state inside a read-side critical section"
        );
        self.rec
            .ctr
            .store(self.domain.gp.load(Ordering::SeqCst), Ordering::SeqCst);
    }

    /// Enter an extended quiescent state (e.g. before blocking).
    #[inline]
    pub fn offline(&self) {
        debug_assert_eq!(self.depth.get(), 0, "offline inside a read-side section");
        self.rec.ctr.store(0, Ordering::SeqCst);
    }

    /// Leave the extended quiescent state.
    #[inline]
    pub fn online(&self) {
        self.rec
            .ctr
            .store(self.domain.gp.load(Ordering::SeqCst), Ordering::SeqCst);
    }

    /// Run `f` while offline (for blocking operations such as lock
    /// acquisition or I/O), restoring the online state afterwards.
    pub fn offline_while<R>(&self, f: impl FnOnce() -> R) -> R {
        self.offline();
        let r = f();
        self.online();
        r
    }

    /// `synchronize_rcu` with this thread exempted (equivalent to the free
    /// function, but skips the thread-local probe).
    pub fn synchronize(&self) {
        self.domain.synchronize(Some(self));
    }
}

impl Drop for RcuThread {
    fn drop(&mut self) {
        CURRENT.with(|c| c.set(std::ptr::null()));
        self.domain.deregister(&self.rec);
    }
}

/// Marker guard for a QSBR read-side critical section (no runtime effect
/// beyond nesting accounting; reclamation is prevented by the *absence* of
/// quiescent-state announcements, not by this guard).
pub struct RcuReadGuard<'a> {
    owner: &'a RcuThread,
}

impl Drop for RcuReadGuard<'_> {
    #[inline(always)]
    fn drop(&mut self) {
        self.owner.depth.set(self.owner.depth.get() - 1);
    }
}
