//! QSBR grace-period machinery: thread records, the global grace-period
//! counter, and `synchronize_rcu`.
//!
//! Memory-ordering contract (full per-site table in DESIGN.md §Memory
//! orderings): the protocol needs only acquire/release pairs, no SeqCst.
//!
//! * Writer side: a publication (e.g. a new table pointer store) is
//!   sequenced-before `gp.fetch_add(1, AcqRel)` in [`RcuDomain::synchronize`].
//! * Reader side: [`RcuThread::quiescent_state`] loads `gp` with `Acquire`
//!   and stores that very value into its `ctr` with `Release`. The stored
//!   value carries the proof: if the waiter later observes
//!   `ctr >= target`, the reader's `gp` load must have synchronized with
//!   the `target` bump, so the reader's *next* read-side section sees every
//!   pre-grace-period publication — it cannot resurrect a stale pointer.
//! * The waiter's `Acquire` load of `ctr` synchronizes with the reader's
//!   `Release` store, so everything the reader did in its previous section
//!   happens-before the writer frees retired memory.

use crossbeam_utils::CachePadded;
use std::cell::Cell;
use std::marker::PhantomData;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// One registered reader thread. `ctr == 0` means offline; otherwise the
/// value of the global grace-period counter at the thread's most recent
/// quiescent state.
///
/// Cache-padded: each reader stores to its own `ctr` on every quiescent
/// state, and an unpadded `Vec<Arc<..>>` registry could land two records'
/// allocations on one line, making every reader's announcement invalidate
/// its neighbour's.
struct ThreadRecord {
    ctr: CachePadded<AtomicU64>,
}

/// The RCU domain: the global grace-period counter plus the registry of
/// reader threads. A single process-wide domain (as in liburcu) is exposed
/// through the free functions; the struct is public so tests can create
/// isolated domains.
pub struct RcuDomain {
    gp: AtomicU64,
    /// Serializes grace-period detection (concurrent `synchronize_rcu`
    /// calls batch behind each other, exactly like liburcu's `gp_lock`).
    gp_lock: Mutex<()>,
    registry: Mutex<Vec<Arc<ThreadRecord>>>,
    /// Number of times a grace-period wait escalated all the way to
    /// `thread::sleep` (observable so tests can pin the no-reader fast
    /// path: a grace period with no stalled reader must never sleep).
    sleeps: AtomicU64,
}

impl Default for RcuDomain {
    fn default() -> Self {
        Self::new()
    }
}

impl RcuDomain {
    pub const fn new() -> Self {
        Self {
            gp: AtomicU64::new(1),
            gp_lock: Mutex::new(()),
            registry: Mutex::new(Vec::new()),
            sleeps: AtomicU64::new(0),
        }
    }

    /// How many grace-period waits have escalated to sleeping since the
    /// domain was created.
    pub fn sleep_count(&self) -> u64 {
        // ord: stats-relaxed — monotonic counter, no ordering role
        self.sleeps.load(Ordering::Relaxed)
    }

    fn register(&'static self) -> RcuThread {
        let rec = Arc::new(ThreadRecord {
            // Born online, as if it had just announced a quiescent state.
            // Acquire: pairs with the AcqRel gp bump so the new thread's
            // first section sees every pre-registration publication.
            // ord: qsbr-handshake — gp/ctr grace-period handshake
            ctr: CachePadded::new(AtomicU64::new(self.gp.load(Ordering::Acquire))),
        });
        self.registry.lock().unwrap().push(rec.clone()); // lock: rcu-registry
        RcuThread {
            domain: self,
            rec,
            depth: Cell::new(0),
            _not_send: PhantomData,
        }
    }

    /// Wait for a full grace period: on return, every read-side critical
    /// section that was in progress when this call began has completed.
    pub fn synchronize(&self, caller: Option<&RcuThread>) {
        // A registered caller must not wait on its own record: announce
        // offline for the duration (its read-side references are its own
        // responsibility — calling synchronize_rcu inside a read-side
        // critical section is a bug, same as in liburcu).
        //
        // AcqRel swap: the Release half publishes the caller's preceding
        // section to whoever observes the 0.
        let restore = caller.map(|t| {
            // ord: qsbr-handshake — gp/ctr grace-period handshake
            let prev = t.rec.ctr.swap(0, Ordering::AcqRel);
            (t, prev)
        });

        {
            let _g = self.gp_lock.lock().unwrap(); // lock: rcu-gp
            // AcqRel: Release makes every store sequenced-before this call
            // (the retiring writer's publications) visible to readers whose
            // Acquire gp load returns >= target; Acquire orders the bump
            // after the previous grace period's ctr observations.
            // ord: qsbr-handshake — gp/ctr grace-period handshake
            let target = self.gp.fetch_add(1, Ordering::AcqRel) + 1;
            // Snapshot the registry; threads registered *after* the bump
            // cannot hold pre-bump references, so the snapshot is enough.
            let records: Vec<Arc<ThreadRecord>> =
                self.registry.lock().unwrap().iter().cloned().collect(); // lock: rcu-registry
            for rec in records {
                // Escalating backoff: pure spin while the reader is likely
                // mid-operation, yield to share a core, and only then sleep
                // (exponentially, capped) for genuinely stalled readers. A
                // reader that is already offline or current breaks on the
                // first load — that path must never sleep (pinned by
                // `no_reader_grace_period_never_sleeps`).
                let mut spins = 0u32;
                let mut sleep_us = 1u64;
                loop {
                    // Acquire: pairs with the reader's Release ctr store so
                    // the reader's completed section happens-before any
                    // post-grace-period free.
                    // ord: qsbr-handshake — gp/ctr grace-period handshake
                    let c = rec.ctr.load(Ordering::Acquire);
                    if c == 0 || c >= target {
                        break;
                    }
                    spins += 1;
                    if spins < 128 {
                        std::hint::spin_loop();
                    } else if spins < 1024 {
                        // Single-core friendliness: give the reader a turn.
                        std::thread::yield_now();
                    } else {
                        // ord: stats-relaxed — monotonic counter, no ordering role
                        self.sleeps.fetch_add(1, Ordering::Relaxed);
                        std::thread::sleep(std::time::Duration::from_micros(sleep_us));
                        sleep_us = (sleep_us * 2).min(128);
                    }
                }
            }
        }

        if let Some((t, prev)) = restore {
            if prev != 0 {
                // Re-online at the *current* GP value (Acquire/Release pair
                // as in `quiescent_state`).
                // ord: qsbr-handshake — gp/ctr grace-period handshake
                t.rec
                    .ctr
                    .store(self.gp.load(Ordering::Acquire), Ordering::Release);
            }
        }
    }

    fn deregister(&self, rec: &Arc<ThreadRecord>) {
        // Go offline FIRST: an in-flight `synchronize` may hold a snapshot
        // containing this record; a frozen non-zero ctr would stall that
        // grace period forever once the thread is gone. Release publishes
        // the thread's final section to the waiter's Acquire load.
        // ord: qsbr-handshake — gp/ctr grace-period handshake
        rec.ctr.store(0, Ordering::Release);
        let mut reg = self.registry.lock().unwrap(); // lock: rcu-registry
        if let Some(pos) = reg.iter().position(|r| Arc::ptr_eq(r, rec)) {
            reg.swap_remove(pos);
        }
    }
}

/// The process-wide RCU domain used by the hash tables.
static GLOBAL: RcuDomain = RcuDomain::new();

pub(crate) fn global() -> &'static RcuDomain {
    &GLOBAL
}

thread_local! {
    /// Set while this thread owns a registration, so `synchronize_rcu`
    /// (the free function) can exempt the caller's own record.
    static CURRENT: Cell<*const ThreadRecord> = const { Cell::new(std::ptr::null()) };
}

/// Run `f` with the calling thread's registration (if any) in an extended
/// quiescent state. Every potentially-blocking wait inside the crate
/// (`synchronize_rcu`, `rcu_barrier`, lock acquisition in rebuild) funnels
/// through this so a registered caller can never stall someone else's
/// grace period while it blocks.
pub(crate) fn with_current_offline<R>(f: impl FnOnce() -> R) -> R {
    let cur = CURRENT.with(|c| c.get());
    if cur.is_null() {
        return f();
    }
    // SAFETY: the record outlives the RcuThread guard that set CURRENT and
    // the guard clears CURRENT on drop, so `cur` is valid here.
    let rec = unsafe { &*cur };
    // ord: qsbr-handshake — gp/ctr grace-period handshake
    let prev = rec.ctr.swap(0, Ordering::AcqRel);
    let r = f();
    if prev != 0 {
        // ord: qsbr-handshake — gp/ctr grace-period handshake
        rec.ctr
            .store(GLOBAL.gp.load(Ordering::Acquire), Ordering::Release);
    }
    r
}

/// Wait for a grace period on the global domain.
///
/// Must **not** be called from inside a read-side critical section (it
/// would deadlock against itself); a registered caller is treated as
/// passing through an extended quiescent state for the duration.
pub fn synchronize_rcu() {
    with_current_offline(|| GLOBAL.synchronize(None));
}

/// A per-thread RCU registration (QSBR). Obtain one with
/// [`RcuThread::register`]; all hash-table operations take `&RcuThread` as
/// compile-time proof the calling thread participates in grace periods.
///
/// Not `Send`: the registration is bound to the OS thread that created it.
pub struct RcuThread {
    domain: &'static RcuDomain,
    rec: Arc<ThreadRecord>,
    /// Read-side nesting depth (guards are re-entrant, like liburcu).
    depth: Cell<u32>,
    _not_send: PhantomData<*const ()>,
}

impl RcuThread {
    /// Register the calling thread with the global domain.
    pub fn register() -> Self {
        let t = global().register();
        CURRENT.with(|c| c.set(Arc::as_ptr(&t.rec)));
        t
    }

    /// Enter a read-side critical section. Zero instructions under QSBR —
    /// the guard only tracks nesting so [`quiescent_state`] can assert it
    /// is not called with a section open (a debug build check).
    ///
    /// [`quiescent_state`]: RcuThread::quiescent_state
    // lint: hot
    #[inline(always)]
    pub fn read_lock(&self) -> RcuReadGuard<'_> {
        self.depth.set(self.depth.get() + 1);
        RcuReadGuard { owner: self }
    }

    /// Announce a quiescent state: the thread holds no RCU-protected
    /// references. Cost: one load + one store.
    ///
    /// Acquire on `gp` + Release on `ctr`: storing the *acquired* gp value
    /// is what proves to the waiter that this thread has seen the
    /// publications preceding that grace period (module docs).
    // lint: hot
    #[inline(always)]
    pub fn quiescent_state(&self) {
        debug_assert_eq!(
            self.depth.get(),
            0,
            "quiescent_state inside a read-side critical section"
        );
        // ord: qsbr-handshake — gp/ctr grace-period handshake
        self.rec
            .ctr
            .store(self.domain.gp.load(Ordering::Acquire), Ordering::Release);
    }

    /// Enter an extended quiescent state (e.g. before blocking). Release
    /// publishes the preceding section before waiters may free.
    #[inline]
    pub fn offline(&self) {
        debug_assert_eq!(self.depth.get(), 0, "offline inside a read-side section");
        // ord: qsbr-handshake — gp/ctr grace-period handshake
        self.rec.ctr.store(0, Ordering::Release);
    }

    /// Leave the extended quiescent state.
    #[inline]
    pub fn online(&self) {
        // ord: qsbr-handshake — gp/ctr grace-period handshake
        self.rec
            .ctr
            .store(self.domain.gp.load(Ordering::Acquire), Ordering::Release);
    }

    /// Run `f` while offline (for blocking operations such as lock
    /// acquisition or I/O), restoring the online state afterwards.
    pub fn offline_while<R>(&self, f: impl FnOnce() -> R) -> R {
        self.offline();
        let r = f();
        self.online();
        r
    }

    /// `synchronize_rcu` with this thread exempted (equivalent to the free
    /// function, but skips the thread-local probe).
    pub fn synchronize(&self) {
        self.domain.synchronize(Some(self));
    }
}

impl Drop for RcuThread {
    fn drop(&mut self) {
        CURRENT.with(|c| c.set(std::ptr::null()));
        self.domain.deregister(&self.rec);
    }
}

/// Marker guard for a QSBR read-side critical section (no runtime effect
/// beyond nesting accounting; reclamation is prevented by the *absence* of
/// quiescent-state announcements, not by this guard).
pub struct RcuReadGuard<'a> {
    owner: &'a RcuThread,
}

impl Drop for RcuReadGuard<'_> {
    #[inline(always)]
    fn drop(&mut self) {
        self.owner.depth.set(self.owner.depth.get() - 1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Satellite regression: a grace period with no stalled reader must
    /// complete without ever reaching the sleep tier of the backoff.
    /// Isolated (leaked) domain: the global domain's readers from parallel
    /// tests could legitimately force sleeps here.
    #[test]
    fn no_reader_grace_period_never_sleeps() {
        // Miri runs the interpreter ~100x slower; 8 grace periods still
        // cover every branch of the no-sleep path.
        let rounds = crate::util::miri_clamp(64, 8);
        let dom: &'static RcuDomain = Box::leak(Box::new(RcuDomain::new()));
        for _ in 0..rounds {
            dom.synchronize(None);
        }
        assert_eq!(dom.sleep_count(), 0, "no-reader grace period slept");

        // A registered caller is exempted from its own grace period, so a
        // single-threaded writer must also stay on the no-sleep path.
        let t = dom.register();
        t.quiescent_state();
        for _ in 0..rounds {
            dom.synchronize(Some(&t));
        }
        assert_eq!(dom.sleep_count(), 0, "self-exempted grace period slept");

        // An offline reader (ctr == 0) must not delay the grace period.
        let r2 = dom.register();
        r2.offline();
        for _ in 0..rounds {
            dom.synchronize(Some(&t));
        }
        assert_eq!(dom.sleep_count(), 0, "offline reader forced a sleep");
    }

    /// The backoff escalates (and is counted) when a reader genuinely
    /// stalls: a reader that announces quiescence only after a delay must
    /// eventually push the waiter into the sleep tier, and the grace
    /// period still completes.
    #[test]
    fn stalled_reader_escalates_to_sleep() {
        let dom: &'static RcuDomain = Box::leak(Box::new(RcuDomain::new()));
        let writer = dom.register();
        writer.quiescent_state();

        let barrier = std::sync::Arc::new(std::sync::Barrier::new(2));
        let b2 = barrier.clone();
        let reader = std::thread::spawn(move || {
            let t = dom.register();
            t.quiescent_state();
            b2.wait();
            // Hold the section open long enough to exhaust spin + yield.
            std::thread::sleep(std::time::Duration::from_millis(20));
            t.quiescent_state();
            // Park until the writer is done so the record stays registered.
            b2.wait();
        });

        barrier.wait();
        dom.synchronize(Some(&writer));
        assert!(dom.sleep_count() > 0, "20ms-stalled reader never slept");
        barrier.wait();
        reader.join().unwrap();
    }
}
