//! Userspace Read-Copy-Update, QSBR flavor — built from scratch.
//!
//! The paper uses liburcu's QSBR model (§4.1): read-side critical sections
//! cost *zero* instructions because every registered thread is assumed to
//! be inside a read-side critical section at all times, except when it
//! explicitly announces a *quiescent state* (or goes *offline*). Writers
//! wait for a grace period with [`synchronize_rcu`]; deferred reclamation
//! uses [`call_rcu`] serviced by a background reclaimer thread.
//!
//! ## Protocol
//!
//! * A global grace-period counter `GP` starts at 1 and is bumped by each
//!   `synchronize_rcu`.
//! * Each registered thread owns a record with a counter `ctr`:
//!   - `ctr == 0` — thread is **offline** (not in any read-side section);
//!   - `ctr == g` — thread last announced a quiescent state when `GP == g`.
//! * `synchronize_rcu` bumps `GP` to `g+1` and waits until every record
//!   has `ctr == 0 || ctr >= g+1`: every thread has either gone offline or
//!   passed through a quiescent state after the bump, so no reader can
//!   still hold a reference obtained before it.
//!
//! The `ctr`/`GP` protocol accesses use acquire/release pairs, not
//! `SeqCst`: a quiescent-state announcement stores the *acquired* `GP`
//! value into `ctr` with `Release`, so a waiter that observes
//! `ctr >= g+1` with `Acquire` knows the reader both finished its prior
//! section (Release→Acquire on `ctr`) and saw every publication that
//! preceded the bump (the stored value proves the reader's `Acquire`
//! load of `GP` synchronized with the `AcqRel` bump). Per-site rationale
//! lives in `qsbr.rs` and DESIGN.md §Memory orderings.
//!
//! ## Usage
//!
//! ```no_run
//! use dhash::rcu::{RcuThread, synchronize_rcu};
//! let t = RcuThread::register();
//! {
//!     let _g = t.read_lock();       // zero-cost marker (QSBR)
//!     // ... access RCU-protected data ...
//! }
//! t.quiescent_state();              // announce: no references held
//! synchronize_rcu();                // writer-side: wait for all readers
//! ```

mod callback;
mod qsbr;

pub use callback::{call_rcu, rcu_barrier, reclaimer_stats};
pub use qsbr::{synchronize_rcu, RcuDomain, RcuReadGuard, RcuThread};

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
    use std::sync::Arc;

    #[test]
    fn synchronize_with_no_readers_completes() {
        synchronize_rcu();
        synchronize_rcu();
    }

    #[test]
    fn synchronize_from_registered_thread_completes() {
        // The caller is itself registered and "online": synchronize_rcu
        // must not wait for its own record.
        let t = RcuThread::register();
        t.quiescent_state();
        synchronize_rcu();
        drop(t);
    }

    #[test]
    fn grace_period_waits_for_reader() {
        // A reader holding a read-side section delays the grace period
        // until it announces a quiescent state.
        let release = Arc::new(AtomicBool::new(false));
        let entered = Arc::new(AtomicBool::new(false));
        let r2 = release.clone();
        let e2 = entered.clone();
        let reader = std::thread::spawn(move || {
            let t = RcuThread::register();
            let _g = t.read_lock();
            e2.store(true, Ordering::SeqCst);
            while !r2.load(Ordering::SeqCst) {
                std::hint::spin_loop();
                std::thread::yield_now();
            }
            drop(_g);
            t.quiescent_state();
            // Stay registered a little so deregistration doesn't mask a bug
            // where synchronize only completes because the vec emptied.
            std::thread::sleep(std::time::Duration::from_millis(5));
        });
        while !entered.load(Ordering::SeqCst) {
            std::thread::yield_now();
        }
        let sync_done = Arc::new(AtomicBool::new(false));
        let sd2 = sync_done.clone();
        let writer = std::thread::spawn(move || {
            synchronize_rcu();
            sd2.store(true, Ordering::SeqCst);
        });
        // Writer must be blocked while the reader is inside its section.
        std::thread::sleep(std::time::Duration::from_millis(30));
        assert!(
            !sync_done.load(Ordering::SeqCst),
            "synchronize_rcu returned while a reader was active"
        );
        release.store(true, Ordering::SeqCst);
        reader.join().unwrap();
        writer.join().unwrap();
        assert!(sync_done.load(Ordering::SeqCst));
    }

    #[test]
    fn offline_readers_do_not_block() {
        let t = RcuThread::register();
        t.offline();
        // While offline, grace periods must pass instantly even though the
        // record exists.
        synchronize_rcu();
        t.online();
        t.quiescent_state();
    }

    #[test]
    fn offline_while_runs_closure_and_restores() {
        let t = RcuThread::register();
        let x = t.offline_while(|| 21 * 2);
        assert_eq!(x, 42);
        // Must be back online: a subsequent quiescent announcement works.
        t.quiescent_state();
    }

    #[test]
    fn call_rcu_runs_callback_after_grace_period() {
        static RAN: AtomicU64 = AtomicU64::new(0);
        let n0 = RAN.load(Ordering::SeqCst);
        call_rcu(move || {
            RAN.fetch_add(1, Ordering::SeqCst);
        });
        rcu_barrier();
        assert!(RAN.load(Ordering::SeqCst) > n0);
    }

    #[test]
    fn call_rcu_defers_past_active_reader() {
        let freed = Arc::new(AtomicBool::new(false));
        let release = Arc::new(AtomicBool::new(false));
        let entered = Arc::new(AtomicBool::new(false));
        let (f2, r2, e2) = (freed.clone(), release.clone(), entered.clone());
        let reader = std::thread::spawn(move || {
            let t = RcuThread::register();
            let _g = t.read_lock();
            e2.store(true, Ordering::SeqCst);
            while !r2.load(Ordering::SeqCst) {
                std::thread::yield_now();
            }
            // Callback must not have run while we were inside the section.
            assert!(!f2.load(Ordering::SeqCst), "reclaimed under a reader");
            drop(_g);
            t.quiescent_state();
        });
        while !entered.load(Ordering::SeqCst) {
            std::thread::yield_now();
        }
        let fcb = freed.clone();
        call_rcu(move || fcb.store(true, Ordering::SeqCst));
        std::thread::sleep(std::time::Duration::from_millis(30));
        release.store(true, Ordering::SeqCst);
        reader.join().unwrap();
        rcu_barrier();
        assert!(freed.load(Ordering::SeqCst));
    }

    #[test]
    fn many_threads_stress() {
        // 8 readers hammering quiescent states while a writer runs
        // synchronize_rcu repeatedly: exercises GP counter races.
        let stop = Arc::new(AtomicBool::new(false));
        let mut handles = Vec::new();
        for _ in 0..8 {
            let s = stop.clone();
            handles.push(std::thread::spawn(move || {
                let t = RcuThread::register();
                let mut iters = 0u64;
                while !s.load(Ordering::SeqCst) {
                    let _g = t.read_lock();
                    std::hint::black_box(iters);
                    drop(_g);
                    t.quiescent_state();
                    iters += 1;
                }
                iters
            }));
        }
        for _ in 0..50 {
            synchronize_rcu();
        }
        stop.store(true, Ordering::SeqCst);
        for h in handles {
            assert!(h.join().unwrap() > 0);
        }
    }

    #[test]
    fn registration_is_reusable_across_threads_lifetimes() {
        for _ in 0..20 {
            let h = std::thread::spawn(|| {
                let t = RcuThread::register();
                t.quiescent_state();
            });
            h.join().unwrap();
        }
        synchronize_rcu();
    }
}
