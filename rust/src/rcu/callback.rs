//! `call_rcu`: deferred execution after a grace period, serviced by a
//! lazily-spawned background reclaimer thread (the paper's delete path
//! must not block on prior readers — §4.1 "(3) To reclaim a node, call_rcu
//! is used, such that a delete operation will not be blocked").

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Sender};
use std::sync::Mutex;
use std::time::Duration;

use once_cell::sync::Lazy;

type Callback = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Callback),
    /// Barrier: reply on the channel once every callback enqueued before
    /// this marker has executed.
    Flush(Sender<()>),
}

thread_local! {
    /// Per-thread clone of the reclaimer sender: call_rcu is on the
    /// delete hot path, and going through the global mutex on every call
    /// serializes all deleters (§Perf opt 3).
    static TLS_TX: std::cell::OnceCell<Sender<Msg>> = const { std::cell::OnceCell::new() };
}

fn with_sender<R>(f: impl FnOnce(&Sender<Msg>) -> R) -> R {
    TLS_TX.with(|c| f(c.get_or_init(|| QUEUE.lock().unwrap().clone()))) // lock: rcu-queue
}

static QUEUE: Lazy<Mutex<Sender<Msg>>> = Lazy::new(|| {
    let (tx, rx) = mpsc::channel::<Msg>();
    std::thread::Builder::new()
        .name("rcu-reclaimer".into())
        .spawn(move || {
            let mut pending: Vec<Msg> = Vec::new();
            loop {
                // Block for the first message, then drain opportunistically
                // so one grace period amortizes over a batch of callbacks.
                match rx.recv() {
                    Ok(m) => pending.push(m),
                    Err(_) => break, // all senders gone: process exit
                }
                while let Ok(m) = rx.try_recv() {
                    pending.push(m);
                    if pending.len() >= 4096 {
                        break;
                    }
                }
                // Give very recent enqueuers a moment to batch up.
                std::thread::sleep(Duration::from_micros(100));
                while let Ok(m) = rx.try_recv() {
                    pending.push(m);
                    if pending.len() >= 8192 {
                        break;
                    }
                }
                super::qsbr::global().synchronize(None);
                // ord: stats-relaxed — monotonic counter, no ordering role
                GRACE_PERIODS.fetch_add(1, Ordering::Relaxed);
                for m in pending.drain(..) {
                    match m {
                        Msg::Run(cb) => {
                            cb();
                            // ord: stats-relaxed — monotonic counter, no ordering role
                            EXECUTED.fetch_add(1, Ordering::Relaxed);
                        }
                        Msg::Flush(tx) => {
                            let _ = tx.send(());
                        }
                    }
                }
            }
        })
        .expect("spawn rcu-reclaimer");
    Mutex::new(tx)
});

static ENQUEUED: AtomicU64 = AtomicU64::new(0);
static EXECUTED: AtomicU64 = AtomicU64::new(0);
static GRACE_PERIODS: AtomicU64 = AtomicU64::new(0);

/// Schedule `f` to run after a future grace period. Never blocks (beyond a
/// channel send); safe to call from inside a read-side critical section.
pub fn call_rcu(f: impl FnOnce() + Send + 'static) {
    // ord: stats-relaxed — monotonic counter, no ordering role
    ENQUEUED.fetch_add(1, Ordering::Relaxed);
    with_sender(|tx| tx.send(Msg::Run(Box::new(f)))).expect("rcu-reclaimer alive");
}

/// Wait until every callback enqueued *before* this call has executed
/// (liburcu's `rcu_barrier`). Used by tests and orderly shutdown.
///
/// A registered caller is placed in an extended quiescent state for the
/// wait — the reclaimer runs `synchronize` internally and would otherwise
/// deadlock against a blocked-but-online caller.
pub fn rcu_barrier() {
    super::qsbr::with_current_offline(|| {
        let (tx, rx) = mpsc::channel();
        with_sender(|q| q.send(Msg::Flush(tx))).expect("rcu-reclaimer alive");
        rx.recv().expect("rcu-reclaimer alive");
    })
}

/// (enqueued, executed, grace_periods) counters for observability tests
/// and the coordinator's metrics endpoint.
pub fn reclaimer_stats() -> (u64, u64, u64) {
    // ord: stats-relaxed — monotonic counter, no ordering role
    (
        ENQUEUED.load(Ordering::Relaxed),
        EXECUTED.load(Ordering::Relaxed),
        GRACE_PERIODS.load(Ordering::Relaxed),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::sync::Arc;

    #[test]
    fn barrier_flushes_all_prior_callbacks() {
        let n = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let n2 = n.clone();
            call_rcu(move || {
                n2.fetch_add(1, Ordering::SeqCst);
            });
        }
        rcu_barrier();
        assert_eq!(n.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn stats_monotonic() {
        let (e0, x0, _) = reclaimer_stats();
        call_rcu(|| {});
        rcu_barrier();
        let (e1, x1, g1) = reclaimer_stats();
        assert!(e1 > e0);
        assert!(x1 > x0);
        assert!(g1 >= 1);
    }

    #[test]
    fn callbacks_from_many_threads() {
        let n = Arc::new(AtomicUsize::new(0));
        let mut hs = Vec::new();
        for _ in 0..4 {
            let n2 = n.clone();
            hs.push(std::thread::spawn(move || {
                for _ in 0..250 {
                    let n3 = n2.clone();
                    call_rcu(move || {
                        n3.fetch_add(1, Ordering::SeqCst);
                    });
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        rcu_barrier();
        assert_eq!(n.load(Ordering::SeqCst), 1000);
    }
}
