//! Rule `reclaim`: every raw-pointer free in the concurrency core is
//! annotated with its reclamation class, pairs with an allocation
//! site, and is unreachable from shared-`&self` operations.
//!
//! ## Annotation grammar
//!
//! ```text
//! // reclaim: <key>                         (Box::into_raw site)
//! // reclaim: <key> via <class>             (free site / call site)
//! ```
//!
//! `<key>` is `[a-z0-9-]+` and names a row of DESIGN.md §Reclamation
//! contract (as a backticked `reclaim:<key>` token). `<class>` says
//! why the free cannot race a reader:
//!
//! | class        | valid on                  | locally checked as |
//! |--------------|---------------------------|--------------------|
//! | `rcu`        | free site                 | inside a `call_rcu(…)` argument (runs after a grace period) |
//! | `grace`      | free site or call site    | a `synchronize` token earlier in the same fn (QSBR waiter) |
//! | `exclusive`  | free site                 | enclosing fn takes `&mut self` / `mut self`, or is `fn drop` |
//! | `contract`   | free site                 | enclosing fn is `unsafe fn` — the obligation moves to call sites |
//! | `unpublished`| call site                 | the pointer never escaped; justification is the annotation text |
//!
//! ## Flow pass
//!
//! A fn containing a `contract`-class free is *contract-freeing*. Any
//! non-deferred call edge to a contract-freeing fn must be discharged:
//! the caller takes `&mut self` (or is `fn drop`), or the call line
//! carries `// reclaim: <key> via unpublished|grace`, or the caller is
//! itself an `unsafe fn` (obligation propagates outward). A plain
//! shared-`&self` fn reaching a free site any other way is the finding
//! this rule exists for.
//!
//! ## Pairing and index agreement
//!
//! Every key needs at least one `Box::into_raw` site and one free
//! site; the key set must equal the `reclaim:<key>` tokens in
//! DESIGN.md §Reclamation contract (both-ways drift).
//!
//! ## Scope
//!
//! Production code in `rust/src/{dhash,lflist,rcu}` — the baselines
//! and the serving layer hold no shared-reclamation contract.

use std::collections::{BTreeMap, BTreeSet};

use super::scan::{self, SourceFile};
use super::{flow, Diagnostic, LintContext};

pub const DESIGN_SECTION: &str = "## Reclamation contract";

const SCOPE: &[&str] = &["rust/src/dhash/", "rust/src/lflist/", "rust/src/rcu/"];

const FREE_TOKENS: &[&str] = &["Box::from_raw", "drop_in_place"];
const ALLOC_TOKEN: &str = "Box::into_raw";

fn in_scope(path: &str) -> bool {
    SCOPE.iter().any(|p| path.starts_with(p))
}

/// Parsed `reclaim:` annotation: key plus optional `via <class>`.
fn site_annot(file: &SourceFile, idx: usize) -> Option<(String, Option<String>)> {
    let parse = |comment: &str| -> Option<(String, Option<String>)> {
        let key = scan::extract_marked_key(comment, "reclaim:")?;
        let after = comment.split("reclaim:").nth(1).unwrap_or("");
        let class = after
            .trim_start()
            .strip_prefix(&key)
            .and_then(|rest| rest.trim_start().strip_prefix("via "))
            .map(|rest| {
                rest.chars()
                    .take_while(|c| c.is_ascii_lowercase() || *c == '-')
                    .collect::<String>()
            })
            .filter(|c| !c.is_empty());
        Some((key, class))
    };
    if let Some(found) = parse(&file.lines[idx].comment) {
        return Some(found);
    }
    let mut j = idx;
    while j > 0 && idx - j < 2 {
        let above = &file.lines[j - 1];
        if !above.code.trim().is_empty() || above.comment.is_empty() {
            break;
        }
        if let Some(found) = parse(&above.comment) {
            return Some(found);
        }
        j -= 1;
    }
    None
}

pub fn check(ctx: &LintContext) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    let graph = flow::CallGraph::build(ctx);

    // key → (has alloc site, has free site, first (file, line)).
    let mut keys: BTreeMap<String, (bool, bool, String, usize)> = BTreeMap::new();
    let mut note = |keys: &mut BTreeMap<String, (bool, bool, String, usize)>,
                    key: &str,
                    alloc: bool,
                    file: &str,
                    line: usize| {
        let e = keys
            .entry(key.to_string())
            .or_insert((false, false, file.to_string(), line));
        if alloc {
            e.0 = true;
        } else {
            e.1 = true;
        }
    };

    // Contract-freeing node ids, and annotated call-site exemptions.
    let mut contract_freeing: BTreeSet<usize> = BTreeSet::new();

    for (fidx, file) in ctx.files.iter().enumerate() {
        if !in_scope(&file.path) || file.test_only {
            continue;
        }
        let extents = scan::fn_extents(file);
        let deferred = flow::deferred_lines(file);
        for (idx, line) in file.lines.iter().enumerate() {
            if line.in_test {
                continue;
            }
            let code = &line.code;
            let is_free = FREE_TOKENS.iter().any(|t| code.contains(t));
            let is_alloc = code.contains(ALLOC_TOKEN);
            if !is_free && !is_alloc {
                continue;
            }
            let annot = site_annot(file, idx);
            if is_alloc {
                match &annot {
                    Some((key, _)) => note(&mut keys, key, true, &file.path, idx + 1),
                    None => out.push(Diagnostic::new(
                        &file.path,
                        idx + 1,
                        "reclaim",
                        "Box::into_raw without a // reclaim: <key> annotation (see DESIGN.md §Reclamation contract)"
                            .to_string(),
                    )),
                }
            }
            if is_free {
                let Some((key, Some(class))) = &annot else {
                    out.push(Diagnostic::new(
                        &file.path,
                        idx + 1,
                        "reclaim",
                        "free site without a // reclaim: <key> via <class> annotation (see DESIGN.md §Reclamation contract)"
                            .to_string(),
                    ));
                    continue;
                };
                note(&mut keys, key, false, &file.path, idx + 1);
                let owner = scan::innermost_extent(&extents, idx);
                let ok = match class.as_str() {
                    "rcu" => deferred[idx],
                    "grace" => owner.is_some_and(|o| {
                        (extents[o].start..idx)
                            .any(|j| file.lines[j].code.contains("synchronize"))
                    }),
                    "exclusive" => owner.is_some_and(|o| {
                        extents[o].exclusive_self || extents[o].name == "drop"
                    }),
                    "contract" => owner.is_some_and(|o| extents[o].is_unsafe),
                    other => {
                        out.push(Diagnostic::new(
                            &file.path,
                            idx + 1,
                            "reclaim",
                            format!("unknown reclamation class '{other}' (rcu|grace|exclusive|contract)"),
                        ));
                        continue;
                    }
                };
                if !ok {
                    out.push(Diagnostic::new(
                        &file.path,
                        idx + 1,
                        "reclaim",
                        format!("free site claims class '{class}' but the path does not support it"),
                    ));
                }
                if class == "contract" {
                    if let Some(o) = owner {
                        if let Some(nid) = graph.nodes.iter().position(|n| {
                            n.file == fidx && n.extent.start == extents[o].start
                        }) {
                            contract_freeing.insert(nid);
                        }
                    }
                }
            }
        }
    }

    // Flow pass: discharge every call edge into a contract-freeing fn.
    // Propagation: an `unsafe fn` caller re-exports the obligation.
    let mut frontier: Vec<usize> = contract_freeing.iter().copied().collect();
    while let Some(target) = frontier.pop() {
        let target_name = graph.nodes[target].extent.name.clone();
        for (nid, node) in graph.nodes.iter().enumerate() {
            let file = &ctx.files[node.file];
            if !in_scope(&file.path) || file.test_only {
                continue;
            }
            for call in &node.calls {
                if call.deferred || call.in_test || call.name != target_name {
                    continue;
                }
                if !graph.resolve(&call.name).contains(&target) {
                    continue;
                }
                // Discharged by an exclusive receiver or Drop.
                if node.extent.exclusive_self || node.extent.name == "drop" {
                    continue;
                }
                // Discharged by a call-site annotation.
                if let Some((_key, class)) = site_annot(file, call.line) {
                    match class.as_deref() {
                        Some("unpublished") => continue,
                        Some("grace") => {
                            let ok = (node.extent.start..call.line)
                                .any(|j| file.lines[j].code.contains("synchronize"));
                            if ok {
                                continue;
                            }
                            out.push(Diagnostic::new(
                                &file.path,
                                call.line + 1,
                                "reclaim",
                                "call-site claims class 'grace' but no synchronize precedes it in this fn"
                                    .to_string(),
                            ));
                            continue;
                        }
                        _ => {
                            out.push(Diagnostic::new(
                                &file.path,
                                call.line + 1,
                                "reclaim",
                                "call into a freeing fn needs // reclaim: <key> via unpublished|grace"
                                    .to_string(),
                            ));
                            continue;
                        }
                    }
                }
                // Propagate through unsafe fns (obligation re-exported
                // to *their* call sites), unless shared-&self — a
                // shared receiver is exactly the path this rule bans.
                if node.extent.shared_self {
                    out.push(Diagnostic::new(
                        &file.path,
                        call.line + 1,
                        "reclaim",
                        format!(
                            "shared-&self fn '{}' reaches free site via '{target_name}' — annotate the call (// reclaim: <key> via unpublished|grace) or restructure",
                            node.extent.name
                        ),
                    ));
                } else if contract_freeing.insert(nid) {
                    frontier.push(nid);
                }
            }
        }
    }

    // Pairing.
    for (key, (has_alloc, has_free, file, line)) in &keys {
        if !has_alloc {
            out.push(Diagnostic::new(
                file,
                *line,
                "reclaim",
                format!("reclaim key '{key}' has free sites but no Box::into_raw site"),
            ));
        }
        if !has_free {
            out.push(Diagnostic::new(
                file,
                *line,
                "reclaim",
                format!("reclaim key '{key}' has alloc sites but no annotated free site"),
            ));
        }
    }

    // DESIGN.md §Reclamation contract: both-ways drift.
    let table = super::design_marked_keys(&ctx.design_md, DESIGN_SECTION, "reclaim:");
    for (key, (_, _, file, line)) in &keys {
        if !table.contains_key(key) {
            out.push(Diagnostic::new(
                file,
                *line,
                "reclaim",
                format!("reclaim key '{key}' is not indexed in DESIGN.md {DESIGN_SECTION}"),
            ));
        }
    }
    for (key, line) in &table {
        if !keys.contains_key(key) {
            out.push(Diagnostic::new(
                "rust/DESIGN.md",
                *line,
                "reclaim",
                format!(
                    "DESIGN.md {DESIGN_SECTION} indexes reclaim key '{key}' but no source site uses it"
                ),
            ));
        }
    }

    out.sort();
    out.dedup();
    out
}
