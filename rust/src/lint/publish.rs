//! Rule `publish`: the hazard/epoch publication protocols are ordered
//! token sequences inside tagged fns — a refactor that reorders the
//! hazard clear past the unlink, or installs the directory pointer
//! before its mirrors, fails lint instead of a torture run.
//!
//! ## Tag grammar
//!
//! ```text
//! // lint: publish <protocol>
//! ```
//!
//! A standalone comment line directly above the protocol fn (doc
//! comments and attributes may sit between). Each protocol names an
//! ordered list of code tokens; the fn body — whitespace-stripped and
//! joined, so multi-line statements match — must contain every token,
//! and their *first occurrences* must appear in protocol order.
//!
//! ## Protocols
//!
//! * `rebuild` — DHashMap table swap: candidate published in `ht_new`
//!   → grace barrier → `rebuild_cur` hazard publish before logical
//!   delete → hazard clear after re-insert → `cur` swap → free the
//!   superseded table (Lemma 4.1 shape).
//! * `drain` — per-node migration: pop under callback → `moving`
//!   hazard publish → hazard clear → deferred free of the duplicate
//!   path.
//! * `install-dir` — mirrors-first directory install: `nshards`, then
//!   `cur_epoch`, then the `dir` pointer last, so readers that load
//!   the new pointer see consistent mirrors.
//! * `resize` — shard split/merge: migration token → intermediate
//!   directory install → grace barrier → drain → free superseded
//!   directories.

use super::scan;
use super::{Diagnostic, LintContext};

pub const TAG_PREFIX: &str = "// lint: publish ";

/// protocol → ordered (whitespace-stripped token, step description).
pub const PROTOCOLS: &[(&str, &[(&str, &str)])] = &[
    (
        "rebuild",
        &[
            ("rebuild_lock.try_lock(", "serialize rebuilds"),
            ("ht_new.store(", "publish the candidate table"),
            ("offline_while(synchronize_rcu)", "grace barrier"),
            ("rebuild_cur.store(cand", "hazard publish before logical delete"),
            ("rebuild_cur.store(std::ptr::null_mut(", "hazard clear after re-insert"),
            ("self.cur.store(", "table swap"),
            ("Box::from_raw(", "free the superseded table"),
        ],
    ),
    (
        "drain",
        &[
            ("take_first_for_distribution(", "pop under the hazard callback"),
            ("moving.store(cand", "hazard publish before logical delete"),
            ("moving.store(std::ptr::null_mut(", "hazard clear after re-insert"),
            ("Node::defer_free(", "deferred free of the duplicate path"),
        ],
    ),
    (
        "install-dir",
        &[
            ("nshards.store(", "mirror: shard count"),
            ("cur_epoch.store(", "mirror: epoch"),
            ("dir.store(", "directory pointer last"),
        ],
    ),
    (
        "resize",
        &[
            ("migration_token.try_lock(", "one migration in flight"),
            ("install_dir(", "install the intermediate directory"),
            ("offline_while(synchronize_rcu)", "grace barrier"),
            ("drain_into(", "drain via the moving hazard"),
            ("Box::from_raw(", "free the superseded directories"),
        ],
    ),
];

pub fn check(ctx: &LintContext) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    for file in &ctx.files {
        for (idx, line) in file.lines.iter().enumerate() {
            let comment = line.comment.trim();
            let Some(proto_name) = comment.strip_prefix(TAG_PREFIX) else {
                continue;
            };
            let proto_name = proto_name.trim();
            let Some((_, steps)) = PROTOCOLS.iter().find(|(n, _)| *n == proto_name) else {
                out.push(Diagnostic::new(
                    &file.path,
                    idx + 1,
                    "publish",
                    format!("unknown publication protocol '{proto_name}'"),
                ));
                continue;
            };
            // Locate the tagged fn (hot-style: within a few lines).
            let mut fn_line = None;
            for j in idx..(idx + 7).min(file.lines.len()) {
                if scan::has_word(&file.lines[j].code, "fn") {
                    fn_line = Some(j);
                    break;
                }
            }
            let Some(start) = fn_line else {
                out.push(Diagnostic::new(
                    &file.path,
                    idx + 1,
                    "publish",
                    format!("// lint: publish {proto_name} tag with no fn following it"),
                ));
                continue;
            };
            let name: String = file.lines[start]
                .code
                .split("fn ")
                .nth(1)
                .unwrap_or("")
                .chars()
                .take_while(|c| c.is_alphanumeric() || *c == '_')
                .collect();
            let end = scan::brace_match(file, start).unwrap_or(file.lines.len() - 1);
            // Whitespace-stripped body with a char-offset → line map.
            let mut body = String::new();
            let mut line_at: Vec<usize> = Vec::new();
            for j in start..=end {
                for c in file.lines[j].code.chars() {
                    if !c.is_whitespace() {
                        body.push(c);
                        line_at.push(j);
                    }
                }
            }
            let mut last_pos: Option<usize> = None;
            let mut last_step = "";
            for &(token, step) in *steps {
                let stripped: String = token.chars().filter(|c| !c.is_whitespace()).collect();
                match body.find(&stripped) {
                    None => out.push(Diagnostic::new(
                        &file.path,
                        start + 1,
                        "publish",
                        format!(
                            "fn '{name}' (protocol '{proto_name}') is missing step '{step}' (token `{token}`)"
                        ),
                    )),
                    Some(pos) => {
                        if let Some(prev) = last_pos {
                            if pos < prev {
                                out.push(Diagnostic::new(
                                    &file.path,
                                    line_at[pos] + 1,
                                    "publish",
                                    format!(
                                        "fn '{name}' (protocol '{proto_name}') performs step '{step}' before step '{last_step}' — protocol order is violated"
                                    ),
                                ));
                            }
                        }
                        if last_pos.map_or(true, |prev| pos > prev) {
                            last_pos = Some(pos);
                            last_step = step;
                        }
                    }
                }
            }
        }
    }
    out
}
