//! Rule `seqcst-budget`: per-file `Ordering::SeqCst` counts in the
//! concurrency core equal `tools/seqcst_allowlist.txt`.
//!
//! Subsumes the old `tools/check_seqcst.sh` grep (the script survives
//! as a thin wrapper that execs this rule). Semantics are the script's,
//! with one upgrade: occurrences are counted on comment-stripped code,
//! so *mentioning* SeqCst in a comment costs no budget. Drift in either
//! direction fails — a new site needs a budget line (and a DESIGN.md
//! §Memory orderings row), a removed site must prune its budget so the
//! allowlist never pads headroom.

use std::collections::BTreeMap;

use super::{Diagnostic, LintContext};

const NEEDLE: &str = "Ordering::SeqCst";

pub fn check(ctx: &LintContext) -> Vec<Diagnostic> {
    let mut out = Vec::new();

    // path → (budget, allowlist line).
    let mut want: BTreeMap<&str, (usize, usize)> = BTreeMap::new();
    for (idx, line) in ctx.allowlist.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_whitespace();
        match (parts.next(), parts.next().and_then(|c| c.parse::<usize>().ok())) {
            (Some(path), Some(count)) => {
                want.insert(path, (count, idx + 1));
            }
            _ => out.push(Diagnostic::new(
                "tools/seqcst_allowlist.txt",
                idx + 1,
                "seqcst-budget",
                format!("unparseable allowlist entry '{line}' (want '<path> <count>')"),
            )),
        }
    }

    // Count code-text occurrences per core file (tests included — the
    // test-local flags are budgeted too).
    let mut got: BTreeMap<&str, (usize, usize)> = BTreeMap::new();
    for file in ctx.core_files() {
        let mut count = 0;
        let mut first = 0;
        for (idx, line) in file.lines.iter().enumerate() {
            let mut rest = line.code.as_str();
            while let Some(pos) = rest.find(NEEDLE) {
                count += 1;
                if first == 0 {
                    first = idx + 1;
                }
                rest = &rest[pos + NEEDLE.len()..];
            }
        }
        if count > 0 {
            got.insert(&file.path, (count, first));
        }
    }

    for (path, (count, first)) in &got {
        match want.get(path) {
            None => out.push(Diagnostic::new(
                path,
                *first,
                "seqcst-budget",
                format!(
                    "{count} SeqCst site(s) but no budget in tools/seqcst_allowlist.txt"
                ),
            )),
            Some((budget, _)) if budget != count => out.push(Diagnostic::new(
                path,
                *first,
                "seqcst-budget",
                format!("{count} SeqCst site(s); allowlist budgets {budget}"),
            )),
            Some(_) => {}
        }
    }
    for (path, (budget, line)) in &want {
        if !got.contains_key(path) {
            out.push(Diagnostic::new(
                "tools/seqcst_allowlist.txt",
                *line,
                "seqcst-budget",
                format!("{path} is budgeted ({budget}) but has no SeqCst sites — prune the entry"),
            ));
        }
    }
    out
}
