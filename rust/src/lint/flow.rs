//! Intra-crate call graph over [`scan::fn_extents`] — the shared flow
//! layer under the `lock-order` and `reclaim` rules.
//!
//! ## Resolution model (deliberately name-based)
//!
//! There is no type inference here. A call site resolves to *every*
//! function in the crate with the callee's name — an over-approximation
//! that is sound for "can this path reach a lock/free?" questions as
//! long as the name is specific. Two heuristics keep the
//! over-approximation from drowning the rules in false edges:
//!
//! 1. **Receivers**: only `self.foo(…)` method calls resolve;
//!    `other.foo(…)` would otherwise alias every `foo` in the crate
//!    (`CURRENT.with` vs `SpinLock::with` is the canonical trap).
//!    Bare calls (`foo(…)`) and path calls (`Node::free(…)`) resolve
//!    by last segment.
//! 2. **Ubiquitous names**: `new`, `drop`, `clone`, `next`, … shadow
//!    std/trait methods on every type; resolving them by name would
//!    wire, say, `Arc::new(…)` into `Coordinator::new` and fabricate
//!    lock edges. They are never resolved ([`DENY_RESOLVE`]).
//!
//! Both heuristics under-approximate *edges*, never *sites*: lock
//! acquisitions and free sites are found by token scan at the line
//! level, so a dropped edge can only miss a transitive ordering, not
//! an unannotated site.

use std::collections::{BTreeMap, BTreeSet};

use super::scan::{self, FnExtent, SourceFile};
use super::LintContext;

/// Names never resolved to call edges: std/trait idioms defined on
/// many types, where name-matching would fabricate paths into
/// unrelated impls.
const DENY_RESOLVE: &[&str] = &[
    "new", "now", "drop", "clone", "default", "from", "into", "fmt", "next", "len",
    "is_empty", "min", "max", "abs", "clamp", "get", "set", "push", "pop", "insert",
    "remove", "clear", "take", "swap", "load", "store", "collect", "iter", "join",
    "spawn", "send", "recv", "wait", "write", "read", "flush", "contains", "extend",
    "retain", "unwrap", "expect", "ok", "err", "f",
];

/// A call site inside a function extent.
#[derive(Debug, Clone)]
pub struct CallSite {
    /// 0-based line of the call.
    pub line: usize,
    /// Last path segment of the callee.
    pub name: String,
    /// Inside a `call_rcu(…)` argument list — runs after a grace
    /// period, not on this path.
    pub deferred: bool,
    /// On an in-test line.
    pub in_test: bool,
}

/// One function in the graph.
pub struct FnNode {
    /// Index into `ctx.files`.
    pub file: usize,
    pub extent: FnExtent,
    pub calls: Vec<CallSite>,
}

pub struct CallGraph {
    pub nodes: Vec<FnNode>,
    by_name: BTreeMap<String, Vec<usize>>,
}

impl CallGraph {
    pub fn build(ctx: &LintContext) -> CallGraph {
        let mut nodes = Vec::new();
        for (fidx, file) in ctx.files.iter().enumerate() {
            let extents = scan::fn_extents(file);
            let deferred = deferred_lines(file);
            let mut calls_per_extent: Vec<Vec<CallSite>> = vec![Vec::new(); extents.len()];
            for (lidx, line) in file.lines.iter().enumerate() {
                let Some(owner) = scan::innermost_extent(&extents, lidx) else { continue };
                for (name, _via_self) in scan::calls_on_line(&line.code) {
                    calls_per_extent[owner].push(CallSite {
                        line: lidx,
                        name,
                        deferred: deferred[lidx],
                        in_test: line.in_test,
                    });
                }
            }
            for (extent, calls) in extents.into_iter().zip(calls_per_extent) {
                nodes.push(FnNode { file: fidx, extent, calls });
            }
        }
        let mut by_name: BTreeMap<String, Vec<usize>> = BTreeMap::new();
        for (i, n) in nodes.iter().enumerate() {
            by_name.entry(n.extent.name.clone()).or_default().push(i);
        }
        CallGraph { nodes, by_name }
    }

    /// Node ids a callee name resolves to (empty for deny-listed or
    /// unknown names).
    pub fn resolve(&self, name: &str) -> &[usize] {
        if DENY_RESOLVE.contains(&name) {
            return &[];
        }
        self.by_name.get(name).map_or(&[], Vec::as_slice)
    }

    /// Transitive closure of non-deferred, non-test call edges from
    /// `start` (inclusive of `start` itself).
    pub fn reachable(&self, start: usize) -> BTreeSet<usize> {
        let mut seen = BTreeSet::new();
        let mut stack = vec![start];
        while let Some(n) = stack.pop() {
            if !seen.insert(n) {
                continue;
            }
            for call in &self.nodes[n].calls {
                if call.deferred || call.in_test {
                    continue;
                }
                for &t in self.resolve(&call.name) {
                    if !seen.contains(&t) {
                        stack.push(t);
                    }
                }
            }
        }
        seen
    }
}

/// Per-line flags: inside the argument list of a `call_rcu(…)` call —
/// code that runs from the reclaimer after a grace period, so lock and
/// free events there are not part of the enclosing function's path.
pub fn deferred_lines(file: &SourceFile) -> Vec<bool> {
    let mut out = vec![false; file.lines.len()];
    let mut i = 0;
    while i < file.lines.len() {
        let code = &file.lines[i].code;
        let Some(pos) = code.find("call_rcu(") else {
            i += 1;
            continue;
        };
        // Paren-match from the `(` of call_rcu across lines.
        let mut depth: i64 = 0;
        let mut j = i;
        let mut tail: &str = &code[pos..];
        loop {
            for c in tail.chars() {
                match c {
                    '(' => depth += 1,
                    ')' => depth -= 1,
                    _ => {}
                }
            }
            if depth <= 0 || j + 1 >= file.lines.len() {
                break;
            }
            j += 1;
            tail = &file.lines[j].code;
        }
        for flag in &mut out[i..=j] {
            *flag = true;
        }
        i = j + 1;
    }
    out
}
