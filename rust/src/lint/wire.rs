//! Rule `wire`: the error-code table cannot drift.
//!
//! Four places state the wire error-code contract:
//!
//! 1. `error::KvError::code()` — the defining map (variant → byte);
//! 2. `error::KvError::code_name()` — byte → human name;
//! 3. `net::proto::wire_code` — the named byte constants the serving
//!    layer and clients use;
//! 4. DESIGN.md §Error codes — the documented table.
//!
//! `code()` is the anchor. The other three must cover exactly its code
//! set, the names in (2) and (4) must agree, and each constant in (3)
//! must be the SCREAMING_SNAKE_CASE of its `code_name()` with the same
//! value. Any one-line drift in any direction fails.

use std::collections::BTreeMap;

use super::scan::SourceFile;
use super::{Diagnostic, LintContext};

pub const DESIGN_SECTION: &str = "### Error codes";
const ERROR_RS: &str = "rust/src/error.rs";
const PROTO_RS: &str = "rust/src/net/proto.rs";

pub fn check(ctx: &LintContext) -> Vec<Diagnostic> {
    let mut out = Vec::new();

    let Some(error_rs) = ctx.files.iter().find(|f| f.path == ERROR_RS) else {
        // Fixture contexts without error.rs simply skip the rule.
        return out;
    };

    // 1. code(): the anchor set. code → line of the arm.
    let mut codes: BTreeMap<u8, usize> = BTreeMap::new();
    for (idx, _raw, line_code) in fn_body(error_rs, "fn code(") {
        if let Some(code) = line_code.split("=> 0x").nth(1).and_then(parse_hex) {
            if codes.insert(code, idx + 1).is_some() {
                out.push(Diagnostic::new(
                    ERROR_RS,
                    idx + 1,
                    "wire",
                    format!("duplicate wire code {code:#04x} in KvError::code()"),
                ));
            }
        }
    }
    if codes.is_empty() {
        out.push(Diagnostic::new(
            ERROR_RS,
            1,
            "wire",
            "could not parse any `=> 0x..` arms out of KvError::code()".to_string(),
        ));
        return out;
    }

    // 2. code_name(): code → (name, line).
    let mut names: BTreeMap<u8, (String, usize)> = BTreeMap::new();
    for (idx, raw, line_code) in fn_body(error_rs, "fn code_name(") {
        let (Some(code), Some(name)) = (
            line_code.trim().strip_prefix("0x").and_then(parse_hex),
            quoted(raw),
        ) else {
            continue;
        };
        names.insert(code, (name, idx + 1));
    }
    diff_sets(&mut out, &codes, &names, ERROR_RS, "KvError::code_name()");

    // 3. net::proto::wire_code constants: code → (CONST_NAME, line).
    let mut consts: BTreeMap<u8, (String, usize)> = BTreeMap::new();
    if let Some(proto_rs) = ctx.files.iter().find(|f| f.path == PROTO_RS) {
        for (idx, _raw, line_code) in mod_body(proto_rs, "pub mod wire_code") {
            let t = line_code.trim();
            let Some(rest) = t.strip_prefix("pub const ") else { continue };
            let (Some(name), Some(code)) = (
                rest.split(':').next().map(|s| s.trim().to_string()),
                rest.split("= 0x").nth(1).and_then(parse_hex),
            ) else {
                continue;
            };
            consts.insert(code, (name, idx + 1));
        }
        diff_sets(&mut out, &codes, &consts, PROTO_RS, "net::proto::wire_code");
        for (code, (cname, line)) in &consts {
            if let Some((wname, _)) = names.get(code) {
                let want = wname.to_uppercase().replace('-', "_");
                if *cname != want {
                    out.push(Diagnostic::new(
                        PROTO_RS,
                        *line,
                        "wire",
                        format!(
                            "wire_code const for {code:#04x} is '{cname}' but code_name() implies '{want}'"
                        ),
                    ));
                }
            }
        }
    } else {
        out.push(Diagnostic::new(
            PROTO_RS,
            1,
            "wire",
            "net/proto.rs not found (wire_code constants unchecked)".to_string(),
        ));
    }

    // 4. DESIGN.md §Error codes rows: code → (name, line).
    let mut design: BTreeMap<u8, (String, usize)> = BTreeMap::new();
    let mut in_section = false;
    for (idx, line) in ctx.design_md.lines().enumerate() {
        if line.starts_with("## ") || line.starts_with("### ") {
            in_section = line.starts_with(DESIGN_SECTION);
            continue;
        }
        if !in_section || !line.trim_start().starts_with('|') {
            continue;
        }
        let cells: Vec<&str> = line.split('|').map(str::trim).collect();
        if cells.len() < 3 {
            continue;
        }
        let (Some(code), Some(name)) = (
            cells[1].trim_matches('`').strip_prefix("0x").and_then(parse_hex),
            backticked(cells.get(2).copied().unwrap_or("")),
        ) else {
            continue;
        };
        design.insert(code, (name, idx + 1));
    }
    diff_sets(&mut out, &codes, &design, "rust/DESIGN.md", "DESIGN.md §Error codes");
    for (code, (dname, line)) in &design {
        if let Some((wname, _)) = names.get(code) {
            if dname != wname {
                out.push(Diagnostic::new(
                    "rust/DESIGN.md",
                    *line,
                    "wire",
                    format!(
                        "DESIGN.md names {code:#04x} '{dname}' but code_name() says '{wname}'"
                    ),
                ));
            }
        }
    }

    out
}

/// Compare a derived map against the anchor code set, reporting codes
/// missing from / extra in `have`.
fn diff_sets<T>(
    out: &mut Vec<Diagnostic>,
    anchor: &BTreeMap<u8, usize>,
    have: &BTreeMap<u8, (T, usize)>,
    file: &str,
    what: &str,
) {
    for (code, line) in anchor {
        if !have.contains_key(code) {
            out.push(Diagnostic::new(
                file,
                1,
                "wire",
                format!(
                    "{what} is missing wire code {code:#04x} (defined at rust/src/error.rs:{line})"
                ),
            ));
        }
    }
    for (code, (_, line)) in have {
        if !anchor.contains_key(code) {
            out.push(Diagnostic::new(
                file,
                *line,
                "wire",
                format!("{what} lists wire code {code:#04x} that KvError::code() never returns"),
            ));
        }
    }
}

/// Lines (0-based index, raw text, code text) of the brace-matched
/// body that starts at the first line whose code contains `needle`.
/// Structural parsing must use the *code* text — a commented-out arm
/// (`// KvError::Legacy => 0x09,`) is not part of the contract — while
/// string contents (blanked in code) come from raw.
fn fn_body<'a>(file: &'a SourceFile, needle: &str) -> Vec<(usize, &'a str, &'a str)> {
    let Some(start) = file.lines.iter().position(|l| l.code.contains(needle)) else {
        return Vec::new();
    };
    let mut depth: i64 = 0;
    let mut opened = false;
    let mut outl = Vec::new();
    for (idx, line) in file.lines.iter().enumerate().skip(start) {
        outl.push((idx, line.raw.as_str(), line.code.as_str()));
        for c in line.code.chars() {
            match c {
                '{' => {
                    depth += 1;
                    opened = true;
                }
                '}' => depth -= 1,
                _ => {}
            }
        }
        if opened && depth <= 0 {
            break;
        }
    }
    outl
}

fn mod_body<'a>(file: &'a SourceFile, needle: &str) -> Vec<(usize, &'a str, &'a str)> {
    fn_body(file, needle)
}

/// Leading hex digits of `s` → byte value.
fn parse_hex(s: &str) -> Option<u8> {
    let digits: String = s.chars().take_while(|c| c.is_ascii_hexdigit()).collect();
    if digits.is_empty() {
        return None;
    }
    u8::from_str_radix(&digits, 16).ok()
}

/// First `"…"` substring of a raw line.
fn quoted(raw: &str) -> Option<String> {
    let a = raw.find('"')? + 1;
    let b = a + raw[a..].find('"')?;
    Some(raw[a..b].to_string())
}

/// First `` `…` `` substring of a markdown cell.
fn backticked(cell: &str) -> Option<String> {
    let a = cell.find('`')? + 1;
    let b = a + cell[a..].find('`')?;
    Some(cell[a..b].to_string())
}
