//! Rule `hot`: functions tagged `// lint: hot` stay allocation-,
//! lock-, sleep- and print-free.
//!
//! The tag is a standalone comment line — exactly `// lint: hot` —
//! directly above a fast-path fn (the `#[inline]` lookup paths);
//! merely *mentioning* the tag in prose does not arm the rule.
//! The rule brace-matches the fn body and
//! denies a fixed token list — mutex/spinlock acquisition, heap
//! allocation, sleeping, formatting/printing. The check is *shallow*
//! (tokens in the tagged body only, not callees): its job is to stop
//! the easy regression where a debug `println!` or a convenience
//! `Vec::new()` lands on the lookup path, not to prove the whole call
//! graph allocation-free. QSBR's `read_lock()` is *not* a lock (it is
//! a no-op counter copy) and is not matched — the deny tokens require
//! a `.lock(` / `.try_lock(` method call.

use super::{Diagnostic, LintContext};
use super::scan::SourceFile;

pub const TAG: &str = "// lint: hot";

/// (needle in code text, human name in the diagnostic)
pub const DENIED: &[(&str, &str)] = &[
    (".lock(", "lock()"),
    (".try_lock(", "try_lock()"),
    ("sleep(", "sleep"),
    ("println!", "println!"),
    ("eprintln!", "eprintln!"),
    ("print!(", "print!"),
    ("format!", "format!"),
    ("vec![", "vec![]"),
    ("Vec::new", "Vec::new"),
    ("Vec::with_capacity", "Vec::with_capacity"),
    ("Box::new", "Box::new"),
    ("String::new", "String::new"),
    ("String::from", "String::from"),
    (".to_vec(", "to_vec()"),
    (".to_string(", "to_string()"),
    (".to_owned(", "to_owned()"),
    (".collect(", "collect()"),
    ("HashMap::new", "HashMap::new"),
    ("HashSet::new", "HashSet::new"),
];

pub fn check(ctx: &LintContext) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    for file in &ctx.files {
        let mut idx = 0;
        while idx < file.lines.len() {
            if file.lines[idx].comment.trim() != TAG {
                idx += 1;
                continue;
            }
            match fn_after_tag(file, idx) {
                Some((fn_line, name, body_end)) => {
                    scan_body(file, fn_line, body_end, &name, &mut out);
                    idx = body_end + 1;
                }
                None => {
                    out.push(Diagnostic::new(
                        &file.path,
                        idx + 1,
                        "hot",
                        "// lint: hot tag with no fn following it".to_string(),
                    ));
                    idx += 1;
                }
            }
        }
    }
    out
}

/// From the tag line, locate the next `fn`, its name, and the line of
/// its matching close brace.
fn fn_after_tag(file: &SourceFile, tag_idx: usize) -> Option<(usize, String, usize)> {
    let lines = &file.lines;
    let mut j = tag_idx;
    // The fn header must follow within a few lines (attributes,
    // comments, and the tag line itself in between are fine).
    let mut fn_line = None;
    while j < lines.len() && j <= tag_idx + 6 {
        if super::scan::has_word(&lines[j].code, "fn") {
            fn_line = Some(j);
            break;
        }
        j += 1;
    }
    let fn_line = fn_line?;
    let code = &lines[fn_line].code;
    let after_fn = code.split("fn ").nth(1)?;
    let name: String = after_fn
        .chars()
        .take_while(|c| c.is_alphanumeric() || *c == '_')
        .collect();
    // Brace-match the body from the first `{` at or after the header.
    let mut depth: i64 = 0;
    let mut opened = false;
    let mut k = fn_line;
    while k < lines.len() {
        for c in lines[k].code.chars() {
            match c {
                '{' => {
                    depth += 1;
                    opened = true;
                }
                '}' => depth -= 1,
                _ => {}
            }
        }
        if opened && depth <= 0 {
            return Some((fn_line, name, k));
        }
        k += 1;
    }
    Some((fn_line, name, lines.len() - 1))
}

fn scan_body(
    file: &SourceFile,
    from: usize,
    to: usize,
    name: &str,
    out: &mut Vec<Diagnostic>,
) {
    for idx in from..=to {
        let code = &file.lines[idx].code;
        for (needle, label) in DENIED {
            if code.contains(needle) {
                out.push(Diagnostic::new(
                    &file.path,
                    idx + 1,
                    "hot",
                    format!(
                        "fn '{name}' is tagged // lint: hot but uses denied operation '{label}'"
                    ),
                ));
            }
        }
    }
}
