//! Rule `hot`: functions tagged `// lint: hot` stay allocation-,
//! lock-, sleep- and print-free.
//!
//! The tag is a standalone comment line — exactly `// lint: hot` —
//! directly above a fast-path fn (the `#[inline]` lookup paths) or a
//! closure binding (`let probe = |k| { … };`); merely *mentioning* the
//! tag in prose does not arm the rule. The rule brace-matches the full
//! body extent — closures and nested `fn` items defined inside a
//! tagged fn are part of its extent and are scanned too — and denies a
//! fixed token list: mutex/spinlock acquisition, heap allocation,
//! sleeping, formatting/printing. The check is *shallow* (tokens in
//! the tagged extent only, not callees): its job is to stop the easy
//! regression where a debug `println!` or a convenience `Vec::new()`
//! lands on the lookup path, not to prove the whole call graph
//! allocation-free. QSBR's `read_lock()` is *not* a lock (it is a
//! no-op counter copy) and is not matched — the deny tokens require a
//! `.lock(` / `.try_lock(` method call.

use super::{Diagnostic, LintContext};
use super::scan::SourceFile;

pub const TAG: &str = "// lint: hot";

/// (needle in code text, human name in the diagnostic)
pub const DENIED: &[(&str, &str)] = &[
    (".lock(", "lock()"),
    (".try_lock(", "try_lock()"),
    ("sleep(", "sleep"),
    ("println!", "println!"),
    ("eprintln!", "eprintln!"),
    ("print!(", "print!"),
    ("format!", "format!"),
    ("vec![", "vec![]"),
    ("Vec::new", "Vec::new"),
    ("Vec::with_capacity", "Vec::with_capacity"),
    ("Box::new", "Box::new"),
    ("String::new", "String::new"),
    ("String::from", "String::from"),
    (".to_vec(", "to_vec()"),
    (".to_string(", "to_string()"),
    (".to_owned(", "to_owned()"),
    (".collect(", "collect()"),
    ("HashMap::new", "HashMap::new"),
    ("HashSet::new", "HashSet::new"),
];

pub fn check(ctx: &LintContext) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    for file in &ctx.files {
        let mut idx = 0;
        while idx < file.lines.len() {
            if file.lines[idx].comment.trim() != TAG {
                idx += 1;
                continue;
            }
            match fn_after_tag(file, idx) {
                Some((fn_line, name, body_end)) => {
                    scan_body(file, fn_line, body_end, &name, &mut out);
                    idx = body_end + 1;
                }
                None => {
                    out.push(Diagnostic::new(
                        &file.path,
                        idx + 1,
                        "hot",
                        "// lint: hot tag with no fn following it".to_string(),
                    ));
                    idx += 1;
                }
            }
        }
    }
    out
}

/// From the tag line, locate the next `fn` header *or* closure
/// binding, its name, and the line of its matching close brace.
fn fn_after_tag(file: &SourceFile, tag_idx: usize) -> Option<(usize, String, usize)> {
    let lines = &file.lines;
    let mut j = tag_idx;
    // The header must follow within a few lines (attributes, comments,
    // and the tag line itself in between are fine).
    let mut found = None;
    while j < lines.len() && j <= tag_idx + 6 {
        let code = &lines[j].code;
        if super::scan::has_word(code, "fn") {
            let name: String = code
                .split("fn ")
                .nth(1)?
                .chars()
                .take_while(|c| c.is_alphanumeric() || *c == '_')
                .collect();
            found = Some((j, name));
            break;
        }
        // A tagged closure binding: `let probe = |k| { … };` or
        // `let probe = move |k| { … };`.
        if super::scan::has_word(code, "let") && (code.contains("= |") || code.contains("= move |"))
        {
            let name: String = code
                .split("let ")
                .nth(1)?
                .trim_start_matches("mut ")
                .chars()
                .take_while(|c| c.is_alphanumeric() || *c == '_')
                .collect();
            if !code.contains('{') && code.contains(';') {
                // Single-expression closure: the binding line is the
                // whole extent.
                return Some((j, name, j));
            }
            found = Some((j, name));
            break;
        }
        j += 1;
    }
    let (fn_line, name) = found?;
    let end = super::scan::brace_match(file, fn_line).unwrap_or(lines.len() - 1);
    Some((fn_line, name, end))
}

fn scan_body(
    file: &SourceFile,
    from: usize,
    to: usize,
    name: &str,
    out: &mut Vec<Diagnostic>,
) {
    for idx in from..=to {
        let code = &file.lines[idx].code;
        for (needle, label) in DENIED {
            if code.contains(needle) {
                out.push(Diagnostic::new(
                    &file.path,
                    idx + 1,
                    "hot",
                    format!(
                        "fn '{name}' is tagged // lint: hot but uses denied operation '{label}'"
                    ),
                ));
            }
        }
    }
}
