//! `dhash-lint` — the repo's concurrency-contract static analyzer.
//!
//! DHash's correctness argument is a protocol: Lemma 4.1's
//! publish→delete→insert→clear ordering, the hazard-pointer handshakes,
//! and the per-site relaxed-ordering invariants from the read-path
//! audit. That protocol lives in comments and DESIGN.md tables — this
//! module makes it *enforced*. Eight rules, each a pure function over
//! scanned source ([`scan`]):
//!
//! | rule | contract |
//! |---|---|
//! | `safety` | every `unsafe` block/fn/impl is adjacent to a `// SAFETY:` comment (or a `/// # Safety` doc section) |
//! | `ord` | every `Ordering::*` site in `dhash`/`lflist`/`rcu` production code carries an `// ord: <key>` annotation, and the key set equals the DESIGN.md §Memory orderings table (drift in either direction fails) |
//! | `seqcst-budget` | per-file `Ordering::SeqCst` counts equal `tools/seqcst_allowlist.txt` (subsumes the old grep script) |
//! | `hot` | fns (or closures) tagged `// lint: hot` contain no locking, allocation, sleeping, or printing tokens anywhere in their extent |
//! | `wire` | `KvError::code()` ↔ `code_name()` ↔ `net::proto::wire_code` ↔ DESIGN.md §Error codes agree byte-for-byte |
//! | `lock-order` | every `.lock(`/`.try_lock(`/spinlock acquire carries `// lock: <key>`, the key set equals DESIGN.md §Lock order, and no reachable acquisition sequence ([`flow`] call graph) inverts the ranked hierarchy |
//! | `reclaim` | every `Box::into_raw`/`Box::from_raw` in the core carries `// reclaim: <key> [via <class>]`, classes are path-checked (rcu/grace/exclusive/contract), pairs and DESIGN.md §Reclamation contract agree, and no shared-`&self` path reaches a free site |
//! | `publish` | fns tagged `// lint: publish <proto>` perform their hazard/epoch publication steps as an ordered token sequence (publish → barrier → clear; mirrors-first install) |
//!
//! The analyzer is hand-rolled (no new deps, per the vendored-deps
//! rule) and line/token based — the [`flow`] layer adds function
//! extents and a name-resolved call graph, but it still never
//! type-checks, so it errs toward explicit annotation over inference.
//! Run it with `cargo run --release --bin dhash-lint`; fixture-driven
//! self-tests live in `rust/tests/lint_self.rs` +
//! `rust/tests/lint_fixtures/`.

pub mod flow;
pub mod hot;
pub mod lock_order;
pub mod ord;
pub mod publish;
pub mod reclaim;
pub mod safety;
pub mod scan;
pub mod seqcst;
pub mod wire;

use std::fmt;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

use scan::SourceFile;

/// One lint finding. Renders as `file:line: [rule] message` — the
/// format the self-tests assert verbatim.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct Diagnostic {
    pub file: String,
    /// 1-based line the finding anchors to.
    pub line: usize,
    pub rule: &'static str,
    pub message: String,
}

impl Diagnostic {
    pub fn new(file: &str, line: usize, rule: &'static str, message: String) -> Diagnostic {
        Diagnostic { file: file.to_string(), line, rule, message }
    }
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.message)
    }
}

/// Everything a rule may look at: the scanned `rust/src` tree plus the
/// two contract documents. Self-tests build synthetic contexts with
/// [`LintContext::from_sources`]; the binary loads the real tree with
/// [`LintContext::load`].
pub struct LintContext {
    pub files: Vec<SourceFile>,
    /// `rust/DESIGN.md`, verbatim.
    pub design_md: String,
    /// `tools/seqcst_allowlist.txt`, verbatim.
    pub allowlist: String,
}

impl LintContext {
    /// Load the real tree. `root` is the repo root (the directory
    /// holding `rust/` and `tools/`).
    pub fn load(root: &Path) -> io::Result<LintContext> {
        let src = root.join("rust/src");
        let mut paths = Vec::new();
        walk_rs(&src, &mut paths)?;
        paths.sort();
        let mut files = Vec::new();
        for p in &paths {
            let text = fs::read_to_string(p)?;
            let rel = p
                .strip_prefix(root)
                .unwrap_or(p)
                .to_string_lossy()
                .replace('\\', "/");
            files.push(SourceFile::parse(&rel, &text));
        }
        let design_md = fs::read_to_string(root.join("rust/DESIGN.md"))?;
        let allowlist = fs::read_to_string(root.join("tools/seqcst_allowlist.txt"))?;
        let mut ctx = LintContext { files, design_md, allowlist };
        ctx.resolve_test_only_files();
        Ok(ctx)
    }

    /// Build a context from in-memory sources (self-tests, fixtures).
    pub fn from_sources(
        sources: &[(&str, &str)],
        design_md: &str,
        allowlist: &str,
    ) -> LintContext {
        let files = sources
            .iter()
            .map(|(path, text)| SourceFile::parse(path, text))
            .collect();
        let mut ctx = LintContext {
            files,
            design_md: design_md.to_string(),
            allowlist: allowlist.to_string(),
        };
        ctx.resolve_test_only_files();
        ctx
    }

    /// Find the repo root by walking up from `start` until a directory
    /// holding both `rust/src` and `tools/seqcst_allowlist.txt`. Makes
    /// the binary work from the workspace root, `rust/`, or anywhere
    /// below.
    pub fn find_root(start: &Path) -> Option<PathBuf> {
        let mut dir = start.to_path_buf();
        loop {
            if dir.join("rust/src").is_dir() && dir.join("tools/seqcst_allowlist.txt").is_file() {
                return Some(dir);
            }
            if !dir.pop() {
                return None;
            }
        }
    }

    /// Propagate `#[cfg(test)] mod name;` declarations: the files they
    /// resolve to are test code in their entirety.
    fn resolve_test_only_files(&mut self) {
        let mut test_paths: Vec<String> = Vec::new();
        for f in &self.files {
            for m in &f.cfg_test_mods {
                // `a/b/mod.rs` (or lib.rs) declaring `mod m;` →
                // `a/b/m.rs`; `a/b/c.rs` declaring it → `a/b/c/m.rs`.
                let dir = match f.path.rsplit_once('/') {
                    Some((d, base)) if base == "mod.rs" || base == "lib.rs" => d.to_string(),
                    Some((d, base)) => {
                        format!("{}/{}", d, base.trim_end_matches(".rs"))
                    }
                    None => String::new(),
                };
                let prefix = if dir.is_empty() { String::new() } else { format!("{dir}/") };
                test_paths.push(format!("{prefix}{m}.rs"));
                test_paths.push(format!("{prefix}{m}/mod.rs"));
            }
        }
        for f in &mut self.files {
            if test_paths.iter().any(|p| *p == f.path) {
                f.test_only = true;
            }
        }
    }

    /// Files in the concurrency core (the `ord` / `seqcst-budget`
    /// scope).
    pub fn core_files(&self) -> impl Iterator<Item = &SourceFile> {
        self.files.iter().filter(|f| {
            f.path.starts_with("rust/src/dhash/")
                || f.path.starts_with("rust/src/lflist/")
                || f.path.starts_with("rust/src/rcu/")
        })
    }
}

fn walk_rs(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    for entry in fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            walk_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// All `<marker><key>` tokens (e.g. `lock:bucket`) in the given
/// section of DESIGN.md, with the 1-based line each first appears on.
/// The section runs from a heading starting with `section` to the next
/// same-or-higher-level heading.
pub fn design_marked_keys(
    design_md: &str,
    section: &str,
    marker: &str,
) -> std::collections::BTreeMap<String, usize> {
    let mut keys = std::collections::BTreeMap::new();
    let mut in_section = false;
    for (idx, line) in design_md.lines().enumerate() {
        if line.starts_with("## ") {
            in_section = line.starts_with(section);
            continue;
        }
        if !in_section {
            continue;
        }
        let mut start = 0;
        while let Some(pos) = line[start..].find(marker) {
            let at = start + pos;
            let boundary = !line[..at]
                .chars()
                .next_back()
                .is_some_and(|c| c.is_alphanumeric() || c == '_');
            if boundary {
                let key: String = line[at + marker.len()..]
                    .chars()
                    .take_while(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || *c == '-')
                    .collect();
                if !key.is_empty() {
                    keys.entry(key).or_insert(idx + 1);
                }
            }
            start = at + marker.len();
        }
    }
    keys
}

/// The rule registry, in report order.
pub const RULES: &[(&str, fn(&LintContext) -> Vec<Diagnostic>)] = &[
    ("safety", safety::check),
    ("ord", ord::check),
    ("seqcst-budget", seqcst::check),
    ("hot", hot::check),
    ("wire", wire::check),
    ("lock-order", lock_order::check),
    ("reclaim", reclaim::check),
    ("publish", publish::check),
];

/// Run the named rules (all when `which` is empty) and return findings
/// sorted by file/line.
pub fn run(ctx: &LintContext, which: &[String]) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    for (name, rule) in RULES {
        if which.is_empty() || which.iter().any(|w| w == name) {
            out.extend(rule(ctx));
        }
    }
    out.sort();
    out
}
