//! Rule `ord`: every memory-ordering choice in the concurrency core is
//! annotated and indexed.
//!
//! ## Annotation grammar
//!
//! ```text
//! // ord: <key> — <free-form justification>
//! ```
//!
//! `<key>` is `[a-z0-9-]+` and names a row of the DESIGN.md §Memory
//! orderings table (as a backticked `ord:<key>` token in that row). One
//! key groups every site pinned by the same invariant — e.g. all of
//! Michael-list link-word traffic is `michael-link`.
//!
//! ## Coverage
//!
//! An annotation covers `Ordering::*` tokens on its own line; an
//! annotation on a comment line covers the statement below it — through
//! the first code line that ends the statement (contains `;` or ends
//! with `{`), so a multi-line `compare_exchange(…, Ordering::AcqRel,
//! Ordering::Acquire)` needs only one annotation. A blank line or a new
//! annotation also ends coverage.
//!
//! ## Scope
//!
//! Production code in `rust/src/{dhash,lflist,rcu}`. Test code — inline
//! `#[cfg(test)]` regions and files declared via `#[cfg(test)] mod x;`
//! — is exempt: test orderings are not protocol claims (the SeqCst ones
//! are budgeted by `seqcst-budget` instead).
//!
//! ## Index agreement
//!
//! The set of keys used in source must equal the set of `ord:<key>`
//! tokens in DESIGN.md §Memory orderings — a key used but undocumented
//! fails, and a documented key no site uses fails (stale row).

use std::collections::BTreeMap;

use super::{Diagnostic, LintContext};

pub const DESIGN_SECTION: &str = "## Memory orderings";

pub fn check(ctx: &LintContext) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    // key → first (file, line) that uses it.
    let mut used: BTreeMap<String, (String, usize)> = BTreeMap::new();

    for file in ctx.core_files() {
        if file.test_only {
            continue;
        }
        // Active annotation key, plus how many more lines it may cover
        // (a cap so a forgotten statement end cannot blanket a file).
        let mut active: Option<String> = None;
        let mut budget = 0usize;
        for (idx, line) in file.lines.iter().enumerate() {
            if line.in_test {
                active = None;
                continue;
            }
            let code = line.code.trim();
            if code.is_empty() && line.comment.is_empty() {
                active = None;
                continue;
            }
            let here = extract_key(&line.comment);
            if let Some(key) = &here {
                used.entry(key.clone())
                    .or_insert_with(|| (file.path.clone(), idx + 1));
                active = Some(key.clone());
                budget = 12;
            }
            if code.contains("Ordering::") && active.is_none() {
                out.push(Diagnostic::new(
                    &file.path,
                    idx + 1,
                    "ord",
                    "Ordering site without an // ord: annotation (see DESIGN.md §Memory orderings)"
                        .to_string(),
                ));
            }
            // Statement end consumes the annotation — except when the
            // annotation itself arrived trailing a closing-brace-only
            // line (`} // ord: key`): that code ends the *previous*
            // statement, and the annotation covers the one below, just
            // as it would from a comment-only line.
            let only_closers = !code.is_empty() && code.chars().all(|c| "}){];, ".contains(c));
            if !code.is_empty() && !(here.is_some() && only_closers) {
                if code.contains(';') || code.ends_with('{') || code.ends_with('}') {
                    active = None;
                } else if budget > 0 {
                    budget -= 1;
                    if budget == 0 {
                        active = None;
                    }
                }
            }
        }
    }

    // DESIGN.md §Memory orderings index.
    let table = design_keys(&ctx.design_md);
    for (key, (file, line)) in &used {
        if !table.contains_key(key) {
            out.push(Diagnostic::new(
                file,
                *line,
                "ord",
                format!("ord key '{key}' is not indexed in DESIGN.md {DESIGN_SECTION}"),
            ));
        }
    }
    for (key, line) in &table {
        if !used.contains_key(key) {
            out.push(Diagnostic::new(
                "rust/DESIGN.md",
                *line,
                "ord",
                format!(
                    "DESIGN.md {DESIGN_SECTION} indexes ord key '{key}' but no source site uses it"
                ),
            ));
        }
    }
    out
}

/// `// ord: <key> …` → `Some(key)`. The `ord:` marker must start at a
/// word boundary so prose like "record: announce" cannot arm the rule.
pub fn extract_key(comment: &str) -> Option<String> {
    super::scan::extract_marked_key(comment, "ord:")
}

/// All `ord:<key>` tokens in the §Memory orderings section of
/// DESIGN.md, with the 1-based line each first appears on.
pub fn design_keys(design_md: &str) -> BTreeMap<String, usize> {
    let mut keys = BTreeMap::new();
    let mut in_section = false;
    for (idx, line) in design_md.lines().enumerate() {
        if line.starts_with("## ") {
            in_section = line.starts_with(DESIGN_SECTION);
            continue;
        }
        if !in_section {
            continue;
        }
        let mut start = 0;
        while let Some(pos) = line[start..].find("ord:") {
            let at = start + pos;
            let boundary = !line[..at]
                .chars()
                .next_back()
                .is_some_and(|c| c.is_alphanumeric() || c == '_');
            if boundary {
                let key: String = line[at + 4..]
                    .chars()
                    .take_while(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || *c == '-')
                    .collect();
                if !key.is_empty() {
                    keys.entry(key).or_insert(idx + 1);
                }
            }
            start = at + 4;
        }
    }
    keys
}
