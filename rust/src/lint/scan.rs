//! Line/token scanner for the lint rules.
//!
//! `dhash-lint` is deliberately not a parser: every contract it checks
//! (SAFETY comments, `ord:` annotations, SeqCst budget, hot-path deny
//! tokens) is a *line-local* property once comments and literals are
//! out of the way. So the scanner does exactly that much: a character
//! state machine splits each line into its **code** part (comments
//! removed, string/char literal contents blanked so a `lock()` inside a
//! log message is not a lock call) and its **comment** part (both
//! `//`-style and nesting `/* */` blocks), then a second pass marks
//! `#[cfg(test)]` regions so rules can scope themselves to production
//! code.

/// One source line, split into its code and comment parts.
pub struct SourceLine {
    /// The raw line text, verbatim.
    pub raw: String,
    /// The line with comments removed and literal contents blanked.
    /// Quotes are kept so adjacent tokens do not merge.
    pub code: String,
    /// The comment text on this line (contents of `//…` and any `/* */`
    /// parts, including doc comments).
    pub comment: String,
    /// True when the line sits inside a `#[cfg(test)]` item (an inline
    /// `mod tests { … }` region or a `#[cfg(test)]`-gated item).
    pub in_test: bool,
}

/// A scanned file: split lines plus test-scoping facts.
pub struct SourceFile {
    /// Path relative to the repo root, forward slashes.
    pub path: String,
    pub lines: Vec<SourceLine>,
    /// The whole file is test code (a parent declared it behind
    /// `#[cfg(test)] mod name;`).
    pub test_only: bool,
    /// Child module names this file declares behind `#[cfg(test)]`
    /// (e.g. `conformance` for `#[cfg(test)] mod conformance;`) — the
    /// loader resolves them to sibling files and marks those
    /// `test_only`.
    pub cfg_test_mods: Vec<String>,
}

/// Literal-scanner state carried across lines.
#[derive(Clone, Copy, PartialEq)]
enum Mode {
    Code,
    /// Inside a block comment, with nesting depth.
    Block(u32),
    /// Inside a `"…"` string literal.
    Str,
    /// Inside a raw string literal `r##"…"##` with this many hashes.
    RawStr(usize),
}

impl SourceFile {
    /// Scan `text` into split lines and mark `#[cfg(test)]` regions.
    pub fn parse(path: &str, text: &str) -> SourceFile {
        let mut mode = Mode::Code;
        let mut lines: Vec<SourceLine> = text
            .lines()
            .map(|l| {
                let (code, comment) = scan_line(l, &mut mode);
                SourceLine { raw: l.to_string(), code, comment, in_test: false }
            })
            .collect();
        let cfg_test_mods = mark_test_regions(&mut lines);
        SourceFile { path: path.to_string(), lines, test_only: false, cfg_test_mods }
    }
}

/// Split one line into (code, comment), advancing the literal state.
fn scan_line(line: &str, mode: &mut Mode) -> (String, String) {
    let ch: Vec<char> = line.chars().collect();
    let mut code = String::new();
    let mut comment = String::new();
    let mut i = 0;
    while i < ch.len() {
        match *mode {
            Mode::Block(depth) => {
                if ch[i] == '*' && ch.get(i + 1) == Some(&'/') {
                    *mode = if depth <= 1 { Mode::Code } else { Mode::Block(depth - 1) };
                    comment.push_str("*/");
                    i += 2;
                } else if ch[i] == '/' && ch.get(i + 1) == Some(&'*') {
                    *mode = Mode::Block(depth + 1);
                    comment.push_str("/*");
                    i += 2;
                } else {
                    comment.push(ch[i]);
                    i += 1;
                }
            }
            Mode::Str => {
                if ch[i] == '\\' {
                    // Escape: blank it and whatever it escapes.
                    code.push(' ');
                    if i + 1 < ch.len() {
                        code.push(' ');
                    }
                    i += 2;
                } else if ch[i] == '"' {
                    code.push('"');
                    *mode = Mode::Code;
                    i += 1;
                } else {
                    code.push(' ');
                    i += 1;
                }
            }
            Mode::RawStr(hashes) => {
                if ch[i] == '"' && (0..hashes).all(|k| ch.get(i + 1 + k) == Some(&'#')) {
                    code.push('"');
                    for _ in 0..hashes {
                        code.push('#');
                    }
                    *mode = Mode::Code;
                    i += 1 + hashes;
                } else {
                    code.push(' ');
                    i += 1;
                }
            }
            Mode::Code => {
                let c = ch[i];
                let prev_ident = code
                    .chars()
                    .last()
                    .is_some_and(|p| p.is_alphanumeric() || p == '_');
                if c == '/' && ch.get(i + 1) == Some(&'/') {
                    // Line comment: the rest of the line (incl. doc
                    // comments) is comment text.
                    comment.extend(ch[i..].iter());
                    i = ch.len();
                } else if c == '/' && ch.get(i + 1) == Some(&'*') {
                    *mode = Mode::Block(1);
                    comment.push_str("/*");
                    i += 2;
                } else if c == '"' {
                    code.push('"');
                    *mode = Mode::Str;
                    i += 1;
                } else if (c == 'r' || c == 'b') && !prev_ident {
                    // Possible raw/byte literal prefix: r"…", r#"…"#,
                    // b"…", br#"…"#, b'…'.
                    let mut j = i + 1;
                    let is_raw = c == 'r' || ch.get(j) == Some(&'r');
                    if c == 'b' && ch.get(j) == Some(&'r') {
                        j += 1;
                    }
                    let mut hashes = 0;
                    while ch.get(j) == Some(&'#') {
                        hashes += 1;
                        j += 1;
                    }
                    if ch.get(j) == Some(&'"') {
                        for k in i..=j {
                            code.push(ch[k]);
                        }
                        *mode = if is_raw { Mode::RawStr(hashes) } else { Mode::Str };
                        i = j + 1;
                    } else if c == 'b' && ch.get(i + 1) == Some(&'\'') {
                        i = blank_char_literal(&ch, i + 1, &mut code, c);
                    } else {
                        code.push(c);
                        i += 1;
                    }
                } else if c == '\'' {
                    // Lifetime or char literal. `'x'` / `'\…'` are char
                    // literals; `'a` followed by anything else is a
                    // lifetime and stays as code.
                    let is_char = ch.get(i + 1) == Some(&'\\')
                        || (ch.get(i + 2) == Some(&'\'') && ch.get(i + 1) != Some(&'\''));
                    if is_char {
                        i = blank_char_literal(&ch, i, &mut code, '\0');
                    } else {
                        code.push('\'');
                        i += 1;
                    }
                } else {
                    code.push(c);
                    i += 1;
                }
            }
        }
    }
    (code, comment)
}

/// Blank a char literal starting at `ch[start] == '\''`; pushes the
/// `b` prefix (if any) plus blanked quotes into `code`. Returns the
/// index just past the closing quote.
fn blank_char_literal(ch: &[char], start: usize, code: &mut String, prefix: char) -> usize {
    if prefix != '\0' {
        code.push(prefix);
    }
    code.push('\'');
    let mut i = start + 1;
    while i < ch.len() {
        if ch[i] == '\\' {
            code.push(' ');
            if i + 1 < ch.len() {
                code.push(' ');
            }
            i += 2;
        } else if ch[i] == '\'' {
            code.push('\'');
            return i + 1;
        } else {
            code.push(' ');
            i += 1;
        }
    }
    i
}

/// Mark `#[cfg(test)]` items: inline brace-delimited items get their
/// whole region flagged `in_test`; `mod name;` declarations are
/// returned so the loader can flag the child file `test_only`.
fn mark_test_regions(lines: &mut [SourceLine]) -> Vec<String> {
    let mut mods = Vec::new();
    let mut i = 0;
    while i < lines.len() {
        if !lines[i].code.contains("#[cfg(test)]") {
            i += 1;
            continue;
        }
        // Find where the gated item starts: either after the attribute
        // on the same line, or on the next line with real code (skipping
        // further attributes and comment-only lines).
        let mut j = i;
        let same_line_rest = lines[i]
            .code
            .split("#[cfg(test)]")
            .nth(1)
            .unwrap_or("")
            .trim()
            .to_string();
        let mut item = same_line_rest;
        if item.is_empty() {
            j = i + 1;
            while j < lines.len() {
                let t = lines[j].code.trim();
                if t.is_empty() || t.starts_with("#[") {
                    j += 1;
                } else {
                    item = t.to_string();
                    break;
                }
            }
        }
        if item.is_empty() {
            i += 1;
            continue;
        }
        if let Some(name) = parse_mod_decl(&item) {
            // `#[cfg(test)] mod name;` — the child file is test-only.
            mods.push(name);
            for line in lines.iter_mut().take(j + 1).skip(i) {
                line.in_test = true;
            }
            i = j + 1;
        } else if !item.contains('{') && item.ends_with(';') {
            // A single `;`-terminated gated item (use, const, …).
            for line in lines.iter_mut().take(j + 1).skip(i) {
                line.in_test = true;
            }
            i = j + 1;
        } else {
            // Brace-delimited item: flag through the matching close.
            let mut depth: i64 = 0;
            let mut opened = false;
            let mut k = j;
            while k < lines.len() {
                for c in lines[k].code.chars() {
                    match c {
                        '{' => {
                            depth += 1;
                            opened = true;
                        }
                        '}' => depth -= 1,
                        _ => {}
                    }
                }
                if opened && depth <= 0 {
                    break;
                }
                k += 1;
            }
            let end = k.min(lines.len() - 1);
            for line in lines.iter_mut().take(end + 1).skip(i) {
                line.in_test = true;
            }
            i = end + 1;
        }
    }
    mods
}

/// `mod name;` (with optional visibility) → `Some(name)`.
fn parse_mod_decl(item: &str) -> Option<String> {
    let t = item.trim().trim_end_matches(';');
    if !item.trim_end().ends_with(';') {
        return None;
    }
    let mut words = t.split_whitespace().peekable();
    while let Some(w) = words.peek() {
        if w.starts_with("pub") {
            words.next();
        } else {
            break;
        }
    }
    if words.next()? != "mod" {
        return None;
    }
    let name = words.next()?;
    if words.next().is_some() || !name.chars().all(|c| c.is_alphanumeric() || c == '_') {
        return None;
    }
    Some(name.to_string())
}

/// True when `code` contains `word` delimited by non-identifier chars.
pub fn has_word(code: &str, word: &str) -> bool {
    let mut start = 0;
    while let Some(pos) = code[start..].find(word) {
        let at = start + pos;
        let before_ok = at == 0
            || !code[..at]
                .chars()
                .next_back()
                .is_some_and(|c| c.is_alphanumeric() || c == '_');
        let after = code[at + word.len()..].chars().next();
        let after_ok = !after.is_some_and(|c| c.is_alphanumeric() || c == '_');
        if before_ok && after_ok {
            return true;
        }
        start = at + word.len();
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(text: &str) -> SourceFile {
        SourceFile::parse("test.rs", text)
    }

    #[test]
    fn strips_line_and_block_comments() {
        let f = parse("let x = 1; // SAFETY: trailing\n/* a /* nested */ b */ let y;\n");
        assert_eq!(f.lines[0].code.trim(), "let x = 1;");
        assert!(f.lines[0].comment.contains("SAFETY: trailing"));
        assert_eq!(f.lines[1].code.trim(), "let y;");
        assert!(f.lines[1].comment.contains("nested"));
    }

    #[test]
    fn blanks_string_contents_but_keeps_quotes() {
        let f = parse("let s = \"lock() // not a comment\"; s.len();\n");
        assert!(!f.lines[0].code.contains("lock()"));
        assert!(f.lines[0].comment.is_empty());
        assert!(f.lines[0].code.contains("s.len();"));
    }

    #[test]
    fn raw_strings_and_chars_and_lifetimes() {
        let f = parse(
            "let r = r#\"unsafe \" inside\"#;\nlet c = '\\'';\nfn f<'a>(x: &'a str) {}\nlet q = 'q';\n",
        );
        assert!(!f.lines[0].code.contains("unsafe"));
        assert!(f.lines[1].code.contains("let c ="));
        assert!(f.lines[2].code.contains("<'a>"));
        assert!(!f.lines[3].code.contains('q') || f.lines[3].code.contains("let q"));
    }

    #[test]
    fn multiline_string_state_carries() {
        let f = parse("let s = \"line one\nOrdering::SeqCst\nend\";\nlet t = 1;\n");
        assert!(!f.lines[1].code.contains("Ordering"));
        assert!(f.lines[3].code.contains("let t"));
    }

    #[test]
    fn cfg_test_region_is_marked() {
        let f = parse(
            "fn prod() {}\n#[cfg(test)]\nmod tests {\n    fn t() {}\n}\nfn prod2() {}\n",
        );
        assert!(!f.lines[0].in_test);
        assert!(f.lines[1].in_test && f.lines[2].in_test && f.lines[4].in_test);
        assert!(!f.lines[5].in_test);
    }

    #[test]
    fn cfg_test_mod_decl_is_recorded() {
        let f = parse("#[cfg(test)]\nmod conformance;\nfn prod() {}\n");
        assert_eq!(f.cfg_test_mods, vec!["conformance".to_string()]);
        assert!(!f.lines[2].in_test);
    }

    #[test]
    fn word_boundaries() {
        assert!(has_word("unsafe {", "unsafe"));
        assert!(!has_word("not_unsafe {", "unsafe"));
        assert!(!has_word("unsafely", "unsafe"));
    }
}
