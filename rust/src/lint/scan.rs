//! Line/token scanner for the lint rules.
//!
//! `dhash-lint` is deliberately not a parser: every contract it checks
//! (SAFETY comments, `ord:` annotations, SeqCst budget, hot-path deny
//! tokens) is a *line-local* property once comments and literals are
//! out of the way. So the scanner does exactly that much: a character
//! state machine splits each line into its **code** part (comments
//! removed, string/char literal contents blanked so a `lock()` inside a
//! log message is not a lock call) and its **comment** part (both
//! `//`-style and nesting `/* */` blocks), then a second pass marks
//! `#[cfg(test)]` regions so rules can scope themselves to production
//! code.

/// One source line, split into its code and comment parts.
pub struct SourceLine {
    /// The raw line text, verbatim.
    pub raw: String,
    /// The line with comments removed and literal contents blanked.
    /// Quotes are kept so adjacent tokens do not merge.
    pub code: String,
    /// The comment text on this line (contents of `//…` and any `/* */`
    /// parts, including doc comments).
    pub comment: String,
    /// True when the line sits inside a `#[cfg(test)]` item (an inline
    /// `mod tests { … }` region or a `#[cfg(test)]`-gated item).
    pub in_test: bool,
}

/// A scanned file: split lines plus test-scoping facts.
pub struct SourceFile {
    /// Path relative to the repo root, forward slashes.
    pub path: String,
    pub lines: Vec<SourceLine>,
    /// The whole file is test code (a parent declared it behind
    /// `#[cfg(test)] mod name;`).
    pub test_only: bool,
    /// Child module names this file declares behind `#[cfg(test)]`
    /// (e.g. `conformance` for `#[cfg(test)] mod conformance;`) — the
    /// loader resolves them to sibling files and marks those
    /// `test_only`.
    pub cfg_test_mods: Vec<String>,
}

/// Literal-scanner state carried across lines.
#[derive(Clone, Copy, PartialEq)]
enum Mode {
    Code,
    /// Inside a block comment, with nesting depth.
    Block(u32),
    /// Inside a `"…"` string literal.
    Str,
    /// Inside a raw string literal `r##"…"##` with this many hashes.
    RawStr(usize),
}

impl SourceFile {
    /// Scan `text` into split lines and mark `#[cfg(test)]` regions.
    pub fn parse(path: &str, text: &str) -> SourceFile {
        let mut mode = Mode::Code;
        let mut lines: Vec<SourceLine> = text
            .lines()
            .map(|l| {
                let (code, comment) = scan_line(l, &mut mode);
                SourceLine { raw: l.to_string(), code, comment, in_test: false }
            })
            .collect();
        let cfg_test_mods = mark_test_regions(&mut lines);
        SourceFile { path: path.to_string(), lines, test_only: false, cfg_test_mods }
    }
}

/// Split one line into (code, comment), advancing the literal state.
fn scan_line(line: &str, mode: &mut Mode) -> (String, String) {
    let ch: Vec<char> = line.chars().collect();
    let mut code = String::new();
    let mut comment = String::new();
    let mut i = 0;
    while i < ch.len() {
        match *mode {
            Mode::Block(depth) => {
                if ch[i] == '*' && ch.get(i + 1) == Some(&'/') {
                    *mode = if depth <= 1 { Mode::Code } else { Mode::Block(depth - 1) };
                    comment.push_str("*/");
                    i += 2;
                } else if ch[i] == '/' && ch.get(i + 1) == Some(&'*') {
                    *mode = Mode::Block(depth + 1);
                    comment.push_str("/*");
                    i += 2;
                } else {
                    comment.push(ch[i]);
                    i += 1;
                }
            }
            Mode::Str => {
                if ch[i] == '\\' {
                    // Escape: blank it and whatever it escapes.
                    code.push(' ');
                    if i + 1 < ch.len() {
                        code.push(' ');
                    }
                    i += 2;
                } else if ch[i] == '"' {
                    code.push('"');
                    *mode = Mode::Code;
                    i += 1;
                } else {
                    code.push(' ');
                    i += 1;
                }
            }
            Mode::RawStr(hashes) => {
                if ch[i] == '"' && (0..hashes).all(|k| ch.get(i + 1 + k) == Some(&'#')) {
                    code.push('"');
                    for _ in 0..hashes {
                        code.push('#');
                    }
                    *mode = Mode::Code;
                    i += 1 + hashes;
                } else {
                    code.push(' ');
                    i += 1;
                }
            }
            Mode::Code => {
                let c = ch[i];
                let prev_ident = code
                    .chars()
                    .last()
                    .is_some_and(|p| p.is_alphanumeric() || p == '_');
                if c == '/' && ch.get(i + 1) == Some(&'/') {
                    // Line comment: the rest of the line (incl. doc
                    // comments) is comment text.
                    comment.extend(ch[i..].iter());
                    i = ch.len();
                } else if c == '/' && ch.get(i + 1) == Some(&'*') {
                    *mode = Mode::Block(1);
                    comment.push_str("/*");
                    i += 2;
                } else if c == '"' {
                    code.push('"');
                    *mode = Mode::Str;
                    i += 1;
                } else if (c == 'r' || c == 'b') && !prev_ident {
                    // Possible raw/byte literal prefix: r"…", r#"…"#,
                    // b"…", br#"…"#, b'…'.
                    let mut j = i + 1;
                    let is_raw = c == 'r' || ch.get(j) == Some(&'r');
                    if c == 'b' && ch.get(j) == Some(&'r') {
                        j += 1;
                    }
                    let mut hashes = 0;
                    while ch.get(j) == Some(&'#') {
                        hashes += 1;
                        j += 1;
                    }
                    if ch.get(j) == Some(&'"') {
                        for k in i..=j {
                            code.push(ch[k]);
                        }
                        *mode = if is_raw { Mode::RawStr(hashes) } else { Mode::Str };
                        i = j + 1;
                    } else if c == 'b' && ch.get(i + 1) == Some(&'\'') {
                        i = blank_char_literal(&ch, i + 1, &mut code, c);
                    } else {
                        code.push(c);
                        i += 1;
                    }
                } else if c == '\'' {
                    // Lifetime or char literal. `'x'` / `'\…'` are char
                    // literals; `'a` followed by anything else is a
                    // lifetime and stays as code.
                    let is_char = ch.get(i + 1) == Some(&'\\')
                        || (ch.get(i + 2) == Some(&'\'') && ch.get(i + 1) != Some(&'\''));
                    if is_char {
                        i = blank_char_literal(&ch, i, &mut code, '\0');
                    } else {
                        code.push('\'');
                        i += 1;
                    }
                } else {
                    code.push(c);
                    i += 1;
                }
            }
        }
    }
    (code, comment)
}

/// Blank a char literal starting at `ch[start] == '\''`; pushes the
/// `b` prefix (if any) plus blanked quotes into `code`. Returns the
/// index just past the closing quote.
fn blank_char_literal(ch: &[char], start: usize, code: &mut String, prefix: char) -> usize {
    if prefix != '\0' {
        code.push(prefix);
    }
    code.push('\'');
    let mut i = start + 1;
    while i < ch.len() {
        if ch[i] == '\\' {
            code.push(' ');
            if i + 1 < ch.len() {
                code.push(' ');
            }
            i += 2;
        } else if ch[i] == '\'' {
            code.push('\'');
            return i + 1;
        } else {
            code.push(' ');
            i += 1;
        }
    }
    i
}

/// Mark `#[cfg(test)]` items: inline brace-delimited items get their
/// whole region flagged `in_test`; `mod name;` declarations are
/// returned so the loader can flag the child file `test_only`.
fn mark_test_regions(lines: &mut [SourceLine]) -> Vec<String> {
    let mut mods = Vec::new();
    let mut i = 0;
    while i < lines.len() {
        if !lines[i].code.contains("#[cfg(test)]") {
            i += 1;
            continue;
        }
        // Find where the gated item starts: either after the attribute
        // on the same line, or on the next line with real code (skipping
        // further attributes and comment-only lines).
        let mut j = i;
        let same_line_rest = lines[i]
            .code
            .split("#[cfg(test)]")
            .nth(1)
            .unwrap_or("")
            .trim()
            .to_string();
        let mut item = same_line_rest;
        if item.is_empty() {
            j = i + 1;
            while j < lines.len() {
                let t = lines[j].code.trim();
                if t.is_empty() || t.starts_with("#[") {
                    j += 1;
                } else {
                    item = t.to_string();
                    break;
                }
            }
        }
        if item.is_empty() {
            i += 1;
            continue;
        }
        if let Some(name) = parse_mod_decl(&item) {
            // `#[cfg(test)] mod name;` — the child file is test-only.
            mods.push(name);
            for line in lines.iter_mut().take(j + 1).skip(i) {
                line.in_test = true;
            }
            i = j + 1;
        } else if !item.contains('{') && item.ends_with(';') {
            // A single `;`-terminated gated item (use, const, …).
            for line in lines.iter_mut().take(j + 1).skip(i) {
                line.in_test = true;
            }
            i = j + 1;
        } else {
            // Brace-delimited item: flag through the matching close.
            let mut depth: i64 = 0;
            let mut opened = false;
            let mut k = j;
            while k < lines.len() {
                for c in lines[k].code.chars() {
                    match c {
                        '{' => {
                            depth += 1;
                            opened = true;
                        }
                        '}' => depth -= 1,
                        _ => {}
                    }
                }
                if opened && depth <= 0 {
                    break;
                }
                k += 1;
            }
            let end = k.min(lines.len() - 1);
            for line in lines.iter_mut().take(end + 1).skip(i) {
                line.in_test = true;
            }
            i = end + 1;
        }
    }
    mods
}

/// `mod name;` (with optional visibility) → `Some(name)`.
fn parse_mod_decl(item: &str) -> Option<String> {
    let t = item.trim().trim_end_matches(';');
    if !item.trim_end().ends_with(';') {
        return None;
    }
    let mut words = t.split_whitespace().peekable();
    while let Some(w) = words.peek() {
        if w.starts_with("pub") {
            words.next();
        } else {
            break;
        }
    }
    if words.next()? != "mod" {
        return None;
    }
    let name = words.next()?;
    if words.next().is_some() || !name.chars().all(|c| c.is_alphanumeric() || c == '_') {
        return None;
    }
    Some(name.to_string())
}

// ------------------------------------------------------------- extents

/// A function extent: the `fn` header line, the line of the body's
/// matching close brace, and the receiver/safety facts the flow rules
/// key on. Extents may nest (nested `fn` items); [`innermost_extent`]
/// resolves a line to the tightest enclosing one.
#[derive(Debug, Clone)]
pub struct FnExtent {
    pub name: String,
    /// 0-based line of the `fn` header.
    pub start: usize,
    /// 0-based line of the body's matching close brace.
    pub end: usize,
    /// Receiver is `&mut self` or by-value `mut self` — the caller
    /// holds exclusive access for the whole call.
    pub exclusive_self: bool,
    /// Receiver is a shared `&self` (or by-value `self`).
    pub shared_self: bool,
    /// Declared `unsafe fn`: its obligations are discharged at call
    /// sites, not inside the body.
    pub is_unsafe: bool,
}

/// Every `fn` item with a body in `file`, in header-line order.
/// Bodyless declarations (trait methods, `extern` blocks) are skipped.
pub fn fn_extents(file: &SourceFile) -> Vec<FnExtent> {
    let mut out = Vec::new();
    for (idx, line) in file.lines.iter().enumerate() {
        if !has_word(&line.code, "fn") {
            continue;
        }
        let Some(after) = line.code.split("fn ").nth(1) else { continue };
        let name: String = after
            .chars()
            .take_while(|c| c.is_alphanumeric() || *c == '_')
            .collect();
        if name.is_empty() {
            continue;
        }
        // Join code from the header until the body `{` (or a `;`,
        // meaning a bodyless declaration). Generous cap: headers are
        // short.
        let mut header = String::new();
        let mut body_open = None;
        'hdr: for (j, l) in file.lines.iter().enumerate().skip(idx).take(10) {
            let text = if j == idx {
                // From the qualifiers (`pub unsafe …`) through the
                // header — an earlier statement on the same line is
                // not header text.
                let at = l.code.find("fn ").unwrap_or(0);
                let qual = l.code[..at]
                    .rfind(|c: char| matches!(c, ';' | '{' | '}' | ')'))
                    .map_or(0, |p| p + 1);
                &l.code[qual..]
            } else {
                l.code.as_str()
            };
            for c in text.chars() {
                match c {
                    '{' => {
                        body_open = Some(j);
                        break 'hdr;
                    }
                    ';' => break 'hdr,
                    _ => header.push(c),
                }
            }
            header.push(' ');
        }
        let Some(open) = body_open else { continue };
        let end = brace_match(file, open).unwrap_or(file.lines.len() - 1);
        let params = param_list(&header);
        let first = params.split(',').next().unwrap_or("").trim();
        let is_receiver = has_word(first, "self");
        let exclusive_self = is_receiver && has_word(first, "mut");
        out.push(FnExtent {
            name,
            start: idx,
            end,
            exclusive_self,
            shared_self: is_receiver && !exclusive_self,
            is_unsafe: has_word(header.split("fn ").next().unwrap_or(""), "unsafe"),
        });
    }
    out
}

/// The parameter list of a joined `fn` header: the parenthesized
/// group after the name, skipping a generic `<...>` section (which may
/// itself contain parens — `F: FnOnce() -> R`).
fn param_list(header: &str) -> &str {
    let ch: Vec<(usize, char)> = header.char_indices().collect();
    let mut i = 0;
    // Past `fn name`.
    if let Some(pos) = header.find("fn ") {
        i = ch.iter().position(|&(b, _)| b >= pos + 3).unwrap_or(ch.len());
        while i < ch.len() && (ch[i].1.is_alphanumeric() || ch[i].1 == '_' || ch[i].1 == ' ') {
            i += 1;
        }
    }
    // Skip a generic section.
    if i < ch.len() && ch[i].1 == '<' {
        let mut depth = 0i64;
        while i < ch.len() {
            match ch[i].1 {
                '<' => depth += 1,
                '>' => {
                    depth -= 1;
                    if depth <= 0 {
                        i += 1;
                        break;
                    }
                }
                _ => {}
            }
            i += 1;
        }
    }
    // The param group.
    while i < ch.len() && ch[i].1 != '(' {
        i += 1;
    }
    if i >= ch.len() {
        return "";
    }
    let open = ch[i].0;
    let mut depth = 0i64;
    while i < ch.len() {
        match ch[i].1 {
            '(' => depth += 1,
            ')' => {
                depth -= 1;
                if depth <= 0 {
                    return &header[open + 1..ch[i].0];
                }
            }
            _ => {}
        }
        i += 1;
    }
    &header[open + 1..]
}

/// Line of the close brace matching the first `{` at or after `from`
/// (counting braces in code text only).
pub fn brace_match(file: &SourceFile, from: usize) -> Option<usize> {
    let mut depth: i64 = 0;
    let mut opened = false;
    for (k, l) in file.lines.iter().enumerate().skip(from) {
        for c in l.code.chars() {
            match c {
                '{' => {
                    depth += 1;
                    opened = true;
                }
                '}' => depth -= 1,
                _ => {}
            }
        }
        if opened && depth <= 0 {
            return Some(k);
        }
    }
    None
}

/// Index of the tightest extent containing 0-based `line`, if any.
pub fn innermost_extent(extents: &[FnExtent], line: usize) -> Option<usize> {
    extents
        .iter()
        .enumerate()
        .filter(|(_, e)| e.start <= line && line <= e.end)
        .min_by_key(|(_, e)| e.end - e.start)
        .map(|(i, _)| i)
}

// --------------------------------------------------------------- calls

/// Rust keywords (and primary expressions) that read like a call when
/// followed by `(`.
const CALL_KEYWORDS: &[&str] = &[
    "if", "while", "for", "match", "return", "loop", "fn", "move", "unsafe", "else", "in",
    "as", "let", "mut", "ref", "dyn", "impl", "where", "use", "pub", "mod", "enum",
    "struct", "trait", "type", "const", "static", "crate", "super", "Self", "self",
];

/// Call-looking tokens on a comment-stripped code line:
/// `(name, via_self)` pairs. `name` is the last path segment of the
/// callee. Method calls are kept only when the receiver is exactly
/// `self` (`self.foo(…)`) — without type inference, `other.foo(…)`
/// cannot be resolved and is dropped rather than over-approximated
/// into every `foo` in the crate. Macros (`name!(…)`) are not calls.
pub fn calls_on_line(code: &str) -> Vec<(String, bool)> {
    let ch: Vec<char> = code.chars().collect();
    let mut out = Vec::new();
    let mut i = 0;
    while i < ch.len() {
        if !(ch[i].is_alphabetic() || ch[i] == '_') {
            i += 1;
            continue;
        }
        let start = i;
        while i < ch.len() && (ch[i].is_alphanumeric() || ch[i] == '_') {
            i += 1;
        }
        if ch.get(i) != Some(&'(') {
            continue;
        }
        let name: String = ch[start..i].iter().collect();
        if CALL_KEYWORDS.contains(&name.as_str()) {
            continue;
        }
        let before: String = ch[..start].iter().collect();
        if before.ends_with('.') {
            // Method call: keep only a `self.` receiver.
            let recv = before[..before.len() - 1].trim_end();
            if recv.ends_with("self")
                && !recv[..recv.len() - 4]
                    .chars()
                    .next_back()
                    .is_some_and(|c| c.is_alphanumeric() || c == '_' || c == '.')
            {
                out.push((name, true));
            }
        } else {
            // Bare or path call (`foo(…)`, `Type::foo(…)`).
            out.push((name, false));
        }
    }
    out
}

/// `<marker> <key>` in a comment → `Some(key)`, where `key` is
/// `[a-z0-9-]+` and the marker must start at a word boundary (prose
/// like "unlock: …" cannot arm a `lock:` rule). Shared by the `ord:`,
/// `lock:`, and `reclaim:` annotation grammars.
pub fn extract_marked_key(comment: &str, marker: &str) -> Option<String> {
    let mut start = 0;
    while let Some(pos) = comment[start..].find(marker) {
        let at = start + pos;
        let boundary = !comment[..at]
            .chars()
            .next_back()
            .is_some_and(|c| c.is_alphanumeric() || c == '_');
        if boundary {
            let key: String = comment[at + marker.len()..]
                .trim_start()
                .chars()
                .take_while(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || *c == '-')
                .collect();
            if !key.is_empty() {
                return Some(key);
            }
        }
        start = at + marker.len();
    }
    None
}

/// True when `code` contains `word` delimited by non-identifier chars.
pub fn has_word(code: &str, word: &str) -> bool {
    let mut start = 0;
    while let Some(pos) = code[start..].find(word) {
        let at = start + pos;
        let before_ok = at == 0
            || !code[..at]
                .chars()
                .next_back()
                .is_some_and(|c| c.is_alphanumeric() || c == '_');
        let after = code[at + word.len()..].chars().next();
        let after_ok = !after.is_some_and(|c| c.is_alphanumeric() || c == '_');
        if before_ok && after_ok {
            return true;
        }
        start = at + word.len();
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(text: &str) -> SourceFile {
        SourceFile::parse("test.rs", text)
    }

    #[test]
    fn strips_line_and_block_comments() {
        let f = parse("let x = 1; // SAFETY: trailing\n/* a /* nested */ b */ let y;\n");
        assert_eq!(f.lines[0].code.trim(), "let x = 1;");
        assert!(f.lines[0].comment.contains("SAFETY: trailing"));
        assert_eq!(f.lines[1].code.trim(), "let y;");
        assert!(f.lines[1].comment.contains("nested"));
    }

    #[test]
    fn blanks_string_contents_but_keeps_quotes() {
        let f = parse("let s = \"lock() // not a comment\"; s.len();\n");
        assert!(!f.lines[0].code.contains("lock()"));
        assert!(f.lines[0].comment.is_empty());
        assert!(f.lines[0].code.contains("s.len();"));
    }

    #[test]
    fn raw_strings_and_chars_and_lifetimes() {
        let f = parse(
            "let r = r#\"unsafe \" inside\"#;\nlet c = '\\'';\nfn f<'a>(x: &'a str) {}\nlet q = 'q';\n",
        );
        assert!(!f.lines[0].code.contains("unsafe"));
        assert!(f.lines[1].code.contains("let c ="));
        assert!(f.lines[2].code.contains("<'a>"));
        assert!(!f.lines[3].code.contains('q') || f.lines[3].code.contains("let q"));
    }

    #[test]
    fn multiline_string_state_carries() {
        let f = parse("let s = \"line one\nOrdering::SeqCst\nend\";\nlet t = 1;\n");
        assert!(!f.lines[1].code.contains("Ordering"));
        assert!(f.lines[3].code.contains("let t"));
    }

    #[test]
    fn cfg_test_region_is_marked() {
        let f = parse(
            "fn prod() {}\n#[cfg(test)]\nmod tests {\n    fn t() {}\n}\nfn prod2() {}\n",
        );
        assert!(!f.lines[0].in_test);
        assert!(f.lines[1].in_test && f.lines[2].in_test && f.lines[4].in_test);
        assert!(!f.lines[5].in_test);
    }

    #[test]
    fn cfg_test_mod_decl_is_recorded() {
        let f = parse("#[cfg(test)]\nmod conformance;\nfn prod() {}\n");
        assert_eq!(f.cfg_test_mods, vec!["conformance".to_string()]);
        assert!(!f.lines[2].in_test);
    }

    #[test]
    fn word_boundaries() {
        assert!(has_word("unsafe {", "unsafe"));
        assert!(!has_word("not_unsafe {", "unsafe"));
        assert!(!has_word("unsafely", "unsafe"));
    }
}
