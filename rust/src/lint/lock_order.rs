//! Rule `lock-order`: every blocking-lock acquisition is tagged with a
//! hierarchy key, and no path acquires locks against the hierarchy.
//!
//! ## Annotation grammar
//!
//! ```text
//! // lock: <key>
//! ```
//!
//! `<key>` is `[a-z0-9-]+` and names a row of DESIGN.md §Lock order
//! (as a backticked `lock:<key>` token). The annotation sits on the
//! acquisition line or on a comment line directly above it (up to two
//! comment-only lines away).
//!
//! ## Acquisition sites
//!
//! A production line in `rust/src/{dhash,lflist,rcu,coordinator}`
//! whose code contains `.lock(`, `.try_lock(`, or `lock.with(` (the
//! spinlock's scoped acquire — `SpinlockList.lock` / `CowArray.wlock`)
//! is one acquisition event. QSBR `read_lock()` is a counter copy, not
//! a lock, and does not match. Test code is exempt.
//!
//! ## Hierarchy check
//!
//! Rank = row order in §Lock order, outermost first. Locks acquired in
//! a function are modeled as held until it returns (RAII guards);
//! locks acquired by a callee are released on return. For each
//! function, the acquisition sequence — its own sites, plus every key
//! reachable through resolved call edges ([`flow`]) — must be
//! rank-monotone: acquiring a key ranked *above* one already held is a
//! finding. Same-key nesting is not flagged (re-acquisition is the
//! spinlock's own concern, and try-lock self-nesting is benign).
//!
//! ## Index agreement
//!
//! Both-ways drift, as with `ord`: a key used in source but absent
//! from §Lock order fails, and a documented key no site uses fails.

use std::collections::BTreeMap;

use super::scan::{self, SourceFile};
use super::{flow, Diagnostic, LintContext};

pub const DESIGN_SECTION: &str = "## Lock order";

const SCOPE: &[&str] = &[
    "rust/src/dhash/",
    "rust/src/lflist/",
    "rust/src/rcu/",
    "rust/src/coordinator/",
];

const TOKENS: &[&str] = &[".lock(", ".try_lock(", "lock.with("];

fn in_scope(path: &str) -> bool {
    SCOPE.iter().any(|p| path.starts_with(p))
}

fn is_acquire(code: &str) -> bool {
    TOKENS.iter().any(|t| code.contains(t))
}

/// The `lock:` key covering a site line: trailing comment on the line
/// itself, or a comment within the two comment-only lines above.
fn site_key(file: &SourceFile, idx: usize, marker: &str) -> Option<String> {
    if let Some(k) = scan::extract_marked_key(&file.lines[idx].comment, marker) {
        return Some(k);
    }
    let mut j = idx;
    while j > 0 && idx - j < 2 {
        let above = &file.lines[j - 1];
        if !above.code.trim().is_empty() || above.comment.is_empty() {
            break;
        }
        if let Some(k) = scan::extract_marked_key(&above.comment, marker) {
            return Some(k);
        }
        j -= 1;
    }
    None
}

pub fn check(ctx: &LintContext) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    // key → first (file, line) using it.
    let mut used: BTreeMap<String, (String, usize)> = BTreeMap::new();
    // Per file: 0-based acquisition line → key.
    let mut acq: BTreeMap<usize, BTreeMap<usize, String>> = BTreeMap::new();

    for (fidx, file) in ctx.files.iter().enumerate() {
        if !in_scope(&file.path) || file.test_only {
            continue;
        }
        for (idx, line) in file.lines.iter().enumerate() {
            if line.in_test || !is_acquire(&line.code) {
                continue;
            }
            match site_key(file, idx, "lock:") {
                Some(key) => {
                    used.entry(key.clone())
                        .or_insert_with(|| (file.path.clone(), idx + 1));
                    acq.entry(fidx).or_default().insert(idx, key);
                }
                None => out.push(Diagnostic::new(
                    &file.path,
                    idx + 1,
                    "lock-order",
                    "lock acquisition without a // lock: <key> annotation (see DESIGN.md §Lock order)"
                        .to_string(),
                )),
            }
        }
    }

    // DESIGN.md §Lock order: rank by row order, plus both-ways drift.
    let table = super::design_marked_keys(&ctx.design_md, DESIGN_SECTION, "lock:");
    let mut rank: BTreeMap<&str, usize> = BTreeMap::new();
    {
        let mut rows: Vec<(&String, &usize)> = table.iter().collect();
        rows.sort_by_key(|(_, line)| **line);
        for (i, (key, _)) in rows.into_iter().enumerate() {
            rank.insert(key.as_str(), i);
        }
    }
    for (key, (file, line)) in &used {
        if !table.contains_key(key) {
            out.push(Diagnostic::new(
                file,
                *line,
                "lock-order",
                format!("lock key '{key}' is not ranked in DESIGN.md {DESIGN_SECTION}"),
            ));
        }
    }
    for (key, line) in &table {
        if !used.contains_key(key) {
            out.push(Diagnostic::new(
                "rust/DESIGN.md",
                *line,
                "lock-order",
                format!(
                    "DESIGN.md {DESIGN_SECTION} ranks lock key '{key}' but no source site uses it"
                ),
            ));
        }
    }

    // Flow pass: per function, the held-set must stay rank-monotone
    // across its own acquisitions and everything reachable from calls.
    let graph = flow::CallGraph::build(ctx);
    // node id → its direct (line, key) acquisitions, line-ordered.
    // A line belongs to the *innermost* extent containing it, so a
    // nested fn's sites are not double-counted against its parent.
    let mut per_file_extents: BTreeMap<usize, Vec<scan::FnExtent>> = BTreeMap::new();
    for fidx in acq.keys() {
        per_file_extents.insert(*fidx, scan::fn_extents(&ctx.files[*fidx]));
    }
    let mut direct: Vec<Vec<(usize, String)>> = Vec::with_capacity(graph.nodes.len());
    for node in &graph.nodes {
        let mut sites = Vec::new();
        if let Some(lines) = acq.get(&node.file) {
            let extents = &per_file_extents[&node.file];
            for (&line, key) in lines.range(node.extent.start..=node.extent.end) {
                if let Some(owner) = scan::innermost_extent(extents, line) {
                    if extents[owner].start != node.extent.start {
                        continue;
                    }
                }
                sites.push((line, key.clone()));
            }
        }
        direct.push(sites);
    }
    for (nid, node) in graph.nodes.iter().enumerate() {
        if direct[nid].is_empty() {
            continue;
        }
        let file = &ctx.files[node.file];
        let deferred = flow::deferred_lines(file);
        // Events in line order: own acquisitions and call sites.
        #[derive(Clone)]
        enum Ev<'a> {
            Acq(&'a str),
            Call(&'a str),
        }
        let mut events: Vec<(usize, Ev)> = Vec::new();
        for (line, key) in &direct[nid] {
            if !deferred[*line] {
                events.push((*line, Ev::Acq(key)));
            }
        }
        for call in &node.calls {
            if !call.deferred && !call.in_test {
                events.push((call.line, Ev::Call(&call.name)));
            }
        }
        events.sort_by_key(|(line, _)| *line);
        let mut held: Vec<(String, usize)> = Vec::new();
        for (line, ev) in events {
            match ev {
                Ev::Acq(k2) => {
                    report_inversions(&mut out, file, line, k2, None, &held, &rank);
                    held.push((k2.to_string(), line));
                }
                Ev::Call(name) => {
                    for &target in graph.resolve(name) {
                        for t in graph.reachable(target) {
                            for (_, k2) in &direct[t] {
                                report_inversions(
                                    &mut out, file, line, k2, Some(name), &held, &rank,
                                );
                            }
                        }
                    }
                }
            }
        }
    }

    out.sort();
    out.dedup();
    out
}

#[allow(clippy::too_many_arguments)]
fn report_inversions(
    out: &mut Vec<Diagnostic>,
    file: &SourceFile,
    line: usize,
    k2: &str,
    via: Option<&str>,
    held: &[(String, usize)],
    rank: &BTreeMap<&str, usize>,
) {
    let Some(&r2) = rank.get(k2) else { return };
    for (k1, l1) in held {
        if k1 == k2 {
            continue;
        }
        let Some(&r1) = rank.get(k1.as_str()) else { continue };
        if r2 < r1 {
            let how = match via {
                Some(callee) => format!("call to '{callee}' can acquire"),
                None => "acquires".to_string(),
            };
            out.push(Diagnostic::new(
                &file.path,
                line + 1,
                "lock-order",
                format!(
                    "{how} lock '{k2}' while '{k1}' (line {}) is held — DESIGN.md {DESIGN_SECTION} ranks '{k2}' above '{k1}'",
                    l1 + 1
                ),
            ));
        }
    }
}
