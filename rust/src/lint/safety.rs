//! Rule `safety`: every `unsafe` site is adjacent to a safety
//! argument.
//!
//! A line whose *code* contains the `unsafe` keyword is covered when
//! one of the following holds:
//!
//! 1. the same line carries a `// SAFETY:` comment (trailing style);
//! 2. the line immediately below carries one (the
//!    `unsafe { // SAFETY: … body }` block-leading style);
//! 3. the contiguous comment block directly above — skipping attribute
//!    lines and sibling one-line `unsafe impl`s, so one comment can
//!    cover a `Send`/`Sync` pair — contains `SAFETY:` or a
//!    `# Safety` doc heading (the contract section of a
//!    `pub unsafe fn`/`unsafe trait` declaration);
//! 4. the `unsafe` sits on a continuation line of a statement whose
//!    first line was covered by (3) — a comment above a multi-line
//!    iterator chain covers closures on the chained lines, through the
//!    line that ends the statement (`;`, or a line ending in `{`/`}`).
//!
//! The rule applies to *all* scanned code, tests included: a test's
//! `unsafe` still dereferences raw pointers and still deserves a
//! sentence saying why that is sound.

use super::scan::has_word;
use super::{Diagnostic, LintContext};

pub fn check(ctx: &LintContext) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    for file in &ctx.files {
        // Statement tracking for rule 4: continuation lines of a
        // statement whose first line was covered stay covered.
        let mut in_stmt = false;
        let mut stmt_covered = false;
        for (idx, line) in file.lines.iter().enumerate() {
            let code = line.code.trim();
            if code.is_empty() {
                if line.comment.is_empty() {
                    // Blank line: any open statement is malformed anyway;
                    // stop extending its coverage.
                    in_stmt = false;
                }
                continue;
            }
            if !in_stmt {
                stmt_covered = covered_above(file, idx);
            }
            if has_word(&line.code, "unsafe")
                && !is_safety_comment(&line.comment)
                && !(idx + 1 < file.lines.len()
                    && is_safety_comment(&file.lines[idx + 1].comment))
                && !stmt_covered
            {
                out.push(Diagnostic::new(
                    &file.path,
                    idx + 1,
                    "safety",
                    "unsafe site without an adjacent // SAFETY: comment".to_string(),
                ));
            }
            in_stmt = !(code.contains(';') || code.ends_with('{') || code.ends_with('}'));
        }
    }
    out
}

fn is_safety_comment(comment: &str) -> bool {
    comment.contains("SAFETY:") || comment.contains("# Safety")
}

/// Does a contiguous comment block directly above line `idx` carry a
/// safety argument? Skips attribute lines and sibling one-line
/// `unsafe impl`s (one SAFETY comment may cover a Send/Sync pair).
fn covered_above(file: &super::scan::SourceFile, idx: usize) -> bool {
    let lines = &file.lines;
    let mut j = idx;
    while j > 0 {
        let above = &lines[j - 1];
        let t = above.code.trim();
        if t.starts_with("#[") || t.contains("unsafe impl") {
            j -= 1;
        } else {
            break;
        }
    }
    while j > 0 {
        let above = &lines[j - 1];
        if above.code.trim().is_empty() && !above.comment.is_empty() {
            if is_safety_comment(&above.comment) {
                return true;
            }
            j -= 1;
        } else {
            break;
        }
    }
    false
}
