//! HT-Split: Shalev & Shavit's split-ordered list (J. ACM 2006), the
//! lock-free *resizable* hash table (userspace-rcu's `rculfhash` lineage).
//!
//! All nodes live in ONE lock-free linked list sorted by *split-order*
//! key: the bit-reversal of the hash. Buckets are just shortcut pointers
//! (dummy nodes) into that list; doubling the bucket count never moves a
//! node — it only adds dummies that *split* existing chains. The costs
//! the paper notes (§2): the hash function is fixed to `key mod 2^i`
//! (resizable, not dynamic — no escape from adversarial collisions), and
//! every operation pays a bit-reversal.
//!
//! Implementation: Michael-style marked-pointer list (reusing the crate's
//! RCU reclamation instead of the original's hazard pointers), a lazily
//! allocated segment directory for the bucket array, and recursive parent
//! initialization of dummy buckets.

use std::sync::atomic::{AtomicPtr, AtomicU64, AtomicUsize, Ordering};

use super::ConcurrentMap;
use crate::dhash::HashFn;
use crate::rcu::{call_rcu, RcuThread};

/// Max directory segments: segment `s` holds 2^s buckets, so 30 segments
/// bound the table at 2^30 buckets — far beyond any test here.
const MAX_SEGMENTS: usize = 30;

const DELETED: usize = 1;

#[inline(always)]
fn untag(w: usize) -> *mut SoNode {
    (w & !DELETED) as *mut SoNode
}

/// Split-order key of a regular node: bit-reversed, LSB set (odd).
#[inline(always)]
fn so_regular(key: u64) -> u64 {
    key.reverse_bits() | 1
}

/// Split-order key of a dummy (bucket) node: bit-reversed, even.
#[inline(always)]
fn so_dummy(bucket: u64) -> u64 {
    bucket.reverse_bits()
}

struct SoNode {
    /// Split-order key (sort key of the master list).
    so_key: u64,
    /// Original key (0 for dummies; kept for debuggability).
    #[allow(dead_code)]
    key: u64,
    val: AtomicU64,
    next: AtomicUsize,
}

impl SoNode {
    fn alloc(so_key: u64, key: u64, val: u64) -> *mut SoNode {
        Box::into_raw(Box::new(SoNode {
            so_key,
            key,
            val: AtomicU64::new(val),
            next: AtomicUsize::new(0),
        }))
    }

    #[inline(always)]
    fn is_dummy(&self) -> bool {
        self.so_key & 1 == 0
    }
}

struct SendSo(*mut SoNode);
// SAFETY: reclaimer-only access after a grace period.
unsafe impl Send for SendSo {}

/// # Safety
/// `p` must be unlinked (unreachable to new readers) and passed here at
/// most once; the reclaimer frees it after a grace period.
unsafe fn defer_free_so(p: *mut SoNode) {
    let w = SendSo(p);
    call_rcu(move || {
        let w = w;
        // SAFETY: grace period elapsed.
        unsafe { drop(Box::from_raw(w.0)) };
    });
}

struct Pos {
    prev: *const AtomicUsize,
    cur: *mut SoNode,
    next: usize,
}

/// The split-ordered-list hash table.
pub struct HtSplit {
    /// Current bucket count (always a power of two).
    size: AtomicUsize,
    /// Live regular nodes (drives automatic doubling).
    count: AtomicUsize,
    /// Segment directory: segment 0 holds bucket 0; segment s>0 holds
    /// buckets [2^(s-1), 2^s). Entries are `*mut SoNode` dummy pointers
    /// stored as usize (0 = uninitialized bucket).
    segments: [AtomicPtr<AtomicUsize>; MAX_SEGMENTS],
    /// Auto-resize threshold (load factor).
    max_load: usize,
}

// SAFETY: lock-free structure over atomics; RCU reclamation.
unsafe impl Send for HtSplit {}
unsafe impl Sync for HtSplit {}

impl HtSplit {
    /// `nbuckets` is rounded up to a power of two. `max_load` is the load
    /// factor beyond which the table doubles itself on insert.
    pub fn new(nbuckets: usize, max_load: usize) -> Self {
        let size = nbuckets.next_power_of_two().max(1);
        let t = Self {
            size: AtomicUsize::new(size),
            count: AtomicUsize::new(0),
            segments: std::array::from_fn(|_| AtomicPtr::new(std::ptr::null_mut())),
            max_load: max_load.max(1),
        };
        // Bucket 0's dummy is the list head; install it eagerly.
        let head = SoNode::alloc(so_dummy(0), 0, 0);
        t.bucket_slot(0).store(head as usize, Ordering::SeqCst);
        t
    }

    /// Segment index + offset for a bucket id.
    #[inline]
    fn locate(bucket: usize) -> (usize, usize) {
        if bucket == 0 {
            (0, 0)
        } else {
            let seg = usize::BITS as usize - bucket.leading_zeros() as usize;
            (seg, bucket - (1 << (seg - 1)))
        }
    }

    /// The directory slot for `bucket`, allocating its segment lazily.
    fn bucket_slot(&self, bucket: usize) -> &AtomicUsize {
        let (seg, off) = Self::locate(bucket);
        let mut ptr = self.segments[seg].load(Ordering::SeqCst);
        if ptr.is_null() {
            let len = if seg == 0 { 1 } else { 1 << (seg - 1) };
            let fresh: Box<[AtomicUsize]> = (0..len).map(|_| AtomicUsize::new(0)).collect();
            let raw = Box::into_raw(fresh) as *mut AtomicUsize;
            match self.segments[seg].compare_exchange(
                std::ptr::null_mut(),
                raw,
                Ordering::SeqCst,
                Ordering::SeqCst,
            ) {
                Ok(_) => ptr = raw,
                Err(winner) => {
                    // SAFETY: we lost; rebuild the box to free it.
                    unsafe {
                        drop(Box::from_raw(std::slice::from_raw_parts_mut(raw, len)));
                    }
                    ptr = winner;
                }
            }
        }
        // SAFETY: segments are never freed while the table lives.
        unsafe { &*ptr.add(off) }
    }

    /// The dummy node of `bucket`, initializing it (and recursively its
    /// parent) if needed — the split-ordered list's signature move.
    fn get_bucket(&self, bucket: usize) -> *mut SoNode {
        let slot = self.bucket_slot(bucket);
        let w = slot.load(Ordering::SeqCst);
        if w != 0 {
            return w as *mut SoNode;
        }
        self.init_bucket(bucket)
    }

    fn init_bucket(&self, bucket: usize) -> *mut SoNode {
        debug_assert!(bucket > 0);
        // Parent: clear the most significant set bit.
        let parent = bucket & !(1usize << (usize::BITS - 1 - bucket.leading_zeros()));
        let parent_dummy = {
            let pslot = self.bucket_slot(parent);
            let w = pslot.load(Ordering::SeqCst);
            if w != 0 {
                w as *mut SoNode
            } else {
                self.init_bucket(parent)
            }
        };
        // Insert this bucket's dummy starting from the parent's dummy.
        let dummy = SoNode::alloc(so_dummy(bucket as u64), 0, 0);
        let slot = self.bucket_slot(bucket);
        match self.list_insert(parent_dummy, dummy) {
            Ok(()) => {
                slot.store(dummy as usize, Ordering::SeqCst);
                dummy
            }
            Err(existing) => {
                // A concurrent initializer beat us: free ours, adopt
                // theirs (it may not be published to the slot yet — CAS).
                // SAFETY: our dummy was never published.
                unsafe { drop(Box::from_raw(dummy)) };
                let _ = slot.compare_exchange(
                    0,
                    existing as usize,
                    Ordering::SeqCst,
                    Ordering::SeqCst,
                );
                slot.load(Ordering::SeqCst) as *mut SoNode
            }
        }
    }

    /// Michael-style search from `head` for `so_key`; unlinks marked
    /// nodes along the way (deferring their reclamation to RCU).
    fn list_search(&self, head: *mut SoNode, so_key: u64) -> Pos {
        'retry: loop {
            // SAFETY: head is a dummy, never reclaimed while the table
            // lives; inner nodes are RCU-protected.
            unsafe {
                let mut prev: *const AtomicUsize = &(*head).next;
                let mut cur = untag((*prev).load(Ordering::SeqCst));
                loop {
                    if cur.is_null() {
                        return Pos { prev, cur, next: 0 };
                    }
                    let next_t = (*cur).next.load(Ordering::SeqCst);
                    if (*prev).load(Ordering::SeqCst) != cur as usize {
                        continue 'retry;
                    }
                    if next_t & DELETED != 0 {
                        let next = next_t & !DELETED;
                        if (*prev)
                            .compare_exchange(
                                cur as usize,
                                next,
                                Ordering::SeqCst,
                                Ordering::SeqCst,
                            )
                            .is_ok()
                        {
                            defer_free_so(cur);
                            cur = next as *mut SoNode;
                            continue;
                        }
                        continue 'retry;
                    }
                    if (*cur).so_key >= so_key {
                        return Pos {
                            prev,
                            cur,
                            next: next_t,
                        };
                    }
                    prev = &(*cur).next;
                    cur = untag(next_t);
                }
            }
        }
    }

    /// Insert `node` (ordered by so_key) starting at dummy `head`.
    /// On duplicate so_key returns the incumbent.
    fn list_insert(&self, head: *mut SoNode, node: *mut SoNode) -> Result<(), *mut SoNode> {
        // SAFETY: node is ours until published; list protected by RCU.
        let so_key = unsafe { (*node).so_key };
        loop {
            let pos = self.list_search(head, so_key);
            // SAFETY: `pos.cur`, when non-null, is RCU-live.
            if !pos.cur.is_null() && unsafe { (*pos.cur).so_key } == so_key {
                return Err(pos.cur);
            }
            // SAFETY: `node` is ours until the CAS publishes it;
            // `pos.prev` is a live link word from the search.
            unsafe {
                (*node).next.store(pos.cur as usize, Ordering::SeqCst);
                if (*pos.prev)
                    .compare_exchange(
                        pos.cur as usize,
                        node as usize,
                        Ordering::SeqCst,
                        Ordering::SeqCst,
                    )
                    .is_ok()
                {
                    return Ok(());
                }
            }
        }
    }

    /// Logically delete the node with `so_key` reachable from `head`.
    fn list_delete(&self, head: *mut SoNode, so_key: u64) -> bool {
        loop {
            let pos = self.list_search(head, so_key);
            // SAFETY: `pos.cur`, when non-null, is RCU-live.
            if pos.cur.is_null() || unsafe { (*pos.cur).so_key } != so_key {
                return false;
            }
            // SAFETY: RCU-live.
            unsafe {
                if (*pos.cur)
                    .next
                    .compare_exchange(
                        pos.next,
                        pos.next | DELETED,
                        Ordering::SeqCst,
                        Ordering::SeqCst,
                    )
                    .is_err()
                {
                    continue;
                }
                // Physical unlink; on failure a later search cleans up.
                if (*pos.prev)
                    .compare_exchange(
                        pos.cur as usize,
                        pos.next,
                        Ordering::SeqCst,
                        Ordering::SeqCst,
                    )
                    .is_ok()
                {
                    defer_free_so(pos.cur);
                }
                return true;
            }
        }
    }

    #[inline]
    fn bucket_of(&self, key: u64) -> usize {
        (key as usize) & (self.size.load(Ordering::SeqCst) - 1)
    }

    /// Double the bucket count (lock-free: losers of the CAS just skip).
    fn maybe_grow(&self) {
        let size = self.size.load(Ordering::SeqCst);
        if self.count.load(Ordering::SeqCst) > size * self.max_load
            && size < (1 << (MAX_SEGMENTS - 1))
        {
            let _ = self
                .size
                .compare_exchange(size, size * 2, Ordering::SeqCst, Ordering::SeqCst);
        }
    }

    /// Explicit resize to a power of two (the §6.2 continuous-resize
    /// protocol drives this). Shrinking leaves orphan dummies in the
    /// list; they are harmless shortcuts that simply stop being used.
    pub fn resize(&self, nbuckets: usize) {
        let size = nbuckets.next_power_of_two().max(1).min(1 << (MAX_SEGMENTS - 1));
        self.size.store(size, Ordering::SeqCst);
    }
}

impl ConcurrentMap for HtSplit {
    fn name(&self) -> &'static str {
        "HT-Split"
    }

    fn lookup(&self, guard: &RcuThread, key: u64) -> Option<u64> {
        let _g = guard.read_lock();
        let head = self.get_bucket(self.bucket_of(key));
        let so = so_regular(key);
        let pos = self.list_search(head, so);
        if !pos.cur.is_null() && unsafe { (*pos.cur).so_key } == so {
            // SAFETY: RCU-live.
            Some(unsafe { (*pos.cur).val.load(Ordering::SeqCst) })
        } else {
            None
        }
    }

    fn insert(&self, guard: &RcuThread, key: u64, val: u64) -> bool {
        let _g = guard.read_lock();
        let head = self.get_bucket(self.bucket_of(key));
        let node = SoNode::alloc(so_regular(key), key, val);
        match self.list_insert(head, node) {
            Ok(()) => {
                self.count.fetch_add(1, Ordering::SeqCst);
                self.maybe_grow();
                true
            }
            Err(_) => {
                // SAFETY: never published.
                unsafe { drop(Box::from_raw(node)) };
                false
            }
        }
    }

    fn delete(&self, guard: &RcuThread, key: u64) -> bool {
        let _g = guard.read_lock();
        let head = self.get_bucket(self.bucket_of(key));
        if self.list_delete(head, so_regular(key)) {
            self.count.fetch_sub(1, Ordering::SeqCst);
            true
        } else {
            false
        }
    }

    /// Resizable only: adopts the bucket count (power of two), ignores
    /// `hash` — exactly the limitation the paper contrasts against.
    fn rebuild(&self, _guard: &RcuThread, nbuckets: usize, _hash: HashFn) -> bool {
        if nbuckets == 0 {
            return false; // invalid geometry, refused at the boundary
        }
        self.resize(nbuckets);
        true
    }

    fn len(&self, guard: &RcuThread) -> usize {
        let _g = guard.read_lock();
        // Walk the master list from bucket 0's dummy.
        let mut n = 0;
        let mut cur = self.get_bucket(0);
        // SAFETY: RCU-live chain.
        unsafe {
            cur = untag((*cur).next.load(Ordering::SeqCst));
            while !cur.is_null() {
                let next_t = (*cur).next.load(Ordering::SeqCst);
                if next_t & DELETED == 0 && !(*cur).is_dummy() {
                    n += 1;
                }
                cur = untag(next_t);
            }
        }
        n
    }
}

impl Drop for HtSplit {
    fn drop(&mut self) {
        // SAFETY: exclusive access; free the master list then segments.
        unsafe {
            let head = self.bucket_slot(0).load(Ordering::SeqCst) as *mut SoNode;
            let mut cur = head;
            while !cur.is_null() {
                let next = untag((*cur).next.load(Ordering::SeqCst));
                drop(Box::from_raw(cur));
                cur = next;
            }
            for (seg, slot) in self.segments.iter().enumerate() {
                let p = slot.load(Ordering::SeqCst);
                if !p.is_null() {
                    let len = if seg == 0 { 1 } else { 1 << (seg - 1) };
                    drop(Box::from_raw(std::slice::from_raw_parts_mut(p, len)));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rcu::rcu_barrier;

    #[test]
    fn split_order_keys() {
        // Dummies are even, regulars odd; parent ordering holds.
        assert_eq!(so_dummy(0), 0);
        assert!(so_regular(0) == 1);
        for b in 1..64u64 {
            assert_eq!(so_dummy(b) & 1, 0);
            assert_eq!(so_regular(b) & 1, 1);
        }
        // Bucket 1's dummy sorts after bucket 0's.
        assert!(so_dummy(0) < so_dummy(1));
    }

    #[test]
    fn locate_segments() {
        assert_eq!(HtSplit::locate(0), (0, 0));
        assert_eq!(HtSplit::locate(1), (1, 0));
        assert_eq!(HtSplit::locate(2), (2, 0));
        assert_eq!(HtSplit::locate(3), (2, 1));
        assert_eq!(HtSplit::locate(4), (3, 0));
        assert_eq!(HtSplit::locate(7), (3, 3));
        assert_eq!(HtSplit::locate(8), (4, 0));
    }

    #[test]
    fn basic_and_growth() {
        let g = RcuThread::register();
        let m = HtSplit::new(2, 4);
        for k in 0..500u64 {
            assert!(m.insert(&g, k, k * 2), "insert {k}");
        }
        // Auto-doubling kicked in.
        assert!(m.size.load(Ordering::SeqCst) > 2);
        assert_eq!(m.len(&g), 500);
        for k in 0..500u64 {
            assert_eq!(m.lookup(&g, k), Some(k * 2), "key {k}");
        }
        for k in (0..500u64).step_by(2) {
            assert!(m.delete(&g, k));
        }
        assert_eq!(m.len(&g), 250);
        assert!(!m.insert(&g, 3, 0), "dup accepted");
        g.quiescent_state();
        rcu_barrier();
    }

    #[test]
    fn shrink_keeps_contents() {
        let g = RcuThread::register();
        let m = HtSplit::new(64, 1 << 20); // no auto-grow
        for k in 0..300u64 {
            m.insert(&g, k, k);
        }
        m.resize(4);
        assert_eq!(m.len(&g), 300);
        for k in 0..300u64 {
            assert_eq!(m.lookup(&g, k), Some(k));
        }
        m.resize(128);
        for k in 0..300u64 {
            assert_eq!(m.lookup(&g, k), Some(k));
        }
        g.quiescent_state();
        rcu_barrier();
    }
}
