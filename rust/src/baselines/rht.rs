//! HT-RHT: the Linux kernel's generic resizable/dynamic hash table
//! (`rhashtable`, Thomas Graf 2014, commit `7e1e77636e36`), user-space
//! form per the paper's §6.1 (Nested/Listed-table features omitted).
//!
//! One next pointer per node, **unordered** per-bucket chains, per-bucket
//! spinlocks for updates. A rebuild repeatedly takes a non-empty old
//! bucket and distributes its **tail** node: the node is first spliced
//! into the head of its new-table chain — which momentarily makes the old
//! chain *flow into* the new one — and then removed from the old chain.
//! Lock-free lookups tolerate being redirected into new-table nodes (the
//! key comparison filters them) and fall back to the new table on a miss.
//!
//! The paper's critique (§2), reproduced by `bench fig3`: the rebuild
//! re-traverses the chain for every node (tail distribution is O(n²) per
//! bucket), bucket locks serialize updates, and unordered chains make
//! misses pay full-chain traversals.

use std::sync::atomic::{AtomicPtr, AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

use super::ConcurrentMap;
use crate::dhash::HashFn;
use crate::lflist::spinlock_list::SpinLock;
use crate::rcu::{call_rcu, synchronize_rcu, RcuThread};

struct RhtNode {
    key: u64,
    val: AtomicU64,
    next: AtomicUsize,
}

struct SendRht(*mut RhtNode);
// SAFETY: reclaimer-only access after a grace period.
unsafe impl Send for SendRht {}

/// # Safety
/// `p` must be unlinked (unreachable to new readers) and passed here at
/// most once; the reclaimer frees it after a grace period.
unsafe fn defer_free_rht(p: *mut RhtNode) {
    let w = SendRht(p);
    call_rcu(move || {
        let w = w;
        // SAFETY: grace period elapsed.
        unsafe { drop(Box::from_raw(w.0)) };
    });
}

struct RhtBucket {
    lock: SpinLock,
    head: AtomicUsize,
}

struct RhtTab {
    nbuckets: usize,
    hash: HashFn,
    buckets: Box<[RhtBucket]>,
    ht_new: AtomicPtr<RhtTab>,
}

impl RhtTab {
    fn alloc(nbuckets: usize, hash: HashFn) -> *mut RhtTab {
        assert!(nbuckets > 0);
        let buckets: Box<[RhtBucket]> = (0..nbuckets)
            .map(|_| RhtBucket {
                lock: SpinLock::new(),
                head: AtomicUsize::new(0),
            })
            .collect();
        Box::into_raw(Box::new(RhtTab {
            nbuckets,
            hash,
            buckets,
            ht_new: AtomicPtr::new(std::ptr::null_mut()),
        }))
    }

    #[inline]
    fn bucket(&self, key: u64) -> &RhtBucket {
        &self.buckets[self.hash.bucket(key, self.nbuckets)]
    }

    /// Lock-free unordered search. May walk across the splice point into
    /// new-table nodes during a rebuild; harmless (keys filter) and
    /// bounded (the walk ends at the chain's NULL).
    fn find(&self, key: u64) -> Option<*mut RhtNode> {
        let mut cur = self.bucket(key).head.load(Ordering::SeqCst) as *mut RhtNode;
        while !cur.is_null() {
            // SAFETY: RCU-live.
            unsafe {
                if (*cur).key == key {
                    return Some(cur);
                }
                cur = (*cur).next.load(Ordering::SeqCst) as *mut RhtNode;
            }
        }
        None
    }

    /// Unlink `key` from this bucket's chain.
    ///
    /// # Safety
    /// The bucket lock must be held: the chain cannot change under the
    /// traversal, and every node reached is live until a grace period.
    unsafe fn unlink_locked(&self, key: u64) -> Option<*mut RhtNode> {
        let bucket = self.bucket(key);
        let mut pp: *const AtomicUsize = &bucket.head;
        loop {
            let cur = (*pp).load(Ordering::SeqCst) as *mut RhtNode;
            if cur.is_null() {
                return None;
            }
            if (*cur).key == key {
                let next = (*cur).next.load(Ordering::SeqCst);
                (*pp).store(next, Ordering::SeqCst);
                return Some(cur);
            }
            pp = &(*cur).next;
        }
    }
}

/// The rhashtable-style dynamic hash table.
pub struct HtRht {
    cur: AtomicPtr<RhtTab>,
    rebuild_lock: Mutex<()>,
}

// SAFETY: atomics + per-bucket locks + RCU reclamation.
unsafe impl Send for HtRht {}
unsafe impl Sync for HtRht {}

impl HtRht {
    pub fn new(nbuckets: usize, hash: HashFn) -> Self {
        Self {
            cur: AtomicPtr::new(RhtTab::alloc(nbuckets, hash)),
            rebuild_lock: Mutex::new(()),
        }
    }

    #[inline]
    fn tab(&self) -> &RhtTab {
        // SAFETY: never null; RCU-protected replacement.
        unsafe { &*self.cur.load(Ordering::SeqCst) }
    }
}

impl ConcurrentMap for HtRht {
    fn name(&self) -> &'static str {
        "HT-RHT"
    }

    fn lookup(&self, guard: &RcuThread, key: u64) -> Option<u64> {
        let _g = guard.read_lock();
        let tab = self.tab();
        if let Some(n) = tab.find(key) {
            // SAFETY: RCU-live.
            return Some(unsafe { (*n).val.load(Ordering::SeqCst) });
        }
        let new = tab.ht_new.load(Ordering::SeqCst);
        if !new.is_null() {
            // SAFETY: alive during read-side section.
            if let Some(n) = unsafe { &*new }.find(key) {
                // SAFETY: RCU-live.
                return Some(unsafe { (*n).val.load(Ordering::SeqCst) });
            }
        }
        None
    }

    fn insert(&self, guard: &RcuThread, key: u64, val: u64) -> bool {
        let _g = guard.read_lock();
        let tab = self.tab();
        let ob = tab.bucket(key);
        ob.lock.lock();
        let new_ptr = tab.ht_new.load(Ordering::SeqCst);
        let inserted = if new_ptr.is_null() {
            if tab.find(key).is_some() {
                false
            } else {
                let n = Box::into_raw(Box::new(RhtNode {
                    key,
                    val: AtomicU64::new(val),
                    next: AtomicUsize::new(ob.head.load(Ordering::SeqCst)),
                }));
                ob.head.store(n as usize, Ordering::SeqCst);
                true
            }
        } else {
            // Rebuild in progress: insert goes to the newest table
            // (kernel behaviour). Dup check covers both.
            // SAFETY: alive during section.
            let new = unsafe { &*new_ptr };
            let nb = new.bucket(key);
            nb.lock.lock();
            let dup = tab.find(key).is_some() || new.find(key).is_some();
            let r = if dup {
                false
            } else {
                let n = Box::into_raw(Box::new(RhtNode {
                    key,
                    val: AtomicU64::new(val),
                    next: AtomicUsize::new(nb.head.load(Ordering::SeqCst)),
                }));
                nb.head.store(n as usize, Ordering::SeqCst);
                true
            };
            nb.lock.unlock();
            r
        };
        ob.lock.unlock();
        inserted
    }

    fn delete(&self, guard: &RcuThread, key: u64) -> bool {
        let _g = guard.read_lock();
        let tab = self.tab();
        let ob = tab.bucket(key);
        ob.lock.lock();
        let new_ptr = tab.ht_new.load(Ordering::SeqCst);
        // SAFETY: locks held on each chain we unlink from. A node is in
        // exactly one chain (distribution moves it under both locks).
        let found = unsafe {
            if let Some(n) = tab.unlink_locked(key) {
                defer_free_rht(n);
                true
            } else if !new_ptr.is_null() {
                let new = &*new_ptr;
                let nb = new.bucket(key);
                nb.lock.lock();
                let r = new.unlink_locked(key);
                nb.lock.unlock();
                if let Some(n) = r {
                    defer_free_rht(n);
                    true
                } else {
                    false
                }
            } else {
                false
            }
        };
        ob.lock.unlock();
        found
    }

    fn rebuild(&self, guard: &RcuThread, nbuckets: usize, hash: HashFn) -> bool {
        if nbuckets == 0 {
            return false; // invalid geometry, refused at the boundary
        }
        let lock = match self.rebuild_lock.try_lock() {
            Ok(g) => g,
            Err(_) => return false,
        };
        let old_ptr = self.cur.load(Ordering::SeqCst);
        // SAFETY: rebuild lock held.
        let old = unsafe { &*old_ptr };
        let new_ptr = RhtTab::alloc(nbuckets, hash);
        // SAFETY: fresh.
        let new = unsafe { &*new_ptr };
        old.ht_new.store(new_ptr, Ordering::SeqCst);
        guard.offline_while(synchronize_rcu);

        // Distribute: per old bucket, repeatedly move the TAIL node — the
        // behaviour the paper singles out ("the rebuild thread must reach
        // the tail of a list to distribute a single node").
        for ob in old.buckets.iter() {
            loop {
                ob.lock.lock();
                // Find tail and its predecessor link.
                // SAFETY: old bucket lock held; chain stable.
                let moved = unsafe {
                    let mut pp: *const AtomicUsize = &ob.head;
                    let mut cur = (*pp).load(Ordering::SeqCst) as *mut RhtNode;
                    if cur.is_null() {
                        false
                    } else {
                        loop {
                            let next = (*cur).next.load(Ordering::SeqCst) as *mut RhtNode;
                            if next.is_null() {
                                break;
                            }
                            pp = &(*cur).next;
                            cur = next;
                        }
                        // `cur` is the tail, `pp` the link pointing at it.
                        let key = (*cur).key;
                        let nb = new.bucket(key);
                        nb.lock.lock();
                        // Splice into the new chain head FIRST (the node
                        // is momentarily reachable from both tables;
                        // old-chain walkers flow into the new chain).
                        (*cur)
                            .next
                            .store(nb.head.load(Ordering::SeqCst), Ordering::SeqCst);
                        nb.head.store(cur as usize, Ordering::SeqCst);
                        // Then cut it out of the old chain.
                        (*pp).store(0, Ordering::SeqCst);
                        nb.lock.unlock();
                        true
                    }
                };
                ob.lock.unlock();
                if !moved {
                    break;
                }
            }
        }

        self.cur.store(new_ptr, Ordering::SeqCst);
        guard.offline_while(synchronize_rcu);
        drop(lock);
        // SAFETY: unpublished for a grace period; buckets are empty.
        unsafe { drop(Box::from_raw(old_ptr)) };
        true
    }

    fn len(&self, guard: &RcuThread) -> usize {
        let _g = guard.read_lock();
        let tab = self.tab();
        let mut n = 0;
        for b in tab.buckets.iter() {
            let mut cur = b.head.load(Ordering::SeqCst) as *mut RhtNode;
            while !cur.is_null() {
                n += 1;
                // SAFETY: RCU-live.
                cur = unsafe { (*cur).next.load(Ordering::SeqCst) as *mut RhtNode };
            }
        }
        n
    }
}

impl Drop for HtRht {
    fn drop(&mut self) {
        let tab_ptr = self.cur.load(Ordering::SeqCst);
        // SAFETY: exclusive access.
        unsafe {
            let tab = &*tab_ptr;
            for b in tab.buckets.iter() {
                let mut cur = b.head.load(Ordering::SeqCst) as *mut RhtNode;
                while !cur.is_null() {
                    let next = (*cur).next.load(Ordering::SeqCst) as *mut RhtNode;
                    drop(Box::from_raw(cur));
                    cur = next;
                }
            }
            drop(Box::from_raw(tab_ptr));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rcu::rcu_barrier;
    use std::sync::atomic::AtomicBool;
    use std::sync::Arc;

    #[test]
    fn tail_distribution_preserves_all_keys() {
        let g = RcuThread::register();
        let m = HtRht::new(4, HashFn::Seeded(1));
        for k in 0..200u64 {
            assert!(m.insert(&g, k, k + 7));
        }
        assert!(m.rebuild(&g, 32, HashFn::Seeded(2)));
        assert_eq!(m.len(&g), 200);
        for k in 0..200u64 {
            assert_eq!(m.lookup(&g, k), Some(k + 7));
        }
        g.quiescent_state();
        rcu_barrier();
    }

    #[test]
    fn lookups_tolerate_redirection_under_live_rebuild() {
        // Readers hammer lookups while a big rebuild runs; no persistent
        // key may be missed even when walks cross the splice point.
        let m = Arc::new(HtRht::new(4, HashFn::Seeded(3)));
        let n = 2000u64;
        {
            let g = RcuThread::register();
            for k in 0..n {
                m.insert(&g, k, k);
            }
            g.quiescent_state();
        }
        let stop = Arc::new(AtomicBool::new(false));
        let m2 = m.clone();
        let s2 = stop.clone();
        let reader = std::thread::spawn(move || {
            let g = RcuThread::register();
            let mut rng = crate::util::SplitMix64::new(5);
            let mut misses = 0u64;
            while !s2.load(Ordering::Relaxed) {
                let k = rng.next_bounded(n);
                if m2.lookup(&g, k).is_none() {
                    misses += 1;
                }
                g.quiescent_state();
            }
            misses
        });
        {
            let g = RcuThread::register();
            for i in 0..4u64 {
                m.rebuild(&g, if i % 2 == 0 { 64 } else { 4 }, HashFn::Seeded(i));
            }
            g.quiescent_state();
        }
        stop.store(true, Ordering::Relaxed);
        assert_eq!(reader.join().unwrap(), 0, "HT-RHT lookup missed a key");
        rcu_barrier();
    }
}
