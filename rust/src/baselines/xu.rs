//! HT-Xu: Herbert Xu's dynamic hash table (Linux kernel commit
//! `eb1d16414339`, 2010; user-space form in perfbook's `hash_resize`).
//!
//! Each node carries **two** sets of next pointers. Readers traverse the
//! pointer set named by the current table; a rebuild re-links every node
//! through the *other* set into the new bucket array in a single
//! traversal, then swaps tables. The paper (§2) lists the costs DHash
//! avoids: per-bucket locks serialize updates against each other and
//! against the rebuild, and the doubled pointers bloat every node and
//! lock the design to this one customized list.
//!
//! Faithfulness notes (DESIGN.md §Substitutions): chains are unordered
//! with head insertion (as in the kernel); updates during a rebuild go to
//! the *new* table and lookups check old-then-new, which preserves the
//! algorithm's locking structure without the kernel's bucket-progress
//! bookkeeping.

use std::sync::atomic::{AtomicPtr, AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

use super::ConcurrentMap;
use crate::dhash::HashFn;
use crate::lflist::spinlock_list::SpinLock;
use crate::rcu::{call_rcu, synchronize_rcu, RcuThread};

/// Node with two next-pointer sets (the signature feature of HT-Xu).
struct XuNode {
    key: u64,
    val: AtomicU64,
    next: [AtomicUsize; 2],
}

struct SendXu(*mut XuNode);
// SAFETY: reclaimer-only access after a grace period.
unsafe impl Send for SendXu {}

/// # Safety
/// `p` must be unlinked (unreachable to new readers) and passed here at
/// most once; the reclaimer frees it after a grace period.
unsafe fn defer_free_xu(p: *mut XuNode) {
    let w = SendXu(p);
    call_rcu(move || {
        let w = w;
        // SAFETY: grace period elapsed.
        unsafe { drop(Box::from_raw(w.0)) };
    });
}

struct XuBucket {
    lock: SpinLock,
    head: AtomicUsize,
}

struct XuTab {
    /// Which `next[]` slot this table's chains thread through.
    idx: usize,
    nbuckets: usize,
    hash: HashFn,
    buckets: Box<[XuBucket]>,
    ht_new: AtomicPtr<XuTab>,
    /// Back-pointer to the predecessor table during the retirement window
    /// (between `cur` swap and the old table's free). The two-pointer-set
    /// design keeps every node linked in BOTH tables' chains through the
    /// transition, so updates during the window must maintain both — a
    /// post-swap delete that only purged the new chain would leave a
    /// freed node reachable through the old chains still being traversed
    /// by pre-swap-view operations (use-after-free).
    ht_old: AtomicPtr<XuTab>,
}

impl XuTab {
    fn alloc(idx: usize, nbuckets: usize, hash: HashFn) -> *mut XuTab {
        assert!(nbuckets > 0);
        let buckets: Box<[XuBucket]> = (0..nbuckets)
            .map(|_| XuBucket {
                lock: SpinLock::new(),
                head: AtomicUsize::new(0),
            })
            .collect();
        Box::into_raw(Box::new(XuTab {
            idx,
            nbuckets,
            hash,
            buckets,
            ht_new: AtomicPtr::new(std::ptr::null_mut()),
            ht_old: AtomicPtr::new(std::ptr::null_mut()),
        }))
    }

    #[inline]
    fn bucket(&self, key: u64) -> &XuBucket {
        &self.buckets[self.hash.bucket(key, self.nbuckets)]
    }

    /// Unordered chain search through this table's pointer set.
    /// Caller is inside an RCU read-side section.
    fn find(&self, key: u64) -> Option<*mut XuNode> {
        let mut cur = self.bucket(key).head.load(Ordering::SeqCst) as *mut XuNode;
        while !cur.is_null() {
            // SAFETY: nodes are RCU-reclaimed; alive during read side.
            unsafe {
                if (*cur).key == key {
                    return Some(cur);
                }
                cur = (*cur).next[self.idx].load(Ordering::SeqCst) as *mut XuNode;
            }
        }
        None
    }

    /// Unlink `key` from this table's chain; returns the node if it
    /// was present.
    ///
    /// # Safety
    /// The table lock must be held: the chain cannot change under the
    /// traversal, and every node reached is live until a grace period.
    unsafe fn unlink_locked(&self, key: u64) -> Option<*mut XuNode> {
        let bucket = self.bucket(key);
        let mut pp: *const AtomicUsize = &bucket.head;
        loop {
            let cur = (*pp).load(Ordering::SeqCst) as *mut XuNode;
            if cur.is_null() {
                return None;
            }
            if (*cur).key == key {
                let next = (*cur).next[self.idx].load(Ordering::SeqCst);
                (*pp).store(next, Ordering::SeqCst);
                return Some(cur);
            }
            pp = &(*cur).next[self.idx];
        }
    }
}

/// Herbert Xu's dynamic hash table.
pub struct HtXu {
    cur: AtomicPtr<XuTab>,
    rebuild_lock: Mutex<()>,
}

// SAFETY: atomics + per-bucket locks + RCU reclamation throughout.
unsafe impl Send for HtXu {}
unsafe impl Sync for HtXu {}

impl HtXu {
    pub fn new(nbuckets: usize, hash: HashFn) -> Self {
        Self {
            cur: AtomicPtr::new(XuTab::alloc(0, nbuckets, hash)),
            rebuild_lock: Mutex::new(()),
        }
    }

    #[inline]
    fn tab(&self) -> &XuTab {
        // SAFETY: never null; RCU-protected replacement.
        unsafe { &*self.cur.load(Ordering::SeqCst) }
    }
}

impl ConcurrentMap for HtXu {
    fn name(&self) -> &'static str {
        "HT-Xu"
    }

    fn lookup(&self, guard: &RcuThread, key: u64) -> Option<u64> {
        let _g = guard.read_lock();
        let tab = self.tab();
        if let Some(n) = tab.find(key) {
            // SAFETY: RCU-live.
            return Some(unsafe { (*n).val.load(Ordering::SeqCst) });
        }
        let new = tab.ht_new.load(Ordering::SeqCst);
        if !new.is_null() {
            // SAFETY: ht_new outlives the read-side section.
            let new = unsafe { &*new };
            if let Some(n) = new.find(key) {
                // SAFETY: RCU-live.
                return Some(unsafe { (*n).val.load(Ordering::SeqCst) });
            }
        }
        None
    }

    fn insert(&self, guard: &RcuThread, key: u64, val: u64) -> bool {
        let _g = guard.read_lock();
        let tab = self.tab();
        let ob = tab.bucket(key);
        ob.lock.lock();
        let new_ptr = tab.ht_new.load(Ordering::SeqCst);
        let r = if new_ptr.is_null() {
            // SAFETY: bucket lock held.
            unsafe {
                if tab.find(key).is_some() {
                    false
                } else {
                    let n = Box::into_raw(Box::new(XuNode {
                        key,
                        val: AtomicU64::new(val),
                        next: [
                            AtomicUsize::new(ob.head.load(Ordering::SeqCst)),
                            AtomicUsize::new(0),
                        ],
                    }));
                    // Head insertion through set `idx` only; fix the slot.
                    if tab.idx == 1 {
                        let h = (*n).next[0].swap(0, Ordering::SeqCst);
                        (*n).next[1].store(h, Ordering::SeqCst);
                    }
                    ob.head.store(n as usize, Ordering::SeqCst);
                    true
                }
            }
        } else {
            // Rebuild in progress: insert into the new table (lock order:
            // old bucket, then new bucket — same as the rebuilder).
            // SAFETY: ht_new set ⇒ table alive during this section.
            let new = unsafe { &*new_ptr };
            let nb = new.bucket(key);
            nb.lock.lock();
            let dup = tab.find(key).is_some() || new.find(key).is_some();
            let r = if dup {
                false
            } else {
                let n = Box::into_raw(Box::new(XuNode {
                    key,
                    val: AtomicU64::new(val),
                    next: [AtomicUsize::new(0), AtomicUsize::new(0)],
                }));
                // SAFETY: fresh node, lock held on the new bucket.
                unsafe {
                    (*n).next[new.idx].store(nb.head.load(Ordering::SeqCst), Ordering::SeqCst);
                }
                nb.head.store(n as usize, Ordering::SeqCst);
                true
            };
            nb.lock.unlock();
            r
        };
        ob.lock.unlock();
        r
    }

    fn delete(&self, guard: &RcuThread, key: u64) -> bool {
        let _g = guard.read_lock();
        let tab = self.tab();
        // Resolve the (older, newer) table pair. Pre-swap view: (tab,
        // tab.ht_new). Retirement window view: (tab.ht_old, tab). Locks
        // are always taken older-table-first, so both views agree on
        // order and cannot deadlock.
        let ht_new = tab.ht_new.load(Ordering::SeqCst);
        let ht_old = tab.ht_old.load(Ordering::SeqCst);
        // Phase matters for the free decision below: during the
        // retirement window the *new* chain is authoritative — the old
        // chains are stale (ops that no longer see ht_old delete through
        // the new chain only), so "found in old chain, missing from new"
        // means ALREADY deleted, not "not yet distributed".
        let window = ht_new.is_null() && !ht_old.is_null();
        // SAFETY: tables in transition are freed only after a grace
        // period past their unlinking; we are inside a read-side section.
        let (older, newer): (&XuTab, Option<&XuTab>) = unsafe {
            if !ht_new.is_null() {
                (tab, Some(&*ht_new))
            } else if !ht_old.is_null() {
                (&*ht_old, Some(tab))
            } else {
                (tab, None)
            }
        };
        let ob = older.bucket(key);
        ob.lock.lock();
        // SAFETY: locks held on every chain we unlink from.
        let found = unsafe {
            let in_old = older.unlink_locked(key);
            let in_new = if let Some(newer) = newer {
                let nb = newer.bucket(key);
                nb.lock.lock();
                let r = newer.unlink_locked(key);
                nb.lock.unlock();
                r
            } else {
                None
            };
            // A distributed node lives in both chains; free exactly once.
            match (in_old, in_new) {
                (Some(a), Some(b)) => {
                    debug_assert_eq!(a, b);
                    defer_free_xu(a);
                    true
                }
                (Some(a), None) => {
                    if window {
                        // Stale old-chain entry: a newer-view delete
                        // already removed and scheduled the node through
                        // the authoritative new chain. Freeing here would
                        // be a double free (observed as glibc fastbin
                        // corruption before this guard).
                        false
                    } else {
                        // Pre-swap: the node simply has not been
                        // distributed yet; the old chain is authoritative.
                        defer_free_xu(a);
                        true
                    }
                }
                (None, Some(b)) => {
                    defer_free_xu(b);
                    true
                }
                (None, None) => false,
            }
        };
        ob.lock.unlock();
        found
    }

    fn rebuild(&self, guard: &RcuThread, nbuckets: usize, hash: HashFn) -> bool {
        if nbuckets == 0 {
            return false; // invalid geometry, refused at the boundary
        }
        let lock = match self.rebuild_lock.try_lock() {
            Ok(g) => g,
            Err(_) => return false,
        };
        let old_ptr = self.cur.load(Ordering::SeqCst);
        // SAFETY: rebuild lock held; only rebuilds replace `cur`.
        let old = unsafe { &*old_ptr };
        let new_ptr = XuTab::alloc(1 - old.idx, nbuckets, hash);
        // SAFETY: fresh.
        let new = unsafe { &*new_ptr };
        new.ht_old.store(old_ptr, Ordering::SeqCst);
        old.ht_new.store(new_ptr, Ordering::SeqCst);
        // Updaters that predate ht_new must drain before we distribute.
        guard.offline_while(synchronize_rcu);

        // Single traversal: re-link every node through the spare pointer
        // set. This is why HT-Xu's rebuild is the fastest of the dynamic
        // tables (paper Fig. 3) — and why its nodes are fat.
        for ob in old.buckets.iter() {
            ob.lock.lock();
            let mut cur = ob.head.load(Ordering::SeqCst) as *mut XuNode;
            while !cur.is_null() {
                // SAFETY: old-bucket lock held; chain stable.
                unsafe {
                    let key = (*cur).key;
                    let next_old = (*cur).next[old.idx].load(Ordering::SeqCst);
                    let nb = new.bucket(key);
                    nb.lock.lock();
                    (*cur).next[new.idx].store(nb.head.load(Ordering::SeqCst), Ordering::SeqCst);
                    nb.head.store(cur as usize, Ordering::SeqCst);
                    nb.lock.unlock();
                    cur = next_old as *mut XuNode;
                }
            }
            ob.lock.unlock();
        }

        // Swap tables. During the retirement window the new table's
        // ht_old keeps updates maintaining BOTH chain sets (see field
        // docs); only after every op that could hold either view drains
        // do we sever the link and free the old bucket arrays (nodes
        // live on — that is the two-pointer-set trick).
        self.cur.store(new_ptr, Ordering::SeqCst);
        guard.offline_while(synchronize_rcu);
        new.ht_old.store(std::ptr::null_mut(), Ordering::SeqCst);
        guard.offline_while(synchronize_rcu);
        drop(lock);
        // SAFETY: unpublished for a grace period; nodes are not owned by
        // the table struct.
        unsafe { drop(Box::from_raw(old_ptr)) };
        true
    }

    fn len(&self, guard: &RcuThread) -> usize {
        let _g = guard.read_lock();
        let tab = self.tab();
        let mut n = 0;
        for b in tab.buckets.iter() {
            let mut cur = b.head.load(Ordering::SeqCst) as *mut XuNode;
            while !cur.is_null() {
                n += 1;
                // SAFETY: RCU-live.
                cur = unsafe { (*cur).next[tab.idx].load(Ordering::SeqCst) as *mut XuNode };
            }
        }
        n
    }
}

impl Drop for HtXu {
    fn drop(&mut self) {
        // Exclusive access: free all nodes via the current pointer set,
        // then the table.
        let tab_ptr = self.cur.load(Ordering::SeqCst);
        // SAFETY: exclusive.
        unsafe {
            let tab = &*tab_ptr;
            for b in tab.buckets.iter() {
                let mut cur = b.head.load(Ordering::SeqCst) as *mut XuNode;
                while !cur.is_null() {
                    let next = (*cur).next[tab.idx].load(Ordering::SeqCst) as *mut XuNode;
                    drop(Box::from_raw(cur));
                    cur = next;
                }
            }
            drop(Box::from_raw(tab_ptr));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rcu::rcu_barrier;

    #[test]
    fn xu_two_pointer_sets_alternate() {
        let g = RcuThread::register();
        let m = HtXu::new(8, HashFn::Seeded(1));
        for k in 0..50u64 {
            assert!(m.insert(&g, k, k));
        }
        // idx flips 0 -> 1 -> 0 across rebuilds.
        assert_eq!(m.tab().idx, 0);
        assert!(m.rebuild(&g, 16, HashFn::Seeded(2)));
        assert_eq!(m.tab().idx, 1);
        assert!(m.rebuild(&g, 8, HashFn::Seeded(3)));
        assert_eq!(m.tab().idx, 0);
        assert_eq!(m.len(&g), 50);
        for k in 0..50u64 {
            assert_eq!(m.lookup(&g, k), Some(k));
        }
        g.quiescent_state();
        rcu_barrier();
    }
}
