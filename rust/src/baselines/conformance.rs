//! Behavioral conformance for every [`ConcurrentMap`]: DHash (plain and
//! sharded) and the three baselines must agree on map semantics (the
//! torture framework and all benches assume this).

use super::{ConcurrentMap, HtRht, HtSplit, HtXu};
use crate::dhash::{DHashMap, HashFn, ShardedDHash};
use crate::rcu::{rcu_barrier, RcuThread};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

fn make(name: &str) -> Arc<dyn ConcurrentMap> {
    match name {
        "dhash" => Arc::new(DHashMap::with_buckets(32, 1)),
        // Same geometry, but buckets backed by the recursive
        // split-ordered list instead of Michael lists: the suite is the
        // proof the fourth backend composes without changing semantics.
        "dhash-splitord" => Arc::new(DHashMap::<crate::lflist::SplitOrderedList>::with_hash(
            32,
            HashFn::Seeded(1),
        )),
        // Same 32-bucket budget, split across 4 shards: the suite is the
        // proof that sharding composes without changing map semantics.
        "sharded" => Arc::new(ShardedDHash::with_buckets(4, 8, 1)),
        "xu" => Arc::new(HtXu::new(32, HashFn::Seeded(1))),
        "rht" => Arc::new(HtRht::new(32, HashFn::Seeded(1))),
        "split" => Arc::new(HtSplit::new(32, 1 << 20)),
        _ => unreachable!(),
    }
}

fn crud(m: &dyn ConcurrentMap) {
    let g = RcuThread::register();
    assert_eq!(m.len(&g), 0);
    for k in 0..300u64 {
        assert!(m.insert(&g, k, k + 1), "{} insert {k}", m.name());
    }
    assert!(!m.insert(&g, 10, 99), "{} dup insert", m.name());
    assert_eq!(m.len(&g), 300);
    for k in 0..300u64 {
        assert_eq!(m.lookup(&g, k), Some(k + 1), "{} lookup {k}", m.name());
    }
    assert_eq!(m.lookup(&g, 300), None);
    for k in (0..300u64).step_by(3) {
        assert!(m.delete(&g, k), "{} delete {k}", m.name());
    }
    assert!(!m.delete(&g, 0), "{} double delete", m.name());
    assert_eq!(m.len(&g), 200);
    for k in 0..300u64 {
        assert_eq!(
            m.lookup(&g, k).is_some(),
            k % 3 != 0,
            "{} post-delete lookup {k}",
            m.name()
        );
    }
    // Upsert: last-wins overwrite-or-insert through the facade (the
    // atomicity of the overwrite is a DHash extra; the *semantics* are
    // part of the shared contract).
    assert!(!m.upsert(&g, 1, 777), "{} upsert of present key", m.name());
    assert_eq!(m.lookup(&g, 1), Some(777));
    assert!(m.upsert(&g, 300, 301), "{} upsert of absent key", m.name());
    assert_eq!(m.lookup(&g, 300), Some(301));
    assert_eq!(m.len(&g), 201, "{} upsert must not duplicate", m.name());
    g.quiescent_state();
    rcu_barrier();
}

fn rebuild_preserves(m: &dyn ConcurrentMap) {
    let g = RcuThread::register();
    for k in 0..500u64 {
        m.insert(&g, k * 3, k);
    }
    assert!(m.rebuild(&g, 128, HashFn::Seeded(77)), "{}", m.name());
    assert_eq!(m.len(&g), 500, "{} len after rebuild", m.name());
    for k in 0..500u64 {
        assert_eq!(m.lookup(&g, k * 3), Some(k), "{} key {k}", m.name());
    }
    // Tables that support enumeration must agree with len/lookup.
    if let Some(snap) = m.snapshot(&g) {
        assert_eq!(snap.len(), 500, "{} snapshot after rebuild", m.name());
        assert!(snap.windows(2).all(|w| w[0].0 < w[1].0), "{} unsorted", m.name());
    }
    if let Some(loads) = m.bucket_loads(&g) {
        assert_eq!(loads.iter().sum::<usize>(), 500, "{} loads", m.name());
    }
    assert!(m.rebuild(&g, 16, HashFn::Seeded(78)));
    assert_eq!(m.len(&g), 500);
    g.quiescent_state();
    rcu_barrier();
}

fn lookups_never_miss_during_rebuilds(m: Arc<dyn ConcurrentMap>) {
    let n = 800u64;
    {
        let g = RcuThread::register();
        for k in 0..n {
            m.insert(&g, k, k);
        }
        g.quiescent_state();
    }
    let stop = Arc::new(AtomicBool::new(false));
    let misses = Arc::new(AtomicU64::new(0));
    let started = Arc::new(AtomicU64::new(0));
    let m2 = m.clone();
    let s2 = stop.clone();
    let mi = misses.clone();
    let st2 = started.clone();
    let reader = std::thread::spawn(move || {
        let g = RcuThread::register();
        let mut rng = crate::util::SplitMix64::new(11);
        let mut ops = 0u64;
        while !s2.load(Ordering::Relaxed) {
            let k = rng.next_bounded(n);
            if m2.lookup(&g, k).is_none() {
                mi.fetch_add(1, Ordering::Relaxed);
            }
            ops += 1;
            st2.store(ops, Ordering::Relaxed);
            g.quiescent_state();
        }
        ops
    });
    // On a single-core host the reader may not get scheduled before the
    // rebuild storm finishes; wait for its first ops so the assertion
    // below actually measures lookups *during* rebuilds.
    while started.load(Ordering::Relaxed) < 16 {
        std::thread::yield_now();
    }
    {
        let g = RcuThread::register();
        for i in 0..6u64 {
            m.rebuild(&g, if i % 2 == 0 { 128 } else { 16 }, HashFn::Seeded(i));
        }
        g.quiescent_state();
    }
    stop.store(true, Ordering::Relaxed);
    let ops = reader.join().unwrap();
    assert!(ops > 0);
    assert_eq!(
        misses.load(Ordering::Relaxed),
        0,
        "{}: lookups missed keys during rebuild",
        m.name()
    );
    rcu_barrier();
}

fn concurrent_update_churn(m: Arc<dyn ConcurrentMap>) {
    let stop = Arc::new(AtomicBool::new(false));
    let mut hs = Vec::new();
    for t in 0..3u64 {
        let m2 = m.clone();
        let s2 = stop.clone();
        hs.push(std::thread::spawn(move || {
            let g = RcuThread::register();
            let base = t * 1000;
            // Toggle pattern (see dhash::tests): insert only when
            // believed absent, delete only when believed present — the
            // outcome guarantees every evaluated table makes.
            let mut present = vec![false; 200];
            let mut rng = crate::util::SplitMix64::new(t + 50);
            let mut iters = 0u64;
            while !s2.load(Ordering::Relaxed) {
                let i = rng.next_bounded(200) as usize;
                let k = base + i as u64;
                if present[i] {
                    assert!(
                        m2.lookup(&g, k).is_some(),
                        "{}: present key {k} missed",
                        m2.name()
                    );
                    assert!(m2.delete(&g, k), "{}: delete of present {k}", m2.name());
                    present[i] = false;
                } else {
                    assert!(m2.insert(&g, k, k), "{}: insert of absent {k}", m2.name());
                    present[i] = true;
                }
                g.quiescent_state();
                iters += 1;
            }
            g.offline();
            iters
        }));
    }
    // Rebuild churn in parallel.
    {
        let g = RcuThread::register();
        for i in 0..6u64 {
            m.rebuild(&g, if i % 2 == 0 { 8 } else { 64 }, HashFn::Seeded(i + 5));
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        g.quiescent_state();
    }
    stop.store(true, Ordering::Relaxed);
    let total: u64 = hs.into_iter().map(|h| h.join().unwrap()).sum();
    assert!(total > 100, "{}: too few iterations {total}", m.name());
    rcu_barrier();
}

macro_rules! map_suite {
    ($modname:ident, $key:literal) => {
        mod $modname {
            use super::*;

            #[test]
            fn crud() {
                super::crud(&*make($key));
            }
            #[test]
            fn rebuild_preserves() {
                super::rebuild_preserves(&*make($key));
            }
            #[test]
            fn lookups_never_miss_during_rebuilds() {
                super::lookups_never_miss_during_rebuilds(make($key));
            }
            #[test]
            fn concurrent_update_churn() {
                super::concurrent_update_churn(make($key));
            }
        }
    };
}

map_suite!(dhash, "dhash");
map_suite!(dhash_splitord, "dhash-splitord");
map_suite!(sharded, "sharded");
map_suite!(xu, "xu");
map_suite!(rht, "rht");
map_suite!(split, "split");

/// The headline-satellite regression: the *default* `upsert` (the one
/// the baselines inherit from `map.rs`) must never lose its write to a
/// concurrent `insert` landing inside its delete→re-insert window.
/// Before the bounded retry fix the conflict was swallowed
/// (`let _ = self.insert(...)`) and the racing insert's value stayed in
/// the table while upsert reported an overwrite — a silent lost write.
/// Fails against the old default; passes against the retry loop.
#[test]
fn default_upsert_never_loses_to_concurrent_inserts() {
    const ROUNDS: u64 = 400;
    const GOOD: u64 = 1 << 40;
    const BAD: u64 = 2 << 40;
    // HtXu does not override the trait default — this hammers map.rs.
    let m = make("xu");
    let barrier = Arc::new(std::sync::Barrier::new(4));
    let mut hs = Vec::new();
    // One upserter: its GOOD value must be what the table holds once
    // the round quiesces, every round.
    {
        let m2 = m.clone();
        let b2 = barrier.clone();
        hs.push(std::thread::spawn(move || {
            let g = RcuThread::register();
            for k in 0..ROUNDS {
                b2.wait();
                m2.upsert(&g, k, GOOD);
                b2.wait();
                g.quiescent_state();
            }
            g.offline();
        }));
    }
    // Two inserters hammering the same key with a bounded burst, aimed
    // at the upserter's delete→insert window.
    for _ in 0..2 {
        let m2 = m.clone();
        let b2 = barrier.clone();
        hs.push(std::thread::spawn(move || {
            let g = RcuThread::register();
            for k in 0..ROUNDS {
                b2.wait();
                for _ in 0..64 {
                    m2.insert(&g, k, BAD);
                }
                b2.wait();
                g.quiescent_state();
            }
            g.offline();
        }));
    }
    let g = RcuThread::register();
    for k in 0..ROUNDS {
        // Pre-populate so the upsert takes the delete→re-insert path.
        assert!(m.insert(&g, k, BAD), "round key {k} must start fresh");
        barrier.wait();
        barrier.wait();
        // The upserter has returned and nothing deletes: last-wins says
        // GOOD is visible now and forever (inserts cannot overwrite).
        assert_eq!(
            m.lookup(&g, k),
            Some(GOOD),
            "{}: upsert lost its write to a concurrent insert (round {k})",
            m.name()
        );
        g.quiescent_state();
    }
    for h in hs {
        h.join().unwrap();
    }
    rcu_barrier();
}

/// Last-wins agreement, audited: three writers issue upsert / insert /
/// delete over a small shared key space while each tracks its own last
/// "open" write per key (an upsert or successful insert opens one; any
/// delete closes it — if the value was present it is removed, and
/// values are globally unique so a closed write can never reappear).
/// At the end, every surviving value must be its writer's last open
/// write of that key: no resurrection, no lost overwrite.
fn last_wins_agreement(m: Arc<dyn ConcurrentMap>) {
    const KEYS: u64 = 128;
    const OPS: u64 = 4000;
    const THREADS: u64 = 3;
    let mut hs = Vec::new();
    for t in 0..THREADS {
        let m2 = m.clone();
        hs.push(std::thread::spawn(move || {
            let g = RcuThread::register();
            let mut rng = crate::util::SplitMix64::new(t + 99);
            let mut last_open: Vec<Option<u64>> = vec![None; KEYS as usize];
            for seq in 0..OPS {
                let k = rng.next_bounded(KEYS);
                let v = (t + 1) * 1_000_000_000 + seq; // globally unique
                match rng.next_bounded(4) {
                    0 | 1 => {
                        m2.upsert(&g, k, v);
                        last_open[k as usize] = Some(v);
                    }
                    2 => {
                        if m2.insert(&g, k, v) {
                            last_open[k as usize] = Some(v);
                        }
                    }
                    _ => {
                        m2.delete(&g, k);
                        last_open[k as usize] = None;
                    }
                }
                if seq % 64 == 0 {
                    g.quiescent_state();
                }
            }
            g.quiescent_state();
            g.offline();
            last_open
        }));
    }
    let views: Vec<Vec<Option<u64>>> = hs.into_iter().map(|h| h.join().unwrap()).collect();
    let g = RcuThread::register();
    for k in 0..KEYS {
        if let Some(v) = m.lookup(&g, k) {
            let t = (v / 1_000_000_000) as usize - 1;
            assert!(t < views.len(), "{}: key {k} holds foreign value {v}", m.name());
            assert_eq!(
                views[t][k as usize],
                Some(v),
                "{}: key {k} holds {v}, not its writer's last open write",
                m.name()
            );
        }
    }
    g.quiescent_state();
    rcu_barrier();
}

/// The agreement audit across `DHashMap` over each of the four bucket
/// backends — same facade, same contract, four very different engines.
mod last_wins {
    use super::*;
    use crate::lflist::{CowSortedArray, MichaelList, SpinlockList, SplitOrderedList};

    #[test]
    fn michael() {
        last_wins_agreement(Arc::new(DHashMap::<MichaelList>::with_hash(
            32,
            HashFn::Seeded(5),
        )));
    }

    #[test]
    fn spinlock() {
        last_wins_agreement(Arc::new(DHashMap::<SpinlockList>::with_hash(
            32,
            HashFn::Seeded(5),
        )));
    }

    #[test]
    fn cow() {
        last_wins_agreement(Arc::new(DHashMap::<CowSortedArray>::with_hash(
            32,
            HashFn::Seeded(5),
        )));
    }

    #[test]
    fn split_ordered() {
        // Two outer buckets: ~64 hot keys land in each split-ordered
        // list, so its local sentinel directory doubles repeatedly
        // mid-churn — the agreement must hold across local growth.
        last_wins_agreement(Arc::new(DHashMap::<SplitOrderedList>::with_hash(
            2,
            HashFn::Seeded(5),
        )));
    }
}

/// `ShardedDHash` **with online resizes**: the full `ConcurrentMap`
/// contract must hold while the shard count itself moves (splits and
/// merges through the directory), not just across per-shard rebuilds.
/// The trait has no resize surface, so these drive ops through the
/// facade and resizes through the concrete handle — exactly how the
/// coordinator composes them.
mod sharded_elastic {
    use super::*;

    #[test]
    fn crud_holds_across_split_and_merge() {
        let m = ShardedDHash::with_buckets(2, 8, 1);
        let g = RcuThread::register();
        for k in 0..300u64 {
            assert!(ConcurrentMap::insert(&m, &g, k, k + 1), "insert {k}");
        }
        m.split_shard(&g, 0, 16, HashFn::Seeded(7)).unwrap();
        m.split_shard(&g, 2, 16, HashFn::Seeded(8)).unwrap();
        assert_eq!(m.shards(), 4);
        // The facade's view is unchanged by the resizes.
        assert_eq!(ConcurrentMap::len(&m, &g), 300);
        assert!(!ConcurrentMap::insert(&m, &g, 10, 99), "dup insert");
        for k in (0..300u64).step_by(3) {
            assert!(ConcurrentMap::delete(&m, &g, k), "delete {k}");
        }
        assert!(!ConcurrentMap::upsert(&m, &g, 1, 777), "upsert present");
        assert_eq!(ConcurrentMap::lookup(&m, &g, 1), Some(777));
        // Merge everything back down to one shard; semantics unchanged.
        while m.shards() > 1 {
            let mut merged = false;
            for s in 0..m.shards() {
                if m.buddy_of(&g, s).is_some() {
                    m.merge_shard(&g, s, 32, HashFn::Seeded(9)).unwrap();
                    merged = true;
                    break;
                }
            }
            assert!(merged, "no mergeable pair above one shard");
        }
        assert_eq!(ConcurrentMap::len(&m, &g), 200);
        for k in 0..300u64 {
            assert_eq!(
                ConcurrentMap::lookup(&m, &g, k).is_some(),
                k % 3 != 0,
                "post-merge lookup {k}"
            );
        }
        let snap = ConcurrentMap::snapshot(&m, &g).unwrap();
        assert_eq!(snap.len(), 200);
        assert!(snap.windows(2).all(|w| w[0].0 < w[1].0), "unsorted");
        let loads = ConcurrentMap::bucket_loads(&m, &g).unwrap();
        assert_eq!(loads.iter().sum::<usize>(), 200);
        g.quiescent_state();
        rcu_barrier();
    }

    #[test]
    fn lookups_never_miss_during_resizes() {
        // The conformance reader-vs-rebuild race, with the geometry
        // change being the shard count itself: a reader hammering
        // always-present keys must never observe a miss while shards
        // split and merge under it.
        let m = Arc::new(ShardedDHash::with_buckets(2, 32, 3));
        let n = 800u64;
        {
            let g = RcuThread::register();
            for k in 0..n {
                m.insert(&g, k, k).unwrap();
            }
            g.quiescent_state();
        }
        let stop = Arc::new(AtomicBool::new(false));
        let misses = Arc::new(AtomicU64::new(0));
        let started = Arc::new(AtomicU64::new(0));
        let m2 = m.clone();
        let s2 = stop.clone();
        let mi = misses.clone();
        let st2 = started.clone();
        let reader = std::thread::spawn(move || {
            let g = RcuThread::register();
            let mut rng = crate::util::SplitMix64::new(11);
            let mut ops = 0u64;
            while !s2.load(Ordering::Relaxed) {
                let k = rng.next_bounded(n);
                if m2.lookup(&g, k).is_none() {
                    mi.fetch_add(1, Ordering::Relaxed);
                }
                ops += 1;
                st2.store(ops, Ordering::Relaxed);
                g.quiescent_state();
            }
            ops
        });
        while started.load(Ordering::Relaxed) < 16 {
            std::thread::yield_now();
        }
        {
            let g = RcuThread::register();
            for i in 0..3u64 {
                m.split_shard(&g, 0, 32, HashFn::Seeded(i)).unwrap();
                m.split_shard(&g, 1, 32, HashFn::Seeded(i + 5)).unwrap();
                while m.shards() > 2 {
                    let s = (0..m.shards())
                        .find(|&s| m.buddy_of(&g, s).is_some())
                        .expect("a mergeable pair exists above the base depth");
                    m.merge_shard(&g, s, 32, HashFn::Seeded(i + 9)).unwrap();
                }
            }
            g.quiescent_state();
        }
        stop.store(true, Ordering::Relaxed);
        let ops = reader.join().unwrap();
        assert!(ops > 0);
        assert_eq!(
            misses.load(Ordering::Relaxed),
            0,
            "lookups missed present keys during split/merge"
        );
        rcu_barrier();
    }

    #[test]
    fn concurrent_update_churn_across_resizes() {
        // The toggle-pattern writers from the shared suite, racing a
        // split/merge storm instead of plain rebuilds: inserts of absent
        // keys and deletes of present keys must keep their outcome
        // guarantees across every epoch.
        let m = Arc::new(ShardedDHash::with_buckets(2, 16, 5));
        let stop = Arc::new(AtomicBool::new(false));
        let mut hs = Vec::new();
        for t in 0..3u64 {
            let m2 = m.clone();
            let s2 = stop.clone();
            hs.push(std::thread::spawn(move || {
                let g = RcuThread::register();
                let base = t * 1000;
                let mut present = vec![false; 200];
                let mut rng = crate::util::SplitMix64::new(t + 50);
                let mut iters = 0u64;
                while !s2.load(Ordering::Relaxed) {
                    let i = rng.next_bounded(200) as usize;
                    let k = base + i as u64;
                    if present[i] {
                        assert!(m2.lookup(&g, k).is_some(), "present key {k} missed");
                        assert!(m2.delete(&g, k), "delete of present {k}");
                        present[i] = false;
                    } else {
                        assert!(m2.insert(&g, k, k).is_ok(), "insert of absent {k}");
                        present[i] = true;
                    }
                    g.quiescent_state();
                    iters += 1;
                }
                g.offline();
                (iters, present.iter().filter(|&&p| p).count())
            }));
        }
        let mut resizes = 0u64;
        {
            let g = RcuThread::register();
            for i in 0..4u64 {
                m.split_shard(&g, (i % 2) as usize, 16, HashFn::Seeded(i)).unwrap();
                resizes += 1;
                std::thread::sleep(std::time::Duration::from_millis(5));
                while m.shards() > 2 {
                    let s = (0..m.shards())
                        .find(|&s| m.buddy_of(&g, s).is_some())
                        .expect("mergeable pair");
                    m.merge_shard(&g, s, 16, HashFn::Seeded(i + 31)).unwrap();
                    resizes += 1;
                }
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
            g.quiescent_state();
        }
        stop.store(true, Ordering::Relaxed);
        let results: Vec<(u64, usize)> = hs.into_iter().map(|h| h.join().unwrap()).collect();
        let total: u64 = results.iter().map(|r| r.0).sum();
        assert!(total > 100, "too few iterations {total}");
        assert!(resizes >= 8, "resize storm too small: {resizes}");
        // Final audit: the map holds exactly what the writers believe.
        let g = RcuThread::register();
        let expect: usize = results.iter().map(|r| r.1).sum();
        assert_eq!(m.len(&g), expect, "population diverged from writers' view");
        g.quiescent_state();
        rcu_barrier();
    }
}
