//! The comparison hash tables from the paper's evaluation (§6.1), built
//! from scratch:
//!
//! * [`HtXu`] — Herbert Xu's dynamic hash table (Linux kernel, 2010):
//!   **two sets of next pointers** per node, per-bucket locks for updates,
//!   single-traversal rebuild that re-links every node through the spare
//!   pointer set and swaps sets at the end.
//! * [`HtRht`] — Thomas Graf's `rhashtable` (Linux kernel, 2014): single
//!   pointer set, per-bucket locks, **unordered** chains, rebuild
//!   distributes the **tail** node of each chain (so lookups may be
//!   redirected into the new table and must tolerate it).
//! * [`HtSplit`] — Shalev & Shavit's split-ordered list (2006): one
//!   lock-free list in bit-reversed key order, dummy nodes per bucket,
//!   resizable only (buckets double/halve; the hash function is fixed
//!   `key mod 2^i`).
//!
//! All four tables (the three above plus `DHashMap`) implement
//! [`ConcurrentMap`], the object-safe trait the torture framework and the
//! benches drive.

pub mod rht;
pub mod split;
pub mod xu;

pub use rht::HtRht;
pub use split::HtSplit;
pub use xu::HtXu;

use crate::dhash::{DHashMap, HashFn};
use crate::lflist::BucketSet;
use crate::rcu::RcuThread;

/// Object-safe facade over the four evaluated hash tables.
pub trait ConcurrentMap: Send + Sync + 'static {
    /// Display name used in bench output (`HT-DHash`, `HT-Xu`, ...).
    fn name(&self) -> &'static str;

    /// Value for `key`, if present.
    fn lookup(&self, guard: &RcuThread, key: u64) -> Option<u64>;

    /// Insert; false if the key already exists.
    fn insert(&self, guard: &RcuThread, key: u64, val: u64) -> bool;

    /// Delete; false if absent.
    fn delete(&self, guard: &RcuThread, key: u64) -> bool;

    /// Dynamically change the table geometry / hash function.
    ///
    /// For the two dynamic tables this installs `hash`; for the resizable
    /// `HtSplit`, `hash` is ignored (the paper's §6.2 protocol degrades
    /// everyone to resizing for comparability anyway) and only the power-
    /// of-two bucket count applies. Returns false if another rebuild is in
    /// flight.
    fn rebuild(&self, guard: &RcuThread, nbuckets: usize, hash: HashFn) -> bool;

    /// Live entries (O(n), diagnostic).
    fn len(&self, guard: &RcuThread) -> usize;

    /// True when no live entries exist (O(n), diagnostic).
    fn is_empty(&self, guard: &RcuThread) -> bool {
        self.len(guard) == 0
    }
}

impl<B: BucketSet> ConcurrentMap for DHashMap<B> {
    fn name(&self) -> &'static str {
        "HT-DHash"
    }

    fn lookup(&self, guard: &RcuThread, key: u64) -> Option<u64> {
        DHashMap::lookup(self, guard, key)
    }

    fn insert(&self, guard: &RcuThread, key: u64, val: u64) -> bool {
        DHashMap::insert(self, guard, key, val).is_ok()
    }

    fn delete(&self, guard: &RcuThread, key: u64) -> bool {
        DHashMap::delete(self, guard, key)
    }

    fn rebuild(&self, guard: &RcuThread, nbuckets: usize, hash: HashFn) -> bool {
        DHashMap::rebuild(self, guard, nbuckets, hash).is_ok()
    }

    fn len(&self, guard: &RcuThread) -> usize {
        DHashMap::len(self, guard)
    }
}

#[cfg(test)]
mod conformance;
