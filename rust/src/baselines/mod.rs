//! The comparison hash tables from the paper's evaluation (§6.1), built
//! from scratch:
//!
//! * [`HtXu`] — Herbert Xu's dynamic hash table (Linux kernel, 2010):
//!   **two sets of next pointers** per node, per-bucket locks for updates,
//!   single-traversal rebuild that re-links every node through the spare
//!   pointer set and swaps sets at the end.
//! * [`HtRht`] — Thomas Graf's `rhashtable` (Linux kernel, 2014): single
//!   pointer set, per-bucket locks, **unordered** chains, rebuild
//!   distributes the **tail** node of each chain (so lookups may be
//!   redirected into the new table and must tolerate it).
//! * [`HtSplit`] — Shalev & Shavit's split-ordered list (2006): one
//!   lock-free list in bit-reversed key order, dummy nodes per bucket,
//!   resizable only (buckets double/halve; the hash function is fixed
//!   `key mod 2^i`).
//!
//! All evaluated tables (the three above plus `DHashMap` and the sharded
//! `ShardedDHash`) implement [`ConcurrentMap`], the object-safe facade
//! the torture framework, the coordinator, and the benches drive. The
//! trait itself lives in [`crate::map`] (re-exported here for existing
//! call sites).

pub mod rht;
pub mod split;
pub mod xu;

pub use rht::HtRht;
pub use split::HtSplit;
pub use xu::HtXu;

pub use crate::map::ConcurrentMap;

#[cfg(test)]
mod conformance;
