//! `ShardedDHash` — independent [`DHashMap`] shards behind one map
//! facade, with an **elastic shard count**: shards split and merge online
//! through an epoch-stamped routing directory (the ROADMAP's "sharding"
//! and "elastic shard count" scaling items).
//!
//! Why shard: a single `DHashMap` serializes every rebuild behind one
//! `rebuild_lock` and migrates the whole keyspace per mitigation. With N
//! shards, each shard is an independent DHash instance that rebuilds on
//! its own: an attack mitigation migrates 1/N of the keys, and the
//! whole-map [`ShardedDHash::rebuild_all`] staggers shard migrations one
//! at a time so the migration working set stays bounded.
//!
//! Routing: a *fixed* pre-hash (`mix64(key ^ SHARD_SALT)`) that is
//! deliberately independent of the per-shard [`HashFn`]. The top `depth`
//! bits of the pre-hash index an immutable, RCU-published [`Directory`]
//! of slots, each naming the shard serving that selector range. A
//! rebuild replaces a shard's hash function but never re-routes keys
//! across shards, so all of the per-shard Lemma-4.1 reasoning carries
//! over by composition; a **split/merge extends or retracts selector
//! bits** (consistent-hashing style), so the selector *input* never
//! changes either — per-key lane routing upstream stays fixed forever.
//!
//! Elasticity: [`ShardedDHash::split_shard`] migrates one shard's keys
//! into two children (each child serves one more selector bit);
//! [`ShardedDHash::merge_shard`] is the inverse, folding a buddy pair
//! into one shard. Both run concurrently with lookup / insert / delete /
//! upsert using the same hazard-period protocol as `DHashMap::rebuild`:
//! during a migration, the affected slots carry a `prev` pointer to the
//! source shard, and ops check **source → hazard node → destination** in
//! that order (the cross-shard Lemma 4.1 — see `lookup`).
//!
//! Staggered-migration invariant: **at most one migration (split, merge,
//! or rebuild) is in flight at any moment.** Every migration path
//! funnels through a single token; the `migrating` gauge is asserted to
//! have been 0 on every acquisition. Targeted operations *trylock* the
//! token (returning [`RebuildBusy`] / [`ResizeError::Busy`] like the
//! paper's `-EBUSY`), while the whole-map sweep blocks for it between
//! shards — offline, so a token holder's grace periods are never
//! stalled.

use std::collections::HashSet;
use std::sync::atomic::{AtomicPtr, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crossbeam_utils::CachePadded;

use super::{DHashMap, HashFn, KeyExists, RebuildBusy, RebuildStats};
use crate::lflist::{BucketSet, MichaelList, Node, LOGICALLY_REMOVED};
use crate::rcu::{synchronize_rcu, RcuThread};
use crate::util::rng::mix64;

/// Salt for the shard-selector pre-hash. A public constant on purpose:
/// shard routing is *not* a secret (an adversary aiming at one shard is
/// exactly the scenario targeted mitigation handles); what matters is
/// that routing never changes when a mitigation installs a fresh seed.
const SHARD_SALT: u64 = 0x9E37_79B9_7F4A_7C15;

/// Directory depth cap: a split that would need more than `2^MAX_DEPTH`
/// slots fails with [`ResizeError::AtMaxDepth`]. 4096 slots is far past
/// any shard count this crate targets; the cap exists so a runaway
/// split loop cannot allocate unbounded directories.
const MAX_DEPTH: u32 = 12;

/// The shard for `key` among `nshards` (a power of two) shards: the top
/// `log2(nshards)` bits of `mix64(key ^ SHARD_SALT)`. Top bits keep the
/// selector independent of [`HashFn::Seeded`], which consumes the low
/// bits of the same mixer through its modulo.
///
/// This is the *uniform* selector: ingest lanes and the attack
/// generators use it over a fixed count. The map itself routes through
/// its directory ([`ShardedDHash::shard_of`]), which agrees with this
/// function whenever every shard sits at the same depth — and is a pure
/// bit-extension of it otherwise.
#[inline(always)]
pub fn shard_of(key: u64, nshards: usize) -> usize {
    debug_assert!(nshards.is_power_of_two());
    if nshards <= 1 {
        return 0;
    }
    (mix64(key ^ SHARD_SALT) >> (64 - nshards.trailing_zeros())) as usize
}

/// The directory slot for `key` at `depth` (top `depth` selector bits).
#[inline(always)]
fn slot_index(key: u64, depth: u32) -> usize {
    if depth == 0 {
        return 0;
    }
    (mix64(key ^ SHARD_SALT) >> (64 - depth)) as usize
}

/// Error from [`ShardedDHash::split_shard`] / [`ShardedDHash::merge_shard`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ResizeError {
    /// Another migration (split, merge, or rebuild) holds the token.
    Busy,
    /// The shard ordinal does not exist under the current directory.
    NoSuchShard,
    /// Split: the directory is at its depth cap ([`MAX_DEPTH`] selector
    /// bits).
    AtMaxDepth,
    /// Merge: the shard has no mergeable buddy (single shard, or the
    /// buddy range is split deeper).
    Unmergeable,
    /// The requested geometry is invalid (zero buckets). Validated at
    /// the resize/rebuild boundary so a malformed wire or CLI request
    /// gets a typed refusal instead of tripping [`Table`]'s internal
    /// `nbuckets > 0` invariant assert deep in the kernel path.
    BadGeometry,
}

impl std::fmt::Display for ResizeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ResizeError::Busy => write!(f, "a migration is already in progress"),
            ResizeError::NoSuchShard => write!(f, "no such shard ordinal"),
            ResizeError::AtMaxDepth => write!(f, "directory is at its depth cap"),
            ResizeError::Unmergeable => write!(f, "shard has no mergeable buddy"),
            ResizeError::BadGeometry => write!(f, "requested geometry is invalid"),
        }
    }
}

impl std::error::Error for ResizeError {}

/// One selector range's routing entry.
struct Slot<B: BucketSet> {
    /// The shard serving this range (the *destination* during a
    /// migration).
    map: Arc<DHashMap<B>>,
    /// During a split/merge, the shard this range's keys are migrating
    /// *from*; checked before `map` (the cross-shard Lemma-4.1 order).
    prev: Option<Arc<DHashMap<B>>>,
    /// Dense ordinal of `map` in slot order (the shard id every
    /// shard-indexed API speaks). Reassigned on every directory build.
    shard: usize,
    /// Stable identity of `map`, assigned once when the shard is
    /// created and never reused: ordinals shift when the directory
    /// changes shape, uids don't. Controller state (mitigation
    /// cooldowns) keys on this, so a shard born from a resize starts
    /// cold while untouched shards keep their clocks across epochs.
    uid: u64,
}

impl<B: BucketSet> Clone for Slot<B> {
    fn clone(&self) -> Self {
        Slot {
            map: self.map.clone(),
            prev: self.prev.clone(),
            shard: self.shard,
            uid: self.uid,
        }
    }
}

/// Scoped holder of the migration gauge: increments on entry (asserting
/// the staggered invariant: it was 0), decrements on drop — the single
/// owner of the invariant for every migration path (rebuild, sweep
/// step, split, merge).
struct MigrationGauge<'a>(&'a AtomicUsize);

impl<'a> MigrationGauge<'a> {
    fn enter(gauge: &'a AtomicUsize) -> Self {
        // AcqRel: the migration token's Mutex already orders every
        // enter/drop pair (at most one holder); the RMW only needs to
        // keep the gauge itself coherent for `migrating_shards`
        // observers, not to fence unrelated protocol state.
        // ord: sharded-dir — mirrors-first directory install / Acquire route reads
        let prev = gauge.fetch_add(1, Ordering::AcqRel);
        assert_eq!(
            prev, 0,
            "staggered-migration invariant violated: a migration is already in flight"
        );
        Self(gauge)
    }
}

impl Drop for MigrationGauge<'_> {
    fn drop(&mut self) {
        // AcqRel: see `enter` — token-serialized, gauge-local coherence.
        // ord: sharded-gauge — migration gauge AcqRel RMW; token serializes transitions
        self.0.fetch_sub(1, Ordering::AcqRel);
    }
}

/// The epoch-stamped routing directory: an immutable snapshot of the
/// shard layout, RCU-published like a `Table` (readers deref it inside a
/// read-side section; split/merge swap the pointer and free the old
/// directory a grace period later). `2^depth` slots; several contiguous
/// slots may alias one shard (its selector prefix is shorter than
/// `depth`).
struct Directory<B: BucketSet> {
    /// Monotone stamp, bumped once per split/merge. Routing decisions
    /// cached outside a read-side section (the batcher's pre-route ids)
    /// carry the epoch so staleness is detectable, never silent.
    epoch: u64,
    /// Selector depth: slot = top `depth` bits of the pre-hash.
    depth: u32,
    slots: Box<[Slot<B>]>,
    /// Ordinal -> first slot index of that shard (distinct maps appear
    /// as contiguous slot runs by construction).
    shard_slots: Box<[usize]>,
}

impl<B: BucketSet> Directory<B> {
    /// Renumber `slots` ordinals densely in slot order and box the
    /// directory up for publication. Asserts the contiguity invariant.
    fn build(epoch: u64, depth: u32, mut slots: Vec<Slot<B>>) -> *mut Directory<B> {
        assert_eq!(slots.len(), 1usize << depth);
        let mut shard_slots = Vec::new();
        for i in 0..slots.len() {
            let fresh = i == 0 || !Arc::ptr_eq(&slots[i].map, &slots[i - 1].map);
            if fresh {
                shard_slots.push(i);
            }
            slots[i].shard = shard_slots.len() - 1;
            debug_assert!(
                fresh || slots[i].shard == slots[i - 1].shard,
                "aliased slots must be contiguous"
            );
        }
        // reclaim: dir — owned raw until installed via install_dir
        Box::into_raw(Box::new(Directory {
            epoch,
            depth,
            slots: slots.into_boxed_slice(),
            shard_slots: shard_slots.into_boxed_slice(),
        }))
    }

    #[inline(always)]
    fn slot_of(&self, key: u64) -> &Slot<B> {
        &self.slots[slot_index(key, self.depth)]
    }

    fn nshards(&self) -> usize {
        self.shard_slots.len()
    }

    fn shard_map(&self, s: usize) -> &Arc<DHashMap<B>> {
        &self.slots[self.shard_slots[s]].map
    }

    /// The slot range `[lo, hi)` shard `s` serves.
    fn shard_range(&self, s: usize) -> (usize, usize) {
        let lo = self.shard_slots[s];
        let hi = self
            .shard_slots
            .get(s + 1)
            .copied()
            .unwrap_or(self.slots.len());
        (lo, hi)
    }

    /// The ordinal of shard `s`'s merge buddy, if the buddy serves
    /// exactly the sibling selector range at the same depth.
    fn buddy_of(&self, s: usize) -> Option<usize> {
        if self.nshards() <= 1 {
            return None;
        }
        let (lo, hi) = self.shard_range(s);
        let size = hi - lo;
        let blo = lo ^ size; // sibling prefix: flip the last prefix bit
        let b = self.slots[blo].shard;
        if b == s {
            return None;
        }
        let (b_lo, b_hi) = self.shard_range(b);
        (b_lo == blo && b_hi - b_lo == size).then_some(b)
    }
}

/// A coherent routing observation of the whole directory, read from ONE
/// directory pointer: the epoch, every shard's `(HashFn, nbuckets)`
/// geometry, and the selector→shard mapping. This is the routing
/// oracle's input for the vectorized `batch_hash_multi` pre-sort — the
/// epoch lets a consumer detect that ids it computed describe a retired
/// layout (a split/merge landed meanwhile) instead of silently sorting
/// by them.
#[derive(Clone, Debug)]
pub struct RouteSnapshot {
    /// Directory epoch this snapshot describes.
    pub epoch: u64,
    /// Shard ordinal -> routing geometry, each pair read from a single
    /// table pointer ([`DHashMap::geometry`]), so a shard's old hash is
    /// never paired with its new bucket count, even mid-rebuild.
    pub shards: Vec<(HashFn, usize)>,
    /// Shard ordinal -> stable shard uid (never reused across resizes).
    /// Per-shard state that must survive epoch changes — the
    /// controller's mitigation cooldowns — keys on this, not on the
    /// ordinal.
    pub uids: Vec<u64>,
    depth: u32,
    slot_shard: Box<[u32]>,
}

impl RouteSnapshot {
    /// A snapshot of a uniform layout (every shard at the same depth)
    /// with identical geometry — what a freshly constructed map reports.
    /// Test/diagnostic use.
    pub fn uniform(nshards: usize, geometry: (HashFn, usize)) -> RouteSnapshot {
        assert!(nshards >= 1 && nshards.is_power_of_two());
        RouteSnapshot {
            epoch: 0,
            shards: vec![geometry; nshards],
            uids: (0..nshards as u64).collect(),
            depth: nshards.trailing_zeros(),
            slot_shard: (0..nshards as u32).collect(),
        }
    }

    /// The shard ordinal `key` routes to under this snapshot.
    #[inline(always)]
    pub fn shard_of(&self, key: u64) -> u32 {
        self.slot_shard[slot_index(key, self.depth)]
    }

    pub fn nshards(&self) -> usize {
        self.shards.len()
    }
}

/// Independent `DHashMap` shards routed by the fixed selector pre-hash
/// through an epoch-stamped directory, with per-shard rebuilds, a
/// staggered whole-map rebuild, and online shard [`split`] / [`merge`].
///
/// [`split`]: ShardedDHash::split_shard
/// [`merge`]: ShardedDHash::merge_shard
pub struct ShardedDHash<B: BucketSet = MichaelList> {
    /// The routing directory (RCU-published; replaced only by split and
    /// merge, which hold the migration token). Cache-padded: every op
    /// on every thread loads this pointer, and during a split/merge
    /// storm the publisher's stores would otherwise invalidate readers'
    /// lines through whatever neighbor shares the cacheline.
    dir: CachePadded<AtomicPtr<Directory<B>>>,
    /// Serializes whole-map sweeps (trylock: a second `rebuild_all` gets
    /// [`RebuildBusy`] instead of queueing behind an O(n) migration).
    rebuild_all_lock: Mutex<()>,
    /// Grants the right to run ONE migration (a shard rebuild, a split,
    /// or a merge), which is what makes the staggered invariant map-wide.
    migration_token: Mutex<()>,
    /// Migrations in flight — 0 or 1 by the invariant (asserted on every
    /// migration start; exposed as [`ShardedDHash::migrating_shards`] so
    /// tests can observe the staggering from outside). Padded so gauge
    /// flips never bounce the `dir`/`moving` lines readers sit on.
    migrating: CachePadded<AtomicUsize>,
    /// The node in its *cross-shard* hazard period (split/merge moves),
    /// or null. One pointer map-wide: the token admits one migration at
    /// a time, and a migration moves one node at a time. Padded: a
    /// migration stores here once per moved node while every reader in
    /// an affected range polls it.
    moving: CachePadded<AtomicPtr<Node>>,
    /// Guard-free mirrors of the directory's shape, for diagnostics that
    /// must not require a registered RCU thread. `cur_epoch` is padded
    /// because the batcher oracle polls it per batch to validate its
    /// cached route snapshot.
    nshards: AtomicUsize,
    cur_epoch: CachePadded<AtomicU64>,
    splits: AtomicU64,
    merges: AtomicU64,
    /// Next stable shard uid (see [`Slot`]); monotone, never reused.
    next_uid: AtomicU64,
}

impl ShardedDHash<MichaelList> {
    /// A sharded map with `nshards` shards of `nbuckets_per_shard` buckets
    /// each, all hashing with the seeded default family.
    pub fn with_buckets(nshards: usize, nbuckets_per_shard: usize, seed: u64) -> Self {
        Self::with_hash(nshards, nbuckets_per_shard, HashFn::Seeded(seed))
    }
}

impl<B: BucketSet> ShardedDHash<B> {
    /// A sharded map with an explicit bucket algorithm and a shared
    /// initial hash function. `nshards` must be a power of two (the
    /// selector takes top bits). Mitigations re-seed shards individually
    /// afterwards, so a shared initial seed costs nothing: shard keysets
    /// are disjoint.
    pub fn with_hash(nshards: usize, nbuckets_per_shard: usize, hash: HashFn) -> Self {
        assert!(
            nshards.is_power_of_two(),
            "shard count must be a power of two, got {nshards}"
        );
        let depth = nshards.trailing_zeros();
        assert!(depth <= MAX_DEPTH, "shard count exceeds the directory cap");
        let slots: Vec<Slot<B>> = (0..nshards)
            .map(|i| Slot {
                map: Arc::new(DHashMap::with_hash(nbuckets_per_shard, hash)),
                prev: None,
                shard: 0,
                uid: i as u64,
            })
            .collect();
        Self {
            dir: CachePadded::new(AtomicPtr::new(Directory::build(0, depth, slots))),
            rebuild_all_lock: Mutex::new(()),
            migration_token: Mutex::new(()),
            migrating: CachePadded::new(AtomicUsize::new(0)),
            moving: CachePadded::new(AtomicPtr::new(std::ptr::null_mut())),
            nshards: AtomicUsize::new(nshards),
            cur_epoch: CachePadded::new(AtomicU64::new(0)),
            splits: AtomicU64::new(0),
            merges: AtomicU64::new(0),
            next_uid: AtomicU64::new(nshards as u64),
        }
    }

    /// The current directory.
    ///
    /// Safety contract (not enforceable by the signature): the caller
    /// must either be inside an RCU read-side critical section, or hold
    /// the migration token (the only writer of `dir`).
    // lint: hot
    #[inline(always)]
    fn dir(&self) -> &Directory<B> {
        // SAFETY: `dir` is never null; a directory is freed only a grace
        // period after being unpublished, and the publisher holds the
        // migration token — covered by either half of the caller
        // contract above.
        //
        // Acquire pairs with `install_dir`'s Release store: it makes the
        // directory's contents (slots, prev links, epoch) visible, plus
        // everything sequenced before the publication — in particular
        // the mirror stores (`nshards`, `cur_epoch`), which is the
        // "mirrors-first" invariant `len`'s epoch re-check relies on.
        // ord: sharded-dir — mirrors-first directory install / Acquire route reads
        unsafe { &*self.dir.load(Ordering::Acquire) }
    }

    /// Current number of shards. Guard-free: a racy-but-safe mirror (the
    /// true value lives in the directory), exact whenever no split/merge
    /// is concurrently publishing.
    pub fn shards(&self) -> usize {
        // Acquire pairs with install_dir's Release mirror store; the
        // value is racy by contract (a publication may be in flight),
        // so no stronger ordering could sharpen it.
        // ord: sharded-dir — mirrors-first directory install / Acquire route reads
        self.nshards.load(Ordering::Acquire)
    }

    /// Current directory epoch (bumped once per completed or in-flight
    /// split/merge publication). Guard-free mirror, like
    /// [`ShardedDHash::shards`].
    pub fn epoch(&self) -> u64 {
        // Acquire pairs with install_dir's Release mirror store. The
        // batcher oracle keys its cached RouteSnapshot on this value:
        // monotone staleness is fine (one conservatively rebuilt
        // snapshot), torn/invented values are not — which coherence on
        // the single word already rules out.
        // ord: sharded-dir — mirrors-first directory install / Acquire route reads
        self.cur_epoch.load(Ordering::Acquire)
    }

    /// Completed splits.
    pub fn split_count(&self) -> u64 {
        // ord: stats-relaxed — monotonic counter, no ordering role
        self.splits.load(Ordering::Relaxed)
    }

    /// Completed merges.
    pub fn merge_count(&self) -> u64 {
        // ord: stats-relaxed — monotonic counter, no ordering role
        self.merges.load(Ordering::Relaxed)
    }

    /// The shard ordinal `key` routes to under the current directory.
    #[inline]
    pub fn shard_of(&self, guard: &RcuThread, key: u64) -> usize {
        let _g = guard.read_lock();
        self.dir().slot_of(key).shard
    }

    /// `(directory epoch, shard ordinal)` for `key`, both read from ONE
    /// directory pointer. The shard-order pre-route uses this
    /// allocation-free read so every routing id carries the epoch of
    /// the exact layout that produced it — ids straddling a resize are
    /// detectable (and fall back) instead of silently mixing layouts,
    /// which a separate `epoch()` + `shard_of()` pair could not
    /// guarantee.
    #[inline]
    pub fn epoch_shard_of(&self, guard: &RcuThread, key: u64) -> (u64, usize) {
        let _g = guard.read_lock();
        let d = self.dir();
        (d.epoch, d.slot_of(key).shard)
    }

    /// Handle to one shard (diagnostics / tests). Rebuilding through
    /// this handle bypasses the staggered-migration token; use
    /// [`ShardedDHash::rebuild_shard`] instead.
    pub fn shard(&self, guard: &RcuThread, s: usize) -> Arc<DHashMap<B>> {
        let _g = guard.read_lock();
        self.dir().shard_map(s).clone()
    }

    /// Migrations in flight right now (0 or 1).
    pub fn migrating_shards(&self) -> usize {
        // Acquire pairs with the gauge's AcqRel RMWs (diagnostic read;
        // the invariant itself is enforced by the token + assertion).
        // ord: sharded-gauge — migration gauge AcqRel RMW; token serializes transitions
        self.migrating.load(Ordering::Acquire)
    }

    /// The ordinal of shard `s`'s merge buddy — the shard serving the
    /// sibling selector range at the same depth — or `None` when `s`
    /// cannot merge right now (single shard, buddy split deeper, or `s`
    /// out of range).
    pub fn buddy_of(&self, guard: &RcuThread, s: usize) -> Option<usize> {
        let _g = guard.read_lock();
        let d = self.dir();
        (s < d.nshards()).then(|| d.buddy_of(s)).flatten()
    }

    /// Lookup in the key's shard (per-shard Algorithm 4), extended with
    /// the cross-shard migration order: during a split/merge of the
    /// key's range, check (1) the migration *source*, (2) the node in
    /// its cross-shard hazard period, (3) the destination. The same
    /// argument as Lemma 4.1 applies: a node is published in `moving`
    /// *before* it is deleted from the source and unpublished only
    /// *after* it is inserted into the destination, so its hazard period
    /// covers every instant it is absent from both shards.
    // lint: hot
    #[inline]
    pub fn lookup(&self, guard: &RcuThread, key: u64) -> Option<u64> {
        if key == u64::MAX {
            return None;
        }
        let _g = guard.read_lock();
        let slot = self.dir().slot_of(key);
        // Steady state (no split/merge touching this range): one branch,
        // straight into the shard. The migration arm is outlined and
        // `#[cold]` so its register pressure and the hazard-pointer poll
        // stay off the fast path.
        if let Some(prev) = &slot.prev {
            if let Some(v) = self.lookup_migrating(prev, key) {
                return Some(v);
            }
        }
        slot.map.lookup(guard, key)
    }

    /// The cross-shard migration arm of [`ShardedDHash::lookup`]: source
    /// shard, then the `moving` hazard node. `None` means "fall through
    /// to the destination shard". Outlined and cold — it is reachable
    /// only while the key's slot carries `prev`, i.e. during the bounded
    /// window of one split/merge.
    ///
    /// The caller must be inside a read-side critical section.
    #[cold]
    #[inline(never)]
    fn lookup_migrating(&self, prev: &DHashMap<B>, key: u64) -> Option<u64> {
        if let Some(n) = prev.live_node(key) {
            // Relaxed: same visibility contract as `DHashMap::lookup` —
            // the initial value rode the Release link CAS that published
            // the node, and in-place upsert overwrites order through the
            // caller's own synchronization (see dhash/mod.rs).
            // ord: node-val — value rides the link publish; later stores racy-by-spec
            return Some(n.val.load(Ordering::Relaxed));
        }
        // Acquire pairs with drain_into's Release publication of the
        // candidate: observing the pointer makes the node's key/flags
        // visible (the cross-shard Lemma 4.1 hazard handoff).
        // ord: sharded-moving — cross-shard hazard pointer (Lemma 4.1 mirror)
        let cur = self.moving.load(Ordering::Acquire);
        if !cur.is_null() {
            // SAFETY: a node reachable through `moving` is reclaimed
            // only after `moving` is cleared *and* a grace period
            // passes; we are inside a read-side section.
            let n = unsafe { &*cur };
            if n.key == key && !n.logically_removed() {
                // ord: node-val — value rides the link publish; later stores racy-by-spec
                return Some(n.val.load(Ordering::Relaxed));
            }
        }
        None
    }

    /// Insert into the key's shard (per-shard Algorithm 6). During a
    /// split/merge of the key's range, inserts go to the *destination*
    /// shard only — the same discipline as `DHashMap::insert` during a
    /// rebuild (Lemma 4.3): the directory swap is followed by a grace
    /// period before any node moves, and a racing duplicate is resolved
    /// when the migration's re-insert fails and drops the source copy.
    #[inline]
    pub fn insert(&self, guard: &RcuThread, key: u64, val: u64) -> Result<(), KeyExists> {
        assert_ne!(key, u64::MAX, "key u64::MAX is reserved (bucket sentinel)");
        let _g = guard.read_lock();
        self.dir().slot_of(key).map.insert(guard, key, val)
    }

    /// Delete from the key's shard (per-shard Algorithm 5), extended
    /// with the cross-shard migration order: source shard, then the
    /// hazard-period node (marked deleted in place — the flag travels
    /// with the node through the re-insert, so it is born dead in the
    /// destination), then the destination shard.
    #[inline]
    pub fn delete(&self, guard: &RcuThread, key: u64) -> bool {
        if key == u64::MAX {
            return false;
        }
        let _g = guard.read_lock();
        let slot = self.dir().slot_of(key);
        if let Some(prev) = &slot.prev {
            if prev.delete(guard, key) {
                return true;
            }
            // Acquire: as in `lookup_migrating` — pairs with the
            // drain's Release publication of the hazard node.
            // ord: sharded-moving — cross-shard hazard pointer (Lemma 4.1 mirror)
            let cur = self.moving.load(Ordering::Acquire);
            if !cur.is_null() {
                // SAFETY: as in lookup.
                let n = unsafe { &*cur };
                if n.key == key {
                    let prev_flags = n.set_flag(LOGICALLY_REMOVED);
                    if prev_flags & LOGICALLY_REMOVED == 0 {
                        // We won the logical deletion.
                        return true;
                    }
                }
            }
        }
        slot.map.delete(guard, key)
    }

    /// Atomic last-wins upsert in the key's shard (value swapped in
    /// place on the live node — see [`DHashMap::upsert`]), searching the
    /// cross-shard migration order when the key's range is mid-split/
    /// merge. Returns true if a new node was inserted.
    pub fn upsert(&self, guard: &RcuThread, key: u64, val: u64) -> bool {
        assert_ne!(key, u64::MAX, "key u64::MAX is reserved (bucket sentinel)");
        loop {
            {
                let _g = guard.read_lock();
                let slot = self.dir().slot_of(key);
                if let Some(prev) = &slot.prev {
                    if let Some(n) = prev.live_node(key) {
                        // Relaxed value stores throughout: same contract
                        // as `DHashMap::upsert` — cross-thread "read my
                        // upsert" visibility is the caller's edge (e.g.
                        // the CompletionSet's Release/Acquire), not the
                        // value word's.
                        // ord: node-val — value rides the link publish; later stores racy-by-spec
                        n.val.store(val, Ordering::Relaxed);
                        return false;
                    }
                    // Acquire: as in `lookup_migrating`.
                    // ord: sharded-moving — cross-shard hazard pointer (Lemma 4.1 mirror)
                    let cur = self.moving.load(Ordering::Acquire);
                    if !cur.is_null() {
                        // SAFETY: as in lookup.
                        let n = unsafe { &*cur };
                        if n.key == key && !n.logically_removed() {
                            // ord: node-val — value rides the link publish; later stores racy-by-spec
                            n.val.store(val, Ordering::Relaxed);
                            return false;
                        }
                    }
                }
                if let Some(n) = slot.map.live_node(key) {
                    // ord: node-val — value rides the link publish; later stores racy-by-spec
                    n.val.store(val, Ordering::Relaxed);
                    return false;
                }
            }
            if self.insert(guard, key, val).is_ok() {
                return true;
            }
            // A concurrent insert won the key between our miss and the
            // insert attempt; retry the in-place path against it.
        }
    }

    /// Targeted rebuild of shard `s` into `nbuckets` buckets under `hash`,
    /// the mitigation primitive: 1/N of the keyspace migrates, the other
    /// shards keep serving untouched. Returns [`RebuildBusy`] if any
    /// migration (rebuild, split, or merge) is already in flight, or if
    /// `s` is not a current shard ordinal (the directory may have changed
    /// since the caller observed it).
    ///
    /// The caller must not be inside a read-side critical section (same
    /// contract as [`DHashMap::rebuild`]).
    pub fn rebuild_shard(
        &self,
        guard: &RcuThread,
        s: usize,
        nbuckets: usize,
        hash: HashFn,
    ) -> Result<RebuildStats, RebuildBusy> {
        self.rebuild_shard_at(guard, None, s, nbuckets, hash)
    }

    /// [`ShardedDHash::rebuild_shard`], additionally refusing (with
    /// [`RebuildBusy`]) when the directory epoch no longer matches
    /// `epoch` — the analytics path uses this so a verdict computed
    /// under one shard layout can never rebuild a *different* shard that
    /// inherited the ordinal after a split/merge.
    pub fn rebuild_shard_at(
        &self,
        guard: &RcuThread,
        epoch: Option<u64>,
        s: usize,
        nbuckets: usize,
        hash: HashFn,
    ) -> Result<RebuildStats, RebuildBusy> {
        let token = match self.migration_token.try_lock() { // lock: migration
            Ok(t) => t,
            Err(_) => return Err(RebuildBusy),
        };
        // Under the token the directory is stable (only migrations
        // replace it, and we hold the only migration right).
        let d = self.dir();
        if s >= d.nshards() || epoch.map_or(false, |e| e != d.epoch) {
            return Err(RebuildBusy);
        }
        let map = d.shard_map(s).clone();
        let mig = MigrationGauge::enter(&self.migrating);
        let r = map.rebuild(guard, nbuckets, hash);
        drop(mig);
        drop(token);
        r
    }

    /// Staggered whole-map rebuild: migrate the shards **one at a time**
    /// into `nbuckets_per_shard` buckets each under `hash`, releasing the
    /// migration token between shards so targeted mitigations and the
    /// paper's concurrent lookup/insert/delete interleave freely. Returns
    /// merged [`RebuildStats`] (`nbuckets` is the new total), or
    /// [`RebuildBusy`] if another whole-map sweep is running.
    ///
    /// The shard set is captured when the sweep starts; a split/merge
    /// interleaving the sweep may retire a captured shard mid-sweep
    /// (rebuilding it is wasted work, never wrong — its keys migrate out
    /// through the directory regardless) and shards born mid-sweep are
    /// not swept.
    ///
    /// The caller must not be inside a read-side critical section.
    pub fn rebuild_all(
        &self,
        guard: &RcuThread,
        nbuckets_per_shard: usize,
        hash: HashFn,
    ) -> Result<RebuildStats, RebuildBusy> {
        let t0 = Instant::now();
        let _all = match self.rebuild_all_lock.try_lock() { // lock: rebuild-all
            Ok(g) => g,
            Err(_) => return Err(RebuildBusy),
        };
        let maps: Vec<Arc<DHashMap<B>>> = {
            let _g = guard.read_lock();
            let d = self.dir();
            (0..d.nshards()).map(|s| d.shard_map(s).clone()).collect()
        };
        let mut moved = 0u64;
        let mut skipped = 0u64;
        let mut dropped_dup = 0u64;
        let nshards = maps.len();
        for map in maps {
            // Blocking token acquisition, offline: a targeted rebuild may
            // hold the token and be waiting out grace periods that need
            // this thread to pass a quiescent state.
            let token = guard
                // lock: migration
                .offline_while(|| self.migration_token.lock().unwrap_or_else(|e| e.into_inner()));
            let mig = MigrationGauge::enter(&self.migrating);
            let r = map.rebuild(guard, nbuckets_per_shard, hash);
            drop(mig);
            drop(token);
            let st = r?;
            moved += st.moved;
            skipped += st.skipped;
            dropped_dup += st.dropped_dup;
        }
        Ok(RebuildStats {
            moved,
            skipped,
            dropped_dup,
            nbuckets: nbuckets_per_shard * nshards,
            elapsed: t0.elapsed(),
        })
    }

    /// Drain every node of `src` into the destination the (already
    /// published and grace-period-settled) directory routes its key to,
    /// publishing each node in the map-wide `moving` hazard pointer
    /// across its delete→insert window. The caller holds the migration
    /// token. Mirrors the distribution loop of `DHashMap::rebuild`
    /// (Alg. 3 lines 24-39) with the destination chosen per key.
    // lint: publish drain
    fn drain_into(&self, src: &DHashMap<B>, new_dir: &Directory<B>) -> (u64, u64) {
        let mut moved = 0u64;
        let mut dropped_dup = 0u64;
        // SAFETY: we hold the migration token, so `src` cannot be
        // mid-rebuild (its `cur` is stable and its `ht_new` is null).
        // Acquire: the table was published by a Release-or-stronger
        // store (construction or a token-serialized rebuild swap).
        // ord: dhash-reader — Acquire table read pairs with rebuild's Release publish
        let src_table = unsafe { &*src.cur.load(Ordering::Acquire) };
        for bucket in src_table.buckets() {
            loop {
                let popped = bucket.take_first_for_distribution(&mut |cand| {
                    // Publish the hazard-period pointer for every
                    // candidate BEFORE its logical delete (the paper's
                    // ordering, Alg. 3 lines 26-29).
                    // ord: sharded-moving — cross-shard hazard pointer (Lemma 4.1 mirror)
                    self.moving.store(cand, Ordering::Release);
                });
                match popped {
                    None => {
                        // A raced candidate may linger in `moving`; clear
                        // before leaving the bucket (same hole as the
                        // rebuild loop — see DESIGN.md §Deviations).
                        // ord: sharded-moving — cross-shard hazard pointer (Lemma 4.1 mirror)
                        self.moving.store(std::ptr::null_mut(), Ordering::Release);
                        break;
                    }
                    Some(n) => {
                        // SAFETY: unlinked by the pop; owned by us.
                        let key = unsafe { (*n).key };
                        let dest = &new_dir.slot_of(key).map;
                        match dest.table().bucket(key).insert(n) {
                            Ok(()) => {
                                moved += 1;
                                // Leave the hazard period (Release = the
                                // paper's smp_wmb).
                                // ord: sharded-moving — cross-shard hazard pointer (Lemma 4.1 mirror)
                                self.moving.store(std::ptr::null_mut(), Ordering::Release);
                            }
                            Err(n) => {
                                // A concurrent insert won the destination;
                                // clear `moving` BEFORE the deferred free
                                // (the rebuild loop's ordering fix).
                                // SeqCst retained (writer-side protocol
                                // store, cold): mirrors the rebuild dup
                                // path's hazard-clear — see DESIGN.md
                                // §Memory orderings. Listed in
                                // tools/seqcst_allowlist.txt.
                                // ord: sharded-moving — cross-shard hazard pointer (Lemma 4.1 mirror)
                                self.moving.store(std::ptr::null_mut(), Ordering::SeqCst);
                                // SAFETY: not in any table; unreachable
                                // once `moving` is cleared.
                                unsafe { Node::defer_free(n) };
                                dropped_dup += 1;
                            }
                        }
                    }
                }
            }
        }
        (moved, dropped_dup)
    }

    /// Publish a freshly built directory (the caller holds the migration
    /// token and frees superseded directories itself, after the grace
    /// periods its protocol already waits out).
    // lint: publish install-dir
    fn install_dir(&self, new_dir: *mut Directory<B>) {
        // SAFETY: `new_dir` was just built and is never null.
        let d = unsafe { &*new_dir };
        // Mirrors first, directory second: anyone who can already route
        // through the new directory is guaranteed to read the new epoch,
        // so epoch re-checks (the pre-route oracle, `len`'s fast path)
        // can only err toward the conservative fallback.
        //
        // Release on all three suffices for that invariant: the mirror
        // stores are sequenced before the `dir` Release store, so a
        // reader whose `dir` Acquire load returns `new_dir` has the new
        // mirror values happen-before its subsequent mirror loads —
        // coherence then forbids it reading the older epoch. The
        // guard-free mirror accessors pair with these stores directly.
        // ord: sharded-dir — mirrors-first directory install / Acquire route reads
        self.nshards.store(d.nshards(), Ordering::Release);
        // ord: sharded-dir — mirrors-first directory install / Acquire route reads
        self.cur_epoch.store(d.epoch, Ordering::Release);
        // ord: sharded-dir — mirrors-first directory install / Acquire route reads
        self.dir.store(new_dir, Ordering::Release);
    }

    /// Split shard `s` online: its keys migrate to two child shards,
    /// each serving one more selector bit (`nbuckets` buckets each,
    /// hashing with `hash`), concurrently with lookup / insert / delete
    /// / upsert. The split publishes an intermediate directory whose
    /// affected slots carry `prev = parent`, waits a grace period so
    /// every thread routes through it, drains the parent (one node at a
    /// time through the `moving` hazard pointer), then publishes the
    /// final directory and retires the parent — the directory-level
    /// analogue of Algorithm 3's three-barrier rebuild.
    ///
    /// The caller must not be inside a read-side critical section.
    pub fn split_shard(
        &self,
        guard: &RcuThread,
        s: usize,
        nbuckets: usize,
        hash: HashFn,
    ) -> Result<RebuildStats, ResizeError> {
        self.split_shard_at(guard, None, s, nbuckets, hash)
    }

    /// [`ShardedDHash::split_shard`], additionally refusing (with
    /// [`ResizeError::Busy`]) when the directory epoch no longer matches
    /// `epoch` — the elastic policy uses this so a decision scored under
    /// one shard layout can never split whichever shard inherited the
    /// ordinal after a concurrent resize (the same pinning
    /// [`ShardedDHash::rebuild_shard_at`] gives mitigations).
    // lint: publish resize
    pub fn split_shard_at(
        &self,
        guard: &RcuThread,
        epoch: Option<u64>,
        s: usize,
        nbuckets: usize,
        hash: HashFn,
    ) -> Result<RebuildStats, ResizeError> {
        let t0 = Instant::now();
        if nbuckets == 0 {
            return Err(ResizeError::BadGeometry);
        }
        let token = match self.migration_token.try_lock() { // lock: migration
            Ok(t) => t,
            Err(_) => return Err(ResizeError::Busy),
        };
        let d0 = self.dir();
        if epoch.map_or(false, |e| e != d0.epoch) {
            return Err(ResizeError::Busy);
        }
        if s >= d0.nshards() {
            return Err(ResizeError::NoSuchShard);
        }
        let (lo, hi) = d0.shard_range(s);
        let local_size = hi - lo;
        if local_size == 1 && d0.depth >= MAX_DEPTH {
            return Err(ResizeError::AtMaxDepth);
        }
        // Acquire (token held: we are the only dir writer; the load
        // only needs to see the last published directory).
        // ord: sharded-dir — mirrors-first directory install / Acquire route reads
        let d0_ptr = self.dir.load(Ordering::Acquire);
        let mig = MigrationGauge::enter(&self.migrating);
        let parent = d0.shard_map(s).clone();
        let c0 = Arc::new(DHashMap::with_hash(nbuckets, hash));
        let c1 = Arc::new(DHashMap::with_hash(nbuckets, hash));
        // ord: stats-relaxed — monotonic counter, no ordering role
        let uid0 = self.next_uid.fetch_add(2, Ordering::Relaxed);
        let child_slot =
            |child: &Arc<DHashMap<B>>, uid: u64, prev: Option<&Arc<DHashMap<B>>>| Slot {
                map: child.clone(),
                prev: prev.cloned(),
                shard: 0,
                uid,
            };

        // Intermediate directory D1: the parent's range routes to the
        // children with `prev = parent`. If the parent owns a single
        // slot the directory doubles (each old slot i becomes 2i and
        // 2i+1 — a pure selector-bit extension); otherwise the range
        // halves in place.
        let build = |with_prev: bool| -> *mut Directory<B> {
            let prev0 = with_prev.then_some(&parent);
            if local_size == 1 {
                let mut slots = Vec::with_capacity(d0.slots.len() * 2);
                for (i, old) in d0.slots.iter().enumerate() {
                    if i == lo {
                        slots.push(child_slot(&c0, uid0, prev0));
                        slots.push(child_slot(&c1, uid0 + 1, prev0));
                    } else {
                        debug_assert!(old.prev.is_none(), "token held: no other migration");
                        slots.push(old.clone());
                        slots.push(old.clone());
                    }
                }
                Directory::build(d0.epoch + 1, d0.depth + 1, slots)
            } else {
                let mid = lo + local_size / 2;
                let slots: Vec<Slot<B>> = d0
                    .slots
                    .iter()
                    .enumerate()
                    .map(|(i, old)| {
                        if (lo..mid).contains(&i) {
                            child_slot(&c0, uid0, prev0)
                        } else if (mid..hi).contains(&i) {
                            child_slot(&c1, uid0 + 1, prev0)
                        } else {
                            old.clone()
                        }
                    })
                    .collect();
                Directory::build(d0.epoch + 1, d0.depth, slots)
            }
        };

        // Barrier 1: publish D1 and wait; afterwards every op routes
        // through it (inserts to the children, reads checking parent →
        // moving → child), so the drain below can never race an insert
        // into an already-drained parent bucket. D0 is unreachable from
        // here on but stays allocated until the end (build(false) still
        // reads its slots).
        let d1_ptr = build(true);
        self.install_dir(d1_ptr);
        guard.offline_while(synchronize_rcu);
        // SAFETY: just built, never null; we are the only dir writer.
        let d1 = unsafe { &*d1_ptr };

        let (moved, dropped_dup) = self.drain_into(&parent, d1);

        // Barrier 2: wait for ops still traversing parent buckets.
        guard.offline_while(synchronize_rcu);

        // Barrier 3: publish the final directory (prev cleared) and wait,
        // then free the superseded directories — dropping the last
        // directory references to the (now empty) parent.
        self.install_dir(build(false));
        guard.offline_while(synchronize_rcu);
        // SAFETY: both unpublished for at least a full grace period.
        unsafe {
            drop(Box::from_raw(d0_ptr)); // reclaim: dir via grace
            drop(Box::from_raw(d1_ptr)); // reclaim: dir via grace
        }

        // ord: stats-relaxed — monotonic counter, no ordering role
        self.splits.fetch_add(1, Ordering::Relaxed);
        drop(mig);
        drop(token);
        Ok(RebuildStats {
            moved,
            skipped: 0,
            dropped_dup,
            nbuckets: nbuckets * 2,
            elapsed: t0.elapsed(),
        })
    }

    /// Merge shard `s` with its buddy online: both shards' keys migrate
    /// into one new shard (`nbuckets` buckets, hashing with `hash`)
    /// serving one selector bit less, concurrently with lookup / insert
    /// / delete / upsert — the exact inverse of
    /// [`ShardedDHash::split_shard`], using the same intermediate
    /// directory + hazard-pointer protocol. The final directory halves
    /// its depth when every slot pair has collapsed.
    ///
    /// The caller must not be inside a read-side critical section.
    pub fn merge_shard(
        &self,
        guard: &RcuThread,
        s: usize,
        nbuckets: usize,
        hash: HashFn,
    ) -> Result<RebuildStats, ResizeError> {
        self.merge_shard_at(guard, None, s, nbuckets, hash)
    }

    /// [`ShardedDHash::merge_shard`] pinned to a directory epoch, like
    /// [`ShardedDHash::split_shard_at`]: refuses with
    /// [`ResizeError::Busy`] when the layout the decision was scored
    /// under is gone.
    // lint: publish resize
    pub fn merge_shard_at(
        &self,
        guard: &RcuThread,
        epoch: Option<u64>,
        s: usize,
        nbuckets: usize,
        hash: HashFn,
    ) -> Result<RebuildStats, ResizeError> {
        let t0 = Instant::now();
        if nbuckets == 0 {
            return Err(ResizeError::BadGeometry);
        }
        let token = match self.migration_token.try_lock() { // lock: migration
            Ok(t) => t,
            Err(_) => return Err(ResizeError::Busy),
        };
        let d0 = self.dir();
        if epoch.map_or(false, |e| e != d0.epoch) {
            return Err(ResizeError::Busy);
        }
        if s >= d0.nshards() {
            return Err(ResizeError::NoSuchShard);
        }
        let Some(b) = d0.buddy_of(s) else {
            return Err(ResizeError::Unmergeable);
        };
        // Acquire (token held: we are the only dir writer; the load
        // only needs to see the last published directory).
        // ord: sharded-dir — mirrors-first directory install / Acquire route reads
        let d0_ptr = self.dir.load(Ordering::Acquire);
        let mig = MigrationGauge::enter(&self.migrating);
        let src_s = d0.shard_map(s).clone();
        let src_b = d0.shard_map(b).clone();
        let merged = Arc::new(DHashMap::with_hash(nbuckets, hash));
        // ord: stats-relaxed — monotonic counter, no ordering role
        let merged_uid = self.next_uid.fetch_add(1, Ordering::Relaxed);

        let build = |with_prev: bool| -> *mut Directory<B> {
            let mut slots: Vec<Slot<B>> = d0
                .slots
                .iter()
                .map(|old| {
                    if old.shard == s || old.shard == b {
                        Slot {
                            map: merged.clone(),
                            prev: with_prev.then(|| old.map.clone()),
                            shard: 0,
                            uid: merged_uid,
                        }
                    } else {
                        old.clone()
                    }
                })
                .collect();
            let mut depth = d0.depth;
            if !with_prev {
                // Opportunistic halving: fold the directory while every
                // even/odd slot pair aliases one shard.
                while depth > 0 && slots.chunks(2).all(|p| Arc::ptr_eq(&p[0].map, &p[1].map)) {
                    slots = slots.into_iter().step_by(2).collect();
                    depth -= 1;
                }
            }
            Directory::build(d0.epoch + 1, depth, slots)
        };

        // Barrier 1 (see split_shard): route everything through the
        // intermediate directory before any node moves.
        let d1_ptr = build(true);
        self.install_dir(d1_ptr);
        guard.offline_while(synchronize_rcu);
        // SAFETY: just built, never null; we are the only dir writer.
        let d1 = unsafe { &*d1_ptr };

        let (moved_s, dup_s) = self.drain_into(&src_s, d1);
        let (moved_b, dup_b) = self.drain_into(&src_b, d1);

        // Barrier 2: ops still traversing source buckets.
        guard.offline_while(synchronize_rcu);

        // Barrier 3: final directory; then free the superseded ones,
        // retiring both sources.
        self.install_dir(build(false));
        guard.offline_while(synchronize_rcu);
        // SAFETY: both unpublished for at least a full grace period.
        unsafe {
            drop(Box::from_raw(d0_ptr)); // reclaim: dir via grace
            drop(Box::from_raw(d1_ptr)); // reclaim: dir via grace
        }

        // ord: stats-relaxed — monotonic counter, no ordering role
        self.merges.fetch_add(1, Ordering::Relaxed);
        drop(mig);
        drop(token);
        Ok(RebuildStats {
            moved: moved_s + moved_b,
            skipped: 0,
            dropped_dup: dup_s + dup_b,
            nbuckets,
            elapsed: t0.elapsed(),
        })
    }

    /// Completed rebuilds, summed over current shards (rebuilds of
    /// shards since retired by a split/merge are not counted).
    pub fn rebuild_count(&self, guard: &RcuThread) -> u64 {
        let _g = guard.read_lock();
        let d = self.dir();
        (0..d.nshards()).map(|s| d.shard_map(s).rebuild_count()).sum()
    }

    /// Total bucket count, summed over shards.
    pub fn nbuckets(&self, guard: &RcuThread) -> usize {
        let _g = guard.read_lock();
        let d = self.dir();
        (0..d.nshards()).map(|s| d.shard_map(s).nbuckets(guard)).sum()
    }

    /// Current bucket count of shard `s`.
    pub fn shard_nbuckets(&self, guard: &RcuThread, s: usize) -> usize {
        let _g = guard.read_lock();
        self.dir().shard_map(s).nbuckets(guard)
    }

    /// Current hash function of shard `s` (shards diverge after targeted
    /// mitigations).
    pub fn shard_hash_fn(&self, guard: &RcuThread, s: usize) -> HashFn {
        let _g = guard.read_lock();
        self.dir().shard_map(s).hash_fn(guard)
    }

    /// Every shard's routing geometry plus the selector→shard mapping,
    /// captured from ONE directory pointer under one RCU guard — the
    /// routing oracle's input for the vectorized `batch_hash_multi`
    /// pre-sort. Each shard's `(hash, nbuckets)` pair comes from a
    /// single table pointer ([`DHashMap::geometry`]), so the snapshot
    /// never pairs a shard's old hash with its new bucket count, even
    /// mid-staggered-rebuild; the embedded epoch lets a consumer detect
    /// (and count, instead of silently absorbing) ids computed against a
    /// layout a split/merge has since retired. A batch sorted with a
    /// stale-but-detected geometry merely loses bucket-order locality —
    /// the same cost as an un-routed batch — because per-op routing
    /// always goes through the live directory.
    pub fn route_snapshot(&self, guard: &RcuThread) -> RouteSnapshot {
        let _g = guard.read_lock();
        let d = self.dir();
        RouteSnapshot {
            epoch: d.epoch,
            shards: (0..d.nshards())
                .map(|s| d.shard_map(s).geometry(guard))
                .collect(),
            uids: (0..d.nshards())
                .map(|s| d.slots[d.shard_slots[s]].uid)
                .collect(),
            depth: d.depth,
            slot_shard: d.slots.iter().map(|sl| sl.shard as u32).collect(),
        }
    }

    /// True when shard `s` can split right now: its selector range spans
    /// more than one slot, or the directory has depth headroom. The
    /// elastic policy consults this so it never keeps planning a split
    /// that [`ShardedDHash::split_shard`] would refuse with
    /// [`ResizeError::AtMaxDepth`] (starving merges of the cooldown).
    pub fn splittable(&self, guard: &RcuThread, s: usize) -> bool {
        let _g = guard.read_lock();
        let d = self.dir();
        if s >= d.nshards() {
            return false;
        }
        let (lo, hi) = d.shard_range(s);
        hi - lo > 1 || d.depth < MAX_DEPTH
    }

    /// Per-shard `(live nodes, nbuckets)` occupancy plus the epoch it
    /// was observed under — the elastic controller's input. O(n) scan.
    pub fn load_profile(&self, guard: &RcuThread) -> (u64, Vec<(usize, usize)>) {
        let (epoch, maps): (u64, Vec<Arc<DHashMap<B>>>) = {
            let _g = guard.read_lock();
            let d = self.dir();
            (
                d.epoch,
                (0..d.nshards()).map(|s| d.shard_map(s).clone()).collect(),
            )
        };
        let prof = maps
            .iter()
            .map(|m| (m.len(guard), m.nbuckets(guard)))
            .collect();
        (epoch, prof)
    }

    /// All live `(key, value)` pairs, merged across the directory:
    /// migration sources first, then the cross-shard hazard node, then
    /// destination shards — the same precedence `lookup` uses —
    /// deduplicated by key. Each shard contributes its own
    /// rebuild-chain-merged pairs (see `DHashMap::merged_pairs`), so the
    /// walk never undercounts during any migration: a node absent from
    /// both its source scan and its destination scan must have its
    /// cross-shard hazard period spanning the gap between them, and at
    /// most one node is in that period at a time (single `moving`
    /// pointer, single migration by the token).
    ///
    /// The caller must be inside a read-side critical section.
    fn merged_pairs_dir(&self, d: &Directory<B>) -> Vec<(u64, u64)> {
        let mut seen: HashSet<u64> = HashSet::new();
        let mut out: Vec<(u64, u64)> = Vec::new();
        // (1) Migration sources (dedup by map identity: a split's parent
        // backs two slot ranges, a merge has one source per range).
        let mut scanned: Vec<*const DHashMap<B>> = Vec::new();
        for slot in d.slots.iter() {
            if let Some(prev) = &slot.prev {
                let p = Arc::as_ptr(prev);
                if !scanned.contains(&p) {
                    scanned.push(p);
                    for (k, v) in prev.merged_pairs() {
                        if seen.insert(k) {
                            out.push((k, v));
                        }
                    }
                }
            }
        }
        // (2) The cross-shard hazard node.
        // Acquire: pairs with the drain's Release publication, as in
        // `lookup_migrating`.
        // ord: sharded-moving — cross-shard hazard pointer (Lemma 4.1 mirror)
        let cur = self.moving.load(Ordering::Acquire);
        if !cur.is_null() {
            // SAFETY: as in lookup.
            let n = unsafe { &*cur };
            if !n.logically_removed() && seen.insert(n.key) {
                // ord: node-val — value rides the link publish; later stores racy-by-spec
                out.push((n.key, n.val.load(Ordering::Relaxed)));
            }
        }
        // (3) Destination shards.
        for s in 0..d.nshards() {
            for (k, v) in d.shard_map(s).merged_pairs() {
                if seen.insert(k) {
                    out.push((k, v));
                }
            }
        }
        out
    }

    /// Live node count across all shards — O(n) scan (diagnostics; racy
    /// under concurrency, but never undercounts during a rebuild *or* a
    /// split/merge — see `merged_pairs_dir`).
    ///
    /// Fast path: with no migration in flight (no slot carries `prev`,
    /// no hazard node) shard keysets are disjoint, so the per-shard
    /// lengths simply sum — no whole-map key-set materialization. A
    /// resize cannot *start* draining while this thread scans (its first
    /// grace period waits on us), but it can publish a new directory;
    /// the epoch re-check catches that and falls back to the coherent
    /// merged walk.
    pub fn len(&self, guard: &RcuThread) -> usize {
        let _g = guard.read_lock();
        let d = self.dir();
        // ord: sharded-moving — cross-shard hazard pointer (Lemma 4.1 mirror)
        if self.moving.load(Ordering::Acquire).is_null()
            && d.slots.iter().all(|sl| sl.prev.is_none())
        {
            let n = (0..d.nshards()).map(|s| d.shard_map(s).len(guard)).sum();
            if self.epoch() == d.epoch {
                return n;
            }
        }
        self.merged_pairs_dir(self.dir()).len()
    }

    pub fn is_empty(&self, guard: &RcuThread) -> bool {
        self.len(guard) == 0
    }

    /// Per-bucket live-node counts, shard 0's buckets first (the detector
    /// cross-check; each shard contributes `shard_nbuckets` entries).
    /// Mid-migration, pairs still held by a source shard are projected
    /// onto their *destination* shard's geometry — where the directory
    /// says the key belongs.
    pub fn bucket_loads(&self, guard: &RcuThread) -> Vec<usize> {
        let _g = guard.read_lock();
        let d = self.dir();
        let geoms: Vec<(HashFn, usize)> = (0..d.nshards())
            .map(|s| d.shard_map(s).geometry(guard))
            .collect();
        let mut loads: Vec<Vec<usize>> = geoms.iter().map(|&(_, nb)| vec![0; nb]).collect();
        for (k, _) in self.merged_pairs_dir(d) {
            let s = d.slot_of(k).shard;
            let (h, nb) = geoms[s];
            loads[s][h.bucket(k, nb)] += 1;
        }
        loads.concat()
    }

    /// Sorted snapshot of all live `(key, value)` pairs across shards
    /// (test use; racy under concurrency, but coherent across directory
    /// epochs — see `merged_pairs_dir`).
    pub fn snapshot(&self, guard: &RcuThread) -> Vec<(u64, u64)> {
        let _g = guard.read_lock();
        let d = self.dir();
        let mut out = self.merged_pairs_dir(d);
        out.sort_unstable();
        out
    }
}

impl<B: BucketSet> Drop for ShardedDHash<B> {
    fn drop(&mut self) {
        // Exclusive access: no concurrent ops, no migration in flight.
        // ord: unshared — exclusive access (&mut/Drop); no concurrent observers
        let d = self.dir.load(Ordering::Relaxed);
        if !d.is_null() {
            // SAFETY: exclusive; dropping the directory drops its shard
            // Arcs, and each last-referenced DHashMap drains itself.
            unsafe { drop(Box::from_raw(d)) }; // reclaim: dir via exclusive
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rcu::rcu_barrier;

    #[test]
    fn shard_of_is_in_range_and_stable() {
        for nshards in [1usize, 2, 4, 16, 64] {
            for k in [0u64, 1, 63, 1 << 40, u64::MAX - 1] {
                let s = shard_of(k, nshards);
                assert!(s < nshards, "shard {s} out of range for {nshards}");
                assert_eq!(s, shard_of(k, nshards), "selector must be pure");
            }
        }
        // One shard: everything routes to shard 0 (no 64-bit shift UB).
        assert_eq!(shard_of(u64::MAX, 1), 0);
    }

    #[test]
    fn shard_of_spreads_keys() {
        let nshards = 8;
        let mut loads = vec![0usize; nshards];
        for k in 0..8000u64 {
            loads[shard_of(k, nshards)] += 1;
        }
        let max = *loads.iter().max().unwrap();
        let min = *loads.iter().min().unwrap();
        assert!(min > 500 && max < 2000, "skewed selector: {loads:?}");
    }

    #[test]
    fn directory_agrees_with_uniform_selector() {
        // A freshly constructed map's directory is a uniform layout: its
        // routing must equal the free-function selector bit for bit.
        let g = RcuThread::register();
        let m = ShardedDHash::with_buckets(8, 8, 1);
        for k in (0..4000u64).map(|i| i.wrapping_mul(0x9e37)) {
            assert_eq!(m.shard_of(&g, k), shard_of(k, 8));
            // The coherent pair read agrees with the separate reads
            // (single-threaded: no resize can interleave them).
            assert_eq!(m.epoch_shard_of(&g, k), (m.epoch(), m.shard_of(&g, k)));
        }
        g.quiescent_state();
        rcu_barrier();
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn non_pow2_shards_rejected() {
        let _ = ShardedDHash::with_buckets(3, 8, 1);
    }

    #[test]
    fn basic_ops_route_consistently() {
        let g = RcuThread::register();
        let m = ShardedDHash::with_buckets(4, 16, 7);
        for k in 0..400u64 {
            m.insert(&g, k, k + 1).unwrap();
        }
        assert_eq!(m.len(&g), 400);
        assert_eq!(m.nbuckets(&g), 64);
        for k in 0..400u64 {
            assert_eq!(m.lookup(&g, k), Some(k + 1));
        }
        assert_eq!(m.insert(&g, 5, 0), Err(KeyExists));
        assert!(m.delete(&g, 5));
        assert!(!m.delete(&g, 5));
        assert_eq!(m.len(&g), 399);
        // The shard populations sum to the total and match the selector.
        let per: Vec<usize> = (0..4).map(|s| m.shard(&g, s).len(&g)).collect();
        assert_eq!(per.iter().sum::<usize>(), 399);
        g.quiescent_state();
        rcu_barrier();
    }

    #[test]
    fn targeted_rebuild_touches_only_its_shard() {
        let g = RcuThread::register();
        let m = ShardedDHash::with_buckets(4, 16, 1);
        for k in 0..800u64 {
            m.insert(&g, k, k).unwrap();
        }
        let victim = 2;
        let before: Vec<HashFn> = (0..4).map(|s| m.shard_hash_fn(&g, s)).collect();
        let stats = m
            .rebuild_shard(&g, victim, 64, HashFn::Seeded(0xfeed))
            .unwrap();
        assert_eq!(stats.moved as usize, m.shard(&g, victim).len(&g));
        for s in 0..4 {
            if s == victim {
                assert_eq!(m.shard_hash_fn(&g, s), HashFn::Seeded(0xfeed));
                assert_eq!(m.shard_nbuckets(&g, s), 64);
            } else {
                assert_eq!(m.shard_hash_fn(&g, s), before[s], "shard {s} was touched");
                assert_eq!(m.shard_nbuckets(&g, s), 16);
            }
        }
        // Routing is independent of the per-shard hash: nothing moved
        // across shards, every key still resolves.
        for k in 0..800u64 {
            assert_eq!(m.lookup(&g, k), Some(k), "key {k} lost");
        }
        assert_eq!(m.rebuild_count(&g), 1);
        g.quiescent_state();
        rcu_barrier();
    }

    #[test]
    fn route_snapshot_tracks_targeted_rebuilds() {
        let g = RcuThread::register();
        let m = ShardedDHash::with_buckets(4, 16, 9);
        let snap = m.route_snapshot(&g);
        assert_eq!(snap.nshards(), 4);
        assert_eq!(snap.epoch, 0);
        assert!(snap
            .shards
            .iter()
            .all(|&(h, nb)| h == HashFn::Seeded(9) && nb == 16));

        // A targeted rebuild diverges exactly one shard's geometry (and
        // does not bump the directory epoch — routing did not change).
        m.rebuild_shard(&g, 2, 64, HashFn::Seeded(0xbeef)).unwrap();
        let snap = m.route_snapshot(&g);
        assert_eq!(snap.epoch, 0);
        assert_eq!(snap.shards[2], (HashFn::Seeded(0xbeef), 64));
        for s in [0usize, 1, 3] {
            assert_eq!(snap.shards[s], (HashFn::Seeded(9), 16), "shard {s} drifted");
        }
        // The snapshot agrees with the per-shard accessors and selector.
        for s in 0..4 {
            assert_eq!(
                snap.shards[s],
                (m.shard_hash_fn(&g, s), m.shard_nbuckets(&g, s))
            );
        }
        for k in 0..1000u64 {
            assert_eq!(snap.shard_of(k) as usize, m.shard_of(&g, k));
        }
        g.quiescent_state();
        rcu_barrier();
    }

    #[test]
    fn rebuild_all_merges_stats_and_preserves_contents() {
        let g = RcuThread::register();
        let m = ShardedDHash::with_buckets(8, 8, 3);
        let n = 1000u64;
        for k in 0..n {
            m.insert(&g, k * 3, k).unwrap();
        }
        let before = m.snapshot(&g);
        let stats = m.rebuild_all(&g, 32, HashFn::Seeded(99)).unwrap();
        assert_eq!(stats.moved, n);
        assert_eq!(stats.dropped_dup, 0);
        assert_eq!(stats.nbuckets, 8 * 32);
        assert_eq!(m.nbuckets(&g), 8 * 32);
        assert_eq!(m.snapshot(&g), before);
        assert_eq!(m.rebuild_count(&g), 8);
        g.quiescent_state();
        rcu_barrier();
    }

    #[test]
    fn split_moves_every_key_to_the_right_child() {
        let g = RcuThread::register();
        let m = ShardedDHash::with_buckets(2, 16, 5);
        for k in 0..600u64 {
            m.insert(&g, k, k * 2).unwrap();
        }
        let before = m.snapshot(&g);
        assert_eq!(m.shards(), 2);
        assert_eq!(m.epoch(), 0);

        let stats = m.split_shard(&g, 1, 32, HashFn::Seeded(0xc0de)).unwrap();
        assert_eq!(m.shards(), 3);
        assert_eq!(m.epoch(), 1, "one epoch bump per split");
        assert_eq!(m.split_count(), 1);
        assert_eq!(stats.dropped_dup, 0);
        // Everything still resolves, contents identical.
        assert_eq!(m.snapshot(&g), before);
        for k in 0..600u64 {
            assert_eq!(m.lookup(&g, k), Some(k * 2), "key {k} lost in split");
        }
        // The split children hold exactly the parent's keys, partitioned
        // by the extended selector (shard 0 kept the other half-space).
        let moved_total: usize = (1..3).map(|s| m.shard(&g, s).len(&g)).sum();
        assert_eq!(stats.moved as usize, moved_total);
        // Every key lives in the shard the directory names, and each
        // child serves a disjoint selector range.
        for k in 0..600u64 {
            let s = m.shard_of(&g, k);
            assert_eq!(m.shard(&g, s).lookup(&g, k), Some(k * 2));
        }
        g.quiescent_state();
        rcu_barrier();
    }

    #[test]
    fn merge_is_the_inverse_of_split() {
        let g = RcuThread::register();
        let m = ShardedDHash::with_buckets(4, 16, 11);
        for k in 0..800u64 {
            m.insert(&g, k, k + 7).unwrap();
        }
        let before = m.snapshot(&g);
        m.split_shard(&g, 3, 16, HashFn::Seeded(1)).unwrap();
        assert_eq!(m.shards(), 5);
        // The two children are each other's buddies.
        assert_eq!(m.buddy_of(&g, 3), Some(4));
        assert_eq!(m.buddy_of(&g, 4), Some(3));
        // A shard at the base depth cannot merge with the deeper pair.
        assert_eq!(m.buddy_of(&g, 2), None);

        let stats = m.merge_shard(&g, 3, 32, HashFn::Seeded(2)).unwrap();
        assert_eq!(m.shards(), 4);
        assert_eq!(m.merge_count(), 1);
        assert_eq!(stats.dropped_dup, 0);
        assert_eq!(m.snapshot(&g), before);
        for k in 0..800u64 {
            assert_eq!(m.lookup(&g, k), Some(k + 7), "key {k} lost in merge");
        }
        g.quiescent_state();
        rcu_barrier();
    }

    #[test]
    fn merge_folds_the_directory_back_to_one_shard() {
        let g = RcuThread::register();
        let m = ShardedDHash::with_buckets(2, 8, 3);
        for k in 0..300u64 {
            m.insert(&g, k, k).unwrap();
        }
        let before = m.snapshot(&g);
        let stats = m.merge_shard(&g, 0, 16, HashFn::Seeded(9)).unwrap();
        assert_eq!(stats.moved, 300);
        assert_eq!(m.shards(), 1);
        assert_eq!(m.nbuckets(&g), 16);
        assert_eq!(m.snapshot(&g), before);
        // A single shard has no buddy.
        assert_eq!(m.buddy_of(&g, 0), None);
        assert_eq!(
            m.merge_shard(&g, 0, 16, HashFn::Seeded(10)),
            Err(ResizeError::Unmergeable)
        );
        g.quiescent_state();
        rcu_barrier();
    }

    #[test]
    fn resize_errors_are_reported() {
        let g = RcuThread::register();
        let m = ShardedDHash::with_buckets(1, 8, 1);
        assert_eq!(
            m.split_shard(&g, 5, 8, HashFn::Seeded(1)),
            Err(ResizeError::NoSuchShard)
        );
        assert_eq!(
            m.merge_shard(&g, 5, 8, HashFn::Seeded(1)),
            Err(ResizeError::NoSuchShard)
        );
        // Epoch-pinned operations refuse a stale epoch — rebuilds and
        // resizes alike (the analytics path relies on this to never
        // mistarget an ordinal a concurrent resize reassigned).
        m.split_shard(&g, 0, 8, HashFn::Seeded(2)).unwrap();
        assert!(m
            .rebuild_shard_at(&g, Some(0), 0, 8, HashFn::Seeded(3))
            .is_err());
        assert_eq!(
            m.split_shard_at(&g, Some(0), 0, 8, HashFn::Seeded(3)),
            Err(ResizeError::Busy)
        );
        assert_eq!(
            m.merge_shard_at(&g, Some(0), 0, 8, HashFn::Seeded(3)),
            Err(ResizeError::Busy)
        );
        assert!(m
            .rebuild_shard_at(&g, Some(m.epoch()), 0, 8, HashFn::Seeded(3))
            .is_ok());
        assert!(m
            .merge_shard_at(&g, Some(m.epoch()), 0, 16, HashFn::Seeded(4))
            .is_ok());
        g.quiescent_state();
        rcu_barrier();
    }

    #[test]
    fn uids_are_stable_across_resizes_and_never_reused() {
        // Ordinals shift when the directory changes shape; uids don't.
        // Controller cooldowns key on uids, so this is what makes a
        // mitigation clock survive an unrelated resize.
        let g = RcuThread::register();
        let m = ShardedDHash::with_buckets(4, 8, 1);
        let before = m.route_snapshot(&g).uids;
        assert_eq!(before, vec![0, 1, 2, 3]);

        m.split_shard(&g, 1, 8, HashFn::Seeded(2)).unwrap();
        let after = m.route_snapshot(&g).uids;
        // Shards 0, 2, 3 keep their uids (at shifted ordinals); the
        // children get fresh ones.
        assert_eq!(after[0], 0);
        assert_eq!(&after[3..], &[2, 3]);
        assert!(after[1] >= 4 && after[2] >= 4 && after[1] != after[2]);

        m.merge_shard(&g, 1, 16, HashFn::Seeded(3)).unwrap();
        let merged = m.route_snapshot(&g).uids;
        assert_eq!(merged.len(), 4);
        assert_eq!(merged[0], 0);
        assert_eq!(&merged[2..], &[2, 3]);
        // The merged shard is a NEW shard: none of the retired uids.
        assert!(!before.contains(&merged[1]) && !after.contains(&merged[1]));
        g.quiescent_state();
        rcu_barrier();
    }

    #[test]
    fn splittable_reflects_depth_headroom() {
        let g = RcuThread::register();
        let m = ShardedDHash::with_buckets(1, 4, 1);
        assert!(m.splittable(&g, 0));
        assert!(!m.splittable(&g, 9), "out of range is not splittable");
        for i in 0..MAX_DEPTH {
            assert!(m.splittable(&g, 0), "headroom at depth {i}");
            m.split_shard(&g, 0, 4, HashFn::Seeded(i as u64)).unwrap();
        }
        // At the cap: single-slot shards can no longer split...
        assert!(!m.splittable(&g, 0));
        assert_eq!(
            m.split_shard(&g, 0, 4, HashFn::Seeded(99)),
            Err(ResizeError::AtMaxDepth)
        );
        // ...but a shard still spanning several slots can halve in place.
        let wide = m.shards() - 1; // the never-split right half-space
        assert!(m.splittable(&g, wide));
        m.split_shard(&g, wide, 4, HashFn::Seeded(100)).unwrap();
        g.quiescent_state();
        rcu_barrier();
    }

    #[test]
    fn split_respects_the_depth_cap() {
        let g = RcuThread::register();
        let m = ShardedDHash::with_buckets(1, 4, 1);
        m.insert(&g, 1, 1).unwrap();
        let mut splits = 0u32;
        loop {
            match m.split_shard(&g, 0, 4, HashFn::Seeded(splits as u64)) {
                Ok(_) => splits += 1,
                Err(ResizeError::AtMaxDepth) => break,
                Err(e) => panic!("unexpected {e:?}"),
            }
            assert!(splits <= MAX_DEPTH, "cap never reached");
        }
        assert_eq!(splits, MAX_DEPTH);
        assert_eq!(m.lookup(&g, 1), Some(1));
        g.quiescent_state();
        rcu_barrier();
    }

    #[test]
    fn uneven_directory_routes_and_snapshots_coherently() {
        // Split one shard of four: five shards at mixed depths. Routing,
        // the snapshot, and per-key placement must all agree.
        let g = RcuThread::register();
        let m = ShardedDHash::with_buckets(4, 8, 13);
        for k in 0..500u64 {
            m.insert(&g, k, k).unwrap();
        }
        m.split_shard(&g, 1, 8, HashFn::Seeded(0xaa)).unwrap();
        assert_eq!(m.shards(), 5);
        let snap = m.route_snapshot(&g);
        assert_eq!(snap.nshards(), 5);
        assert_eq!(snap.epoch, m.epoch());
        let mut per = vec![0usize; 5];
        for k in 0..500u64 {
            let s = snap.shard_of(k) as usize;
            assert_eq!(s, m.shard_of(&g, k));
            assert_eq!(m.shard(&g, s).lookup(&g, k), Some(k));
            per[s] += 1;
        }
        assert_eq!(per.iter().sum::<usize>(), 500);
        // bucket_loads shape matches the per-shard geometry concatenation
        // and sums to the population.
        let loads = m.bucket_loads(&g);
        assert_eq!(loads.len(), m.nbuckets(&g));
        assert_eq!(loads.iter().sum::<usize>(), 500);
        g.quiescent_state();
        rcu_barrier();
    }

    #[test]
    fn lookups_never_miss_pinned_keys_during_split_and_merge() {
        // The elastic headline: always-present keys must never read
        // Missing while their shard splits or merges under them.
        use std::sync::atomic::AtomicBool;
        let m = Arc::new(ShardedDHash::with_buckets(2, 32, 17));
        let pinned: Vec<u64> = (0..512u64).collect();
        {
            let g = RcuThread::register();
            for &k in &pinned {
                m.insert(&g, k, k ^ 0xF00D).unwrap();
            }
            g.quiescent_state();
        }
        let stop = Arc::new(AtomicBool::new(false));
        let mut readers = Vec::new();
        for t in 0..2u64 {
            let m2 = m.clone();
            let s2 = stop.clone();
            let keys = pinned.clone();
            readers.push(std::thread::spawn(move || {
                let g = RcuThread::register();
                let mut rng = crate::util::SplitMix64::new(t + 1);
                let mut ops = 0u64;
                while !s2.load(Ordering::Relaxed) {
                    let k = keys[rng.next_bounded(keys.len() as u64) as usize];
                    assert_eq!(
                        m2.lookup(&g, k),
                        Some(k ^ 0xF00D),
                        "pinned key {k} went missing mid-resize"
                    );
                    ops += 1;
                    g.quiescent_state();
                }
                g.offline();
                ops
            }));
        }
        {
            let g = RcuThread::register();
            for round in 0..3u64 {
                m.split_shard(&g, 0, 32, HashFn::Seeded(round)).unwrap();
                assert!(m.migrating_shards() <= 1);
                m.split_shard(&g, (round as usize) % m.shards(), 32, HashFn::Seeded(round + 9))
                    .unwrap();
                // Merge back what is mergeable until we return to 2.
                while m.shards() > 2 {
                    let mut merged = false;
                    for s in 0..m.shards() {
                        if m.buddy_of(&g, s).is_some() {
                            m.merge_shard(&g, s, 32, HashFn::Seeded(round + 77)).unwrap();
                            merged = true;
                            break;
                        }
                    }
                    assert!(merged, "no mergeable pair while above target");
                }
            }
            g.quiescent_state();
        }
        stop.store(true, Ordering::Relaxed);
        let total: u64 = readers.into_iter().map(|h| h.join().unwrap()).sum();
        assert!(total > 0);
        {
            let g = RcuThread::register();
            assert_eq!(m.len(&g), pinned.len());
            g.quiescent_state();
        }
        rcu_barrier();
    }
}
