//! `ShardedDHash` — N independent [`DHashMap`] shards behind one map
//! facade (the ROADMAP's "sharding" scaling item).
//!
//! Why shard: a single `DHashMap` serializes every rebuild behind one
//! `rebuild_lock` and migrates the whole keyspace per mitigation. With N
//! shards, each shard is an independent DHash instance that rebuilds on
//! its own: an attack mitigation migrates 1/N of the keys, and the
//! whole-map [`ShardedDHash::rebuild_all`] staggers shard migrations one
//! at a time so the migration working set stays bounded.
//!
//! Routing: [`shard_of`] — a *fixed* pre-hash (top bits of
//! `mix64(key ^ SHARD_SALT)`) that is deliberately independent of the
//! per-shard [`HashFn`]. A rebuild replaces a shard's hash function but
//! never re-routes keys across shards, so all of the per-shard Lemma-4.1
//! reasoning carries over by composition: every key's full history
//! happens inside one `DHashMap`.
//!
//! Staggered-rebuild invariant: **at most one shard is migrating at any
//! moment.** Every rebuild path (targeted [`ShardedDHash::rebuild_shard`]
//! and the whole-map sweep) funnels through a single migration token; the
//! `migrating` gauge is asserted to have been 0 on every acquisition.
//! Targeted rebuilds *trylock* the token (returning [`RebuildBusy`] like
//! the paper's `-EBUSY`), while the sweep blocks for it between shards —
//! offline, so a token holder's grace periods are never stalled.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use super::{DHashMap, HashFn, KeyExists, RebuildBusy, RebuildStats};
use crate::lflist::{BucketSet, MichaelList};
use crate::rcu::RcuThread;
use crate::util::rng::mix64;

/// Salt for the shard-selector pre-hash. A public constant on purpose:
/// shard routing is *not* a secret (an adversary aiming at one shard is
/// exactly the scenario targeted mitigation handles); what matters is
/// that routing never changes when a mitigation installs a fresh seed.
const SHARD_SALT: u64 = 0x9E37_79B9_7F4A_7C15;

/// The shard for `key` among `nshards` (a power of two) shards: the top
/// `log2(nshards)` bits of `mix64(key ^ SHARD_SALT)`. Top bits keep the
/// selector independent of [`HashFn::Seeded`], which consumes the low
/// bits of the same mixer through its modulo.
#[inline(always)]
pub fn shard_of(key: u64, nshards: usize) -> usize {
    debug_assert!(nshards.is_power_of_two());
    if nshards <= 1 {
        return 0;
    }
    (mix64(key ^ SHARD_SALT) >> (64 - nshards.trailing_zeros())) as usize
}

/// N independent `DHashMap` shards routed by the fixed [`shard_of`]
/// pre-hash, with per-shard and staggered whole-map rebuilds.
pub struct ShardedDHash<B: BucketSet = MichaelList> {
    shards: Box<[DHashMap<B>]>,
    /// Serializes whole-map sweeps (trylock: a second `rebuild_all` gets
    /// [`RebuildBusy`] instead of queueing behind an O(n) migration).
    rebuild_all_lock: Mutex<()>,
    /// Grants the right to migrate ONE shard. Both targeted rebuilds and
    /// the sweep acquire it per migration, which is what makes the
    /// staggered invariant map-wide rather than sweep-local.
    migration_token: Mutex<()>,
    /// Shards currently migrating — 0 or 1 by the invariant (asserted on
    /// every migration start; exposed as [`ShardedDHash::migrating_shards`]
    /// so tests can observe the staggering from outside).
    migrating: AtomicUsize,
}

impl ShardedDHash<MichaelList> {
    /// A sharded map with `nshards` shards of `nbuckets_per_shard` buckets
    /// each, all hashing with the seeded default family.
    pub fn with_buckets(nshards: usize, nbuckets_per_shard: usize, seed: u64) -> Self {
        Self::with_hash(nshards, nbuckets_per_shard, HashFn::Seeded(seed))
    }
}

impl<B: BucketSet> ShardedDHash<B> {
    /// A sharded map with an explicit bucket algorithm and a shared
    /// initial hash function. `nshards` must be a power of two (the
    /// selector takes top bits). Mitigations re-seed shards individually
    /// afterwards, so a shared initial seed costs nothing: shard keysets
    /// are disjoint.
    pub fn with_hash(nshards: usize, nbuckets_per_shard: usize, hash: HashFn) -> Self {
        assert!(
            nshards.is_power_of_two(),
            "shard count must be a power of two, got {nshards}"
        );
        Self {
            shards: (0..nshards)
                .map(|_| DHashMap::with_hash(nbuckets_per_shard, hash))
                .collect(),
            rebuild_all_lock: Mutex::new(()),
            migration_token: Mutex::new(()),
            migrating: AtomicUsize::new(0),
        }
    }

    /// Number of shards (fixed at construction).
    pub fn shards(&self) -> usize {
        self.shards.len()
    }

    /// The shard `key` routes to.
    #[inline(always)]
    pub fn shard_of(&self, key: u64) -> usize {
        shard_of(key, self.shards.len())
    }

    /// Read access to one shard (diagnostics / tests). Rebuilding through
    /// this handle bypasses the staggered-migration token; use
    /// [`ShardedDHash::rebuild_shard`] instead.
    pub fn shard(&self, s: usize) -> &DHashMap<B> {
        &self.shards[s]
    }

    /// Shards with a migration in flight right now (0 or 1).
    pub fn migrating_shards(&self) -> usize {
        self.migrating.load(Ordering::SeqCst)
    }

    /// Lookup in the key's shard (per-shard Algorithm 4).
    #[inline]
    pub fn lookup(&self, guard: &RcuThread, key: u64) -> Option<u64> {
        self.shards[self.shard_of(key)].lookup(guard, key)
    }

    /// Insert into the key's shard (per-shard Algorithm 6).
    #[inline]
    pub fn insert(&self, guard: &RcuThread, key: u64, val: u64) -> Result<(), KeyExists> {
        self.shards[self.shard_of(key)].insert(guard, key, val)
    }

    /// Delete from the key's shard (per-shard Algorithm 5).
    #[inline]
    pub fn delete(&self, guard: &RcuThread, key: u64) -> bool {
        self.shards[self.shard_of(key)].delete(guard, key)
    }

    /// Atomic last-wins upsert in the key's shard (value swapped in
    /// place on the live node — see [`DHashMap::upsert`]). Returns true
    /// if a new node was inserted.
    #[inline]
    pub fn upsert(&self, guard: &RcuThread, key: u64, val: u64) -> bool {
        self.shards[self.shard_of(key)].upsert(guard, key, val)
    }

    /// Migrate one shard. The caller must hold `migration_token`.
    fn migrate_shard(
        &self,
        guard: &RcuThread,
        s: usize,
        nbuckets: usize,
        hash: HashFn,
    ) -> Result<RebuildStats, RebuildBusy> {
        let prev = self.migrating.fetch_add(1, Ordering::SeqCst);
        assert_eq!(
            prev, 0,
            "staggered-rebuild invariant violated: a shard is already migrating"
        );
        let r = self.shards[s].rebuild(guard, nbuckets, hash);
        self.migrating.fetch_sub(1, Ordering::SeqCst);
        r
    }

    /// Targeted rebuild of shard `s` into `nbuckets` buckets under `hash`,
    /// the mitigation primitive: 1/N of the keyspace migrates, the other
    /// shards keep serving untouched. Returns [`RebuildBusy`] if any shard
    /// (this one or another) is already migrating.
    ///
    /// The caller must not be inside a read-side critical section (same
    /// contract as [`DHashMap::rebuild`]).
    pub fn rebuild_shard(
        &self,
        guard: &RcuThread,
        s: usize,
        nbuckets: usize,
        hash: HashFn,
    ) -> Result<RebuildStats, RebuildBusy> {
        let token = match self.migration_token.try_lock() {
            Ok(t) => t,
            Err(_) => return Err(RebuildBusy),
        };
        let r = self.migrate_shard(guard, s, nbuckets, hash);
        drop(token);
        r
    }

    /// Staggered whole-map rebuild: migrate the shards **one at a time**
    /// into `nbuckets_per_shard` buckets each under `hash`, releasing the
    /// migration token between shards so targeted mitigations and the
    /// paper's concurrent lookup/insert/delete interleave freely. Returns
    /// merged [`RebuildStats`] (`nbuckets` is the new total), or
    /// [`RebuildBusy`] if another whole-map sweep is running.
    ///
    /// The caller must not be inside a read-side critical section.
    pub fn rebuild_all(
        &self,
        guard: &RcuThread,
        nbuckets_per_shard: usize,
        hash: HashFn,
    ) -> Result<RebuildStats, RebuildBusy> {
        let t0 = Instant::now();
        let _all = match self.rebuild_all_lock.try_lock() {
            Ok(g) => g,
            Err(_) => return Err(RebuildBusy),
        };
        let mut moved = 0u64;
        let mut skipped = 0u64;
        let mut dropped_dup = 0u64;
        for s in 0..self.shards.len() {
            // Blocking token acquisition, offline: a targeted rebuild may
            // hold the token and be waiting out grace periods that need
            // this thread to pass a quiescent state.
            let token = guard
                .offline_while(|| self.migration_token.lock().unwrap_or_else(|e| e.into_inner()));
            let st = self.migrate_shard(guard, s, nbuckets_per_shard, hash)?;
            drop(token);
            moved += st.moved;
            skipped += st.skipped;
            dropped_dup += st.dropped_dup;
        }
        Ok(RebuildStats {
            moved,
            skipped,
            dropped_dup,
            nbuckets: nbuckets_per_shard * self.shards.len(),
            elapsed: t0.elapsed(),
        })
    }

    /// Completed rebuilds, summed over shards.
    pub fn rebuild_count(&self) -> u64 {
        self.shards.iter().map(|s| s.rebuild_count()).sum()
    }

    /// Total bucket count, summed over shards.
    pub fn nbuckets(&self, guard: &RcuThread) -> usize {
        self.shards.iter().map(|s| s.nbuckets(guard)).sum()
    }

    /// Current bucket count of shard `s`.
    pub fn shard_nbuckets(&self, guard: &RcuThread, s: usize) -> usize {
        self.shards[s].nbuckets(guard)
    }

    /// Current hash function of shard `s` (shards diverge after targeted
    /// mitigations).
    pub fn shard_hash_fn(&self, guard: &RcuThread, s: usize) -> HashFn {
        self.shards[s].hash_fn(guard)
    }

    /// Every shard's routing geometry `(hash, nbuckets)`, captured under
    /// one RCU guard — the routing oracle's input for the vectorized
    /// `batch_hash_multi` pre-sort. Each shard's pair comes from a
    /// single table pointer ([`DHashMap::geometry`]), so the snapshot
    /// never pairs a shard's old hash with its new bucket count, even
    /// mid-staggered-rebuild. Across shards the view is coherent enough
    /// by construction: at most one shard is migrating (the staggered
    /// invariant), the fixed selector means a just-superseded geometry
    /// can never route a key to the wrong *shard*, and a batch sorted
    /// with a stale bucket geometry merely loses bucket-order locality
    /// for that one shard — the same cost as an un-routed batch.
    pub fn route_snapshot(&self, guard: &RcuThread) -> Vec<(HashFn, usize)> {
        self.shards.iter().map(|s| s.geometry(guard)).collect()
    }

    /// Live node count across all shards — O(n) scan (diagnostics; racy
    /// under concurrency, but never undercounts during a migration — see
    /// [`DHashMap::len`]).
    pub fn len(&self, guard: &RcuThread) -> usize {
        self.shards.iter().map(|s| s.len(guard)).sum()
    }

    pub fn is_empty(&self, guard: &RcuThread) -> bool {
        self.len(guard) == 0
    }

    /// Per-bucket live-node counts, shard 0's buckets first (the detector
    /// cross-check; each shard contributes `shard_nbuckets` entries).
    pub fn bucket_loads(&self, guard: &RcuThread) -> Vec<usize> {
        self.shards
            .iter()
            .flat_map(|s| s.bucket_loads(guard))
            .collect()
    }

    /// Sorted snapshot of all live `(key, value)` pairs across shards
    /// (test use; racy under concurrency).
    pub fn snapshot(&self, guard: &RcuThread) -> Vec<(u64, u64)> {
        let mut out: Vec<(u64, u64)> = self
            .shards
            .iter()
            .flat_map(|s| s.snapshot(guard))
            .collect();
        out.sort_unstable();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rcu::rcu_barrier;

    #[test]
    fn shard_of_is_in_range_and_stable() {
        for nshards in [1usize, 2, 4, 16, 64] {
            for k in [0u64, 1, 63, 1 << 40, u64::MAX - 1] {
                let s = shard_of(k, nshards);
                assert!(s < nshards, "shard {s} out of range for {nshards}");
                assert_eq!(s, shard_of(k, nshards), "selector must be pure");
            }
        }
        // One shard: everything routes to shard 0 (no 64-bit shift UB).
        assert_eq!(shard_of(u64::MAX, 1), 0);
    }

    #[test]
    fn shard_of_spreads_keys() {
        let nshards = 8;
        let mut loads = vec![0usize; nshards];
        for k in 0..8000u64 {
            loads[shard_of(k, nshards)] += 1;
        }
        let max = *loads.iter().max().unwrap();
        let min = *loads.iter().min().unwrap();
        assert!(min > 500 && max < 2000, "skewed selector: {loads:?}");
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn non_pow2_shards_rejected() {
        let _ = ShardedDHash::with_buckets(3, 8, 1);
    }

    #[test]
    fn basic_ops_route_consistently() {
        let g = RcuThread::register();
        let m = ShardedDHash::with_buckets(4, 16, 7);
        for k in 0..400u64 {
            m.insert(&g, k, k + 1).unwrap();
        }
        assert_eq!(m.len(&g), 400);
        assert_eq!(m.nbuckets(&g), 64);
        for k in 0..400u64 {
            assert_eq!(m.lookup(&g, k), Some(k + 1));
        }
        assert_eq!(m.insert(&g, 5, 0), Err(KeyExists));
        assert!(m.delete(&g, 5));
        assert!(!m.delete(&g, 5));
        assert_eq!(m.len(&g), 399);
        // The shard populations sum to the total and match the selector.
        let per: Vec<usize> = (0..4).map(|s| m.shard(s).len(&g)).collect();
        assert_eq!(per.iter().sum::<usize>(), 399);
        g.quiescent_state();
        rcu_barrier();
    }

    #[test]
    fn targeted_rebuild_touches_only_its_shard() {
        let g = RcuThread::register();
        let m = ShardedDHash::with_buckets(4, 16, 1);
        for k in 0..800u64 {
            m.insert(&g, k, k).unwrap();
        }
        let victim = 2;
        let before: Vec<HashFn> = (0..4).map(|s| m.shard_hash_fn(&g, s)).collect();
        let stats = m
            .rebuild_shard(&g, victim, 64, HashFn::Seeded(0xfeed))
            .unwrap();
        assert_eq!(stats.moved as usize, m.shard(victim).len(&g));
        for s in 0..4 {
            if s == victim {
                assert_eq!(m.shard_hash_fn(&g, s), HashFn::Seeded(0xfeed));
                assert_eq!(m.shard_nbuckets(&g, s), 64);
            } else {
                assert_eq!(m.shard_hash_fn(&g, s), before[s], "shard {s} was touched");
                assert_eq!(m.shard_nbuckets(&g, s), 16);
            }
        }
        // Routing is independent of the per-shard hash: nothing moved
        // across shards, every key still resolves.
        for k in 0..800u64 {
            assert_eq!(m.lookup(&g, k), Some(k), "key {k} lost");
        }
        assert_eq!(m.rebuild_count(), 1);
        g.quiescent_state();
        rcu_barrier();
    }

    #[test]
    fn route_snapshot_tracks_targeted_rebuilds() {
        let g = RcuThread::register();
        let m = ShardedDHash::with_buckets(4, 16, 9);
        let snap = m.route_snapshot(&g);
        assert_eq!(snap.len(), 4);
        assert!(snap.iter().all(|&(h, nb)| h == HashFn::Seeded(9) && nb == 16));

        // A targeted rebuild diverges exactly one shard's geometry.
        m.rebuild_shard(&g, 2, 64, HashFn::Seeded(0xbeef)).unwrap();
        let snap = m.route_snapshot(&g);
        assert_eq!(snap[2], (HashFn::Seeded(0xbeef), 64));
        for s in [0usize, 1, 3] {
            assert_eq!(snap[s], (HashFn::Seeded(9), 16), "shard {s} drifted");
        }
        // The snapshot agrees with the per-shard accessors.
        for s in 0..4 {
            assert_eq!(snap[s], (m.shard_hash_fn(&g, s), m.shard_nbuckets(&g, s)));
        }
        g.quiescent_state();
        rcu_barrier();
    }

    #[test]
    fn rebuild_all_merges_stats_and_preserves_contents() {
        let g = RcuThread::register();
        let m = ShardedDHash::with_buckets(8, 8, 3);
        let n = 1000u64;
        for k in 0..n {
            m.insert(&g, k * 3, k).unwrap();
        }
        let before = m.snapshot(&g);
        let stats = m.rebuild_all(&g, 32, HashFn::Seeded(99)).unwrap();
        assert_eq!(stats.moved, n);
        assert_eq!(stats.dropped_dup, 0);
        assert_eq!(stats.nbuckets, 8 * 32);
        assert_eq!(m.nbuckets(&g), 8 * 32);
        assert_eq!(m.snapshot(&g), before);
        assert_eq!(m.rebuild_count(), 8);
        g.quiescent_state();
        rcu_barrier();
    }
}
