//! The user-replaceable hash function (paper Alg. 2: `ht->hash`).
//!
//! `Seeded` is the production family — the splitmix64 finalizer keyed by
//! seed, the exact same mix the L1 Pallas kernel computes (see
//! `python/compile/kernels/hash_kernel.py` and the agreement tests).
//! `Modulo` is a deliberately weak function (`key % nbuckets`) kept for
//! the collision-attack experiments: an adversary can trivially flood one
//! bucket, which is precisely the situation `rebuild` exists to escape.

use crate::util::rng::mix64;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HashFn {
    /// `mix64(key ^ seed) % nbuckets` — strong, keyed.
    Seeded(u64),
    /// `key % nbuckets` — weak, attackable (evaluation use).
    Modulo,
}

impl HashFn {
    /// Map a key to a bucket index in `[0, nbuckets)`.
    #[inline(always)]
    pub fn bucket(self, key: u64, nbuckets: usize) -> usize {
        debug_assert!(nbuckets > 0);
        match self {
            HashFn::Seeded(seed) => (mix64(key ^ seed) % nbuckets as u64) as usize,
            HashFn::Modulo => (key % nbuckets as u64) as usize,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seeded_spreads_sequential_keys() {
        let n = 64;
        let mut loads = vec![0usize; n];
        for k in 0..6400u64 {
            loads[HashFn::Seeded(7).bucket(k, n)] += 1;
        }
        let max = *loads.iter().max().unwrap();
        // Poisson with mean 100: max should be well under 2x mean.
        assert!(max < 200, "max load {max}");
    }

    #[test]
    fn modulo_is_attackable() {
        let n = 64;
        let mut loads = vec![0usize; n];
        // Attack keys: all congruent to 3 mod 64.
        for i in 0..1000u64 {
            loads[HashFn::Modulo.bucket(3 + i * 64, n)] += 1;
        }
        assert_eq!(loads[3], 1000);
        assert_eq!(loads.iter().sum::<usize>(), 1000);
    }

    #[test]
    fn different_seeds_different_placement() {
        let n = 1024;
        let moved = (0..1000u64)
            .filter(|&k| HashFn::Seeded(1).bucket(k, n) != HashFn::Seeded(2).bucket(k, n))
            .count();
        assert!(moved > 950, "{moved}/1000 moved");
    }

    #[test]
    fn bucket_always_in_range() {
        for n in [1usize, 2, 3, 64, 1000] {
            for k in [0u64, 1, 63, u64::MAX] {
                assert!(HashFn::Seeded(9).bucket(k, n) < n);
                assert!(HashFn::Modulo.bucket(k, n) < n);
            }
        }
    }
}
