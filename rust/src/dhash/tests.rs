//! DHash unit + concurrency tests, run against all three bucket
//! implementations through the macro at the bottom.

use super::*;
use crate::lflist::{CowSortedArray, SpinlockList};
use crate::rcu::rcu_barrier;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

fn basic_ops<B: BucketSet>() {
    let g = RcuThread::register();
    let m: DHashMap<B> = DHashMap::with_hash(64, HashFn::Seeded(1));
    assert!(m.is_empty(&g));
    for k in 0..100u64 {
        m.insert(&g, k, k * 3).unwrap();
    }
    assert_eq!(m.len(&g), 100);
    assert_eq!(m.lookup(&g, 42), Some(126));
    assert_eq!(m.lookup(&g, 100), None);
    assert_eq!(m.insert(&g, 42, 0), Err(KeyExists));
    assert!(m.delete(&g, 42));
    assert!(!m.delete(&g, 42));
    assert_eq!(m.lookup(&g, 42), None);
    assert_eq!(m.len(&g), 99);
    g.quiescent_state();
    rcu_barrier();
}

fn rebuild_preserves_contents<B: BucketSet>() {
    let g = RcuThread::register();
    let m: DHashMap<B> = DHashMap::with_hash(32, HashFn::Seeded(1));
    let n = 2000u64;
    for k in 0..n {
        m.insert(&g, k * 7, k).unwrap();
    }
    let before = m.snapshot(&g);
    let stats = m.rebuild(&g, 128, HashFn::Seeded(999)).unwrap();
    assert_eq!(stats.moved, n);
    assert_eq!(stats.skipped, 0);
    assert_eq!(stats.dropped_dup, 0);
    assert_eq!(m.nbuckets(&g), 128);
    assert_eq!(m.hash_fn(&g), HashFn::Seeded(999));
    let after = m.snapshot(&g);
    assert_eq!(before, after);
    assert_eq!(m.rebuild_count(), 1);
    g.quiescent_state();
    rcu_barrier();
}

fn rebuild_shrink_and_regrow<B: BucketSet>() {
    let g = RcuThread::register();
    let m: DHashMap<B> = DHashMap::with_hash(256, HashFn::Seeded(3));
    for k in 0..500u64 {
        m.insert(&g, k, k).unwrap();
    }
    m.rebuild(&g, 8, HashFn::Seeded(4)).unwrap();
    assert_eq!(m.len(&g), 500);
    m.rebuild(&g, 512, HashFn::Seeded(5)).unwrap();
    assert_eq!(m.len(&g), 500);
    for k in 0..500u64 {
        assert_eq!(m.lookup(&g, k), Some(k), "key {k} lost");
    }
    g.quiescent_state();
    rcu_barrier();
}

fn rebuild_escapes_collision_attack<B: BucketSet>() {
    // The paper's motivating scenario: Modulo hashing + adversarial keys
    // puts everything in one bucket; rebuilding to a seeded hash function
    // restores the expected load distribution.
    let g = RcuThread::register();
    let nb = 64;
    let m: DHashMap<B> = DHashMap::with_hash(nb, HashFn::Modulo);
    for i in 0..640u64 {
        m.insert(&g, 5 + i * nb as u64, i).unwrap(); // all ≡ 5 (mod 64)
    }
    let loads = m.bucket_loads(&g);
    assert_eq!(loads[5], 640);
    m.rebuild(&g, nb, HashFn::Seeded(0xfeed)).unwrap();
    let loads = m.bucket_loads(&g);
    let max = *loads.iter().max().unwrap();
    assert!(max < 64, "attack survived rebuild: max bucket {max}");
    assert_eq!(loads.iter().sum::<usize>(), 640);
    g.quiescent_state();
    rcu_barrier();
}

fn ops_see_all_keys_during_rebuild<B: BucketSet>() {
    // Lemma 4.1 under stress: reader threads must never miss a persistent
    // key while rebuilds churn.
    let m: Arc<DHashMap<B>> = Arc::new(DHashMap::with_hash(16, HashFn::Seeded(1)));
    let nkeys = 512u64;
    {
        let g = RcuThread::register();
        for k in 0..nkeys {
            m.insert(&g, k, k + 1).unwrap();
        }
        g.quiescent_state();
    }
    let stop = Arc::new(AtomicBool::new(false));
    let misses = Arc::new(AtomicU64::new(0));
    let lookups = Arc::new(AtomicU64::new(0));
    let mut readers = Vec::new();
    for t in 0..3 {
        let m2 = m.clone();
        let s2 = stop.clone();
        let mi = misses.clone();
        let lo = lookups.clone();
        readers.push(std::thread::spawn(move || {
            let g = RcuThread::register();
            let mut rng = crate::util::SplitMix64::new(t as u64 + 99);
            while !s2.load(Ordering::Relaxed) {
                let k = rng.next_bounded(nkeys);
                match m2.lookup(&g, k) {
                    Some(v) => assert_eq!(v, k + 1),
                    None => {
                        mi.fetch_add(1, Ordering::Relaxed);
                    }
                }
                lo.fetch_add(1, Ordering::Relaxed);
                g.quiescent_state();
            }
        }));
    }
    // Single-core host: wait until readers actually run before starting
    // the rebuild storm, or the window can close with zero lookups.
    while lookups.load(Ordering::Relaxed) < 32 {
        std::thread::yield_now();
    }
    // Rebuild continuously for a while, alternating size and seed.
    {
        let g = RcuThread::register();
        for i in 0..12u64 {
            let nb = if i % 2 == 0 { 64 } else { 16 };
            m.rebuild(&g, nb, HashFn::Seeded(i)).unwrap();
        }
        g.quiescent_state();
    }
    stop.store(true, Ordering::Relaxed);
    for r in readers {
        r.join().unwrap();
    }
    assert_eq!(
        misses.load(Ordering::Relaxed),
        0,
        "lookup missed a persistent key during rebuild (Lemma 4.1 violated) \
         after {} lookups",
        lookups.load(Ordering::Relaxed)
    );
    assert!(lookups.load(Ordering::Relaxed) > 0);
    rcu_barrier();
}

fn updates_during_rebuild_linearize<B: BucketSet>() {
    // Threads own disjoint key ranges and record their final intent;
    // after heavy rebuild churn the map must agree exactly.
    let m: Arc<DHashMap<B>> = Arc::new(DHashMap::with_hash(32, HashFn::Seeded(7)));
    let per = 256u64;
    let stop = Arc::new(AtomicBool::new(false));
    let mut workers = Vec::new();
    for t in 0..3u64 {
        let m2 = m.clone();
        let s2 = stop.clone();
        workers.push(std::thread::spawn(move || {
            let g = RcuThread::register();
            let base = t * per;
            let mut rng = crate::util::SplitMix64::new(t);
            // expected[i] = Some(v) if key base+i should be present.
            // Toggle pattern: only insert keys believed absent and delete
            // keys believed present. (Inserting a *present* key during a
            // rebuild may legitimately succeed — Alg. 6 dup-checks only
            // the new table; Lemma 4.4 is one-directional — so the
            // blanket random-op assert would be unsound. The properties
            // asserted here are exactly the paper's Lemmas 4.1/4.2/4.4.)
            let mut expected: Vec<Option<u64>> = vec![None; per as usize];
            while !s2.load(Ordering::Relaxed) {
                let i = rng.next_bounded(per);
                let k = base + i;
                match expected[i as usize] {
                    None => {
                        let v = rng.next_u64() >> 1;
                        assert!(
                            m2.insert(&g, k, v).is_ok(),
                            "insert failed on absent key {k} (Lemma 4.3/4.4)"
                        );
                        // Lemma 4.4: the key must now be visible.
                        assert_eq!(m2.lookup(&g, k), Some(v), "inserted key {k} invisible");
                        expected[i as usize] = Some(v);
                    }
                    Some(v) => {
                        // Lemma 4.1: a present key is always found.
                        assert_eq!(m2.lookup(&g, k), Some(v), "present key {k} missed");
                        // Lemma 4.2: a present key can always be deleted.
                        assert!(m2.delete(&g, k), "delete failed on present key {k}");
                        expected[i as usize] = None;
                    }
                }
                g.quiescent_state();
            }
            g.offline();
            (base, expected)
        }));
    }
    {
        let g = RcuThread::register();
        for i in 0..10u64 {
            let nb = [16usize, 64, 8, 128][i as usize % 4];
            m.rebuild(&g, nb, HashFn::Seeded(1000 + i)).unwrap();
        }
        g.quiescent_state();
    }
    stop.store(true, Ordering::Relaxed);
    let g = RcuThread::register();
    for w in workers {
        let (base, expected) = w.join().unwrap();
        for (i, exp) in expected.iter().enumerate() {
            let k = base + i as u64;
            assert_eq!(m.lookup(&g, k), *exp, "final state mismatch for key {k}");
        }
    }
    g.quiescent_state();
    rcu_barrier();
}

fn concurrent_rebuild_is_busy<B: BucketSet>() {
    let m: Arc<DHashMap<B>> = Arc::new(DHashMap::with_hash(8, HashFn::Seeded(1)));
    {
        let g = RcuThread::register();
        for k in 0..4_000u64 {
            m.insert(&g, k, k).unwrap();
        }
        g.quiescent_state();
    }
    // Two threads contend the rebuild trylock with (slow, 4k-node)
    // rebuilds. Exactly one can hold it at a time, so with both sides
    // hammering, at least one side must observe RebuildBusy.
    let (started_tx, started_rx) = std::sync::mpsc::channel();
    let m2 = m.clone();
    let h = std::thread::spawn(move || {
        let g = RcuThread::register();
        let mut ok = 0u32;
        let mut busy = false;
        started_tx.send(()).unwrap();
        while ok < 3 {
            match m2.rebuild(&g, 16, HashFn::Seeded(2 + ok as u64)) {
                Ok(_) => ok += 1,
                Err(RebuildBusy) => {
                    busy = true;
                    // QSBR discipline: a spinning registered thread must
                    // keep announcing quiescence, or the lock holder's
                    // synchronize_rcu waits on us forever.
                    g.quiescent_state();
                    std::thread::yield_now();
                }
            }
        }
        g.offline();
        busy
    });
    let g = RcuThread::register();
    g.offline_while(|| started_rx.recv()).unwrap();
    let mut main_busy = false;
    for i in 0..8u64 {
        match m.rebuild(&g, 16, HashFn::Seeded(100 + i)) {
            Err(RebuildBusy) => {
                main_busy = true;
                break;
            }
            Ok(_) => std::thread::yield_now(),
        }
    }
    // Join OFFLINE: h's remaining rebuilds run synchronize_rcu, which
    // would wait forever on this thread's online-but-blocked record.
    let h_busy = g.offline_while(|| h.join()).unwrap();
    assert!(
        main_busy || h_busy,
        "two contending rebuilders never collided on the trylock"
    );
    g.quiescent_state();
    rcu_barrier();
}

fn snapshot_never_undercounts_during_rebuild<B: BucketSet>() {
    // Regression: len/snapshot/bucket_loads used to scan only the current
    // table, so during a rebuild they missed nodes already migrated to
    // ht_new and the hazard-period node. With a stable population (no
    // user deletes), the diagnostics must report *exactly* the logical
    // contents at every instant of a concurrent rebuild storm.
    let m: Arc<DHashMap<B>> = Arc::new(DHashMap::with_hash(32, HashFn::Seeded(1)));
    let n = 600u64;
    {
        let g = RcuThread::register();
        for k in 0..n {
            m.insert(&g, k, k).unwrap();
        }
        g.quiescent_state();
    }
    let stop = Arc::new(AtomicBool::new(false));
    let m2 = m.clone();
    let s2 = stop.clone();
    let rebuilder = std::thread::spawn(move || {
        let g = RcuThread::register();
        let mut i = 0u64;
        while !s2.load(Ordering::Relaxed) {
            let nb = if i % 2 == 0 { 128 } else { 16 };
            m2.rebuild(&g, nb, HashFn::Seeded(i)).unwrap();
            i += 1;
            g.quiescent_state();
        }
        g.offline();
        i
    });
    let g = RcuThread::register();
    // Keep probing until the storm has completed several rebuilds, so the
    // probes provably raced active migrations (bounded so a wedged
    // rebuilder fails loudly instead of spinning forever).
    let mut round = 0u32;
    while m.rebuild_count() < 3 {
        assert!(round < 200_000, "rebuilder made no progress");
        let len = m.len(&g);
        assert_eq!(len, n as usize, "len miscount (round {round})");
        let snap = m.snapshot(&g);
        assert_eq!(snap.len(), n as usize, "snapshot miscount (round {round})");
        for (i, &(k, v)) in snap.iter().enumerate() {
            assert_eq!((k, v), (i as u64, i as u64), "snapshot content (round {round})");
        }
        let loads = m.bucket_loads(&g);
        assert_eq!(
            loads.iter().sum::<usize>(),
            n as usize,
            "bucket_loads miscount (round {round})"
        );
        round += 1;
        g.quiescent_state();
    }
    stop.store(true, Ordering::Relaxed);
    // Join OFFLINE: the rebuilder's in-flight rebuild runs
    // synchronize_rcu, which would wait forever on this thread's
    // online-but-blocked record.
    let rebuilds = g.offline_while(|| rebuilder.join()).unwrap();
    assert!(rebuilds >= 3, "rebuilder never ran");
    rcu_barrier();
}

fn no_leaks_across_rebuilds<B: BucketSet>() {
    use crate::lflist::mem_stats;
    // Settle outstanding callbacks from other tests first.
    rcu_barrier();
    let live0 = mem_stats::live();
    {
        let g = RcuThread::register();
        let m: DHashMap<B> = DHashMap::with_hash(16, HashFn::Seeded(1));
        for k in 0..1000u64 {
            m.insert(&g, k, k).unwrap();
        }
        for k in 0..500u64 {
            m.delete(&g, k);
        }
        m.rebuild(&g, 64, HashFn::Seeded(2)).unwrap();
        m.rebuild(&g, 8, HashFn::Seeded(3)).unwrap();
        assert_eq!(m.len(&g), 500);
        g.quiescent_state();
        // Drop the map with 500 live nodes.
    }
    rcu_barrier();
    // Tests run concurrently in one process, so other suites may allocate
    // while we run; tolerate growth but catch gross leaks of our own 1000
    // nodes when the environment is quiet.
    let live1 = mem_stats::live();
    assert!(
        live1 <= live0 + 64,
        "node leak suspected: live {live0} -> {live1}"
    );
}

fn upsert_semantics<B: BucketSet>() {
    let g = RcuThread::register();
    let m: DHashMap<B> = DHashMap::with_hash(32, HashFn::Seeded(1));
    assert!(m.upsert(&g, 5, 50), "absent key must insert");
    assert!(!m.upsert(&g, 5, 51), "present key must swap in place");
    assert_eq!(m.lookup(&g, 5), Some(51));
    assert_eq!(m.len(&g), 1, "in-place swap must not duplicate the node");
    for k in 0..300u64 {
        m.upsert(&g, k, k);
    }
    assert_eq!(m.len(&g), 300);
    // Overwrites after a rebuild land on the migrated nodes.
    m.rebuild(&g, 128, HashFn::Seeded(9)).unwrap();
    for k in 0..300u64 {
        assert!(!m.upsert(&g, k, k + 7), "key {k} lost by rebuild");
    }
    for k in 0..300u64 {
        assert_eq!(m.lookup(&g, k), Some(k + 7));
    }
    assert_eq!(m.len(&g), 300);
    g.quiescent_state();
    rcu_barrier();
}

fn overwrites_never_expose_missing<B: BucketSet>() {
    // Regression for the coordinator's old Put path (delete-then-insert,
    // server.rs pre-PR-3): overwriting a key must never make it
    // observably absent — not to a concurrent reader, and not while a
    // rebuild migrates the table. `upsert` swaps the value on the live
    // node, so a key that always had a value always resolves.
    let m: Arc<DHashMap<B>> = Arc::new(DHashMap::with_hash(32, HashFn::Seeded(2)));
    let nkeys = 64u64;
    {
        let g = RcuThread::register();
        for k in 0..nkeys {
            m.insert(&g, k, 1).unwrap();
        }
        g.quiescent_state();
    }
    let stop = Arc::new(AtomicBool::new(false));
    let misses = Arc::new(AtomicU64::new(0));
    let started = Arc::new(AtomicU64::new(0));
    let mut threads = Vec::new();
    // Writers: continuous overwrites of every key.
    for t in 0..2u64 {
        let m2 = m.clone();
        let s = stop.clone();
        threads.push(std::thread::spawn(move || {
            let g = RcuThread::register();
            let mut v = t + 2;
            while !s.load(Ordering::Relaxed) {
                for k in 0..nkeys {
                    assert!(!m2.upsert(&g, k, v), "key {k} vanished under overwrite");
                    v = v.wrapping_add(1);
                }
                g.quiescent_state();
            }
            g.offline();
        }));
    }
    // Reader: every key is always present.
    {
        let m2 = m.clone();
        let s = stop.clone();
        let mi = misses.clone();
        let st = started.clone();
        threads.push(std::thread::spawn(move || {
            let g = RcuThread::register();
            let mut ops = 0u64;
            while !s.load(Ordering::Relaxed) {
                for k in 0..nkeys {
                    if m2.lookup(&g, k).is_none() {
                        mi.fetch_add(1, Ordering::Relaxed);
                    }
                }
                ops += 1;
                st.store(ops, Ordering::Relaxed);
                g.quiescent_state();
            }
            g.offline();
        }));
    }
    // Wait for real reader/writer overlap (single-core hosts), then
    // churn rebuilds so overwrites also race migrations.
    while started.load(Ordering::Relaxed) < 8 {
        std::thread::yield_now();
    }
    {
        let g = RcuThread::register();
        for i in 0..6u64 {
            m.rebuild(&g, if i % 2 == 0 { 128 } else { 16 }, HashFn::Seeded(40 + i))
                .unwrap();
        }
        g.quiescent_state();
    }
    stop.store(true, Ordering::Relaxed);
    for h in threads {
        h.join().unwrap();
    }
    assert_eq!(
        misses.load(Ordering::Relaxed),
        0,
        "a reader saw Missing for a key that always had a value"
    );
    rcu_barrier();
}

macro_rules! dhash_suite {
    ($modname:ident, $ty:ty) => {
        mod $modname {
            #[allow(unused_imports)]
            use super::*;

            #[test]
            fn basic_ops() {
                super::basic_ops::<$ty>();
            }
            #[test]
            fn rebuild_preserves_contents() {
                super::rebuild_preserves_contents::<$ty>();
            }
            #[test]
            fn rebuild_shrink_and_regrow() {
                super::rebuild_shrink_and_regrow::<$ty>();
            }
            #[test]
            fn rebuild_escapes_collision_attack() {
                super::rebuild_escapes_collision_attack::<$ty>();
            }
            #[test]
            fn ops_see_all_keys_during_rebuild() {
                super::ops_see_all_keys_during_rebuild::<$ty>();
            }
            #[test]
            fn updates_during_rebuild_linearize() {
                super::updates_during_rebuild_linearize::<$ty>();
            }
            #[test]
            fn concurrent_rebuild_is_busy() {
                super::concurrent_rebuild_is_busy::<$ty>();
            }
            #[test]
            fn snapshot_never_undercounts_during_rebuild() {
                super::snapshot_never_undercounts_during_rebuild::<$ty>();
            }
            #[test]
            fn no_leaks_across_rebuilds() {
                super::no_leaks_across_rebuilds::<$ty>();
            }
            #[test]
            fn upsert_semantics() {
                super::upsert_semantics::<$ty>();
            }
            #[test]
            fn overwrites_never_expose_missing() {
                super::overwrites_never_expose_missing::<$ty>();
            }
        }
    };
}

dhash_suite!(michael, crate::lflist::MichaelList);
dhash_suite!(spinlock, SpinlockList);
dhash_suite!(cow, CowSortedArray);

#[test]
fn display_impls() {
    assert!(format!("{RebuildBusy}").contains("rebuild"));
    assert!(format!("{KeyExists}").contains("exists"));
}

#[test]
fn default_constructor_and_reexport() {
    let g = RcuThread::register();
    let m = DHashMap::with_buckets(128, 0xabc);
    m.insert(&g, 1, 2).unwrap();
    assert_eq!(m.lookup(&g, 1), Some(2));
    assert_eq!(m.nbuckets(&g), 128);
    assert_eq!(m.hash_fn(&g), HashFn::Seeded(0xabc));
    g.quiescent_state();
}
