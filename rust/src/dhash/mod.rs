//! DHash — the paper's dynamic hash table (Algorithms 2–6).
//!
//! A `DHashMap` owns one hash table (an array of [`BucketSet`] buckets)
//! plus, while a rebuild is in progress, a second one it is migrating to.
//! [`DHashMap::rebuild`] swaps in an arbitrary *new hash function* (not
//! merely a resize) without blocking concurrent lookup / insert / delete.
//!
//! The migration protocol (§3–§4): the rebuild thread distributes each
//! node with *regular* list operations — delete from the old table, insert
//! into the new — accepting a short **hazard period** in which the node is
//! in neither table. During it, the node stays reachable through the
//! per-map pointer `rebuild_cur`, and every lookup/delete checks, in this
//! exact order:
//!
//! 1. the old table,
//! 2. the node pointed to by `rebuild_cur`,
//! 3. the new table.
//!
//! Lemma 4.1 (proved in the paper, exercised by `tests::` here and the
//! `rust/tests/rebuild_torture.rs` integration suite) shows this order
//! never misses a present key, because the rebuild writes in the opposite
//! order: `rebuild_cur := n` → delete(old) → insert(new) → `rebuild_cur :=
//! NULL`.

mod hashfn;
pub mod sharded;
mod table;

pub use hashfn::HashFn;
pub use sharded::{shard_of, ResizeError, RouteSnapshot, ShardedDHash};
pub use table::RebuildStats;

use crossbeam_utils::CachePadded;
use std::collections::HashSet;
use std::sync::atomic::{AtomicPtr, AtomicU64, Ordering};

use crate::lflist::{
    BucketSet, DeleteOutcome, MichaelList, Node, LOGICALLY_REMOVED,
};
use crate::rcu::{synchronize_rcu, RcuThread};
use table::Table;

/// Error returned by [`DHashMap::rebuild`] when another rebuild holds the
/// rebuild lock (the paper's `-EBUSY`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RebuildBusy;

impl std::fmt::Display for RebuildBusy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "a rebuild operation is already in progress")
    }
}

impl std::error::Error for RebuildBusy {}

/// Error returned by [`DHashMap::insert`] on duplicate key (`-EEXIST`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KeyExists;

impl std::fmt::Display for KeyExists {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "a node with this key already exists")
    }
}

impl std::error::Error for KeyExists {}

/// The dynamic hash table (paper Algorithm 2), generic over the bucket
/// set algorithm (paper goal 2 — modularity). `MichaelList` is the
/// default and the configuration evaluated in the paper.
pub struct DHashMap<B: BucketSet = MichaelList> {
    /// `htp`: the current table. Replaced by rebuild (Alg. 3 line 42).
    ///
    /// Cache-padded: every lookup loads `cur`, while a rebuild stores
    /// `rebuild_cur` once per migrated node — unpadded they share a line
    /// and a rebuild storm invalidates every reader's cached `cur`.
    cur: CachePadded<AtomicPtr<Table<B>>>,
    /// The node currently in its hazard period, or null (Alg. 2).
    /// Padded for the same reason as `cur` (it is the write-hot field).
    rebuild_cur: CachePadded<AtomicPtr<Node>>,
    /// Serializes rebuild attempts (Alg. 2 `rebuild_lock`; trylock only).
    rebuild_lock: std::sync::Mutex<()>,
    /// Completed rebuild count (metrics).
    rebuilds: AtomicU64,
}

// SAFETY: all shared state is atomics + RCU-managed tables.
unsafe impl<B: BucketSet> Send for DHashMap<B> {}
unsafe impl<B: BucketSet> Sync for DHashMap<B> {}

impl DHashMap<MichaelList> {
    /// A map with `nbuckets` buckets hashing with the seeded default
    /// family (`mix64(key ^ seed) % nbuckets`).
    pub fn with_buckets(nbuckets: usize, seed: u64) -> Self {
        Self::with_hash(nbuckets, HashFn::Seeded(seed))
    }
}

impl<B: BucketSet> DHashMap<B> {
    /// A map with an explicit bucket algorithm and hash function
    /// (`ht_alloc` in Alg. 2).
    pub fn with_hash(nbuckets: usize, hash: HashFn) -> Self {
        Self {
            cur: CachePadded::new(AtomicPtr::new(Table::alloc(nbuckets, hash))),
            rebuild_cur: CachePadded::new(AtomicPtr::new(std::ptr::null_mut())),
            rebuild_lock: std::sync::Mutex::new(()),
            rebuilds: AtomicU64::new(0),
        }
    }

    // lint: hot
    #[inline(always)]
    fn table(&self) -> &Table<B> {
        // Acquire: pairs with rebuild's table-swap store, so a reader that
        // observes the new table pointer observes the fully-initialized
        // table behind it. No total order with other atomics is needed:
        // Lemma 4.1's check order only relies on per-location coherence
        // plus the mark→hazard Release chain (see `live_node_slow`).
        // SAFETY: `cur` is never null; the pointed-to table is freed only
        // after a grace period follows its replacement, and all callers
        // hold a read-side critical section.
        // ord: dhash-reader — Acquire table read pairs with rebuild's Release publish
        unsafe { &*self.cur.load(Ordering::Acquire) }
    }

    /// The live node holding `key`, searched in Algorithm 4's proven
    /// order: (1) the old table, (2) the hazard-period node, (3) the new
    /// table. Lemma 4.1: this order never misses a present key.
    ///
    /// `#[inline]`: steps (1)–(2) are the steady-state fast path (one
    /// table load, one bucket find, one null check); the rebuild-only
    /// arms live in the `#[cold]` outlined `live_node_slow`.
    ///
    /// The caller must be inside a read-side critical section; the
    /// reference is valid until that section ends.
    // lint: hot
    #[inline]
    fn live_node(&self, key: u64) -> Option<&Node> {
        let htp = self.table();
        // (1) Search the old (current) hash table.
        if let Some(n) = htp.bucket(key).find(key) {
            return Some(n);
        }
        // (2) No rebuild in progress -> definitive miss. Acquire: pairs
        // with the rebuild's ht_new publication store, making the new
        // table's contents visible before we walk it.
        // ord: dhash-reader — Acquire table read pairs with rebuild's Release publish
        let htp_new = htp.ht_new.load(Ordering::Acquire);
        if htp_new.is_null() {
            return None;
        }
        self.live_node_slow(htp_new, key)
    }

    /// Steps (3)–(4) of Algorithm 4: the hazard-period node and the new
    /// table. Only reachable while a rebuild is migrating this map.
    ///
    /// Why Acquire on `rebuild_cur` suffices (Lemma 4.1 without SeqCst):
    /// the rebuild publishes `rebuild_cur = n` with Release *before* the
    /// logical-delete CAS that can make `n` missing from the old table,
    /// and that CAS is itself Release. A lookup that misses `n` in step
    /// (1) Acquire-loaded the marked/unlinked link word, so it
    /// synchronizes with the delete CAS — which happens-after the hazard
    /// store — making the non-null `rebuild_cur` visible to the Acquire
    /// load here. Miss-implies-hazard-visible needs only this
    /// release/acquire chain, not a global SC order.
    #[cold]
    #[inline(never)]
    fn live_node_slow(&self, htp_new: *mut Table<B>, key: u64) -> Option<&Node> {
        // (3) Check the node in its hazard period.
        // ord: dhash-reader — Acquire table read pairs with rebuild's Release publish
        let cur = self.rebuild_cur.load(Ordering::Acquire);
        if !cur.is_null() {
            // SAFETY: a node reachable through rebuild_cur is reclaimed
            // only after rebuild_cur is cleared *and* a grace period
            // passes; we are inside a read-side section.
            let n = unsafe { &*cur };
            if n.key == key && !n.logically_removed() {
                return Some(n);
            }
        }
        // (4) Search the new hash table.
        // SAFETY: ht_new tables are freed only after replacement + grace
        // period; non-null here means it is still installed.
        let htp_new = unsafe { &*htp_new };
        htp_new.bucket(key).find(key)
    }

    /// Lookup (paper Algorithm 4). Returns a copy of the value.
    ///
    /// `u64::MAX` is reserved (bucket sentinel) and is never present.
    ///
    /// Relaxed `val` load: the initial value was published by the Release
    /// link CAS the bucket traversal synchronized with; later overwrites
    /// (`upsert`) are racy by spec, and cross-thread read-your-write
    /// ordering is provided externally (the completion-slot Release/
    /// Acquire pair in the coordinator).
    // lint: hot
    #[inline]
    pub fn lookup(&self, guard: &RcuThread, key: u64) -> Option<u64> {
        if key == u64::MAX {
            return None;
        }
        let _g = guard.read_lock();
        // ord: node-val — value rides the link publish; later stores racy-by-spec
        self.live_node(key).map(|n| n.val.load(Ordering::Relaxed))
    }

    /// Atomic last-wins upsert: overwrite the value **in place** on the
    /// live node when the key is present (the `val` field is atomic and
    /// travels with the node through a rebuild's re-insertion, so the
    /// swap is safe mid-migration), insert otherwise. Returns true if a
    /// new node was inserted, false if an existing value was replaced.
    ///
    /// This is what makes the coordinator's `Put` atomic: the
    /// delete-then-insert overwrite it replaces had a window in which a
    /// concurrent `Get` observed `Missing` for a key that always had a
    /// value. Here an overwritten key is never absent — by Lemma 4.1 the
    /// in-place path finds every present key even during a rebuild, and
    /// the insert path only runs when the key is absent.
    pub fn upsert(&self, guard: &RcuThread, key: u64, val: u64) -> bool {
        assert_ne!(key, u64::MAX, "key u64::MAX is reserved (bucket sentinel)");
        loop {
            {
                let _g = guard.read_lock();
                if let Some(n) = self.live_node(key) {
                    // Relaxed: last-wins overwrite on one location needs
                    // only coherence; see `lookup` for the visibility
                    // contract.
                    // ord: node-val — value rides the link publish; later stores racy-by-spec
                    n.val.store(val, Ordering::Relaxed);
                    return false;
                }
            }
            if self.insert(guard, key, val).is_ok() {
                return true;
            }
            // A concurrent insert won the key between our miss and the
            // insert attempt; retry the in-place path against it.
        }
    }

    /// ABLATION ONLY (bench `ablation`, row `hazard`): Algorithm 4
    /// *without* step (2), the `rebuild_cur` hazard-period check. This is
    /// deliberately incorrect — it demonstrates the false negatives the
    /// paper's check-order proof (Lemma 4.1) exists to prevent. Never use
    /// it for real lookups.
    #[doc(hidden)]
    pub fn lookup_skip_hazard_check(&self, guard: &RcuThread, key: u64) -> Option<u64> {
        let _g = guard.read_lock();
        let htp = self.table();
        if key == u64::MAX {
            return None;
        }
        if let Some(n) = htp.bucket(key).find(key) {
            // ord: node-val — value rides the link publish; later stores racy-by-spec
            return Some(n.val.load(Ordering::Relaxed));
        }
        // ord: dhash-reader — Acquire table read pairs with rebuild's Release publish
        let htp_new = htp.ht_new.load(Ordering::Acquire);
        if htp_new.is_null() {
            return None;
        }
        // SAFETY: as in `lookup`.
        let htp_new = unsafe { &*htp_new };
        // ord: node-val — value rides the link publish; later stores racy-by-spec
        htp_new
            .bucket(key)
            .find(key)
            .map(|n| n.val.load(Ordering::Relaxed))
    }

    /// Delete (paper Algorithm 5). Returns true if a node was deleted.
    pub fn delete(&self, guard: &RcuThread, key: u64) -> bool {
        if key == u64::MAX {
            return false;
        }
        let _g = guard.read_lock();
        let htp = self.table();
        // (1) Try the old table.
        if let DeleteOutcome::Deleted(_) = htp.bucket(key).delete(key, LOGICALLY_REMOVED) {
            return true;
        }
        // Acquire pair, same reasoning as `live_node`/`live_node_slow`:
        // a miss in step (1) synchronized with the delete CAS that made
        // the node missing, which happens-after the hazard publication.
        // ord: dhash-reader — Acquire table read pairs with rebuild's Release publish
        let htp_new = htp.ht_new.load(Ordering::Acquire);
        if htp_new.is_null() {
            return false;
        }
        // (2) Check the hazard-period node: mark it deleted in place
        // (paper line 75). The flag is preserved by the rebuild's
        // re-insert, so the node is born dead in the new table.
        // ord: dhash-reader — Acquire table read pairs with rebuild's Release publish
        let cur = self.rebuild_cur.load(Ordering::Acquire);
        if !cur.is_null() {
            // SAFETY: as in lookup.
            let n = unsafe { &*cur };
            if n.key == key {
                let prev = n.set_flag(LOGICALLY_REMOVED);
                if prev & LOGICALLY_REMOVED == 0 {
                    // We won the logical deletion.
                    return true;
                }
                // Already deleted by someone else; fall through.
            }
        }
        // (3) Try the new table.
        // SAFETY: as in lookup.
        let htp_new = unsafe { &*htp_new };
        matches!(
            htp_new.bucket(key).delete(key, LOGICALLY_REMOVED),
            DeleteOutcome::Deleted(_)
        )
    }

    /// Insert (paper Algorithm 6). Fails with [`KeyExists`] if the key is
    /// already present.
    pub fn insert(&self, guard: &RcuThread, key: u64, val: u64) -> Result<(), KeyExists> {
        assert_ne!(key, u64::MAX, "key u64::MAX is reserved (bucket sentinel)");
        let node = Node::alloc(key, val);
        let _g = guard.read_lock();
        let htp = self.table();
        // Acquire: see `live_node` — the new table is fully visible when
        // its pointer is.
        // ord: dhash-reader — Acquire table read pairs with rebuild's Release publish
        let htp_new = htp.ht_new.load(Ordering::Acquire);
        // No rebuild -> old table; rebuild in progress -> new table
        // (Lemma 4.3: the RCU barrier in rebuild makes this safe).
        let bucket = if htp_new.is_null() {
            htp.bucket(key)
        } else {
            // SAFETY: as in lookup.
            unsafe { &*htp_new }.bucket(key)
        };
        match bucket.insert(node) {
            Ok(()) => Ok(()),
            Err(n) => {
                // SAFETY: rejected nodes were never published (paper frees
                // directly on line 97).
                // reclaim: node via unpublished — rejected before any reader could see it
                unsafe { Node::free(n) };
                Err(KeyExists)
            }
        }
    }

    /// Rebuild (paper Algorithm 3): migrate every node into a fresh table
    /// with `nbuckets` buckets and hash function `hash`, concurrently with
    /// other operations. Returns stats, or [`RebuildBusy`] if another
    /// rebuild is running.
    ///
    /// The caller must *not* be inside a read-side critical section; its
    /// registration is placed offline across the internal grace-period
    /// waits.
    // lint: publish rebuild
    pub fn rebuild(
        &self,
        guard: &RcuThread,
        nbuckets: usize,
        hash: HashFn,
    ) -> Result<RebuildStats, RebuildBusy> {
        let t0 = std::time::Instant::now();
        // Line 19: trylock; concurrent rebuilds get -EBUSY.
        let lock = match self.rebuild_lock.try_lock() { // lock: map-rebuild
            Ok(g) => g,
            Err(_) => return Err(RebuildBusy),
        };

        // Acquire: the previous rebuild's swap store is also ordered by
        // the rebuild lock; Acquire keeps this correct even for a reader
        // path that might call in without it in the future.
        // ord: dhash-rebuild — Algorithm 3 rebuild barrier (writer side, lock-serialized)
        let htp_ptr = self.cur.load(Ordering::Acquire);
        // SAFETY: we hold the rebuild lock; `cur` can only be replaced by
        // a rebuild, so the table stays alive for this whole function.
        let htp = unsafe { &*htp_ptr };

        // Line 21-22: allocate and publish the new table.
        let htp_new_ptr = Table::<B>::alloc(nbuckets, hash);
        // SAFETY: freshly allocated, never null.
        let htp_new = unsafe { &*htp_new_ptr };
        // SeqCst retained (writer-side protocol store, cold): this is the
        // three-barrier protocol's first publication; barrier 1 below
        // relies on it being ordered before the grace period for every
        // observer. Listed in tools/seqcst_allowlist.txt.
        // ord: dhash-rebuild — Algorithm 3 rebuild barrier (writer side, lock-serialized)
        htp.ht_new.store(htp_new_ptr, Ordering::SeqCst);

        // Line 23 (barrier 1): wait for ops that may not see ht_new yet.
        guard.offline_while(synchronize_rcu);

        // Lines 24-39: distribute every node, head-first.
        let mut moved = 0u64;
        let skipped = 0u64;
        let mut dropped_dup = 0u64;
        for bucket in htp.buckets() {
            loop {
                // Lines 25-29 fused (§Perf opt 2): take the head node
                // for distribution in one traversal; the `publish`
                // callback keeps the paper's ordering (rebuild_cur set
                // BEFORE the logical delete, so a node is reachable via
                // rebuild_cur from the moment it can be absent from the
                // old table — the crux of Lemma 4.1).
                let popped = bucket.take_first_for_distribution(&mut |cand| {
                    // Line 26-27: publish the hazard-period pointer for
                    // every candidate BEFORE its logical delete. Release
                    // is the paper's smp_wmb (§Perf opt 1).
                    // ord: dhash-rebuild — Algorithm 3 rebuild barrier (writer side, lock-serialized)
                    self.rebuild_cur.store(cand, Ordering::Release);
                });
                match popped {
                    None => {
                        // A raced candidate may linger in rebuild_cur; a
                        // user delete could free it after its own grace
                        // period while the pointer still dangles (the
                        // paper's pseudocode has the same hole on its
                        // line-30 `continue` path — see DESIGN.md
                        // §Deviations). Clear before leaving the bucket.
                        // ord: dhash-rebuild — Algorithm 3 rebuild barrier (writer side, lock-serialized)
                        self.rebuild_cur
                            .store(std::ptr::null_mut(), Ordering::Release);
                        break;
                    }
                    Some(n) => {
                        // SAFETY: unlinked by the pop; owned by us.
                        let key = unsafe { (*n).key };
                        let _ = skipped; // concurrent-delete losses are folded into the pop loop
                        // Line 32 (prepare_node) — DELIBERATE DEVIATION
                        // from the paper's pseudocode: we do NOT clear
                        // IS_BEING_DISTRIBUTED here. Clearing it would
                        // make the node's `next` word byte-identical to
                        // its pre-distribution value, re-arming stale
                        // unlink/link CASes held by concurrent ops whose
                        // `prev` is this node (an ABA the paper's removed
                        // tag field used to prevent). Instead, `insert`
                        // clears the bit atomically with publishing the
                        // node's new successor — a single transition from
                        // old-chain view to new-chain view. See
                        // DESIGN.md §Deviations.
                        // Lines 33-34: insert into the new table.
                        match htp_new.bucket(key).insert(n) {
                            Ok(()) => {
                                moved += 1;
                                // Line 37-38: leave the hazard period
                                // (Release = the paper's smp_wmb).
                                // ord: dhash-rebuild — Algorithm 3 rebuild barrier (writer side, lock-serialized)
                                self.rebuild_cur
                                    .store(std::ptr::null_mut(), Ordering::Release);
                            }
                            Err(n) => {
                                // Line 35: a concurrent insert won the new
                                // table; drop the old node. NOTE: we clear
                                // rebuild_cur BEFORE the deferred free —
                                // the paper's pseudocode orders these the
                                // other way, which would let a reader
                                // starting mid-grace-period still fetch
                                // the pointer (see DESIGN.md §Deviations).
                                // SeqCst retained (cold duplicate path):
                                // the clear must not be reordered after
                                // the defer_free enqueue in any observable
                                // way; allowlisted rather than re-proved.
                                // ord: dhash-rebuild — Algorithm 3 rebuild barrier (writer side, lock-serialized)
                                self.rebuild_cur
                                    .store(std::ptr::null_mut(), Ordering::SeqCst);
                                // SAFETY: not in any table; unreachable
                                // once rebuild_cur is cleared.
                                unsafe { Node::defer_free(n) };
                                dropped_dup += 1;
                            }
                        }
                    }
                }
            }
        }

        // Line 41: wait for ops still accessing nodes via old buckets.
        guard.offline_while(synchronize_rcu);
        // Line 42: install the new table. SeqCst retained (writer-side
        // protocol store between barriers 2 and 3, one per rebuild):
        // keeps the swap totally ordered against the grace-period
        // machinery exactly as the paper's proof sketch assumes.
        // ord: dhash-rebuild — Algorithm 3 rebuild barrier (writer side, lock-serialized)
        self.cur.store(htp_new_ptr, Ordering::SeqCst);
        // Line 43: wait for ops still referencing the old table.
        guard.offline_while(synchronize_rcu);
        // Lines 44-45: release the lock, free the old table.
        drop(lock);
        // SAFETY: unpublished for a full grace period; leftover nodes in
        // its buckets (marked-but-still-linked residue) are freed by the
        // table's Drop, which has exclusive access now.
        unsafe { drop(Box::from_raw(htp_ptr)) }; // reclaim: table via grace

        // ord: stats-relaxed — monotonic counter, no ordering role
        self.rebuilds.fetch_add(1, Ordering::Relaxed);
        Ok(RebuildStats {
            moved,
            skipped,
            dropped_dup,
            nbuckets,
            elapsed: t0.elapsed(),
        })
    }

    /// Number of completed rebuilds.
    pub fn rebuild_count(&self) -> u64 {
        // ord: stats-relaxed — monotonic counter, no ordering role
        self.rebuilds.load(Ordering::Relaxed)
    }

    /// Current bucket count.
    pub fn nbuckets(&self, guard: &RcuThread) -> usize {
        let _g = guard.read_lock();
        self.table().nbuckets
    }

    /// Current hash function.
    pub fn hash_fn(&self, guard: &RcuThread) -> HashFn {
        let _g = guard.read_lock();
        self.table().hash
    }

    /// Current `(hash, nbuckets)` geometry, both read from ONE table
    /// pointer inside one read-side section. Back-to-back
    /// [`DHashMap::hash_fn`] + [`DHashMap::nbuckets`] calls sample the
    /// table twice and can straddle a rebuild's table swap, pairing the
    /// old hash with the new bucket count; this accessor cannot.
    pub fn geometry(&self, guard: &RcuThread) -> (HashFn, usize) {
        let _g = guard.read_lock();
        let t = self.table();
        (t.hash, t.nbuckets)
    }

    /// All live `(key, value)` pairs, merged across the table *chain*:
    /// the current table, the hazard-period node, and any in-progress
    /// rebuild's destination table(s), deduplicated by key with the same
    /// precedence `lookup` uses (old table → hazard node → new table).
    ///
    /// Scanning only the current table undercounts mid-migration: nodes
    /// already distributed to `ht_new` and the node in its hazard period
    /// are invisible there. The walk below closes that. Why one
    /// `rebuild_cur` sample between tables suffices: a node absent from
    /// the scanned table was unlinked *before* our scan of its bucket,
    /// and one absent from the next table is not yet re-inserted at the
    /// time we scan its destination bucket — so its hazard period (set
    /// before the unlink, cleared after the re-insert) covers every
    /// instant between the two scans, including the sample point. Since
    /// at most one node is in its hazard period at a time, no second
    /// node can slip through the same gap.
    ///
    /// The caller must be inside a read-side critical section.
    fn merged_pairs(&self) -> Vec<(u64, u64)> {
        let mut out: Vec<(u64, u64)> = Vec::new();
        let mut seen: HashSet<u64> = HashSet::new();
        // SAFETY: as in `table` — `cur` is never null and every table
        // reachable from it stays alive for the duration of our read-side
        // critical section (tables are freed a grace period after being
        // unpublished).
        // ord: dhash-reader — Acquire table read pairs with rebuild's Release publish
        let mut t: &Table<B> = unsafe { &*self.cur.load(Ordering::Acquire) };
        loop {
            for (k, v) in t.buckets().flat_map(|b| b.collect()) {
                if seen.insert(k) {
                    out.push((k, v));
                }
            }
            // Acquire: pairs with the rebuild's ht_new publication, same
            // reasoning as the lookup path (a node missing from `t` was
            // unlinked by a Release CAS that happens-after it).
            // ord: dhash-reader — Acquire table read pairs with rebuild's Release publish
            let next = t.ht_new.load(Ordering::Acquire);
            if next.is_null() {
                // `ht_new` is published before the first node is
                // distributed out of `t`, so null here means the scan
                // above saw every node still owned by this table.
                break;
            }
            // A rebuild is (or was) migrating t → next: catch the unique
            // node in its hazard period, then follow the chain (a second
            // rebuild may have started while we were scanning).
            // ord: dhash-reader — Acquire table read pairs with rebuild's Release publish
            let cur = self.rebuild_cur.load(Ordering::Acquire);
            if !cur.is_null() {
                // SAFETY: as in `lookup` — reclaimed only after
                // `rebuild_cur` is cleared plus a grace period.
                let n = unsafe { &*cur };
                if !n.logically_removed() && seen.insert(n.key) {
                    // ord: node-val — value rides the link publish; later stores racy-by-spec
                    out.push((n.key, n.val.load(Ordering::Relaxed)));
                }
            }
            // SAFETY: non-null `ht_new` tables are freed only a grace
            // period after their predecessor is unpublished; we are in a
            // read-side section.
            t = unsafe { &*next };
        }
        out
    }

    /// Live node count — O(n) scan (diagnostics; racy under concurrency,
    /// but never transiently *undercounts* during a rebuild: the count
    /// merges the old table, the hazard-period node, and the new table).
    pub fn len(&self, guard: &RcuThread) -> usize {
        let _g = guard.read_lock();
        self.merged_pairs().len()
    }

    pub fn is_empty(&self, guard: &RcuThread) -> bool {
        self.len(guard) == 0
    }

    /// Per-bucket live-node counts (the collision diagnostic the
    /// coordinator's detector cross-checks), projected onto the *current*
    /// table's geometry. Mid-rebuild, already-migrated nodes and the
    /// hazard-period node are merged in so the loads never undercount.
    pub fn bucket_loads(&self, guard: &RcuThread) -> Vec<usize> {
        let _g = guard.read_lock();
        let htp = self.table();
        let mut loads = vec![0usize; htp.nbuckets];
        for (k, _) in self.merged_pairs() {
            loads[htp.hash.bucket(k, htp.nbuckets)] += 1;
        }
        loads
    }

    /// Sorted snapshot of all live `(key, value)` pairs (test use; racy
    /// under concurrency, but never transiently misses a key that is
    /// logically present while a rebuild migrates — see `merged_pairs`).
    pub fn snapshot(&self, guard: &RcuThread) -> Vec<(u64, u64)> {
        let _g = guard.read_lock();
        let mut out = self.merged_pairs();
        out.sort_unstable();
        out
    }
}

impl<B: BucketSet> Drop for DHashMap<B> {
    fn drop(&mut self) {
        // Exclusive access: no concurrent ops, no rebuild in flight (it
        // would borrow &self). A grace period covers stragglers that might
        // still be referenced by queued call_rcu callbacks? No — callbacks
        // never touch tables, only nodes they own. Direct free is safe.
        // Relaxed: exclusive access (&mut self).
        // ord: unshared — exclusive access (&mut/Drop); no concurrent observers
        let cur = self.cur.load(Ordering::Relaxed);
        if !cur.is_null() {
            // SAFETY: exclusive; Table::drop drains buckets.
            unsafe {
                // ord: unshared — exclusive access (&mut/Drop); no concurrent observers
                let ht_new = (*cur).ht_new.load(Ordering::Relaxed);
                if !ht_new.is_null() {
                    drop(Box::from_raw(ht_new)); // reclaim: table via exclusive
                }
                drop(Box::from_raw(cur)); // reclaim: table via exclusive
            }
        }
    }
}

#[cfg(test)]
mod tests;
