//! The table backbone (paper Alg. 2 `struct ht`): a bucket array, the
//! hash function, and the `ht_new` forwarding pointer set during rebuild.

use std::sync::atomic::AtomicPtr;
use std::time::Duration;

use super::HashFn;
use crate::lflist::BucketSet;

pub(super) struct Table<B: BucketSet> {
    pub nbuckets: usize,
    pub hash: HashFn,
    pub bkts: Box<[B]>,
    /// Null unless a rebuild is migrating this table into a successor.
    pub ht_new: AtomicPtr<Table<B>>,
}

impl<B: BucketSet> Table<B> {
    /// `ht_alloc` (Alg. 2): heap-allocate a table with empty buckets.
    pub fn alloc(nbuckets: usize, hash: HashFn) -> *mut Table<B> {
        assert!(nbuckets > 0, "hash table needs at least one bucket");
        let bkts: Box<[B]> = (0..nbuckets).map(|_| B::new()).collect();
        // reclaim: table — owned raw until published via cur/ht_new
        Box::into_raw(Box::new(Table {
            nbuckets,
            hash,
            bkts,
            ht_new: AtomicPtr::new(std::ptr::null_mut()),
        }))
    }

    /// The bucket for `key` under this table's hash function.
    #[inline(always)]
    pub fn bucket(&self, key: u64) -> &B {
        &self.bkts[self.hash.bucket(key, self.nbuckets)]
    }

    pub fn buckets(&self) -> impl Iterator<Item = &B> {
        self.bkts.iter()
    }
}

// Dropping a table drains its buckets (each BucketSet frees residual
// nodes in drain_exclusive / its own Drop).

/// Outcome of a completed rebuild (returned by `DHashMap::rebuild`).
#[derive(Debug, Clone)]
pub struct RebuildStats {
    /// Nodes migrated into the new table.
    pub moved: u64,
    /// Nodes that vanished under us (concurrently deleted) — Alg. 3 l.30.
    pub skipped: u64,
    /// Nodes dropped because a concurrent insert won the new table —
    /// Alg. 3 l.35.
    pub dropped_dup: u64,
    /// Bucket count of the new table.
    pub nbuckets: usize,
    /// Wall-clock duration of the whole rebuild (including the three
    /// grace periods).
    pub elapsed: Duration,
}

impl std::fmt::Display for RebuildStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "rebuild: moved={} skipped={} dropped_dup={} nbuckets={} elapsed={:?}",
            self.moved, self.skipped, self.dropped_dup, self.nbuckets, self.elapsed
        )
    }
}
