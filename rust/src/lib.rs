//! # DHash — dynamic, efficient concurrent hash tables
//!
//! A from-scratch reproduction of *"DHash: Enabling Dynamic and Efficient
//! Hash Tables"* (Wang, Fu, Xiao, Tian — CS.DC 2020) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the paper's contribution: a concurrent hash table
//!   whose hash function can be replaced *on the fly* (`rebuild`) without
//!   blocking concurrent lookup / insert / delete. Plus every substrate it
//!   needs: a userspace QSBR [`rcu`] implementation, an RCU-based lock-free
//!   ordered list ([`lflist`]), the three baselines the paper evaluates
//!   against ([`baselines`]), the hash-torture benchmarking framework
//!   ([`torture`]), and a serving-style coordinator ([`coordinator`]) that
//!   detects hash-collision attacks and triggers rebuilds.
//! * **L2/L1 (analytics kernels)** — the collision-analytics compute
//!   (batched keyed hashing + bucket-skew statistics) behind the
//!   [`runtime::Engine`] trait: a pure-Rust native backend (default,
//!   dependency-free) and, under the `pjrt` feature, the AOT-lowered
//!   JAX + Pallas HLO artifacts. Python is never on the request path —
//!   it is only the reference implementation and artifact producer.
//!
//! ## Quick start
//!
//! ```no_run
//! use dhash::dhash::{DHashMap, HashFn};
//! use dhash::rcu::RcuThread;
//!
//! let map = DHashMap::with_buckets(1024, 0xdead_beef);
//! let guard = RcuThread::register();
//! map.insert(&guard, 42, 4242).unwrap();
//! assert_eq!(map.lookup(&guard, 42), Some(4242));
//! // Change the hash function while other threads keep operating:
//! map.rebuild(&guard, 4096, HashFn::Seeded(0x1234_5678)).unwrap();
//! assert_eq!(map.lookup(&guard, 42), Some(4242));
//! map.delete(&guard, 42);
//! ```
//!
//! See `DESIGN.md` for the full system inventory and the per-experiment
//! index mapping every figure of the paper to a bench target.

pub mod baselines;
pub mod coordinator;
pub mod dhash;
pub mod error;
pub mod lflist;
pub mod lint;
pub mod map;
pub mod net;
pub mod rcu;
pub mod runtime;
pub mod torture;
pub mod util;

pub use crate::dhash::{DHashMap, ShardedDHash};
pub use crate::error::KvError;
pub use crate::map::ConcurrentMap;
pub use crate::rcu::RcuThread;
