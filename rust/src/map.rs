//! The [`ConcurrentMap`] facade: the object-safe trait every layer of the
//! repo programs against — torture, benches, the coordinator's KV workers,
//! and the CLI all drive tables through it, so a deployment can swap the
//! paper's single [`DHashMap`] for the sharded [`ShardedDHash`] (or one of
//! the §6 baselines) without touching a call site.
//!
//! The trait used to live in [`crate::baselines`] (which still re-exports
//! it); it moved here when it grew the diagnostic surface
//! (`bucket_loads` / `snapshot`) that the sharded refactor threads through
//! the stack.

use crate::dhash::{DHashMap, HashFn, ShardedDHash};
use crate::lflist::BucketSet;
use crate::rcu::RcuThread;

/// Retry budget for the default [`ConcurrentMap::upsert`]: each failed
/// round means a concurrent insert landed inside our delete→insert
/// window, so progress-starvation needs that adversarial interleaving
/// this many times in a row. The bound exists only so a hypothetical
/// pathological scheduler cannot spin a worker forever.
const UPSERT_RETRY_BOUND: usize = 1024;

/// Object-safe facade over the evaluated hash tables.
pub trait ConcurrentMap: Send + Sync + 'static {
    /// Display name used in bench output (`HT-DHash`, `HT-Xu`, ...).
    fn name(&self) -> &'static str;

    /// Value for `key`, if present.
    fn lookup(&self, guard: &RcuThread, key: u64) -> Option<u64>;

    /// Insert; false if the key already exists.
    fn insert(&self, guard: &RcuThread, key: u64, val: u64) -> bool;

    /// Delete; false if absent.
    fn delete(&self, guard: &RcuThread, key: u64) -> bool;

    /// Last-wins overwrite-or-insert. Returns true if the key was newly
    /// inserted, false if an existing entry was overwritten.
    ///
    /// Default: delete-then-insert, which is what the baselines can do —
    /// NOT atomic: a concurrent reader can observe a transient miss
    /// between the delete and the re-insert. The DHash implementations
    /// override this with an in-place value swap on the live node, so a
    /// key being overwritten is never absent (the coordinator's `Put`
    /// relies on this).
    ///
    /// The delete→insert window can race a concurrent insert that wins
    /// the empty slot first; swallowing that conflict would silently
    /// drop this call's value (a lost write: upsert returns as if it
    /// overwrote, but the *other* writer's value survives). The default
    /// therefore retries the delete→insert cycle until its own insert
    /// lands. The retry count is bounded for paranoia; every retry
    /// requires an adversarial interleaving to land inside the window,
    /// so the bound is unreachable outside pathological schedules — and
    /// even then the final attempt's failure leaves a *concurrent*
    /// writer's value in place, never a stale one.
    fn upsert(&self, guard: &RcuThread, key: u64, val: u64) -> bool {
        if self.insert(guard, key, val) {
            return true;
        }
        // The key existed: last-wins requires OUR value to be the one
        // visible when we return (until someone else writes later).
        for _ in 0..UPSERT_RETRY_BOUND {
            self.delete(guard, key);
            if self.insert(guard, key, val) {
                return false;
            }
            // A concurrent insert won the window — delete it and retry.
        }
        false
    }

    /// Dynamically change the table geometry / hash function.
    ///
    /// For the dynamic tables this installs `hash`; for the resizable
    /// `HtSplit`, `hash` is ignored (the paper's §6.2 protocol degrades
    /// everyone to resizing for comparability anyway) and only the power-
    /// of-two bucket count applies. `nbuckets` is the *total* budget: the
    /// sharded map divides it across shards and rebuilds them one at a
    /// time (staggered). Returns false if another rebuild is in flight
    /// or the requested geometry is invalid (`nbuckets == 0`) — the
    /// geometry check happens here at the boundary so a malformed wire
    /// or CLI request can never reach the table allocator's internal
    /// `nbuckets > 0` assert (the coordinator surfaces the same refusal
    /// as [`crate::error::ResizeError::BadGeometry`] with a wire code).
    fn rebuild(&self, guard: &RcuThread, nbuckets: usize, hash: HashFn) -> bool;

    /// Live entries (O(n), diagnostic).
    fn len(&self, guard: &RcuThread) -> usize;

    /// True when no live entries exist (O(n), diagnostic).
    fn is_empty(&self, guard: &RcuThread) -> bool {
        self.len(guard) == 0
    }

    /// Per-bucket live-node counts under the current geometry, for tables
    /// that expose their bucket structure (`None` otherwise — the
    /// baselines keep their chains private). The DHash implementations
    /// merge the mid-rebuild sources (old table, hazard node, new table)
    /// so the counts never undercount during a migration.
    fn bucket_loads(&self, _guard: &RcuThread) -> Option<Vec<usize>> {
        None
    }

    /// Sorted snapshot of all live `(key, value)` pairs, for tables that
    /// support enumeration (`None` otherwise).
    fn snapshot(&self, _guard: &RcuThread) -> Option<Vec<(u64, u64)>> {
        None
    }
}

impl<B: BucketSet> ConcurrentMap for DHashMap<B> {
    fn name(&self) -> &'static str {
        "HT-DHash"
    }

    fn lookup(&self, guard: &RcuThread, key: u64) -> Option<u64> {
        DHashMap::lookup(self, guard, key)
    }

    fn insert(&self, guard: &RcuThread, key: u64, val: u64) -> bool {
        DHashMap::insert(self, guard, key, val).is_ok()
    }

    fn delete(&self, guard: &RcuThread, key: u64) -> bool {
        DHashMap::delete(self, guard, key)
    }

    fn upsert(&self, guard: &RcuThread, key: u64, val: u64) -> bool {
        DHashMap::upsert(self, guard, key, val)
    }

    fn rebuild(&self, guard: &RcuThread, nbuckets: usize, hash: HashFn) -> bool {
        if nbuckets == 0 {
            return false;
        }
        DHashMap::rebuild(self, guard, nbuckets, hash).is_ok()
    }

    fn len(&self, guard: &RcuThread) -> usize {
        DHashMap::len(self, guard)
    }

    fn bucket_loads(&self, guard: &RcuThread) -> Option<Vec<usize>> {
        Some(DHashMap::bucket_loads(self, guard))
    }

    fn snapshot(&self, guard: &RcuThread) -> Option<Vec<(u64, u64)>> {
        Some(DHashMap::snapshot(self, guard))
    }
}

impl<B: BucketSet> ConcurrentMap for ShardedDHash<B> {
    fn name(&self) -> &'static str {
        "HT-DHash-Sharded"
    }

    fn lookup(&self, guard: &RcuThread, key: u64) -> Option<u64> {
        ShardedDHash::lookup(self, guard, key)
    }

    fn insert(&self, guard: &RcuThread, key: u64, val: u64) -> bool {
        ShardedDHash::insert(self, guard, key, val).is_ok()
    }

    fn delete(&self, guard: &RcuThread, key: u64) -> bool {
        ShardedDHash::delete(self, guard, key)
    }

    fn upsert(&self, guard: &RcuThread, key: u64, val: u64) -> bool {
        ShardedDHash::upsert(self, guard, key, val)
    }

    fn rebuild(&self, guard: &RcuThread, nbuckets: usize, hash: HashFn) -> bool {
        if nbuckets == 0 {
            return false;
        }
        // `nbuckets` is the total budget; split it across shards.
        let per_shard = (nbuckets / self.shards()).max(1);
        self.rebuild_all(guard, per_shard, hash).is_ok()
    }

    fn len(&self, guard: &RcuThread) -> usize {
        ShardedDHash::len(self, guard)
    }

    fn bucket_loads(&self, guard: &RcuThread) -> Option<Vec<usize>> {
        Some(ShardedDHash::bucket_loads(self, guard))
    }

    fn snapshot(&self, guard: &RcuThread) -> Option<Vec<(u64, u64)>> {
        Some(ShardedDHash::snapshot(self, guard))
    }
}
