//! The AOT-artifact detector backend (cargo feature `pjrt`): load the
//! HLO text lowered from the JAX/Pallas kernels (`artifacts/*.hlo.txt`,
//! produced once by `python -m compile.aot`) for execution on an
//! in-process PJRT CPU client. Python is never on the request path.
//!
//! What compiles here — and is tested everywhere — is the artifact
//! plumbing: manifest parsing, shape constants, HLO sanity checks, and
//! the fixed-batch padding rule the lowered graphs require. Actually
//! *executing* the HLO needs an in-process XLA binding (`xla-rs` /
//! `xla_extension`), which is not part of this workspace's offline
//! dependency set; until that binding is wired back in (DESIGN.md
//! §Feature matrix documents the seam), the execute paths return a
//! descriptive error and deployments use the default
//! [`super::NativeEngine`], which implements the same kernel semantics.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::{check_multi_args, Detection, Engine, HashKind, ShardParams};

/// The loaded artifact bundle: manifest constants plus the HLO module
/// text of the kernels, shape-checked and ready for a PJRT compile.
pub struct PjrtEngine {
    dir: PathBuf,
    batch_hash_hlo: String,
    detector_hlo: String,
    /// The vectorized multi-shard routing kernel is newer than some
    /// artifact bundles, so its HLO is optional: absent means
    /// `batch_hash_multi` reports the artifact missing instead of the
    /// whole bundle failing to load.
    batch_hash_multi_hlo: Option<String>,
    batch: usize,
    nbins: usize,
}

impl PjrtEngine {
    /// Load and validate the artifact bundle from `dir`.
    pub fn load(dir: &Path) -> Result<PjrtEngine> {
        let manifest = std::fs::read_to_string(dir.join("manifest.json")).with_context(|| {
            format!(
                "reading {}/manifest.json (run `python -m compile.aot --out-dir artifacts`)",
                dir.display()
            )
        })?;
        let batch = json_usize(&manifest, "batch").context("manifest: batch")?;
        let nbins = json_usize(&manifest, "nbins").context("manifest: nbins")?;
        let load = |name: &str| -> Result<String> {
            let path = dir.join(name);
            let text = std::fs::read_to_string(&path)
                .with_context(|| format!("reading {}", path.display()))?;
            if !text.contains("HloModule") {
                bail!("{} does not look like HLO text", path.display());
            }
            Ok(text)
        };
        let batch_hash_multi_hlo = if dir.join("batch_hash_multi.hlo.txt").exists() {
            Some(load("batch_hash_multi.hlo.txt")?)
        } else {
            None
        };
        Ok(PjrtEngine {
            dir: dir.to_path_buf(),
            batch_hash_hlo: load("batch_hash.hlo.txt")?,
            detector_hlo: load("detector.hlo.txt")?,
            batch_hash_multi_hlo,
            batch,
            nbins,
        })
    }

    /// Pad (or fold) `keys` to exactly `self.batch` entries — the lowered
    /// graphs have a fixed `[batch]` input shape. Shorter samples repeat
    /// cyclically so the histogram stays proportional.
    pub fn pad_keys(&self, keys: &[u64]) -> Vec<u64> {
        assert!(!keys.is_empty(), "empty key sample");
        let mut out = Vec::with_capacity(self.batch);
        for i in 0..self.batch {
            out.push(keys[i % keys.len()]);
        }
        out
    }

    /// HLO module text of one kernel (compile input for the PJRT client).
    pub fn hlo_text(&self, kernel: &str) -> Option<&str> {
        match kernel {
            "batch_hash" => Some(&self.batch_hash_hlo),
            "batch_hash_multi" => self.batch_hash_multi_hlo.as_deref(),
            "detector" => Some(&self.detector_hlo),
            _ => None,
        }
    }

    fn check_args(&self, keys: &[u64], nbuckets: u64) -> Result<()> {
        if nbuckets == 0 {
            bail!("nbuckets must be positive");
        }
        if keys.is_empty() {
            bail!("empty key sample");
        }
        Ok(())
    }

    fn execute_unavailable(&self, kernel: &str) -> anyhow::Error {
        anyhow::anyhow!(
            "PJRT backend loaded {kernel}.hlo.txt from {} but cannot execute it: in-process \
             XLA execution needs the `xla-rs` binding, which is outside the offline dependency \
             set (see DESIGN.md §Feature matrix). Use the default native engine \
             (unset DHASH_ENGINE or set DHASH_ENGINE=native).",
            self.dir.display()
        )
    }
}

impl Engine for PjrtEngine {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn batch(&self) -> usize {
        self.batch
    }

    fn nbins(&self) -> usize {
        self.nbins
    }

    fn batch_hash(
        &self,
        keys: &[u64],
        seed: u64,
        nbuckets: u64,
        kind: HashKind,
    ) -> Result<Vec<i32>> {
        self.check_args(keys, nbuckets)?;
        // Argument marshalling parity with the lowered graph signature:
        // (keys u64[batch], seed u64[1], nbuckets u64[1], kind u64[1]).
        // Oversized inputs would loop this per `batch`-sized chunk — the
        // exact-length contract is chunking, never truncation.
        let _args = (self.pad_keys(keys), [seed], [nbuckets], [kind.tag()]);
        Err(self.execute_unavailable("batch_hash"))
    }

    fn batch_hash_multi(
        &self,
        keys: &[u64],
        shard_ids: &[u32],
        shard_params: &[ShardParams],
    ) -> Result<Vec<i64>> {
        check_multi_args(keys, shard_ids, shard_params)?;
        if keys.is_empty() {
            bail!("empty key sample");
        }
        if self.batch_hash_multi_hlo.is_none() {
            bail!(
                "artifact bundle in {} predates the batch_hash_multi kernel \
                 (re-run `python -m compile.aot`)",
                self.dir.display()
            );
        }
        // Marshalling parity with the lowered graph signature: keys and
        // shard ids pad to the fixed [batch] shape (chunked per `batch`
        // for oversized inputs), per-shard params ride as [nshards]
        // vectors: (keys u64[batch], shard_ids u32[batch],
        // seeds u64[nshards], nbuckets u64[nshards], kinds u64[nshards]).
        let padded_ids: Vec<u32> = (0..self.batch)
            .map(|i| shard_ids[i % shard_ids.len()])
            .collect();
        let seeds: Vec<u64> = shard_params.iter().map(|p| p.0).collect();
        let nbuckets: Vec<u64> = shard_params.iter().map(|p| p.1).collect();
        let kinds: Vec<u64> = shard_params.iter().map(|p| p.2.tag()).collect();
        let _args = (self.pad_keys(keys), padded_ids, seeds, nbuckets, kinds);
        Err(self.execute_unavailable("batch_hash_multi"))
    }

    fn detect(&self, keys: &[u64], seed: u64, nbuckets: u64, kind: HashKind) -> Result<Detection> {
        self.check_args(keys, nbuckets)?;
        let _args = (self.pad_keys(keys), [seed], [nbuckets], [kind.tag()]);
        Err(self.execute_unavailable("detector"))
    }
}

/// Extract `"name": <integer>` from a flat JSON string (the manifest is
/// machine-generated and tiny; a JSON crate is unavailable offline).
fn json_usize(s: &str, name: &str) -> Result<usize> {
    let pat = format!("\"{name}\":");
    let at = s.find(&pat).with_context(|| format!("missing {name}"))?;
    let rest = s[at + pat.len()..].trim_start();
    let digits: String = rest.chars().take_while(|c| c.is_ascii_digit()).collect();
    digits.parse().with_context(|| format!("bad {name}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_usize_extracts() {
        let s = r#"{ "batch": 4096, "nbins": 256, "outputs": {} }"#;
        assert_eq!(json_usize(s, "batch").unwrap(), 4096);
        assert_eq!(json_usize(s, "nbins").unwrap(), 256);
        assert!(json_usize(s, "missing").is_err());
    }

    #[test]
    fn load_validates_a_synthetic_artifact_dir() {
        let dir = std::env::temp_dir().join(format!("dhash-pjrt-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), r#"{"batch": 2048, "nbins": 128}"#).unwrap();
        std::fs::write(dir.join("batch_hash.hlo.txt"), "HloModule batch_hash\n").unwrap();
        std::fs::write(dir.join("detector.hlo.txt"), "HloModule detector\n").unwrap();

        let e = PjrtEngine::load(&dir).unwrap();
        assert_eq!(e.batch(), 2048);
        assert_eq!(e.nbins(), 128);
        assert_eq!(e.name(), "pjrt");
        assert!(e.hlo_text("detector").unwrap().contains("HloModule"));
        assert!(e.hlo_text("nope").is_none());
        // The multi kernel's HLO is optional (older bundles lack it).
        assert!(e.hlo_text("batch_hash_multi").is_none());
        assert_eq!(e.pad_keys(&[1, 2, 3]).len(), 2048);
        // Execution is stubbed offline: a descriptive error, not a panic.
        assert!(e.batch_hash(&[1], 0, 16, HashKind::Modulo).is_err());
        assert!(e.batch_hash_multi(&[1], &[0], &[(0, 16, HashKind::Modulo)]).is_err());
        assert!(e.detect(&[1], 0, 16, HashKind::Seeded).is_err());

        // With the multi artifact present, its HLO loads and the execute
        // path still reports the offline stub (not a missing artifact).
        std::fs::write(
            dir.join("batch_hash_multi.hlo.txt"),
            "HloModule batch_hash_multi\n",
        )
        .unwrap();
        let e = PjrtEngine::load(&dir).unwrap();
        assert!(e.hlo_text("batch_hash_multi").unwrap().contains("HloModule"));
        let err = e.batch_hash_multi(&[1], &[0], &[(0, 16, HashKind::Modulo)]).unwrap_err();
        assert!(err.to_string().contains("cannot execute"), "{err}");

        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_rejects_missing_or_bogus_artifacts() {
        let dir = std::env::temp_dir().join(format!("dhash-pjrt-bogus-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        assert!(PjrtEngine::load(&dir).is_err(), "no manifest must fail");
        std::fs::write(dir.join("manifest.json"), r#"{"batch": 64, "nbins": 16}"#).unwrap();
        std::fs::write(dir.join("batch_hash.hlo.txt"), "not hlo").unwrap();
        std::fs::write(dir.join("detector.hlo.txt"), "HloModule d\n").unwrap();
        assert!(PjrtEngine::load(&dir).is_err(), "bogus HLO must fail");
        std::fs::remove_dir_all(&dir).ok();
    }
}
