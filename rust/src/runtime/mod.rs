//! PJRT runtime: load the AOT artifacts (`artifacts/*.hlo.txt`, produced
//! once by `make artifacts`) and execute them from Rust. Python is never
//! on the request path — the coordinator calls [`Engine`] methods, which
//! run the pre-compiled XLA executables on the in-process CPU PJRT
//! client (see /opt/xla-example/load_hlo for the reference wiring).

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// Skew statistics computed by the detector artifact (the L2 graph built
/// from the L1 Pallas kernels).
#[derive(Clone, Debug)]
pub struct Detection {
    /// Pearson chi-square of the key sample's bucket histogram against
    /// uniform, over `nbins` detector bins. Under a healthy hash this is
    /// ~chi2(nbins-1): mean ≈ nbins-1, stddev ≈ sqrt(2(nbins-1)).
    pub chi2: f32,
    /// Largest detector-bin load in the sample.
    pub max_load: i32,
    /// The full folded histogram (diagnostics / logging).
    pub hist: Vec<i32>,
}

/// Hash-function kind tags shared with the kernels (0 = modulo,
/// 1 = seeded mix64).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HashKind {
    Modulo,
    Seeded,
}

impl HashKind {
    fn tag(self) -> u64 {
        match self {
            HashKind::Modulo => 0,
            HashKind::Seeded => 1,
        }
    }

    /// The kind tag for a table's [`crate::dhash::HashFn`], plus its seed.
    pub fn of(hash: crate::dhash::HashFn) -> (Self, u64) {
        match hash {
            crate::dhash::HashFn::Modulo => (HashKind::Modulo, 0),
            crate::dhash::HashFn::Seeded(s) => (HashKind::Seeded, s),
        }
    }
}

/// The loaded-and-compiled artifact bundle.
pub struct Engine {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    batch_hash: xla::PjRtLoadedExecutable,
    detector: xla::PjRtLoadedExecutable,
    /// Exported batch size (keys per execution); inputs are padded.
    pub batch: usize,
    /// Detector histogram bins.
    pub nbins: usize,
}

impl Engine {
    /// Default artifact directory: `$DHASH_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("DHASH_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    /// Load and compile both artifacts from `dir`.
    pub fn load(dir: &Path) -> Result<Engine> {
        let manifest = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json (run `make artifacts`)", dir.display()))?;
        let batch = json_usize(&manifest, "batch").context("manifest: batch")?;
        let nbins = json_usize(&manifest, "nbins").context("manifest: nbins")?;

        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let load = |name: &str| -> Result<xla::PjRtLoadedExecutable> {
            let path = dir.join(name);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path not UTF-8")?,
            )
            .with_context(|| format!("parsing {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client
                .compile(&comp)
                .with_context(|| format!("compiling {}", path.display()))
        };
        let batch_hash = load("batch_hash.hlo.txt")?;
        let detector = load("detector.hlo.txt")?;
        Ok(Engine {
            client,
            batch_hash,
            detector,
            batch,
            nbins,
        })
    }

    /// Pad (or fold) `keys` to exactly `self.batch` entries. Shorter
    /// samples repeat cyclically so the histogram stays proportional.
    fn pad_keys(&self, keys: &[u64]) -> Vec<u64> {
        assert!(!keys.is_empty(), "empty key sample");
        let mut out = Vec::with_capacity(self.batch);
        for i in 0..self.batch {
            out.push(keys[i % keys.len()]);
        }
        out
    }

    fn args(
        &self,
        keys: &[u64],
        seed: u64,
        nbuckets: u64,
        kind: HashKind,
    ) -> Result<[xla::Literal; 4]> {
        if nbuckets == 0 {
            bail!("nbuckets must be positive");
        }
        let keys = self.pad_keys(keys);
        Ok([
            xla::Literal::vec1(&keys),
            xla::Literal::vec1(&[seed]),
            xla::Literal::vec1(&[nbuckets]),
            xla::Literal::vec1(&[kind.tag()]),
        ])
    }

    /// Bucket ids for up to `batch` keys (`batch_hash.hlo.txt`). Returns
    /// exactly `keys.len().min(batch)` ids.
    pub fn batch_hash(
        &self,
        keys: &[u64],
        seed: u64,
        nbuckets: u64,
        kind: HashKind,
    ) -> Result<Vec<i32>> {
        let args = self.args(keys, seed, nbuckets, kind)?;
        let result = self.batch_hash.execute::<xla::Literal>(&args)?[0][0]
            .to_literal_sync()?
            .to_tuple1()?;
        let ids: Vec<i32> = result.to_vec()?;
        Ok(ids[..keys.len().min(self.batch)].to_vec())
    }

    /// Skew statistics for a key sample (`detector.hlo.txt`).
    pub fn detect(
        &self,
        keys: &[u64],
        seed: u64,
        nbuckets: u64,
        kind: HashKind,
    ) -> Result<Detection> {
        let args = self.args(keys, seed, nbuckets, kind)?;
        let (chi2, max_load, hist) = self.detector.execute::<xla::Literal>(&args)?[0][0]
            .to_literal_sync()?
            .to_tuple3()?;
        Ok(Detection {
            chi2: chi2.get_first_element::<f32>()?,
            max_load: max_load.get_first_element::<i32>()?,
            hist: hist.to_vec()?,
        })
    }

    /// Detector threshold for "this sample is an attack": mean + `k`
    /// standard deviations of the chi2(nbins-1) null distribution.
    pub fn chi2_threshold(&self, k: f32) -> f32 {
        let dof = (self.nbins - 1) as f32;
        dof + k * (2.0 * dof).sqrt()
    }
}

/// Extract `"name": <integer>` from a flat JSON string (the manifest is
/// machine-generated and tiny; a JSON crate is unavailable offline).
fn json_usize(s: &str, name: &str) -> Result<usize> {
    let pat = format!("\"{name}\":");
    let at = s.find(&pat).with_context(|| format!("missing {name}"))?;
    let rest = s[at + pat.len()..].trim_start();
    let digits: String = rest.chars().take_while(|c| c.is_ascii_digit()).collect();
    digits.parse().with_context(|| format!("bad {name}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_usize_extracts() {
        let s = r#"{ "batch": 4096, "nbins": 256, "outputs": {} }"#;
        assert_eq!(json_usize(s, "batch").unwrap(), 4096);
        assert_eq!(json_usize(s, "nbins").unwrap(), 256);
        assert!(json_usize(s, "missing").is_err());
    }

    #[test]
    fn chi2_threshold_shape() {
        // Engine::load needs artifacts; threshold math is pure.
        let dof = 255.0f32;
        let t = dof + 8.0 * (2.0 * dof).sqrt();
        assert!(t > dof && t < 3.0 * dof);
    }
}
