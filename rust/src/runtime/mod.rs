//! The detector engine: batched keyed hashing + bucket-skew statistics
//! behind one [`Engine`] trait, with pluggable backends.
//!
//! The coordinator's analytics path (batch pre-hashing and the chi-square
//! collision detector) is expressed as two kernels — `batch_hash` and
//! `detect` — whose reference semantics live in
//! `python/compile/kernels/ref.py`. Two backends implement them:
//!
//! * [`native::NativeEngine`] (**default**) — a pure-Rust
//!   reimplementation, bit-for-bit equal to the Python reference on the
//!   hash path and validated against golden vectors emitted by
//!   `python/tests/gen_golden.py`. Runs on any machine: no artifacts, no
//!   Python toolchain.
//! * [`pjrt::PjrtEngine`] (cargo feature `pjrt`) — the AOT-artifact
//!   backend: loads the HLO text lowered from the JAX/Pallas kernels
//!   (`python -m compile.aot`) for execution on an in-process PJRT
//!   client. The artifact plumbing (manifest, shapes, padding) compiles
//!   and is tested everywhere; executing the HLO additionally needs an
//!   XLA binding that is not part of the offline dependency set — see
//!   `DESIGN.md` §Feature matrix.
//!
//! Backend selection is environment-driven: `DHASH_ENGINE=native` (the
//! default) or `DHASH_ENGINE=pjrt`, resolved by [`load_engine`].

pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;

pub use native::NativeEngine;
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtEngine;

use std::path::PathBuf;

use anyhow::{bail, Result};

/// Skew statistics computed by a detector backend over one key sample.
#[derive(Clone, Debug)]
pub struct Detection {
    /// Pearson chi-square of the key sample's bucket histogram against
    /// uniform, over `nbins` detector bins. Under a healthy hash this is
    /// ~chi2(nbins-1): mean ≈ nbins-1, stddev ≈ sqrt(2(nbins-1)).
    pub chi2: f32,
    /// Largest detector-bin load in the sample.
    pub max_load: i32,
    /// The full folded histogram (diagnostics / logging).
    pub hist: Vec<i32>,
}

/// Hash-function kind tags shared with the kernels (0 = modulo,
/// 1 = seeded mix64).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HashKind {
    Modulo,
    Seeded,
}

impl HashKind {
    /// The numeric tag the kernels take as their `kind` argument.
    pub fn tag(self) -> u64 {
        match self {
            HashKind::Modulo => 0,
            HashKind::Seeded => 1,
        }
    }

    /// The kind tag for a table's [`crate::dhash::HashFn`], plus its seed.
    pub fn of(hash: crate::dhash::HashFn) -> (Self, u64) {
        match hash {
            crate::dhash::HashFn::Modulo => (HashKind::Modulo, 0),
            crate::dhash::HashFn::Seeded(s) => (HashKind::Seeded, s),
        }
    }
}

/// A detector backend: the two analytics kernels plus the shape constants
/// policy code needs. Backends are constructed on the thread that uses
/// them (the PJRT client is not `Send`), so the trait does not require
/// `Send`.
pub trait Engine {
    /// Backend name for logs and bench rows.
    fn name(&self) -> &'static str;

    /// Keys per kernel execution. The native backend processes samples of
    /// any size up to this; the artifact backend pads shorter samples.
    fn batch(&self) -> usize;

    /// Detector histogram bins (bucket ids are folded modulo this).
    fn nbins(&self) -> usize;

    /// Bucket ids for up to [`Engine::batch`] keys. Returns exactly
    /// `keys.len().min(self.batch())` ids.
    fn batch_hash(
        &self,
        keys: &[u64],
        seed: u64,
        nbuckets: u64,
        kind: HashKind,
    ) -> Result<Vec<i32>>;

    /// Skew statistics for a key sample.
    fn detect(&self, keys: &[u64], seed: u64, nbuckets: u64, kind: HashKind) -> Result<Detection>;

    /// Detector threshold for "this sample is an attack": mean + `k`
    /// standard deviations of the chi2(nbins-1) null distribution.
    fn chi2_threshold(&self, k: f32) -> f32 {
        let dof = (self.nbins() - 1) as f32;
        dof + k * (2.0 * dof).sqrt()
    }
}

/// Artifact directory for the PJRT backend: `$DHASH_ARTIFACTS` or
/// `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var_os("DHASH_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

/// Construct the configured detector backend: `$DHASH_ENGINE` picks
/// `native` (the default) or `pjrt` (requires the `pjrt` cargo feature
/// and artifacts from `python -m compile.aot`).
pub fn load_engine() -> Result<Box<dyn Engine>> {
    match std::env::var("DHASH_ENGINE").as_deref() {
        Err(_) | Ok("") | Ok("native") => Ok(Box::new(NativeEngine::new())),
        Ok("pjrt") => load_pjrt(),
        Ok(other) => bail!("unknown DHASH_ENGINE {other:?} (expected \"native\" or \"pjrt\")"),
    }
}

#[cfg(feature = "pjrt")]
fn load_pjrt() -> Result<Box<dyn Engine>> {
    Ok(Box::new(PjrtEngine::load(&artifacts_dir())?))
}

#[cfg(not(feature = "pjrt"))]
fn load_pjrt() -> Result<Box<dyn Engine>> {
    bail!("DHASH_ENGINE=pjrt requested, but this binary was built without the `pjrt` feature")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_engine_is_native() {
        // The suite does not set DHASH_ENGINE; the default must be the
        // dependency-free native backend.
        let engine = load_engine().unwrap();
        assert_eq!(engine.name(), "native");
        assert!(engine.batch() >= 1024);
        assert!(engine.nbins() >= 64);
    }

    #[test]
    fn chi2_threshold_shape() {
        let engine = NativeEngine::new();
        let dof = (engine.nbins() - 1) as f32;
        let t = engine.chi2_threshold(8.0);
        assert!(t > dof && t < 3.0 * dof);
        assert!(engine.chi2_threshold(4.0) < t);
    }

    #[test]
    fn hash_kind_tags_and_of() {
        assert_eq!(HashKind::Modulo.tag(), 0);
        assert_eq!(HashKind::Seeded.tag(), 1);
        assert_eq!(
            HashKind::of(crate::dhash::HashFn::Modulo),
            (HashKind::Modulo, 0)
        );
        assert_eq!(
            HashKind::of(crate::dhash::HashFn::Seeded(7)),
            (HashKind::Seeded, 7)
        );
    }
}
