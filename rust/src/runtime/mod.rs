//! The detector engine: batched keyed hashing + bucket-skew statistics
//! behind one [`Engine`] trait, with pluggable backends.
//!
//! The coordinator's analytics path (batch pre-hashing, vectorized
//! multi-shard routing, and the chi-square collision detector) is
//! expressed as three kernels — `batch_hash`, `batch_hash_multi`, and
//! `detect` — whose reference semantics live in
//! `python/compile/kernels/ref.py`. Two backends implement them:
//!
//! * [`native::NativeEngine`] (**default**) — a pure-Rust
//!   reimplementation, bit-for-bit equal to the Python reference on the
//!   hash path and validated against golden vectors emitted by
//!   `python/tests/gen_golden.py`. Runs on any machine: no artifacts, no
//!   Python toolchain.
//! * [`pjrt::PjrtEngine`] (cargo feature `pjrt`) — the AOT-artifact
//!   backend: loads the HLO text lowered from the JAX/Pallas kernels
//!   (`python -m compile.aot`) for execution on an in-process PJRT
//!   client. The artifact plumbing (manifest, shapes, padding) compiles
//!   and is tested everywhere; executing the HLO additionally needs an
//!   XLA binding that is not part of the offline dependency set — see
//!   `DESIGN.md` §Feature matrix.
//!
//! Backend selection is environment-driven: `DHASH_ENGINE=native` (the
//! default) or `DHASH_ENGINE=pjrt`, resolved by [`load_engine`].

pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;

pub use native::NativeEngine;
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtEngine;

use std::path::PathBuf;

use anyhow::{bail, Result};

/// Skew statistics computed by a detector backend over one key sample.
#[derive(Clone, Debug)]
pub struct Detection {
    /// Pearson chi-square of the key sample's bucket histogram against
    /// uniform, over `nbins` detector bins. Under a healthy hash this is
    /// ~chi2(nbins-1): mean ≈ nbins-1, stddev ≈ sqrt(2(nbins-1)).
    pub chi2: f32,
    /// Largest detector-bin load in the sample.
    pub max_load: i32,
    /// The full folded histogram (diagnostics / logging).
    pub hist: Vec<i32>,
}

/// Hash-function kind tags shared with the kernels (0 = modulo,
/// 1 = seeded mix64).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HashKind {
    Modulo,
    Seeded,
}

impl HashKind {
    /// The numeric tag the kernels take as their `kind` argument.
    pub fn tag(self) -> u64 {
        match self {
            HashKind::Modulo => 0,
            HashKind::Seeded => 1,
        }
    }

    /// The kind tag for a table's [`crate::dhash::HashFn`], plus its seed.
    pub fn of(hash: crate::dhash::HashFn) -> (Self, u64) {
        match hash {
            crate::dhash::HashFn::Modulo => (HashKind::Modulo, 0),
            crate::dhash::HashFn::Seeded(s) => (HashKind::Seeded, s),
        }
    }
}

/// Per-shard hash geometry for [`Engine::batch_hash_multi`]:
/// `(seed, nbuckets, kind)`, one entry per shard, indexed by shard id.
pub type ShardParams = (u64, u64, HashKind);

/// Compose a `(shard, bucket)` pair into the i64 routing id the
/// batcher's pre-routing sort orders by: `(shard << 32) | bucket`.
/// Sorting these ids walks shards in order and, within a shard, buckets
/// in order — the full locality order the coordinator batches for.
#[inline]
pub fn composite_route_id(shard: u32, bucket: u32) -> i64 {
    ((shard as i64) << 32) | bucket as i64
}

/// Shared argument validation for [`Engine::batch_hash_multi`] backends:
/// one shard id per key, every id in range, and every shard's bucket
/// count positive and small enough for the composite id's 32-bit bucket
/// field.
pub(crate) fn check_multi_args(
    keys: &[u64],
    shard_ids: &[u32],
    shard_params: &[ShardParams],
) -> Result<()> {
    if shard_ids.len() != keys.len() {
        bail!("shard_ids length {} != keys length {}", shard_ids.len(), keys.len());
    }
    for (s, &(_, nbuckets, _)) in shard_params.iter().enumerate() {
        if nbuckets == 0 {
            bail!("shard {s}: nbuckets must be positive");
        }
        if nbuckets > u32::MAX as u64 {
            bail!("shard {s}: nbuckets {nbuckets} exceeds the 32-bit bucket field");
        }
    }
    if let Some(&s) = shard_ids.iter().find(|&&s| s as usize >= shard_params.len()) {
        bail!("shard id {s} out of range ({} shards)", shard_params.len());
    }
    Ok(())
}

/// A detector backend: the analytics kernels plus the shape constants
/// policy code needs. Backends are constructed on the thread that uses
/// them (the PJRT client is not `Send`), so the trait does not require
/// `Send`.
pub trait Engine {
    /// Backend name for logs and bench rows.
    fn name(&self) -> &'static str;

    /// Keys per kernel execution. Hash kernels chunk larger inputs over
    /// this internally; the artifact backend pads shorter samples.
    fn batch(&self) -> usize;

    /// Detector histogram bins (bucket ids are folded modulo this).
    fn nbins(&self) -> usize;

    /// Bucket ids for `keys` under one hash geometry. The answer always
    /// has exactly `keys.len()` entries: inputs larger than
    /// [`Engine::batch`] are chunked internally, never truncated (a
    /// short answer would make the batcher's exact-length guard fail and
    /// the batch silently lose its pre-routing).
    fn batch_hash(
        &self,
        keys: &[u64],
        seed: u64,
        nbuckets: u64,
        kind: HashKind,
    ) -> Result<Vec<i32>>;

    /// Composite routing ids ([`composite_route_id`]: `(shard << 32) |
    /// bucket`) for a mixed-shard batch in ONE engine call: key `i` is
    /// hashed with `shard_params[shard_ids[i] as usize]`. Like
    /// [`Engine::batch_hash`], the answer always has exactly
    /// `keys.len()` entries — larger inputs are chunked over
    /// [`Engine::batch`] internally. Errors if `shard_ids.len() !=
    /// keys.len()`, a shard id is out of range, or any shard's
    /// `nbuckets` is 0 or exceeds `u32::MAX` (the composite id keeps
    /// the bucket in 32 bits).
    ///
    /// The kernel itself is layout-agnostic: `shard_ids` and
    /// `shard_params` must come from ONE epoch-stamped
    /// `ShardedDHash::route_snapshot`, and the caller (the batcher's
    /// routing oracle) re-checks the live directory epoch afterwards —
    /// under elastic sharding the shard *set* moves, and ids computed
    /// against a retired epoch are discarded (counted as an epoch
    /// fallback) rather than sorted by.
    fn batch_hash_multi(
        &self,
        keys: &[u64],
        shard_ids: &[u32],
        shard_params: &[ShardParams],
    ) -> Result<Vec<i64>>;

    /// Skew statistics for a key sample.
    fn detect(&self, keys: &[u64], seed: u64, nbuckets: u64, kind: HashKind) -> Result<Detection>;

    /// Detector threshold for "this sample is an attack": mean + `k`
    /// standard deviations of the chi2(nbins-1) null distribution.
    fn chi2_threshold(&self, k: f32) -> f32 {
        let dof = (self.nbins() - 1) as f32;
        dof + k * (2.0 * dof).sqrt()
    }
}

/// Artifact directory for the PJRT backend: `$DHASH_ARTIFACTS` or
/// `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var_os("DHASH_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

/// Construct the configured detector backend: `$DHASH_ENGINE` picks
/// `native` (the default) or `pjrt` (requires the `pjrt` cargo feature
/// and artifacts from `python -m compile.aot`).
pub fn load_engine() -> Result<Box<dyn Engine>> {
    match std::env::var("DHASH_ENGINE").as_deref() {
        Err(_) | Ok("") | Ok("native") => Ok(Box::new(NativeEngine::new())),
        Ok("pjrt") => load_pjrt(),
        Ok(other) => bail!("unknown DHASH_ENGINE {other:?} (expected \"native\" or \"pjrt\")"),
    }
}

#[cfg(feature = "pjrt")]
fn load_pjrt() -> Result<Box<dyn Engine>> {
    Ok(Box::new(PjrtEngine::load(&artifacts_dir())?))
}

#[cfg(not(feature = "pjrt"))]
fn load_pjrt() -> Result<Box<dyn Engine>> {
    bail!("DHASH_ENGINE=pjrt requested, but this binary was built without the `pjrt` feature")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_engine_is_native() {
        // The suite does not set DHASH_ENGINE; the default must be the
        // dependency-free native backend.
        let engine = load_engine().unwrap();
        assert_eq!(engine.name(), "native");
        assert!(engine.batch() >= 1024);
        assert!(engine.nbins() >= 64);
    }

    #[test]
    fn chi2_threshold_shape() {
        let engine = NativeEngine::new();
        let dof = (engine.nbins() - 1) as f32;
        let t = engine.chi2_threshold(8.0);
        assert!(t > dof && t < 3.0 * dof);
        assert!(engine.chi2_threshold(4.0) < t);
    }

    #[test]
    fn composite_route_id_layout() {
        assert_eq!(composite_route_id(0, 0), 0);
        assert_eq!(composite_route_id(0, 7), 7);
        assert_eq!(composite_route_id(1, 0), 1 << 32);
        assert_eq!(composite_route_id(3, 9), (3 << 32) | 9);
        // The full u32 bucket range fits without sign contamination.
        assert_eq!(composite_route_id(2, u32::MAX), (2i64 << 32) | 0xffff_ffff);
        // Sort order is shard-major, bucket-minor.
        assert!(composite_route_id(0, u32::MAX) < composite_route_id(1, 0));
    }

    #[test]
    fn multi_args_are_validated() {
        let p: Vec<ShardParams> = vec![(1, 16, HashKind::Seeded), (2, 8, HashKind::Modulo)];
        assert!(check_multi_args(&[1, 2], &[0, 1], &p).is_ok());
        assert!(check_multi_args(&[], &[], &p).is_ok());
        // One shard id per key.
        assert!(check_multi_args(&[1, 2], &[0], &p).is_err());
        // Shard ids must be in range.
        assert!(check_multi_args(&[1], &[2], &p).is_err());
        // Zero buckets and >32-bit bucket counts are rejected.
        assert!(check_multi_args(&[1], &[0], &[(0, 0, HashKind::Seeded)]).is_err());
        let wide = [(0, u32::MAX as u64 + 1, HashKind::Seeded)];
        assert!(check_multi_args(&[1], &[0], &wide).is_err());
    }

    #[test]
    fn hash_kind_tags_and_of() {
        assert_eq!(HashKind::Modulo.tag(), 0);
        assert_eq!(HashKind::Seeded.tag(), 1);
        assert_eq!(
            HashKind::of(crate::dhash::HashFn::Modulo),
            (HashKind::Modulo, 0)
        );
        assert_eq!(
            HashKind::of(crate::dhash::HashFn::Seeded(7)),
            (HashKind::Seeded, 7)
        );
    }
}
