//! The default detector backend: a pure-Rust reimplementation of the
//! batched keyed-hash, bucket-histogram, and chi-square kernels.
//!
//! Semantics match `python/compile/kernels/ref.py` exactly:
//!
//! * `batch_hash`: `kind == 0` → `key % nbuckets`; `kind == 1` →
//!   `mix64(key ^ seed) % nbuckets` (the splitmix64 finalizer shared with
//!   [`crate::util::rng::mix64`] and the Pallas kernel — pinned vectors on
//!   all three sides).
//! * `batch_hash_multi`: the same placement rule dispatched per key
//!   through a vector of per-shard `(seed, nbuckets, kind)` geometries,
//!   emitting composite `(shard << 32) | bucket` routing ids
//!   ([`crate::runtime::composite_route_id`]) for the batcher's
//!   mixed-shard pre-sort.
//! * `detect`: fold bucket ids modulo `nbins`, histogram, Pearson
//!   chi-square against the uniform expectation `n / nbins`, max load.
//!
//! One deliberate difference from the AOT artifact: the artifact executes
//! a fixed `[batch]`-shaped graph, so short samples are padded by cyclic
//! repetition; the native backend computes on the exact sample, which
//! keeps the chi-square on its nominal null distribution for every sample
//! size. `rust/tests/golden_vectors.rs` pins both kernels against vectors
//! emitted by the Python reference implementation.

use anyhow::{bail, Result};

use super::{check_multi_args, composite_route_id, Detection, Engine, HashKind, ShardParams};
use crate::util::rng::mix64;

/// Pure-Rust detector engine. Construction is free; the struct only
/// carries the shape constants.
pub struct NativeEngine {
    batch: usize,
    nbins: usize,
}

/// Keys per execution, matching the exported artifact batch
/// (`python/compile/model.py::BATCH`) so sampler sizing is
/// backend-independent.
pub const DEFAULT_BATCH: usize = 4096;

/// Detector histogram bins, matching
/// `python/compile/kernels/hist_kernel.py::NBINS`. Table bucket ids are
/// folded modulo this, so detection granularity assumes `nbuckets` is a
/// multiple of (or at least no smaller than) `nbins`.
pub const DEFAULT_NBINS: usize = 256;

impl NativeEngine {
    pub fn new() -> Self {
        Self::with_shape(DEFAULT_BATCH, DEFAULT_NBINS)
    }

    /// An engine with explicit shape constants (tests and experiments).
    pub fn with_shape(batch: usize, nbins: usize) -> Self {
        assert!(batch > 0 && nbins > 0);
        Self { batch, nbins }
    }

    /// One key's bucket id under the kernel's placement rules.
    #[inline]
    fn bucket(key: u64, seed: u64, nbuckets: u64, kind: HashKind) -> u64 {
        match kind {
            HashKind::Modulo => key % nbuckets,
            HashKind::Seeded => mix64(key ^ seed) % nbuckets,
        }
    }
}

impl Default for NativeEngine {
    fn default() -> Self {
        Self::new()
    }
}

impl Engine for NativeEngine {
    fn name(&self) -> &'static str {
        "native"
    }

    fn batch(&self) -> usize {
        self.batch
    }

    fn nbins(&self) -> usize {
        self.nbins
    }

    fn batch_hash(
        &self,
        keys: &[u64],
        seed: u64,
        nbuckets: u64,
        kind: HashKind,
    ) -> Result<Vec<i32>> {
        if nbuckets == 0 {
            bail!("nbuckets must be positive");
        }
        // Chunked over the kernel batch: the caller always gets exactly
        // `keys.len()` ids. (This used to `.take(self.batch)`, silently
        // truncating oversized inputs — which made the batcher's
        // exact-length guard fail and every such batch lose its
        // pre-routing with no trace.)
        let mut out = Vec::with_capacity(keys.len());
        for chunk in keys.chunks(self.batch) {
            out.extend(chunk.iter().map(|&k| Self::bucket(k, seed, nbuckets, kind) as i32));
        }
        Ok(out)
    }

    fn batch_hash_multi(
        &self,
        keys: &[u64],
        shard_ids: &[u32],
        shard_params: &[ShardParams],
    ) -> Result<Vec<i64>> {
        check_multi_args(keys, shard_ids, shard_params)?;
        // One call for the whole mixed-shard batch: per-key geometry
        // dispatch, chunked over the kernel batch like `batch_hash` so
        // the exact-length contract holds at any input size.
        let mut out = Vec::with_capacity(keys.len());
        for (kc, sc) in keys.chunks(self.batch).zip(shard_ids.chunks(self.batch)) {
            for (&k, &s) in kc.iter().zip(sc) {
                let (seed, nbuckets, kind) = shard_params[s as usize];
                // bucket < nbuckets <= u32::MAX (checked above).
                let b = Self::bucket(k, seed, nbuckets, kind) as u32;
                out.push(composite_route_id(s, b));
            }
        }
        Ok(out)
    }

    fn detect(&self, keys: &[u64], seed: u64, nbuckets: u64, kind: HashKind) -> Result<Detection> {
        if nbuckets == 0 {
            bail!("nbuckets must be positive");
        }
        if keys.is_empty() {
            bail!("empty key sample");
        }
        let mut hist = vec![0i32; self.nbins];
        for &k in keys {
            let bin = (Self::bucket(k, seed, nbuckets, kind) % self.nbins as u64) as usize;
            hist[bin] += 1;
        }
        let expected = keys.len() as f64 / self.nbins as f64;
        let chi2: f64 = hist
            .iter()
            .map(|&h| {
                let d = h as f64 - expected;
                d * d / expected
            })
            .sum();
        let max_load = hist.iter().copied().max().unwrap_or(0);
        Ok(Detection {
            chi2: chi2 as f32,
            max_load,
            hist,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dhash::HashFn;
    use crate::util::SplitMix64;

    #[test]
    fn agrees_with_table_hash_fn() {
        // The native kernel and the data path's HashFn must place every
        // key identically — the same invariant the PJRT artifact pins in
        // rust/tests/hash_agreement.rs.
        let e = NativeEngine::new();
        let mut rng = SplitMix64::new(99);
        let keys: Vec<u64> = (0..512).map(|_| rng.next_u64()).collect();
        for (seed, nb) in [(0u64, 1024u64), (0xdead_beef, 97), (u64::MAX, 4096)] {
            let ids = e.batch_hash(&keys, seed, nb, HashKind::Seeded).unwrap();
            for (k, id) in keys.iter().zip(&ids) {
                assert_eq!(*id as usize, HashFn::Seeded(seed).bucket(*k, nb as usize));
            }
        }
        let ids = e.batch_hash(&keys, 0, 64, HashKind::Modulo).unwrap();
        for (k, id) in keys.iter().zip(&ids) {
            assert_eq!(*id as usize, HashFn::Modulo.bucket(*k, 64));
        }
    }

    #[test]
    fn batch_hash_chunks_instead_of_truncating() {
        // Regression: inputs larger than the kernel batch used to come
        // back truncated to `batch` ids; they must now chunk to an
        // exact-length answer with per-key results unchanged.
        let e = NativeEngine::with_shape(8, 4);
        let keys: Vec<u64> = (0..37).map(|i| i * 7919).collect();
        let ids = e.batch_hash(&keys, 1, 16, HashKind::Seeded).unwrap();
        assert_eq!(ids.len(), keys.len());
        for (k, id) in keys.iter().zip(&ids) {
            assert_eq!(*id as usize, HashFn::Seeded(1).bucket(*k, 16));
        }
        assert!(e.batch_hash(&[], 1, 16, HashKind::Seeded).unwrap().is_empty());
        assert!(e.batch_hash(&keys, 1, 0, HashKind::Seeded).is_err());
    }

    #[test]
    fn batch_hash_multi_matches_per_shard_batch_hash() {
        use crate::runtime::{composite_route_id, ShardParams};
        let e = NativeEngine::new();
        let params: Vec<ShardParams> = vec![
            (0xd1e5, 1024, HashKind::Seeded),
            (0xfeed, 2048, HashKind::Seeded),
            (0, 97, HashKind::Modulo),
        ];
        let mut rng = SplitMix64::new(41);
        let keys: Vec<u64> = (0..512).map(|_| rng.next_u64()).collect();
        let shard_ids: Vec<u32> = keys.iter().map(|&k| (k % 3) as u32).collect();
        let multi = e.batch_hash_multi(&keys, &shard_ids, &params).unwrap();
        assert_eq!(multi.len(), keys.len());
        for (i, (&k, &s)) in keys.iter().zip(&shard_ids).enumerate() {
            let (seed, nb, kind) = params[s as usize];
            let bucket = e.batch_hash(&[k], seed, nb, kind).unwrap()[0];
            assert_eq!(multi[i], composite_route_id(s, bucket as u32));
            // Composite layout: shard in the high half, bucket low.
            assert_eq!((multi[i] >> 32) as u32, s);
            assert_eq!((multi[i] & 0xffff_ffff) as i32, bucket);
        }
    }

    #[test]
    fn batch_hash_multi_chunks_and_validates() {
        use crate::runtime::ShardParams;
        let e = NativeEngine::with_shape(8, 4);
        let params: Vec<ShardParams> = vec![(7, 16, HashKind::Seeded), (9, 32, HashKind::Seeded)];
        // Input far beyond the kernel batch: exact-length answer, same
        // per-key ids as one-key calls.
        let keys: Vec<u64> = (0..100).map(|i| i * 2_654_435_761).collect();
        let shard_ids: Vec<u32> = keys.iter().map(|&k| (k & 1) as u32).collect();
        let multi = e.batch_hash_multi(&keys, &shard_ids, &params).unwrap();
        assert_eq!(multi.len(), keys.len());
        for (i, (&k, &s)) in keys.iter().zip(&shard_ids).enumerate() {
            let one = e.batch_hash_multi(&[k], &[s], &params).unwrap();
            assert_eq!(multi[i], one[0], "chunking changed key {k:#x}");
        }
        // Argument validation (shared with every backend).
        assert!(e.batch_hash_multi(&keys, &shard_ids[..5], &params).is_err());
        assert!(e.batch_hash_multi(&[1], &[2], &params).is_err());
        assert!(e.batch_hash_multi(&[1], &[0], &[(0, 0, HashKind::Seeded)]).is_err());
        assert!(e.batch_hash_multi(&[], &[], &params).unwrap().is_empty());
    }

    #[test]
    fn detect_uniform_vs_attack() {
        let e = NativeEngine::new();
        let dof = (e.nbins() - 1) as f32;

        // Uniform random keys, seeded hash: chi2 near its null mean.
        let mut rng = SplitMix64::new(3);
        let uniform: Vec<u64> = (0..e.batch()).map(|_| rng.next_u64()).collect();
        let d = e.detect(&uniform, 5, 4096, HashKind::Seeded).unwrap();
        assert!(d.chi2 < 2.0 * dof, "uniform chi2 {}", d.chi2);
        assert_eq!(d.hist.iter().map(|&x| x as usize).sum::<usize>(), e.batch());

        // Collision attack under the weak modulo hash: chi2 explodes.
        let attack: Vec<u64> = (0..e.batch() as u64).map(|i| 7 + i * 4096).collect();
        let d = e.detect(&attack, 0, 4096, HashKind::Modulo).unwrap();
        assert!(d.chi2 > 50.0 * dof, "attack chi2 {}", d.chi2);
        assert_eq!(d.max_load as usize, e.batch());

        // The same attack keys under a fresh seeded hash: healthy again —
        // the mitigation the coordinator performs.
        let d = e.detect(&attack, 0x1234, 4096, HashKind::Seeded).unwrap();
        assert!(d.chi2 < 2.0 * dof, "post-rebuild chi2 {}", d.chi2);
    }

    #[test]
    fn detect_short_samples_use_exact_length() {
        // Unlike the fixed-shape artifact, the native backend does not pad:
        // the histogram of a 2-key sample sums to 2.
        let e = NativeEngine::new();
        let d = e.detect(&[42, 43], 1, 4096, HashKind::Seeded).unwrap();
        assert_eq!(d.hist.iter().map(|&x| x as i64).sum::<i64>(), 2);
        assert!(d.max_load <= 2);
        assert!(e.detect(&[], 1, 4096, HashKind::Seeded).is_err());
    }

    #[test]
    fn detect_single_bucket_chi2_closed_form() {
        // n keys in one bin of nbins: chi2 = (n-e)^2/e + (nbins-1)*e with
        // e = n/nbins. Exact arithmetic check against the implementation.
        let e = NativeEngine::with_shape(4096, 256);
        let n = 1024u64;
        let keys: Vec<u64> = (0..n).map(|i| 3 + i * 256).collect(); // all ≡ 3 (mod 256)
        let d = e.detect(&keys, 0, 256, HashKind::Modulo).unwrap();
        let exp = n as f64 / 256.0;
        let want = (n as f64 - exp) * (n as f64 - exp) / exp + 255.0 * exp;
        assert!((d.chi2 as f64 - want).abs() / want < 1e-6, "{} vs {want}", d.chi2);
        assert_eq!(d.max_load, n as i32);
        assert_eq!(d.hist[3], n as i32);
    }
}
