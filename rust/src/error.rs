//! The unified public error surface.
//!
//! Before this module every layer grew its own ad-hoc error type —
//! [`SubmitError`] in the client, [`ResizeError`] in the sharded map,
//! [`OracleError`] in the batcher, stringly `anyhow` in the CLI — with
//! no common vocabulary and no stable numeric identity. [`KvError`]
//! unifies them: every public error converts into it, every variant
//! carries a **stable numeric code** ([`KvError::code`]), and the wire
//! protocol's error byte is *defined as* that code
//! ([`crate::net::proto::ResponseFrame::error`]), so an in-process
//! error and its on-wire representation can never drift apart.
//!
//! ## Code table
//!
//! | code | error |
//! |------|-------|
//! | 0x01 | [`KvError::Shutdown`] — coordinator shut down |
//! | 0x02 | [`KvError::Overloaded`] — per-connection inflight window full, request shed |
//! | 0x10 | [`ResizeError::Busy`] |
//! | 0x11 | [`ResizeError::NoSuchShard`] |
//! | 0x12 | [`ResizeError::AtMaxDepth`] |
//! | 0x13 | [`ResizeError::Unmergeable`] |
//! | 0x14 | [`ResizeError::BadGeometry`] |
//! | 0x20 | [`OracleError::Engine`] |
//! | 0x21 | [`OracleError::Epoch`] |
//! | 0x30 | [`ProtoError::BadMagic`] |
//! | 0x31 | [`ProtoError::BadVersion`] |
//! | 0x32 | [`ProtoError::BadOpCode`] |
//! | 0x33 | [`ProtoError::BadStatus`] |
//! | 0x34 | [`ProtoError::ValueTooLong`] |
//! | 0x35 | [`ProtoError::BadValueLen`] |
//! | 0x36 | [`ProtoError::BadReserved`] |
//!
//! Codes are append-only: new variants take new numbers, existing
//! numbers are never reassigned (they are the wire contract).

use std::error::Error;
use std::fmt;

pub use crate::coordinator::{OracleError, SubmitError};
pub use crate::dhash::ResizeError;
pub use crate::util::cli::CliError;

/// A wire frame that cannot be (or have been) produced by a conforming
/// peer. Framing is byte-exact, so any of these means the stream
/// position is no longer trustworthy and the connection must be failed
/// (after an error frame carrying the code, where possible).
#[non_exhaustive]
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProtoError {
    /// First byte of a frame is not the expected magic.
    BadMagic(u8),
    /// Unsupported protocol version byte.
    BadVersion(u8),
    /// Unknown request op-code byte.
    BadOpCode(u8),
    /// Unknown response status byte.
    BadStatus(u8),
    /// Value-length field exceeds [`crate::net::proto::MAX_VALUE_LEN`];
    /// rejected straight from the header, before any allocation.
    ValueTooLong(u32),
    /// Value length inconsistent with the op/status byte (`op` holds
    /// the wire op or status byte the length disagreed with).
    BadValueLen { op: u8, len: u32 },
    /// A reserved byte was not zero.
    BadReserved(u8),
}

impl fmt::Display for ProtoError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ProtoError::BadMagic(b) => write!(f, "bad frame magic {b:#04x}"),
            ProtoError::BadVersion(b) => write!(f, "unsupported protocol version {b}"),
            ProtoError::BadOpCode(b) => write!(f, "unknown op code {b}"),
            ProtoError::BadStatus(b) => write!(f, "unknown response status {b}"),
            ProtoError::ValueTooLong(n) => write!(f, "value length {n} exceeds the cap"),
            ProtoError::BadValueLen { op, len } => {
                write!(f, "value length {len} inconsistent with op/status {op}")
            }
            ProtoError::BadReserved(b) => write!(f, "reserved byte {b:#04x} must be 0"),
        }
    }
}

impl Error for ProtoError {}

/// The crate-wide error: everything a KV request (in-process or on the
/// wire) can fail with. See the module docs for the stable code table.
#[non_exhaustive]
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KvError {
    /// The coordinator is shut down (or shut down while the request was
    /// pending) — [`SubmitError::Shutdown`].
    Shutdown,
    /// The per-connection inflight window was full and the request was
    /// shed. The *request* failed; the connection stays open.
    Overloaded,
    /// The peer sent bytes that are not a valid frame.
    Protocol(ProtoError),
    /// An online shard split/merge/rebuild was refused.
    Resize(ResizeError),
    /// The batch routing oracle could not answer.
    Oracle(OracleError),
}

impl KvError {
    /// The stable numeric code — the byte the wire protocol carries in
    /// error responses. Append-only; never renumbered.
    pub const fn code(&self) -> u8 {
        match self {
            KvError::Shutdown => 0x01,
            KvError::Overloaded => 0x02,
            KvError::Resize(ResizeError::Busy) => 0x10,
            KvError::Resize(ResizeError::NoSuchShard) => 0x11,
            KvError::Resize(ResizeError::AtMaxDepth) => 0x12,
            KvError::Resize(ResizeError::Unmergeable) => 0x13,
            KvError::Resize(ResizeError::BadGeometry) => 0x14,
            KvError::Oracle(OracleError::Engine) => 0x20,
            KvError::Oracle(OracleError::Epoch) => 0x21,
            KvError::Protocol(ProtoError::BadMagic(_)) => 0x30,
            KvError::Protocol(ProtoError::BadVersion(_)) => 0x31,
            KvError::Protocol(ProtoError::BadOpCode(_)) => 0x32,
            KvError::Protocol(ProtoError::BadStatus(_)) => 0x33,
            KvError::Protocol(ProtoError::ValueTooLong(_)) => 0x34,
            KvError::Protocol(ProtoError::BadValueLen { .. }) => 0x35,
            KvError::Protocol(ProtoError::BadReserved(_)) => 0x36,
        }
    }

    /// Human name for a wire code byte (diagnostics on the client side,
    /// where only the code survives the trip).
    pub fn code_name(code: u8) -> &'static str {
        match code {
            0x01 => "shutdown",
            0x02 => "overloaded",
            0x10 => "resize-busy",
            0x11 => "resize-no-such-shard",
            0x12 => "resize-at-max-depth",
            0x13 => "resize-unmergeable",
            0x14 => "resize-bad-geometry",
            0x20 => "oracle-engine",
            0x21 => "oracle-epoch",
            0x30 => "proto-bad-magic",
            0x31 => "proto-bad-version",
            0x32 => "proto-bad-op",
            0x33 => "proto-bad-status",
            0x34 => "proto-value-too-long",
            0x35 => "proto-bad-value-len",
            0x36 => "proto-bad-reserved",
            _ => "unknown",
        }
    }
}

impl fmt::Display for KvError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            KvError::Shutdown => write!(f, "coordinator is shut down"),
            KvError::Overloaded => write!(f, "inflight window full; request shed"),
            KvError::Protocol(e) => write!(f, "protocol error: {e}"),
            KvError::Resize(e) => write!(f, "resize refused: {e}"),
            KvError::Oracle(e) => write!(f, "routing oracle failed: {e}"),
        }
    }
}

impl Error for KvError {
    fn source(&self) -> Option<&(dyn Error + 'static)> {
        match self {
            KvError::Protocol(e) => Some(e),
            KvError::Resize(e) => Some(e),
            _ => None,
        }
    }
}

impl From<SubmitError> for KvError {
    fn from(e: SubmitError) -> Self {
        match e {
            SubmitError::Shutdown => KvError::Shutdown,
        }
    }
}

impl From<ResizeError> for KvError {
    fn from(e: ResizeError) -> Self {
        KvError::Resize(e)
    }
}

impl From<OracleError> for KvError {
    fn from(e: OracleError) -> Self {
        KvError::Oracle(e)
    }
}

impl From<ProtoError> for KvError {
    fn from(e: ProtoError) -> Self {
        KvError::Protocol(e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codes_are_stable_and_distinct() {
        let all = [
            KvError::Shutdown,
            KvError::Overloaded,
            KvError::Resize(ResizeError::Busy),
            KvError::Resize(ResizeError::NoSuchShard),
            KvError::Resize(ResizeError::AtMaxDepth),
            KvError::Resize(ResizeError::Unmergeable),
            KvError::Resize(ResizeError::BadGeometry),
            KvError::Oracle(OracleError::Engine),
            KvError::Oracle(OracleError::Epoch),
            KvError::Protocol(ProtoError::BadMagic(0)),
            KvError::Protocol(ProtoError::BadVersion(0)),
            KvError::Protocol(ProtoError::BadOpCode(0)),
            KvError::Protocol(ProtoError::BadStatus(0)),
            KvError::Protocol(ProtoError::ValueTooLong(0)),
            KvError::Protocol(ProtoError::BadValueLen { op: 0, len: 0 }),
            KvError::Protocol(ProtoError::BadReserved(1)),
        ];
        // Pin the published numbers: these are the wire contract.
        assert_eq!(KvError::Shutdown.code(), 0x01);
        assert_eq!(KvError::Overloaded.code(), 0x02);
        assert_eq!(KvError::Resize(ResizeError::Busy).code(), 0x10);
        assert_eq!(KvError::Oracle(OracleError::Epoch).code(), 0x21);
        assert_eq!(KvError::Protocol(ProtoError::BadMagic(9)).code(), 0x30);
        let mut seen = std::collections::BTreeSet::new();
        for e in all {
            assert!(seen.insert(e.code()), "duplicate code {:#04x}", e.code());
            assert_ne!(KvError::code_name(e.code()), "unknown", "{e:?}");
            // Every unified error displays and sources like a std error.
            let _: &dyn Error = &e;
            assert!(!e.to_string().is_empty());
        }
        assert_eq!(KvError::code_name(0xEE), "unknown");
    }

    #[test]
    fn conversions_preserve_identity() {
        assert_eq!(KvError::from(SubmitError::Shutdown), KvError::Shutdown);
        assert_eq!(
            KvError::from(ResizeError::AtMaxDepth).code(),
            KvError::Resize(ResizeError::AtMaxDepth).code()
        );
        assert_eq!(KvError::from(OracleError::Engine).code(), 0x20);
        assert_eq!(
            KvError::from(ProtoError::ValueTooLong(u32::MAX)).code(),
            0x34
        );
    }
}
