//! CPU pinning for the torture framework's performance-first thread
//! mapping (paper §6.1: "a new thread is mapped to the CPU core that has
//! the smallest number of worker threads running on it").
//!
//! The `libc` crate is outside this workspace's dependency set, so the one
//! syscall needed (`sched_setaffinity`) is declared directly against the
//! C library; `cpu_set_t` is a plain 1024-bit mask on Linux. On a
//! single-core container this degenerates to pinning everything to core 0,
//! but the mapping logic is kept faithful so the harness behaves correctly
//! on real multi-core hosts.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Number of online CPUs, snapshotted before any pinning narrows this
/// thread's affinity mask (`available_parallelism` reads the mask).
pub fn ncpus() -> usize {
    static NCPUS: OnceLock<usize> = OnceLock::new();
    *NCPUS.get_or_init(|| {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    })
}

#[cfg(target_os = "linux")]
fn set_affinity(cpu: usize) -> bool {
    // glibc/musl: int sched_setaffinity(pid_t, size_t, const cpu_set_t*);
    // pid 0 = the calling thread; cpu_set_t = 1024-bit mask (16 u64s).
    extern "C" {
        fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u64) -> i32;
    }
    let mut mask = [0u64; 16];
    if cpu >= mask.len() * 64 {
        return false;
    }
    mask[cpu / 64] |= 1u64 << (cpu % 64);
    // SAFETY: the mask buffer outlives the call and the size matches.
    unsafe { sched_setaffinity(0, std::mem::size_of_val(&mask), mask.as_ptr()) == 0 }
}

#[cfg(not(target_os = "linux"))]
fn set_affinity(_cpu: usize) -> bool {
    // Thread affinity is not portable; treat pinning as a successful
    // no-op so the harness proceeds identically.
    true
}

/// Pin the calling thread to `cpu` (modulo the online CPU count).
/// Returns false if the kernel rejected the mask (non-fatal: the harness
/// proceeds unpinned).
pub fn pin_to(cpu: usize) -> bool {
    set_affinity(cpu % ncpus())
}

static NEXT: AtomicUsize = AtomicUsize::new(0);

/// Performance-first mapping: assign worker `i` the next least-loaded core
/// (round-robin over online cores, which is equivalent under uniform
/// workers). Returns the core id chosen.
pub fn pin_next() -> usize {
    let cpu = NEXT.fetch_add(1, Ordering::Relaxed) % ncpus();
    pin_to(cpu);
    cpu
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ncpus_positive_and_stable() {
        assert!(ncpus() >= 1);
        assert_eq!(ncpus(), ncpus());
    }

    #[test]
    fn pin_to_current_host() {
        // Must not crash, and pinning to core 0 should succeed everywhere.
        assert!(pin_to(0));
        // Out-of-range wraps.
        assert!(pin_to(ncpus() + 3));
    }

    #[test]
    fn round_robin_advances() {
        let a = pin_next();
        let b = pin_next();
        let n = ncpus();
        if n > 1 {
            assert_ne!(a, b);
        } else {
            assert_eq!(a, b);
        }
    }
}
