//! CPU pinning for the torture framework's performance-first thread
//! mapping (paper §6.1: "a new thread is mapped to the CPU core that has
//! the smallest number of worker threads running on it").
//!
//! On the single-core container this degenerates to pinning everything to
//! core 0, but the mapping logic is kept faithful so the harness behaves
//! correctly on real multi-core hosts.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of online CPUs.
pub fn ncpus() -> usize {
    // SAFETY: sysconf is async-signal-safe and has no memory preconditions.
    let n = unsafe { libc::sysconf(libc::_SC_NPROCESSORS_ONLN) };
    if n <= 0 {
        1
    } else {
        n as usize
    }
}

/// Pin the calling thread to `cpu` (modulo the online CPU count).
/// Returns false if the kernel rejected the mask (non-fatal: the harness
/// proceeds unpinned).
pub fn pin_to(cpu: usize) -> bool {
    let n = ncpus();
    let cpu = cpu % n;
    // SAFETY: CPU_* macros are reimplemented via raw bit manipulation on a
    // zeroed cpu_set_t, which is a plain bitmask struct.
    unsafe {
        let mut set: libc::cpu_set_t = std::mem::zeroed();
        libc::CPU_SET(cpu, &mut set);
        libc::sched_setaffinity(0, std::mem::size_of::<libc::cpu_set_t>(), &set) == 0
    }
}

static NEXT: AtomicUsize = AtomicUsize::new(0);

/// Performance-first mapping: assign worker `i` the next least-loaded core
/// (round-robin over online cores, which is equivalent under uniform
/// workers). Returns the core id chosen.
pub fn pin_next() -> usize {
    let cpu = NEXT.fetch_add(1, Ordering::Relaxed) % ncpus();
    pin_to(cpu);
    cpu
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ncpus_positive() {
        assert!(ncpus() >= 1);
    }

    #[test]
    fn pin_to_current_host() {
        // Must not crash, and pinning to core 0 should succeed everywhere.
        assert!(pin_to(0));
        // Out-of-range wraps.
        assert!(pin_to(ncpus() + 3));
    }

    #[test]
    fn round_robin_advances() {
        let a = pin_next();
        let b = pin_next();
        let n = ncpus();
        if n > 1 {
            assert_ne!(a, b);
        } else {
            assert_eq!(a, b);
        }
    }
}
