//! Small self-contained utilities: PRNG, hashing, statistics, CLI parsing,
//! and CPU affinity. The offline build environment provides no `rand`,
//! `clap`, or `criterion`, so these are implemented in-repo.

pub mod affinity;
pub mod cli;
pub mod prop;
pub mod rng;
pub mod stats;

pub use rng::{mix64, SplitMix64};
pub use stats::{LatencyHistogram, Summary};
