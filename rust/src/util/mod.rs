//! Small self-contained utilities: PRNG, hashing, statistics, CLI parsing,
//! and CPU affinity. The offline build environment provides no `rand`,
//! `clap`, or `criterion`, so these are implemented in-repo.

pub mod affinity;
pub mod cli;
pub mod prop;
pub mod rng;
pub mod stats;

pub use rng::{mix64, SplitMix64};
pub use stats::{LatencyHistogram, Summary};

/// True when the test run should clamp its iteration budgets: compiled
/// under Miri (`cfg!(miri)`) or launched with `DHASH_MIRI=1` (the CI
/// knob, also useful under TSan/qemu). Interpreted or instrumented
/// execution is orders of magnitude slower, so stress loops shrink to
/// a smoke-sized subset while keeping every code path exercised.
pub fn miri_slow() -> bool {
    cfg!(miri) || std::env::var_os("DHASH_MIRI").is_some_and(|v| v == "1")
}

/// `full` normally, `clamped` when [`miri_slow`] says so.
pub fn miri_clamp(full: usize, clamped: usize) -> usize {
    if miri_slow() {
        clamped
    } else {
        full
    }
}
