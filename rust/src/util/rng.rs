//! Deterministic PRNG and the keyed hash function shared with the L1
//! Pallas kernel.
//!
//! `mix64` is the splitmix64 finalizer. It is *the* hash family DHash's
//! tables use (`bucket = mix64(key ^ seed) % nbuckets`), and the Pallas
//! kernel in `python/compile/kernels/hash_kernel.py` implements the exact
//! same bit-for-bit mix so that the Rust data path and the AOT detector
//! artifact agree on bucket placement. `python/tests/test_kernel.py` and
//! `rust/tests/hash_agreement.rs` pin this agreement on fixed vectors.

/// splitmix64 finalizer: a strong 64-bit mixing permutation.
///
/// Reference: Steele, Lea, Flood — "Fast Splittable Pseudorandom Number
/// Generators" (OOPSLA'14); constants by Stafford (variant 13).
#[inline(always)]
pub fn mix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Seeded bucket placement used by every table implementation.
#[inline(always)]
pub fn bucket_of(key: u64, seed: u64, nbuckets: usize) -> usize {
    debug_assert!(nbuckets > 0);
    (mix64(key ^ seed) % nbuckets as u64) as usize
}

/// SplitMix64 PRNG: tiny, fast, and statistically solid for workload
/// generation. One instance per worker thread (no sharing, no locks).
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline(always)]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e37_79b9_7f4a_7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift reduction.
    #[inline(always)]
    pub fn next_bounded(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline(always)]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mix64_pinned_vectors() {
        // Pinned against the canonical splitmix64 reference implementation.
        // The same vectors are asserted by python/tests/test_kernel.py to
        // guarantee Rust <-> Pallas hash agreement.
        assert_eq!(mix64(0), 0xe220a8397b1dcdaf);
        assert_eq!(mix64(1), 0x910a2dec89025cc1);
        assert_eq!(mix64(2), 0x975835de1c9756ce);
        assert_eq!(mix64(0xdeadbeef), 0x4adfb90f68c9eb9b);
        assert_eq!(mix64(u64::MAX), 0xe4d971771b652c20);
    }

    #[test]
    fn mix64_is_a_permutation_locally() {
        // Distinct inputs must map to distinct outputs (spot check).
        let mut seen = std::collections::HashSet::new();
        for i in 0..10_000u64 {
            assert!(seen.insert(mix64(i)), "collision at {i}");
        }
    }

    #[test]
    fn bucket_of_in_range_and_seed_sensitive() {
        let n = 97;
        let mut moved = 0;
        for k in 0..1000u64 {
            let a = bucket_of(k, 1, n);
            let b = bucket_of(k, 2, n);
            assert!(a < n && b < n);
            if a != b {
                moved += 1;
            }
        }
        // Changing the seed must re-place the vast majority of keys.
        assert!(moved > 900, "only {moved}/1000 keys moved");
    }

    #[test]
    fn splitmix_bounded_uniform() {
        let mut rng = SplitMix64::new(42);
        let bound = 10;
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[rng.next_bounded(bound) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "skewed bucket: {c}");
        }
    }

    #[test]
    fn splitmix_f64_in_unit_interval() {
        let mut rng = SplitMix64::new(7);
        for _ in 0..10_000 {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn splitmix_deterministic() {
        let mut a = SplitMix64::new(123);
        let mut b = SplitMix64::new(123);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }
}
