//! Benchmark statistics: mean / stddev / percentiles without external
//! crates (criterion is unavailable in the offline environment; the bench
//! harness in `rust/benches/` prints the same rows the paper's figures
//! report, with stddev error bars like the paper's Figure 2).

/// Summary statistics over a set of samples.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

impl Summary {
    /// Compute a summary; `samples` need not be sorted. Empty input yields
    /// an all-zero summary.
    pub fn of(samples: &[f64]) -> Self {
        if samples.is_empty() {
            return Self {
                n: 0,
                mean: 0.0,
                stddev: 0.0,
                min: 0.0,
                max: 0.0,
                p50: 0.0,
                p90: 0.0,
                p99: 0.0,
            };
        }
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted: Vec<f64> = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Self {
            n,
            mean,
            stddev: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile(&sorted, 0.50),
            p90: percentile(&sorted, 0.90),
            p99: percentile(&sorted, 0.99),
        }
    }
}

/// Nearest-rank percentile on pre-sorted data.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = (q * (sorted.len() - 1) as f64).round() as usize;
    sorted[rank.min(sorted.len() - 1)]
}

/// Sub-bucket resolution of [`LatencyHistogram`]: each power-of-two value
/// range is divided into `2^SUB_BITS` linear buckets, bounding the
/// relative quantization error at `1 / 2^SUB_BITS` (~3%).
const SUB_BITS: u32 = 5;
const SUBS: u64 = 1 << SUB_BITS;
/// Chunks 0..=59 cover every u64 value (chunk 0 is `[0, SUBS)` at width
/// 1; chunk c >= 1 is `[SUBS << (c-1), SUBS << c)` at width `2^(c-1)`).
const CHUNKS: usize = (64 - SUB_BITS as usize) + 1;

/// HdrHistogram-style fixed-bucket latency histogram: log2 chunks with
/// linear sub-buckets, so recording is O(1) with no allocation and the
/// full u64 range (nanoseconds) fits in `CHUNKS * SUBS` counters.
/// Percentile values are reported as the recorded bucket's upper bound,
/// so p-quantiles are never understated and the relative error is
/// bounded by the sub-bucket width (~3% at `SUB_BITS = 5`).
///
/// This is the shared percentile code path for the bench harness
/// (`benches/common` wraps it as `LatencyRecorder`); single-writer by
/// design — per-thread instances merge at the end of a run.
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    counts: Vec<u64>,
    total: u64,
    min: u64,
    max: u64,
    sum: u128,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self {
            counts: vec![0; CHUNKS * SUBS as usize],
            total: 0,
            min: u64::MAX,
            max: 0,
            sum: 0,
        }
    }

    /// Bucket index for `v`.
    #[inline]
    fn index_of(v: u64) -> usize {
        if v < SUBS {
            return v as usize;
        }
        let msb = 63 - v.leading_zeros();
        let chunk = (msb - SUB_BITS + 1) as u64;
        (chunk * SUBS + (v >> (chunk - 1)) - SUBS) as usize
    }

    /// Upper bound of bucket `idx` (the value a percentile reports).
    #[inline]
    fn value_of(idx: usize) -> u64 {
        let chunk = idx as u64 / SUBS;
        if chunk == 0 {
            return idx as u64;
        }
        let width = 1u64 << (chunk - 1);
        ((SUBS + idx as u64 % SUBS) << (chunk - 1)) + width - 1
    }

    /// Record one sample (O(1), allocation-free).
    #[inline]
    pub fn record(&mut self, v: u64) {
        self.counts[Self::index_of(v)] += 1;
        self.total += 1;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
        self.sum += v as u128;
    }

    /// Fold another histogram into this one (per-thread recorders merge
    /// at the end of a measurement window).
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.total += other.total;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
        self.sum += other.sum;
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn min(&self) -> u64 {
        if self.total == 0 {
            0
        } else {
            self.min
        }
    }

    /// Exact maximum recorded sample (not quantized).
    pub fn max(&self) -> u64 {
        self.max
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum as f64 / self.total as f64
        }
    }

    /// The `q`-quantile (`0.0..=1.0`) as the matching bucket's upper
    /// bound; 0 on an empty histogram.
    pub fn percentile(&self, q: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let rank = ((q.clamp(0.0, 1.0) * self.total as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (idx, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                // Never report past the true max (the top bucket's upper
                // bound can overshoot it).
                return Self::value_of(idx).min(self.max);
            }
        }
        self.max
    }
}

/// Online mean/variance accumulator (Welford) for streaming metrics in the
/// coordinator's stats loop, where buffering every latency sample would
/// allocate on the hot path.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn stddev(&self) -> f64 {
        if self.n > 1 {
            (self.m2 / (self.n - 1) as f64).sqrt()
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.stddev - (2.5f64).sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn summary_empty_and_singleton() {
        let e = Summary::of(&[]);
        assert_eq!(e.n, 0);
        assert_eq!(e.mean, 0.0);
        let s = Summary::of(&[7.5]);
        assert_eq!(s.n, 1);
        assert_eq!(s.mean, 7.5);
        assert_eq!(s.stddev, 0.0);
        assert_eq!(s.p99, 7.5);
    }

    #[test]
    fn percentile_ranks() {
        let v: Vec<f64> = (1..=100).map(|x| x as f64).collect();
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 1.0), 100.0);
        // Nearest-rank on 100 items: rank = round(0.5 * 99) = 50 → value 51.
        assert_eq!(percentile(&v, 0.5), 51.0);
    }

    #[test]
    fn histogram_exact_below_resolution() {
        // Values below SUBS land in width-1 buckets: percentiles are exact.
        let mut h = LatencyHistogram::new();
        for v in 0..SUBS {
            h.record(v);
        }
        assert_eq!(h.count(), SUBS);
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), SUBS - 1);
        assert_eq!(h.percentile(0.5), SUBS / 2 - 1);
        assert_eq!(h.percentile(1.0), SUBS - 1);
    }

    #[test]
    fn histogram_relative_error_bound() {
        // Reported quantile for a single recorded value is its bucket's
        // upper bound: never below the value, within 1/SUBS above it.
        for v in [1u64, 31, 32, 33, 100, 1_000, 123_456, 1 << 40, u64::MAX] {
            let mut h = LatencyHistogram::new();
            h.record(v);
            let got = h.percentile(0.999);
            assert!(got >= v, "p999 {got} understates {v}");
            let bound = v.saturating_add(v / SUBS + 1);
            assert!(got <= bound, "p999 {got} exceeds error bound {bound} for {v}");
        }
    }

    #[test]
    fn histogram_percentile_monotone_and_mean() {
        let mut h = LatencyHistogram::new();
        for i in 1..=10_000u64 {
            h.record(i);
        }
        let p50 = h.percentile(0.50);
        let p99 = h.percentile(0.99);
        let p999 = h.percentile(0.999);
        assert!(p50 <= p99 && p99 <= p999);
        assert!(p50 >= 5_000 && p50 <= 5_200);
        assert!(p999 >= 9_990);
        assert!((h.mean() - 5_000.5).abs() < 1.0);
    }

    #[test]
    fn histogram_merge_equals_combined() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        let mut all = LatencyHistogram::new();
        for i in 0..500u64 {
            let v = i * i % 7_919;
            if i % 2 == 0 {
                a.record(v);
            } else {
                b.record(v);
            }
            all.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        assert_eq!(a.min(), all.min());
        assert_eq!(a.max(), all.max());
        for q in [0.5, 0.9, 0.99, 0.999] {
            assert_eq!(a.percentile(q), all.percentile(q));
        }
    }

    #[test]
    fn histogram_empty() {
        let h = LatencyHistogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.percentile(0.99), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), 0);
    }

    #[test]
    fn welford_matches_batch() {
        let xs: Vec<f64> = (0..1000).map(|i| (i as f64).sin() * 10.0).collect();
        let mut w = Welford::default();
        for &x in &xs {
            w.push(x);
        }
        let s = Summary::of(&xs);
        assert!((w.mean() - s.mean).abs() < 1e-9);
        assert!((w.stddev() - s.stddev).abs() < 1e-9);
        assert_eq!(w.count(), 1000);
    }
}
