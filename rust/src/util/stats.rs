//! Benchmark statistics: mean / stddev / percentiles without external
//! crates (criterion is unavailable in the offline environment; the bench
//! harness in `rust/benches/` prints the same rows the paper's figures
//! report, with stddev error bars like the paper's Figure 2).

/// Summary statistics over a set of samples.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

impl Summary {
    /// Compute a summary; `samples` need not be sorted. Empty input yields
    /// an all-zero summary.
    pub fn of(samples: &[f64]) -> Self {
        if samples.is_empty() {
            return Self {
                n: 0,
                mean: 0.0,
                stddev: 0.0,
                min: 0.0,
                max: 0.0,
                p50: 0.0,
                p90: 0.0,
                p99: 0.0,
            };
        }
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted: Vec<f64> = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Self {
            n,
            mean,
            stddev: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile(&sorted, 0.50),
            p90: percentile(&sorted, 0.90),
            p99: percentile(&sorted, 0.99),
        }
    }
}

/// Nearest-rank percentile on pre-sorted data.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = (q * (sorted.len() - 1) as f64).round() as usize;
    sorted[rank.min(sorted.len() - 1)]
}

/// Online mean/variance accumulator (Welford) for streaming metrics in the
/// coordinator's stats loop, where buffering every latency sample would
/// allocate on the hot path.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn stddev(&self) -> f64 {
        if self.n > 1 {
            (self.m2 / (self.n - 1) as f64).sqrt()
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.stddev - (2.5f64).sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn summary_empty_and_singleton() {
        let e = Summary::of(&[]);
        assert_eq!(e.n, 0);
        assert_eq!(e.mean, 0.0);
        let s = Summary::of(&[7.5]);
        assert_eq!(s.n, 1);
        assert_eq!(s.mean, 7.5);
        assert_eq!(s.stddev, 0.0);
        assert_eq!(s.p99, 7.5);
    }

    #[test]
    fn percentile_ranks() {
        let v: Vec<f64> = (1..=100).map(|x| x as f64).collect();
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 1.0), 100.0);
        // Nearest-rank on 100 items: rank = round(0.5 * 99) = 50 → value 51.
        assert_eq!(percentile(&v, 0.5), 51.0);
    }

    #[test]
    fn welford_matches_batch() {
        let xs: Vec<f64> = (0..1000).map(|i| (i as f64).sin() * 10.0).collect();
        let mut w = Welford::default();
        for &x in &xs {
            w.push(x);
        }
        let s = Summary::of(&xs);
        assert!((w.mean() - s.mean).abs() < 1e-9);
        assert!((w.stddev() - s.stddev).abs() < 1e-9);
        assert_eq!(w.count(), 1000);
    }
}
