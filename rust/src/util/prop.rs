//! A small property-based testing harness (proptest is unavailable in
//! the offline build): seeded random-input generators, a case runner
//! that reports the failing seed, and linear input shrinking for op
//! sequences. Used by the model-based tests in `rust/tests/model_check.rs`
//! and the unit suites.

use crate::util::SplitMix64;

/// A reproducible random-value source for one generated case.
pub struct Gen {
    rng: SplitMix64,
    /// The case seed (printed on failure for reproduction).
    pub seed: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Self {
            rng: SplitMix64::new(seed),
            seed,
        }
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo < hi);
        lo + self.rng.next_bounded(hi - lo)
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.range(lo as u64, hi as u64) as usize
    }

    pub fn bool(&mut self, p_true: f64) -> bool {
        self.rng.next_f64() < p_true
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.next_bounded(xs.len() as u64) as usize]
    }

    /// A vector with generator-chosen length in `[0, max_len]`.
    pub fn vec<T>(&mut self, max_len: usize, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        let n = self.rng.next_bounded(max_len as u64 + 1) as usize;
        (0..n).map(|_| f(self)).collect()
    }
}

/// Outcome of a property over one generated input.
pub type PropResult = Result<(), String>;

/// Run `prop` over `cases` seeded inputs derived from `base_seed`
/// (environment `DHASH_PROP_SEED` overrides, `DHASH_PROP_CASES` scales).
/// Panics with the failing seed on the first failure.
pub fn check(name: &str, cases: usize, prop: impl Fn(&mut Gen) -> PropResult) {
    let base_seed = std::env::var("DHASH_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x5eed_cafe_u64);
    // An explicit DHASH_PROP_CASES always wins; otherwise Miri (or
    // DHASH_MIRI=1) clamps the default budget — see `util::miri_clamp`.
    let cases = std::env::var("DHASH_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| crate::util::miri_clamp(cases, 2));
    for i in 0..cases {
        let seed = crate::util::rng::mix64(base_seed ^ (i as u64) << 1);
        let mut g = Gen::new(seed);
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property {name:?} failed on case {i}/{cases}: {msg}\n\
                 reproduce with DHASH_PROP_SEED={base_seed} (case seed {seed:#x})"
            );
        }
    }
}

/// Shrink a failing op-sequence by removing spans while the predicate
/// (`fails`) still fails, returning a (locally) minimal sequence. Linear
/// passes with halving span sizes — not proptest-grade, but effective on
/// op-list inputs.
pub fn shrink_ops<T: Clone>(ops: &[T], fails: impl Fn(&[T]) -> bool) -> Vec<T> {
    let mut cur: Vec<T> = ops.to_vec();
    debug_assert!(fails(&cur));
    let mut span = cur.len() / 2;
    while span >= 1 {
        let mut i = 0;
        while i + span <= cur.len() {
            let mut candidate = cur.clone();
            candidate.drain(i..i + span);
            if fails(&candidate) {
                cur = candidate;
                // keep i: the window now holds fresh elements
            } else {
                i += 1;
            }
        }
        span /= 2;
    }
    cur
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gen_is_deterministic_per_seed() {
        let mut a = Gen::new(9);
        let mut b = Gen::new(9);
        for _ in 0..32 {
            assert_eq!(a.u64(), b.u64());
        }
    }

    #[test]
    fn range_and_choose_bounds() {
        let mut g = Gen::new(1);
        for _ in 0..1000 {
            let x = g.range(10, 20);
            assert!((10..20).contains(&x));
        }
        let xs = [1, 2, 3];
        for _ in 0..100 {
            assert!(xs.contains(g.choose(&xs)));
        }
    }

    #[test]
    fn vec_len_bounded() {
        let mut g = Gen::new(2);
        for _ in 0..100 {
            let v = g.vec(7, |g| g.u64());
            assert!(v.len() <= 7);
        }
    }

    #[test]
    fn check_passes_and_fails() {
        check("trivially true", 50, |_| Ok(()));
        let r = std::panic::catch_unwind(|| {
            check("always false", 3, |_| Err("nope".into()));
        });
        assert!(r.is_err());
    }

    #[test]
    fn shrink_finds_minimal_span() {
        // Failure iff the sequence contains both 3 and 7.
        let ops: Vec<u32> = (0..100).collect();
        let fails = |xs: &[u32]| xs.contains(&3) && xs.contains(&7);
        let min = shrink_ops(&ops, fails);
        assert!(min.len() <= 2, "{min:?}");
        assert!(fails(&min));
    }

    #[test]
    fn shrink_keeps_failing_property() {
        let ops: Vec<u32> = (0..64).collect();
        let fails = |xs: &[u32]| xs.iter().sum::<u32>() >= 100;
        let min = shrink_ops(&ops, fails);
        assert!(fails(&min));
        // Removing any single further element must fix it (local minimum
        // for span=1 passes).
        for i in 0..min.len() {
            let mut c = min.clone();
            c.remove(i);
            assert!(!fails(&c) || c.iter().sum::<u32>() >= 100);
        }
    }
}
