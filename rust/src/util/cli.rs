//! Minimal `--flag value` argument parser (clap is unavailable offline).
//!
//! Supports `--name value`, `--name=value`, boolean `--name`, and a list
//! of positional arguments. Parsing is **per-subcommand**: each
//! subcommand declares its own [`CmdSpec`] flag registry, an unknown or
//! misspelled flag is a hard error that lists the valid flags, and
//! every spec renders a `--help` page with defaults. (The old scheme —
//! one global known-flag list shared by every subcommand — silently
//! tolerated flags that belonged to *other* subcommands, so e.g.
//! `serve --pre-rout bucket` did nothing.)

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    flags: BTreeMap<String, String>,
    positional: Vec<String>,
}

/// A command-line usage error (unknown flag, bad value). Part of the
/// unified error surface via `crate::error`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CliError(pub String);

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "argument error: {}", self.0)
    }
}

impl std::error::Error for CliError {}

impl Args {
    /// Parse from an explicit token stream. `known` lists the accepted
    /// flag names (without the `--`); a value-less occurrence stores
    /// `"true"`.
    pub fn parse<I: IntoIterator<Item = String>>(
        tokens: I,
        known: &[&str],
    ) -> Result<Self, CliError> {
        let mut out = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                if !known.contains(&name.as_str()) {
                    return Err(CliError(format!("unknown flag --{name}")));
                }
                let value = match inline {
                    Some(v) => v,
                    None => {
                        // Treat a following token as the value unless it is
                        // itself a flag.
                        match it.peek() {
                            Some(next) if !next.starts_with("--") => it.next().unwrap(),
                            _ => "true".to_string(),
                        }
                    }
                };
                out.flags.insert(name, value);
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    pub fn from_env(known: &[&str]) -> Result<Self, CliError> {
        Self::parse(std::env::args().skip(1), known)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_bool(&self, name: &str) -> bool {
        matches!(self.get(name), Some("true") | Some("1") | Some("yes"))
    }

    pub fn get_or<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| CliError(format!("bad value for --{name}: {s:?}"))),
        }
    }
}

/// One registered flag: the name (without `--`), the default rendered
/// in `--help`, and a one-line description.
#[derive(Clone, Copy, Debug)]
pub struct FlagSpec {
    pub name: &'static str,
    pub default: &'static str,
    pub help: &'static str,
}

impl FlagSpec {
    /// Const constructor keeping registry tables to one line per flag.
    pub const fn new(name: &'static str, default: &'static str, help: &'static str) -> Self {
        Self {
            name,
            default,
            help,
        }
    }
}

/// A subcommand's flag registry: the only flags this subcommand
/// accepts. [`CmdSpec::parse`] hard-errors on anything else, listing
/// the valid set; [`CmdSpec::help`] renders the `--help` page.
#[derive(Clone, Copy, Debug)]
pub struct CmdSpec {
    pub name: &'static str,
    pub about: &'static str,
    pub flags: &'static [FlagSpec],
}

impl CmdSpec {
    /// Parse this subcommand's tokens against its registry. `--help` is
    /// always accepted (check [`Args::get_bool`]`("help")`). An unknown
    /// flag is a hard error that names the valid flags.
    pub fn parse<I: IntoIterator<Item = String>>(&self, tokens: I) -> Result<Args, CliError> {
        let mut known: Vec<&str> = self.flags.iter().map(|f| f.name).collect();
        known.push("help");
        Args::parse(tokens, &known).map_err(|CliError(msg)| {
            let valid: Vec<String> = self.flags.iter().map(|f| format!("--{}", f.name)).collect();
            CliError(format!(
                "{msg}\nvalid flags for `{}`: {} (see `{} --help`)",
                self.name,
                valid.join(", "),
                self.name
            ))
        })
    }

    /// The `--help` page: about line, then each flag with its default.
    pub fn help(&self) -> String {
        let mut out = format!("dhash {} — {}\n\nflags:\n", self.name, self.about);
        let width = self
            .flags
            .iter()
            .map(|f| f.name.len())
            .chain(std::iter::once("help".len()))
            .max()
            .unwrap_or(4);
        for f in self.flags {
            let pad = " ".repeat(width - f.name.len());
            out.push_str(&format!(
                "  --{}{}  {} (default: {})\n",
                f.name, pad, f.help, f.default
            ));
        }
        let pad = " ".repeat(width - "help".len());
        out.push_str(&format!("  --help{pad}  print this help\n"));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_forms() {
        let a = Args::parse(
            toks("run --threads 8 --alpha=20 --verbose --out x.csv"),
            &["threads", "alpha", "verbose", "out"],
        )
        .unwrap();
        assert_eq!(a.positional(), &["run".to_string()]);
        assert_eq!(a.get_or("threads", 1usize).unwrap(), 8);
        assert_eq!(a.get_or("alpha", 0u64).unwrap(), 20);
        assert!(a.get_bool("verbose"));
        assert_eq!(a.get("out"), Some("x.csv"));
    }

    #[test]
    fn rejects_unknown() {
        assert!(Args::parse(toks("--nope 3"), &["yes"]).is_err());
    }

    #[test]
    fn bad_value_type() {
        let a = Args::parse(toks("--threads abc"), &["threads"]).unwrap();
        assert!(a.get_or("threads", 1usize).is_err());
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(toks(""), &["threads"]).unwrap();
        assert_eq!(a.get_or("threads", 4usize).unwrap(), 4);
        assert!(!a.get_bool("threads"));
    }

    const SPEC: CmdSpec = CmdSpec {
        name: "serve",
        about: "run the KV service",
        flags: &[
            FlagSpec::new("listen", "off", "bind address"),
            FlagSpec::new("secs", "10", "run duration"),
        ],
    };

    #[test]
    fn cmdspec_accepts_registered_flags_and_help() {
        let a = SPEC.parse(toks("--listen 127.0.0.1:0 --secs 3")).unwrap();
        assert_eq!(a.get("listen"), Some("127.0.0.1:0"));
        assert_eq!(a.get_or("secs", 0u64).unwrap(), 3);
        let h = SPEC.parse(toks("--help")).unwrap();
        assert!(h.get_bool("help"));
    }

    #[test]
    fn cmdspec_unknown_flag_lists_valid_set() {
        // The misspelled-flag failure mode the registry exists for:
        // `--sec` (for `--secs`) must fail loudly, naming the options.
        let err = SPEC.parse(toks("--sec 3")).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("unknown flag --sec"), "{msg}");
        assert!(msg.contains("--listen"), "{msg}");
        assert!(msg.contains("--secs"), "{msg}");
        assert!(msg.contains("serve"), "{msg}");
    }

    #[test]
    fn cmdspec_help_shows_defaults() {
        let h = SPEC.help();
        assert!(h.contains("dhash serve"), "{h}");
        assert!(h.contains("--listen"), "{h}");
        assert!(h.contains("default: 10"), "{h}");
        assert!(h.contains("--help"), "{h}");
    }
}
