//! Minimal `--flag value` argument parser (clap is unavailable offline).
//!
//! Supports `--name value`, `--name=value`, boolean `--name`, and a list of
//! positional arguments. Unknown flags are an error so typos fail loudly.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    flags: BTreeMap<String, String>,
    positional: Vec<String>,
}

#[derive(Debug)]
pub struct ParseError(pub String);

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "argument error: {}", self.0)
    }
}

impl std::error::Error for ParseError {}

impl Args {
    /// Parse from an explicit token stream. `known` lists the accepted flag
    /// names (without the `--`); a value-less occurrence stores `"true"`.
    pub fn parse<I: IntoIterator<Item = String>>(
        tokens: I,
        known: &[&str],
    ) -> Result<Self, ParseError> {
        let mut out = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                if !known.contains(&name.as_str()) {
                    return Err(ParseError(format!("unknown flag --{name}")));
                }
                let value = match inline {
                    Some(v) => v,
                    None => {
                        // Treat a following token as the value unless it is
                        // itself a flag.
                        match it.peek() {
                            Some(next) if !next.starts_with("--") => it.next().unwrap(),
                            _ => "true".to_string(),
                        }
                    }
                };
                out.flags.insert(name, value);
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    pub fn from_env(known: &[&str]) -> Result<Self, ParseError> {
        Self::parse(std::env::args().skip(1), known)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_bool(&self, name: &str) -> bool {
        matches!(self.get(name), Some("true") | Some("1") | Some("yes"))
    }

    pub fn get_or<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T, ParseError> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| ParseError(format!("bad value for --{name}: {s:?}"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_forms() {
        let a = Args::parse(
            toks("run --threads 8 --alpha=20 --verbose --out x.csv"),
            &["threads", "alpha", "verbose", "out"],
        )
        .unwrap();
        assert_eq!(a.positional(), &["run".to_string()]);
        assert_eq!(a.get_or("threads", 1usize).unwrap(), 8);
        assert_eq!(a.get_or("alpha", 0u64).unwrap(), 20);
        assert!(a.get_bool("verbose"));
        assert_eq!(a.get("out"), Some("x.csv"));
    }

    #[test]
    fn rejects_unknown() {
        assert!(Args::parse(toks("--nope 3"), &["yes"]).is_err());
    }

    #[test]
    fn bad_value_type() {
        let a = Args::parse(toks("--threads abc"), &["threads"]).unwrap();
        assert!(a.get_or("threads", 1usize).is_err());
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(toks(""), &["threads"]).unwrap();
        assert_eq!(a.get_or("threads", 4usize).unwrap(), 4);
        assert!(!a.get_bool("threads"));
    }
}
