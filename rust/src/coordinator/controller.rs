//! The rebuild controller: turns attack verdicts into rebuild calls with
//! a fresh random seed, rate-limited by a cooldown keyed per **stable
//! shard uid** so a sustained attack cannot make the service thrash on
//! back-to-back rebuilds — while an attack on one shard never blocks
//! mitigating a different shard (targeted mitigation). Uids (from
//! `RouteSnapshot::uids`) are assigned at shard creation and never
//! reused: a shard born from a split/merge starts cold instead of
//! inheriting a dead shard's clock, and a surviving shard keeps its
//! clock across unrelated resizes even though its *ordinal* shifts.
//!
//! It also owns the **elastic policy**: given per-shard occupancy and
//! chi² pressure, decide whether to split a hot shard or merge a cold
//! buddy pair ([`RebuildController::plan_resize`]), bounded by
//! `max_shards` and its own resize cooldown.

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::dhash::HashFn;
use crate::util::rng::mix64;

#[derive(Clone, Debug)]
pub struct ControllerConfig {
    /// Minimum spacing between mitigation rebuilds of the *same* shard
    /// (identified by its stable uid).
    pub cooldown: Duration,
    /// Bucket count for mitigation rebuilds (None = keep current).
    pub rebuild_buckets: Option<usize>,
}

impl Default for ControllerConfig {
    fn default() -> Self {
        Self {
            cooldown: Duration::from_secs(1),
            rebuild_buckets: None,
        }
    }
}

/// Knobs for the elastic (split/merge) policy. `None` in
/// [`super::CoordinatorConfig`] keeps the shard count fixed.
#[derive(Clone, Debug)]
pub struct ElasticConfig {
    /// Hard cap on the shard count; a split is never planned past it.
    pub max_shards: usize,
    /// Split a shard when its pressure (load factor, inflated by chi²
    /// skew — see [`RebuildController::plan_resize`]) exceeds this.
    pub split_load_factor: f64,
    /// Merge a buddy pair when BOTH load factors sit below this. Keep it
    /// well under half of `split_load_factor` or the policy thrashes.
    pub merge_load_factor: f64,
    /// Weight of chi² pressure in the split score: a shard at the
    /// detector threshold counts as `1 + chi2_weight` times its load.
    pub chi2_weight: f64,
    /// Minimum spacing between planned resizes (splits or merges).
    pub cooldown: Duration,
}

impl Default for ElasticConfig {
    fn default() -> Self {
        Self {
            max_shards: 16,
            split_load_factor: 16.0,
            merge_load_factor: 2.0,
            chi2_weight: 1.0,
            cooldown: Duration::from_millis(500),
        }
    }
}

/// What the elastic policy decided for one evaluation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ResizeAction {
    /// Split this shard ordinal in two.
    Split(usize),
    /// Merge this shard ordinal with its buddy.
    Merge(usize),
}

/// Record of one mitigation rebuild.
#[derive(Clone, Debug)]
pub struct RebuildEvent {
    /// Offset from coordinator start.
    pub at: Duration,
    /// The shard that was rebuilt (0 in unsharded deployments).
    pub shard: usize,
    /// Directory epoch the verdict (and the shard ordinal) was observed
    /// under.
    pub epoch: u64,
    /// chi2 that triggered the rebuild.
    pub chi2: f32,
    /// The hash function installed.
    pub new_hash: HashFn,
    /// Nodes moved (from `RebuildStats`).
    pub moved: u64,
    /// Rebuild wall time.
    pub elapsed: Duration,
}

/// Record of one completed elastic resize (split or merge).
#[derive(Clone, Debug)]
pub struct ResizeEvent {
    /// Offset from coordinator start.
    pub at: Duration,
    /// What happened.
    pub action: ResizeAction,
    /// Directory epoch the decision was made under (the epoch *before*
    /// the resize; the resize bumped it).
    pub epoch: u64,
    /// Shard count after the resize completed.
    pub shards_after: usize,
    /// Nodes migrated.
    pub moved: u64,
    /// Resize wall time (including its grace periods).
    pub elapsed: Duration,
}

pub struct RebuildController {
    cfg: ControllerConfig,
    start: Instant,
    state: Mutex<CtlState>,
}

struct CtlState {
    /// Per-shard cooldown clocks, keyed by stable shard uid (never
    /// reused): a shard created by a split/merge starts cold, and a
    /// surviving shard keeps its clock across unrelated resizes.
    /// Expired entries (older than the cooldown — permissive anyway)
    /// are purged on every plan call, so retired shards cannot
    /// accumulate clocks forever.
    last_rebuild: HashMap<u64, Instant>,
    /// Last planned resize (split or merge), for the elastic cooldown.
    last_resize: Option<Instant>,
    seed_state: u64,
    events: Vec<RebuildEvent>,
    resize_events: Vec<ResizeEvent>,
}

impl RebuildController {
    pub fn new(cfg: ControllerConfig, entropy: u64) -> Self {
        Self {
            cfg,
            start: Instant::now(),
            state: Mutex::new(CtlState {
                last_rebuild: HashMap::new(),
                last_resize: None,
                seed_state: entropy,
                events: Vec::new(),
                resize_events: Vec::new(),
            }),
        }
    }

    /// [`RebuildController::plan_mitigation_for`] on shard uid 0 — the
    /// whole-map path for unsharded deployments (whose single shard
    /// keeps uid 0 forever).
    pub fn plan_mitigation(&self, now: Instant) -> Option<HashFn> {
        self.plan_mitigation_for(0, now)
    }

    /// If the shard's cooldown allows, pick a fresh hash function for a
    /// targeted mitigation of the shard with stable uid `shard_uid`
    /// (`RouteSnapshot::uids[ordinal]`). Cooldowns are independent per
    /// shard: a hot shard being in cooldown must not block mitigating a
    /// freshly-attacked one, and — because uids survive resizes while
    /// ordinals do not — a split of shard A can neither reset nor
    /// transplant shard B's clock. The attacker cannot predict the next
    /// seed: it chains the previous seed state through mix64 with the
    /// current monotonic clock (and the shard uid, so two shards
    /// mitigated in the same instant never share a seed).
    pub fn plan_mitigation_for(&self, shard_uid: u64, now: Instant) -> Option<HashFn> {
        let mut st = self.state.lock().unwrap(); // lock: coord-state
        // Expired clocks are permissive anyway; purge them so uids of
        // long-retired shards cannot accumulate.
        let cooldown = self.cfg.cooldown;
        st.last_rebuild
            .retain(|_, &mut t| now.saturating_duration_since(t) < cooldown);
        if let Some(&last) = st.last_rebuild.get(&shard_uid) {
            if now.duration_since(last) < cooldown {
                return None;
            }
        }
        st.last_rebuild.insert(shard_uid, now);
        st.seed_state = mix64(
            st.seed_state
                ^ self.start.elapsed().as_nanos() as u64
                ^ shard_uid.wrapping_mul(0x9E37_79B9_7F4A_7C15),
        );
        Some(HashFn::Seeded(st.seed_state))
    }

    /// Target bucket count for a mitigation rebuild.
    pub fn buckets_for(&self, current: usize) -> usize {
        self.cfg.rebuild_buckets.unwrap_or(current)
    }

    /// The elastic policy: decide whether to split or merge, given one
    /// coherent observation of the directory. `profile[s]` is shard
    /// `s`'s `(live nodes, nbuckets)`, `chi2s[s]` its latest detector
    /// statistic (0 when unevaluated), `splittable[s]` whether a split
    /// of `s` can succeed right now (depth headroom — see
    /// `ShardedDHash::splittable`), `buddies[s]` its mergeable buddy
    /// ordinal (None when it cannot merge right now).
    ///
    /// Split pressure is load factor inflated by chi² skew — a shard
    /// both hot *and* skewed splits first, which also halves what the
    /// next targeted mitigation has to migrate. Only splittable shards
    /// compete, so a shard pinned at the directory's depth cap cannot
    /// burn the resize cooldown on doomed split plans and starve the
    /// merge branch. Merges require BOTH buddies cold, so a cold shard
    /// never drags its hot buddy into a merged shard that would
    /// immediately re-split.
    #[allow(clippy::too_many_arguments)]
    pub fn plan_resize(
        &self,
        cfg: &ElasticConfig,
        profile: &[(usize, usize)],
        chi2s: &[f32],
        chi2_threshold: f32,
        splittable: &[bool],
        buddies: &[Option<usize>],
        now: Instant,
    ) -> Option<ResizeAction> {
        let mut st = self.state.lock().unwrap(); // lock: coord-state
        if let Some(last) = st.last_resize {
            if now.duration_since(last) < cfg.cooldown {
                return None;
            }
        }
        let lf = |s: usize| profile[s].0 as f64 / profile[s].1.max(1) as f64;
        let pressure = |s: usize| {
            let skew = chi2s.get(s).copied().unwrap_or(0.0) as f64 / chi2_threshold.max(1.0) as f64;
            lf(s) * (1.0 + cfg.chi2_weight * skew.clamp(0.0, 4.0))
        };
        let nshards = profile.len();
        // Split the highest-pressure shard that can split, capacity
        // permitting.
        if nshards < cfg.max_shards {
            if let Some(hot) = (0..nshards)
                .filter(|&s| splittable.get(s).copied().unwrap_or(false))
                .max_by(|&a, &b| pressure(a).total_cmp(&pressure(b)))
            {
                if pressure(hot) > cfg.split_load_factor {
                    st.last_resize = Some(now);
                    return Some(ResizeAction::Split(hot));
                }
            }
        }
        // Merge the coldest mergeable pair.
        let mut cold: Vec<usize> = (0..nshards).collect();
        cold.sort_by(|&a, &b| lf(a).total_cmp(&lf(b)));
        for s in cold {
            if lf(s) >= cfg.merge_load_factor {
                break; // sorted: nothing colder remains
            }
            if let Some(b) = buddies.get(s).copied().flatten() {
                if b < nshards && lf(b) < cfg.merge_load_factor {
                    st.last_resize = Some(now);
                    return Some(ResizeAction::Merge(s));
                }
            }
        }
        None
    }

    /// Record a completed mitigation of `shard` (observed under `epoch`).
    pub fn record(
        &self,
        epoch: u64,
        shard: usize,
        chi2: f32,
        new_hash: HashFn,
        moved: u64,
        elapsed: Duration,
    ) {
        let mut st = self.state.lock().unwrap(); // lock: coord-state
        st.events.push(RebuildEvent {
            at: self.start.elapsed(),
            shard,
            epoch,
            chi2,
            new_hash,
            moved,
            elapsed,
        });
    }

    /// Record a completed elastic resize.
    pub fn record_resize(
        &self,
        action: ResizeAction,
        epoch: u64,
        shards_after: usize,
        moved: u64,
        elapsed: Duration,
    ) {
        let mut st = self.state.lock().unwrap(); // lock: coord-state
        st.resize_events.push(ResizeEvent {
            at: self.start.elapsed(),
            action,
            epoch,
            shards_after,
            moved,
            elapsed,
        });
    }

    pub fn events(&self) -> Vec<RebuildEvent> {
        self.state.lock().unwrap().events.clone() // lock: coord-state
    }

    pub fn resize_events(&self) -> Vec<ResizeEvent> {
        self.state.lock().unwrap().resize_events.clone() // lock: coord-state
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cooldown_gates_rebuilds() {
        let c = RebuildController::new(
            ControllerConfig {
                cooldown: Duration::from_millis(100),
                rebuild_buckets: None,
            },
            42,
        );
        let t0 = Instant::now();
        let first = c.plan_mitigation(t0);
        assert!(first.is_some());
        // Immediately after: blocked.
        assert!(c.plan_mitigation(t0 + Duration::from_millis(10)).is_none());
        // After cooldown: allowed, and with a different seed.
        let second = c.plan_mitigation(t0 + Duration::from_millis(150));
        assert!(second.is_some());
        assert_ne!(first, second);
    }

    #[test]
    fn cooldown_is_per_shard() {
        let c = RebuildController::new(
            ControllerConfig {
                cooldown: Duration::from_millis(100),
                rebuild_buckets: None,
            },
            7,
        );
        let t0 = Instant::now();
        let a = c.plan_mitigation_for(0, t0);
        assert!(a.is_some());
        // Shard uid 0 is cooling down, but uid 3 is independent.
        assert!(c.plan_mitigation_for(0, t0 + Duration::from_millis(10)).is_none());
        let b = c.plan_mitigation_for(3, t0 + Duration::from_millis(10));
        assert!(b.is_some());
        assert_ne!(a, b, "distinct shards must get distinct seeds");
        // And uid 3 now cools down on its own clock.
        assert!(c.plan_mitigation_for(3, t0 + Duration::from_millis(50)).is_none());
    }

    #[test]
    fn reborn_shards_start_cold_and_survivors_keep_their_clocks() {
        // Resizes shift shard *ordinals* but never reuse *uids*: a shard
        // born from a split/merge (fresh uid) must start cold, while an
        // untouched shard's clock survives the epoch change untouched —
        // and expired clocks are purged so retired uids don't pile up.
        let c = RebuildController::new(
            ControllerConfig {
                cooldown: Duration::from_millis(100),
                rebuild_buckets: None,
            },
            5,
        );
        let t0 = Instant::now();
        assert!(c.plan_mitigation_for(2, t0).is_some());
        // Same uid: cooling down — even if a resize of OTHER shards
        // bumped the directory epoch meanwhile (uid keying makes that
        // invisible here, which is the point).
        assert!(c.plan_mitigation_for(2, t0 + Duration::from_millis(10)).is_none());
        // A freshly created shard (new uid, e.g. a split child): cold.
        assert!(c.plan_mitigation_for(9, t0 + Duration::from_millis(10)).is_some());
        assert_eq!(c.state.lock().unwrap().last_rebuild.len(), 2);
        // Past the cooldown, expired clocks are purged on the next plan.
        assert!(c.plan_mitigation_for(4, t0 + Duration::from_millis(500)).is_some());
        assert_eq!(
            c.state.lock().unwrap().last_rebuild.len(),
            1,
            "expired uids must be purged"
        );
    }

    #[test]
    fn seeds_are_unpredictable_chain() {
        let c = RebuildController::new(ControllerConfig::default(), 1);
        let a = c.plan_mitigation(Instant::now()).unwrap();
        let c2 = RebuildController::new(ControllerConfig::default(), 2);
        let b = c2.plan_mitigation(Instant::now()).unwrap();
        assert_ne!(a, b);
    }

    #[test]
    fn buckets_override() {
        let keep = RebuildController::new(ControllerConfig::default(), 3);
        assert_eq!(keep.buckets_for(64), 64);
        let grow = RebuildController::new(
            ControllerConfig {
                cooldown: Duration::ZERO,
                rebuild_buckets: Some(4096),
            },
            3,
        );
        assert_eq!(grow.buckets_for(64), 4096);
    }

    #[test]
    fn events_recorded() {
        let c = RebuildController::new(ControllerConfig::default(), 9);
        c.record(4, 2, 777.0, HashFn::Seeded(1), 100, Duration::from_millis(3));
        let ev = c.events();
        assert_eq!(ev.len(), 1);
        assert_eq!(ev[0].shard, 2);
        assert_eq!(ev[0].epoch, 4);
        assert_eq!(ev[0].chi2, 777.0);
        assert_eq!(ev[0].moved, 100);
        c.record_resize(ResizeAction::Split(2), 4, 3, 50, Duration::from_millis(1));
        let rv = c.resize_events();
        assert_eq!(rv.len(), 1);
        assert_eq!(rv[0].action, ResizeAction::Split(2));
        assert_eq!(rv[0].shards_after, 3);
    }

    #[test]
    fn elastic_policy_splits_hot_and_merges_cold() {
        let c = RebuildController::new(ControllerConfig::default(), 11);
        let el = ElasticConfig {
            max_shards: 4,
            split_load_factor: 8.0,
            merge_load_factor: 2.0,
            chi2_weight: 0.0,
            cooldown: Duration::ZERO,
        };
        let thr = 400.0;
        let t0 = Instant::now();
        let all = &[true, true][..];
        let buddies = &[Some(1), Some(0)][..];
        // Shard 1 is hot (lf 16), shard 0 cold-ish (lf 4): split 1.
        let prof = [(64usize, 16usize), (256, 16)];
        assert_eq!(
            c.plan_resize(&el, &prof, &[0.0, 0.0], thr, all, buddies, t0),
            Some(ResizeAction::Split(1))
        );
        // Both cold: merge the colder one with its buddy.
        let prof = [(8usize, 16usize), (4, 16)];
        assert_eq!(
            c.plan_resize(&el, &prof, &[0.0, 0.0], thr, all, buddies, t0),
            Some(ResizeAction::Merge(1))
        );
        // Cold shard whose buddy is hot: no merge (hysteresis), no split
        // (below the cutoff).
        let prof = [(4usize, 16usize), (100, 16)];
        assert_eq!(
            c.plan_resize(&el, &prof, &[0.0, 0.0], thr, all, buddies, t0),
            None
        );
        // In-between load on every shard: steady state.
        let prof = [(64usize, 16usize), (64, 16)];
        assert_eq!(
            c.plan_resize(&el, &prof, &[0.0, 0.0], thr, all, buddies, t0),
            None
        );
        // The hot shard pinned at the depth cap cannot split; the policy
        // must fall through to the merge scan instead of planning a
        // doomed split (and burning the cooldown on it) — here the cold
        // pair merges even though shard 1 screams.
        let prof = [(8usize, 16usize), (512, 16), (4, 16)];
        assert_eq!(
            c.plan_resize(
                &el,
                &prof,
                &[0.0, 0.0, 0.0],
                thr,
                &[true, false, true],
                &[None, None, Some(0)],
                t0
            ),
            Some(ResizeAction::Merge(2))
        );
    }

    #[test]
    fn elastic_policy_respects_caps_and_cooldown() {
        let c = RebuildController::new(ControllerConfig::default(), 13);
        let el = ElasticConfig {
            max_shards: 2,
            split_load_factor: 8.0,
            merge_load_factor: 2.0,
            chi2_weight: 0.0,
            cooldown: Duration::from_millis(100),
        };
        let t0 = Instant::now();
        let all = &[true, true][..];
        let none = &[None, None][..];
        // At capacity: the hot shard cannot split.
        let prof = [(512usize, 16usize), (512, 16)];
        assert_eq!(c.plan_resize(&el, &prof, &[], 400.0, all, none, t0), None);
        // Below capacity it can — once; the cooldown gates the next.
        let el2 = ElasticConfig { max_shards: 4, ..el };
        assert!(matches!(
            c.plan_resize(&el2, &prof, &[], 400.0, all, none, t0),
            Some(ResizeAction::Split(_))
        ));
        assert_eq!(
            c.plan_resize(&el2, &prof, &[], 400.0, all, none, t0 + Duration::from_millis(10)),
            None,
            "resize cooldown must gate back-to-back resizes"
        );
        assert!(c
            .plan_resize(&el2, &prof, &[], 400.0, all, none, t0 + Duration::from_millis(150))
            .is_some());
    }

    #[test]
    fn elastic_policy_weighs_chi2_pressure() {
        let c = RebuildController::new(ControllerConfig::default(), 17);
        let el = ElasticConfig {
            max_shards: 4,
            split_load_factor: 8.0,
            merge_load_factor: 1.0,
            chi2_weight: 1.0,
            cooldown: Duration::ZERO,
        };
        let t0 = Instant::now();
        // Equal load (lf 6, below the cutoff), but shard 1 is at 2x the
        // detector threshold: pressure 6 * (1 + 2) = 18 > 8 -> split 1.
        let prof = [(96usize, 16usize), (96, 16)];
        assert_eq!(
            c.plan_resize(
                &el,
                &prof,
                &[0.0, 800.0],
                400.0,
                &[true, true],
                &[None, None],
                t0
            ),
            Some(ResizeAction::Split(1))
        );
    }
}
