//! The rebuild controller: turns attack verdicts into rebuild calls with
//! a fresh random seed, rate-limited by a **per-shard** cooldown so a
//! sustained attack cannot make the service thrash on back-to-back
//! rebuilds — while an attack on one shard never blocks mitigating a
//! different shard (targeted mitigation).

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::dhash::HashFn;
use crate::util::rng::mix64;

#[derive(Clone, Debug)]
pub struct ControllerConfig {
    /// Minimum spacing between mitigation rebuilds of the *same* shard.
    pub cooldown: Duration,
    /// Bucket count for mitigation rebuilds (None = keep current).
    pub rebuild_buckets: Option<usize>,
}

impl Default for ControllerConfig {
    fn default() -> Self {
        Self {
            cooldown: Duration::from_secs(1),
            rebuild_buckets: None,
        }
    }
}

/// Record of one mitigation rebuild.
#[derive(Clone, Debug)]
pub struct RebuildEvent {
    /// Offset from coordinator start.
    pub at: Duration,
    /// The shard that was rebuilt (0 in unsharded deployments).
    pub shard: usize,
    /// chi2 that triggered the rebuild.
    pub chi2: f32,
    /// The hash function installed.
    pub new_hash: HashFn,
    /// Nodes moved (from `RebuildStats`).
    pub moved: u64,
    /// Rebuild wall time.
    pub elapsed: Duration,
}

pub struct RebuildController {
    cfg: ControllerConfig,
    start: Instant,
    state: Mutex<CtlState>,
}

struct CtlState {
    /// Per-shard cooldown clocks (shard 0 doubles as the whole-map clock
    /// for unsharded deployments).
    last_rebuild: HashMap<usize, Instant>,
    seed_state: u64,
    events: Vec<RebuildEvent>,
}

impl RebuildController {
    pub fn new(cfg: ControllerConfig, entropy: u64) -> Self {
        Self {
            cfg,
            start: Instant::now(),
            state: Mutex::new(CtlState {
                last_rebuild: HashMap::new(),
                seed_state: entropy,
                events: Vec::new(),
            }),
        }
    }

    /// [`RebuildController::plan_mitigation_for`] on shard 0 — the
    /// whole-map path for unsharded deployments.
    pub fn plan_mitigation(&self, now: Instant) -> Option<HashFn> {
        self.plan_mitigation_for(0, now)
    }

    /// If `shard`'s cooldown allows, pick a fresh hash function for a
    /// targeted mitigation of that shard. Cooldowns are independent per
    /// shard: a hot shard being in cooldown must not block mitigating a
    /// freshly-attacked one. The attacker cannot predict the next seed:
    /// it chains the previous seed state through mix64 with the current
    /// monotonic clock (and the shard id, so two shards mitigated in the
    /// same instant never share a seed).
    pub fn plan_mitigation_for(&self, shard: usize, now: Instant) -> Option<HashFn> {
        let mut st = self.state.lock().unwrap();
        if let Some(&last) = st.last_rebuild.get(&shard) {
            if now.duration_since(last) < self.cfg.cooldown {
                return None;
            }
        }
        st.last_rebuild.insert(shard, now);
        st.seed_state = mix64(
            st.seed_state
                ^ self.start.elapsed().as_nanos() as u64
                ^ (shard as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
        );
        Some(HashFn::Seeded(st.seed_state))
    }

    /// Target bucket count for a mitigation rebuild.
    pub fn buckets_for(&self, current: usize) -> usize {
        self.cfg.rebuild_buckets.unwrap_or(current)
    }

    /// Record a completed mitigation of `shard`.
    pub fn record(&self, shard: usize, chi2: f32, new_hash: HashFn, moved: u64, elapsed: Duration) {
        let mut st = self.state.lock().unwrap();
        st.events.push(RebuildEvent {
            at: self.start.elapsed(),
            shard,
            chi2,
            new_hash,
            moved,
            elapsed,
        });
    }

    pub fn events(&self) -> Vec<RebuildEvent> {
        self.state.lock().unwrap().events.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cooldown_gates_rebuilds() {
        let c = RebuildController::new(
            ControllerConfig {
                cooldown: Duration::from_millis(100),
                rebuild_buckets: None,
            },
            42,
        );
        let t0 = Instant::now();
        let first = c.plan_mitigation(t0);
        assert!(first.is_some());
        // Immediately after: blocked.
        assert!(c.plan_mitigation(t0 + Duration::from_millis(10)).is_none());
        // After cooldown: allowed, and with a different seed.
        let second = c.plan_mitigation(t0 + Duration::from_millis(150));
        assert!(second.is_some());
        assert_ne!(first, second);
    }

    #[test]
    fn cooldown_is_per_shard() {
        let c = RebuildController::new(
            ControllerConfig {
                cooldown: Duration::from_millis(100),
                rebuild_buckets: None,
            },
            7,
        );
        let t0 = Instant::now();
        let a = c.plan_mitigation_for(0, t0);
        assert!(a.is_some());
        // Shard 0 is cooling down, but shard 3 is independent.
        assert!(c.plan_mitigation_for(0, t0 + Duration::from_millis(10)).is_none());
        let b = c.plan_mitigation_for(3, t0 + Duration::from_millis(10));
        assert!(b.is_some());
        assert_ne!(a, b, "distinct shards must get distinct seeds");
        // And shard 3 now cools down on its own clock.
        assert!(c.plan_mitigation_for(3, t0 + Duration::from_millis(50)).is_none());
    }

    #[test]
    fn seeds_are_unpredictable_chain() {
        let c = RebuildController::new(ControllerConfig::default(), 1);
        let a = c.plan_mitigation(Instant::now()).unwrap();
        let c2 = RebuildController::new(ControllerConfig::default(), 2);
        let b = c2.plan_mitigation(Instant::now()).unwrap();
        assert_ne!(a, b);
    }

    #[test]
    fn buckets_override() {
        let keep = RebuildController::new(ControllerConfig::default(), 3);
        assert_eq!(keep.buckets_for(64), 64);
        let grow = RebuildController::new(
            ControllerConfig {
                cooldown: Duration::ZERO,
                rebuild_buckets: Some(4096),
            },
            3,
        );
        assert_eq!(grow.buckets_for(64), 4096);
    }

    #[test]
    fn events_recorded() {
        let c = RebuildController::new(ControllerConfig::default(), 9);
        c.record(2, 777.0, HashFn::Seeded(1), 100, Duration::from_millis(3));
        let ev = c.events();
        assert_eq!(ev.len(), 1);
        assert_eq!(ev[0].shard, 2);
        assert_eq!(ev[0].chi2, 777.0);
        assert_eq!(ev[0].moved, 100);
    }
}
