//! Attack detection: a lock-free key sampler fed by the KV workers and
//! the chi-square skew test evaluated through the AOT detector artifact.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::time::Duration;

/// Lock-free ring buffer of recently *inserted* keys (collision attacks
/// are insert floods). Writers race benignly: a slot may be overwritten
/// before it is ever read — sampling, not logging.
pub struct KeySampler {
    ring: Box<[AtomicU64]>,
    /// Total pushes (monotone; ring index = pushes % capacity).
    pushes: AtomicUsize,
}

impl KeySampler {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity.is_power_of_two(), "capacity must be 2^k");
        Self {
            ring: (0..capacity).map(|_| AtomicU64::new(0)).collect(),
            pushes: AtomicUsize::new(0),
        }
    }

    /// Record a key (hot path: one fetch_add + one store, both relaxed).
    #[inline]
    pub fn push(&self, key: u64) {
        let i = self.pushes.fetch_add(1, Ordering::Relaxed) & (self.ring.len() - 1);
        self.ring[i].store(key, Ordering::Relaxed);
    }

    /// Keys recorded so far (saturating at capacity for the snapshot).
    pub fn occupancy(&self) -> usize {
        self.pushes.load(Ordering::Relaxed).min(self.ring.len())
    }

    pub fn total_pushes(&self) -> usize {
        self.pushes.load(Ordering::Relaxed)
    }

    /// Snapshot the most recent `occupancy()` keys.
    pub fn snapshot(&self) -> Vec<u64> {
        let n = self.occupancy();
        (0..n).map(|i| self.ring[i].load(Ordering::Relaxed)).collect()
    }
}

/// Split a key sample by destination shard under ONE epoch-stamped
/// [`RouteSnapshot`](crate::dhash::RouteSnapshot) — the same directory
/// view a [`crate::dhash::ShardedDHash`] routes with. The analytics
/// thread evaluates chi² per shard from the partitions, so a collision
/// flood aimed at one shard trips only that shard's verdict (targeted
/// mitigation). Keying the partition by the snapshot (shard ordinal +
/// epoch) instead of a bare shard count is what keeps verdicts
/// attributable across splits/merges: a partition computed under epoch
/// `e` can never be read as shard ids of a later layout, because the
/// caller checks `snap.epoch` before acting on it. With one shard this
/// is the identity partition.
pub fn partition_by_shard(keys: &[u64], snap: &crate::dhash::RouteSnapshot) -> Vec<Vec<u64>> {
    let mut parts = vec![Vec::new(); snap.nshards()];
    for &k in keys {
        parts[snap.shard_of(k) as usize].push(k);
    }
    parts
}

/// Detector policy knobs.
#[derive(Clone, Debug)]
pub struct DetectorConfig {
    /// Ring capacity (power of two). 4096 matches the artifact batch.
    pub sample_capacity: usize,
    /// How often the analytics thread evaluates the sample.
    pub period: Duration,
    /// Alarm threshold in chi2 standard deviations above the null mean:
    /// chi2 > (nbins-1) + sigma * sqrt(2 (nbins-1)).
    pub sigma: f32,
    /// Minimum sampled keys before verdicts are meaningful.
    pub min_samples: usize,
}

impl Default for DetectorConfig {
    fn default() -> Self {
        Self {
            sample_capacity: 4096,
            period: Duration::from_millis(50),
            sigma: 8.0,
            min_samples: 1024,
        }
    }
}

/// Outcome of one detector evaluation.
#[derive(Clone, Debug, PartialEq)]
pub enum SkewVerdict {
    /// Not enough data yet.
    Insufficient,
    /// Distribution consistent with a healthy hash.
    Healthy { chi2: f32 },
    /// Bucket-load skew beyond the threshold: collision attack or
    /// pathological workload; a rebuild is warranted.
    Attack { chi2: f32, max_load: i32 },
}

impl SkewVerdict {
    /// Classify a detector output against the policy threshold.
    pub fn classify(
        cfg: &DetectorConfig,
        samples: usize,
        chi2: f32,
        max_load: i32,
        nbins: usize,
    ) -> SkewVerdict {
        if samples < cfg.min_samples {
            return SkewVerdict::Insufficient;
        }
        let dof = (nbins - 1) as f32;
        let threshold = dof + cfg.sigma * (2.0 * dof).sqrt();
        if chi2 > threshold {
            SkewVerdict::Attack { chi2, max_load }
        } else {
            SkewVerdict::Healthy { chi2 }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sampler_wraps_and_snapshots() {
        let s = KeySampler::new(8);
        assert_eq!(s.occupancy(), 0);
        for k in 0..5u64 {
            s.push(k);
        }
        assert_eq!(s.occupancy(), 5);
        assert_eq!(s.snapshot(), vec![0, 1, 2, 3, 4]);
        for k in 5..20u64 {
            s.push(k);
        }
        assert_eq!(s.occupancy(), 8);
        assert_eq!(s.total_pushes(), 20);
        let snap = s.snapshot();
        assert_eq!(snap.len(), 8);
        // Ring holds the latest window (16..20 wrapped over 8..16).
        assert!(snap.contains(&19));
    }

    #[test]
    #[should_panic(expected = "2^k")]
    fn sampler_requires_pow2() {
        KeySampler::new(12);
    }

    #[test]
    fn verdict_thresholds() {
        let cfg = DetectorConfig {
            min_samples: 100,
            sigma: 8.0,
            ..Default::default()
        };
        let nbins = 256;
        // dof = 255, threshold = 255 + 8*sqrt(510) ~= 435.7
        assert_eq!(
            SkewVerdict::classify(&cfg, 50, 9999.0, 100, nbins),
            SkewVerdict::Insufficient
        );
        assert!(matches!(
            SkewVerdict::classify(&cfg, 4096, 300.0, 30, nbins),
            SkewVerdict::Healthy { .. }
        ));
        assert!(matches!(
            SkewVerdict::classify(&cfg, 4096, 500.0, 900, nbins),
            SkewVerdict::Attack { .. }
        ));
    }

    #[test]
    fn partition_by_shard_agrees_with_selector() {
        use crate::dhash::{HashFn, RouteSnapshot};
        let keys: Vec<u64> = (0..4096u64).map(|k| k.wrapping_mul(0x9e37)).collect();
        let nshards = 8;
        let snap = RouteSnapshot::uniform(nshards, (HashFn::Seeded(1), 64));
        let parts = partition_by_shard(&keys, &snap);
        assert_eq!(parts.len(), nshards);
        assert_eq!(parts.iter().map(|p| p.len()).sum::<usize>(), keys.len());
        // A uniform snapshot partitions exactly like the fixed selector.
        for (s, part) in parts.iter().enumerate() {
            assert!(part.iter().all(|&k| crate::dhash::shard_of(k, nshards) == s));
        }
        // Unsharded: identity partition.
        let one = partition_by_shard(&keys, &RouteSnapshot::uniform(1, (HashFn::Seeded(1), 64)));
        assert_eq!(one.len(), 1);
        assert_eq!(one[0], keys);
    }

    #[test]
    fn partition_by_shard_follows_the_live_directory() {
        // After a split, the partition must track the directory (five
        // shards at mixed depths), not any uniform selector.
        use crate::dhash::{HashFn, ShardedDHash};
        use crate::rcu::{rcu_barrier, RcuThread};
        let g = RcuThread::register();
        let m = ShardedDHash::with_buckets(4, 16, 3);
        m.split_shard(&g, 2, 16, HashFn::Seeded(9)).unwrap();
        let snap = m.route_snapshot(&g);
        let keys: Vec<u64> = (0..2048u64).map(|k| k.wrapping_mul(0x9e37)).collect();
        let parts = partition_by_shard(&keys, &snap);
        assert_eq!(parts.len(), 5);
        assert_eq!(parts.iter().map(|p| p.len()).sum::<usize>(), keys.len());
        for (s, part) in parts.iter().enumerate() {
            assert!(part.iter().all(|&k| m.shard_of(&g, k) == s), "shard {s}");
        }
        g.quiescent_state();
        rcu_barrier();
    }

    #[test]
    fn concurrent_pushes_do_not_lose_counts() {
        let s = std::sync::Arc::new(KeySampler::new(1024));
        let mut hs = Vec::new();
        for t in 0..4u64 {
            let s2 = s.clone();
            hs.push(std::thread::spawn(move || {
                for i in 0..10_000u64 {
                    s2.push(t * 100_000 + i);
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(s.total_pushes(), 40_000);
        assert_eq!(s.occupancy(), 1024);
    }
}
