//! The coordinator proper: wires ingest lanes → per-lane batchers →
//! workers → the sharded map, plus the analytics thread (per-shard
//! detector verdicts, targeted rebuild mitigation, and — when
//! [`CoordinatorConfig::elastic`] is set — the load-based online shard
//! split/merge policy).
//!
//! The KV workers program against the [`ConcurrentMap`] facade; only the
//! analytics thread needs the concrete [`ShardedDHash`] (per-shard hash
//! functions, targeted rebuilds, and splits/merges have no trait-level
//! surface). With `shards == 1` the sharded map degenerates to the
//! paper's single `DHashMap` and every behavior matches the pre-sharding
//! coordinator.

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::channel;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crossbeam_utils::CachePadded;

use super::batcher::{
    Batch, Batcher, BatcherConfig, IngestLanes, LaneMsg, OracleError, PreRoute, Request, Response,
    RouteOutcome,
};
use super::client::KvClient;
use super::controller::{ControllerConfig, ElasticConfig, RebuildController, ResizeAction};
use super::detector::{partition_by_shard, DetectorConfig, KeySampler, SkewVerdict};
use crate::dhash::{HashFn, RouteSnapshot, ShardedDHash};
use crate::map::ConcurrentMap;
use crate::rcu::RcuThread;
use crate::runtime::{load_engine, Engine, HashKind, ShardParams};

#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Buckets **per shard** (the total bucket budget is
    /// `shards * nbuckets`; with `shards == 1` this is the whole table,
    /// exactly as before sharding).
    pub nbuckets: usize,
    pub hash: HashFn,
    /// Initial shard count (power of two; 1 = the paper's single table).
    /// With [`CoordinatorConfig::elastic`] set, the count then moves
    /// online between 1 and `max_shards` as load demands.
    pub shards: usize,
    /// Independent ingest lanes (power of two; 1 = the old single
    /// funnel). A key's lane is the fixed shard-selector pre-hash
    /// ([`crate::dhash::shard_of`] over the lane count), so per-key
    /// submission order is preserved into the batch stream and neither a
    /// rebuild (which only swaps per-shard hash functions) nor a shard
    /// split/merge (which only extends/retracts *selector* bits — the
    /// selector input never changes) can ever re-route a key's lane.
    /// Each lane is drained by its own batcher thread. Note per-key FIFO
    /// is a lane/batch property: with `workers > 1`, consecutive batches
    /// may still execute concurrently (exactly as with the pre-lane
    /// single batcher).
    pub lanes: usize,
    /// KV worker threads.
    pub workers: usize,
    pub batcher: BatcherConfig,
    pub detector: DetectorConfig,
    pub controller: ControllerConfig,
    /// Online shard split/merge policy (None = the shard count stays
    /// fixed at `shards`). Evaluated by the analytics thread, so it
    /// requires `enable_analytics`.
    pub elastic: Option<ElasticConfig>,
    /// Run the detector/mitigation loop on the configured engine backend
    /// ([`crate::runtime::load_engine`]; the native backend by default,
    /// `DHASH_ENGINE=pjrt` for the AOT-artifact backend).
    pub enable_analytics: bool,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            nbuckets: 4096,
            hash: HashFn::Seeded(0xD1E5_5EED),
            shards: 1,
            lanes: 1,
            workers: 2,
            batcher: BatcherConfig::default(),
            detector: DetectorConfig::default(),
            controller: ControllerConfig::default(),
            elastic: None,
            enable_analytics: true,
        }
    }
}

/// Aggregate service counters.
#[derive(Clone, Debug, Default)]
pub struct CoordinatorStats {
    pub total_requests: u64,
    pub total_batches: u64,
    /// Batches pre-route-sorted by routing id (composite `(shard,
    /// bucket)` order under [`PreRoute::Bucket`]).
    pub pre_routed_batches: u64,
    /// Pre-route attempts abandoned because the oracle answered with the
    /// wrong number of ids (the exact-length guard; a truncating engine
    /// surfaces here instead of silently dropping entries).
    pub pre_route_fallbacks_length: u64,
    /// Pre-route attempts abandoned because the routing engine failed or
    /// was unavailable (e.g. `pre_route: Bucket` without analytics).
    pub pre_route_fallbacks_engine: u64,
    /// Pre-route attempts abandoned because a shard split/merge moved
    /// the directory epoch while the ids were being computed — expected
    /// (and rare) while a resize is in flight, never silent.
    pub pre_route_fallbacks_epoch: u64,
    /// Route-snapshot (re)builds across all lane oracles. On the steady
    /// path (directory epoch unchanged) each lane builds its snapshot
    /// once and then serves every batch from the cache, so this stays at
    /// the lane count until a split/merge moves the epoch — asserted by
    /// the latency smoke bench.
    pub snapshot_rebuilds: u64,
    /// Mitigation + manual rebuilds completed (a staggered whole-map
    /// rebuild counts once).
    pub rebuilds: u64,
    /// Completed online shard splits.
    pub splits: u64,
    /// Completed online shard merges.
    pub merges: u64,
    /// Current shard count (moves when `elastic` is set).
    pub shards: u64,
    /// Current directory epoch (bumped once per split/merge).
    pub epoch: u64,
    /// Max per-shard chi2 from the most recent detector evaluation
    /// (0 until evaluated).
    pub last_chi2: f32,
    /// chi2 per shard from the most recent evaluation (empty until
    /// evaluated; shards with no sampled keys report 0).
    pub last_chi2_per_shard: Vec<f32>,
    /// Detector evaluation cycles performed.
    pub detector_runs: u64,
    /// Network front-end counters, folded in by
    /// [`crate::net::server::NetServer::fold_stats`] when the
    /// coordinator serves over the wire (`None` for in-process-only
    /// deployments).
    pub net: Option<crate::net::NetStats>,
}

struct Shared {
    map: ShardedDHash,
    sampler: KeySampler,
    stop: AtomicBool,
    /// Padded: every worker bumps this once per request; sharing a line
    /// with `total_batches` (bumped by every lane thread) would bounce
    /// both counters across all cores.
    total_requests: CachePadded<AtomicU64>,
    total_batches: CachePadded<AtomicU64>,
    pre_routed_batches: AtomicU64,
    pre_route_fallbacks_length: AtomicU64,
    pre_route_fallbacks_engine: AtomicU64,
    pre_route_fallbacks_epoch: AtomicU64,
    snapshot_rebuilds: AtomicU64,
    rebuilds: AtomicU64,
    detector_runs: AtomicU64,
    /// f32 bits of the last max-over-shards chi2.
    last_chi2: AtomicU64,
    /// Last per-shard chi2 values.
    shard_chi2: Mutex<Vec<f32>>,
    controller: RebuildController,
}

/// The running service. Create with [`Coordinator::start`], submit
/// through [`Coordinator::client`] tickets (or the blocking
/// `execute` / `execute_many` wrappers), stop with
/// [`Coordinator::shutdown`].
pub struct Coordinator {
    shared: Arc<Shared>,
    /// The lane senders handed to clients; `None` once shut down. Only
    /// `client()` takes this lock — submission itself runs on each
    /// client's own sender clones.
    ingest: Mutex<Option<IngestLanes>>,
    threads: Mutex<Vec<JoinHandle<()>>>,
    cfg: CoordinatorConfig,
}

impl Coordinator {
    pub fn start(cfg: CoordinatorConfig) -> anyhow::Result<Coordinator> {
        anyhow::ensure!(
            cfg.shards >= 1 && cfg.shards.is_power_of_two(),
            "shards must be a power of two, got {}",
            cfg.shards
        );
        anyhow::ensure!(
            cfg.lanes >= 1 && cfg.lanes.is_power_of_two(),
            "lanes must be a power of two, got {}",
            cfg.lanes
        );
        anyhow::ensure!(
            cfg.elastic.is_none() || cfg.enable_analytics,
            "the elastic split/merge policy runs on the analytics thread; \
             enable_analytics must be set"
        );
        if let Some(el) = &cfg.elastic {
            anyhow::ensure!(
                el.max_shards >= 1,
                "elastic max_shards must be at least 1"
            );
        }
        let shared = Arc::new(Shared {
            map: ShardedDHash::with_hash(cfg.shards, cfg.nbuckets, cfg.hash),
            sampler: KeySampler::new(cfg.detector.sample_capacity),
            stop: AtomicBool::new(false),
            total_requests: CachePadded::new(AtomicU64::new(0)),
            total_batches: CachePadded::new(AtomicU64::new(0)),
            pre_routed_batches: AtomicU64::new(0),
            pre_route_fallbacks_length: AtomicU64::new(0),
            pre_route_fallbacks_engine: AtomicU64::new(0),
            pre_route_fallbacks_epoch: AtomicU64::new(0),
            snapshot_rebuilds: AtomicU64::new(0),
            rebuilds: AtomicU64::new(0),
            detector_runs: AtomicU64::new(0),
            last_chi2: AtomicU64::new(0),
            shard_chi2: Mutex::new(Vec::new()),
            controller: RebuildController::new(
                cfg.controller.clone(),
                // Seed entropy: wall clock + ASLR'd stack address. Not
                // cryptographic, but unpredictable enough that an attacker
                // cannot precompute collisions for the *next* seed.
                crate::util::rng::mix64(
                    std::time::SystemTime::now()
                        .duration_since(std::time::UNIX_EPOCH)
                        .map(|d| d.as_nanos() as u64)
                        .unwrap_or(0)
                        ^ (&cfg as *const _ as u64),
                ),
            ),
        });

        let (batch_tx, batch_rx) = channel::<Batch>();
        let batch_rx = Arc::new(Mutex::new(batch_rx));
        let mut threads = Vec::new();

        // Ingest lanes: one queue per lane, each drained by its own
        // batcher thread into the shared worker queue. The lane channels
        // close through `LaneMsg::Close` markers (not sender drops), so
        // shutdown drains cleanly even while clients still hold cloned
        // senders.
        let mut lane_txs = Vec::with_capacity(cfg.lanes);
        for lane in 0..cfg.lanes {
            let (lane_tx, lane_rx) = channel::<LaneMsg>();
            lane_txs.push(lane_tx);
            let cfg_b = cfg.batcher.clone();
            let shared2 = shared.clone();
            let batch_tx = batch_tx.clone();
            // Bucket-order pre-routing needs its own engine (backends
            // need not be Send — the PJRT client is thread-bound — so
            // each thread that evaluates kernels owns one). Shard-order
            // pre-routing is the fixed selector through the directory:
            // no engine.
            let want_engine = cfg_b.pre_route == PreRoute::Bucket && cfg.enable_analytics;
            threads.push(
                std::thread::Builder::new()
                    .name(format!("dhash-batcher-{lane}"))
                    .spawn(move || {
                        let batcher = Batcher::new(cfg_b);
                        let engine: Option<Box<dyn Engine>> = if want_engine {
                            load_engine().ok()
                        } else {
                            None
                        };
                        let g = RcuThread::register();
                        // Epoch-keyed cache of the route snapshot and its
                        // lowered engine params: the steady path (directory
                        // epoch unchanged) serves every batch from here —
                        // no directory walk, no per-batch allocations — and
                        // rebuilds only when the epoch moves or an epoch
                        // fallback proves the cache stale. Geometry drift
                        // *without* an epoch bump (a targeted mitigation
                        // rebuild) leaves cached ids stale-but-sound:
                        // routing ids only order the batch, per-op routing
                        // always goes through the live directory (see
                        // `ShardedDHash::route_snapshot`).
                        let route_cache: RefCell<Option<(RouteSnapshot, Vec<ShardParams>)>> =
                            RefCell::new(None);
                        loop {
                            // Collect OFFLINE (blocking recv must not
                            // stall grace periods), then route online.
                            let (entries, open) =
                                g.offline_while(|| batcher.collect(&lane_rx));
                            if !entries.is_empty() {
                                // Routing oracle: i64 routing ids in the
                                // shard-major composite id space, computed
                                // against ONE epoch-stamped RouteSnapshot
                                // (shard mapping + every shard's (hash,
                                // nbuckets), read from one directory
                                // pointer). Bucket mode hashes the whole
                                // mixed-shard batch in ONE batch_hash_multi
                                // call. If a split/merge moves the epoch
                                // mid-computation the ids describe a
                                // retired layout: the oracle reports
                                // Epoch and the batch ships un-routed —
                                // counted below, like every fallback.
                                let oracle =
                                    |keys: &[u64]| -> Result<Vec<i64>, OracleError> {
                                        let (ids, epoch) = match batcher.cfg.pre_route {
                                            PreRoute::Off => return Err(OracleError::Engine),
                                            // Shard order needs only the
                                            // selector→shard mapping: read
                                            // it per key, with each key's
                                            // epoch taken from the SAME
                                            // directory pointer as its
                                            // mapping (no snapshot
                                            // allocations on this path) —
                                            // a resize straddling the batch
                                            // shows up as an epoch change
                                            // between keys, or against the
                                            // live epoch re-checked below.
                                            PreRoute::Shard => {
                                                let mut epoch0 = None;
                                                let mut ids = Vec::with_capacity(keys.len());
                                                for &k in keys {
                                                    let (e, s) =
                                                        shared2.map.epoch_shard_of(&g, k);
                                                    if *epoch0.get_or_insert(e) != e {
                                                        return Err(OracleError::Epoch);
                                                    }
                                                    ids.push((s as i64) << 32);
                                                }
                                                let epoch = epoch0
                                                    .unwrap_or_else(|| shared2.map.epoch());
                                                (ids, epoch)
                                            }
                                            PreRoute::Bucket => {
                                                let e = engine
                                                    .as_ref()
                                                    .ok_or(OracleError::Engine)?;
                                                let mut cache = route_cache.borrow_mut();
                                                let live = shared2.map.epoch();
                                                if cache
                                                    .as_ref()
                                                    .map_or(true, |(s, _)| s.epoch != live)
                                                {
                                                    let snap =
                                                        shared2.map.route_snapshot(&g);
                                                    let params: Vec<ShardParams> = snap
                                                        .shards
                                                        .iter()
                                                        .map(|&(hash, nb)| {
                                                            let (kind, seed) =
                                                                HashKind::of(hash);
                                                            (seed, nb as u64, kind)
                                                        })
                                                        .collect();
                                                    shared2
                                                        .snapshot_rebuilds
                                                        .fetch_add(1, Ordering::Relaxed);
                                                    *cache = Some((snap, params));
                                                }
                                                let (snap, params) =
                                                    cache.as_ref().expect("just filled");
                                                let shard_ids: Vec<u32> = keys
                                                    .iter()
                                                    .map(|&k| snap.shard_of(k))
                                                    .collect();
                                                let ids = e
                                                    .batch_hash_multi(keys, &shard_ids, params)
                                                    .map_err(|_| OracleError::Engine)?;
                                                (ids, snap.epoch)
                                            }
                                        };
                                        if shared2.map.epoch() != epoch {
                                            return Err(OracleError::Epoch);
                                        }
                                        Ok(ids)
                                    };
                                let b = batcher.route(entries, Some(&oracle));
                                g.quiescent_state();
                                shared2.total_batches.fetch_add(1, Ordering::Relaxed);
                                match b.outcome {
                                    RouteOutcome::Routed => {
                                        shared2.pre_routed_batches.fetch_add(1, Ordering::Relaxed);
                                    }
                                    RouteOutcome::FallbackLength => {
                                        shared2
                                            .pre_route_fallbacks_length
                                            .fetch_add(1, Ordering::Relaxed);
                                    }
                                    RouteOutcome::FallbackEngine => {
                                        shared2
                                            .pre_route_fallbacks_engine
                                            .fetch_add(1, Ordering::Relaxed);
                                    }
                                    RouteOutcome::FallbackEpoch => {
                                        shared2
                                            .pre_route_fallbacks_epoch
                                            .fetch_add(1, Ordering::Relaxed);
                                        // The ids straddled a resize: the
                                        // cached snapshot (if its epoch
                                        // matched the mid-publish mirror)
                                        // may be stale — drop it so the
                                        // next batch rebuilds against the
                                        // settled directory.
                                        route_cache.borrow_mut().take();
                                    }
                                    RouteOutcome::Unrouted => {}
                                }
                                if batch_tx.send(b).is_err() {
                                    break;
                                }
                            }
                            if !open {
                                break; // lane closed: shutdown
                            }
                        }
                    })?,
            );
        }
        let ingest = IngestLanes::new(lane_txs);
        // The workers' queue must close when the lane threads exit;
        // they hold the only other clones.
        drop(batch_tx);

        // KV workers: drive the map through the ConcurrentMap facade.
        for w in 0..cfg.workers.max(1) {
            let shared2 = shared.clone();
            let rx = batch_rx.clone();
            threads.push(
                std::thread::Builder::new()
                    .name(format!("dhash-worker-{w}"))
                    .spawn(move || {
                        let g = RcuThread::register();
                        let kv: &dyn ConcurrentMap = &shared2.map;
                        loop {
                            // Block offline so grace periods keep flowing
                            // while we wait for work.
                            let batch = g.offline_while(|| {
                                let rx = rx.lock().unwrap(); // lock: worker-queue
                                rx.recv().ok()
                            });
                            let Some(batch) = batch else { break };
                            for entry in batch.entries {
                                let resp = match entry.req {
                                    Request::Get { key } => match kv.lookup(&g, key) {
                                        Some(v) => Response::Value(v),
                                        None => Response::Missing,
                                    },
                                    Request::Put { key, val } => {
                                        // Atomic last-wins overwrite: the
                                        // DHash maps swap the value in
                                        // place, so a concurrent Get
                                        // never sees the key absent.
                                        kv.upsert(&g, key, val);
                                        shared2.sampler.push(key);
                                        Response::Ok
                                    }
                                    Request::Del { key } => {
                                        if kv.delete(&g, key) {
                                            Response::Ok
                                        } else {
                                            Response::Missing
                                        }
                                    }
                                };
                                shared2.total_requests.fetch_add(1, Ordering::Relaxed);
                                entry.complete(resp);
                            }
                            g.quiescent_state();
                        }
                    })?,
            );
        }

        // Analytics thread: per-shard detector verdicts + targeted
        // mitigation + the elastic split/merge policy. Engines need not
        // be Send (the PJRT client is thread-bound), so the engine is
        // constructed *inside* the thread; load errors are reported back
        // over a ready channel.
        if cfg.enable_analytics {
            let shared2 = shared.clone();
            let det = cfg.detector.clone();
            let elastic = cfg.elastic.clone();
            let (ready_tx, ready_rx) = channel::<anyhow::Result<()>>();
            threads.push(
                std::thread::Builder::new()
                    .name("dhash-analytics".into())
                    .spawn(move || {
                        let engine = match load_engine() {
                            Ok(e) => {
                                let _ = ready_tx.send(Ok(()));
                                e
                            }
                            Err(e) => {
                                let _ = ready_tx.send(Err(e));
                                return;
                            }
                        };
                        let g = RcuThread::register();
                        let mut detect_err_logged = false;
                        while !shared2.stop.load(Ordering::Relaxed) {
                            g.offline_while(|| std::thread::sleep(det.period));
                            // ONE epoch-stamped directory observation per
                            // cycle: the partition, every per-shard
                            // geometry, the verdict attribution, and the
                            // resize decision all speak (epoch, ordinal)
                            // of this snapshot — a split/merge landing
                            // mid-cycle invalidates the epoch check
                            // instead of misattributing a verdict.
                            let snap = shared2.map.route_snapshot(&g);
                            let nshards = snap.nshards();
                            // Verdict floor per shard: the sample splits
                            // roughly evenly across shards, so each
                            // shard's share of min_samples keeps the same
                            // statistical footing the unsharded detector
                            // had. Recomputed per cycle — the shard count
                            // moves under the elastic policy.
                            let mut per_cfg = det.clone();
                            per_cfg.min_samples = (det.min_samples + nshards - 1) / nshards;
                            let mut chi2s = vec![0.0f32; nshards];
                            let keys = shared2.sampler.snapshot();
                            if !keys.is_empty() {
                                let parts = partition_by_shard(&keys, &snap);
                                let mut max_chi2 = 0.0f32;
                                let mut evaluated = false;
                                for (s, part) in parts.iter().enumerate() {
                                    if part.is_empty() {
                                        continue;
                                    }
                                    let (hash, nb) = snap.shards[s];
                                    let (kind, seed) = HashKind::of(hash);
                                    let d = match engine.detect(part, seed, nb as u64, kind) {
                                        Ok(d) => d,
                                        Err(e) => {
                                            // A backend that cannot evaluate
                                            // (e.g. the pjrt backend without
                                            // an XLA binding) means detection
                                            // is dead; say so once instead of
                                            // silently never mitigating.
                                            if !detect_err_logged {
                                                detect_err_logged = true;
                                                eprintln!(
                                                    "dhash-analytics: detector disabled, \
                                                     engine {:?} cannot evaluate: {e:?}",
                                                    engine.name()
                                                );
                                            }
                                            continue;
                                        }
                                    };
                                    evaluated = true;
                                    chi2s[s] = d.chi2;
                                    max_chi2 = max_chi2.max(d.chi2);
                                    let verdict = SkewVerdict::classify(
                                        &per_cfg,
                                        part.len(),
                                        d.chi2,
                                        d.max_load,
                                        engine.nbins(),
                                    );
                                    if let SkewVerdict::Attack { chi2, .. } = verdict {
                                        // Cooldown keyed by the shard's
                                        // stable uid: resizes shift
                                        // ordinals, never uids.
                                        if let Some(new_hash) = shared2
                                            .controller
                                            .plan_mitigation_for(snap.uids[s], Instant::now())
                                        {
                                            let nb_new = shared2.controller.buckets_for(nb);
                                            // Targeted mitigation, pinned to
                                            // the epoch the verdict was
                                            // computed under: if a split or
                                            // merge moved the directory
                                            // meanwhile, the rebuild is
                                            // refused instead of migrating
                                            // whichever shard inherited the
                                            // ordinal.
                                            if let Ok(stats) = shared2.map.rebuild_shard_at(
                                                &g,
                                                Some(snap.epoch),
                                                s,
                                                nb_new,
                                                new_hash,
                                            ) {
                                                shared2.rebuilds.fetch_add(1, Ordering::Relaxed);
                                                shared2.controller.record(
                                                    snap.epoch,
                                                    s,
                                                    chi2,
                                                    new_hash,
                                                    stats.moved,
                                                    stats.elapsed,
                                                );
                                            }
                                        }
                                    }
                                }
                                if evaluated {
                                    shared2.detector_runs.fetch_add(1, Ordering::Relaxed);
                                    shared2
                                        .last_chi2
                                        .store(max_chi2.to_bits() as u64, Ordering::Relaxed);
                                    *shared2.shard_chi2.lock().unwrap() = chi2s.clone(); // lock: coord-stats
                                }
                            }
                            // Elastic policy: occupancy (+ chi² pressure)
                            // decides splits/merges, evaluated under the
                            // same epoch as everything above.
                            if let Some(el) = &elastic {
                                let (ep, profile) = shared2.map.load_profile(&g);
                                if ep == snap.epoch && profile.len() == nshards {
                                    let splittable: Vec<bool> = (0..nshards)
                                        .map(|s| shared2.map.splittable(&g, s))
                                        .collect();
                                    let buddies: Vec<Option<usize>> = (0..nshards)
                                        .map(|s| shared2.map.buddy_of(&g, s))
                                        .collect();
                                    let action = shared2.controller.plan_resize(
                                        el,
                                        &profile,
                                        &chi2s,
                                        engine.chi2_threshold(det.sigma),
                                        &splittable,
                                        &buddies,
                                        Instant::now(),
                                    );
                                    match action {
                                        Some(ResizeAction::Split(s)) => {
                                            // Children keep the parent's
                                            // geometry: capacity doubles,
                                            // per-shard load halves.
                                            // Epoch-pinned, like the
                                            // mitigation path: a resize
                                            // that raced the scoring makes
                                            // this refuse, not mistarget.
                                            let (hash, nb) = snap.shards[s];
                                            if let Ok(st) = shared2.map.split_shard_at(
                                                &g,
                                                Some(snap.epoch),
                                                s,
                                                nb.max(1),
                                                hash,
                                            ) {
                                                shared2.controller.record_resize(
                                                    ResizeAction::Split(s),
                                                    snap.epoch,
                                                    shared2.map.shards(),
                                                    st.moved,
                                                    st.elapsed,
                                                );
                                            }
                                        }
                                        Some(ResizeAction::Merge(s)) => {
                                            // The merged shard absorbs both
                                            // buddies' budgets. Epoch-pinned
                                            // like the split arm.
                                            let (hash, nb) = snap.shards[s];
                                            if let Ok(st) = shared2.map.merge_shard_at(
                                                &g,
                                                Some(snap.epoch),
                                                s,
                                                (nb * 2).max(1),
                                                hash,
                                            ) {
                                                shared2.controller.record_resize(
                                                    ResizeAction::Merge(s),
                                                    snap.epoch,
                                                    shared2.map.shards(),
                                                    st.moved,
                                                    st.elapsed,
                                                );
                                            }
                                        }
                                        None => {}
                                    }
                                }
                            }
                            g.quiescent_state();
                        }
                    })?,
            );
            // Propagate artifact-loading failures to the caller.
            ready_rx
                .recv()
                .map_err(|_| anyhow::anyhow!("analytics thread died during startup"))??;
        }

        Ok(Coordinator {
            shared,
            ingest: Mutex::new(Some(ingest)),
            threads: Mutex::new(threads),
            cfg,
        })
    }

    /// A submission handle onto the ingest lanes: the completion-based
    /// API ([`KvClient::submit`] → [`super::Ticket`]). Take one per
    /// client thread — it is a clone of the lane senders, so submission
    /// shares no lock. A client taken after [`Coordinator::shutdown`]
    /// (or outliving it) fails every submit with
    /// [`super::SubmitError::Shutdown`]; it never panics or hangs.
    pub fn client(&self) -> KvClient {
        let lanes = self
            .ingest
            // lock: coord-ingest
            .lock()
            .unwrap()
            .clone()
            .unwrap_or_else(IngestLanes::closed);
        KvClient::new(lanes)
    }

    /// Execute one request (blocks for the reply). Thin wrapper over
    /// [`Coordinator::client`]; panics if the coordinator is shut down,
    /// matching the pre-ticket API.
    pub fn execute(&self, req: Request) -> Response {
        self.client()
            .submit(req)
            .expect("coordinator is shut down")
            .wait()
            .expect("workers alive")
    }

    /// Execute a batch of requests, returning responses in submission
    /// order. Thin wrapper over [`Coordinator::client`]; panics if the
    /// coordinator is shut down, matching the pre-ticket API.
    pub fn execute_many(&self, reqs: Vec<Request>) -> Vec<Response> {
        self.client()
            .submit_batch(&reqs)
            .expect("coordinator is shut down")
            .wait()
            .expect("workers alive")
    }

    /// Trigger a staggered whole-map rebuild right now (ops tooling /
    /// tests). `nbuckets` is per shard, matching `CoordinatorConfig`.
    ///
    /// Refuses a zero-bucket geometry with
    /// [`ResizeError::BadGeometry`](crate::error::ResizeError::BadGeometry)
    /// before touching the map — this is the coordinator-side boundary
    /// check that keeps a malformed `Rebuild` request (wire or CLI) from
    /// panicking a worker on the table allocator's internal invariant —
    /// and reports a rebuild already in flight as
    /// [`ResizeError::Busy`](crate::error::ResizeError::Busy).
    pub fn force_rebuild(
        &self,
        nbuckets: usize,
        hash: HashFn,
    ) -> Result<(), crate::error::KvError> {
        use crate::error::{KvError, ResizeError};
        if nbuckets == 0 {
            return Err(KvError::Resize(ResizeError::BadGeometry));
        }
        let g = RcuThread::register();
        let res = self.shared.map.rebuild_all(&g, nbuckets, hash);
        if res.is_ok() {
            self.shared.rebuilds.fetch_add(1, Ordering::Relaxed);
        }
        g.quiescent_state();
        res.map(|_| ()).map_err(|_| KvError::Resize(ResizeError::Busy))
    }

    /// The underlying sharded map (shared with the service; use a
    /// registered guard). `shards == 1` in unsharded deployments.
    pub fn map(&self) -> &ShardedDHash {
        &self.shared.map
    }

    /// Mitigation rebuild history.
    pub fn rebuild_events(&self) -> Vec<super::RebuildEvent> {
        self.shared.controller.events()
    }

    /// Elastic split/merge history (empty unless
    /// [`CoordinatorConfig::elastic`] is set; splits/merges driven
    /// directly through [`Coordinator::map`] count in
    /// [`CoordinatorStats`] but not here).
    pub fn resize_events(&self) -> Vec<super::ResizeEvent> {
        self.shared.controller.resize_events()
    }

    pub fn stats(&self) -> CoordinatorStats {
        CoordinatorStats {
            total_requests: self.shared.total_requests.load(Ordering::Relaxed),
            total_batches: self.shared.total_batches.load(Ordering::Relaxed),
            pre_routed_batches: self.shared.pre_routed_batches.load(Ordering::Relaxed),
            pre_route_fallbacks_length: self
                .shared
                .pre_route_fallbacks_length
                .load(Ordering::Relaxed),
            pre_route_fallbacks_engine: self
                .shared
                .pre_route_fallbacks_engine
                .load(Ordering::Relaxed),
            pre_route_fallbacks_epoch: self
                .shared
                .pre_route_fallbacks_epoch
                .load(Ordering::Relaxed),
            snapshot_rebuilds: self.shared.snapshot_rebuilds.load(Ordering::Relaxed),
            rebuilds: self.shared.rebuilds.load(Ordering::Relaxed),
            splits: self.shared.map.split_count(),
            merges: self.shared.map.merge_count(),
            shards: self.shared.map.shards() as u64,
            epoch: self.shared.map.epoch(),
            last_chi2: f32::from_bits(self.shared.last_chi2.load(Ordering::Relaxed) as u32),
            last_chi2_per_shard: self.shared.shard_chi2.lock().unwrap().clone(), // lock: coord-stats
            detector_runs: self.shared.detector_runs.load(Ordering::Relaxed),
            net: None,
        }
    }

    pub fn config(&self) -> &CoordinatorConfig {
        &self.cfg
    }

    /// Stop all service threads and wait for them. Requests enqueued
    /// before the shutdown drain first (per-lane close markers); any
    /// submitted after it resolve their tickets to
    /// [`super::SubmitError::Shutdown`] instead of hanging — outstanding
    /// [`KvClient`]s keep working as error-returning stubs.
    pub fn shutdown(&self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        // Close markers unwind the lane batchers (draining what's
        // queued), whose exit closes the worker queue in turn. Sender
        // clones held by stray clients can't keep the lanes alive: the
        // threads stop at the marker, not at channel disconnect.
        if let Some(lanes) = self.ingest.lock().unwrap().take() { // lock: coord-ingest
            lanes.close();
        }
        let mut threads = self.threads.lock().unwrap(); // lock: coord-threads
        for h in threads.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown();
    }
}
