//! The coordinator proper: wires batcher → workers → DHashMap, plus the
//! analytics thread (detector engine + rebuild controller).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use super::batcher::{Batch, Batcher, BatcherConfig, Entry, Request, Response};
use super::controller::{ControllerConfig, RebuildController};
use super::detector::{DetectorConfig, KeySampler, SkewVerdict};
use crate::dhash::{DHashMap, HashFn};
use crate::rcu::RcuThread;
use crate::runtime::{load_engine, Engine, HashKind};

#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    pub nbuckets: usize,
    pub hash: HashFn,
    /// KV worker threads.
    pub workers: usize,
    pub batcher: BatcherConfig,
    pub detector: DetectorConfig,
    pub controller: ControllerConfig,
    /// Run the detector/mitigation loop on the configured engine backend
    /// ([`crate::runtime::load_engine`]; the native backend by default,
    /// `DHASH_ENGINE=pjrt` for the AOT-artifact backend).
    pub enable_analytics: bool,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            nbuckets: 4096,
            hash: HashFn::Seeded(0xD1E5_5EED),
            workers: 2,
            batcher: BatcherConfig::default(),
            detector: DetectorConfig::default(),
            controller: ControllerConfig::default(),
            enable_analytics: true,
        }
    }
}

/// Aggregate service counters.
#[derive(Clone, Debug, Default)]
pub struct CoordinatorStats {
    pub total_requests: u64,
    pub total_batches: u64,
    /// Mitigation + manual rebuilds completed.
    pub rebuilds: u64,
    /// chi2 from the most recent detector evaluation (0 until evaluated).
    pub last_chi2: f32,
    /// Detector evaluations performed.
    pub detector_runs: u64,
}

struct Shared {
    map: DHashMap,
    sampler: KeySampler,
    stop: AtomicBool,
    total_requests: AtomicU64,
    total_batches: AtomicU64,
    rebuilds: AtomicU64,
    detector_runs: AtomicU64,
    /// f32 bits of the last chi2.
    last_chi2: AtomicU64,
    controller: RebuildController,
}

/// The running service. Create with [`Coordinator::start`], stop with
/// [`Coordinator::shutdown`].
pub struct Coordinator {
    shared: Arc<Shared>,
    input: Mutex<Option<Sender<Entry>>>,
    threads: Mutex<Vec<JoinHandle<()>>>,
    cfg: CoordinatorConfig,
}

impl Coordinator {
    pub fn start(cfg: CoordinatorConfig) -> anyhow::Result<Coordinator> {
        let shared = Arc::new(Shared {
            map: DHashMap::with_hash(cfg.nbuckets, cfg.hash),
            sampler: KeySampler::new(cfg.detector.sample_capacity),
            stop: AtomicBool::new(false),
            total_requests: AtomicU64::new(0),
            total_batches: AtomicU64::new(0),
            rebuilds: AtomicU64::new(0),
            detector_runs: AtomicU64::new(0),
            last_chi2: AtomicU64::new(0),
            controller: RebuildController::new(
                cfg.controller.clone(),
                // Seed entropy: wall clock + ASLR'd stack address. Not
                // cryptographic, but unpredictable enough that an attacker
                // cannot precompute collisions for the *next* seed.
                crate::util::rng::mix64(
                    std::time::SystemTime::now()
                        .duration_since(std::time::UNIX_EPOCH)
                        .map(|d| d.as_nanos() as u64)
                        .unwrap_or(0)
                        ^ (&cfg as *const _ as u64),
                ),
            ),
        });

        let (client_tx, client_rx) = channel::<Entry>();
        let (batch_tx, batch_rx) = channel::<Batch>();
        let batch_rx = Arc::new(Mutex::new(batch_rx));
        let mut threads = Vec::new();

        // Batcher thread.
        {
            let cfg_b = cfg.batcher.clone();
            let shared2 = shared.clone();
            // Pre-hashing needs its own engine (backends need not be
            // Send — the PJRT client is thread-bound — so each thread
            // that evaluates kernels owns one).
            let want_prehash = cfg_b.pre_hash && cfg.enable_analytics;
            threads.push(
                std::thread::Builder::new()
                    .name("dhash-batcher".into())
                    .spawn(move || {
                        let batcher = Batcher::new(cfg_b);
                        let engine: Option<Box<dyn Engine>> = if want_prehash {
                            load_engine().ok()
                        } else {
                            None
                        };
                        let g = RcuThread::register();
                        loop {
                            // Collect OFFLINE (blocking recv must not
                            // stall grace periods), then route online.
                            let Some(entries) =
                                g.offline_while(|| batcher.collect(&client_rx))
                            else {
                                break; // input closed: shutdown
                            };
                            let b = match engine.as_ref() {
                                Some(e) => {
                                    // Hash oracle: the table's *current*
                                    // function, evaluated through the
                                    // engine backend.
                                    let oracle = |keys: &[u64]| -> Option<Vec<i32>> {
                                        let hash = shared2.map.hash_fn(&g);
                                        let nb = shared2.map.nbuckets(&g) as u64;
                                        let (kind, seed) = HashKind::of(hash);
                                        e.batch_hash(keys, seed, nb, kind).ok()
                                    };
                                    batcher.route(entries, Some(&oracle))
                                }
                                None => batcher.route(entries, None),
                            };
                            g.quiescent_state();
                            shared2.total_batches.fetch_add(1, Ordering::Relaxed);
                            if batch_tx.send(b).is_err() {
                                break;
                            }
                        }
                    })?,
            );
        }

        // KV workers.
        for w in 0..cfg.workers.max(1) {
            let shared2 = shared.clone();
            let rx = batch_rx.clone();
            threads.push(
                std::thread::Builder::new()
                    .name(format!("dhash-worker-{w}"))
                    .spawn(move || {
                        let g = RcuThread::register();
                        loop {
                            // Block offline so grace periods keep flowing
                            // while we wait for work.
                            let batch = g.offline_while(|| {
                                let rx = rx.lock().unwrap();
                                rx.recv().ok()
                            });
                            let Some(batch) = batch else { break };
                            for (req, reply, seq) in batch.entries {
                                let resp = match req {
                                    Request::Get { key } => match shared2.map.lookup(&g, key) {
                                        Some(v) => Response::Value(v),
                                        None => Response::Missing,
                                    },
                                    Request::Put { key, val } => {
                                        // Upsert: last-wins.
                                        if shared2.map.insert(&g, key, val).is_err() {
                                            shared2.map.delete(&g, key);
                                            let _ = shared2.map.insert(&g, key, val);
                                        }
                                        shared2.sampler.push(key);
                                        Response::Ok
                                    }
                                    Request::Del { key } => {
                                        if shared2.map.delete(&g, key) {
                                            Response::Ok
                                        } else {
                                            Response::Missing
                                        }
                                    }
                                };
                                shared2.total_requests.fetch_add(1, Ordering::Relaxed);
                                let _ = reply.send((seq, resp));
                            }
                            g.quiescent_state();
                        }
                    })?,
            );
        }

        // Analytics thread: detector + mitigation. Engines need not be
        // Send (the PJRT client is thread-bound), so the engine is
        // constructed *inside* the thread; load errors are reported back
        // over a ready channel.
        if cfg.enable_analytics {
            let shared2 = shared.clone();
            let det = cfg.detector.clone();
            let (ready_tx, ready_rx) = channel::<anyhow::Result<()>>();
            threads.push(
                std::thread::Builder::new()
                    .name("dhash-analytics".into())
                    .spawn(move || {
                        let engine = match load_engine() {
                            Ok(e) => {
                                let _ = ready_tx.send(Ok(()));
                                e
                            }
                            Err(e) => {
                                let _ = ready_tx.send(Err(e));
                                return;
                            }
                        };
                        let g = RcuThread::register();
                        let mut detect_err_logged = false;
                        while !shared2.stop.load(Ordering::Relaxed) {
                            g.offline_while(|| std::thread::sleep(det.period));
                            let keys = shared2.sampler.snapshot();
                            if keys.is_empty() {
                                continue;
                            }
                            let hash = shared2.map.hash_fn(&g);
                            let nb = shared2.map.nbuckets(&g) as u64;
                            let (kind, seed) = HashKind::of(hash);
                            let d = match engine.detect(&keys, seed, nb, kind) {
                                Ok(d) => d,
                                Err(e) => {
                                    // A backend that cannot evaluate (e.g.
                                    // the pjrt backend without an XLA
                                    // binding) means detection is dead;
                                    // say so once instead of silently
                                    // never mitigating.
                                    if !detect_err_logged {
                                        detect_err_logged = true;
                                        eprintln!(
                                            "dhash-analytics: detector disabled, \
                                             engine {:?} cannot evaluate: {e:?}",
                                            engine.name()
                                        );
                                    }
                                    continue;
                                }
                            };
                            shared2.detector_runs.fetch_add(1, Ordering::Relaxed);
                            shared2
                                .last_chi2
                                .store(d.chi2.to_bits() as u64, Ordering::Relaxed);
                            let verdict = SkewVerdict::classify(
                                &det,
                                keys.len(),
                                d.chi2,
                                d.max_load,
                                engine.nbins(),
                            );
                            if let SkewVerdict::Attack { chi2, .. } = verdict {
                                if let Some(new_hash) =
                                    shared2.controller.plan_mitigation(Instant::now())
                                {
                                    let nb = shared2
                                        .controller
                                        .buckets_for(shared2.map.nbuckets(&g));
                                    if let Ok(stats) = shared2.map.rebuild(&g, nb, new_hash) {
                                        shared2.rebuilds.fetch_add(1, Ordering::Relaxed);
                                        shared2.controller.record(
                                            chi2,
                                            new_hash,
                                            stats.moved,
                                            stats.elapsed,
                                        );
                                    }
                                }
                            }
                            g.quiescent_state();
                        }
                    })?,
            );
            // Propagate artifact-loading failures to the caller.
            ready_rx
                .recv()
                .map_err(|_| anyhow::anyhow!("analytics thread died during startup"))??;
        }

        Ok(Coordinator {
            shared,
            input: Mutex::new(Some(client_tx)),
            threads: Mutex::new(threads),
            cfg,
        })
    }

    /// Execute one request (blocks for the reply).
    pub fn execute(&self, req: Request) -> Response {
        self.execute_many(vec![req]).pop().unwrap()
    }

    /// Execute a batch of requests, returning responses in order.
    pub fn execute_many(&self, reqs: Vec<Request>) -> Vec<Response> {
        let n = reqs.len();
        let (reply_tx, reply_rx) = channel();
        {
            let input = self.input.lock().unwrap();
            let tx = input.as_ref().expect("coordinator is shut down");
            for (i, r) in reqs.into_iter().enumerate() {
                tx.send((r, reply_tx.clone(), i)).expect("batcher alive");
            }
        }
        drop(reply_tx);
        let mut out = vec![Response::Missing; n];
        for _ in 0..n {
            let (i, resp) = reply_rx.recv().expect("workers alive");
            out[i] = resp;
        }
        out
    }

    /// Trigger a rebuild right now (ops tooling / tests).
    pub fn force_rebuild(&self, nbuckets: usize, hash: HashFn) -> bool {
        let g = RcuThread::register();
        let ok = self.shared.map.rebuild(&g, nbuckets, hash).is_ok();
        if ok {
            self.shared.rebuilds.fetch_add(1, Ordering::Relaxed);
        }
        g.quiescent_state();
        ok
    }

    /// The underlying map (shared with the service; use a registered
    /// guard).
    pub fn map(&self) -> &DHashMap {
        &self.shared.map
    }

    /// Mitigation rebuild history.
    pub fn rebuild_events(&self) -> Vec<super::RebuildEvent> {
        self.shared.controller.events()
    }

    pub fn stats(&self) -> CoordinatorStats {
        CoordinatorStats {
            total_requests: self.shared.total_requests.load(Ordering::Relaxed),
            total_batches: self.shared.total_batches.load(Ordering::Relaxed),
            rebuilds: self.shared.rebuilds.load(Ordering::Relaxed),
            last_chi2: f32::from_bits(self.shared.last_chi2.load(Ordering::Relaxed) as u32),
            detector_runs: self.shared.detector_runs.load(Ordering::Relaxed),
        }
    }

    pub fn config(&self) -> &CoordinatorConfig {
        &self.cfg
    }

    /// Stop all service threads and wait for them.
    pub fn shutdown(&self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        // Closing the input channel unwinds batcher then workers.
        *self.input.lock().unwrap() = None;
        let mut threads = self.threads.lock().unwrap();
        for h in threads.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown();
    }
}
