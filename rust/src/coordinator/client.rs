//! The completion-based ingest client: [`KvClient`] tickets over the
//! coordinator's multi-lane batchers.
//!
//! The pre-lane API (`execute` / `execute_many`) funneled every request
//! through one `Mutex<Sender>`, allocated a fresh mpsc reply channel per
//! call, and blocked until the reply arrived — the single blocking
//! batcher it fed serialized ahead of the shards (ROADMAP "Async
//! batcher"). The redesign splits submission from completion:
//!
//! * [`KvClient::submit`] / [`KvClient::submit_batch`] enqueue requests
//!   on one of N independent ingest lanes and return immediately with a
//!   [`Ticket`] / [`BatchTicket`];
//! * a ticket is a handle onto a **shared, pre-allocated completion
//!   buffer** ([`CompletionSet`]): one atomic slot per request, written
//!   in place by the KV worker that executes it — no per-call channel
//!   allocation on the hot path;
//! * `poll` / `wait` / `wait_timeout` observe the buffer; batch
//!   responses come back **in submission order** (slot *i* belongs to
//!   request *i*).
//!
//! Clients are cheap: a `KvClient` is a clone of the lane senders, so
//! every thread takes its own from [`Coordinator::client`] and submits
//! without any shared lock.
//!
//! Shutdown safety: a request that can no longer be executed (the
//! coordinator shut down, a lane closed, or a worker died mid-batch)
//! resolves its slot to [`SubmitError::Shutdown`] instead of hanging —
//! the batcher entry fails its slot on drop, so every accepted ticket
//! resolves eventually.
//!
//! [`Coordinator::client`]: super::Coordinator::client

use std::sync::atomic::{AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use super::batcher::{Entry, IngestLanes, Request, Response};

/// Why a submission (or an accepted request) could not complete.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitError {
    /// The coordinator is shut down (or shut down / lost its worker
    /// while the request was pending).
    Shutdown,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Shutdown => write!(f, "coordinator is shut down"),
        }
    }
}

impl std::error::Error for SubmitError {}

// Slot states. A slot is written exactly once, by the worker that
// executes the request (or by `Entry::drop` when the request can no
// longer be executed), then never changes.
const SLOT_PENDING: u8 = 0;
const SLOT_OK: u8 = 1; // Response::Ok
const SLOT_VALUE: u8 = 2; // Response::Value(val)
const SLOT_MISSING: u8 = 3; // Response::Missing
const SLOT_FAILED: u8 = 4; // SubmitError::Shutdown

/// One pre-allocated completion slot: the response discriminant plus its
/// payload, written in place — the replacement for the old per-call
/// `Sender<(usize, Response)>` reply channel.
///
/// Aligned to a cacheline: adjacent slots of one batch are resolved by
/// *different* KV workers concurrently (the batcher fans a batch's
/// entries out by key), so packed 16-byte slots would put four resolvers
/// on one line and turn every `fulfill` into a coherence miss for its
/// neighbors — measured by the `rebuild`/`splitmerge` write scenarios of
/// `benches/latency.rs`.
#[repr(align(64))]
struct Slot {
    kind: AtomicU8,
    val: AtomicU64,
}

/// The shared completion buffer behind a [`Ticket`] / [`BatchTicket`]:
/// one slot per submitted request, a remaining-count, and a condvar for
/// blocking waits. Allocated once per submission (a single `Arc`), then
/// only atomics are touched.
pub(crate) struct CompletionSet {
    slots: Box<[Slot]>,
    /// Slots not yet resolved. The last resolver notifies the condvar.
    remaining: AtomicUsize,
    /// Pure wait/notify plumbing; no data lives under the lock. The
    /// resolver takes it before notifying so a waiter can never check
    /// `remaining` and miss the wakeup between check and sleep.
    lock: Mutex<()>,
    done: Condvar,
}

impl CompletionSet {
    pub(crate) fn new(n: usize) -> Self {
        Self {
            slots: (0..n)
                .map(|_| Slot {
                    kind: AtomicU8::new(SLOT_PENDING),
                    val: AtomicU64::new(0),
                })
                .collect(),
            remaining: AtomicUsize::new(n),
            lock: Mutex::new(()),
            done: Condvar::new(),
        }
    }

    pub(crate) fn len(&self) -> usize {
        self.slots.len()
    }

    /// Resolve slot `idx` with a worker response. Called exactly once
    /// per slot (each `Entry` owns its slot).
    pub(crate) fn fulfill(&self, idx: usize, resp: Response) {
        let s = &self.slots[idx];
        let kind = match resp {
            Response::Ok => SLOT_OK,
            Response::Value(v) => {
                s.val.store(v, Ordering::Relaxed);
                SLOT_VALUE
            }
            Response::Missing => SLOT_MISSING,
        };
        s.kind.store(kind, Ordering::Release);
        self.finish_one();
    }

    /// Resolve slot `idx` as failed (the request was dropped without
    /// being executed: shutdown, closed lane, dead worker).
    pub(crate) fn fail(&self, idx: usize) {
        self.slots[idx].kind.store(SLOT_FAILED, Ordering::Release);
        self.finish_one();
    }

    fn finish_one(&self) {
        if self.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
            // Hold the lock across the notify: a waiter between its
            // `remaining` check and the condvar sleep holds it, so we
            // cannot slip a notification into that window.
            let _g = self.lock.lock().unwrap(); // lock: completion
            self.done.notify_all();
        }
    }

    fn is_done(&self) -> bool {
        self.remaining.load(Ordering::Acquire) == 0
    }

    /// Decode slot `idx`; `None` while still pending.
    pub(crate) fn poll_slot(&self, idx: usize) -> Option<Result<Response, SubmitError>> {
        let s = &self.slots[idx];
        match s.kind.load(Ordering::Acquire) {
            SLOT_PENDING => None,
            SLOT_OK => Some(Ok(Response::Ok)),
            SLOT_VALUE => Some(Ok(Response::Value(s.val.load(Ordering::Relaxed)))),
            SLOT_MISSING => Some(Ok(Response::Missing)),
            _ => Some(Err(SubmitError::Shutdown)),
        }
    }

    /// Block until every slot is resolved, or `timeout` (if given)
    /// elapses. True = done.
    fn wait_done(&self, timeout: Option<Duration>) -> bool {
        // `Instant + Duration` panics on overflow, which a huge timeout
        // (`Duration::MAX` as "effectively forever") would hit; overflow
        // means the deadline is unreachable, so treat it as no deadline.
        let deadline = timeout.and_then(|t| Instant::now().checked_add(t));
        let mut g = self.lock.lock().unwrap(); // lock: completion
        while self.remaining.load(Ordering::Acquire) != 0 {
            match deadline {
                None => g = self.done.wait(g).unwrap(),
                Some(d) => {
                    let now = Instant::now();
                    if now >= d {
                        return false;
                    }
                    let (g2, _) = self.done.wait_timeout(g, d - now).unwrap();
                    g = g2;
                }
            }
        }
        true
    }

    /// All slots in submission order; `Err` if any request failed.
    fn collect(&self) -> Result<Vec<Response>, SubmitError> {
        debug_assert!(self.is_done());
        (0..self.slots.len())
            .map(|i| self.poll_slot(i).expect("completion set is done"))
            .collect()
    }
}

/// Completion handle for one [`KvClient::submit`]-ted request.
pub struct Ticket {
    set: Arc<CompletionSet>,
}

impl Ticket {
    /// Non-blocking: the response if the request has completed.
    pub fn poll(&self) -> Option<Result<Response, SubmitError>> {
        self.set.poll_slot(0)
    }

    /// Block until the request completes.
    pub fn wait(&self) -> Result<Response, SubmitError> {
        self.set.wait_done(None);
        self.set.poll_slot(0).expect("completion set is done")
    }

    /// Block up to `timeout`; `None` if the request is still pending.
    pub fn wait_timeout(&self, timeout: Duration) -> Option<Result<Response, SubmitError>> {
        if self.set.wait_done(Some(timeout)) {
            self.set.poll_slot(0)
        } else {
            None
        }
    }
}

/// Completion handle for one [`KvClient::submit_batch`]: `wait` returns
/// the responses **in submission order** (slot *i* = request *i*).
pub struct BatchTicket {
    set: Arc<CompletionSet>,
}

impl BatchTicket {
    /// Number of requests in the batch.
    pub fn len(&self) -> usize {
        self.set.len()
    }

    pub fn is_empty(&self) -> bool {
        self.set.len() == 0
    }

    /// Non-blocking: all responses if every request has completed.
    pub fn poll(&self) -> Option<Result<Vec<Response>, SubmitError>> {
        if self.set.is_done() {
            Some(self.set.collect())
        } else {
            None
        }
    }

    /// Non-blocking, per-slot: the outcome of every request once all
    /// have completed, in submission order. Unlike [`poll`], a failed
    /// slot does not mask its batch-mates — the network front end needs
    /// the good responses even when a shutdown failed the rest.
    ///
    /// [`poll`]: BatchTicket::poll
    pub fn poll_each(&self) -> Option<Vec<Result<Response, SubmitError>>> {
        if self.set.is_done() {
            Some(
                (0..self.set.len())
                    .map(|i| self.set.poll_slot(i).expect("completion set is done"))
                    .collect(),
            )
        } else {
            None
        }
    }

    /// Block until every request completes; responses in submission
    /// order. `Err` if any request was dropped by a shutdown.
    pub fn wait(&self) -> Result<Vec<Response>, SubmitError> {
        self.set.wait_done(None);
        self.set.collect()
    }

    /// Block up to `timeout`; `None` if any request is still pending.
    pub fn wait_timeout(&self, timeout: Duration) -> Option<Result<Vec<Response>, SubmitError>> {
        if self.set.wait_done(Some(timeout)) {
            Some(self.set.collect())
        } else {
            None
        }
    }
}

/// Submission handle onto the coordinator's ingest lanes. Obtain one per
/// thread from [`Coordinator::client`] (it is a clone of the lane
/// senders — no lock is shared between clients), submit requests, and
/// resolve the returned tickets at your own pace.
///
/// [`Coordinator::client`]: super::Coordinator::client
#[derive(Clone)]
pub struct KvClient {
    lanes: IngestLanes,
}

impl KvClient {
    pub(crate) fn new(lanes: IngestLanes) -> Self {
        Self { lanes }
    }

    /// Number of ingest lanes this client submits across.
    pub fn lanes(&self) -> usize {
        self.lanes.nlanes()
    }

    /// Enqueue one request on its key's lane. Returns immediately with a
    /// [`Ticket`]; [`SubmitError::Shutdown`] if the coordinator is shut
    /// down.
    pub fn submit(&self, req: Request) -> Result<Ticket, SubmitError> {
        let set = Arc::new(CompletionSet::new(1));
        self.lanes.dispatch(Entry::new(req, set.clone(), 0))?;
        Ok(Ticket { set })
    }

    /// Enqueue a batch, each request on its key's lane, sharing one
    /// pre-allocated completion buffer. Responses come back in
    /// submission order. On [`SubmitError::Shutdown`] a prefix of the
    /// batch may still execute (submission is per-lane, not
    /// transactional); no ticket is returned, so nothing leaks.
    pub fn submit_batch(&self, reqs: &[Request]) -> Result<BatchTicket, SubmitError> {
        let set = Arc::new(CompletionSet::new(reqs.len()));
        for (i, r) in reqs.iter().enumerate() {
            self.lanes.dispatch(Entry::new(*r, set.clone(), i))?;
        }
        Ok(BatchTicket { set })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slots_resolve_in_submission_order() {
        let set = Arc::new(CompletionSet::new(3));
        assert!(!set.is_done());
        // Resolve out of order; collect still returns slot order.
        set.fulfill(2, Response::Missing);
        set.fulfill(0, Response::Value(7));
        assert!(!set.is_done());
        assert_eq!(set.poll_slot(0), Some(Ok(Response::Value(7))));
        assert_eq!(set.poll_slot(1), None);
        set.fulfill(1, Response::Ok);
        assert!(set.is_done());
        assert_eq!(
            set.collect().unwrap(),
            vec![Response::Value(7), Response::Ok, Response::Missing]
        );
    }

    #[test]
    fn failed_slot_poisons_the_batch() {
        let set = Arc::new(CompletionSet::new(2));
        set.fulfill(0, Response::Ok);
        set.fail(1);
        assert!(set.is_done());
        assert_eq!(set.collect(), Err(SubmitError::Shutdown));
        // Per-slot decoding still distinguishes the good one.
        assert_eq!(set.poll_slot(0), Some(Ok(Response::Ok)));
        assert_eq!(set.poll_slot(1), Some(Err(SubmitError::Shutdown)));
    }

    #[test]
    fn empty_batch_is_born_done() {
        let set = CompletionSet::new(0);
        assert!(set.is_done());
        assert!(set.wait_done(Some(Duration::from_millis(1))));
        assert_eq!(set.collect().unwrap(), Vec::<Response>::new());
    }

    #[test]
    fn wait_blocks_until_resolution() {
        let set = Arc::new(CompletionSet::new(1));
        let t = Ticket { set: set.clone() };
        assert_eq!(t.poll(), None);
        assert_eq!(t.wait_timeout(Duration::from_millis(10)), None);
        let s2 = set.clone();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            s2.fulfill(0, Response::Value(99));
        });
        assert_eq!(t.wait(), Ok(Response::Value(99)));
        assert_eq!(t.poll(), Some(Ok(Response::Value(99))));
        h.join().unwrap();
    }

    #[test]
    fn wait_timeout_duration_max_means_forever_not_panic() {
        // Regression: the deadline used to be `Instant::now() + t`,
        // which panics on overflow for Duration::MAX. It must behave
        // like an untimed wait instead.
        let set = Arc::new(CompletionSet::new(1));
        let t = Ticket { set: set.clone() };
        let s2 = set.clone();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            s2.fulfill(0, Response::Value(5));
        });
        assert_eq!(t.wait_timeout(Duration::MAX), Some(Ok(Response::Value(5))));
        h.join().unwrap();
        // Already-done sets resolve immediately under the same timeout.
        let set = Arc::new(CompletionSet::new(1));
        set.fulfill(0, Response::Ok);
        let bt = BatchTicket { set };
        assert_eq!(
            bt.wait_timeout(Duration::MAX).unwrap().unwrap(),
            vec![Response::Ok]
        );
    }

    #[test]
    fn poll_each_surfaces_good_slots_beside_failures() {
        let set = Arc::new(CompletionSet::new(3));
        let bt = BatchTicket { set: set.clone() };
        set.fulfill(0, Response::Value(4));
        set.fail(1);
        assert_eq!(bt.poll_each(), None, "incomplete batch must not resolve");
        set.fulfill(2, Response::Missing);
        assert_eq!(
            bt.poll_each().unwrap(),
            vec![
                Ok(Response::Value(4)),
                Err(SubmitError::Shutdown),
                Ok(Response::Missing),
            ]
        );
        // The batch-level view still reports the poisoning error.
        assert_eq!(bt.poll(), Some(Err(SubmitError::Shutdown)));
    }

    #[test]
    fn batch_wait_timeout_returns_after_last_slot() {
        let set = Arc::new(CompletionSet::new(2));
        let bt = BatchTicket { set: set.clone() };
        assert_eq!(bt.len(), 2);
        assert!(bt.poll().is_none());
        set.fulfill(1, Response::Ok);
        assert!(bt.poll().is_none(), "half-done batch must not resolve");
        assert!(bt.wait_timeout(Duration::from_millis(5)).is_none());
        set.fulfill(0, Response::Ok);
        assert_eq!(
            bt.wait_timeout(Duration::from_millis(5)).unwrap().unwrap(),
            vec![Response::Ok, Response::Ok]
        );
    }
}
