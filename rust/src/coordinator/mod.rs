//! The serving-shaped L3 coordinator: a concurrent KV service built on
//! the sharded DHash ([`crate::dhash::ShardedDHash`]; `shards == 1`
//! degenerates to the paper's single `DHashMap`) with request batching,
//! worker routing, per-shard hash-collision attack detection through the
//! AOT analytics artifacts, and automatic *targeted* rebuild mitigation —
//! only the attacked shard migrates.
//!
//! Role in the reproduction: the paper motivates dynamic hash tables with
//! bursty / adversarial workloads reaching servers in batches (§1,
//! rationale 4). This module is that server. Ingest is completion-based:
//! clients submit through [`KvClient`] and resolve [`Ticket`]s against a
//! shared pre-allocated completion buffer — no per-call reply channel,
//! no shared submission lock:
//!
//! ```text
//!  KvClient ──submit──► lane 0 ─► Batcher 0 ─┐
//!   tickets  (lane =    lane 1 ─► Batcher 1 ─┼─► worker queue ─► KV
//!   ◄─slot     fixed      ⋮    (size/time)  ─┘     workers ──► DHashMap
//!    writes  pre-hash)  lane N-1                      │
//!                 │                                   └─ key samples ─┐
//!                 ▼                                                   ▼
//!            (optional batch pre-hash            Analytics thread: Engine
//!             via the Engine backend)            detect(sample) → chi²
//!                                                     │ chi² > threshold
//!                                                     ▼
//!                                              RebuildController
//!                                              (new seed → ht_rebuild)
//! ```
//!
//! Python never runs here: the analytics thread evaluates the detector
//! kernels through a [`crate::runtime::Engine`] backend — the pure-Rust
//! native engine by default, or the AOT PJRT artifacts under
//! `DHASH_ENGINE=pjrt` (feature `pjrt`).

mod batcher;
mod client;
mod controller;
mod detector;
mod server;

pub use batcher::{
    Batch, Batcher, BatcherConfig, OracleError, PreRoute, Request, Response, RouteOutcome,
};
pub use client::{BatchTicket, KvClient, SubmitError, Ticket};
pub use controller::{
    ControllerConfig, ElasticConfig, RebuildController, RebuildEvent, ResizeAction, ResizeEvent,
};
pub use detector::{DetectorConfig, KeySampler, SkewVerdict};
pub use server::{Coordinator, CoordinatorConfig, CoordinatorStats};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dhash::HashFn;
    use std::sync::Arc;
    use std::time::Duration;

    fn quick_config() -> CoordinatorConfig {
        CoordinatorConfig {
            nbuckets: 64,
            hash: HashFn::Seeded(7),
            shards: 1,
            lanes: 1,
            workers: 2,
            batcher: BatcherConfig {
                max_batch: 16,
                max_wait: Duration::from_micros(200),
                pre_route: PreRoute::Off,
            },
            detector: DetectorConfig {
                sample_capacity: 1024,
                period: Duration::from_millis(20),
                sigma: 8.0,
                min_samples: 256,
            },
            controller: ControllerConfig {
                cooldown: Duration::from_millis(50),
                rebuild_buckets: None,
            },
            elastic: None,
            // These tests use 64 buckets — fewer than the detector's 256
            // bins, which the folding histogram would misread as skew (the
            // detector assumes nbuckets >= nbins; see runtime::native).
            // The detector loop is covered by tests/coordinator_e2e.rs.
            enable_analytics: false,
        }
    }

    #[test]
    fn coordinator_serves_requests() {
        let c = Arc::new(Coordinator::start(quick_config()).unwrap());
        assert_eq!(c.execute(Request::put(1, 10)), Response::Ok);
        assert_eq!(c.execute(Request::get(1)), Response::Value(10));
        assert_eq!(c.execute(Request::del(1)), Response::Ok);
        assert_eq!(c.execute(Request::get(1)), Response::Missing);
        c.shutdown();
    }

    #[test]
    fn coordinator_batch_roundtrip() {
        let c = Arc::new(Coordinator::start(quick_config()).unwrap());
        let reqs: Vec<Request> = (0..100u64).map(|k| Request::put(k, k * 2)).collect();
        let resps = c.execute_many(reqs);
        assert!(resps.iter().all(|r| *r == Response::Ok));
        let gets: Vec<Request> = (0..100u64).map(Request::get).collect();
        let resps = c.execute_many(gets);
        for (k, r) in resps.iter().enumerate() {
            assert_eq!(*r, Response::Value(k as u64 * 2));
        }
        let stats = c.stats();
        assert!(stats.total_requests >= 200);
        assert!(stats.total_batches >= 1);
        c.shutdown();
    }

    #[test]
    fn put_overwrites() {
        let c = Arc::new(Coordinator::start(quick_config()).unwrap());
        c.execute(Request::put(5, 1));
        c.execute(Request::put(5, 2));
        assert_eq!(c.execute(Request::get(5)), Response::Value(2));
        c.shutdown();
    }

    #[test]
    fn manual_rebuild_keeps_data() {
        let c = Arc::new(Coordinator::start(quick_config()).unwrap());
        for k in 0..200u64 {
            c.execute(Request::put(k, k));
        }
        c.force_rebuild(128, HashFn::Seeded(0x1234)).unwrap();
        for k in 0..200u64 {
            assert_eq!(c.execute(Request::get(k)), Response::Value(k), "key {k}");
        }
        assert_eq!(c.stats().rebuilds, 1);
        c.shutdown();
    }

    #[test]
    fn zero_bucket_rebuild_is_refused_not_a_panic() {
        use crate::error::{KvError, ResizeError};
        let c = Arc::new(Coordinator::start(quick_config()).unwrap());
        c.execute(Request::put(7, 7));
        // A malformed geometry must come back as the typed wire error,
        // never reach the table allocator's assert.
        let err = c.force_rebuild(0, HashFn::Seeded(1)).unwrap_err();
        assert_eq!(err, KvError::Resize(ResizeError::BadGeometry));
        assert_eq!(err.code(), 0x14);
        assert_eq!(c.stats().rebuilds, 0);
        // The map is untouched and still serving.
        assert_eq!(c.execute(Request::get(7)), Response::Value(7));
        c.shutdown();
    }

    #[test]
    fn sharded_coordinator_serves_and_rebuilds() {
        let mut cfg = quick_config();
        cfg.shards = 4;
        let c = Arc::new(Coordinator::start(cfg).unwrap());
        for k in 0..400u64 {
            assert_eq!(c.execute(Request::put(k, k * 2)), Response::Ok);
        }
        // Staggered whole-map rebuild, then everything still resolves.
        assert!(c.force_rebuild(32, HashFn::Seeded(0x5a5a)).is_ok());
        for k in 0..400u64 {
            assert_eq!(c.execute(Request::get(k)), Response::Value(k * 2), "key {k}");
        }
        assert_eq!(c.stats().rebuilds, 1);
        assert_eq!(c.map().shards(), 4);
        c.shutdown();
    }

    #[test]
    fn failing_oracle_counts_engine_fallbacks_but_still_serves() {
        // Bucket pre-routing without analytics has no engine: every
        // batch's pre-route attempt must fail *visibly* (the old code
        // swallowed this in a `_ => {}` arm) while the batch is still
        // delivered and every request answered.
        let mut cfg = quick_config();
        cfg.shards = 4;
        cfg.batcher.pre_route = PreRoute::Bucket;
        assert!(!cfg.enable_analytics, "test needs the engine absent");
        let c = Arc::new(Coordinator::start(cfg).unwrap());
        let reqs: Vec<Request> = (0..200u64).map(|k| Request::put(k, k + 1)).collect();
        let resps = c.execute_many(reqs);
        assert!(resps.iter().all(|r| *r == Response::Ok));
        for k in 0..200u64 {
            assert_eq!(c.execute(Request::get(k)), Response::Value(k + 1));
        }
        c.shutdown();
        let st = c.stats();
        assert!(st.total_batches >= 1);
        assert_eq!(
            st.pre_route_fallbacks_engine, st.total_batches,
            "every batch must count its failed pre-route attempt"
        );
        assert_eq!(st.pre_routed_batches, 0);
        assert_eq!(st.pre_route_fallbacks_length, 0);
    }

    #[test]
    fn shard_order_pre_route_needs_no_engine() {
        // PreRoute::Shard uses the fixed selector: it must route (and
        // count as routed) even with analytics off.
        let mut cfg = quick_config();
        cfg.shards = 4;
        cfg.batcher.pre_route = PreRoute::Shard;
        let c = Arc::new(Coordinator::start(cfg).unwrap());
        let reqs: Vec<Request> = (0..200u64).map(|k| Request::put(k, k)).collect();
        assert!(c.execute_many(reqs).iter().all(|r| *r == Response::Ok));
        c.shutdown();
        let st = c.stats();
        assert!(st.total_batches >= 1);
        assert_eq!(st.pre_routed_batches, st.total_batches);
        assert_eq!(st.pre_route_fallbacks_engine, 0);
        assert_eq!(st.pre_route_fallbacks_length, 0);
    }

    #[test]
    fn non_pow2_shards_rejected() {
        let mut cfg = quick_config();
        cfg.shards = 6;
        assert!(Coordinator::start(cfg).is_err());
    }

    #[test]
    fn non_pow2_lanes_rejected() {
        let mut cfg = quick_config();
        cfg.lanes = 3;
        assert!(Coordinator::start(cfg).is_err());
    }

    #[test]
    fn elastic_without_analytics_rejected() {
        // The split/merge policy runs on the analytics thread; asking
        // for elasticity with analytics off would silently never resize.
        let mut cfg = quick_config();
        cfg.elastic = Some(ElasticConfig::default());
        assert!(!cfg.enable_analytics);
        assert!(Coordinator::start(cfg).is_err());
    }

    #[test]
    fn stats_surface_directory_shape() {
        let mut cfg = quick_config();
        cfg.shards = 4;
        let c = Arc::new(Coordinator::start(cfg).unwrap());
        let st = c.stats();
        assert_eq!(st.shards, 4);
        assert_eq!(st.epoch, 0);
        assert_eq!(st.splits, 0);
        assert_eq!(st.merges, 0);
        // A split driven directly through the map surfaces in the stats.
        {
            let g = crate::rcu::RcuThread::register();
            c.map()
                .split_shard(&g, 1, 64, crate::dhash::HashFn::Seeded(5))
                .unwrap();
            g.quiescent_state();
        }
        let st = c.stats();
        assert_eq!(st.shards, 5);
        assert_eq!(st.epoch, 1);
        assert_eq!(st.splits, 1);
        c.shutdown();
    }

    #[test]
    fn pipelined_tickets_resolve_in_submission_order() {
        // Submit everything up front, wait afterwards: the pipelined
        // shape execute_many can't express. Both lane configurations
        // must reassemble responses in submission order.
        for lanes in [1usize, 4] {
            let mut cfg = quick_config();
            cfg.lanes = lanes;
            // One worker: batches drain in queue order, so the same-key
            // op sequence below is answered in submission order (with
            // more workers, consecutive batches may interleave — per-key
            // FIFO is a lane/batch property, not a worker-pool one).
            cfg.workers = 1;
            let c = Arc::new(Coordinator::start(cfg).unwrap());
            let client = c.client();
            assert_eq!(client.lanes(), lanes);

            let puts: Vec<Request> = (0..200u64).map(|k| Request::put(k, k * 7)).collect();
            let pt = client.submit_batch(&puts).unwrap();
            assert_eq!(pt.len(), 200);
            assert!(pt.wait().unwrap().iter().all(|r| *r == Response::Ok));

            // Individual tickets, waited in reverse submission order —
            // completion order must not matter.
            let gets: Vec<_> = (0..200u64)
                .map(|k| client.submit(Request::get(k)).unwrap())
                .collect();
            for (k, t) in gets.iter().enumerate().rev() {
                assert_eq!(
                    t.wait().unwrap(),
                    Response::Value(k as u64 * 7),
                    "lanes={lanes} key {k}"
                );
            }

            // Batch of mixed ops: slot i always answers request i.
            let mixed = vec![
                Request::get(3),
                Request::del(3),
                Request::get(3),
                Request::put(3, 1),
            ];
            let resps = client.submit_batch(&mixed).unwrap().wait().unwrap();
            assert_eq!(
                resps,
                vec![
                    Response::Value(21),
                    Response::Ok,
                    Response::Missing,
                    Response::Ok
                ]
            );
            c.shutdown();
        }
    }

    #[test]
    fn ticket_poll_and_wait_timeout() {
        let c = Arc::new(Coordinator::start(quick_config()).unwrap());
        let client = c.client();
        let t = client.submit(Request::put(9, 90)).unwrap();
        // Poll until resolved (the service is live, so this terminates).
        let resp = loop {
            if let Some(r) = t.poll() {
                break r;
            }
            std::thread::yield_now();
        };
        assert_eq!(resp.unwrap(), Response::Ok);
        let t = client.submit(Request::get(9)).unwrap();
        assert_eq!(
            t.wait_timeout(Duration::from_secs(10)).unwrap().unwrap(),
            Response::Value(90)
        );
        c.shutdown();
    }

    #[test]
    fn submit_after_shutdown_errors() {
        let c = Arc::new(Coordinator::start(quick_config()).unwrap());
        let survivor = c.client(); // taken before the shutdown
        assert_eq!(
            survivor.submit(Request::put(1, 1)).unwrap().wait(),
            Ok(Response::Ok)
        );
        c.shutdown();
        // Clients taken after shutdown fail fast...
        assert_eq!(
            c.client().submit(Request::get(1)).err(),
            Some(SubmitError::Shutdown)
        );
        assert_eq!(
            c.client().submit_batch(&[Request::get(1)]).err(),
            Some(SubmitError::Shutdown)
        );
        // ...and a pre-shutdown client resolves to an error instead of
        // panicking or hanging (its send may land after the lane thread
        // exited, or be accepted and dropped — both are Shutdown).
        match survivor.submit(Request::get(1)) {
            Err(SubmitError::Shutdown) => {}
            Ok(t) => assert_eq!(t.wait(), Err(SubmitError::Shutdown)),
        }
    }

    #[test]
    fn shutdown_with_pending_tickets_resolves_them_all() {
        let mut cfg = quick_config();
        cfg.lanes = 2;
        let c = Arc::new(Coordinator::start(cfg).unwrap());
        let client = c.client();
        // Pile up work and shut down immediately: every ticket must
        // resolve — drained requests to a response, raced ones to
        // Shutdown — and none may hang.
        let tickets: Vec<_> = (0..500u64)
            .filter_map(|k| client.submit(Request::put(k, k)).ok())
            .collect();
        c.shutdown();
        for t in tickets {
            match t.wait_timeout(Duration::from_secs(30)) {
                Some(Ok(Response::Ok)) | Some(Err(SubmitError::Shutdown)) => {}
                other => panic!("pending ticket resolved oddly: {other:?}"),
            }
        }
    }

    #[test]
    fn concurrent_clients() {
        let c = Arc::new(Coordinator::start(quick_config()).unwrap());
        let mut hs = Vec::new();
        for t in 0..4u64 {
            let c2 = c.clone();
            hs.push(std::thread::spawn(move || {
                for i in 0..200u64 {
                    let k = t * 1000 + i;
                    assert_eq!(c2.execute(Request::put(k, k)), Response::Ok);
                    assert_eq!(c2.execute(Request::get(k)), Response::Value(k));
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(c.stats().total_requests, 4 * 400);
        c.shutdown();
    }
}
