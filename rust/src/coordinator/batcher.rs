//! Request types, the per-lane size/time batcher, and the multi-lane
//! ingest front end.
//!
//! Clients enqueue requests through [`IngestLanes`] — N independent
//! queues, the lane picked by the *fixed* shard-selector pre-hash of the
//! key ([`crate::dhash::shard_of`]), so a key always rides the same lane
//! (per-key FIFO into the batch stream; past that point, >1 worker may
//! still interleave consecutive batches, as ever) and a rebuild, which
//! only swaps per-shard [`HashFn`]s, can never re-route a key's lane.
//! Each lane is
//! drained by its own [`Batcher`] loop grouping entries into batches of
//! up to `max_batch`, waiting at most `max_wait` for stragglers — the
//! paper's rationale 4: update requests reach hash tables in batches,
//! and handling them as batches is where throughput comes from.
//!
//! [`HashFn`]: crate::dhash::HashFn

use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::client::{CompletionSet, SubmitError};
use crate::dhash::shard_of;

// The model types moved to the wire-protocol module (they ARE the wire
// vocabulary now); re-exported here so in-process users are unaffected.
pub use crate::net::proto::{Request, Response};

/// One enqueued request: the op plus its completion slot (index into the
/// submission's shared [`CompletionSet`]). Replaces the old
/// `(Request, Sender<(usize, Response)>, usize)` tuple — completion is a
/// slot write, not a channel send, and an entry dropped unexecuted
/// (shutdown, closed lane, dead worker) fails its slot so the ticket
/// resolves instead of hanging.
pub(crate) struct Entry {
    pub(crate) req: Request,
    set: Arc<CompletionSet>,
    slot: usize,
    executed: bool,
}

impl Entry {
    pub(crate) fn new(req: Request, set: Arc<CompletionSet>, slot: usize) -> Self {
        Self {
            req,
            set,
            slot,
            executed: false,
        }
    }

    pub(crate) fn key(&self) -> u64 {
        self.req.key()
    }

    /// Resolve this entry's completion slot with the worker's response.
    pub(crate) fn complete(mut self, resp: Response) {
        self.executed = true;
        self.set.fulfill(self.slot, resp);
    }
}

impl Drop for Entry {
    fn drop(&mut self) {
        // Dropped without executing: the entry sat in a lane or batch
        // that was discarded. Fail the slot so the ticket resolves.
        if !self.executed {
            self.set.fail(self.slot);
        }
    }
}

/// What travels down a lane: a request entry, or the shutdown marker.
/// `Close` (sent once per lane by `Coordinator::shutdown`) lets the lane
/// drain everything enqueued before it — mpsc order — and then exit,
/// even while clients still hold cloned senders.
pub(crate) enum LaneMsg {
    Req(Entry),
    Close,
}

/// The multi-lane ingest front end: one queue per lane, lane picked by
/// the fixed shard-selector pre-hash of the key. Clone-cheap — a
/// [`super::KvClient`] is a clone of this, so submission takes no shared
/// lock.
#[derive(Clone)]
pub(crate) struct IngestLanes {
    txs: Vec<Sender<LaneMsg>>,
}

impl IngestLanes {
    pub(crate) fn new(txs: Vec<Sender<LaneMsg>>) -> Self {
        assert!(
            txs.len().is_power_of_two(),
            "lane count must be a power of two, got {}",
            txs.len()
        );
        Self { txs }
    }

    /// A permanently-closed front end (what post-shutdown clients get):
    /// every dispatch fails with [`SubmitError::Shutdown`].
    pub(crate) fn closed() -> Self {
        Self { txs: Vec::new() }
    }

    pub(crate) fn nlanes(&self) -> usize {
        self.txs.len()
    }

    /// The lane `key` rides — [`shard_of`] over the lane count, the same
    /// fixed pre-hash the sharded map routes with, independent of every
    /// per-shard hash function.
    pub(crate) fn lane_of(&self, key: u64) -> usize {
        shard_of(key, self.txs.len())
    }

    /// Enqueue one entry on its key's lane.
    pub(crate) fn dispatch(&self, entry: Entry) -> Result<(), SubmitError> {
        if self.txs.is_empty() {
            // `entry` drops here, failing its completion slot.
            return Err(SubmitError::Shutdown);
        }
        self.txs[self.lane_of(entry.key())]
            .send(LaneMsg::Req(entry))
            .map_err(|_| SubmitError::Shutdown)
    }

    /// Send the shutdown marker down every lane. Entries enqueued before
    /// the marker still drain (per-lane FIFO); later ones are dropped by
    /// the exiting lane thread and resolve to [`SubmitError::Shutdown`].
    pub(crate) fn close(&self) {
        for tx in &self.txs {
            let _ = tx.send(LaneMsg::Close);
        }
    }
}

/// How (and whether) the batcher pre-sorts each batch by routing id
/// before handing it to a worker.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PreRoute {
    /// No pre-routing: batches stay in arrival order.
    Off,
    /// Sort by shard id only — the pre-`batch_hash_multi` behavior, kept
    /// as an ablation baseline. Needs no engine; a worker walks shards
    /// in order but touches each shard's buckets in arrival order.
    Shard,
    /// Sort by the full `(shard << 32) | bucket` composite id, computed
    /// by ONE vectorized [`crate::runtime::Engine::batch_hash_multi`]
    /// call over every shard's current geometry. Requires the engine
    /// (`enable_analytics`); without it every batch counts an
    /// engine-fallback and is delivered un-routed.
    Bucket,
}

impl PreRoute {
    /// Stable label for bench rows and logs.
    pub fn label(self) -> &'static str {
        match self {
            PreRoute::Off => "off",
            PreRoute::Shard => "shard",
            PreRoute::Bucket => "bucket",
        }
    }

    /// Numeric code for JSON bench rows (off=0, shard=1, bucket=2).
    pub fn code(self) -> u8 {
        match self {
            PreRoute::Off => 0,
            PreRoute::Shard => 1,
            PreRoute::Bucket => 2,
        }
    }
}

/// Why a routing oracle could not answer with usable ids. The batch is
/// still delivered (arrival order); the cause is surfaced through
/// [`RouteOutcome`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OracleError {
    /// The routing engine failed or was unavailable.
    Engine,
    /// The directory epoch changed while the ids were being computed:
    /// they describe a shard layout a split/merge has since retired, so
    /// sorting by them would order the batch for the wrong shards.
    Epoch,
}

impl std::fmt::Display for OracleError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            OracleError::Engine => write!(f, "routing engine failed or unavailable"),
            OracleError::Epoch => write!(f, "directory epoch moved mid-computation"),
        }
    }
}

impl std::error::Error for OracleError {}

/// What happened to one batch's pre-route attempt. Everything but
/// `Routed`/`Unrouted` is a *fallback*: the batch is still delivered in
/// arrival order, and the server counts the cause in
/// [`super::CoordinatorStats`] — routing degradation is never silent.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RouteOutcome {
    /// Entries were sorted by routing id.
    Routed,
    /// Pre-routing is off (or no oracle was supplied): arrival order by
    /// design, not a failure.
    Unrouted,
    /// The oracle answered with the wrong number of ids (exact-length
    /// guard: a short answer would drop entries and fail their
    /// completion slots, so the batch keeps arrival order instead).
    FallbackLength,
    /// The oracle's engine failed or was unavailable.
    FallbackEngine,
    /// A split/merge moved the directory epoch mid-computation
    /// ([`OracleError::Epoch`]); expected (and rare) while a resize is
    /// in flight, never silent.
    FallbackEpoch,
}

/// A batch handed to a KV worker.
pub struct Batch {
    pub(crate) entries: Vec<Entry>,
    /// Why (or why not) this batch was pre-routed.
    pub outcome: RouteOutcome,
}

impl Batch {
    /// True when entries are sorted by routing id so a worker touches
    /// shards and buckets in order (locality; the `batchhash` ablation
    /// and `shard_scale` pre-route axis measure the effect).
    pub fn pre_hashed(&self) -> bool {
        self.outcome == RouteOutcome::Routed
    }
}

#[derive(Clone, Debug)]
pub struct BatcherConfig {
    /// Max requests per batch.
    pub max_batch: usize,
    /// Max time to wait filling a batch once it has at least one entry.
    pub max_wait: Duration,
    /// Pre-route mode: sort each batch by routing id before it reaches a
    /// worker (see [`PreRoute`]).
    pub pre_route: PreRoute,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self {
            max_batch: 64,
            max_wait: Duration::from_micros(200),
            pre_route: PreRoute::Off,
        }
    }
}

/// The per-lane batching loop: runs on its own thread, draining one
/// lane's channel into batches. `route`'s oracle (when pre-routing)
/// maps keys to i64 routing ids via the lane's own engine.
pub struct Batcher {
    pub(crate) cfg: BatcherConfig,
}

impl Batcher {
    pub fn new(cfg: BatcherConfig) -> Self {
        Self { cfg }
    }

    /// Drain one batch's entries from a lane (BLOCKING — the caller must
    /// be in an RCU-offline state, see `server.rs`). Returns the batch
    /// plus whether the lane is still open; a closed lane (its senders
    /// dropped, or [`LaneMsg::Close`] received) still flushes whatever
    /// preceded the close — the drain-on-close guarantee.
    pub(crate) fn collect(&self, rx: &Receiver<LaneMsg>) -> (Vec<Entry>, bool) {
        // Block for the first entry.
        let first = match rx.recv() {
            Ok(LaneMsg::Req(e)) => e,
            Ok(LaneMsg::Close) | Err(_) => return (Vec::new(), false),
        };
        let mut entries = vec![first];
        let deadline = Instant::now() + self.cfg.max_wait;
        while entries.len() < self.cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(LaneMsg::Req(e)) => entries.push(e),
                Ok(LaneMsg::Close) => return (entries, false),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => return (entries, false),
            }
        }
        (entries, true)
    }

    /// Turn collected entries into a [`Batch`], pre-routing (sorting by
    /// the i64 routing id the oracle computes — composite
    /// `(shard, bucket)` ids under [`PreRoute::Bucket`]) when enabled.
    /// Runs RCU-online (the oracle reads the shards' current geometry).
    /// Every non-`Routed` path delivers the batch in arrival order and
    /// says why in [`Batch::outcome`] — no invisible fallback arm.
    pub(crate) fn route(
        &self,
        mut entries: Vec<Entry>,
        hash_ids: Option<&dyn Fn(&[u64]) -> Result<Vec<i64>, OracleError>>,
    ) -> Batch {
        let outcome = if self.cfg.pre_route == PreRoute::Off {
            RouteOutcome::Unrouted
        } else if let Some(hash_ids) = hash_ids {
            let keys: Vec<u64> = entries.iter().map(|e| e.key()).collect();
            match hash_ids(&keys) {
                // Exact-length guard: zipping a short id vector would
                // silently drop entries — and fail their completion
                // slots. Engines chunk internally now, so a mismatch is
                // an oracle bug; it is counted, not swallowed.
                Ok(ids) if ids.len() == entries.len() => {
                    // Stable sort by routing id (preserves per-key op
                    // order within the batch).
                    let mut tagged: Vec<(i64, Entry)> = ids.into_iter().zip(entries).collect();
                    tagged.sort_by_key(|(id, _)| *id);
                    entries = tagged.into_iter().map(|(_, e)| e).collect();
                    RouteOutcome::Routed
                }
                Ok(_) => RouteOutcome::FallbackLength,
                Err(OracleError::Engine) => RouteOutcome::FallbackEngine,
                Err(OracleError::Epoch) => RouteOutcome::FallbackEpoch,
            }
        } else {
            RouteOutcome::Unrouted
        };
        Batch { entries, outcome }
    }

    /// collect + route in one call (tests / simple drivers).
    #[cfg(test)]
    pub(crate) fn next_batch(
        &self,
        rx: &Receiver<LaneMsg>,
        hash_ids: Option<&dyn Fn(&[u64]) -> Result<Vec<i64>, OracleError>>,
    ) -> Option<Batch> {
        let (entries, _open) = self.collect(rx);
        if entries.is_empty() {
            None
        } else {
            Some(self.route(entries, hash_ids))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    /// Entries backed by one shared completion set, tuple-test style.
    fn entries(reqs: &[Request]) -> (Arc<CompletionSet>, Vec<Entry>) {
        let set = Arc::new(CompletionSet::new(reqs.len()));
        let es = reqs
            .iter()
            .enumerate()
            .map(|(i, r)| Entry::new(*r, set.clone(), i))
            .collect();
        (set, es)
    }

    #[test]
    fn batches_by_size() {
        let b = Batcher::new(BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_secs(1),
            pre_route: PreRoute::Off,
        });
        let (tx, rx) = channel();
        let reqs: Vec<Request> = (0..10u64).map(Request::get).collect();
        let (_set, es) = entries(&reqs);
        for e in es {
            tx.send(LaneMsg::Req(e)).unwrap();
        }
        let batch = b.next_batch(&rx, None).unwrap();
        assert_eq!(batch.entries.len(), 4);
        assert!(!batch.pre_hashed());
        let batch = b.next_batch(&rx, None).unwrap();
        assert_eq!(batch.entries.len(), 4);
    }

    #[test]
    fn batches_by_time() {
        let b = Batcher::new(BatcherConfig {
            max_batch: 1000,
            max_wait: Duration::from_millis(10),
            pre_route: PreRoute::Off,
        });
        let (tx, rx) = channel();
        let (_set, es) = entries(&[Request::get(1), Request::get(2)]);
        for e in es {
            tx.send(LaneMsg::Req(e)).unwrap();
        }
        let t0 = Instant::now();
        let batch = b.next_batch(&rx, None).unwrap();
        assert_eq!(batch.entries.len(), 2);
        assert!(t0.elapsed() >= Duration::from_millis(9));
    }

    #[test]
    fn closed_channel_ends() {
        let b = Batcher::new(BatcherConfig::default());
        let (tx, rx) = channel::<LaneMsg>();
        drop(tx);
        assert!(b.next_batch(&rx, None).is_none());
    }

    #[test]
    fn close_marker_flushes_then_ends() {
        // Drain-on-close: everything enqueued before Close comes out in
        // one final batch, then the lane reports closed.
        let b = Batcher::new(BatcherConfig {
            max_batch: 100,
            max_wait: Duration::from_secs(10), // would block forever sans Close
            pre_route: PreRoute::Off,
        });
        let (tx, rx) = channel();
        let reqs: Vec<Request> = (0..5u64).map(Request::get).collect();
        let (set, es) = entries(&reqs);
        for e in es {
            tx.send(LaneMsg::Req(e)).unwrap();
        }
        tx.send(LaneMsg::Close).unwrap();
        let t0 = Instant::now();
        let (batch, open) = b.collect(&rx);
        assert_eq!(batch.len(), 5, "entries before Close must drain");
        assert!(!open);
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "Close must cut the straggler wait short"
        );
        let (rest, open) = b.collect(&rx);
        assert!(rest.is_empty());
        assert!(!open);
        // Nothing was executed; dropping the batch fails every slot, so
        // the abandoned tickets resolve instead of hanging.
        drop(batch);
        for i in 0..5 {
            assert_eq!(set.poll_slot(i), Some(Err(SubmitError::Shutdown)));
        }
    }

    #[test]
    fn dropped_entries_fail_their_slots() {
        let (set, es) = entries(&[Request::get(1), Request::get(2)]);
        let mut es = es;
        es.pop().unwrap().complete(Response::Missing);
        drop(es); // entry 0 dropped unexecuted
        // Slot 0 failed, slot 1 fulfilled: the batch resolves (to an
        // error), never hangs.
        assert_eq!(set.poll_slot(0), Some(Err(SubmitError::Shutdown)));
        assert_eq!(set.poll_slot(1), Some(Ok(Response::Missing)));
    }

    #[test]
    fn lanes_route_by_fixed_selector_and_preserve_per_key_fifo() {
        let nlanes = 4usize;
        let mut txs = Vec::new();
        let mut rxs = Vec::new();
        for _ in 0..nlanes {
            let (tx, rx) = channel();
            txs.push(tx);
            rxs.push(rx);
        }
        let lanes = IngestLanes::new(txs);
        assert_eq!(lanes.nlanes(), nlanes);

        // Interleave several ops per key; values encode submission order.
        let keys = [3u64, 17, 3, 99, 17, 3, 99, 1024, 17];
        let reqs: Vec<Request> = keys
            .iter()
            .enumerate()
            .map(|(i, &k)| Request::put(k, i as u64))
            .collect();
        let (_set, es) = entries(&reqs);
        for e in es {
            lanes.dispatch(e).unwrap();
        }
        lanes.close();

        // Lane routing must match the fixed selector exactly...
        for (&k, r) in keys.iter().zip(&reqs) {
            assert_eq!(lanes.lane_of(k), shard_of(k, nlanes));
            assert_eq!(r.key(), k);
        }
        // ...and within each lane, each key's ops appear in submission
        // order (mpsc FIFO + sticky lane choice = per-key FIFO).
        let b = Batcher::new(BatcherConfig {
            max_batch: 64,
            max_wait: Duration::from_millis(1),
            pre_route: PreRoute::Off,
        });
        let mut last_seq: std::collections::HashMap<u64, u64> = Default::default();
        let mut seen = 0usize;
        for (lane, rx) in rxs.iter().enumerate() {
            loop {
                let (batch, open) = b.collect(rx);
                for e in &batch {
                    let (k, seq) = match e.req {
                        Request::Put { key, val } => (key, val),
                        _ => unreachable!(),
                    };
                    assert_eq!(lanes.lane_of(k), lane, "key {k} on the wrong lane");
                    if let Some(prev) = last_seq.insert(k, seq) {
                        assert!(prev < seq, "key {k}: op {seq} overtook {prev}");
                    }
                    seen += 1;
                }
                // Entries are dropped unexecuted here; that's fine, the
                // set is abandoned.
                drop(batch);
                if !open {
                    break;
                }
            }
        }
        assert_eq!(seen, keys.len(), "every entry must drain before Close");
    }

    #[test]
    fn closed_front_end_rejects_dispatch() {
        let lanes = IngestLanes::closed();
        let (set, mut es) = entries(&[Request::get(5)]);
        assert_eq!(
            lanes.dispatch(es.pop().unwrap()),
            Err(SubmitError::Shutdown)
        );
        // The rejected entry failed its slot on drop.
        assert_eq!(set.poll_slot(0), Some(Err(SubmitError::Shutdown)));
    }

    #[test]
    fn pre_hash_sorts_by_bucket() {
        let b = Batcher::new(BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(1),
            pre_route: PreRoute::Bucket,
        });
        let (tx, rx) = channel();
        let reqs: Vec<Request> = [9u64, 1, 5, 3].iter().map(|&k| Request::get(k)).collect();
        let (_set, es) = entries(&reqs);
        for e in es {
            tx.send(LaneMsg::Req(e)).unwrap();
        }
        // Fake hash: routing id = key (identity).
        let hash = |keys: &[u64]| Ok(keys.iter().map(|&k| k as i64).collect());
        let batch = b.next_batch(&rx, Some(&hash)).unwrap();
        assert!(batch.pre_hashed());
        assert_eq!(batch.outcome, RouteOutcome::Routed);
        let keys: Vec<u64> = batch.entries.iter().map(|e| e.key()).collect();
        assert_eq!(keys, vec![1, 3, 5, 9]);
    }

    #[test]
    fn composite_ids_sort_shard_major_bucket_minor() {
        // Composite (shard << 32) | bucket ids: the sort must group by
        // shard first, then bucket — full bucket-order locality, not the
        // old shard-id-only order.
        use crate::runtime::composite_route_id;
        let b = Batcher::new(BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(1),
            pre_route: PreRoute::Bucket,
        });
        let (tx, rx) = channel();
        // key encodes (shard, bucket) as shard*100 + bucket.
        let reqs: Vec<Request> = [102u64, 3, 105, 201, 7, 104]
            .iter()
            .map(|&k| Request::get(k))
            .collect();
        let (_set, es) = entries(&reqs);
        for e in es {
            tx.send(LaneMsg::Req(e)).unwrap();
        }
        let hash = |keys: &[u64]| {
            Ok(keys
                .iter()
                .map(|&k| composite_route_id((k / 100) as u32, (k % 100) as u32))
                .collect())
        };
        let batch = b.next_batch(&rx, Some(&hash)).unwrap();
        assert_eq!(batch.outcome, RouteOutcome::Routed);
        let keys: Vec<u64> = batch.entries.iter().map(|e| e.key()).collect();
        assert_eq!(keys, vec![3, 7, 102, 104, 105, 201]);
    }

    #[test]
    fn pre_route_with_small_kernel_batch_still_sorts() {
        // Regression for the silent-truncation bug: an engine whose
        // kernel batch (8) is smaller than max_batch (64) used to answer
        // with a truncated id vector, fail the exact-length check, and
        // deliver every batch un-routed through an invisible `_ => {}`
        // arm. batch_hash now chunks internally, so the real engine
        // pre-routes oversized batches.
        use crate::runtime::{Engine, HashKind, NativeEngine};
        let b = Batcher::new(BatcherConfig {
            max_batch: 64,
            max_wait: Duration::from_millis(1),
            pre_route: PreRoute::Bucket,
        });
        let (tx, rx) = channel();
        let reqs: Vec<Request> = (0..64u64).rev().map(Request::get).collect();
        let (_set, es) = entries(&reqs);
        for e in es {
            tx.send(LaneMsg::Req(e)).unwrap();
        }
        let engine = NativeEngine::with_shape(8, 4);
        assert!(b.cfg.max_batch > engine.batch());
        let oracle = |keys: &[u64]| -> Result<Vec<i64>, OracleError> {
            let ids = engine
                .batch_hash(keys, 1, 16, HashKind::Seeded)
                .map_err(|_| OracleError::Engine)?;
            Ok(ids.into_iter().map(i64::from).collect())
        };
        let batch = b.next_batch(&rx, Some(&oracle)).unwrap();
        assert!(
            batch.pre_hashed(),
            "a kernel batch below max_batch must no longer kill pre-routing"
        );
        assert_eq!(batch.outcome, RouteOutcome::Routed);
        assert_eq!(batch.entries.len(), 64);
        let ids: Vec<i64> = batch.entries.iter().map(|e| oracle(&[e.key()]).unwrap()[0]).collect();
        assert!(ids.windows(2).all(|w| w[0] <= w[1]), "not bucket-sorted");
    }

    #[test]
    fn pre_hash_with_short_id_vector_keeps_all_entries() {
        // A buggy oracle answering with fewer ids than keys must keep
        // every entry (a dropped entry would fail its completion slot),
        // fall back to arrival order, and report the length cause.
        let b = Batcher::new(BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(1),
            pre_route: PreRoute::Bucket,
        });
        let (tx, rx) = channel();
        let reqs: Vec<Request> = [9u64, 1, 5, 3].iter().map(|&k| Request::get(k)).collect();
        let (_set, es) = entries(&reqs);
        for e in es {
            tx.send(LaneMsg::Req(e)).unwrap();
        }
        let hash = |keys: &[u64]| Ok(keys.iter().take(2).map(|&k| k as i64).collect());
        let batch = b.next_batch(&rx, Some(&hash)).unwrap();
        assert!(!batch.pre_hashed());
        assert_eq!(batch.outcome, RouteOutcome::FallbackLength);
        assert_eq!(batch.entries.len(), 4);
        let keys: Vec<u64> = batch.entries.iter().map(|e| e.key()).collect();
        assert_eq!(keys, vec![9, 1, 5, 3], "fallback must keep arrival order");
    }

    #[test]
    fn failing_oracle_falls_back_with_engine_cause() {
        let b = Batcher::new(BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(1),
            pre_route: PreRoute::Bucket,
        });
        let (tx, rx) = channel();
        let (_set, es) = entries(&[Request::get(4), Request::get(2)]);
        for e in es {
            tx.send(LaneMsg::Req(e)).unwrap();
        }
        let hash = |_keys: &[u64]| -> Result<Vec<i64>, OracleError> { Err(OracleError::Engine) };
        let batch = b.next_batch(&rx, Some(&hash)).unwrap();
        assert!(!batch.pre_hashed());
        assert_eq!(batch.outcome, RouteOutcome::FallbackEngine);
        assert_eq!(batch.entries.len(), 2);
        // Off mode never consults the oracle: Unrouted, not a fallback.
        let b_off = Batcher::new(BatcherConfig::default());
        let (tx, rx) = channel();
        let (_set, es) = entries(&[Request::get(1)]);
        for e in es {
            tx.send(LaneMsg::Req(e)).unwrap();
        }
        let batch = b_off.next_batch(&rx, Some(&hash)).unwrap();
        assert_eq!(batch.outcome, RouteOutcome::Unrouted);
    }

    #[test]
    fn stale_epoch_falls_back_with_epoch_cause() {
        // An oracle that detects its ids were computed against a retired
        // directory (a split/merge landed mid-computation) must keep
        // every entry in arrival order and report the epoch cause — the
        // mid-resize analogue of the engine fallback.
        let b = Batcher::new(BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(1),
            pre_route: PreRoute::Bucket,
        });
        let (tx, rx) = channel();
        let reqs: Vec<Request> = [8u64, 2, 6].iter().map(|&k| Request::get(k)).collect();
        let (_set, es) = entries(&reqs);
        for e in es {
            tx.send(LaneMsg::Req(e)).unwrap();
        }
        let hash = |_keys: &[u64]| -> Result<Vec<i64>, OracleError> { Err(OracleError::Epoch) };
        let batch = b.next_batch(&rx, Some(&hash)).unwrap();
        assert!(!batch.pre_hashed());
        assert_eq!(batch.outcome, RouteOutcome::FallbackEpoch);
        let keys: Vec<u64> = batch.entries.iter().map(|e| e.key()).collect();
        assert_eq!(keys, vec![8, 2, 6], "fallback must keep arrival order");
    }

    #[test]
    fn pre_route_labels_and_codes() {
        assert_eq!(PreRoute::Off.label(), "off");
        assert_eq!(PreRoute::Shard.label(), "shard");
        assert_eq!(PreRoute::Bucket.label(), "bucket");
        assert_eq!(
            [PreRoute::Off.code(), PreRoute::Shard.code(), PreRoute::Bucket.code()],
            [0, 1, 2]
        );
    }
}
