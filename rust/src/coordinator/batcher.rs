//! Request types and the size/time batcher.
//!
//! Clients enqueue single requests; the batcher groups them into batches
//! of up to `max_batch`, waiting at most `max_wait` for stragglers — the
//! paper's rationale 4: update requests reach hash tables in batches, and
//! handling them as batches is where throughput comes from.

use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::time::{Duration, Instant};

/// A KV operation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Request {
    Get { key: u64 },
    Put { key: u64, val: u64 },
    Del { key: u64 },
}

impl Request {
    pub fn get(key: u64) -> Self {
        Request::Get { key }
    }

    pub fn put(key: u64, val: u64) -> Self {
        Request::Put { key, val }
    }

    pub fn del(key: u64) -> Self {
        Request::Del { key }
    }

    pub fn key(&self) -> u64 {
        match *self {
            Request::Get { key } | Request::Put { key, .. } | Request::Del { key } => key,
        }
    }
}

/// Reply to a [`Request`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Response {
    /// Put/Del succeeded.
    Ok,
    /// Get hit.
    Value(u64),
    /// Get/Del miss.
    Missing,
}

/// One enqueued request: the op, the client's reply channel, and the
/// client-side sequence number (so `execute_many` reassembles order).
pub(crate) type Entry = (Request, Sender<(usize, Response)>, usize);

/// A batch handed to a KV worker.
pub struct Batch {
    pub(crate) entries: Vec<Entry>,
    /// Set by the batcher when pre-hashing is enabled: entries are sorted
    /// by bucket id so a worker touches buckets in order (locality; the
    /// `batchhash` ablation measures the effect).
    pub pre_hashed: bool,
}

#[derive(Clone, Debug)]
pub struct BatcherConfig {
    /// Max requests per batch.
    pub max_batch: usize,
    /// Max time to wait filling a batch once it has at least one entry.
    pub max_wait: Duration,
    /// Sort each batch by routing id (requires analytics; no-op without
    /// it). Unsharded: bucket id via the AOT batch-hash artifact.
    /// Sharded: the fixed shard-selector id, so a worker walks shards in
    /// order (the per-shard hash may diverge after targeted mitigations).
    pub pre_hash: bool,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self {
            max_batch: 64,
            max_wait: Duration::from_micros(200),
            pre_hash: false,
        }
    }
}

/// The batching loop: runs on its own thread, draining the client channel
/// into batches. `hash_fn` (when pre-hashing) maps keys to bucket ids via
/// the analytics thread.
pub struct Batcher {
    pub(crate) cfg: BatcherConfig,
}

impl Batcher {
    pub fn new(cfg: BatcherConfig) -> Self {
        Self { cfg }
    }

    /// Drain one batch's entries from `rx` (BLOCKING — the caller must be
    /// in an RCU-offline state, see `server.rs`). Returns None when the
    /// channel is closed and empty (shutdown).
    pub(crate) fn collect(&self, rx: &Receiver<Entry>) -> Option<Vec<Entry>> {
        // Block for the first entry.
        let first = match rx.recv() {
            Ok(e) => e,
            Err(_) => return None,
        };
        let mut entries = vec![first];
        let deadline = Instant::now() + self.cfg.max_wait;
        while entries.len() < self.cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(e) => entries.push(e),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        Some(entries)
    }

    /// Turn collected entries into a [`Batch`], pre-routing (sorting by
    /// bucket id) when enabled and the hash oracle is available. Runs
    /// RCU-online (it may read the table's current hash function).
    pub(crate) fn route(
        &self,
        mut entries: Vec<Entry>,
        hash_ids: Option<&dyn Fn(&[u64]) -> Option<Vec<i32>>>,
    ) -> Batch {
        let mut pre_hashed = false;
        if self.cfg.pre_hash {
            if let Some(hash_ids) = hash_ids {
                let keys: Vec<u64> = entries.iter().map(|(r, _, _)| r.key()).collect();
                match hash_ids(&keys) {
                    // Engines may return fewer ids than keys (the kernel
                    // batch caps at `Engine::batch()`); zipping a short id
                    // vector would silently drop entries — and their reply
                    // channels. Pre-route only on an exact-length answer.
                    Some(ids) if ids.len() == entries.len() => {
                        // Stable sort by bucket id (preserves per-key op
                        // order within the batch).
                        let mut tagged: Vec<(i32, Entry)> =
                            ids.into_iter().zip(entries).collect();
                        tagged.sort_by_key(|(id, _)| *id);
                        entries = tagged.into_iter().map(|(_, e)| e).collect();
                        pre_hashed = true;
                    }
                    _ => {}
                }
            }
        }
        Batch {
            entries,
            pre_hashed,
        }
    }

    /// collect + route in one call (tests / simple drivers).
    #[cfg(test)]
    pub(crate) fn next_batch(
        &self,
        rx: &Receiver<Entry>,
        hash_ids: Option<&dyn Fn(&[u64]) -> Option<Vec<i32>>>,
    ) -> Option<Batch> {
        self.collect(rx).map(|e| self.route(e, hash_ids))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn batches_by_size() {
        let b = Batcher::new(BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_secs(1),
            pre_hash: false,
        });
        let (tx, rx) = channel();
        let (reply, _keep) = channel();
        for i in 0..10usize {
            tx.send((Request::get(i as u64), reply.clone(), i)).unwrap();
        }
        let batch = b.next_batch(&rx, None).unwrap();
        assert_eq!(batch.entries.len(), 4);
        assert!(!batch.pre_hashed);
        let batch = b.next_batch(&rx, None).unwrap();
        assert_eq!(batch.entries.len(), 4);
    }

    #[test]
    fn batches_by_time() {
        let b = Batcher::new(BatcherConfig {
            max_batch: 1000,
            max_wait: Duration::from_millis(10),
            pre_hash: false,
        });
        let (tx, rx) = channel();
        let (reply, _keep) = channel();
        tx.send((Request::get(1), reply.clone(), 0)).unwrap();
        tx.send((Request::get(2), reply.clone(), 1)).unwrap();
        let t0 = Instant::now();
        let batch = b.next_batch(&rx, None).unwrap();
        assert_eq!(batch.entries.len(), 2);
        assert!(t0.elapsed() >= Duration::from_millis(9));
    }

    #[test]
    fn closed_channel_ends() {
        let b = Batcher::new(BatcherConfig::default());
        let (tx, rx) = channel::<Entry>();
        drop(tx);
        assert!(b.next_batch(&rx, None).is_none());
    }

    #[test]
    fn pre_hash_sorts_by_bucket() {
        let b = Batcher::new(BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(1),
            pre_hash: true,
        });
        let (tx, rx) = channel();
        let (reply, _keep) = channel();
        for (i, k) in [9u64, 1, 5, 3].iter().enumerate() {
            tx.send((Request::get(*k), reply.clone(), i)).unwrap();
        }
        // Fake hash: bucket = key (identity).
        let hash = |keys: &[u64]| Some(keys.iter().map(|&k| k as i32).collect());
        let batch = b.next_batch(&rx, Some(&hash)).unwrap();
        assert!(batch.pre_hashed);
        let keys: Vec<u64> = batch.entries.iter().map(|(r, _, _)| r.key()).collect();
        assert_eq!(keys, vec![1, 3, 5, 9]);
    }

    #[test]
    fn pre_hash_with_short_id_vector_keeps_all_entries() {
        // An engine whose kernel batch is smaller than the request batch
        // returns fewer ids than keys; routing must keep every entry (a
        // dropped entry would orphan its reply channel) and fall back to
        // un-routed order.
        let b = Batcher::new(BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(1),
            pre_hash: true,
        });
        let (tx, rx) = channel();
        let (reply, _keep) = channel();
        for (i, k) in [9u64, 1, 5, 3].iter().enumerate() {
            tx.send((Request::get(*k), reply.clone(), i)).unwrap();
        }
        let hash = |keys: &[u64]| Some(keys.iter().take(2).map(|&k| k as i32).collect());
        let batch = b.next_batch(&rx, Some(&hash)).unwrap();
        assert!(!batch.pre_hashed);
        assert_eq!(batch.entries.len(), 4);
    }

    #[test]
    fn request_accessors() {
        assert_eq!(Request::put(3, 4).key(), 3);
        assert_eq!(Request::del(5).key(), 5);
        assert_eq!(Request::get(6).key(), 6);
    }
}
