//! `dhash-lint` — enforce the repo's concurrency contracts.
//!
//! ```text
//! cargo run --release --bin dhash-lint            # all rules
//! cargo run --release --bin dhash-lint -- --rule seqcst-budget
//! cargo run --release --bin dhash-lint -- --root /path/to/repo
//! cargo run --release --bin dhash-lint -- --list-rules
//! ```
//!
//! Exit status: 0 when clean, 1 when any rule fires, 2 on usage or
//! I/O errors. Diagnostics print one per line as
//! `file:line: [rule] message`. See `rust/src/lint/mod.rs` for the
//! rule inventory and DESIGN.md §Static analysis & sanitizers for the
//! annotation grammar.

use std::path::PathBuf;
use std::process::ExitCode;

use dhash::lint::{self, LintContext};

fn main() -> ExitCode {
    let mut root: Option<PathBuf> = None;
    let mut rules: Vec<String> = Vec::new();
    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--root" => match args.next() {
                Some(p) => root = Some(PathBuf::from(p)),
                None => return usage("--root needs a path"),
            },
            "--rule" => match args.next() {
                Some(r) => rules.push(r),
                None => return usage("--rule needs a rule name"),
            },
            "--list-rules" => {
                for (name, _) in lint::RULES {
                    println!("{name}");
                }
                return ExitCode::SUCCESS;
            }
            "--help" | "-h" => return usage(""),
            other => return usage(&format!("unknown argument '{other}'")),
        }
    }
    for r in &rules {
        if !lint::RULES.iter().any(|(name, _)| name == r) {
            return usage(&format!("unknown rule '{r}' (see --list-rules)"));
        }
    }

    let root = match root {
        Some(r) => r,
        None => {
            let cwd = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
            match LintContext::find_root(&cwd) {
                Some(r) => r,
                None => {
                    eprintln!(
                        "dhash-lint: could not find the repo root (a directory with rust/src \
                         and tools/seqcst_allowlist.txt) above {}",
                        cwd.display()
                    );
                    return ExitCode::from(2);
                }
            }
        }
    };

    let ctx = match LintContext::load(&root) {
        Ok(ctx) => ctx,
        Err(e) => {
            eprintln!("dhash-lint: failed to load {}: {e}", root.display());
            return ExitCode::from(2);
        }
    };

    let diags = lint::run(&ctx, &rules);
    for d in &diags {
        println!("{d}");
    }
    if diags.is_empty() {
        let which = if rules.is_empty() {
            lint::RULES.iter().map(|(n, _)| *n).collect::<Vec<_>>().join(", ")
        } else {
            rules.join(", ")
        };
        println!(
            "dhash-lint: OK — {} file(s) clean under rules: {which}",
            ctx.files.len()
        );
        ExitCode::SUCCESS
    } else {
        eprintln!("dhash-lint: {} finding(s)", diags.len());
        ExitCode::FAILURE
    }
}

fn usage(err: &str) -> ExitCode {
    if !err.is_empty() {
        eprintln!("dhash-lint: {err}");
    }
    eprintln!(
        "usage: dhash-lint [--root REPO_ROOT] [--rule NAME]... [--list-rules]\n\
         rules: safety, ord, seqcst-budget, hot, wire"
    );
    if err.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::from(2)
    }
}
