//! `dhash-lint` — enforce the repo's concurrency contracts.
//!
//! ```text
//! cargo run --release --bin dhash-lint            # all rules
//! cargo run --release --bin dhash-lint -- --rule seqcst-budget
//! cargo run --release --bin dhash-lint -- --rule lock-order,reclaim
//! cargo run --release --bin dhash-lint -- --format json
//! cargo run --release --bin dhash-lint -- --root /path/to/repo
//! cargo run --release --bin dhash-lint -- --list-rules
//! ```
//!
//! Exit status: 0 when clean, 1 when any rule fires, 2 on usage or
//! I/O errors. Diagnostics print one per line as
//! `file:line: [rule] message`; `--format json` emits a JSON array
//! with one `{file, line, rule, message}` object per finding (an
//! empty array when clean) for CI problem-matcher annotation. See
//! `rust/src/lint/mod.rs` for the rule inventory and DESIGN.md
//! §Static analysis & sanitizers for the annotation grammar.

use std::path::PathBuf;
use std::process::ExitCode;

use dhash::lint::{self, LintContext};

fn main() -> ExitCode {
    let mut root: Option<PathBuf> = None;
    let mut rules: Vec<String> = Vec::new();
    let mut json = false;
    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--root" => match args.next() {
                Some(p) => root = Some(PathBuf::from(p)),
                None => return usage("--root needs a path"),
            },
            "--rule" => match args.next() {
                // Comma-separated lists compose: `--rule a,b --rule c`.
                Some(r) => rules.extend(
                    r.split(',')
                        .map(str::trim)
                        .filter(|s| !s.is_empty())
                        .map(str::to_string),
                ),
                None => return usage("--rule needs a rule name"),
            },
            "--format" => match args.next().as_deref() {
                Some("json") => json = true,
                Some("text") => json = false,
                Some(other) => return usage(&format!("unknown format '{other}' (text|json)")),
                None => return usage("--format needs text|json"),
            },
            "--list-rules" => {
                for (name, _) in lint::RULES {
                    println!("{name}");
                }
                return ExitCode::SUCCESS;
            }
            "--help" | "-h" => return usage(""),
            other => return usage(&format!("unknown argument '{other}'")),
        }
    }
    for r in &rules {
        if !lint::RULES.iter().any(|(name, _)| name == r) {
            return usage(&format!("unknown rule '{r}' (see --list-rules)"));
        }
    }

    let root = match root {
        Some(r) => r,
        None => {
            let cwd = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
            match LintContext::find_root(&cwd) {
                Some(r) => r,
                None => {
                    eprintln!(
                        "dhash-lint: could not find the repo root (a directory with rust/src \
                         and tools/seqcst_allowlist.txt) above {}",
                        cwd.display()
                    );
                    return ExitCode::from(2);
                }
            }
        }
    };

    let ctx = match LintContext::load(&root) {
        Ok(ctx) => ctx,
        Err(e) => {
            eprintln!("dhash-lint: failed to load {}: {e}", root.display());
            return ExitCode::from(2);
        }
    };

    let diags = lint::run(&ctx, &rules);
    if json {
        println!("{}", render_json(&diags));
    } else {
        for d in &diags {
            println!("{d}");
        }
    }
    if diags.is_empty() {
        if !json {
            let which = if rules.is_empty() {
                lint::RULES.iter().map(|(n, _)| *n).collect::<Vec<_>>().join(", ")
            } else {
                rules.join(", ")
            };
            println!(
                "dhash-lint: OK — {} file(s) clean under rules: {which}",
                ctx.files.len()
            );
        }
        ExitCode::SUCCESS
    } else {
        eprintln!("dhash-lint: {} finding(s)", diags.len());
        ExitCode::FAILURE
    }
}

/// Hand-rolled JSON (no new deps): an array of one object per finding.
fn render_json(diags: &[lint::Diagnostic]) -> String {
    let mut out = String::from("[");
    for (i, d) in diags.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "\n  {{\"file\": \"{}\", \"line\": {}, \"rule\": \"{}\", \"message\": \"{}\"}}",
            escape_json(&d.file),
            d.line,
            escape_json(d.rule),
            escape_json(&d.message)
        ));
    }
    if !diags.is_empty() {
        out.push('\n');
    }
    out.push(']');
    out
}

fn escape_json(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn usage(err: &str) -> ExitCode {
    if !err.is_empty() {
        eprintln!("dhash-lint: {err}");
    }
    eprintln!(
        "usage: dhash-lint [--root REPO_ROOT] [--rule NAME[,NAME...]]... \
         [--format text|json] [--list-rules]\n\
         rules: safety, ord, seqcst-budget, hot, wire, lock-order, reclaim, publish"
    );
    if err.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::from(2)
    }
}
