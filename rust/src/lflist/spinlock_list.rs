//! A spinlock-serialized ordered list bucket.
//!
//! The simplest correct [`BucketSet`]: every operation takes a per-bucket
//! spinlock. This is the progress/engineering-effort end of the paper's
//! modularity trade-off (goal 2) — and, paired with the torture framework,
//! it demonstrates *why* the lock-free default wins under heavy load (the
//! `buckets` ablation bench).
//!
//! Two things remain concurrent even under the lock:
//! * reclamation — `find` results must stay valid after unlock, so
//!   deletion defers frees with `call_rcu`;
//! * the hazard-period protocol — a deleter holding `rebuild_cur` may OR
//!   `LOGICALLY_REMOVED` into *any* node's `next` word at any moment
//!   (§4.4), so traversals always untag link words and link updates use
//!   flag-preserving CAS rather than plain stores.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

use super::{untag, BucketSet, DeleteOutcome, Node, FLAG_MASK, LOGICALLY_REMOVED};

/// Minimal test-and-test-and-set spinlock (parking_lot is unavailable in
/// the offline build; a raw spinlock also matches the per-bucket locks of
/// the baselines we compare against).
pub(crate) struct SpinLock {
    locked: AtomicBool,
}

impl SpinLock {
    pub(crate) const fn new() -> Self {
        Self {
            locked: AtomicBool::new(false),
        }
    }

    #[inline]
    pub(crate) fn lock(&self) {
        loop {
            // ord: spinlock-bucket — bucket lock Acquire/Release; Release link stores for lock-free readers
            if !self.locked.swap(true, Ordering::Acquire) {
                return;
            }
            let mut spins = 0;
            // ord: spinlock-bucket — bucket lock Acquire/Release; Release link stores for lock-free readers
            while self.locked.load(Ordering::Relaxed) {
                spins += 1;
                if spins < 32 {
                    std::hint::spin_loop();
                } else {
                    // Mandatory on the single-core CI host: the holder
                    // cannot progress unless we yield.
                    std::thread::yield_now();
                }
            }
        }
    }

    #[inline]
    pub(crate) fn unlock(&self) {
        // ord: spinlock-bucket — bucket lock Acquire/Release; Release link stores for lock-free readers
        self.locked.store(false, Ordering::Release);
    }

    pub(crate) fn with<R>(&self, f: impl FnOnce() -> R) -> R {
        self.lock(); // lock: bucket
        let r = f();
        self.unlock();
        r
    }
}

/// Update the node-pointer part of a link word, preserving any flag bits a
/// concurrent hazard-period deleter may set between our load and store.
///
/// # Safety
/// `link` must point to a valid link word (bucket head or a live node's
/// `next` field) and the caller must hold the bucket lock (so no other
/// thread rewrites the *pointer* part concurrently).
///
/// Orderings: Acquire load observes a racing hazard-period mark; AcqRel
/// CAS publishes the pointed-to node's contents (insert's link step) with
/// its Release half — the same pairing as the lock-free list's link CAS.
unsafe fn set_link(link: &AtomicUsize, target: usize) {
    debug_assert_eq!(target & FLAG_MASK, 0);
    loop {
        // ord: spinlock-bucket — bucket lock Acquire/Release; Release link stores for lock-free readers
        let old = link.load(Ordering::Acquire);
        let new = target | (old & FLAG_MASK);
        // ord: spinlock-bucket — bucket lock Acquire/Release; Release link stores for lock-free readers
        if link
            .compare_exchange(old, new, Ordering::AcqRel, Ordering::Acquire)
            .is_ok()
        {
            return;
        }
    }
}

/// Spinlock-protected sorted singly-linked list over the shared [`Node`]
/// representation.
pub struct SpinlockList {
    lock: SpinLock,
    head: AtomicUsize,
}

// SAFETY: the chain is only restructured under `lock`; reclamation is RCU.
unsafe impl Send for SpinlockList {}
unsafe impl Sync for SpinlockList {}

impl SpinlockList {
    /// Unlink and lazily reclaim marked nodes; lock must be held.
    ///
    /// Marked nodes appear in a lock-based bucket only through the
    /// born-dead insert path (a hazard-period delete raced with a rebuild
    /// re-insert).
    ///
    /// # Safety
    /// The bucket lock must be held: links cannot change under the
    /// traversal, and unlinked nodes go to `call_rcu` exactly once.
    unsafe fn prune_locked(&self) {
        let mut pp: *const AtomicUsize = &self.head;
        loop {
            // ord: spinlock-bucket — bucket lock Acquire/Release; Release link stores for lock-free readers
            let cur = untag((*pp).load(Ordering::Acquire));
            if cur.is_null() {
                return;
            }
            let flags = (*cur).flags();
            if flags != 0 {
                // ord: spinlock-bucket — bucket lock Acquire/Release; Release link stores for lock-free readers
                let next = untag((*cur).next.load(Ordering::Acquire));
                set_link(&*pp, next as usize);
                if flags == LOGICALLY_REMOVED {
                    Node::defer_free(cur);
                }
                // IS_BEING_DISTRIBUTED nodes belong to the rebuilder.
            } else {
                pp = &(*cur).next;
            }
        }
    }
}

// SAFETY: trait contract upheld — RCU-deferred reclamation, synchronous
// unlink for distribution (everything is synchronous under the lock), and
// LOGICALLY_REMOVED preservation on insert / link updates (flag-preserving
// CAS everywhere).
unsafe impl BucketSet for SpinlockList {
    fn new() -> Self {
        Self {
            lock: SpinLock::new(),
            head: AtomicUsize::new(0),
        }
    }

    fn find(&self, key: u64) -> Option<&Node> {
        self.lock.with(|| { // lock: bucket
            // SAFETY: lock held, chain stable; refs stay valid past unlock
            // thanks to RCU-deferred reclamation.
            // Acquire link loads: the chain structure is lock-private,
            // but flag bits arrive from hazard-period deleters outside
            // the lock (AcqRel RMWs in Node::set_flag).
            unsafe {
                // ord: spinlock-bucket — bucket lock Acquire/Release; Release link stores for lock-free readers
                let mut cur = untag(self.head.load(Ordering::Acquire));
                while !cur.is_null() {
                    let k = (*cur).key;
                    if k == key {
                        return if (*cur).flags() == 0 {
                            Some(&*cur)
                        } else {
                            None
                        };
                    }
                    if k > key {
                        return None;
                    }
                    // ord: spinlock-bucket — bucket lock Acquire/Release; Release link stores for lock-free readers
                    cur = untag((*cur).next.load(Ordering::Acquire));
                }
                None
            }
        })
    }

    fn insert(&self, node: *mut Node) -> Result<(), *mut Node> {
        self.lock.with(|| { // lock: bucket
            // SAFETY: lock held.
            unsafe {
                self.prune_locked();
                let key = (*node).key;
                let mut pp: *const AtomicUsize = &self.head;
                // ord: spinlock-bucket — bucket lock Acquire/Release; Release link stores for lock-free readers
                let mut cur = untag((*pp).load(Ordering::Acquire));
                while !cur.is_null() && (*cur).key < key {
                    pp = &(*cur).next;
                    // ord: spinlock-bucket — bucket lock Acquire/Release; Release link stores for lock-free readers
                    cur = untag((*cur).next.load(Ordering::Acquire));
                }
                if !cur.is_null() && (*cur).key == key {
                    return Err(node);
                }
                // Point the node at its successor, preserving a racing
                // LOGICALLY_REMOVED (hazard-period delete, §4.4).
                loop {
                    // ord: spinlock-bucket — bucket lock Acquire/Release; Release link stores for lock-free readers
                    let old = (*node).next.load(Ordering::Acquire);
                    let new = cur as usize | (old & LOGICALLY_REMOVED);
                    // ord: spinlock-bucket — bucket lock Acquire/Release; Release link stores for lock-free readers
                    if (*node)
                        .next
                        .compare_exchange(old, new, Ordering::AcqRel, Ordering::Acquire)
                        .is_ok()
                    {
                        break;
                    }
                }
                set_link(&*pp, node as usize);
                Ok(())
            }
        })
    }

    fn delete(&self, key: u64, flag: usize) -> DeleteOutcome {
        self.lock.with(|| { // lock: bucket
            // SAFETY: lock held.
            unsafe {
                let mut pp: *const AtomicUsize = &self.head;
                loop {
                    // ord: spinlock-bucket — bucket lock Acquire/Release; Release link stores for lock-free readers
                    let cur = untag((*pp).load(Ordering::Acquire));
                    if cur.is_null() {
                        return DeleteOutcome::NotFound;
                    }
                    let k = (*cur).key;
                    if k == key {
                        if (*cur).flags() != 0 {
                            return DeleteOutcome::NotFound; // already dead
                        }
                        (*cur).set_flag(flag);
                        // ord: spinlock-bucket — bucket lock Acquire/Release; Release link stores for lock-free readers
                        let next = untag((*cur).next.load(Ordering::Acquire));
                        set_link(&*pp, next as usize);
                        if flag == LOGICALLY_REMOVED {
                            Node::defer_free(cur);
                        }
                        return DeleteOutcome::Deleted(cur);
                    }
                    if k > key {
                        return DeleteOutcome::NotFound;
                    }
                    pp = &(*cur).next;
                }
            }
        })
    }

    fn first(&self) -> Option<*mut Node> {
        self.lock.with(|| { // lock: bucket
            // SAFETY: lock held.
            unsafe {
                self.prune_locked();
                // ord: spinlock-bucket — bucket lock Acquire/Release; Release link stores for lock-free readers
                let h = untag(self.head.load(Ordering::Acquire));
                if h.is_null() {
                    None
                } else {
                    Some(h)
                }
            }
        })
    }

    fn len(&self) -> usize {
        self.collect().len()
    }

    fn collect(&self) -> Vec<(u64, u64)> {
        self.lock.with(|| { // lock: bucket
            let mut out = Vec::new();
            // SAFETY: lock held.
            unsafe {
                // ord: spinlock-bucket — bucket lock Acquire/Release; Release link stores for lock-free readers
                let mut cur = untag(self.head.load(Ordering::Acquire));
                while !cur.is_null() {
                    if (*cur).flags() == 0 {
                        // ord: node-val — value rides the link publish; later stores racy-by-spec
                        out.push(((*cur).key, (*cur).val.load(Ordering::Relaxed)));
                    }
                    // ord: spinlock-bucket — bucket lock Acquire/Release; Release link stores for lock-free readers
                    cur = untag((*cur).next.load(Ordering::Acquire));
                }
            }
            out
        })
    }

    fn drain_exclusive(&mut self) {
        // SAFETY: exclusive access.
        // Relaxed: exclusive access, no concurrent readers or writers.
        unsafe {
            // ord: unshared — exclusive access (&mut/Drop); no concurrent observers
            let mut cur = untag(self.head.load(Ordering::Relaxed));
            while !cur.is_null() {
                // ord: unshared — exclusive access (&mut/Drop); no concurrent observers
                let next = untag((*cur).next.load(Ordering::Relaxed));
                Node::free(cur);
                cur = next;
            }
            // ord: unshared — exclusive access (&mut/Drop); no concurrent observers
            self.head.store(0, Ordering::Relaxed);
        }
    }
}

impl Drop for SpinlockList {
    fn drop(&mut self) {
        self.drain_exclusive();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn spinlock_mutual_exclusion() {
        let lock = Arc::new(SpinLock::new());
        let counter = Arc::new(std::cell::UnsafeCell::new(0u64));
        struct Shared(Arc<std::cell::UnsafeCell<u64>>);
        // SAFETY: the spinlock under test serializes all access.
        unsafe impl Send for Shared {}
        let mut hs = Vec::new();
        for _ in 0..4 {
            let l = lock.clone();
            let c = Shared(counter.clone());
            hs.push(std::thread::spawn(move || {
                let c = c; // move the Send wrapper itself
                for _ in 0..10_000 {
                    // SAFETY: mutation only under the lock.
                    l.with(|| unsafe { *c.0.get() += 1 });
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        // SAFETY: all threads joined.
        assert_eq!(unsafe { *counter.get() }, 40_000);
    }

    #[test]
    fn ordered_unique() {
        let l = SpinlockList::new();
        for k in [9u64, 1, 5, 3, 7] {
            l.insert(Node::alloc(k, 0)).unwrap();
        }
        let ks: Vec<u64> = l.collect().into_iter().map(|(k, _)| k).collect();
        assert_eq!(ks, vec![1, 3, 5, 7, 9]);
        let dup = Node::alloc(5, 0);
        assert!(l.insert(dup).is_err());
        // SAFETY: rejected node unpublished.
        unsafe { Node::free(dup) };
    }
}
