//! A copy-on-write sorted-array bucket: wait-free lookups, lock-serialized
//! copy-on-write updates.
//!
//! The read-optimized end of the paper's modularity trade-off (goal 2):
//! `find` is a single atomic load plus a binary search over an immutable
//! snapshot — no retries, no CAS — making lookups *wait-free*. Updates
//! clone the (small, load-factor-sized) array under a per-bucket spinlock
//! and publish the new version with one atomic pointer swap; the old
//! version is reclaimed through RCU once pre-existing readers finish.
//!
//! The hazard-period protocol costs nothing here: flags live on the shared
//! [`Node`], not in the array, so a racing `LOGICALLY_REMOVED` from a
//! `rebuild_cur` deleter is never lost — `find` re-checks node flags after
//! the binary search.

use std::sync::atomic::{AtomicPtr, Ordering};

use super::spinlock_list::SpinLock;
use super::{BucketSet, DeleteOutcome, Node, LOGICALLY_REMOVED};
use crate::rcu::call_rcu;

type Version = Vec<*mut Node>;

/// Send wrapper so a retired version can cross to the reclaimer thread.
struct SendVersion(*mut Version);
// SAFETY: only touched after a grace period, exclusively.
unsafe impl Send for SendVersion {}

pub struct CowSortedArray {
    /// Current immutable version (sorted by key, unique keys). Never null.
    current: AtomicPtr<Version>,
    /// Serializes writers (copy-on-write).
    wlock: SpinLock,
}

// SAFETY: versions are immutable once published; retirement goes through
// RCU; writers are serialized by `wlock`.
unsafe impl Send for CowSortedArray {}
unsafe impl Sync for CowSortedArray {}

impl CowSortedArray {
    fn load_version(&self) -> &Version {
        // Acquire: pairs with the Release half of `publish`'s swap so the
        // new version's contents (the Vec it points to) are visible —
        // this one load is the entirety of the wait-free read path.
        // SAFETY: the version pointer is never null and, under the
        // caller's RCU read-side section, not yet reclaimed.
        // ord: cow-version — RCU version-pointer publish (Release store / Acquire load)
        unsafe { &*self.current.load(Ordering::Acquire) }
    }

    /// Publish `new`, retiring the old version through RCU. Lock held.
    fn publish(&self, new: Version) {
        let new_ptr = Box::into_raw(Box::new(new)); // reclaim: cow-version
        // AcqRel: Release publishes the new version's contents to
        // `load_version`'s Acquire; Acquire orders the retirement of the
        // old version after every read we did of it under the lock.
        // ord: cow-version — RCU version-pointer publish (Release store / Acquire load)
        let old = self.current.swap(new_ptr, Ordering::AcqRel);
        let retired = SendVersion(old);
        call_rcu(move || {
            let retired = retired; // move the wrapper, not the raw field
            // SAFETY: grace period elapsed; the Vec (not the nodes it
            // points to) is dropped.
            unsafe { drop(Box::from_raw(retired.0)) }; // reclaim: cow-version via rcu
        });
    }

    /// Copy the current version, dropping dead nodes (freeing born-dead
    /// ones).
    ///
    /// # Safety
    /// The writer lock must be held: no concurrent version swap, and a
    /// born-dead node freed here was never published to any reader.
    unsafe fn clean_copy(&self) -> Version {
        let cur = self.load_version();
        let mut out = Vec::with_capacity(cur.len() + 1);
        for &p in cur.iter() {
            let flags = (*p).flags();
            if flags == 0 {
                out.push(p);
            } else if flags == LOGICALLY_REMOVED {
                Node::defer_free(p);
            }
            // IS_BEING_DISTRIBUTED: dropped from the array, owned by the
            // rebuilder.
        }
        out
    }
}

// SAFETY: trait contract upheld (see module docs for the flag story).
unsafe impl BucketSet for CowSortedArray {
    fn new() -> Self {
        Self {
            // reclaim: cow-version — the initial (empty) version
            current: AtomicPtr::new(Box::into_raw(Box::new(Vec::new()))),
            wlock: SpinLock::new(),
        }
    }

    // lint: hot
    fn find(&self, key: u64) -> Option<&Node> {
        let v = self.load_version();
        // SAFETY: array entries are RCU-live nodes.
        match v.binary_search_by_key(&key, |&p| unsafe { (*p).key }) {
            Ok(i) => {
                // SAFETY: as above — the version array pins RCU-live nodes.
                let node = unsafe { &*v[i] };
                if node.flags() == 0 {
                    Some(node)
                } else {
                    None
                }
            }
            Err(_) => None,
        }
    }

    fn insert(&self, node: *mut Node) -> Result<(), *mut Node> {
        self.wlock.with(|| { // lock: bucket
            // SAFETY: writer lock held.
            unsafe {
                let mut next = self.clean_copy();
                let key = (*node).key;
                match next.binary_search_by_key(&key, |&p| (*p).key) {
                    Ok(_) => return Err(node),
                    Err(pos) => next.insert(pos, node),
                }
                // Clear the distribution flag as part of insertion (trait
                // contract); LOGICALLY_REMOVED, if a hazard-period deleter
                // raced us, is preserved and makes the node born-dead.
                (*node).clean_flag(super::IS_BEING_DISTRIBUTED);
                self.publish(next);
                Ok(())
            }
        })
    }

    fn delete(&self, key: u64, flag: usize) -> DeleteOutcome {
        self.wlock.with(|| { // lock: bucket
            // SAFETY: writer lock held.
            unsafe {
                let cur = self.load_version();
                let idx = match cur.binary_search_by_key(&key, |&p| (*p).key) {
                    Ok(i) => i,
                    Err(_) => return DeleteOutcome::NotFound,
                };
                let node = cur[idx];
                // Exactly-one-deleter: CAS the flag in from an unflagged
                // state (a plain OR could "succeed" on an already-dead
                // node).
                // AcqRel flag CAS: the Release half makes the mark (the
                // delete's linearization point) publish prior stores, the
                // same pairing as Node::set_flag.
                loop {
                    // ord: node-flag-rmw — mark RMW in the link word orders mark vs unlink
                    let old = (*node).next.load(Ordering::Acquire);
                    if old & super::FLAG_MASK != 0 {
                        return DeleteOutcome::NotFound; // already dead
                    }
                    // ord: node-flag-rmw — mark RMW in the link word orders mark vs unlink
                    if (*node)
                        .next
                        .compare_exchange(old, old | flag, Ordering::AcqRel, Ordering::Acquire)
                        .is_ok()
                    {
                        break;
                    }
                }
                let mut next = Vec::with_capacity(cur.len() - 1);
                next.extend_from_slice(&cur[..idx]);
                next.extend_from_slice(&cur[idx + 1..]);
                self.publish(next);
                if flag == LOGICALLY_REMOVED {
                    Node::defer_free(node);
                }
                DeleteOutcome::Deleted(node)
            }
        })
    }

    fn first(&self) -> Option<*mut Node> {
        let v = self.load_version();
        // SAFETY: RCU-live entries.
        v.iter()
            .copied()
            .find(|&p| unsafe { (*p).flags() } == 0)
    }

    fn len(&self) -> usize {
        let v = self.load_version();
        // SAFETY: RCU-live entries.
        v.iter().filter(|&&p| unsafe { (*p).flags() } == 0).count()
    }

    fn collect(&self) -> Vec<(u64, u64)> {
        let v = self.load_version();
        // SAFETY: RCU-live entries.
        // ord: node-val — value rides the link publish; later stores racy-by-spec
        v.iter()
            .filter(|&&p| unsafe { (*p).flags() } == 0)
            .map(|&p| unsafe { ((*p).key, (*p).val.load(Ordering::Relaxed)) })
            .collect()
    }

    fn drain_exclusive(&mut self) {
        // SAFETY: exclusive access; free nodes then the version vec.
        // Relaxed: `&mut self` excludes concurrent readers and writers.
        unsafe {
            // ord: unshared — exclusive access (&mut/Drop); no concurrent observers
            let v = self.current.load(Ordering::Relaxed);
            for &p in (*v).iter() {
                Node::free(p);
            }
            (*v).clear();
        }
    }
}

impl Drop for CowSortedArray {
    fn drop(&mut self) {
        self.drain_exclusive();
        // SAFETY: exclusive; reclaim the final (now empty) version.
        unsafe {
            // ord: unshared — exclusive access (&mut/Drop); no concurrent observers
            // reclaim: cow-version via exclusive
            drop(Box::from_raw(self.current.load(Ordering::Relaxed)));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rcu::{rcu_barrier, RcuThread};

    #[test]
    fn cow_basics() {
        let t = RcuThread::register();
        let b = CowSortedArray::new();
        for k in [3u64, 1, 2] {
            b.insert(Node::alloc(k, k * 2)).unwrap();
        }
        assert_eq!(b.len(), 3);
        assert_eq!(b.find(2).unwrap().val.load(Ordering::Relaxed), 4);
        assert!(matches!(
            b.delete(2, LOGICALLY_REMOVED),
            DeleteOutcome::Deleted(_)
        ));
        assert!(b.find(2).is_none());
        assert_eq!(b.collect(), vec![(1, 2), (3, 6)]);
        t.quiescent_state();
        rcu_barrier();
    }

    #[test]
    fn cow_old_snapshot_remains_readable() {
        // A reader's reference obtained before an update stays valid
        // (RCU): simulate by holding a &Node across a delete of another
        // key.
        let t = RcuThread::register();
        let b = CowSortedArray::new();
        b.insert(Node::alloc(1, 10)).unwrap();
        b.insert(Node::alloc(2, 20)).unwrap();
        let g = t.read_lock();
        let n1 = b.find(1).unwrap();
        b.delete(2, LOGICALLY_REMOVED);
        // n1 still readable.
        assert_eq!(n1.val.load(Ordering::Relaxed), 10);
        drop(g);
        t.quiescent_state();
        rcu_barrier();
    }
}
