//! Hash-bucket set algorithms (paper §4.1, Algorithm 1).
//!
//! The central type is [`Node`]: a key/value pair whose `next` word embeds
//! the paper's two flag bits in its least-significant bits:
//!
//! * [`LOGICALLY_REMOVED`] (bit 0) — the node was logically deleted by a
//!   user `delete`; whoever physically unlinks it reclaims it via
//!   `call_rcu`.
//! * [`IS_BEING_DISTRIBUTED`] (bit 1) — the node was logically removed by
//!   a *rebuild* operation; its memory is **not** reclaimed because the
//!   rebuild thread will re-insert the very same node into the new table.
//!
//! DHash is modular (paper goal 2): any set providing the Algorithm 1 API
//! can serve as the bucket implementation. That API is the [`BucketSet`]
//! trait here, and four implementations with different progress/perf
//! trade-offs ship with the crate:
//!
//! | impl | find | insert/delete | notes |
//! |---|---|---|---|
//! | [`MichaelList`] | lock-free | lock-free | the paper's default: RCU-based Michael list |
//! | [`SpinlockList`] | blocking | blocking | simplest correct baseline bucket |
//! | [`CowSortedArray`] | wait-free | blocking (copy-on-write) | read-optimized bucket |
//! | [`SplitOrderedList`] | lock-free | lock-free | recursive split-ordering: grows locally |

pub mod cow_array;
pub mod michael;
pub mod spinlock_list;
pub mod split_ordered;

pub use cow_array::CowSortedArray;
pub use michael::MichaelList;
pub use spinlock_list::SpinlockList;
pub use split_ordered::SplitOrderedList;

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

use crate::rcu::call_rcu;

/// Bit 0 of `Node::next`: logically deleted by a user delete operation.
pub const LOGICALLY_REMOVED: usize = 0b01;
/// Bit 1 of `Node::next`: logically removed by a rebuild operation, about
/// to be re-inserted into the new table (do not reclaim).
pub const IS_BEING_DISTRIBUTED: usize = 0b10;
/// Mask of both flag bits.
pub const FLAG_MASK: usize = 0b11;

/// A hash-table node. Allocated on insert, moved (not copied) between the
/// old and the new table by rebuild operations, reclaimed through RCU.
///
/// `next` is a tagged pointer: the two least-significant bits are the flag
/// bits above (pointers are at least word-aligned on every supported
/// architecture, as the paper notes in §4.1).
#[repr(C)]
pub struct Node {
    pub key: u64,
    pub val: AtomicU64,
    pub next: AtomicUsize,
}

/// Process-wide node allocation accounting, used by leak tests and the
/// coordinator's metrics endpoint. Relaxed counters: negligible cost next
/// to the allocator call they accompany.
pub mod mem_stats {
    use std::sync::atomic::{AtomicU64, Ordering};

    pub(super) static ALLOCS: AtomicU64 = AtomicU64::new(0);
    pub(super) static FREES: AtomicU64 = AtomicU64::new(0);

    /// (allocated, freed) node counts since process start.
    pub fn counts() -> (u64, u64) {
        // ord: stats-relaxed — monotonic counter, no ordering role
        (ALLOCS.load(Ordering::Relaxed), FREES.load(Ordering::Relaxed))
    }

    /// Nodes currently live (allocated - freed).
    ///
    /// The two counters are read with independent Relaxed loads, so a
    /// racing thread can bump both between our loads and make FREES
    /// appear ahead of ALLOCS (every free is preceded by an alloc, but
    /// not in *our* observation order). Saturate instead of wrapping to
    /// ~`u64::MAX`, which leak checks would misread as a huge leak.
    pub fn live() -> u64 {
        let (a, f) = counts();
        a.saturating_sub(f)
    }
}

impl Node {
    /// Heap-allocate a node. The caller owns the raw pointer until it is
    /// successfully published into a set.
    pub fn alloc(key: u64, val: u64) -> *mut Node {
        // ord: stats-relaxed — monotonic counter, no ordering role
        mem_stats::ALLOCS.fetch_add(1, Ordering::Relaxed);
        // reclaim: node — owned raw until published into a set
        Box::into_raw(Box::new(Node {
            key,
            val: AtomicU64::new(val),
            next: AtomicUsize::new(0),
        }))
    }

    /// Immediately free a node.
    ///
    /// # Safety
    /// `ptr` must be a unique, unpublished (or fully unlinked and
    /// grace-period-expired) node allocated by [`Node::alloc`].
    pub unsafe fn free(ptr: *mut Node) {
        // ord: stats-relaxed — monotonic counter, no ordering role
        mem_stats::FREES.fetch_add(1, Ordering::Relaxed);
        drop(Box::from_raw(ptr)); // reclaim: node via contract — caller proves unreachability (# Safety)
    }

    /// Free a node after a grace period (`call_rcu(htnp, free)` in the
    /// paper's pseudocode).
    ///
    /// # Safety
    /// `ptr` must be unlinked from every set (unreachable for new readers)
    /// and must not be freed by anyone else.
    pub unsafe fn defer_free(ptr: *mut Node) {
        let p = SendPtr(ptr);
        call_rcu(move || {
            let p = p; // move the whole wrapper (edition-2021 field capture)
            // SAFETY: a grace period has elapsed since the node became
            // unreachable, so no reader holds a reference.
            unsafe { Node::free(p.0) }
        });
    }

    /// The flag bits of this node's `next` word.
    ///
    /// Acquire: pairs with the AcqRel flag RMWs / link CASes so a reader
    /// that observes a mark also observes everything the marker published
    /// before it (DESIGN.md §Memory orderings, cluster L).
    #[inline(always)]
    pub fn flags(&self) -> usize {
        // ord: node-flag-rmw — mark RMW in the link word orders mark vs unlink
        self.next.load(Ordering::Acquire) & FLAG_MASK
    }

    /// True if a user delete has logically removed this node.
    #[inline(always)]
    pub fn logically_removed(&self) -> bool {
        self.flags() & LOGICALLY_REMOVED != 0
    }

    /// Atomically set flag bits (paper's `set_flag` helper, Alg. 2).
    /// Returns the *previous* flag bits.
    ///
    /// AcqRel: the Release half publishes the marker's prior stores with
    /// the mark (a logical delete is the linearization point of delete);
    /// the Acquire half orders the marker's subsequent unlink attempt
    /// after any link state it read here.
    #[inline]
    pub fn set_flag(&self, flag: usize) -> usize {
        // ord: node-flag-rmw — mark RMW in the link word orders mark vs unlink
        self.next.fetch_or(flag & FLAG_MASK, Ordering::AcqRel) & FLAG_MASK
    }

    /// Atomically clear flag bits (paper's `clean_flag` helper, Alg. 2).
    /// AcqRel for the same pairing as [`Node::set_flag`].
    #[inline]
    pub fn clean_flag(&self, flag: usize) {
        // ord: node-flag-rmw — mark RMW in the link word orders mark vs unlink
        self.next.fetch_and(!(flag & FLAG_MASK), Ordering::AcqRel);
    }
}

/// Untag a `next` word into a node pointer.
#[inline(always)]
pub(crate) fn untag(word: usize) -> *mut Node {
    (word & !FLAG_MASK) as *mut Node
}

/// The flag bits of a `next` word.
#[inline(always)]
pub(crate) fn tag_of(word: usize) -> usize {
    word & FLAG_MASK
}

/// Raw-pointer wrapper that may cross threads (for `call_rcu` closures).
pub(crate) struct SendPtr(pub *mut Node);
// SAFETY: the pointer's referent is only touched after a grace period, at
// which point the reclaimer thread has exclusive access.
unsafe impl Send for SendPtr {}

/// Outcome of a `BucketSet::delete`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeleteOutcome {
    /// The node with the matching key was logically removed by this call.
    /// The pointer is valid until the end of the current RCU read-side
    /// critical section; for `IS_BEING_DISTRIBUTED` deletes it is also
    /// guaranteed to be physically unlinked, so the rebuild thread may
    /// reuse it.
    Deleted(*mut Node),
    /// No live node with the key was present.
    NotFound,
}

/// The Algorithm 1 API: what a set algorithm must provide to serve as a
/// DHash bucket. All methods are called from within an RCU read-side
/// critical section (the `DHashMap` wrapper guarantees this).
///
/// # Safety
/// Implementations must guarantee:
/// * returned node pointers remain valid until the current grace period
///   expires;
/// * `delete(_, LOGICALLY_REMOVED)` reclaims through [`Node::defer_free`]
///   (never synchronously);
/// * `delete(_, IS_BEING_DISTRIBUTED)` physically unlinks before
///   returning and does **not** reclaim;
/// * `insert` preserves a concurrently-set `LOGICALLY_REMOVED` bit on the
///   node being inserted (the hazard-period delete race, §4.4), and
///   clears `IS_BEING_DISTRIBUTED` *atomically with* publishing the
///   node's new successor — a node arriving from a rebuild still carries
///   the bit, which keeps stale CASes (whose `prev` is this node) failing
///   until the node's next pointer really has moved to the new chain.
pub unsafe trait BucketSet: Send + Sync + 'static {
    fn new() -> Self
    where
        Self: Sized;

    /// Find the live node with `key` (paper: `lflist_find`).
    fn find(&self, key: u64) -> Option<&Node>;

    /// Insert an owned node (paper: `lflist_insert`). On duplicate key the
    /// node is returned to the caller via `Err` and the set is unchanged.
    fn insert(&self, node: *mut Node) -> Result<(), *mut Node>;

    /// Logically delete the node with `key`, tagging it with `flag`
    /// (paper: `lflist_delete`).
    fn delete(&self, key: u64, flag: usize) -> DeleteOutcome;

    /// First live node, used by the rebuild traversal (DHash distributes
    /// *head* nodes — §6.3 credits this for its rebuild speed).
    fn first(&self) -> Option<*mut Node>;

    /// Atomically take the first live node for distribution: equivalent
    /// to `first()` + `delete(key, IS_BEING_DISTRIBUTED)` but fused so
    /// implementations can do it in one traversal (§Perf opt 2: the
    /// rebuild loop is the paper's Fig 3 hot path).
    ///
    /// `publish` is invoked with each candidate BEFORE its logical
    /// delete — DHash points `rebuild_cur` at the node there, preserving
    /// the paper's hazard-period ordering (Alg. 3 line 26 precedes line
    /// 29): from the moment a node can be missing from the old table, it
    /// is reachable through `rebuild_cur`. Returns the unlinked,
    /// DIST-tagged node, or None when no live node remains.
    fn take_first_for_distribution(
        &self,
        publish: &mut dyn FnMut(*mut Node),
    ) -> Option<*mut Node> {
        loop {
            let p = self.first()?;
            publish(p);
            // SAFETY: RCU-live; key is immutable.
            let key = unsafe { (*p).key };
            match self.delete(key, IS_BEING_DISTRIBUTED) {
                DeleteOutcome::Deleted(n) => return Some(n),
                DeleteOutcome::NotFound => continue, // raced a deleter
            }
        }
    }

    /// Count of live nodes (O(n); test/diagnostic use).
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Snapshot of live `(key, value)` pairs in key order (test use).
    fn collect(&self) -> Vec<(u64, u64)>;

    /// Drain and free all nodes. Requires exclusive access (`&mut`), used
    /// by table teardown after a final grace period.
    fn drain_exclusive(&mut self);
}

#[cfg(test)]
mod conformance;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn node_flag_helpers() {
        let n = Node::alloc(7, 70);
        // SAFETY: exclusive access in this test.
        unsafe {
            assert_eq!((*n).flags(), 0);
            assert!(!(*n).logically_removed());
            let prev = (*n).set_flag(LOGICALLY_REMOVED);
            assert_eq!(prev, 0);
            assert!((*n).logically_removed());
            let prev = (*n).set_flag(IS_BEING_DISTRIBUTED);
            assert_eq!(prev, LOGICALLY_REMOVED);
            assert_eq!((*n).flags(), FLAG_MASK);
            (*n).clean_flag(IS_BEING_DISTRIBUTED);
            assert_eq!((*n).flags(), LOGICALLY_REMOVED);
            (*n).clean_flag(LOGICALLY_REMOVED);
            assert_eq!((*n).flags(), 0);
            Node::free(n);
        }
    }

    #[test]
    fn tagging_roundtrip() {
        let n = Node::alloc(1, 2);
        let word = n as usize | IS_BEING_DISTRIBUTED;
        assert_eq!(untag(word), n);
        assert_eq!(tag_of(word), IS_BEING_DISTRIBUTED);
        // SAFETY: exclusive access.
        unsafe { Node::free(n) };
    }

    #[test]
    fn nodes_are_word_aligned() {
        // The two flag bits require >= 4-byte alignment; Node contains
        // u64/atomics so alignment is 8 on all supported targets.
        assert!(std::mem::align_of::<Node>() >= 4);
        for _ in 0..64 {
            let n = Node::alloc(0, 0);
            assert_eq!(n as usize & FLAG_MASK, 0);
            // SAFETY: exclusive access.
            unsafe { Node::free(n) };
        }
    }
}
