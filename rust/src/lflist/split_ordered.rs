//! Recursive split-ordered list (Shalev & Shavit, "Split-Ordered Lists:
//! Lock-Free Extensible Hash Tables", JACM 2006) as a DHash bucket set.
//!
//! One lock-free ordered list holds every node of the bucket, sorted by
//! *split-order rank*: the bit-reversal of the node's pre-hashed key. A
//! growable directory of permanent *dummy* nodes (one per local bucket)
//! provides shortcuts into the list, so a lookup walks only its own
//! local bucket's chain segment. Doubling the local bucket count never
//! moves a node — in split order, bucket `b`'s segment simply splits in
//! two where the new dummy for bucket `b + size` lands — so a bucket's
//! effective fanout doubles *locally*, with no table-wide migration and
//! no blocking of concurrent lookup/insert/delete. Dummies are created
//! lazily and recursively (parent before child, where `parent(b)` clears
//! `b`'s top set bit), exactly as in the paper.
//!
//! Adaptations for DHash (this crate):
//!
//! * Nodes carry *user* keys (they migrate between tables through the
//!   rebuild protocol, which reads `Node::key`), so the split-order rank
//!   is derived, not stored: `rank(k) = (reverse(mix64(k)) | 1, k)`.
//!   The `| 1` makes regular ranks odd (dummy ranks are even, so the two
//!   namespaces never collide); the user key breaks ties between the two
//!   pre-hashes that differ only in their top bit, keeping the rank
//!   injective. `mix64` is a bijection, so adversarial user keys cannot
//!   collapse the split order the way raw bit-reversal would.
//! * A third link-word tag bit ([`DUMMY_TAG`]) marks pointers *to* dummy
//!   nodes. Dummies cannot be recognized by key (any u64 is a legal user
//!   key), and the bit travels with every link CAS for free. `Node`'s
//!   flag helpers mask [`FLAG_MASK`] only, so the bit survives them.
//! * RCU replaces the paper's memory management, like `michael.rs`:
//!   traversals revalidate `*prev == cur` and restart from their dummy on
//!   any mismatch, which also tolerates DHash's distributed-node reuse.
//! * Chains end at a permanent tail dummy with rank `(MAX, MAX)` instead
//!   of NULL (same reuse-ABA argument as `michael::SENTINEL_KEY`).
//!
//! The directory is a tagged-pointer [`GrowableArray`]: a segment tree
//! whose root word carries the tree height in its low bits, doubling by
//! CAS-installing a new root above the old one. Segments are only freed
//! under exclusive access (teardown), so readers never race a free.

use std::sync::atomic::{AtomicUsize, Ordering};

use super::{BucketSet, DeleteOutcome, Node, FLAG_MASK, IS_BEING_DISTRIBUTED, LOGICALLY_REMOVED};
use crate::util::rng::mix64;

/// Bit 2 of a link word: the pointed-to node is a dummy (bucket sentinel
/// or the tail). Requires 8-byte alignment; `Node` is `#[repr(C)]` with
/// u64/atomic fields, so this holds on every supported target.
pub const DUMMY_TAG: usize = 0b100;
const _: () = assert!(std::mem::align_of::<Node>() >= 8);

/// Every tag bit a split-order link word can carry.
const TAG_MASK3: usize = FLAG_MASK | DUMMY_TAG;

/// Untag a split-order link word into a node pointer (this module must
/// not use the crate-wide `untag`, which masks `FLAG_MASK` only).
#[inline(always)]
fn untag3(word: usize) -> *mut Node {
    (word & !TAG_MASK3) as *mut Node
}

/// Local growth threshold: double the local bucket count once the live
/// count exceeds `SPLIT_LOAD × size` (paper §4, MAX_LOAD).
const SPLIT_LOAD: usize = 2;
/// Cap on the local bucket count (keeps the directory height ≤ 3).
const MAX_LOCAL_BUCKETS: usize = 1 << 16;

/// Split-order rank of a regular node: bit-reversed pre-hash with the
/// low bit forced odd, tie-broken by the user key (see module docs).
#[inline(always)]
fn regular_rank(key: u64) -> (u64, u64) {
    (mix64(key).reverse_bits() | 1, key)
}

/// Split-order rank of bucket `b`'s dummy: plain bit reversal (even).
#[inline(always)]
fn dummy_rank(bucket: u64) -> (u64, u64) {
    (bucket.reverse_bits(), 0)
}

/// Rank of an in-list node, given the dummy tag its link word carried.
/// The tail dummy (key `u64::MAX`) ranks after everything.
#[inline(always)]
fn node_rank(is_dummy: bool, key: u64) -> (u64, u64) {
    if is_dummy {
        if key == u64::MAX {
            (u64::MAX, u64::MAX)
        } else {
            dummy_rank(key)
        }
    } else {
        regular_rank(key)
    }
}

/// Parent bucket in the recursive-split order: clear the top set bit.
#[inline(always)]
fn parent_bucket(b: usize) -> usize {
    debug_assert!(b > 0);
    b & !(1usize << (usize::BITS as usize - 1 - b.leading_zeros() as usize))
}

const SEG_LOG: usize = 6;
const SEG_SIZE: usize = 1 << SEG_LOG;
/// Low bits of the root word hold the tree height (1..); `Segment` is
/// 64-byte aligned so the pointer bits and the tag never overlap.
const HEIGHT_MASK: usize = SEG_SIZE - 1;

/// One node of the directory's segment tree: 64 child/leaf slots.
/// Leaf slots hold dummy-`Node` pointers, inner slots child segments.
#[repr(align(64))]
struct Segment {
    slots: [AtomicUsize; SEG_SIZE],
}

impl Segment {
    fn alloc() -> *mut Segment {
        // reclaim: split-seg — owned raw until published by a root/child CAS
        Box::into_raw(Box::new(Segment {
            slots: [0usize; SEG_SIZE].map(AtomicUsize::new),
        }))
    }
}

/// Free one segment.
///
/// # Safety
/// `seg` must be unreachable: either it lost its publish CAS (never
/// visible), or the caller holds exclusive access to the whole array.
unsafe fn free_segment(seg: *mut Segment) {
    drop(Box::from_raw(seg)); // reclaim: split-seg via contract — caller proves unreachability
}

/// Free a whole segment tree of the given height.
///
/// # Safety
/// Caller must hold exclusive access to the array (teardown path): no
/// concurrent reader may hold a reference into any segment.
unsafe fn free_tree(seg: *mut Segment, height: usize) {
    if height > 1 {
        for i in 0..SEG_SIZE {
            // ord: unshared — exclusive access (&mut/Drop); no concurrent observers
            let child = (*seg).slots[i].load(Ordering::Relaxed);
            if child != 0 {
                free_tree(child as *mut Segment, height - 1);
            }
        }
    }
    free_segment(seg);
}

/// The paper's tagged-pointer growable array: a segment tree reached
/// through a root word whose low bits carry the height. Growing doubles
/// capacity ×64 by installing a new root whose slot 0 is the old root;
/// existing slot references stay valid forever (segments move never).
struct GrowableArray {
    root: AtomicUsize,
}

impl GrowableArray {
    fn new() -> Self {
        Self {
            root: AtomicUsize::new(Segment::alloc() as usize | 1),
        }
    }

    /// The leaf slot for `index`, allocating path segments on demand.
    fn slot(&self, index: usize) -> &AtomicUsize {
        loop {
            // ord: split-dir — Acquire pairs with the Release root/child publish CAS
            let root = self.root.load(Ordering::Acquire);
            let height = root & HEIGHT_MASK;
            if SEG_LOG * height < usize::BITS as usize && index >> (SEG_LOG * height) != 0 {
                self.grow(root);
                continue;
            }
            let mut seg = (root & !HEIGHT_MASK) as *mut Segment;
            let mut level = height - 1;
            loop {
                let i = (index >> (SEG_LOG * level)) & (SEG_SIZE - 1);
                // SAFETY: segments reachable from the published root are
                // freed only under exclusive access (teardown), so `seg`
                // outlives this shared borrow of `self`.
                let slot = unsafe { &(*seg).slots[i] };
                if level == 0 {
                    return slot;
                }
                // ord: split-dir — Acquire pairs with the Release root/child publish CAS
                let mut child = slot.load(Ordering::Acquire);
                if child == 0 {
                    let fresh = Segment::alloc();
                    // ord: split-dir — Release publishes the zeroed segment to Acquire readers
                    match slot.compare_exchange(
                        0,
                        fresh as usize,
                        Ordering::AcqRel,
                        Ordering::Acquire,
                    ) {
                        Ok(_) => child = fresh as usize,
                        Err(cur) => {
                            // SAFETY: `fresh` lost the publish CAS; no
                            // other thread ever saw it.
                            // reclaim: split-seg via unpublished — lost the child CAS, never visible
                            unsafe { free_segment(fresh) };
                            child = cur;
                        }
                    }
                }
                seg = child as *mut Segment;
                level -= 1;
            }
        }
    }

    /// Install a new root one level above `root` (capacity ×64).
    fn grow(&self, root: usize) {
        let height = root & HEIGHT_MASK;
        let fresh = Segment::alloc();
        // SAFETY: `fresh` is exclusively ours until the CAS publishes it.
        // ord: split-dir — plain store; the Release root CAS below publishes it
        unsafe { (*fresh).slots[0].store(root & !HEIGHT_MASK, Ordering::Relaxed) };
        // ord: split-dir — Release publishes the taller tree to Acquire readers
        if self
            .root
            .compare_exchange(
                root,
                fresh as usize | (height + 1),
                Ordering::AcqRel,
                Ordering::Acquire,
            )
            .is_err()
        {
            // SAFETY: `fresh` lost the root CAS; never visible.
            // reclaim: split-seg via unpublished — lost the root CAS, never visible
            unsafe { free_segment(fresh) };
        }
    }

    /// Free every segment. Idempotent; leaves the array unusable.
    fn teardown(&mut self) {
        let root = *self.root.get_mut();
        if root == 0 {
            return;
        }
        // SAFETY: `&mut self` proves no concurrent reader exists.
        unsafe { free_tree((root & !HEIGHT_MASK) as *mut Segment, root & HEIGHT_MASK) };
        *self.root.get_mut() = 0;
    }
}

impl Drop for GrowableArray {
    fn drop(&mut self) {
        self.teardown();
    }
}

/// Position returned by the searches: `cur` is the first node with
/// rank ≥ the target (never null — chains end at the tail dummy),
/// `prev` the link word pointing at it.
struct Pos {
    prev: *const AtomicUsize,
    cur: *mut Node,
    /// [`DUMMY_TAG`] if `cur` is a dummy, else 0 (as read from `*prev`).
    cur_tag: usize,
    /// Unmarked `next` word of `cur` (carries the successor's dummy tag).
    next: usize,
}

impl Pos {
    #[inline(always)]
    fn found(&self, key: u64) -> bool {
        // SAFETY: `cur` is a list node kept alive by RCU. Dummies and the
        // tail are never a match: the tag bit discriminates them.
        self.cur_tag == 0 && unsafe { (*self.cur).key } == key
    }
}

/// The recursive split-ordered list. One instance per (outer) hash
/// bucket; each instance grows its *local* fanout independently.
pub struct SplitOrderedList {
    /// Bucket-0 dummy: the permanent physical head of the split-order
    /// chain. Written once in `new`, never relinked.
    head: *mut Node,
    /// Lazily populated dummy directory: slot `b` caches bucket `b`'s
    /// dummy once it is linked (0 = not yet initialized).
    dir: GrowableArray,
    /// Current local bucket count (power of two, grows by doubling).
    size: AtomicUsize,
    /// Approximate live regular-node count (growth heuristic only; may
    /// over-count born-dead inserts, never the other direction).
    count: AtomicUsize,
}

// SAFETY: all mutation happens through atomics; `head` is written once
// before the value is shared; reclamation goes through RCU / teardown.
unsafe impl Send for SplitOrderedList {}
unsafe impl Sync for SplitOrderedList {}

impl SplitOrderedList {
    fn new_with_sentinels() -> Self {
        let tail = Node::alloc(u64::MAX, 0);
        let head = Node::alloc(0, 0);
        // SAFETY: both nodes are exclusively owned until `Self` escapes.
        // ord: split-link — pre-publication store; Self is not shared yet
        unsafe { (*head).next.store(tail as usize | DUMMY_TAG, Ordering::Relaxed) };
        Self {
            head,
            dir: GrowableArray::new(),
            size: AtomicUsize::new(1),
            count: AtomicUsize::new(0),
        }
    }

    /// Current local bucket count (power of two; diagnostic).
    pub fn local_size(&self) -> usize {
        // ord: split-size — growth heuristic; any power-of-two snapshot routes correctly
        self.size.load(Ordering::Relaxed)
    }

    /// The local bucket `key` routes to under the current `size`. Stale
    /// reads are safe either way: a smaller value routes to an ancestor
    /// dummy (longer walk), a larger one initializes the deeper dummy.
    #[inline(always)]
    fn bucket_of(&self, key: u64) -> usize {
        // ord: split-size — growth heuristic; any power-of-two snapshot routes correctly
        (mix64(key) as usize) & (self.size.load(Ordering::Relaxed) - 1)
    }

    /// Link word to start a search for `key` from: its bucket's dummy.
    fn bucket_head(&self, key: u64) -> *const AtomicUsize {
        let d = self.dummy_for(self.bucket_of(key));
        // SAFETY: dummies are permanent; the link word outlives `self`'s
        // shared borrows.
        unsafe { &(*d).next as *const AtomicUsize }
    }

    /// Bucket `b`'s dummy node, initializing it (and, recursively, its
    /// ancestors) on first use.
    fn dummy_for(&self, b: usize) -> *mut Node {
        if b == 0 {
            return self.head;
        }
        let slot = self.dir.slot(b);
        // ord: split-dir — Acquire pairs with the Release slot publish in init_bucket
        let p = slot.load(Ordering::Acquire);
        if p != 0 {
            return p as *mut Node;
        }
        self.init_bucket(b, slot)
    }

    /// Slow path of [`Self::dummy_for`]: link a dummy for bucket `b`
    /// into the chain (after its parent dummy) and cache it in `slot`.
    /// Exactly one dummy per rank can link — racers find the winner's
    /// node via the rank-equality check and free their own candidate.
    #[cold]
    fn init_bucket(&self, b: usize, slot: &AtomicUsize) -> *mut Node {
        let parent = self.dummy_for(parent_bucket(b));
        let rank = dummy_rank(b as u64);
        let d = Node::alloc(b as u64, 0);
        let published = loop {
            // SAFETY: parent dummies are permanent; the link word stays
            // valid for the duration of the call.
            let pos = self.search(unsafe { &(*parent).next }, rank);
            // SAFETY: `pos.cur` is RCU-live; `key` is immutable.
            if pos.cur_tag != 0 && unsafe { (*pos.cur).key } == b as u64 {
                break pos.cur; // another thread linked this bucket's dummy
            }
            // Our dummy is unpublished and dummies are never marked, so a
            // plain store suffices; the link CAS publishes it.
            // SAFETY: we own `d` until the link CAS below succeeds.
            // ord: split-link — successor in place before the Release link publish
            unsafe { (*d).next.store(pos.cur as usize | pos.cur_tag, Ordering::Relaxed) };
            // SAFETY: `pos.prev` is a live link word under RCU.
            if unsafe {
                // ord: split-link — link-word publish/traversal contract (split-order flavor)
                (*pos.prev)
                    .compare_exchange(
                        pos.cur as usize | pos.cur_tag,
                        d as usize | DUMMY_TAG,
                        Ordering::AcqRel,
                        Ordering::Acquire,
                    )
                    .is_ok()
            } {
                break d;
            }
        };
        if published != d {
            // SAFETY: our candidate lost the init race; it was never
            // linked, so no other thread can hold a reference.
            // reclaim: node via unpublished — lost the dummy-init race, never visible
            unsafe { Node::free(d) };
        }
        // Cache the in-list dummy. Racers computed the same pointer, so a
        // lost CAS means the identical value is already published.
        // ord: split-dir — Release publishes the dummy to Acquire readers of the slot
        let _ = slot.compare_exchange(
            0,
            published as usize,
            Ordering::AcqRel,
            Ordering::Acquire,
        );
        published
    }

    /// Michael-style search in split-order rank space, starting at
    /// `start` (a dummy's link word). Returns the position of the first
    /// node with rank ≥ `rank`, physically unlinking every marked node
    /// encountered (only regular nodes are ever marked). Same ordering
    /// contract as `michael::search`: Acquire loads, AcqRel link CAS,
    /// `*prev == cur` revalidation, restart from `start` on mismatch.
    fn search(&self, start: *const AtomicUsize, rank: (u64, u64)) -> Pos {
        'retry: loop {
            let mut prev = start;
            // SAFETY: `start` is the link word of a permanent dummy;
            // subsequent `prev` values are link words of RCU-live nodes.
            // ord: split-link — link-word publish/traversal contract (split-order flavor)
            let w = unsafe { (*prev).load(Ordering::Acquire) };
            let mut cur = untag3(w);
            let mut cur_tag = w & DUMMY_TAG;
            loop {
                // `cur` is never null: chains end at the tail dummy.
                // SAFETY: RCU keeps `cur` alive.
                // ord: split-link — link-word publish/traversal contract (split-order flavor)
                let next_t = unsafe { (*cur).next.load(Ordering::Acquire) };
                // SAFETY: `prev` is the starting dummy's link word or a
                // link word reached by this traversal; RCU keeps either
                // alive for the caller's read-side critical section.
                // ord: split-link — link-word publish/traversal contract (split-order flavor)
                if unsafe { (*prev).load(Ordering::Acquire) } != (cur as usize | cur_tag) {
                    continue 'retry;
                }
                if next_t & FLAG_MASK != 0 {
                    // Marked: unlink before moving past (§4.4 rule). The
                    // republished word keeps the successor's dummy tag.
                    let next = next_t & !FLAG_MASK;
                    // SAFETY: `prev` stays a live link word (RCU); the
                    // CAS only republishes values read from it.
                    if unsafe {
                        // ord: split-link — link-word publish/traversal contract (split-order flavor)
                        (*prev)
                            .compare_exchange(
                                cur as usize | cur_tag,
                                next,
                                Ordering::AcqRel,
                                Ordering::Acquire,
                            )
                            .is_ok()
                    } {
                        if next_t & FLAG_MASK == LOGICALLY_REMOVED {
                            // SAFETY: we won the unlink CAS; the node is
                            // unreachable for new readers and ours to
                            // reclaim after a grace period.
                            unsafe { Node::defer_free(cur) };
                        }
                        cur = untag3(next);
                        cur_tag = next & DUMMY_TAG;
                        continue;
                    }
                    continue 'retry;
                }
                // SAFETY: RCU keeps `cur` alive; `key` is immutable.
                let crank = node_rank(cur_tag != 0, unsafe { (*cur).key });
                if crank >= rank {
                    return Pos {
                        prev,
                        cur,
                        cur_tag,
                        next: next_t,
                    };
                }
                // SAFETY: `cur` stays valid; taking the address of its
                // atomic `next` field is safe under RCU.
                prev = unsafe { &(*cur).next as *const AtomicUsize };
                cur = untag3(next_t);
                cur_tag = next_t & DUMMY_TAG;
            }
        }
    }

    /// Like [`Self::search`], but stops at the first *live regular* node
    /// in split order (or the tail, when none remains). Used by the
    /// distribution pop: rebuild does not care about key order, only
    /// about taking some live head cheaply.
    fn search_first_live(&self) -> Pos {
        'retry: loop {
            // SAFETY: the head dummy is permanent.
            let mut prev = unsafe { &(*self.head).next as *const AtomicUsize };
            // ord: split-link — link-word publish/traversal contract (split-order flavor)
            let w = unsafe { (*prev).load(Ordering::Acquire) };
            let mut cur = untag3(w);
            let mut cur_tag = w & DUMMY_TAG;
            loop {
                // SAFETY: RCU keeps `cur` alive.
                // ord: split-link — link-word publish/traversal contract (split-order flavor)
                let next_t = unsafe { (*cur).next.load(Ordering::Acquire) };
                // SAFETY: as in `search`.
                // ord: split-link — link-word publish/traversal contract (split-order flavor)
                if unsafe { (*prev).load(Ordering::Acquire) } != (cur as usize | cur_tag) {
                    continue 'retry;
                }
                if next_t & FLAG_MASK != 0 {
                    let next = next_t & !FLAG_MASK;
                    // SAFETY: `prev` stays a live link word (RCU); the
                    // CAS only republishes values read from it.
                    if unsafe {
                        // ord: split-link — link-word publish/traversal contract (split-order flavor)
                        (*prev)
                            .compare_exchange(
                                cur as usize | cur_tag,
                                next,
                                Ordering::AcqRel,
                                Ordering::Acquire,
                            )
                            .is_ok()
                    } {
                        if next_t & FLAG_MASK == LOGICALLY_REMOVED {
                            // SAFETY: we won the unlink CAS; ours to
                            // reclaim after a grace period.
                            unsafe { Node::defer_free(cur) };
                        }
                        cur = untag3(next);
                        cur_tag = next & DUMMY_TAG;
                        continue;
                    }
                    continue 'retry;
                }
                if cur_tag == 0 {
                    return Pos {
                        prev,
                        cur,
                        cur_tag,
                        next: next_t,
                    };
                }
                // SAFETY: RCU keeps `cur` alive; `key` is immutable.
                if unsafe { (*cur).key } == u64::MAX {
                    // The tail: no live regular node anywhere.
                    return Pos {
                        prev,
                        cur,
                        cur_tag,
                        next: next_t,
                    };
                }
                // An interior dummy: walk through it.
                // SAFETY: as in `search`.
                prev = unsafe { &(*cur).next as *const AtomicUsize };
                cur = untag3(next_t);
                cur_tag = next_t & DUMMY_TAG;
            }
        }
    }

    /// Double the local bucket count once the live count crosses the
    /// load threshold. Dummies for the new buckets appear lazily.
    fn maybe_grow(&self, live: usize) {
        // ord: split-size — growth heuristic; any power-of-two snapshot routes correctly
        let s = self.size.load(Ordering::Relaxed);
        if live > s.saturating_mul(SPLIT_LOAD) && s < MAX_LOCAL_BUCKETS {
            // A lost CAS means another inserter already doubled — done.
            // ord: split-size — growth heuristic; any power-of-two snapshot routes correctly
            let _ = self
                .size
                .compare_exchange(s, s * 2, Ordering::Relaxed, Ordering::Relaxed);
        }
    }

    fn insert_node(&self, node: *mut Node) -> Result<(), *mut Node> {
        // SAFETY: caller owns `node` (unpublished here); `key` immutable.
        let key = unsafe { (*node).key };
        debug_assert_ne!(key, u64::MAX, "u64::MAX keys are reserved");
        let rank = regular_rank(key);
        loop {
            let pos = self.search(self.bucket_head(key), rank);
            if pos.found(key) {
                return Err(node);
            }
            // Point the node at its successor. CAS (not store) so a
            // deleter arriving through `rebuild_cur` cannot have its
            // LOGICALLY_REMOVED bit overwritten; the same CAS clears
            // IS_BEING_DISTRIBUTED atomically with the re-publish.
            loop {
                // SAFETY: node is ours or (rebuild path) unlinked+owned.
                // ord: split-link — link-word publish/traversal contract (split-order flavor)
                let old = unsafe { (*node).next.load(Ordering::Acquire) };
                let new = (pos.cur as usize | pos.cur_tag) | (old & LOGICALLY_REMOVED);
                // SAFETY: same exclusive ownership of `node` as above —
                // no other thread can reach it before the link CAS.
                if unsafe {
                    // ord: split-link — link-word publish/traversal contract (split-order flavor)
                    (*node)
                        .next
                        .compare_exchange(old, new, Ordering::AcqRel, Ordering::Acquire)
                        .is_ok()
                } {
                    break;
                }
            }
            // Link CAS: Release publishes key/val/next, Acquire
            // revalidates against concurrent unlinks. Regular nodes link
            // without the dummy tag.
            // SAFETY: `pos.prev` is valid under RCU (revalidated by CAS).
            if unsafe {
                // ord: split-link — link-word publish/traversal contract (split-order flavor)
                (*pos.prev)
                    .compare_exchange(
                        pos.cur as usize | pos.cur_tag,
                        node as usize,
                        Ordering::AcqRel,
                        Ordering::Acquire,
                    )
                    .is_ok()
            } {
                // ord: split-size — growth heuristic; any power-of-two snapshot routes correctly
                let live = self.count.fetch_add(1, Ordering::Relaxed) + 1;
                self.maybe_grow(live);
                return Ok(());
            }
            // Lost the race: retry from a fresh search.
        }
    }

    fn delete_node(&self, key: u64, flag: usize) -> DeleteOutcome {
        debug_assert!(flag == LOGICALLY_REMOVED || flag == IS_BEING_DISTRIBUTED);
        let rank = regular_rank(key);
        loop {
            let pos = self.search(self.bucket_head(key), rank);
            if !pos.found(key) {
                return DeleteOutcome::NotFound;
            }
            let cur = pos.cur; // regular node: its link words carry no dummy tag
            // Logical delete: mark `next`. Expected is the unmarked
            // snapshot (successor dummy tag included), so exactly one
            // deleter wins; AcqRel publishes everything sequenced before
            // the mark (Lemma 4.1 on the rebuild's hazard path).
            // SAFETY: `cur` was reached by `search` inside the caller's
            // RCU read section, so the node is live for the CAS.
            if unsafe {
                // ord: split-link — link-word publish/traversal contract (split-order flavor)
                (*cur)
                    .next
                    .compare_exchange(
                        pos.next,
                        pos.next | flag,
                        Ordering::AcqRel,
                        Ordering::Acquire,
                    )
                    .is_err()
            } {
                continue; // raced another op; a fresh search decides
            }
            // ord: split-size — growth heuristic; any power-of-two snapshot routes correctly
            self.count.fetch_sub(1, Ordering::Relaxed);
            // Physical unlink; the clean word keeps the successor's tag.
            // SAFETY: `pos.prev` is a live link word from the traversal.
            if unsafe {
                // ord: split-link — link-word publish/traversal contract (split-order flavor)
                (*pos.prev)
                    .compare_exchange(
                        cur as usize,
                        pos.next & !FLAG_MASK,
                        Ordering::AcqRel,
                        Ordering::Acquire,
                    )
                    .is_ok()
            } {
                if flag == LOGICALLY_REMOVED {
                    // SAFETY: unlinked by us; reclaim after grace period.
                    unsafe { Node::defer_free(cur) };
                }
            } else if flag == IS_BEING_DISTRIBUTED {
                // The rebuild thread reuses this node: force the unlink
                // via a search over its bucket segment (unlinks every
                // marked node up to and including our rank).
                let _ = self.search(self.bucket_head(key), rank);
            }
            return DeleteOutcome::Deleted(cur);
        }
    }
}

// SAFETY: see trait contract; the implementation maintains all four
// guarantees (RCU-valid pointers, call_rcu reclamation, unlink-before-
// return for distribution, LOGICALLY_REMOVED preservation + atomic
// IS_BEING_DISTRIBUTED clear on insert) — same protocol as michael.rs,
// in split-order rank space.
unsafe impl BucketSet for SplitOrderedList {
    fn new() -> Self {
        Self::new_with_sentinels()
    }

    // lint: hot
    fn find(&self, key: u64) -> Option<&Node> {
        let pos = self.search(self.bucket_head(key), regular_rank(key));
        if pos.found(key) {
            // SAFETY: valid under the caller's RCU read-side section.
            Some(unsafe { &*pos.cur })
        } else {
            None
        }
    }

    fn insert(&self, node: *mut Node) -> Result<(), *mut Node> {
        self.insert_node(node)
    }

    fn delete(&self, key: u64, flag: usize) -> DeleteOutcome {
        self.delete_node(key, flag)
    }

    fn first(&self) -> Option<*mut Node> {
        // The chain is ordered by split-order rank, not user key, so the
        // live minimum takes a full walk (diagnostic/teardown use; the
        // rebuild hot path uses `take_first_for_distribution` instead).
        let mut best: *mut Node = std::ptr::null_mut();
        let mut best_key = u64::MAX;
        // SAFETY: head is permanent; traversal nodes are RCU-live.
        // ord: split-link — link-word publish/traversal contract (split-order flavor)
        let mut w = unsafe { (*self.head).next.load(Ordering::Acquire) };
        let mut cur = untag3(w);
        while !cur.is_null() {
            // SAFETY: RCU keeps `cur` alive.
            // ord: split-link — link-word publish/traversal contract (split-order flavor)
            let next_t = unsafe { (*cur).next.load(Ordering::Acquire) };
            if w & DUMMY_TAG == 0 && next_t & FLAG_MASK == 0 {
                // SAFETY: RCU-live; `key` is immutable.
                let k = unsafe { (*cur).key };
                if k < best_key {
                    best_key = k;
                    best = cur;
                }
            }
            w = next_t;
            cur = untag3(next_t);
        }
        if best.is_null() {
            None
        } else {
            Some(best)
        }
    }

    fn take_first_for_distribution(
        &self,
        publish: &mut dyn FnMut(*mut Node),
    ) -> Option<*mut Node> {
        // Pop the split-order head: rebuild needs *a* live node, not the
        // key minimum, and the first live regular in rank order is one
        // traversal away (amortized O(1) as the chain drains front-first).
        loop {
            let pos = self.search_first_live();
            if pos.cur_tag != 0 {
                return None; // reached the tail: nothing live remains
            }
            let cur = pos.cur;
            // Hazard publication precedes the logical delete (Alg. 3
            // lines 26 -> 29).
            publish(cur);
            // Logical removal for distribution (expected: unmarked); the
            // Release half orders the hazard publication above before
            // the mark (Lemma 4.1).
            // SAFETY: `cur` came out of the traversal under the rebuild
            // thread's RCU read section — live node, valid link word.
            if unsafe {
                // ord: split-link — link-word publish/traversal contract (split-order flavor)
                (*cur)
                    .next
                    .compare_exchange(
                        pos.next,
                        pos.next | IS_BEING_DISTRIBUTED,
                        Ordering::AcqRel,
                        Ordering::Acquire,
                    )
                    .is_err()
            } {
                continue; // raced a deleter or an insert after cur
            }
            // ord: split-size — growth heuristic; any power-of-two snapshot routes correctly
            self.count.fetch_sub(1, Ordering::Relaxed);
            // Physical unlink; on failure force it via a bucket search
            // (the rebuild reuses the node, so it must be out first).
            // SAFETY: `pos.prev` is a live link word from the traversal
            // above; the marked `cur` cannot be freed before our unlink.
            if unsafe {
                // ord: split-link — link-word publish/traversal contract (split-order flavor)
                (*pos.prev)
                    .compare_exchange(
                        cur as usize,
                        pos.next & !FLAG_MASK,
                        Ordering::AcqRel,
                        Ordering::Acquire,
                    )
                    .is_err()
            } {
                // SAFETY: key immutable, node RCU-live.
                let key = unsafe { (*cur).key };
                let _ = self.search(self.bucket_head(key), regular_rank(key));
            }
            return Some(cur);
        }
    }

    fn len(&self) -> usize {
        let mut n = 0;
        // SAFETY: head is permanent; traversal nodes are RCU-live.
        // ord: split-link — link-word publish/traversal contract (split-order flavor)
        let mut w = unsafe { (*self.head).next.load(Ordering::Acquire) };
        let mut cur = untag3(w);
        while !cur.is_null() {
            // SAFETY: RCU keeps `cur` alive.
            // ord: split-link — link-word publish/traversal contract (split-order flavor)
            let next_t = unsafe { (*cur).next.load(Ordering::Acquire) };
            if w & DUMMY_TAG == 0 && next_t & FLAG_MASK == 0 {
                n += 1;
            }
            w = next_t;
            cur = untag3(next_t);
        }
        n
    }

    fn collect(&self) -> Vec<(u64, u64)> {
        let mut out = Vec::new();
        // SAFETY: head is permanent; traversal nodes are RCU-live.
        // ord: split-link — link-word publish/traversal contract (split-order flavor)
        let mut w = unsafe { (*self.head).next.load(Ordering::Acquire) };
        let mut cur = untag3(w);
        while !cur.is_null() {
            // SAFETY: RCU keeps `cur` alive.
            // ord: split-link — link-word publish/traversal contract (split-order flavor)
            let next_t = unsafe { (*cur).next.load(Ordering::Acquire) };
            if w & DUMMY_TAG == 0 && next_t & FLAG_MASK == 0 {
                // SAFETY: `cur` is non-null here and RCU-live; the value
                // rode the Release link publish our Acquire walk saw.
                // ord: node-val — value rides the link publish; later stores racy-by-spec
                unsafe { out.push(((*cur).key, (*cur).val.load(Ordering::Relaxed))) };
            }
            w = next_t;
            cur = untag3(next_t);
        }
        // The chain is in split-order rank order; the trait promises
        // user-key order.
        out.sort_unstable_by_key(|&(k, _)| k);
        out
    }

    fn drain_exclusive(&mut self) {
        let mut cur = self.head;
        while !cur.is_null() {
            // SAFETY: exclusive access (`&mut self`), no concurrent
            // readers can exist; free everything (dummies, tail,
            // residual regulars) immediately.
            unsafe {
                // ord: unshared — exclusive access (&mut/Drop); no concurrent observers
                let next = untag3((*cur).next.load(Ordering::Relaxed));
                Node::free(cur);
                cur = next;
            }
        }
        self.head = std::ptr::null_mut();
        self.dir.teardown();
        *self.count.get_mut() = 0;
    }
}

impl Drop for SplitOrderedList {
    fn drop(&mut self) {
        self.drain_exclusive();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rcu::{rcu_barrier, RcuThread};
    use std::sync::Arc;

    fn keys(l: &SplitOrderedList) -> Vec<u64> {
        l.collect().into_iter().map(|(k, _)| k).collect()
    }

    #[test]
    fn collect_is_user_key_ordered() {
        let l = SplitOrderedList::new();
        for k in [5u64, 1, 9, 3, 7] {
            assert!(l.insert(Node::alloc(k, k * 10)).is_ok());
        }
        assert_eq!(keys(&l), vec![1, 3, 5, 7, 9]);
        assert_eq!(l.len(), 5);
    }

    #[test]
    fn duplicate_insert_rejected() {
        let l = SplitOrderedList::new();
        assert!(l.insert(Node::alloc(4, 1)).is_ok());
        let dup = Node::alloc(4, 2);
        match l.insert(dup) {
            Err(p) => {
                assert_eq!(p, dup);
                // SAFETY: rejected node never published.
                unsafe { Node::free(p) };
            }
            Ok(()) => panic!("duplicate accepted"),
        }
        assert_eq!(l.len(), 1);
        assert_eq!(l.find(4).unwrap().val.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn find_miss_and_hit() {
        let l = SplitOrderedList::new();
        for k in [2u64, 4, 6] {
            l.insert(Node::alloc(k, k)).unwrap();
        }
        assert!(l.find(3).is_none());
        assert!(l.find(0).is_none());
        assert!(l.find(7).is_none());
        assert_eq!(l.find(4).unwrap().key, 4);
    }

    #[test]
    fn delete_logical_and_reinsert() {
        let t = RcuThread::register();
        let l = SplitOrderedList::new();
        l.insert(Node::alloc(10, 1)).unwrap();
        assert!(matches!(
            l.delete(10, LOGICALLY_REMOVED),
            DeleteOutcome::Deleted(_)
        ));
        assert!(l.find(10).is_none());
        assert_eq!(l.delete(10, LOGICALLY_REMOVED), DeleteOutcome::NotFound);
        l.insert(Node::alloc(10, 2)).unwrap();
        assert_eq!(l.find(10).unwrap().val.load(Ordering::Relaxed), 2);
        t.quiescent_state();
        rcu_barrier();
    }

    #[test]
    fn delete_for_distribution_unlinks_but_does_not_free() {
        let t = RcuThread::register();
        let l = SplitOrderedList::new();
        l.insert(Node::alloc(1, 11)).unwrap();
        l.insert(Node::alloc(2, 22)).unwrap();
        let n = match l.delete(1, IS_BEING_DISTRIBUTED) {
            DeleteOutcome::Deleted(p) => p,
            _ => panic!("missing node"),
        };
        assert_eq!(keys(&l), vec![2]);
        // SAFETY: unlinked, not reclaimed by contract.
        unsafe {
            assert_eq!((*n).key, 1);
            assert_eq!((*n).flags(), IS_BEING_DISTRIBUTED);
        }
        // Reuse in another list (insert clears the distribution flag
        // atomically with the link).
        let l2 = SplitOrderedList::new();
        l2.insert(n).unwrap();
        assert_eq!(keys(&l2), vec![1]);
        t.quiescent_state();
        rcu_barrier();
    }

    #[test]
    fn insert_preserves_concurrent_logical_removal() {
        let t = RcuThread::register();
        let l = SplitOrderedList::new();
        let n = Node::alloc(5, 5);
        // SAFETY: we own n.
        unsafe { (*n).set_flag(LOGICALLY_REMOVED) };
        l.insert(n).unwrap();
        // Born dead: find must skip it; the traversal unlinks + frees.
        assert!(l.find(5).is_none());
        assert_eq!(l.len(), 0);
        t.quiescent_state();
        rcu_barrier();
    }

    #[test]
    fn first_returns_user_key_minimum() {
        let t = RcuThread::register();
        let l = SplitOrderedList::new();
        for k in [4u64, 2, 9] {
            l.insert(Node::alloc(k, k)).unwrap();
        }
        l.delete(2, LOGICALLY_REMOVED);
        let f = l.first().unwrap();
        // SAFETY: RCU-live.
        assert_eq!(unsafe { (*f).key }, 4);
        t.quiescent_state();
        rcu_barrier();
    }

    #[test]
    fn local_growth_crosses_threshold_and_keeps_membership() {
        let l = SplitOrderedList::new();
        assert_eq!(l.local_size(), 1);
        let n = 200u64;
        for k in 0..n {
            l.insert(Node::alloc(k, k + 1000)).unwrap();
        }
        // 200 live nodes over SPLIT_LOAD=2 forces several doublings.
        assert!(l.local_size() >= 32, "size {}", l.local_size());
        assert_eq!(l.len(), n as usize);
        for k in 0..n {
            assert_eq!(l.find(k).unwrap().val.load(Ordering::Relaxed), k + 1000);
        }
        assert_eq!(keys(&l), (0..n).collect::<Vec<_>>());
    }

    #[test]
    fn deep_dummy_directory_grows_past_one_segment() {
        // Push the local bucket count past SEG_SIZE so the directory
        // installs a second tree level, then verify every key.
        let l = SplitOrderedList::new();
        let n = 400u64;
        for k in 0..n {
            l.insert(Node::alloc(k, k)).unwrap();
        }
        assert!(l.local_size() > SEG_SIZE, "size {}", l.local_size());
        for k in 0..n {
            assert_eq!(l.find(k).unwrap().key, k);
        }
    }

    #[test]
    fn empty_list_edge_cases() {
        let l = SplitOrderedList::new();
        assert!(l.find(0).is_none());
        assert!(l.first().is_none());
        assert!(l.is_empty());
        assert_eq!(l.delete(0, LOGICALLY_REMOVED), DeleteOutcome::NotFound);
    }

    #[test]
    fn u64_extreme_keys() {
        let l = SplitOrderedList::new();
        for k in [0u64, 1, u64::MAX - 2, u64::MAX - 1] {
            l.insert(Node::alloc(k, k)).unwrap();
        }
        assert_eq!(keys(&l), vec![0, 1, u64::MAX - 2, u64::MAX - 1]);
        assert_eq!(l.find(u64::MAX - 1).unwrap().key, u64::MAX - 1);
    }

    #[test]
    fn concurrent_same_key_insert_exactly_one_wins() {
        for _ in 0..20 {
            let l = Arc::new(SplitOrderedList::new());
            let mut hs = Vec::new();
            for _ in 0..4 {
                let l2 = l.clone();
                hs.push(std::thread::spawn(move || {
                    let g = RcuThread::register();
                    let n = Node::alloc(42, 0);
                    let r = l2.insert(n);
                    let won = if let Err(p) = r {
                        // SAFETY: rejected, unpublished.
                        unsafe { Node::free(p) };
                        false
                    } else {
                        true
                    };
                    g.quiescent_state();
                    won
                }));
            }
            let wins = hs
                .into_iter()
                .map(|h| h.join().unwrap())
                .filter(|&x| x)
                .count();
            assert_eq!(wins, 1);
            assert_eq!(l.len(), 1);
        }
    }

    #[test]
    fn concurrent_growth_keeps_every_key() {
        let l = Arc::new(SplitOrderedList::new());
        let mut hs = Vec::new();
        for t in 0..4u64 {
            let l2 = l.clone();
            hs.push(std::thread::spawn(move || {
                let g = RcuThread::register();
                for i in 0..500u64 {
                    l2.insert(Node::alloc(t * 10_000 + i, i)).unwrap();
                    g.quiescent_state();
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(l.len(), 2000);
        assert!(l.local_size() >= 64, "size {}", l.local_size());
        let ks = keys(&l);
        assert!(ks.windows(2).all(|w| w[0] < w[1]), "not sorted/unique");
        for t in 0..4u64 {
            for i in (0..500u64).step_by(97) {
                assert!(l.find(t * 10_000 + i).is_some());
            }
        }
        rcu_barrier();
    }

    #[test]
    fn concurrent_insert_delete_churn_under_growth() {
        let l = Arc::new(SplitOrderedList::new());
        let mut hs = Vec::new();
        for t in 0..4u64 {
            let l2 = l.clone();
            hs.push(std::thread::spawn(move || {
                let g = RcuThread::register();
                for i in 0..1500u64 {
                    let k = (t * 7 + i) % 256;
                    if i % 2 == 0 {
                        if let Err(p) = l2.insert(Node::alloc(k, i)) {
                            // SAFETY: rejected, unpublished.
                            unsafe { Node::free(p) };
                        }
                    } else {
                        l2.delete(k, LOGICALLY_REMOVED);
                    }
                    g.quiescent_state();
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        // Structural invariant after the dust settles: sorted unique.
        let ks = keys(&l);
        assert!(ks.windows(2).all(|w| w[0] < w[1]));
        assert!(ks.iter().all(|&k| k < 256));
        rcu_barrier();
    }
}
