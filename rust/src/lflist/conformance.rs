//! Shared conformance suite: every [`BucketSet`] implementation must pass
//! the same behavioral contract (Algorithm 1 semantics + the DHash
//! hazard-period requirements). Invoked once per implementation via the
//! macro at the bottom.

use super::*;
use crate::rcu::{rcu_barrier, RcuThread};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

fn keys<B: BucketSet>(b: &B) -> Vec<u64> {
    b.collect().into_iter().map(|(k, _)| k).collect()
}

pub(crate) fn ordered_unique_inserts<B: BucketSet>() {
    let b = B::new();
    for k in [8u64, 3, 11, 1, 6] {
        assert!(b.insert(Node::alloc(k, k)).is_ok());
    }
    assert_eq!(keys(&b), vec![1, 3, 6, 8, 11]);
    assert_eq!(b.len(), 5);
}

pub(crate) fn duplicate_rejected<B: BucketSet>() {
    let b = B::new();
    b.insert(Node::alloc(9, 1)).unwrap();
    let dup = Node::alloc(9, 2);
    let r = b.insert(dup);
    assert!(r.is_err());
    // SAFETY: rejected node never published.
    unsafe { Node::free(r.unwrap_err()) };
    assert_eq!(b.find(9).unwrap().val.load(Ordering::SeqCst), 1);
}

pub(crate) fn delete_then_miss<B: BucketSet>() {
    let t = RcuThread::register();
    let b = B::new();
    for k in 0..16u64 {
        b.insert(Node::alloc(k, k)).unwrap();
    }
    for k in (0..16u64).step_by(2) {
        assert!(matches!(
            b.delete(k, LOGICALLY_REMOVED),
            DeleteOutcome::Deleted(_)
        ));
    }
    for k in 0..16u64 {
        assert_eq!(b.find(k).is_some(), k % 2 == 1, "key {k}");
    }
    assert_eq!(b.len(), 8);
    assert_eq!(b.delete(2, LOGICALLY_REMOVED), DeleteOutcome::NotFound);
    t.quiescent_state();
    rcu_barrier();
}

pub(crate) fn distribution_unlinks_without_reclaim<B: BucketSet>() {
    let t = RcuThread::register();
    let b = B::new();
    b.insert(Node::alloc(5, 50)).unwrap();
    b.insert(Node::alloc(6, 60)).unwrap();
    let n = match b.delete(5, IS_BEING_DISTRIBUTED) {
        DeleteOutcome::Deleted(p) => p,
        _ => panic!("expected node"),
    };
    assert_eq!(keys(&b), vec![6]);
    // The node must still be alive with the distribution flag set, owned
    // by us (the rebuild role): reuse it in a fresh bucket.
    // SAFETY: contract guarantees unlinked + unreclaimed.
    unsafe {
        assert_eq!((*n).key, 5);
        assert!((*n).flags() & IS_BEING_DISTRIBUTED != 0);
    }
    // Re-insert WITHOUT clearing the flag: insert itself must drop
    // IS_BEING_DISTRIBUTED when it publishes the new successor.
    let b2 = B::new();
    b2.insert(n).unwrap();
    let found = b2.find(5).unwrap();
    assert_eq!(found.val.load(Ordering::SeqCst), 50);
    assert_eq!(found.flags() & IS_BEING_DISTRIBUTED, 0, "flag not cleared");
    t.quiescent_state();
    rcu_barrier();
}

pub(crate) fn born_dead_insert_invisible<B: BucketSet>() {
    // §4.4 race: a hazard-period deleter marks the node before the rebuild
    // re-insert lands. The node must never become visible.
    let t = RcuThread::register();
    let b = B::new();
    let n = Node::alloc(7, 70);
    // SAFETY: we own n.
    unsafe { (*n).set_flag(LOGICALLY_REMOVED) };
    b.insert(n).unwrap();
    assert!(b.find(7).is_none());
    assert!(!keys(&b).contains(&7));
    t.quiescent_state();
    rcu_barrier();
}

pub(crate) fn first_returns_live_minimum<B: BucketSet>() {
    let t = RcuThread::register();
    let b = B::new();
    assert!(b.first().is_none());
    for k in [4u64, 2, 9] {
        b.insert(Node::alloc(k, 0)).unwrap();
    }
    b.delete(2, LOGICALLY_REMOVED);
    let f = b.first().unwrap();
    // SAFETY: RCU-live.
    assert_eq!(unsafe { (*f).key }, 4);
    t.quiescent_state();
    rcu_barrier();
}

pub(crate) fn drain_style_rebuild_empties<B: BucketSet>() {
    // Emulates the rebuild traversal: repeatedly take `first`, remove it
    // for distribution, reuse elsewhere.
    let t = RcuThread::register();
    let b = B::new();
    for k in 0..32u64 {
        b.insert(Node::alloc(k, k)).unwrap();
    }
    let b2 = B::new();
    let mut moved = 0;
    while let Some(p) = b.first() {
        // SAFETY: RCU-live.
        let key = unsafe { (*p).key };
        match b.delete(key, IS_BEING_DISTRIBUTED) {
            DeleteOutcome::Deleted(n) => {
                // insert clears IS_BEING_DISTRIBUTED itself.
                b2.insert(n).unwrap();
                moved += 1;
            }
            DeleteOutcome::NotFound => {}
        }
    }
    assert_eq!(moved, 32);
    assert!(b.is_empty());
    assert_eq!(b2.len(), 32);
    t.quiescent_state();
    rcu_barrier();
}

pub(crate) fn concurrent_churn_no_corruption<B: BucketSet>(b: Arc<B>) {
    let stop = Arc::new(AtomicBool::new(false));
    let mut hs = Vec::new();
    for tid in 0..3u64 {
        let b2 = b.clone();
        let s2 = stop.clone();
        hs.push(std::thread::spawn(move || {
            let g = RcuThread::register();
            let mut i = 0u64;
            while !s2.load(Ordering::SeqCst) {
                let k = (tid * 13 + i * 7) % 48;
                match i % 3 {
                    0 => {
                        if let Err(p) = b2.insert(Node::alloc(k, i)) {
                            // SAFETY: rejected, unpublished.
                            unsafe { Node::free(p) };
                        }
                    }
                    1 => {
                        b2.delete(k, LOGICALLY_REMOVED);
                    }
                    _ => {
                        if let Some(n) = b2.find(k) {
                            assert_eq!(n.key, k);
                        }
                    }
                }
                g.quiescent_state();
                i += 1;
            }
            i
        }));
    }
    let run_ms = crate::util::miri_clamp(200, 20) as u64;
    std::thread::sleep(std::time::Duration::from_millis(run_ms));
    stop.store(true, Ordering::SeqCst);
    let total: u64 = hs.into_iter().map(|h| h.join().unwrap()).sum();
    let floor = crate::util::miri_clamp(300, 1) as u64;
    assert!(total > floor, "too few iterations: {total}");
    let ks = keys(&*b);
    assert!(ks.windows(2).all(|w| w[0] < w[1]), "order violated: {ks:?}");
    rcu_barrier();
}

macro_rules! conformance_suite {
    ($modname:ident, $ty:ty) => {
        mod $modname {
            use super::*;

            #[test]
            fn ordered_unique_inserts() {
                super::ordered_unique_inserts::<$ty>();
            }
            #[test]
            fn duplicate_rejected() {
                super::duplicate_rejected::<$ty>();
            }
            #[test]
            fn delete_then_miss() {
                super::delete_then_miss::<$ty>();
            }
            #[test]
            fn distribution_unlinks_without_reclaim() {
                super::distribution_unlinks_without_reclaim::<$ty>();
            }
            #[test]
            fn born_dead_insert_invisible() {
                super::born_dead_insert_invisible::<$ty>();
            }
            #[test]
            fn first_returns_live_minimum() {
                super::first_returns_live_minimum::<$ty>();
            }
            #[test]
            fn drain_style_rebuild_empties() {
                super::drain_style_rebuild_empties::<$ty>();
            }
            #[test]
            fn concurrent_churn_no_corruption() {
                super::concurrent_churn_no_corruption(std::sync::Arc::new(<$ty>::new()));
            }
        }
    };
}

conformance_suite!(michael, super::MichaelList);
conformance_suite!(spinlock, super::SpinlockList);
conformance_suite!(cow, super::CowSortedArray);
conformance_suite!(split_ordered, super::SplitOrderedList);
