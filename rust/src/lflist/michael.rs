//! RCU-based lock-free ordered linked list (paper §4.1).
//!
//! This is Michael's lock-free list [SPAA'02] with the paper's three
//! modifications:
//!
//! 1. RCU replaces hazard pointers as the memory-reclamation scheme, which
//!    removes the per-step memory fences of the hazard-pointer protocol
//!    from traversal;
//! 2. the per-node 64-bit ABA `tag` field is dropped — RCU guarantees a
//!    node cannot be reclaimed (hence reused through the allocator) while
//!    any reader that might hold a reference is still inside its read-side
//!    critical section;
//! 3. reclamation uses `call_rcu`, so `delete` never blocks on readers.
//!
//! One DHash-specific subtlety remains (paper §4.4): a node removed with
//! `IS_BEING_DISTRIBUTED` is *reused* — re-inserted into the new table
//! with its flags cleared — potentially while an old-table traversal still
//! holds a reference to it. The list tolerates this because `search`
//! re-validates `*prev == cur` before acting on a loaded `next` word and
//! restarts from the (old table's) head on any mismatch, so a traversal
//! can never silently continue through a node that was unlinked under it.

use std::sync::atomic::{AtomicUsize, Ordering};

use super::{
    tag_of, untag, BucketSet, DeleteOutcome, Node, FLAG_MASK, IS_BEING_DISTRIBUTED,
    LOGICALLY_REMOVED,
};

/// Position returned by `search`: `cur` is the first live node with
/// `key >= target` (or null), `prev` the link word pointing at it.
struct Pos {
    prev: *const AtomicUsize,
    cur: *mut Node,
    /// Untagged `next` word of `cur` (0 if `cur` is null).
    next: usize,
}

impl Pos {
    #[inline(always)]
    fn found(&self, key: u64) -> bool {
        debug_assert_ne!(key, SENTINEL_KEY, "u64::MAX keys are reserved");
        // SAFETY: `cur`, when non-null, is a list node kept alive by RCU.
        // The sentinel (key == SENTINEL_KEY) is structural, never a match:
        // DHashMap reserves u64::MAX at the API boundary.
        !self.cur.is_null() && unsafe { (*self.cur).key } == key
    }
}

/// The lock-free ordered list. One instance per hash bucket.
pub struct MichaelList {
    head: AtomicUsize,
}

// SAFETY: all mutation happens through atomics; reclamation through RCU.
unsafe impl Send for MichaelList {}
unsafe impl Sync for MichaelList {}

/// Sentinel key of the permanent tail node each list ends with. Chains
/// never terminate in NULL: a reused (distributed) node's `next` word
/// therefore never transits through a value (`0`) that a stale tail
/// insert/delete CAS from the *old* table could still expect — the last
/// piece of the reuse-ABA story (see `dhash::rebuild`'s deviation note).
/// The key value `u64::MAX` is reserved; `DHashMap` rejects it.
pub const SENTINEL_KEY: u64 = u64::MAX;

impl MichaelList {
    fn new_with_sentinel() -> Self {
        let sentinel = Node::alloc(SENTINEL_KEY, 0);
        Self {
            head: AtomicUsize::new(sentinel as usize),
        }
    }

    /// True if `p` is this chain's permanent tail.
    #[inline(always)]
    fn is_sentinel(p: *mut Node) -> bool {
        // SAFETY: sentinel nodes live as long as the list.
        !p.is_null() && unsafe { (*p).key } == SENTINEL_KEY
    }

    /// Michael's search, RCU flavor. Returns the position for `key`,
    /// physically unlinking every marked node encountered on the way.
    ///
    /// Unlink/reclaim protocol: the thread whose CAS unlinks a node owns
    /// the reclamation decision. Nodes whose flags are exactly
    /// `LOGICALLY_REMOVED` are handed to `call_rcu`; nodes carrying
    /// `IS_BEING_DISTRIBUTED` (alone or together with a concurrent
    /// `LOGICALLY_REMOVED` from the hazard-period delete path) belong to
    /// the rebuild thread, which re-inserts or frees them itself.
    /// Memory orderings (DESIGN.md §Memory orderings, cluster L): every
    /// link-word load is `Acquire` and every link-word CAS publishes with
    /// `Release` (via `AcqRel`). Invariant: a traversal that observes a
    /// node pointer observes the node's `key`/initial `val` (written
    /// before the Release link CAS that published it), and a traversal
    /// that observes a mark observes everything the marker published
    /// first — for the rebuild path that includes the `rebuild_cur`
    /// hazard store (Lemma 4.1 needs mark-implies-hazard-visible, which
    /// is exactly Release→Acquire on the link word; no total order over
    /// unrelated atomics, i.e. no SeqCst, is required). Failed CASes use
    /// `Acquire`: the observed value seeds the next iteration's reads.
    fn search(&self, key: u64) -> Pos {
        'retry: loop {
            let mut prev: *const AtomicUsize = &self.head;
            // SAFETY: `prev` points to either the bucket head or the
            // `next` field of a node kept alive by RCU for the duration of
            // the caller's read-side critical section.
            // ord: michael-link — link-word publish/traversal contract (Michael 2002)
            let mut cur = untag(unsafe { (*prev).load(Ordering::Acquire) });
            loop {
                if cur.is_null() {
                    return Pos {
                        prev,
                        cur,
                        next: 0,
                    };
                }
                // SAFETY: as above; RCU keeps `cur` alive.
                // ord: michael-link — link-word publish/traversal contract (Michael 2002)
                let next_t = unsafe { (*cur).next.load(Ordering::Acquire) };
                // Re-validate: `prev` must still point at `cur` with no
                // flags. Fails if (a) a concurrent op unlinked/inserted
                // here, (b) the node holding `prev` got marked, or (c) a
                // rebuild reused a node under us. Restart from head.
                // SAFETY: `prev` is the head word or a link word inside a
                // node reached by this traversal; RCU keeps either alive
                // for the duration of the caller's read section.
                // ord: michael-link — link-word publish/traversal contract (Michael 2002)
                if unsafe { (*prev).load(Ordering::Acquire) } != cur as usize {
                    continue 'retry;
                }
                if tag_of(next_t) != 0 {
                    // `cur` is logically deleted: unlink it before moving
                    // past (the §4.4 rule — never traverse beyond a marked
                    // node without removing it first).
                    let next = next_t & !FLAG_MASK;
                    // SAFETY: `prev` stays a live link word (RCU, as
                    // above); the CAS only republishes values read from it.
                    if unsafe {
                        // ord: michael-link — link-word publish/traversal contract (Michael 2002)
                        (*prev)
                            .compare_exchange(
                                cur as usize,
                                next,
                                Ordering::AcqRel,
                                Ordering::Acquire,
                            )
                            .is_ok()
                    } {
                        if tag_of(next_t) == LOGICALLY_REMOVED {
                            // SAFETY: we won the unlink CAS; the node is
                            // unreachable for new readers and ours to
                            // reclaim after a grace period.
                            unsafe { Node::defer_free(cur) };
                        }
                        cur = next as *mut Node;
                        continue;
                    } else {
                        continue 'retry;
                    }
                }
                // SAFETY: RCU keeps `cur` alive.
                let ckey = unsafe { (*cur).key };
                if ckey >= key {
                    return Pos {
                        prev,
                        cur,
                        next: next_t,
                    };
                }
                // SAFETY: `cur` stays valid; taking the address of its
                // atomic `next` field is safe under RCU.
                prev = unsafe { &(*cur).next as *const AtomicUsize };
                cur = untag(next_t);
            }
        }
    }

    /// Lock-free insert preserving a concurrently-set `LOGICALLY_REMOVED`
    /// bit on `node` (hazard-period semantics, see trait docs).
    fn insert_node(&self, node: *mut Node) -> Result<(), *mut Node> {
        // SAFETY: caller owns `node` (unpublished here); RCU protects the
        // list nodes touched by `search`.
        let key = unsafe { (*node).key };
        loop {
            let pos = self.search(key);
            if pos.found(key) {
                return Err(node);
            }
            // Point the node at its successor. CAS (not store) so a delete
            // arriving through `rebuild_cur` between our load and the link
            // CAS cannot have its LOGICALLY_REMOVED bit overwritten.
            // Acquire load + AcqRel CAS: must observe (and preserve) a
            // concurrent deleter's mark, and the successor pointer must be
            // in place before the link CAS below publishes the node.
            loop {
                // SAFETY: node is ours or (rebuild path) unlinked + owned.
                // ord: michael-link — link-word publish/traversal contract (Michael 2002)
                let old = unsafe { (*node).next.load(Ordering::Acquire) };
                let new = pos.cur as usize | (old & LOGICALLY_REMOVED);
                // SAFETY: same exclusive ownership of `node` as the load
                // above — no other thread can reach it before the link CAS.
                if unsafe {
                    // ord: michael-link — link-word publish/traversal contract (Michael 2002)
                    (*node)
                        .next
                        .compare_exchange(old, new, Ordering::AcqRel, Ordering::Acquire)
                        .is_ok()
                } {
                    break;
                }
            }
            // Link CAS. Release half publishes the node's key/val/next to
            // any traversal that Acquire-loads this link word; Acquire
            // half revalidates against concurrent unlinks.
            // SAFETY: `pos.prev` valid under RCU (revalidated by the CAS).
            if unsafe {
                // ord: michael-link — link-word publish/traversal contract (Michael 2002)
                (*pos.prev)
                    .compare_exchange(
                        pos.cur as usize,
                        node as usize,
                        Ordering::AcqRel,
                        Ordering::Acquire,
                    )
                    .is_ok()
            } {
                return Ok(());
            }
            // Lost the race: retry from a fresh search.
        }
    }

    fn delete_node(&self, key: u64, flag: usize) -> DeleteOutcome {
        debug_assert!(flag == LOGICALLY_REMOVED || flag == IS_BEING_DISTRIBUTED);
        loop {
            let pos = self.search(key);
            if !pos.found(key) {
                return DeleteOutcome::NotFound;
            }
            let cur = pos.cur;
            // Logical delete: mark `next`. The expected value is the
            // unmarked snapshot, so exactly one deleter can win. AcqRel:
            // the Release half makes the mark (delete's linearization
            // point) publish everything sequenced before it — on the
            // rebuild's hazard path that is the `rebuild_cur` store Lemma
            // 4.1 depends on.
            // SAFETY: `cur` was reached by `search` inside the caller's
            // RCU read section, so the node is live for the CAS.
            if unsafe {
                // ord: michael-link — link-word publish/traversal contract (Michael 2002)
                (*cur)
                    .next
                    .compare_exchange(
                        pos.next,
                        pos.next | flag,
                        Ordering::AcqRel,
                        Ordering::Acquire,
                    )
                    .is_err()
            } {
                // Another op marked or relinked `cur`; retry. If it was
                // deleted by someone else, the fresh search reports
                // NotFound.
                continue;
            }
            // Physical unlink. On success the unlinker reclaims iff the
            // node carries only LOGICALLY_REMOVED. AcqRel/Acquire as in
            // `search`'s unlink CAS.
            // SAFETY: `pos.prev` is a live link word from the same
            // traversal (RCU read section pins it).
            if unsafe {
                // ord: michael-link — link-word publish/traversal contract (Michael 2002)
                (*pos.prev)
                    .compare_exchange(cur as usize, pos.next, Ordering::AcqRel, Ordering::Acquire)
                    .is_ok()
            } {
                if flag == LOGICALLY_REMOVED {
                    // SAFETY: unlinked by us; reclaim after grace period.
                    unsafe { Node::defer_free(cur) };
                }
            } else if flag == IS_BEING_DISTRIBUTED {
                // The rebuild thread is about to *reuse* this node, so it
                // must be physically out of the list first. `search` walks
                // until it reaches a key >= ours and unlinks every marked
                // node on the way, so one call suffices to guarantee the
                // unlink happened (here or elsewhere).
                let _ = self.search(key);
            }
            return DeleteOutcome::Deleted(cur);
        }
    }
}

// SAFETY: see trait contract; the implementation above maintains all four
// guarantees (RCU-valid pointers, call_rcu reclamation, unlink-before-
// return for distribution, LOGICALLY_REMOVED preservation on insert).
unsafe impl BucketSet for MichaelList {
    fn new() -> Self {
        Self::new_with_sentinel()
    }

    // lint: hot
    fn find(&self, key: u64) -> Option<&Node> {
        let pos = self.search(key);
        if pos.found(key) {
            // SAFETY: valid under the caller's RCU read-side section.
            Some(unsafe { &*pos.cur })
        } else {
            None
        }
    }

    fn insert(&self, node: *mut Node) -> Result<(), *mut Node> {
        self.insert_node(node)
    }

    fn delete(&self, key: u64, flag: usize) -> DeleteOutcome {
        self.delete_node(key, flag)
    }

    fn first(&self) -> Option<*mut Node> {
        // key 0 is <= every key, so this returns the first live node and
        // opportunistically unlinks marked ones at the front.
        let pos = self.search(0);
        if pos.cur.is_null() || Self::is_sentinel(pos.cur) {
            None
        } else {
            Some(pos.cur)
        }
    }

    fn take_first_for_distribution(
        &self,
        publish: &mut dyn FnMut(*mut Node),
    ) -> Option<*mut Node> {
        // Fused first() + delete(key, DIST): one search instead of two
        // (the default impl re-searches by key, which for the head node
        // walks the same prefix again). §Perf opt 2.
        loop {
            let pos = self.search(0);
            if pos.cur.is_null() || Self::is_sentinel(pos.cur) {
                return None;
            }
            let cur = pos.cur;
            // Hazard publication precedes the logical delete (Alg. 3
            // lines 26 -> 29).
            publish(cur);
            // Logical removal for distribution (expected: unmarked). The
            // AcqRel mark's Release half orders the hazard publication
            // above before the mark: a reader that sees this node marked
            // (and thus possibly missing from the old table) is guaranteed
            // to see `rebuild_cur` pointing at it (Lemma 4.1).
            // SAFETY: `cur` came out of `search` under the rebuild
            // thread's RCU read section — live node, valid link word.
            if unsafe {
                // ord: michael-link — link-word publish/traversal contract (Michael 2002)
                (*cur)
                    .next
                    .compare_exchange(
                        pos.next,
                        pos.next | IS_BEING_DISTRIBUTED,
                        Ordering::AcqRel,
                        Ordering::Acquire,
                    )
                    .is_err()
            } {
                continue; // raced a deleter or an insert after cur
            }
            // Physical unlink; on failure force it via a search (the
            // rebuild reuses the node, so it must be out of the chain).
            // SAFETY: `pos.prev` is a live link word from the traversal
            // above; the marked `cur` cannot be freed before our unlink.
            if unsafe {
                // ord: michael-link — link-word publish/traversal contract (Michael 2002)
                (*pos.prev)
                    .compare_exchange(cur as usize, pos.next, Ordering::AcqRel, Ordering::Acquire)
                    .is_err()
            } {
                // SAFETY: key immutable, node RCU-live.
                let _ = self.search(unsafe { (*cur).key });
            }
            return Some(cur);
        }
    }

    fn len(&self) -> usize {
        self.collect().len()
    }

    fn collect(&self) -> Vec<(u64, u64)> {
        let mut out = Vec::new();
        // ord: michael-link — link-word publish/traversal contract (Michael 2002)
        let mut cur = untag(self.head.load(Ordering::Acquire));
        while !cur.is_null() {
            // SAFETY: alive under RCU (callers hold a read-side section;
            // tests hold exclusive access).
            // ord: michael-link — link-word publish/traversal contract (Michael 2002)
            let next_t = unsafe { (*cur).next.load(Ordering::Acquire) };
            if tag_of(next_t) == 0 && !Self::is_sentinel(cur) {
                // Relaxed val: the initial value was published by the
                // Release link CAS our Acquire walk synchronized with;
                // later upserts are racy-by-spec for a snapshot.
                // SAFETY: `cur` is non-null here and RCU-live, as above.
                // ord: node-val — value rides the link publish; later stores racy-by-spec
                unsafe { out.push(((*cur).key, (*cur).val.load(Ordering::Relaxed))) };
            }
            cur = untag(next_t);
        }
        out
    }

    fn drain_exclusive(&mut self) {
        let mut cur = untag(*self.head.get_mut());
        while !cur.is_null() {
            // SAFETY: exclusive access (`&mut self`), no concurrent
            // readers can exist; free immediately (Relaxed suffices).
            unsafe {
                // ord: unshared — exclusive access (&mut/Drop); no concurrent observers
                let next = untag((*cur).next.load(Ordering::Relaxed));
                Node::free(cur);
                cur = next;
            }
        }
        *self.head.get_mut() = 0;
    }
}

impl Drop for MichaelList {
    fn drop(&mut self) {
        self.drain_exclusive();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rcu::{rcu_barrier, RcuThread};
    use std::sync::atomic::AtomicBool;
    use std::sync::Arc;

    fn keys(list: &MichaelList) -> Vec<u64> {
        list.collect().into_iter().map(|(k, _)| k).collect()
    }

    #[test]
    fn insert_keeps_order() {
        let l = MichaelList::new();
        for k in [5u64, 1, 9, 3, 7] {
            assert!(l.insert(Node::alloc(k, k * 10)).is_ok());
        }
        assert_eq!(keys(&l), vec![1, 3, 5, 7, 9]);
    }

    #[test]
    fn duplicate_insert_rejected() {
        let l = MichaelList::new();
        assert!(l.insert(Node::alloc(4, 1)).is_ok());
        let dup = Node::alloc(4, 2);
        match l.insert(dup) {
            Err(p) => {
                assert_eq!(p, dup);
                // SAFETY: rejected node never published.
                unsafe { Node::free(p) };
            }
            Ok(()) => panic!("duplicate accepted"),
        }
        assert_eq!(l.len(), 1);
        assert_eq!(l.find(4).unwrap().val.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn find_miss_and_hit() {
        let l = MichaelList::new();
        for k in [2u64, 4, 6] {
            l.insert(Node::alloc(k, k)).unwrap();
        }
        assert!(l.find(3).is_none());
        assert!(l.find(0).is_none());
        assert!(l.find(7).is_none());
        assert_eq!(l.find(4).unwrap().key, 4);
    }

    #[test]
    fn delete_logical_and_reinsert() {
        let t = RcuThread::register();
        let l = MichaelList::new();
        l.insert(Node::alloc(10, 1)).unwrap();
        assert!(matches!(
            l.delete(10, LOGICALLY_REMOVED),
            DeleteOutcome::Deleted(_)
        ));
        assert!(l.find(10).is_none());
        assert_eq!(l.delete(10, LOGICALLY_REMOVED), DeleteOutcome::NotFound);
        // Same key can be inserted again.
        l.insert(Node::alloc(10, 2)).unwrap();
        assert_eq!(l.find(10).unwrap().val.load(Ordering::Relaxed), 2);
        t.quiescent_state();
        rcu_barrier();
    }

    #[test]
    fn delete_for_distribution_unlinks_but_does_not_free() {
        let t = RcuThread::register();
        let l = MichaelList::new();
        l.insert(Node::alloc(1, 11)).unwrap();
        l.insert(Node::alloc(2, 22)).unwrap();
        let n = match l.delete(1, IS_BEING_DISTRIBUTED) {
            DeleteOutcome::Deleted(p) => p,
            _ => panic!("missing node"),
        };
        // Physically unlinked: not reachable, len drops.
        assert_eq!(keys(&l), vec![2]);
        // Node memory still live and owned by us (the "rebuild thread"):
        // SAFETY: unlinked, not reclaimed by contract.
        unsafe {
            assert_eq!((*n).key, 1);
            assert_eq!((*n).flags(), IS_BEING_DISTRIBUTED);
        }
        // Reuse it in another list, as rebuild does (insert clears the
        // distribution flag atomically with the link).
        let l2 = MichaelList::new();
        l2.insert(n).unwrap();
        assert_eq!(keys(&l2), vec![1]);
        t.quiescent_state();
        rcu_barrier();
    }

    #[test]
    fn insert_preserves_concurrent_logical_removal() {
        // Simulates the §4.4 hazard-period race: a deleter marks the node
        // through rebuild_cur *while* the rebuild thread re-inserts it.
        let t = RcuThread::register();
        let l = MichaelList::new();
        let n = Node::alloc(5, 5);
        // Deleter marks first (worst case), then insert runs.
        // SAFETY: we own n.
        unsafe { (*n).set_flag(LOGICALLY_REMOVED) };
        l.insert(n).unwrap();
        // The node is in the list but born dead: find must skip it and the
        // traversal unlinks + frees it.
        assert!(l.find(5).is_none());
        assert_eq!(l.len(), 0);
        t.quiescent_state();
        rcu_barrier();
    }

    #[test]
    fn first_skips_marked_nodes() {
        let t = RcuThread::register();
        let l = MichaelList::new();
        for k in [1u64, 2, 3] {
            l.insert(Node::alloc(k, k)).unwrap();
        }
        l.delete(1, LOGICALLY_REMOVED);
        let f = l.first().unwrap();
        // SAFETY: RCU-live.
        assert_eq!(unsafe { (*f).key }, 2);
        t.quiescent_state();
        rcu_barrier();
    }

    #[test]
    fn empty_list_edge_cases() {
        let l = MichaelList::new();
        assert!(l.find(0).is_none());
        assert!(l.first().is_none());
        assert!(l.is_empty());
        assert_eq!(l.delete(0, LOGICALLY_REMOVED), DeleteOutcome::NotFound);
    }

    #[test]
    fn u64_extreme_keys() {
        // u64::MAX itself is the reserved sentinel key; MAX-1 is the
        // largest storable key.
        let l = MichaelList::new();
        for k in [0u64, 1, u64::MAX - 2, u64::MAX - 1] {
            l.insert(Node::alloc(k, k)).unwrap();
        }
        assert_eq!(keys(&l), vec![0, 1, u64::MAX - 2, u64::MAX - 1]);
        assert_eq!(l.find(u64::MAX - 1).unwrap().key, u64::MAX - 1);
    }

    #[test]
    fn concurrent_disjoint_inserts() {
        let l = Arc::new(MichaelList::new());
        let mut hs = Vec::new();
        for t in 0..4u64 {
            let l2 = l.clone();
            hs.push(std::thread::spawn(move || {
                let g = RcuThread::register();
                for i in 0..250u64 {
                    l2.insert(Node::alloc(t * 1000 + i, i)).unwrap();
                    g.quiescent_state();
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(l.len(), 1000);
        let ks = keys(&l);
        assert!(ks.windows(2).all(|w| w[0] < w[1]), "not sorted/unique");
    }

    #[test]
    fn concurrent_same_key_insert_exactly_one_wins() {
        for _ in 0..20 {
            let l = Arc::new(MichaelList::new());
            let mut hs = Vec::new();
            for _ in 0..4 {
                let l2 = l.clone();
                hs.push(std::thread::spawn(move || {
                    let g = RcuThread::register();
                    let n = Node::alloc(42, 0);
                    let r = l2.insert(n);
                    if let Err(p) = r {
                        // SAFETY: rejected, unpublished.
                        unsafe { Node::free(p) };
                        g.quiescent_state();
                        false
                    } else {
                        g.quiescent_state();
                        true
                    }
                }));
            }
            let wins = hs
                .into_iter()
                .map(|h| h.join().unwrap())
                .filter(|&x| x)
                .count();
            assert_eq!(wins, 1);
            assert_eq!(l.len(), 1);
        }
    }

    #[test]
    fn concurrent_insert_delete_churn() {
        let l = Arc::new(MichaelList::new());
        let stop = Arc::new(AtomicBool::new(false));
        let mut hs = Vec::new();
        for t in 0..4u64 {
            let l2 = l.clone();
            let s2 = stop.clone();
            hs.push(std::thread::spawn(move || {
                let g = RcuThread::register();
                let mut i = 0u64;
                while !s2.load(Ordering::Relaxed) {
                    let k = (t * 7 + i) % 64;
                    if i % 2 == 0 {
                        if let Err(p) = l2.insert(Node::alloc(k, i)) {
                            // SAFETY: rejected, unpublished.
                            unsafe { Node::free(p) };
                        }
                    } else {
                        l2.delete(k, LOGICALLY_REMOVED);
                    }
                    g.quiescent_state();
                    i += 1;
                }
                i
            }));
        }
        std::thread::sleep(std::time::Duration::from_millis(300));
        stop.store(true, Ordering::Relaxed);
        let total: u64 = hs.into_iter().map(|h| h.join().unwrap()).sum();
        assert!(total > 1000, "too few iterations: {total}");
        // Structural invariant after the dust settles: sorted unique keys.
        let ks = keys(&l);
        assert!(ks.windows(2).all(|w| w[0] < w[1]));
        rcu_barrier();
    }
}
